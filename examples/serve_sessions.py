"""Serving example: batched decode engine + Redynis session router.

A 4-pod cluster serves a zipfian session stream; session caches migrate to
their traffic sources, and killing the leader pod mid-run exercises the
heartbeat + bully re-election (the paper's §11 future work, implemented).

Run: PYTHONPATH=src python examples/serve_sessions.py
"""

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build
from repro.serving import Request, ServeEngine, SessionRouter
from repro.serving.kvcache import state_bytes

cfg = reduced(get_config("qwen3-1.7b"))
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
engine = ServeEngine(model, params, num_lanes=8, cache_len=128)
router = SessionRouter(
    num_pods=4,
    max_sessions=64,
    sweep_period=20,
    session_bytes=state_bytes(engine.state) / 8,
)

rng = np.random.default_rng(0)
SESSIONS = 24
home = {f"s{i}": i % 4 for i in range(SESSIONS)}
ranks = np.arange(1, SESSIONS + 1) ** -1.2
pop = ranks / ranks.sum()

for i in range(150):
    sid = f"s{rng.choice(SESSIONS, p=pop)}"
    route = router.route(sid, home[sid])
    if engine.lanes.lookup(sid) is None:
        engine.admit(
            Request(sid, rng.integers(0, cfg.vocab_size, 12), max_new=6)
        )
    engine.step()
    router.tick()
    if i == 75:
        print(f"killing leader pod {router.leader} ...")
        router.fail_pod(router.leader)

engine.run_to_completion()
print(f"tokens generated: {engine.tokens_out}")
print(f"session-cache hit rate: {router.hit_rate():.1%}")
print(f"cache migrations: {router.stats['migrations']} "
      f"({router.stats['migrated_bytes']/1e6:.0f} MB moved)")
print(f"leader after failure: pod {router.leader} "
      f"({router.stats['elections']} election)")
