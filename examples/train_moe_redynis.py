"""End-to-end driver: train a reduced deepseek-moe for a few hundred steps
on CPU with the full production loop — grad-accumulated steps, async
checkpointing, and both Redynis daemons (expert replica cache + hot-row
embedding) repartitioning live state as traffic statistics accumulate.

Run: PYTHONPATH=src python examples/train_moe_redynis.py [--steps 200]
"""

import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import build
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="deepseek-moe-16b")
args = ap.parse_args()

cfg = dataclasses.replace(
    reduced(get_config(args.arch)), sweep_period=10, hot_embed_rows=64
)
model = build(cfg)
print(f"{cfg.name} (reduced): {model.num_params()/1e6:.2f}M params, "
      f"{cfg.num_experts} experts top-{cfg.top_k}, "
      f"{cfg.hot_expert_slots} replica slots/layer")

with tempfile.TemporaryDirectory() as ckpt_dir:
    trainer = Trainer(
        model,
        TrainConfig(
            opt=OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
            microbatches=2,
            checkpoint_dir=ckpt_dir,
            checkpoint_every=50,
            log_every=20,
        ),
        num_nodes=4,  # Redynis sees 4 EP "nodes"
    )
    pipe = Pipeline(
        DataConfig(vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, zipf_a=1.3)
    )
    state = trainer.init_state(jax.random.PRNGKey(0))
    state, hist = trainer.run(state, pipe, args.steps)

    print(f"\nloss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    print(f"hot-path traffic fraction: {hist[-1].get('moe_hot_frac', 0):.1%}")
    print(f"token drop rate:           {hist[-1].get('moe_dropped', 0):.1%}")
    print(f"expert sweeps: {int(state.expert_placement.sweeps)}, "
          f"replica hit rate {float(trainer.expert_daemon.hit_rate(state.expert_placement)):.1%}")
    print(f"embed sweeps:  {int(state.hot_embed.sweeps)}, "
          f"hot-row hit rate {float(trainer.embed_daemon.hit_rate(state.hot_embed)):.1%}")
