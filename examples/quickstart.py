"""Quickstart: the paper's core loop in one page.

Builds a 3-node metadata cluster (the paper's testbed size), streams a
skewed workload at it, runs the placement daemon, and shows replicas
following traffic — then the placement-policy API racing decision rules
through the trace simulator, then the same engine applied to MoE expert
placement.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core import (
    PlacementDaemon,
    create_store,
    record_accesses,
    max_coefficient,
)
from repro.core.expert_placement import ExpertPlacement

# --- 1. the paper's object/node world: keys on a 3-node Redis cluster ------
K, N = 100, 3
store = create_store(K, N)
store = store._replace(
    hosts=jnp.zeros((K, N), bool).at[:, 0].set(True),  # everything on node 0
    live=jnp.ones((K,), bool),
    home=jnp.zeros((K,), jnp.int32),
)
daemon = PlacementDaemon(num_nodes=N, h=max_coefficient(N), expiry=100)

rng = np.random.default_rng(0)
for tick in range(10):
    # zipfian traffic: hot keys 0..9 requested mostly from node 2
    hot = rng.integers(0, 10, 300)
    cold = rng.integers(10, K, 30)
    keys = jnp.asarray(np.concatenate([hot, cold]), jnp.int32)
    nodes = jnp.asarray(
        np.concatenate([np.full(300, 2), rng.integers(0, N, 30)]), jnp.int32
    )
    store = record_accesses(store, keys, nodes, now=tick)
    plan, store = daemon.step(store, now=tick)

hosts = np.asarray(store.hosts)
print("hot keys now replicated on node 2:", hosts[:10, 2].all())
print(
    "mean replicas/key — hot: %.2f  cold: %.2f"
    % (hosts[:10].sum(1).mean(), hosts[10:].sum(1).mean())
)

# --- 2. placement policies as first-class values ----------------------------
# The decision rule is a value: pass any registered policy to the trace
# simulator. (The old `scenario=Scenario.X` enum spelling is removed; the
# simulator raises with the exact policy replacement if you pass one.)
from repro.kvsim import (
    ClusterConfig,
    CostGreedyPolicy,
    RedynisPolicy,
    ServiceConfig,
    SizeAwarePolicy,
    StaticPolicy,
    TelemetryConfig,
    TopKPolicy,
    WorkloadConfig,
    describe_policy,
    run_scenario,
)

wl = WorkloadConfig(num_requests=5_000, num_keys=200, skewed=True, affinity=0.7)
cl = ClusterConfig()
print("\npolicy head-to-head (skewed trace, 3-node testbed):")
for pol in (
    StaticPolicy(mode="remote"),  # the paper's worst-case baseline
    RedynisPolicy(),  # Algorithm 3 at the starvation-safe H = 1/n
    RedynisPolicy(h=0.05, decay=0.9),  # more replication, decayed counters
    TopKPolicy(k=20),  # replicate the 20 globally hottest keys
):
    r = run_scenario(wl, cl, pol)
    print(
        f"  {describe_policy(pol):28s} hit={r.hit_rate:.3f} "
        f"tput={r.throughput_ops_s:7.1f} ops/s"
    )

# --- 2b. tails, not means: in-scan telemetry --------------------------------
# Means hide exactly what geo round-trips inflate. telemetry= makes the
# fused engine accumulate log-bin latency histograms and per-chunk series
# inside the scan; run_scenario then also returns a SimTrace with
# interpolated quantiles and convergence diagnostics.
print("\np99 head-to-head (same trace, telemetry enabled):")
for pol in (
    StaticPolicy(mode="remote"),
    RedynisPolicy(),
    RedynisPolicy(h=0.05, decay=0.9),
    TopKPolicy(k=20),
):
    r, trace = run_scenario(wl, cl, pol, telemetry=TelemetryConfig())
    p50, p99 = trace.quantiles([0.5, 0.99])
    print(
        f"  {describe_policy(pol):28s} p50={p50:6.1f} ms  p99={p99:6.1f} ms  "
        f"converged@chunk {trace.convergence_chunk()}"
    )

# --- 2c. queueing-aware service times: size-aware vs cost-greedy ------------
# service= turns on the M/M/1 contention term: each request's latency gains
# a wait proportional to rho/(1-rho) on its serving node, where rho folds
# size-proportional service demand (object_bytes / serve_bytes_per_ms)
# against per-node capacity. Under lognormal object sizes, cost-per-KiB
# admission (costgreedy) strands hot large objects on one owner node;
# sizeaware's small/large pools replicate them with a bounded fanout, so its
# tail is lower even though both replicate aggressively. Off by default —
# service=None replays the exact uncontended program.
from repro.kvsim import wan5_cluster

wl_sz = WorkloadConfig(
    num_requests=8_000, num_keys=1_000, skewed=True, num_nodes=5,
    region_weights=(0.2,) * 5, affinity=0.8, read_fraction=1.0,
    object_bytes_sigma=1.0,
)
cl_sz = wan5_cluster()._replace(
    service=ServiceConfig(serve_bytes_per_ms=128.0, capacity_factor=1.0)
)
print("\ncontention on (M/M/1 queueing), sizeaware vs costgreedy:")
for pol in (SizeAwarePolicy(), CostGreedyPolicy()):
    r, trace = run_scenario(wl_sz, cl_sz, pol, telemetry=TelemetryConfig())
    p50, p99 = trace.quantiles([0.5, 0.99])
    print(
        f"  {describe_policy(pol):28s} p50={p50:6.1f} ms  p99={p99:6.1f} ms  "
        f"peak rho={float(trace.load_factor.max()):.3f}"
    )

# --- 2d. the routing tier: how stale can the directory be? ------------------
# Real routers don't read the daemon's ownership map synchronously — they
# hold a cached view that lags placement by a publish interval. routing=
# turns on that tier: consults on the read path, a versioned publish queue
# lagging publish_lag_chunks behind daemon decisions, and a mis-route
# detour (forward hop + redirect) whenever the published owner is stale.
# A rotating-hotspot workload makes placement genuinely move, so lag
# genuinely mis-routes; sweep the lag to price your consistency budget.
# Off by default — routing=None replays the exact unrouted program.
from repro.kvsim import RoutingConfig, diurnal_workload

wl_rt = diurnal_workload(
    num_requests=10_000, num_keys=400, affinity=0.8, read_fraction=0.7
)
cl_rt = wan5_cluster()
r_static, _ = run_scenario(
    wl_rt, cl_rt, StaticPolicy(mode="replicated"), daemon_interval=100,
    telemetry=TelemetryConfig(),
)
print(
    "\nstaleness sweep (diurnal wan5; best lag-free static: "
    f"replicated mean={r_static.mean_latency_ms:.1f} ms):"
)
for lag in (0, 8, 64):
    r, trace = run_scenario(
        wl_rt, cl_rt._replace(routing=RoutingConfig(publish_lag_chunks=lag)),
        RedynisPolicy(), daemon_interval=100, telemetry=TelemetryConfig(),
    )
    beats = "beats it" if r.mean_latency_ms < r_static.mean_latency_ms \
        else "loses"
    print(
        f"  publish_lag={lag:3d}  mean={r.mean_latency_ms:6.1f} ms  "
        f"mis-routes={int(r.mis_routes):5d}  "
        f"peak mis-route rate={float(trace.mis_route_rate.max()):.2%}  "
        f"({beats})"
    )

# --- 2e. latency provenance: WHERE do the milliseconds come from? -----------
# attribution= decomposes every request's latency along the 8-component
# taxonomy priced in kernels/chunk_replay/ref.py (component sums
# reconstruct the total exactly). The head-to-head the paper's argument
# rests on: static replication kills read RTT but pays the write-broadcast
# leg on EVERY write, while redynis pays a small transient routing-detour
# (stale-directory redirects while placement converges) instead. Off by
# default — attribution=None replays the bit-exact unattributed program.
from repro.kvsim import AttributionConfig, wan5_workload

wl_at = wan5_workload(num_requests=10_000, num_keys=400, read_fraction=0.9)
cl_at = wan5_cluster()._replace(
    service=ServiceConfig(serve_bytes_per_ms=128.0, capacity_factor=2.0),
    routing=RoutingConfig(publish_lag_chunks=2, cache_entries=64),
)
print("\nlatency attribution (wan5, 90% reads), redynis vs replicated:")
breakdowns = {}
for pol in (RedynisPolicy(h=0.2), StaticPolicy(mode="replicated")):
    r, trace = run_scenario(
        wl_at, cl_at, pol,
        telemetry=TelemetryConfig(attribution=AttributionConfig()),
    )
    attr = trace.attribution
    breakdowns[describe_policy(pol)] = attr
    top3 = sorted(attr.items(), key=lambda kv: -kv[1]["mean_ms"])[:3]
    parts = "  ".join(
        f"{name}={s['mean_ms']:.1f}ms({s['share']:.0%})" for name, s in top3
    )
    print(f"  {describe_policy(pol):28s} mean={r.mean_latency_ms:6.1f} ms  {parts}")
rd, st_ = breakdowns.values()
print(
    "  -> replicated pays the broadcast leg "
    f"({st_['write_broadcast']['mean_ms']:.1f} ms/req), redynis trades it "
    f"for a {rd['routing_detour']['mean_ms']:.2f} ms detour + "
    f"{rd['directory_fetch']['mean_ms']:.2f} ms directory-fetch cost"
)

# --- 2f. failure drill: what does replication buy when a region dies? -------
# faults= schedules a membership timeline (kvsim/faults.py). Crash the
# hottest region mid-trace: requests from the dead region are refused,
# reads fall back to the nearest LIVE replica, writes fail over to the
# first live master, and — the dynamic-placement payoff — the redynis
# daemon re-seeds crash-wiped keys, while a static map never repairs.
# Off by default — faults=None replays the bit-exact fault-free program.
from repro.kvsim import region_outage

wl_f = wan5_workload(
    num_requests=10_000, num_keys=400, affinity=0.8, read_fraction=0.7
)
outage = region_outage(0, 40, 30, mode="crash")  # chunks [40, 70)
print("\nregion-outage drill (wan5, crash hottest region chunks 40-70):")
for pol in (RedynisPolicy(), StaticPolicy(mode="replicated")):
    r, trace = run_scenario(
        wl_f, wan5_cluster()._replace(faults=outage), pol,
        daemon_interval=100, telemetry=TelemetryConfig(),
    )
    rec = trace.recovery_chunks(40)
    print(
        f"  {describe_policy(pol):28s} min avail="
        f"{float(trace.availability.min()):.2f}  "
        f"unavail reads={int(r.unavailable_reads):4d}  "
        f"failovers={int(r.failovers):4d}  "
        f"repairs={int(r.repair_moves):3d}  "
        f"recovery={'never' if rec < 0 else f'{rec} chunks'}"
    )
print(
    "  -> both refuse the dead region's own traffic, but only redynis "
    "re-seeds the wiped keys\n     (static's crashed copies stay lost: "
    "repairs=0, recovery=never)"
)

# --- 3. the same algorithm placing MoE experts ------------------------------
ep = ExpertPlacement(num_layers=2, num_experts=16, num_nodes=4, slots=4, period=5)
st = ep.init_state()
for step in range(10):
    counts = np.zeros((2, 8, 16), np.float32)
    for l in range(2):
        for g in range(8):
            np.add.at(counts[l, g], rng.choice([1, 5, 9], 80), 1)  # hot experts
            np.add.at(counts[l, g], rng.integers(0, 16, 20), 1)
    st = ep.fold(st, jnp.asarray(counts), jnp.arange(8, dtype=jnp.int32) % 4)
    if ep.due(step + 1):
        st = ep.sweep(st)

print("replica cache (layer 0):", sorted(np.asarray(st.hot_ids)[0].tolist()))
print(f"traffic served by replicas: {float(ep.hit_rate(st)):.1%}")
