"""Telemetry subsystem guard rails.

Four pinned properties (the ISSUE-4 acceptance criteria):

  1. Kernel ⇄ reference parity: the Pallas ``latency_histogram`` (one-hot
     matmul accumulation, interpret mode on CPU) must agree with the
     pure-jnp scatter-add oracle — exactly for 0/1 weights (integer counts
     are order-independent in f32 below 2**24), allclose for real weights.
  2. Quantile interpolation: ``SimTrace`` quantiles read off the log-bin
     histogram must land within ONE relative bin width of ``np.percentile``
     over the reference engine's raw per-request latencies.
  3. Telemetry-off (and telemetry-on) aggregates are bit-identical to the
     pre-telemetry engines, for both engines × both sweep backends — the
     scan carry is untouched by telemetry, it only adds ``ys``.
  4. Merge associativity: histograms accumulated under the seed-vmapped
     batched engine and summed equal the sum of independently-run per-seed
     histograms (and the reference engine's), so ``run_experiment`` merging
     by summation is sound.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.latency_histogram.ops import latency_histogram
from repro.kernels.latency_histogram.ref import (
    bin_edges,
    bin_index,
    latency_histogram_ref,
)
from repro.kvsim import (
    ClusterConfig,
    RedynisPolicy,
    SimResult,
    StaticPolicy,
    TelemetryConfig,
    WorkloadConfig,
    confidence_interval_99,
    histogram_quantile,
    run_experiment,
    run_scenario,
    run_scenario_reference,
    wan5_cluster,
    wan5_workload,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# 1. Histogram kernel ⇄ reference parity.
# ---------------------------------------------------------------------------


def _random_chunk(seed, r, g, lo, hi):
    """Latencies spanning under/overflow, random groups, 0/1 weights."""
    rng = np.random.default_rng(seed)
    # Log-uniform over [lo/10, hi*10] guarantees traffic in the underflow
    # and overflow buckets as well as every interior decade.
    lat = np.exp(
        rng.uniform(np.log(max(lo / 10, 1e-6)), np.log(hi * 10), size=r)
    ).astype(np.float32)
    group = rng.integers(0, g, size=r).astype(np.int32)
    weight = (rng.random(r) < 0.8).astype(np.float32)
    return jnp.asarray(lat), jnp.asarray(group), jnp.asarray(weight)


def check_kernel_matches_ref(seed, r, g, b, lo, hi, tr):
    lat, group, weight = _random_chunk(seed, r, g, lo, hi)
    kw = dict(num_groups=g, num_bins=b, lo=lo, hi=hi)
    ref = latency_histogram_ref(lat, group, weight, **kw)
    ker = latency_histogram(lat, group, weight, tr=tr, interpret=True, **kw)
    np.testing.assert_array_equal(np.asarray(ker), np.asarray(ref))
    # Conservation: every weighted request lands in exactly one bucket.
    np.testing.assert_allclose(
        float(jnp.sum(ker)), float(jnp.sum(weight)), rtol=1e-6
    )


# Fixed grid: odd R (pad path), single-tile and multi-tile, tight and wide
# bin ranges, group counts from the simulator's 2N=6 up to 16.
KERNEL_GRID = [
    (0, 512, 6, 64, 1.0, 10_000.0, 256),
    (1, 1000, 6, 128, 1.0, 10_000.0, 256),  # daemon_interval-sized, pad path
    (2, 77, 10, 32, 5.0, 500.0, 64),  # odd R, narrow range
    (3, 2048, 16, 128, 0.1, 1e6, 1024),
    (4, 1, 2, 8, 1.0, 100.0, 64),  # single request
]


@pytest.mark.parametrize("params", KERNEL_GRID)
def test_latency_histogram_kernel_matches_ref(params):
    check_kernel_matches_ref(*params)


def test_latency_histogram_real_weights_allclose():
    """Non-0/1 weights: matmul and scatter-add sum in different orders, so
    the guarantee weakens from bit-exact to allclose."""
    rng = np.random.default_rng(7)
    lat, group, _ = _random_chunk(7, 800, 6, 1.0, 10_000.0)
    weight = jnp.asarray(rng.random(800).astype(np.float32))
    kw = dict(num_groups=6, num_bins=64, lo=1.0, hi=10_000.0)
    ref = latency_histogram_ref(lat, group, weight, **kw)
    ker = latency_histogram(lat, group, weight, tr=256, interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref), rtol=1e-5)


def test_bin_index_boundaries():
    """Pinned bucket semantics: underflow < lo, overflow >= hi, interior
    edges land in the bucket they open."""
    lo, hi, b = 1.0, 1000.0, 32
    idx = bin_index(jnp.asarray([0.0, 0.5, 1.0, 999.9, 1000.0, 1e9]), lo, hi, b)
    assert int(idx[0]) == 0 and int(idx[1]) == 0  # underflow
    assert int(idx[2]) == 1  # first interior bucket opens at lo
    assert int(idx[3]) == b - 2  # last interior bucket
    assert int(idx[4]) == b - 1 and int(idx[5]) == b - 1  # overflow


if HAVE_HYPOTHESIS:
    chunk_strategy = st.tuples(
        st.integers(0, 2**31 - 1),  # numpy seed
        st.integers(1, 600),  # r requests (odd sizes exercise the pad)
        st.integers(2, 12),  # g groups
        st.sampled_from([8, 32, 128]),  # b bins
        st.floats(0.05, 50.0),  # lo
        st.floats(2.0, 1e5),  # hi / lo ratio
        st.sampled_from([64, 256]),  # tile
    )

    @settings(max_examples=25, deadline=None)
    @given(chunk_strategy)
    def test_latency_histogram_kernel_matches_ref_fuzz(params):
        seed, r, g, b, lo, ratio, tr = params
        check_kernel_matches_ref(seed, r, g, b, lo, lo * ratio, tr)


# ---------------------------------------------------------------------------
# 2. Quantile interpolation vs np.percentile.
# ---------------------------------------------------------------------------


def assert_within_one_bin(interp, exact, edges, label=""):
    """Log-spaced bins have constant relative width rho = edges[2]/edges[1];
    one-bin-width accuracy means interp/exact lies in [1/rho, rho]."""
    rho = float(edges[2] / edges[1])
    assert exact / rho <= interp <= exact * rho * (1 + 1e-9), (
        f"{label}: interpolated {interp} vs exact {exact} "
        f"(allowed factor {rho})"
    )


def test_histogram_quantile_vs_percentile_synthetic():
    rng = np.random.default_rng(3)
    samples = np.exp(rng.normal(3.0, 1.2, size=20_000)).astype(np.float32)
    lo, hi, b = 1.0, 10_000.0, 128
    hist = np.asarray(latency_histogram_ref(
        jnp.asarray(samples), jnp.zeros(len(samples), jnp.int32),
        jnp.ones(len(samples), jnp.float32),
        num_groups=1, num_bins=b, lo=lo, hi=hi,
    ))[0]
    edges = bin_edges(lo, hi, b)
    for q in (0.5, 0.9, 0.95, 0.99, 0.999):
        interp = histogram_quantile(hist, edges, q)
        exact = float(np.percentile(samples, 100 * q))
        assert_within_one_bin(interp, exact, edges, f"q={q}")


def test_reference_engine_quantiles_vs_raw_latencies():
    """The oracle path: SimTrace quantiles vs np.percentile of the raw
    per-request latencies only the reference engine materialises."""
    wl = WorkloadConfig(
        num_requests=4_000, num_keys=200, skewed=True, read_fraction=0.9,
        affinity=0.8,
    )
    _, trace = run_scenario_reference(
        wl, ClusterConfig(), RedynisPolicy(), seed=2, daemon_interval=500,
        telemetry=TelemetryConfig(),
    )
    raw = trace.raw_latency_ms
    assert raw.shape == (4_000,)
    for q in (0.5, 0.9, 0.99):
        assert_within_one_bin(
            trace.quantile(q), float(np.percentile(raw, 100 * q)),
            trace.edges, f"q={q}",
        )


def test_acceptance_wan5_fused_p99_matches_reference_percentile():
    """ISSUE-4 acceptance: with telemetry enabled, run_scenario(policy=
    RedynisPolicy(...)) on wan5 returns a SimTrace whose interpolated P99
    matches np.percentile of the reference engine's raw per-request
    latencies within one histogram-bin width."""
    wl = wan5_workload(num_requests=4_000, num_keys=200, affinity=0.8)
    cl = wan5_cluster()
    cfg = TelemetryConfig()
    _, fused = run_scenario(
        wl, cl, RedynisPolicy(h=0.2), seed=0, daemon_interval=500,
        telemetry=cfg,
    )
    _, ref = run_scenario_reference(
        wl, cl, RedynisPolicy(h=0.2), seed=0, daemon_interval=500,
        telemetry=cfg,
    )
    # Same f32 latencies -> same buckets: the two engines' histograms are
    # identical, not merely close.
    np.testing.assert_array_equal(fused.hist_group, ref.hist_group)
    exact_p99 = float(np.percentile(ref.raw_latency_ms, 99))
    assert_within_one_bin(fused.quantile(0.99), exact_p99, fused.edges, "p99")


# ---------------------------------------------------------------------------
# 3. Telemetry-off (and on) bit-exactness, both engines × both backends.
# ---------------------------------------------------------------------------


def assert_results_equal(a: SimResult, b: SimResult, ctx: str):
    for field, x, y in zip(SimResult._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{ctx} {field}"
        )


@pytest.mark.parametrize("engine", ["scan", "reference"])
@pytest.mark.parametrize("backend", ["jax", "pallas"])
def test_telemetry_is_a_bitexact_noop(engine, backend):
    """Enabling telemetry must not perturb a single aggregate bit — the
    PR-3 goldens stay valid with or without a SimTrace attached."""
    run = run_scenario if engine == "scan" else run_scenario_reference
    wl = WorkloadConfig(
        num_requests=2_000, num_keys=150, skewed=True, affinity=0.8
    )
    cl = ClusterConfig(capacity_bytes=24 * 1024.0)
    pol = RedynisPolicy(backend=backend, expiry=4, decay=0.5)
    plain = run(wl, cl, pol, seed=3, daemon_interval=500)
    on, trace = run(
        wl, cl, pol, seed=3, daemon_interval=500, telemetry=TelemetryConfig()
    )
    assert isinstance(plain, SimResult)
    assert_results_equal(plain, on, f"{engine}/{backend}")
    assert float(trace.requests.sum()) == 2_000.0
    # A disabled config is the same static as no config at all.
    off = run(
        wl, cl, pol, seed=3, daemon_interval=500,
        telemetry=TelemetryConfig(enabled=False),
    )
    assert isinstance(off, SimResult)
    assert_results_equal(plain, off, f"{engine}/{backend} disabled-config")


def test_pallas_telemetry_backend_matches_jax_inside_scan():
    """The Pallas histogram kernel runs INSIDE the fused lax.scan body
    (vmap-compatible, interpret off-TPU) and must reproduce the pure-JAX
    telemetry backend's SimTrace exactly."""
    wl = WorkloadConfig(num_requests=2_000, num_keys=150, skewed=True)
    a_res, a = run_scenario(
        wl, ClusterConfig(), RedynisPolicy(), seed=1,
        daemon_interval=500, telemetry=TelemetryConfig(backend="jax"),
    )
    b_res, b = run_scenario(
        wl, ClusterConfig(), RedynisPolicy(), seed=1,
        daemon_interval=500, telemetry=TelemetryConfig(backend="pallas"),
    )
    assert_results_equal(a_res, b_res, "telemetry-backend")
    np.testing.assert_array_equal(a.hist_group, b.hist_group)
    np.testing.assert_array_equal(a.chunk_hist, b.chunk_hist)


# ---------------------------------------------------------------------------
# 4. vmap-merge associativity + run_experiment surface.
# ---------------------------------------------------------------------------

_EXPERIMENT_KW = dict(
    read_fractions=(0.9,), skewed=True, iterations=3, num_requests=3_000,
    num_keys=150, affinity=0.8,
)


def test_vmap_merged_histogram_equals_sum_of_per_seed_runs():
    """Sum of independently-run per-seed histograms == the seed-vmapped
    batched engine's merged histogram (integer counts: exact)."""
    cfg = TelemetryConfig()
    pols = [RedynisPolicy(), RedynisPolicy(h=0.05, decay=0.9)]
    res = run_experiment(policies=pols, telemetry=cfg, **_EXPERIMENT_KW)
    wl = WorkloadConfig(
        num_requests=3_000, num_keys=150, skewed=True, read_fraction=0.9,
        affinity=0.8,
    )
    for pol, (label, rows) in zip(pols, res["policies"].items()):
        per_seed = [
            run_scenario(wl, ClusterConfig(), pol, seed=s, telemetry=cfg)[1]
            for s in range(3)
        ]
        np.testing.assert_array_equal(
            rows[0]["trace"].hist_group,
            sum(t.hist_group for t in per_seed),
            err_msg=label,
        )
        assert float(rows[0]["trace"].requests.sum()) == 3 * 3_000.0
        # Occupancy is a point sample, not a counter: the seed-merged
        # trace must AVERAGE it, not inflate it by the seed count.
        np.testing.assert_allclose(
            rows[0]["trace"].occupancy_bytes,
            np.mean([t.occupancy_bytes for t in per_seed], axis=0),
            rtol=1e-6, err_msg=label,
        )


def test_experiment_reference_engine_matches_scan_telemetry():
    cfg = TelemetryConfig()
    pols = [RedynisPolicy()]
    scan = run_experiment(policies=pols, telemetry=cfg, **_EXPERIMENT_KW)
    ref = run_experiment(
        policies=pols, telemetry=cfg, engine="reference", **_EXPERIMENT_KW
    )
    a = scan["policies"]["redynis(h=0.3333333333333333)"][0]
    b = ref["policies"]["redynis(h=0.3333333333333333)"][0]
    np.testing.assert_array_equal(
        a["trace"].hist_group, b["trace"].hist_group
    )
    np.testing.assert_allclose(
        a["p99_latency_ms"], b["p99_latency_ms"], rtol=1e-9
    )


def test_experiment_rows_report_p99_ci_bands():
    res = run_experiment(
        policies=[RedynisPolicy(), StaticPolicy(mode="remote")],
        telemetry=TelemetryConfig(), **_EXPERIMENT_KW,
    )
    for label, rows in res["policies"].items():
        row = rows[0]
        assert row["p99_ci99"] >= 0.0, label
        assert row["p99_latency_ms"] > 0.0, label
        assert set(row["quantiles"]) == {"p50", "p90", "p95", "p99", "p999"}
        # The CI is over per-seed interpolated P99 samples; the reported
        # centre must be consistent with the merged-histogram P99 (same
        # distribution family, so within one bin width).
        assert_within_one_bin(
            row["p99_latency_ms"], row["trace"].quantile(0.99),
            row["trace"].edges, label,
        )
    # Every policy row carries the same quantile surface (no legacy grid:
    # the row-building path is shared).
    more = run_experiment(
        policies=[StaticPolicy(mode="local")],
        read_fractions=(0.9,), iterations=2, num_requests=2_000,
        telemetry=TelemetryConfig(),
    )
    assert "p99_latency_ms" in more["policies"]["static(mode='local')"][0]


def test_confidence_interval_accepts_quantile_sample_stacks():
    """[S] scalars keep the legacy float contract; [S, Q] per-seed quantile
    stacks reduce along the seed axis and return arrays."""
    m, ci = confidence_interval_99(np.array([1.0, 2.0, 3.0]))
    assert isinstance(m, float) and isinstance(ci, float)
    np.testing.assert_allclose(m, 2.0)
    stack = np.array([[1.0, 10.0], [3.0, 30.0], [2.0, 20.0]])
    mv, civ = confidence_interval_99(stack)
    np.testing.assert_allclose(mv, [2.0, 20.0])
    np.testing.assert_allclose(civ[1], civ[0] * 10.0)
    m1, ci1 = confidence_interval_99(np.array([5.0]))
    assert (m1, ci1) == (5.0, 0.0)


# ---------------------------------------------------------------------------
# SimTrace views + convergence diagnostics + config validation.
# ---------------------------------------------------------------------------


def test_simtrace_views_are_consistent():
    wl = WorkloadConfig(num_requests=3_000, num_keys=150, skewed=True,
                        read_fraction=0.75)
    _, trace = run_scenario(
        wl, ClusterConfig(), RedynisPolicy(), telemetry=TelemetryConfig()
    )
    np.testing.assert_allclose(trace.hist, trace.hist_read + trace.hist_write)
    np.testing.assert_allclose(trace.hist, trace.hist_node.sum(axis=0))
    np.testing.assert_allclose(trace.hist, trace.chunk_hist.sum(axis=0))
    assert float(trace.hist.sum()) == 3_000.0
    # ~75% reads; the read/write split must reflect the trace mix.
    assert 0.6 < trace.hist_read.sum() / 3_000.0 < 0.9
    assert trace.num_nodes == 3
    assert trace.occupancy_bytes.shape == (trace.hit_rate.shape[0], 3)


def test_convergence_diagnostics():
    wl = WorkloadConfig(num_requests=4_000, num_keys=150, skewed=True,
                        affinity=0.8)
    cfg = TelemetryConfig()
    # A static map is converged from chunk 0 and never moves a replica.
    _, static = run_scenario(
        wl, ClusterConfig(), StaticPolicy(mode="remote"), telemetry=cfg,
        daemon_interval=500,
    )
    assert static.convergence_chunk(1e-6) == 0
    assert static.post_convergence_moves() == 0.0
    np.testing.assert_array_equal(static.moves, 0.0)
    # Redynis digs out of the offsite placement: hit-rate must climb, and
    # the first chunk (cold map) cannot already be within eps of terminal.
    _, adaptive = run_scenario(
        wl, ClusterConfig(), RedynisPolicy(), telemetry=cfg,
        daemon_interval=500,
    )
    c = adaptive.convergence_chunk(0.02)
    assert 0 < c < adaptive.hit_rate.shape[0]
    assert adaptive.hit_rate[-1] > adaptive.hit_rate[0]
    assert adaptive.moves[0] > 0  # the first sweep replicates hot keys


def test_telemetry_config_validation():
    with pytest.raises(ValueError, match="num_bins"):
        TelemetryConfig(num_bins=3).validate()
    with pytest.raises(ValueError, match="lo_ms"):
        TelemetryConfig(lo_ms=10.0, hi_ms=1.0).validate()
    with pytest.raises(ValueError, match="backend"):
        TelemetryConfig(backend="cuda").validate()
    with pytest.raises(ValueError):
        run_scenario(
            WorkloadConfig(num_requests=100, num_keys=10),
            ClusterConfig(),
            RedynisPolicy(),
            telemetry=TelemetryConfig(num_bins=2),
        )
