"""Per-kernel allclose-vs-oracle sweeps (shapes × dtypes, interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.flash_decode.ref import decode_ref
from repro.kernels.hot_gather.ops import hot_gather
from repro.kernels.hot_gather.ref import hot_gather_ref
from repro.kernels.moe_router.ops import moe_router
from repro.kernels.moe_router.ref import router_ref
from repro.kernels.ownership_sweep.ops import ownership_sweep
from repro.kernels.ownership_sweep.ref import sweep_ref


@pytest.mark.parametrize(
    "b,s,t,h,kh,dh,causal,window",
    [
        (2, 256, 256, 4, 2, 64, True, 0),
        (1, 128, 128, 8, 1, 128, True, 0),  # MQA
        (2, 256, 256, 4, 4, 32, True, 64),  # MHA + sliding window
        (1, 128, 384, 4, 2, 64, False, 0),  # cross attention, T > S
        (1, 192, 192, 6, 2, 64, True, 0),  # non-power-of-two blocks
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, s, t, h, kh, dh, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(hash((b, s, t)) % 2**31), 3)
    q = jax.random.normal(ks[0], (b, s, h, dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, kh, dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, kh, dh), jnp.float32).astype(dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, bq=64, bk=64)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, t, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, t, dh)
    ref = attention_ref(
        qf, kf, vf, group=h // kh, heads=h, kv_heads=kh, causal=causal, window=window
    ).reshape(b, h, s, dh).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize(
    "b,t,h,kh,dh,bk",
    [(2, 1024, 8, 2, 64, 256), (4, 512, 4, 1, 128, 512), (2, 768, 16, 16, 32, 128)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode(b, t, h, kh, dh, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(t), 4)
    q = jax.random.normal(ks[0], (b, h, dh), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (b, t, kh, dh), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (b, t, kh, dh), jnp.float32).astype(dtype)
    lengths = jax.random.randint(ks[3], (b,), 1, t)
    out = flash_decode(q, kc, vc, lengths, bk=bk)
    ref = decode_ref(q, kc, vc, lengths)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("k,n,h,expiry", [(1000, 16, 0.0625, 0), (513, 3, 1 / 3, 50), (64, 64, 0.01, 10)])
def test_ownership_sweep(k, n, h, expiry):
    ks = jax.random.split(jax.random.PRNGKey(k), 4)
    counts = jax.random.randint(ks[0], (k, n), 0, 50).astype(jnp.float32)
    counts = counts * (jax.random.uniform(ks[1], (k, n)) > 0.5)
    hosts = jax.random.uniform(ks[2], (k, n)) > 0.7
    live = jax.random.uniform(ks[3], (k,)) > 0.1
    last = jax.random.randint(ks[0], (k,), 0, 100)
    out = ownership_sweep(counts, hosts, live, last, 100, h=h, expiry=expiry, tk=256)
    ref = sweep_ref(counts, hosts, live, last, 100, h=h, expiry=expiry)
    for i, (a, b) in enumerate(zip(out, ref)):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b).reshape(np.asarray(a).shape),
            err_msg=f"output {i}",
        )


@pytest.mark.parametrize("t,e,k,tt", [(512, 64, 6, 128), (300, 32, 8, 128), (1024, 8, 2, 256)])
def test_moe_router(t, e, k, tt):
    logits = jax.random.normal(jax.random.PRNGKey(t + e), (t, e), jnp.float32)
    g, i, c = moe_router(logits, k=k, tt=tt)
    gr, ir, cr = router_ref(logits, k)
    np.testing.assert_allclose(np.asarray(g), np.asarray(gr), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(c), np.asarray(cr), atol=1e-6)
    assert float(c.sum()) == t * k  # histogram mass = assignments


@pytest.mark.parametrize("v,r,d,t", [(5000, 64, 256, 333), (1024, 8, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_hot_gather(v, r, d, t, dtype):
    ks = jax.random.split(jax.random.PRNGKey(v), 3)
    slot_map = jnp.full((v,), -1, jnp.int32)
    hot_rows = jax.random.choice(ks[0], v, (r,), replace=False)
    slot_map = slot_map.at[hot_rows].set(jnp.arange(r, dtype=jnp.int32))
    table = jax.random.normal(ks[1], (r, d), jnp.float32).astype(dtype)
    tokens = jax.random.randint(ks[2], (t,), 0, v)
    rows, hit = hot_gather(tokens, slot_map, table, tt=128, td=128)
    rr, hr = hot_gather_ref(tokens, slot_map, table)
    np.testing.assert_array_equal(np.asarray(hit), np.asarray(hr))
    np.testing.assert_array_equal(
        np.asarray(rows, np.float32), np.asarray(rr, np.float32)
    )


def test_hot_gather_vjp_matches_ref():
    v, r, d = 200, 16, 32
    slot_map = jnp.full((v,), -1, jnp.int32).at[jnp.arange(r) * 3].set(
        jnp.arange(r, dtype=jnp.int32)
    )
    table = jax.random.normal(jax.random.PRNGKey(0), (r, d), jnp.float32)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (64,), 0, v)
    f1 = lambda t: jnp.sum(jnp.sin(hot_gather(tokens, slot_map, t)[0]))
    f2 = lambda t: jnp.sum(jnp.sin(hot_gather_ref(tokens, slot_map, t)[0]))
    np.testing.assert_allclose(
        np.asarray(jax.grad(f1)(table)), np.asarray(jax.grad(f2)(table)), atol=1e-5
    )
