"""Routing-tier test tier (ISSUE-8 acceptance).

Pins the stale-directory routing tier (``RoutingConfig`` — router-site
ownership caches, versioned lagged publishes, mis-route pricing):

1. Routing OFF (``routing=None`` and ``RoutingConfig(enabled=False)``)
   compiles the exact pre-routing program — bit-identical results across
   both engines × both replay backends × both trace modes, still
   reproducing the seed Fig 2/3 goldens.
2. Kernel ⇄ reference parity: the Pallas chunk-replay kernel fed the
   canonical ``routing_extra_ms_ref`` pre-pass output must agree with the
   jnp oracle across topologies × read modes — histograms bit-exact,
   busy/lat_sum allclose — plus the pre-pass's own outcome invariants
   (fresh consults are free, misses fetch, flags are consistent).
3. Zero lag + unbounded cache ⇒ every consult prices at exactly 0.0 and
   the engine results are bit-identical to the no-routing run (the
   ``lat + 0.0`` identity).
4. Staleness axis: mis-routes and mean latency are monotone in
   ``publish_lag_chunks``; shrinking ``cache_entries`` only adds
   directory fetches; ``cache_entries >= K`` collapses to the unbounded
   cache program.
5. Engine agreement with routing ON: fused scan == per-chunk reference ==
   Pallas replay == streamed traces (counts bit-exact, latency allclose),
   and the telemetry per-chunk series sum to the aggregate counters.
6. 2-rank ``shard_map`` equivalence with routing on (``run_multi_rank``).

Hypothesis (when installed) fuzzes the pre-pass invariants over random
maps, published views, and cache states.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_replay.ops import chunk_replay
from repro.kernels.chunk_replay.ref import (
    READ_MODES,
    chunk_replay_ref,
    routing_extra_ms_ref,
)
from repro.kvsim import (
    ClusterConfig,
    RedynisPolicy,
    RoutingConfig,
    SimResult,
    StaticPolicy,
    TelemetryConfig,
    WorkloadConfig,
    diurnal_workload,
    normalize_routing,
    run_scenario,
    run_scenario_reference,
    wan5_cluster,
    wan5_edge_cluster,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


TOPOLOGIES = {
    "flat": ClusterConfig().rtt_matrix(),
    "wan5": wan5_cluster().rtt_matrix(),
    "wan5_edge": wan5_edge_cluster().rtt_matrix(),
}

BASELINES = {
    "local": StaticPolicy(mode="local"),
    "remote": StaticPolicy(mode="remote"),
    "optimized": RedynisPolicy(),
    "replicated": StaticPolicy(mode="replicated"),
}

# The seed Fig 2/3 goldens (see tests/test_simulate_equivalence.py) — the
# routing tier must leave them untouched while it is off.
SEED_GOLDENS = {
    "local": (292.95444558371173, 1.0, 10.0, 0.0),
    "remote": (26.632222325791975, 0.0, 110.0, 0.0),
    "optimized": (164.78536705940513, 0.92115, 17.885, 1000.0),
    "replicated": (292.95444558371173, 1.0, 10.0, 0.0),
}

ENGINES = [
    ("scan-jax-materialized", lambda wl, cl, pol: run_scenario(
        wl, cl, pol, seed=0)),
    ("scan-jax-streamed", lambda wl, cl, pol: run_scenario(
        wl, cl, pol, seed=0, trace_mode="streamed")),
    ("scan-pallas-materialized", lambda wl, cl, pol: run_scenario(
        wl, cl, pol, seed=0, replay_backend="pallas")),
    ("scan-pallas-streamed", lambda wl, cl, pol: run_scenario(
        wl, cl, pol, seed=0, replay_backend="pallas",
        trace_mode="streamed")),
    ("reference", lambda wl, cl, pol: run_scenario_reference(
        wl, cl, pol, seed=0)),
]


def assert_results_equal(a: SimResult, b: SimResult, ctx: str):
    for field, x, y in zip(SimResult._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{ctx} {field}"
        )


# A staleness-rich scenario: diurnal hotset rotation keeps the daemon
# moving keys that are still being read cross-region, so lagged publishes
# genuinely mis-route (affinity < 1 creates the non-local consult stream).
def _staleness_scenario():
    return (
        diurnal_workload(
            num_requests=20_000, num_keys=400, affinity=0.8,
            read_fraction=0.7,
        ),
        wan5_cluster(),
    )


STALE_INTERVAL = 100


# ---------------------------------------------------------------------------
# 1. Routing off is a structural no-op: seed goldens stay bit-exact.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(BASELINES))
@pytest.mark.parametrize("engine", [e[0] for e in ENGINES])
def test_routing_off_is_bitexact_and_reproduces_goldens(name, engine):
    """routing=None and RoutingConfig(enabled=False) are the SAME static
    (normalize_routing collapses both), so the compiled program — and every
    result bit — is identical to the pre-routing engine, which the seed
    goldens pin."""
    run = dict(ENGINES)[engine]
    wl = WorkloadConfig(num_requests=20_000)
    plain = run(wl, ClusterConfig(), BASELINES[name])
    disabled = run(
        wl, ClusterConfig(routing=RoutingConfig(enabled=False)),
        BASELINES[name],
    )
    assert_results_equal(plain, disabled, f"{engine}/{name}")
    assert plain.router_consults == 0.0
    assert plain.mis_routes == 0.0
    tput, hit, mean_lat, moves = SEED_GOLDENS[name]
    np.testing.assert_allclose(plain.throughput_ops_s, tput, rtol=1e-4)
    np.testing.assert_allclose(plain.hit_rate, hit, rtol=1e-5)
    np.testing.assert_allclose(plain.mean_latency_ms, mean_lat, rtol=1e-4)
    np.testing.assert_allclose(plain.replication_moves, moves, rtol=0)


# ---------------------------------------------------------------------------
# 2. Kernel ⇄ reference parity: routing extra_ms through the Pallas kernel.
# ---------------------------------------------------------------------------


def _random_routed_chunk(seed, b, k, n, move_fraction=0.15):
    """Random authoritative map + a published view that re-homed a slice of
    the keys + random cache/freshness state (the engine always derives
    fresh ⊆ cached; the pre-pass must hold up under that invariant)."""
    rng = np.random.default_rng(seed)
    hosts = rng.random((k, n)) < 0.4
    pub = hosts.copy()
    moved = rng.random(k) < move_fraction
    pub[moved] = rng.random((int(moved.sum()), n)) < 0.4
    cached = rng.random(b) < 0.7
    fresh = cached & (rng.random(b) < 0.6)
    return (
        jnp.asarray(hosts),
        jnp.asarray(pub),
        jnp.asarray(cached),
        jnp.asarray(fresh),
        jnp.asarray(rng.integers(0, k, b).astype(np.int32)),
        jnp.asarray(rng.integers(0, n, b).astype(np.int32)),
        jnp.asarray(rng.random(b) < 0.8),  # is_read
        jnp.asarray(rng.random(b) < 0.9),  # valid (padding path)
    )


def check_routed_kernel_matches_ref(
    rtt, seed, b, k, read_mode="map", home_node=0, tr=256, tkey=128
):
    n = rtt.shape[0]
    hosts, pub, cached, fresh, keys, nodes, is_read, valid = (
        _random_routed_chunk(seed, b, k, n)
    )
    extra, consult, fetches, stale, mis = routing_extra_ms_ref(
        hosts, pub, cached, fresh, keys, nodes, is_read, valid, rtt,
        read_mode=read_mode, home_node=home_node,
    )
    # Outcome invariants of the canonical pre-pass.
    consult_n, fetch_n = np.asarray(consult), np.asarray(fetches)
    stale_n, mis_n = np.asarray(stale), np.asarray(mis)
    cached_n, fresh_n = np.asarray(cached), np.asarray(fresh)
    extra_n = np.asarray(extra)
    assert not np.any(fetch_n & ~consult_n)
    assert not np.any(stale_n & ~consult_n)
    assert not np.any(mis_n & ~consult_n)
    assert not np.any(fetch_n & cached_n)
    assert not np.any(stale_n & ~cached_n)
    assert not np.any(mis_n & fresh_n)
    # Fresh (or non-consulting) requests are free; the real topologies are
    # metric, so detours and fetches can only add latency.
    assert np.all(extra_n[fresh_n | ~consult_n] == 0.0)
    assert np.all(extra_n >= 0.0)
    kw = dict(
        service_ms=10.0, master=0, xfer_read_ms=2.0, xfer_write_ms=3.0,
        read_mode=read_mode, num_bins=64, lo=1.0, hi=5_000.0,
    )
    ref = chunk_replay_ref(
        hosts, keys, nodes, is_read, valid, rtt, extra_ms=extra, **kw
    )
    ker = chunk_replay(
        hosts, keys, nodes, is_read, valid, rtt, extra_ms=extra,
        backend="pallas", tr=tr, tkey=tkey, interpret=True, **kw,
    )
    np.testing.assert_allclose(
        np.asarray(ker[0]), np.asarray(ref[0]), rtol=1e-5, err_msg="busy"
    )
    np.testing.assert_allclose(
        float(ker[1]), float(ref[1]), rtol=1e-5, err_msg="lat_sum"
    )
    for i, name in ((2, "hits"), (3, "reads"), (4, "count")):
        assert float(ker[i]) == float(ref[i]), (name, ker[i], ref[i])
    # The kernel adds extra_ms in the oracle's elementwise position, so the
    # mis-routed f32 latency bits — and the histogram buckets — match.
    np.testing.assert_array_equal(np.asarray(ker[5]), np.asarray(ref[5]))


PARITY_GRID = [
    (topo, mode, home)
    for topo in TOPOLOGIES
    for mode in READ_MODES
    for home in (0, 2)
]


@pytest.mark.parametrize(
    "topo,mode,home", PARITY_GRID,
    ids=[f"{t}-{m}-home{h}" for t, m, h in PARITY_GRID],
)
def test_routed_kernel_matches_ref(topo, mode, home):
    check_routed_kernel_matches_ref(
        TOPOLOGIES[topo], seed=hash((topo, mode, home)) % 2**32,
        b=777, k=333, read_mode=mode, home_node=home,
    )


if HAVE_HYPOTHESIS:
    routed_strategy = st.tuples(
        st.integers(0, 2**31 - 1),  # numpy seed
        st.integers(1, 400),  # b requests
        st.integers(1, 200),  # k keys
        st.sampled_from(sorted(TOPOLOGIES)),
        st.sampled_from(READ_MODES),
    )

    @settings(max_examples=30, deadline=None)
    @given(routed_strategy)
    def test_routed_pre_pass_fuzz(params):
        """The pre-pass invariants over random maps/views/cache states."""
        seed, b, k, topo, mode = params
        rtt = TOPOLOGIES[topo]
        n = rtt.shape[0]
        check_routed_kernel_matches_ref(
            rtt, seed=seed, b=b, k=k, read_mode=mode,
            home_node=seed % n,
        )


# ---------------------------------------------------------------------------
# 3. Zero lag + unbounded cache is the bit-exact identity.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["optimized", "local"])
def test_zero_lag_unbounded_cache_is_identity(name):
    """L=0 publishes instantly and the warm cache never misses, so every
    consult prices at exactly 0.0 — and lat + 0.0 is a bit-exact f32
    identity on the engine's positive latencies."""
    wl = WorkloadConfig(num_requests=20_000)
    off = run_scenario(wl, ClusterConfig(), BASELINES[name], seed=0)
    on = run_scenario(
        wl, ClusterConfig(routing=RoutingConfig()), BASELINES[name], seed=0
    )
    for field in (
        "throughput_ops_s", "hit_rate", "mean_latency_ms", "node_busy_ms",
        "replication_moves", "deletion_moves", "evictions",
        "capacity_evictions", "peak_occupancy_bytes",
    ):
        np.testing.assert_array_equal(
            np.asarray(getattr(off, field)), np.asarray(getattr(on, field)),
            err_msg=field,
        )
    assert on.mis_routes == 0.0
    assert on.directory_fetches == 0.0
    if name == "optimized":
        assert on.router_consults > 0.0


# ---------------------------------------------------------------------------
# 4. The staleness/consistency axis.
# ---------------------------------------------------------------------------


def test_mis_routes_monotone_in_publish_lag():
    """More propagation lag can only widen the window in which routers
    hold moved keys' old owners: mis-routes, stale consults, and mean
    latency are non-decreasing along the lag ladder (strictly more
    mis-routes at the far end)."""
    wl, cl = _staleness_scenario()
    rows = []
    for lag in (0, 2, 8, 32):
        r = run_scenario(
            wl, cl._replace(routing=RoutingConfig(publish_lag_chunks=lag)),
            RedynisPolicy(), seed=0, daemon_interval=STALE_INTERVAL,
        )
        rows.append((lag, r))
    for (_, a), (_, b) in zip(rows, rows[1:]):
        assert b.mis_routes >= a.mis_routes
        assert b.stale_consults >= a.stale_consults
        assert b.mean_latency_ms >= a.mean_latency_ms
        assert b.router_consults == a.router_consults
    assert rows[0][1].mis_routes == 0.0
    assert rows[-1][1].mis_routes > rows[0][1].mis_routes


def test_smaller_cache_only_adds_fetches():
    """Shrinking cache_entries converts consults into directory fetches
    (monotonically costlier) without changing WHICH requests mis-route —
    staleness is a property of the publish lag, not the cache; and a cache
    at/above the keyspace is the unbounded program, bit-exactly."""
    wl, cl = _staleness_scenario()

    def run(entries):
        return run_scenario(
            wl,
            cl._replace(routing=RoutingConfig(
                publish_lag_chunks=4, cache_entries=entries, decay=0.9,
            )),
            RedynisPolicy(), seed=0, daemon_interval=STALE_INTERVAL,
        )

    unbounded = run(0)
    at_k = run(wl.num_keys)
    assert_results_equal(unbounded, at_k, "cache>=K collapse")
    assert unbounded.directory_fetches == 0.0
    prev = unbounded
    for entries in (50, 10):
        r = run(entries)
        assert r.directory_fetches > prev.directory_fetches
        assert r.mean_latency_ms > prev.mean_latency_ms
        assert r.mis_routes == unbounded.mis_routes
        prev = r


def test_routing_validation():
    with pytest.raises(ValueError, match="num_routers"):
        RoutingConfig(num_routers=-1).validate()
    with pytest.raises(ValueError, match="cache_entries"):
        RoutingConfig(cache_entries=-1).validate()
    with pytest.raises(ValueError, match="publish_lag_chunks"):
        RoutingConfig(publish_lag_chunks=-1).validate()
    with pytest.raises(ValueError, match="decay"):
        RoutingConfig(decay=0.0).validate()
    assert normalize_routing(None) is None
    assert normalize_routing(RoutingConfig(enabled=False)) is None
    assert normalize_routing(RoutingConfig()) == RoutingConfig()
    wl = WorkloadConfig(num_requests=100)
    with pytest.raises(ValueError, match="home_node"):
        run_scenario(
            wl, ClusterConfig(routing=RoutingConfig(home_node=7)),
            RedynisPolicy(), seed=0,
        )
    with pytest.raises(ValueError, match="num_routers"):
        run_scenario(
            wl, ClusterConfig(routing=RoutingConfig(num_routers=9)),
            RedynisPolicy(), seed=0,
        )


# ---------------------------------------------------------------------------
# 5. Engine agreement with routing ON + telemetry consistency.
# ---------------------------------------------------------------------------


def test_engines_agree_with_routing_on():
    wl, cl = _staleness_scenario()
    cfg = cl._replace(routing=RoutingConfig(
        publish_lag_chunks=8, cache_entries=50, decay=0.9, home_node=2,
    ))
    kw = dict(seed=0, daemon_interval=STALE_INTERVAL)
    runs = {
        "jax": run_scenario(wl, cfg, RedynisPolicy(), **kw),
        "pallas": run_scenario(
            wl, cfg, RedynisPolicy(), replay_backend="pallas", **kw
        ),
        "streamed": run_scenario(
            wl, cfg, RedynisPolicy(), trace_mode="streamed", **kw
        ),
        "reference": run_scenario_reference(wl, cfg, RedynisPolicy(), **kw),
    }
    base = runs["jax"]
    assert base.mis_routes > 0.0 and base.directory_fetches > 0.0
    for name, r in runs.items():
        # Counts are integer surfaces: bit-exact across all engines.
        assert r.router_consults == base.router_consults, name
        assert r.directory_fetches == base.directory_fetches, name
        assert r.mis_routes == base.mis_routes, name
        assert r.stale_consults == base.stale_consults, name
        # The reference engine divides its (identical) hit/read counts in
        # float64 where the fused engine divides in f32.
        np.testing.assert_allclose(
            r.hit_rate, base.hit_rate, rtol=1e-6, err_msg=name
        )
        np.testing.assert_allclose(
            r.mean_latency_ms, base.mean_latency_ms, rtol=1e-5,
            err_msg=name,
        )
        np.testing.assert_allclose(
            r.node_busy_ms, base.node_busy_ms, rtol=1e-4, err_msg=name
        )


def test_telemetry_series_sum_to_aggregates():
    wl, cl = _staleness_scenario()
    cfg = cl._replace(routing=RoutingConfig(
        publish_lag_chunks=8, cache_entries=50, decay=0.9,
    ))
    result, trace = run_scenario(
        wl, cfg, RedynisPolicy(), seed=0, daemon_interval=STALE_INTERVAL,
        telemetry=TelemetryConfig(),
    )
    np.testing.assert_allclose(
        trace.router_consults.sum(), result.router_consults
    )
    np.testing.assert_allclose(
        trace.directory_fetches.sum(), result.directory_fetches
    )
    np.testing.assert_allclose(trace.mis_routes.sum(), result.mis_routes)
    np.testing.assert_allclose(
        trace.stale_consults.sum(), result.stale_consults
    )
    # Every stale consult lands in exactly one staleness-age bin.
    np.testing.assert_allclose(
        trace.stale_age_hist.sum(), result.stale_consults
    )
    np.testing.assert_allclose(
        trace.stale_age_hist.sum(axis=1), trace.stale_consults
    )
    rate = trace.mis_route_rate
    assert rate.shape == trace.mis_routes.shape
    assert np.all((rate >= 0.0) & (rate <= 1.0))
    # The reference engine's trace agrees chunk-for-chunk on the counters.
    _, ref_trace = run_scenario_reference(
        wl, cfg, RedynisPolicy(), seed=0, daemon_interval=STALE_INTERVAL,
        telemetry=TelemetryConfig(),
    )
    np.testing.assert_array_equal(
        trace.mis_routes, ref_trace.mis_routes
    )
    np.testing.assert_array_equal(
        trace.stale_age_hist, ref_trace.stale_age_hist
    )


# ---------------------------------------------------------------------------
# 6. Sharded equivalence with routing on (2 virtual ranks).
# ---------------------------------------------------------------------------


SHARDED_ROUTING_SCRIPT = r"""
import numpy as np
from repro.kvsim import (run_scenario, diurnal_workload, wan5_cluster,
                         RedynisPolicy, RoutingConfig, TelemetryConfig)

wl = diurnal_workload(num_requests=20000, num_keys=401, affinity=0.8,
                      read_fraction=0.7)
cl = wan5_cluster()._replace(routing=RoutingConfig(
    publish_lag_chunks=8, cache_entries=50, decay=0.9))
for trace_mode in ('materialized', 'streamed'):
    kw = dict(seed=3, daemon_interval=100, telemetry=TelemetryConfig(),
              trace_mode=trace_mode)
    r1, t1 = run_scenario(wl, cl, RedynisPolicy(), **kw)
    r2, t2 = run_scenario(wl, cl, RedynisPolicy(), num_shards=2, **kw)
    assert r1.mis_routes > 0.0
    # Counter surfaces: bit-exact under psum (and K=401 exercises the
    # ceil-division padding alongside the sharded router caches).
    for f in ('router_consults', 'directory_fetches', 'mis_routes',
              'stale_consults', 'hit_rate', 'replication_moves',
              'deletion_moves'):
        assert getattr(r1, f) == getattr(r2, f), (f, trace_mode)
    np.testing.assert_array_equal(t1.mis_routes, t2.mis_routes)
    np.testing.assert_array_equal(t1.stale_age_hist, t2.stale_age_hist)
    np.testing.assert_allclose(r1.node_busy_ms, r2.node_busy_ms, rtol=1e-4)
    np.testing.assert_allclose(r1.mean_latency_ms, r2.mean_latency_ms,
                               rtol=1e-4)
    print('OK', trace_mode)
print('SHARDED_ROUTING_EQUIVALENCE_OK')
"""


def test_sharded_routing_matches_single_device(run_multi_rank):
    out = run_multi_rank(SHARDED_ROUTING_SCRIPT, num_devices=2, timeout=600)
    assert "SHARDED_ROUTING_EQUIVALENCE_OK" in out
