"""Streamed trace generation ⇄ materialised trace equivalence.

``generate_trace_chunk`` must be BIT-identical to slicing the materialised
``generate_trace`` output — same ``fold_in`` stream, every workload knob,
chunk sizes that do and don't divide ``num_requests``. This is the contract
that lets ``trace_mode="streamed"`` reuse the seed goldens unchanged: if any
draw shifts by one counter position the engine equivalence tests downstream
all fail, so this file is the first place to look.

Positions ``>= num_requests`` are explicitly unspecified (the engine masks
them), so every comparison here clips the final partial chunk to ``R``.
"""

import numpy as np
import pytest

from repro.kvsim import WorkloadConfig, diurnal_workload, wan5_workload
from repro.kvsim.workload import (
    generate_key_state,
    generate_trace,
    generate_trace_chunk,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


# Every preset family named in the issue: uniform, region-skewed (wan5),
# diurnal rotation, lognormal sizes — plus affinity + read mix stressors.
PRESETS = {
    "uniform": WorkloadConfig(num_requests=777, num_keys=64),
    "skewed": WorkloadConfig(
        num_requests=777, num_keys=64, skewed=True, read_fraction=0.7
    ),
    "wan5": wan5_workload(num_requests=777, num_keys=64, affinity=0.8),
    "diurnal": diurnal_workload(num_requests=777, num_keys=64, affinity=0.8),
    "lognormal": wan5_workload(
        num_requests=777,
        num_keys=64,
        affinity=0.8,
        object_bytes_sigma=0.5,
        read_fraction=0.6,
    ),
}


def _concat_chunks(cfg, seed, chunk_size):
    """Concatenate streamed chunks, clipped to num_requests."""
    num_chunks = -(-cfg.num_requests // chunk_size)
    ks, ns, rs = [], [], []
    for c in range(num_chunks):
        ch = generate_trace_chunk(cfg, seed, c, chunk_size)
        ks.append(np.asarray(ch.keys))
        ns.append(np.asarray(ch.nodes))
        rs.append(np.asarray(ch.is_read))
    r = cfg.num_requests
    return (
        np.concatenate(ks)[:r],
        np.concatenate(ns)[:r],
        np.concatenate(rs)[:r],
    )


@pytest.mark.parametrize("name", sorted(PRESETS))
# 777 = 3 * 7 * 37: 100 and 256 leave partial final chunks, 111 divides.
@pytest.mark.parametrize("chunk_size", [100, 111, 256])
def test_chunked_equals_materialized(name, chunk_size):
    cfg = PRESETS[name]
    trace = generate_trace(cfg, seed=5)
    keys, nodes, is_read = _concat_chunks(cfg, 5, chunk_size)
    np.testing.assert_array_equal(keys, np.asarray(trace.keys))
    np.testing.assert_array_equal(nodes, np.asarray(trace.nodes))
    np.testing.assert_array_equal(is_read, np.asarray(trace.is_read))


@pytest.mark.parametrize("name", sorted(PRESETS))
def test_key_state_equals_materialized(name):
    """natural_node and object_bytes from the O(K) generator match the
    fields inside the full trace bit-for-bit (same fold_in draws)."""
    cfg = PRESETS[name]
    trace = generate_trace(cfg, seed=5)
    natural, obj = generate_key_state(cfg, seed=5)
    np.testing.assert_array_equal(
        np.asarray(natural), np.asarray(trace.natural_node)
    )
    np.testing.assert_array_equal(
        np.asarray(obj).view(np.uint32),
        np.asarray(trace.object_bytes).view(np.uint32),
    )


def test_single_chunk_is_whole_trace():
    """chunk_size == num_requests: one window IS the trace."""
    cfg = PRESETS["wan5"]
    trace = generate_trace(cfg, seed=9)
    ch = generate_trace_chunk(cfg, 9, 0, cfg.num_requests)
    np.testing.assert_array_equal(np.asarray(ch.keys), np.asarray(trace.keys))
    np.testing.assert_array_equal(
        np.asarray(ch.nodes), np.asarray(trace.nodes)
    )
    np.testing.assert_array_equal(
        np.asarray(ch.is_read), np.asarray(trace.is_read)
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        name=st.sampled_from(sorted(PRESETS)),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        # Odd sizes rarely divide 777 — the partial-final-chunk case
        # dominates, which is exactly the boundary worth fuzzing.
        chunk_size=st.integers(min_value=1, max_value=900),
    )
    def test_stream_equivalence_property(name, seed, chunk_size):
        cfg = PRESETS[name]
        trace = generate_trace(cfg, seed=seed)
        keys, nodes, is_read = _concat_chunks(cfg, seed, chunk_size)
        np.testing.assert_array_equal(keys, np.asarray(trace.keys))
        np.testing.assert_array_equal(nodes, np.asarray(trace.nodes))
        np.testing.assert_array_equal(is_read, np.asarray(trace.is_read))
