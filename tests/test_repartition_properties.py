"""Property tests for the repartition execution layer (core/repartition.py):
`plan_moves` schedules are unique / capacity-bounded / hottest-consistent,
and `publish_and_fill` with ``axis_name=None`` matches the real ``shard_map``
collective path on a 2-rank CPU mesh (via the ``run_multi_rank`` conftest
fixture — a subprocess, so the main pytest process stays single-device)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.placement import PlacementPlan
from repro.core.repartition import create_cache, plan_moves, publish_and_fill


def random_plan(rng, k, n):
    owners = rng.random((k, n)) < 0.5
    home = rng.integers(0, n, size=k).astype(np.int32)
    owners[np.arange(k), home] = True  # every key keeps its home replica
    prev = rng.random((k, n)) < 0.3
    return (
        PlacementPlan(
            owners=jnp.asarray(owners),
            to_add=jnp.asarray(owners & ~prev),
            to_drop=jnp.asarray(prev & ~owners),
            expired=jnp.zeros((k,), bool),
        ),
        jnp.asarray(home),
        owners,
        home,
    )


@pytest.mark.parametrize("seed", range(8))
def test_plan_moves_slots_unique_and_within_capacity(seed):
    rng = np.random.default_rng(seed)
    k, n, cap = int(rng.integers(4, 40)), int(rng.integers(2, 6)), int(rng.integers(1, 9))
    plan, home, owners, home_np = random_plan(rng, k, n)
    moves = plan_moves(plan, home, cap, max_moves=k, object_bytes=8.0)

    slot_ids = np.asarray(moves.slot_ids)
    assert slot_ids.shape == (n, cap)
    for r in range(n):
        row = slot_ids[r]
        filled = row[row >= 0]
        # unique, in-range, and only objects this rank wants but doesn't home
        assert len(set(filled.tolist())) == len(filled)
        wanted = set(np.nonzero(owners[:, r] & (home_np != r))[0].tolist())
        assert set(filled.tolist()) <= wanted
        # truncation fills exactly min(|wanted|, capacity) slots
        assert len(filled) == min(len(wanted), cap)


@pytest.mark.parametrize("seed", range(8))
def test_plan_moves_priority_keeps_hottest(seed):
    """With a priority vector the truncated schedule must keep exactly the
    top-capacity hottest wanted objects (ties broken by object id)."""
    rng = np.random.default_rng(100 + seed)
    k, n, cap = int(rng.integers(6, 40)), int(rng.integers(2, 5)), int(rng.integers(1, 6))
    plan, home, owners, home_np = random_plan(rng, k, n)
    heat = rng.integers(0, 5, size=k).astype(np.float32)  # few levels -> ties
    moves = plan_moves(
        plan, home, cap, max_moves=k, object_bytes=8.0,
        priority=jnp.asarray(heat),
    )
    slot_ids = np.asarray(moves.slot_ids)
    for r in range(n):
        wanted = np.nonzero(owners[:, r] & (home_np != r))[0]
        expect = sorted(wanted.tolist(), key=lambda i: (-heat[i], i))[:cap]
        got = [i for i in slot_ids[r].tolist() if i >= 0]
        assert got == expect, (r, heat.tolist())


def test_plan_moves_publishes_every_add():
    rng = np.random.default_rng(7)
    k, n = 16, 3
    plan, home, _, _ = random_plan(rng, k, n)
    moves = plan_moves(plan, home, 8, max_moves=k, object_bytes=4.0)
    published = set(int(i) for i in np.asarray(moves.publish_ids) if i >= 0)
    added = set(np.nonzero(np.asarray(plan.to_add).any(axis=1))[0].tolist())
    assert published == added
    np.testing.assert_allclose(
        float(moves.moved_bytes), 4.0 * len(added), rtol=1e-6
    )


def test_publish_and_fill_fills_desired_slots():
    """Single-process (axis_name=None) semantics: every desired slot whose
    object was published this sweep holds the correct payload."""
    rng = np.random.default_rng(11)
    k, n, cap = 12, 2, 6
    plan, home, owners, home_np = random_plan(rng, k, n)
    moves = plan_moves(plan, home, cap, max_moves=k, object_bytes=4.0)
    values = jnp.arange(k * 3, dtype=jnp.float32).reshape(k, 3)
    for r in range(n):
        filled = publish_and_fill(
            create_cache(cap, (3,)), moves, values,
            jnp.arange(k, dtype=jnp.int32), rank=r,
        )
        ids = np.asarray(filled.ids)
        desired = np.asarray(moves.slot_ids)[r]
        published = set(int(i) for i in np.asarray(moves.publish_ids) if i >= 0)
        for slot, want in enumerate(desired.tolist()):
            if want >= 0 and want in published:
                assert ids[slot] == want
                np.testing.assert_allclose(
                    np.asarray(filled.data[slot]), np.asarray(values[want])
                )


SHARD_MAP_SCRIPT = r"""
from functools import partial
import jax, jax.numpy as jnp, numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.placement import PlacementPlan
from repro.core.repartition import create_cache, plan_moves, publish_and_fill

k, n, cap, d = 12, 2, 5, 3
rng = np.random.default_rng(0)
owners = rng.random((k, n)) < 0.6
home = (np.arange(k) % n).astype(np.int32)  # even split: k/2 objects per rank
owners[np.arange(k), home] = True
prev = rng.random((k, n)) < 0.3
plan = PlacementPlan(
    owners=jnp.asarray(owners),
    to_add=jnp.asarray(owners & ~prev),
    to_drop=jnp.asarray(prev & ~owners),
    expired=jnp.zeros((k,), bool),
)
moves = plan_moves(plan, jnp.asarray(home), cap, max_moves=k, object_bytes=4.0)
values = np.arange(k * d, dtype=np.float32).reshape(k, d)

# Reference path: axis_name=None, every "rank" sees the full object table.
ref = [
    publish_and_fill(
        create_cache(cap, (d,)), moves, jnp.asarray(values),
        jnp.arange(k, dtype=jnp.int32), rank=r,
    )
    for r in range(n)
]

# Collective path: each rank holds only its home shard; one psum assembles
# the publish buffer (the paper's per-key RPCs as a single fused collective).
local_ids = np.stack([np.where(home == r)[0] for r in range(n)])  # [n, k/2]
local_vals = values[local_ids]  # [n, k/2, d]
mesh = Mesh(np.array(jax.devices()[:n]), ("x",))

@partial(shard_map, mesh=mesh, in_specs=(P("x"), P("x")), out_specs=P("x"))
def run(lv, lid):
    rank = jax.lax.axis_index("x")
    out = publish_and_fill(
        create_cache(cap, (d,)), moves, lv[0], lid[0],
        rank=rank, axis_name="x",
    )
    return jax.tree_util.tree_map(lambda a: a[None], out)

got = run(jnp.asarray(local_vals), jnp.asarray(local_ids, dtype=jnp.int32))
for r in range(n):
    np.testing.assert_array_equal(np.asarray(got.ids[r]), np.asarray(ref[r].ids))
    np.testing.assert_allclose(np.asarray(got.data[r]), np.asarray(ref[r].data))
print("SHARD_MAP_EQUIVALENCE_OK")
"""


def test_publish_and_fill_matches_shard_map_two_ranks(run_multi_rank):
    out = run_multi_rank(SHARD_MAP_SCRIPT, num_devices=2, timeout=300)
    assert "SHARD_MAP_EQUIVALENCE_OK" in out
