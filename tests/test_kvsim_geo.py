"""Geo-topology latency model tests: nearest-replica reads, relay+broadcast
writes over the [N, N] RTT matrix, and the new WAN / diurnal workloads."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kvsim import (
    RedynisPolicy,
    StaticPolicy,
    WAN5_RTT_MS,
    diurnal_workload,
    generate_trace,
    run_scenario,
    wan5_cluster,
    wan5_workload,
)
from repro.kvsim.cluster import (
    ClusterConfig,
    flat_rtt,
    nearest_replica_rtt,
    read_latency,
    read_latency_geo,
    write_latency,
    write_latency_geo,
)


def test_wan5_rtt_matrix_is_symmetric_zero_diag():
    m = np.asarray(WAN5_RTT_MS)
    np.testing.assert_array_equal(m, m.T)
    np.testing.assert_array_equal(np.diag(m), 0.0)
    assert (m + np.eye(5) > 0).all()


def test_nearest_replica_picks_minimum_rtt():
    rtt = jnp.asarray(
        [[0.0, 10.0, 50.0], [10.0, 0.0, 30.0], [50.0, 30.0, 0.0]], jnp.float32
    )
    # key replicated on {1, 2}; requests from nodes 0, 1, 2
    replicas = jnp.asarray([[False, True, True]] * 3)
    nodes = jnp.asarray([0, 1, 2], jnp.int32)
    got = nearest_replica_rtt(rtt, replicas, nodes)
    np.testing.assert_allclose(np.asarray(got), [10.0, 0.0, 0.0])


def test_nearest_replica_orphan_pays_worst_rtt():
    rtt = jnp.asarray([[0.0, 40.0], [40.0, 0.0]], jnp.float32)
    got = nearest_replica_rtt(
        rtt, jnp.zeros((1, 2), bool), jnp.asarray([0], jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(got), [40.0])


@pytest.mark.parametrize("local_ms", [0.0, 5.0])
def test_geo_read_write_collapse_to_flat_model(local_ms):
    """On the degenerate flat topology the geo functions must equal the
    paper-verbatim flat functions for every hit/miss and owner combination —
    including a nonzero intra-node latency on the diagonal."""
    cfg = ClusterConfig(local_ms=local_ms)
    rtt = cfg.rtt_matrix()
    np.testing.assert_array_equal(
        np.asarray(rtt), np.asarray(flat_rtt(3, 100.0, local_ms))
    )

    # reads: hit (replica at requester) vs miss
    replicas = jnp.asarray([[True, False, True], [False, True, False]])
    nodes = jnp.asarray([0, 2], jnp.int32)
    hit = replicas[jnp.arange(2), nodes]
    np.testing.assert_allclose(
        np.asarray(read_latency_geo(cfg, rtt, replicas, nodes)),
        np.asarray(read_latency(cfg, hit)),
    )

    # writes: sole-local / master-owner-only / remote-owner combinations
    replicas = jnp.asarray(
        [[False, True, False], [True, False, False], [True, True, False]]
    )
    nodes = jnp.asarray([1, 0, 2], jnp.int32)
    sole = jnp.asarray([True, False, False])
    owners_not_master = replicas.at[:, cfg.master].set(False)
    any_remote = jnp.any(owners_not_master, axis=-1)
    np.testing.assert_allclose(
        np.asarray(write_latency_geo(cfg, rtt, replicas, nodes, sole)),
        np.asarray(write_latency(cfg, nodes, sole, any_remote)),
    )


def test_geo_write_pays_relay_plus_farthest_owner():
    rtt = jnp.asarray(
        [[0.0, 10.0, 50.0], [10.0, 0.0, 30.0], [50.0, 30.0, 0.0]], jnp.float32
    )
    cfg = ClusterConfig(service_ms=1.0, master=0)
    replicas = jnp.asarray([[True, True, True]])
    nodes = jnp.asarray([1], jnp.int32)  # requester != master
    sole = jnp.asarray([False])
    # relay rtt[1,0]=10 + broadcast max(rtt[0, owners])=50
    got = write_latency_geo(cfg, rtt, replicas, nodes, sole)
    np.testing.assert_allclose(np.asarray(got), [1.0 + 10.0 + 50.0])


def test_transfer_cost_scales_with_value_bytes():
    cfg_small = ClusterConfig(transfer_ms_per_kb=2.0, value_bytes=1024.0)
    cfg_large = cfg_small._replace(value_bytes=4096.0)
    rtt = cfg_small.rtt_matrix()
    replicas = jnp.asarray([[False, True, False]])
    nodes = jnp.asarray([0], jnp.int32)
    lat_small = float(read_latency_geo(cfg_small, rtt, replicas, nodes)[0])
    lat_large = float(read_latency_geo(cfg_large, rtt, replicas, nodes)[0])
    assert lat_large == pytest.approx(lat_small + 2.0 * 3.0)  # +3 KB remote
    # local reads never pay transfer
    local = jnp.asarray([[True, False, False]])
    assert float(read_latency_geo(cfg_large, rtt, local, nodes)[0]) == cfg_large.service_ms
    # ... even when the diagonal models a nonzero intra-node latency
    cfg_diag = cfg_large._replace(local_ms=5.0)
    lat = float(read_latency_geo(cfg_diag, cfg_diag.rtt_matrix(), local, nodes)[0])
    assert lat == cfg_diag.service_ms + 5.0  # intra-node RTT, no transfer


def test_wan5_scenario_ordering():
    """Paper §10 shape survives real geography: local > optimized > remote."""
    geo = wan5_cluster()
    wl = wan5_workload(num_requests=10_000, num_keys=500)
    loc = run_scenario(wl, geo, StaticPolicy(mode="local"), seed=0)
    opt = run_scenario(wl, geo, RedynisPolicy(), seed=0)
    rem = run_scenario(wl, geo, StaticPolicy(mode="remote"), seed=0)
    assert loc.throughput_ops_s > opt.throughput_ops_s > rem.throughput_ops_s
    assert opt.throughput_ops_s > 3 * rem.throughput_ops_s
    assert opt.hit_rate > 0.7


def test_region_weights_shape_natural_sources():
    wl = wan5_workload(num_requests=1_000, num_keys=2_000)
    t = generate_trace(wl, seed=0)
    counts = np.bincount(np.asarray(t.natural_node), minlength=5) / wl.num_keys
    # hot regions get more keys than cold ones (0.35/0.25 vs 0.12/0.08)
    assert counts[0] > counts[3] and counts[1] > counts[4]


def test_diurnal_rotation_moves_request_sources():
    wl = diurnal_workload(num_requests=8_000, num_keys=400)
    t = generate_trace(wl, seed=0)
    nodes = np.asarray(t.nodes)
    q = len(nodes) // wl.diurnal_shifts
    first, last = nodes[:q], nodes[-q:]
    # phase p shifts sources by p (mod n): the hot region (weight 0.6 on
    # region 0) appears at region 0 in phase 0 and region 3 in phase 3
    h_first = np.bincount(first, minlength=5)
    h_last = np.bincount(last, minlength=5)
    assert h_first.argmax() == 0
    assert h_last.argmax() == 3


def test_decay_daemon_chases_diurnal_hot_region():
    """The beyond-paper count decay exists exactly for this workload: with
    saturating raw counters the daemon clings to stale placements; decayed
    counters follow the sun."""
    geo = wan5_cluster()
    wl = diurnal_workload(num_requests=20_000)
    sticky = run_scenario(wl, geo, RedynisPolicy(decay=1.0), seed=0)
    chasing = run_scenario(wl, geo, RedynisPolicy(decay=0.5), seed=0)
    assert chasing.hit_rate > sticky.hit_rate + 0.1
    assert chasing.throughput_ops_s > sticky.throughput_ops_s
