"""Distributed-semantics tests, run in a subprocess with 8 forced host
devices (jax locks the device count at first init, so the main pytest
process must stay at 1 device for the smoke tests)."""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, reduced, ShapeConfig
from repro.dist import embed_lookup, softmax_xent, unembed_logits
from repro.launch.mesh import make_mesh
from repro.launch.sharding import (
    batch_shardings, make_dist, param_shardings, state_shardings,
)
from repro.models import build

mesh = make_mesh((2, 4), ("data", "model"))
dist = make_dist(mesh)

# ---- 1. vocab-sharded embedding lookup == local take -----------------------
v, d = 64, 16
table = jax.random.normal(jax.random.PRNGKey(0), (v, d))
tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 6), 0, v)
with mesh:
    sharded = jax.jit(
        lambda t, tok: embed_lookup(t, tok, dist),
        in_shardings=(NamedSharding(mesh, P("model", None)), NamedSharding(mesh, P("data", None))),
    )(table, tokens)
local = jnp.take(table, tokens, axis=0)
np.testing.assert_allclose(np.asarray(sharded), np.asarray(local), atol=1e-6)
print("embed_lookup OK")

# ---- 2. vocab-sharded xent == local xent ----------------------------------
x = jax.random.normal(jax.random.PRNGKey(2), (4, 8, d))
targets = jax.random.randint(jax.random.PRNGKey(3), (4, 8), 0, v - 10)
with mesh:
    l_sharded = jax.jit(
        lambda x, t, tg: softmax_xent(x, t, tg, dist, num_chunks=4, vocab_size=v - 4),
        in_shardings=(
            NamedSharding(mesh, P("data", None, None)),
            NamedSharding(mesh, P("model", None)),
            NamedSharding(mesh, P("data", None)),
        ),
    )(x, table, targets)
l_local = softmax_xent(x, table, targets, None, num_chunks=4, vocab_size=v - 4)
np.testing.assert_allclose(float(l_sharded), float(l_local), rtol=1e-5)
print("softmax_xent OK")

# ---- 3. sharded grads == local grads (tiny dense arch) ---------------------
cfg = dataclasses.replace(reduced(get_config("qwen3-1.7b")), num_layers=2, remat="none")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = model.make_batch(ShapeConfig("s", 32, 4, "train"), jax.random.PRNGKey(1))
batch["targets"] = batch["tokens"]
loss_local, _ = model.loss(params, batch)
p_sh = param_shardings(model, mesh)
b_sh = batch_shardings(model, mesh, {k: jax.ShapeDtypeStruct(x.shape, x.dtype) for k, x in batch.items()})
with mesh:
    loss_dist, _ = jax.jit(
        lambda p, b: model.loss(p, b, dist), in_shardings=(p_sh, b_sh)
    )(params, batch)
np.testing.assert_allclose(float(loss_dist), float(loss_local), rtol=2e-2)
print("dense sharded loss OK", float(loss_dist), float(loss_local))

# ---- 4. MoE arch: sharded loss == local loss (dispatch einsum + a2a) -------
cfg = dataclasses.replace(reduced(get_config("granite-moe-1b-a400m")), num_layers=2, remat="none")
model = build(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = model.make_batch(ShapeConfig("s", 32, 4, "train"), jax.random.PRNGKey(1))
batch["targets"] = batch["tokens"]
loss_local, _ = model.loss(params, batch)
p_sh = param_shardings(model, mesh)
b_sh = batch_shardings(model, mesh, {k: jax.ShapeDtypeStruct(x.shape, x.dtype) for k, x in batch.items()})
with mesh:
    loss_dist, _ = jax.jit(
        lambda p, b: model.loss(p, b, dist), in_shardings=(p_sh, b_sh)
    )(params, batch)
np.testing.assert_allclose(float(loss_dist), float(loss_local), rtol=2e-2)
print("moe sharded loss OK", float(loss_dist), float(loss_local))

# ---- 5. decode state shardings compile + match local ----------------------
state = model.init_state(8, 16)
s_sh = state_shardings(model, mesh, state)
toks = jnp.zeros((8,), jnp.int32)
with mesh:
    logits_dist, _ = jax.jit(
        lambda p, s, t: model.decode_step(p, s, t, dist),
        in_shardings=(p_sh, s_sh, NamedSharding(mesh, P("data"))),
    )(params, state, toks)
logits_local, _ = model.decode_step(params, state, toks)
np.testing.assert_allclose(
    np.asarray(logits_dist, np.float32), np.asarray(logits_local, np.float32),
    atol=0.15, rtol=0.05,
)
print("decode sharded OK")

# ---- 6. multi-pod style mesh (pod axis) -----------------------------------
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
dist3 = make_dist(mesh3)
p_sh3 = param_shardings(model, mesh3)
b_sh3 = batch_shardings(model, mesh3, {k: jax.ShapeDtypeStruct(x.shape, x.dtype) for k, x in batch.items()})
with mesh3:
    loss3, _ = jax.jit(
        lambda p, b: model.loss(p, b, dist3), in_shardings=(p_sh3, b_sh3)
    )(params, batch)
np.testing.assert_allclose(float(loss3), float(loss_local), rtol=2e-2)
print("multi-pod mesh OK")
print("ALL DISTRIBUTED TESTS PASSED")
"""


@pytest.mark.slow
def test_distributed_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=1200,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    assert "ALL DISTRIBUTED TESTS PASSED" in proc.stdout
