"""Property tests for the attention implementations (hypothesis-driven
shape sweeps): blockwise == dense under padding, windows, GQA groupings."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    blockwise_attention,
    decode_attention,
    dense_attention,
)


@settings(max_examples=12, deadline=None)
@given(
    st.integers(1, 2),  # batch
    st.integers(33, 160),  # seq (often non-chunk-aligned)
    st.sampled_from([(4, 1), (4, 2), (4, 4), (6, 2)]),  # (H, KH)
    st.sampled_from([0, 17, 64]),  # window
    st.sampled_from([32, 64]),  # chunk
)
def test_blockwise_equals_dense(b, s, heads, window, chunk):
    h, kh = heads
    ks = jax.random.split(jax.random.PRNGKey(s * 7 + h), 3)
    q = jax.random.normal(ks[0], (b, s, h, 16))
    k = jax.random.normal(ks[1], (b, s, kh, 16))
    v = jax.random.normal(ks[2], (b, s, kh, 16))
    o1 = blockwise_attention(q, k, v, causal=True, window=window, chunk=chunk)
    o2 = dense_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5, rtol=3e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(40, 200), st.integers(1, 3))
def test_cross_attention_kv_padding(t, b):
    """Non-chunk-aligned memories (whisper's 1500 frames) mask correctly."""
    ks = jax.random.split(jax.random.PRNGKey(t), 3)
    q = jax.random.normal(ks[0], (b, 64, 4, 16))
    k = jax.random.normal(ks[1], (b, t, 2, 16))
    v = jax.random.normal(ks[2], (b, t, 2, 16))
    o1 = blockwise_attention(q, k, v, causal=False, chunk=32)
    o2 = dense_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=3e-5, rtol=3e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 64))
def test_decode_matches_dense_last_position(t):
    """decode_attention over a cache == dense attention's final row."""
    ks = jax.random.split(jax.random.PRNGKey(t), 3)
    b, h, kh, d = 2, 4, 2, 16
    k = jax.random.normal(ks[1], (b, t, kh, d))
    v = jax.random.normal(ks[2], (b, t, kh, d))
    q_full = jax.random.normal(ks[0], (b, t, h, d))
    dense = dense_attention(q_full, k, v, causal=True)[:, -1]
    dec = decode_attention(q_full[:, -1], k, v, jnp.full((b,), t, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec), np.asarray(dense), atol=3e-5, rtol=3e-5)
