"""Capacity-aware scored placement pipeline: projection-stage properties
(numpy oracle, budget compliance, bit-exact inf reduction to Algorithm 3),
unified expiry semantics, post-projection plan_moves consistency, and the
end-to-end hit-rate-vs-capacity degradation axis. Seeded grids always run;
hypothesis widens the search when installed (CI does)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.costmodel import budget_plan, project_capacity
from repro.core.metadata import create_store
from repro.core.ownership import ownership_fraction
from repro.core.placement import PlacementDaemon, sweep
from repro.core.repartition import plan_moves
from repro.kvsim import (
    ClusterConfig,
    RedynisPolicy,
    StaticPolicy,
    WorkloadConfig,
    run_scenario,
    wan5_edge_cluster,
)

BASELINES = {
    "local": StaticPolicy(mode="local"),
    "remote": StaticPolicy(mode="remote"),
    "optimized": RedynisPolicy(),
    "replicated": StaticPolicy(mode="replicated"),
}

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


def _random_inputs(seed, k, n):
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 50, size=(k, n)).astype(np.float32)
    counts[rng.random(k) < 0.2] = 0.0  # zero-traffic rows
    hosts = rng.random((k, n)) < 0.4
    live = rng.random(k) < 0.9
    obj = rng.integers(1, 200, size=k).astype(np.float32)
    return counts, hosts, live, obj


def _projection_oracle(owners, hosts, f, obj, budget):
    """Per-node admission in plain Python: rank by f descending (held beats
    add at equal f, then lowest id) and admit while the *running* byte total
    fits — no skip-and-continue: a too-big candidate blocks everything
    colder, exactly the fixed-shape cumsum rule the jnp projector computes."""
    k, n = owners.shape
    out = np.zeros_like(owners)
    held = owners & hosts
    for x in range(n):
        cands = sorted(
            np.nonzero(owners[:, x])[0].tolist(),
            key=lambda i: (-f[i, x], not held[i, x], i),
        )
        sizes = np.cumsum([obj[i] for i in cands])
        for j, i in enumerate(cands):
            out[i, x] = sizes[j] <= budget[x]
    return out


def check_projection_matches_oracle(seed, k, n):
    rng = np.random.default_rng(seed)
    counts, hosts, live, obj = _random_inputs(seed, k, n)
    owners = rng.random((k, n)) < 0.5
    f = np.asarray(ownership_fraction(jnp.asarray(counts)))
    budget = rng.integers(50, 2000, size=n).astype(np.float32)

    projected, evicted, rejected = project_capacity(
        jnp.asarray(owners), jnp.asarray(hosts), jnp.asarray(f),
        jnp.asarray(obj), jnp.asarray(budget),
    )
    expect = _projection_oracle(owners, hosts, f, obj, budget)
    np.testing.assert_array_equal(np.asarray(projected), expect)
    np.testing.assert_array_equal(
        np.asarray(evicted), (owners & hosts) & ~expect
    )
    np.testing.assert_array_equal(
        np.asarray(rejected), (owners & ~hosts) & ~expect
    )


def check_projection_budget_and_shrink(seed, n, k):
    counts, hosts, live, obj = _random_inputs(seed, k, n)
    rng = np.random.default_rng(seed)
    owners = rng.random((k, n)) < 0.6
    f = ownership_fraction(jnp.asarray(counts))
    budget = rng.integers(1, 1500, size=n).astype(np.float32)
    projected, evicted, rejected = project_capacity(
        jnp.asarray(owners), jnp.asarray(hosts), f,
        jnp.asarray(obj), jnp.asarray(budget),
    )
    projected = np.asarray(projected)
    # budget respected exactly, and the projection only ever shrinks
    occupancy = (projected * obj[:, None]).sum(axis=0)
    assert np.all(occupancy <= budget + 1e-4), (occupancy, budget)
    assert np.all(projected <= owners)
    # evicted/rejected partition the trimmed set
    trimmed = owners & ~projected
    np.testing.assert_array_equal(
        np.asarray(evicted) | np.asarray(rejected), trimmed
    )
    assert not np.any(np.asarray(evicted) & np.asarray(rejected))


def check_infinite_budget_bit_exact(seed, n, k):
    """budget = inf ⇒ the paper's Algorithm 3, bit-for-bit: running the
    projection stage with an infinite budget must equal skipping it."""
    counts, hosts, live, obj = _random_inputs(seed, k, n)
    store = create_store(k, n)._replace(
        access_counts=jnp.asarray(counts, jnp.int32),
        hosts=jnp.asarray(hosts),
        live=jnp.asarray(live),
        last_access=jnp.asarray(
            np.random.default_rng(seed).integers(0, 90, k), jnp.int32
        ),
    )
    h = 1.0 / n
    base_plan, base_store = sweep(store, h, 100, 10)
    inf_plan, inf_store = sweep(
        store, h, 100, 10,
        object_bytes=jnp.asarray(obj),
        capacity_bytes=jnp.full((n,), jnp.inf),
    )
    for name, a, b in zip(base_plan._fields, base_plan, inf_plan):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"plan.{name}"
        )
    for name, a, b in zip(base_store._fields, base_store, inf_store):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"store.{name}"
        )


GRID = [(s, n, k) for s, (n, k) in enumerate(
    [(2, 4), (3, 60), (4, 17), (5, 48), (8, 33), (2, 1)]
)]


@pytest.mark.parametrize("seed,n,k", GRID)
def test_project_capacity_matches_numpy_oracle(seed, n, k):
    check_projection_matches_oracle(1000 + seed, k, n)


@pytest.mark.parametrize("seed,n,k", GRID)
def test_projection_respects_budget_and_only_shrinks(seed, n, k):
    check_projection_budget_and_shrink(seed, n, k)


@pytest.mark.parametrize("seed,n,k", GRID)
def test_sweep_with_infinite_budget_is_bit_exact_algorithm3(seed, n, k):
    check_infinite_budget_bit_exact(seed, n, k)


if HAVE_HYPOTHESIS:
    dims = st.tuples(
        st.integers(0, 10_000), st.integers(2, 8), st.integers(1, 48)
    )

    @settings(max_examples=25, deadline=None)
    @given(dims)
    def test_projection_oracle_fuzz(p):
        check_projection_matches_oracle(p[0], p[2], p[1])

    @settings(max_examples=25, deadline=None)
    @given(dims)
    def test_projection_budget_fuzz(p):
        check_projection_budget_and_shrink(*p)

    @settings(max_examples=20, deadline=None)
    @given(dims)
    def test_infinite_budget_bit_exact_fuzz(p):
        check_infinite_budget_bit_exact(*p)


def test_last_replica_eviction_and_readmission():
    """Bounded-cache semantics: under pressure the projection may evict a
    key's last replica (budget outranks the starvation guard); the key's
    counts survive, so a later sweep re-admits it once it ranks above the
    budget line — and in the meantime the simulator serves it at the
    topology's worst RTT instead of failing."""
    k, n = 4, 2
    # key 3 has the lowest ownership fraction on node 0 (f = .5, pinned to
    # node 0 only by the starvation guard at H = .6); everyone holds node 0
    counts = jnp.asarray([[9, 3], [8, 3], [7, 3], [1, 1]], jnp.int32)
    store = create_store(k, n)._replace(
        access_counts=counts,
        hosts=jnp.asarray([[True, False]] * k),
        live=jnp.ones((k,), bool),
    )
    obj = jnp.full((k,), 100.0)
    cap = jnp.asarray([300.0, 300.0])
    plan, swept = sweep(store, 0.6, 0, object_bytes=obj, capacity_bytes=cap)
    owners = np.asarray(plan.owners)
    assert not owners[3].any()  # last replica evicted — orphaned
    assert np.asarray(plan.capacity_evicted)[3, 0]
    # traffic shifts: key 3 becomes hottest -> re-admitted, coldest evicted
    swept = swept._replace(
        access_counts=swept.access_counts.at[3, 0].add(100)
    )
    plan2, _ = sweep(swept, 0.6, 1, object_bytes=obj, capacity_bytes=cap)
    assert np.asarray(plan2.owners)[3, 0]  # back above the budget line
    # the orphan read path is priced, not fatal (worst RTT = flat remote_ms)
    from repro.kvsim.cluster import nearest_replica_rtt

    rtt = ClusterConfig().rtt_matrix()
    lat = nearest_replica_rtt(
        rtt, jnp.zeros((1, 3), bool), jnp.zeros((1,), jnp.int32)
    )
    assert float(lat[0]) == 100.0


def test_peak_occupancy_static_scenarios_report_initial_map():
    """LOCAL/REPLICATED never mutate the replica map: their peak occupancy
    is exactly the full-replication map's bytes (K × object_bytes/node)."""
    wl = WorkloadConfig(num_requests=2_000)
    r = run_scenario(wl, ClusterConfig(), StaticPolicy(mode="local"), seed=0)
    expect = wl.num_keys * wl.object_bytes
    np.testing.assert_allclose(r.peak_occupancy_bytes, expect)
    assert r.evictions == 0.0 and r.capacity_evictions == 0.0


def test_budget_plan_evicts_coldest_held_when_over_budget():
    """A node holding more than its budget must shed its coldest replicas
    (keys ordered by ownership fraction) and keep the hottest."""
    k, n = 6, 2
    counts = jnp.asarray(
        [[60, 0], [50, 0], [40, 0], [30, 0], [20, 0], [10, 0]], jnp.float32
    )
    hosts = jnp.ones((k, n), bool)
    store = create_store(k, n)._replace(
        access_counts=counts.astype(jnp.int32), hosts=hosts,
        live=jnp.ones((k,), bool),
    )
    plan, _ = sweep(store, 0.5, 0)  # node 0 gets all keys, node 1 none
    obj = jnp.full((k,), 100.0)
    trimmed = budget_plan(plan, counts, obj, 300.0)
    owners = np.asarray(trimmed.owners)
    # node 0: only the 3 hottest keys (ids 0,1,2) fit 300 bytes
    np.testing.assert_array_equal(owners[:, 0], [True] * 3 + [False] * 3)
    evicted = np.asarray(trimmed.capacity_evicted)
    assert evicted[:, 0].sum() == 3  # cold held replicas evicted
    np.testing.assert_array_equal(
        np.asarray(trimmed.to_drop), np.asarray(plan.to_drop) | evicted
    )


def test_expiry_zero_is_disabled_on_every_path():
    """Unified expiry convention: 0 and None both disable, on both backends
    (the seed diverged: core treated 0 as 'expire anything untouched')."""
    counts, hosts, live, _ = _random_inputs(7, 33, 4)
    store = create_store(33, 4)._replace(
        access_counts=jnp.asarray(counts, jnp.int32),
        hosts=jnp.asarray(hosts),
        live=jnp.asarray(live),
        last_access=jnp.zeros((33,), jnp.int32),  # all stale vs now=100
    )
    plans = [
        sweep(store, 0.25, 100, exp, backend=bk)[0]
        for exp in (None, 0)
        for bk in ("jax", "pallas")
    ]
    for p in plans:
        assert not np.asarray(p.expired).any()
        np.testing.assert_array_equal(
            np.asarray(p.owners), np.asarray(plans[0].owners)
        )
    # positive expiry still purges
    plan_on, _ = sweep(store, 0.25, 100, 10)
    assert np.asarray(plan_on.expired).sum() > 0


def test_daemon_validates_expiry_and_backend():
    with pytest.raises(ValueError, match="expiry"):
        PlacementDaemon(4, expiry=-1)
    with pytest.raises(ValueError, match="backend"):
        PlacementDaemon(4, backend="cuda")
    PlacementDaemon(4, expiry=0, backend="pallas")  # 0 = disabled, valid


def test_plan_moves_respects_post_projection_plan():
    """plan_moves on a capacity-projected plan must never schedule an
    evicted replica into a cache slot nor publish a rejected add."""
    rng = np.random.default_rng(3)
    k, n = 24, 3
    counts, hosts, live, obj = _random_inputs(3, k, n)
    store = create_store(k, n)._replace(
        access_counts=jnp.asarray(counts, jnp.int32),
        hosts=jnp.asarray(hosts),
        live=jnp.ones((k,), bool),
    )
    plan, _ = sweep(
        store, 1.0 / n, 0,
        object_bytes=jnp.asarray(obj),
        capacity_bytes=jnp.full((n,), 400.0),
    )
    home = jnp.asarray(rng.integers(0, n, k), jnp.int32)
    moves = plan_moves(
        plan, home, cache_capacity=8, max_moves=k,
        object_bytes=jnp.asarray(obj),
    )
    owners = np.asarray(plan.owners)
    home_np = np.asarray(home)
    slot_ids = np.asarray(moves.slot_ids)
    for rank in range(n):
        filled = [i for i in slot_ids[rank].tolist() if i >= 0]
        wanted = set(np.nonzero(owners[:, rank] & (home_np != rank))[0].tolist())
        assert set(filled) <= wanted
        # per-rank cache residency accounting matches the schedule
        np.testing.assert_allclose(
            float(moves.slot_bytes[rank]), obj[filled].sum(), rtol=1e-6
        )
    published = set(int(i) for i in np.asarray(moves.publish_ids) if i >= 0)
    surviving_adds = set(np.nonzero(np.asarray(plan.to_add).any(-1))[0].tolist())
    assert published == surviving_adds


# ---------------------------------------------------------------------------
# End-to-end: the new scenario axis (hit-rate vs capacity).
# ---------------------------------------------------------------------------

CAPACITIES = (float("inf"), 128 * 1024.0, 64 * 1024.0, 32 * 1024.0, 16 * 1024.0)


def test_optimized_hit_rate_degrades_monotonically_with_capacity():
    """Property: shrinking per-node replica budgets can only hurt the
    OPTIMIZED hit rate; budgets smaller than the hot set must evict
    (hot set = 100 keys × 1 KiB = 100 KiB per node at convergence)."""
    wl = WorkloadConfig(num_requests=20_000, skewed=True)
    hits, evics = [], []
    for cap in CAPACITIES:
        r = run_scenario(
            wl, ClusterConfig(capacity_bytes=cap), RedynisPolicy(), seed=0
        )
        hits.append(r.hit_rate)
        evics.append(r.capacity_evictions)
    for smaller, larger in zip(hits[1:], hits[:-1]):
        assert smaller <= larger + 1e-3, hits
    assert evics[0] == 0.0  # inf budget: projection never runs
    assert all(e > 0 for e in evics[1:]), evics  # finite budgets evict
    # a budget well under the hot set visibly degrades vs Algorithm 3
    assert hits[-1] < hits[0] - 0.2, hits


def test_infinite_capacity_run_is_default_run():
    """ClusterConfig(capacity_bytes=inf) must be indistinguishable from the
    pre-refactor engine (the projection stage compiles away)."""
    wl = WorkloadConfig(num_requests=5_000, skewed=True)
    base = ClusterConfig()
    explicit = ClusterConfig(capacity_bytes=float("inf"))
    for name, pol in BASELINES.items():
        a = run_scenario(wl, base, pol, seed=1)
        b = run_scenario(wl, explicit, pol, seed=1)
        assert a.throughput_ops_s == b.throughput_ops_s, name
        assert a.hit_rate == b.hit_rate, name
        assert a.capacity_evictions == 0.0 and b.capacity_evictions == 0.0


def test_wan5_edge_node_keeps_core_unconstrained():
    """Heterogeneous preset: the small edge node evicts while the run still
    completes, and the new metrics are reported per node."""
    from repro.kvsim import wan5_workload

    wl = wan5_workload(num_requests=10_000, num_keys=300)
    cl = wan5_edge_cluster(edge_capacity_bytes=8 * 1024.0)
    r = run_scenario(wl, cl, RedynisPolicy(), seed=0, daemon_interval=500)
    assert r.capacity_evictions > 0
    # peak occupancy is reported per node ([N] vector)
    assert r.peak_occupancy_bytes.shape == (5,)
