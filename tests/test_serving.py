"""Serving layer: engine lanes/eviction, decode fidelity, router behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import build
from repro.serving import LaneTable, Request, ServeEngine
from repro.serving.kvcache import state_bytes


def test_lane_table_lru_eviction():
    lt = LaneTable(2)
    l0, ev = lt.bind("a")
    assert ev is None
    l1, _ = lt.bind("b")
    lt.lookup("a")  # refresh a -> b becomes LRU
    l2, evicted = lt.bind("c")
    assert evicted == "b" and l2 == l1
    lt.release("a")
    assert "a" not in lt.active


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "rwkv6-1.6b"])
def test_engine_batched_generation(arch):
    cfg = reduced(get_config(arch))
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, num_lanes=4, cache_len=64)
    rng = np.random.default_rng(0)
    for i in range(3):
        eng.admit(Request(f"s{i}", rng.integers(0, cfg.vocab_size, 12), max_new=5))
    outs = eng.run_to_completion()
    assert all(len(v) == 6 for v in outs.values())
    assert eng.tokens_out == 15
    assert state_bytes(eng.state) > 0


def test_engine_interleaved_admission():
    """A request admitted mid-decode of others generates correctly."""
    cfg = reduced(get_config("qwen3-1.7b"))
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt_a = np.arange(8) % cfg.vocab_size
    prompt_b = (np.arange(8) * 3 + 1) % cfg.vocab_size

    eng = ServeEngine(m, params, num_lanes=2, cache_len=32)
    eng.admit(Request("a", prompt_a, max_new=4))
    eng.step()
    eng.admit(Request("b", prompt_b, max_new=4))  # joins mid-flight
    out = eng.run_to_completion()

    for sid, prompt in (("a", prompt_a), ("b", prompt_b)):
        seq, ref = list(prompt), []
        for _ in range(5):
            logits, _ = m.prefill(params, {"tokens": jnp.asarray(seq, jnp.int32)[None]})
            t = int(jnp.argmax(logits, -1)[0])
            ref.append(t)
            seq.append(t)
        assert out[sid] == ref, sid


def test_engine_sampled_generation_reproducible():
    cfg = reduced(get_config("qwen3-1.7b"))
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    outs = []
    for _ in range(2):
        eng = ServeEngine(m, params, num_lanes=2, cache_len=32, temperature=1.0, seed=7)
        eng.admit(Request("a", np.arange(8) % cfg.vocab_size, max_new=6))
        outs.append(eng.run_to_completion()["a"])
    assert outs[0] == outs[1]
    assert max(outs[0]) < cfg.vocab_size
