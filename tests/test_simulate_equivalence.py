"""Regression guard for the scan-fused simulation engine: the single
``lax.scan`` program (`run_scenario`) must match the retained per-chunk
Python reference loop (`run_scenario_reference`) field-for-field, and the
degenerate flat-RTT topology must reproduce the seed Fig 2/3 numbers."""

import numpy as np
import pytest

from repro.kvsim import (
    ClusterConfig,
    RedynisPolicy,
    SimResult,
    StaticPolicy,
    WorkloadConfig,
    flat_rtt,
    run_scenario,
    run_scenario_reference,
    wan5_cluster,
    wan5_workload,
)

# Reference accumulates busy-time in float64 host-side, the fused engine in
# float32 on device: allclose, not bit-identical.
RTOL = 1e-4

# The four seed-era baselines, as policies (the legacy Scenario spellings).
BASELINES = {
    "local": StaticPolicy(mode="local"),
    "remote": StaticPolicy(mode="remote"),
    "optimized": RedynisPolicy(),
    "replicated": StaticPolicy(mode="replicated"),
}


def assert_results_match(a: SimResult, b: SimResult, ctx: str = ""):
    for field, x, y in zip(SimResult._fields, a, b):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=RTOL, err_msg=f"{ctx} {field}"
        )


@pytest.mark.parametrize("name", sorted(BASELINES))
def test_scan_matches_reference_all_scenarios(name):
    policy = BASELINES[name]
    wl = WorkloadConfig(num_requests=4_000, num_keys=200, skewed=True)
    cl = ClusterConfig()
    a = run_scenario(wl, cl, policy, seed=2, daemon_interval=500)
    b = run_scenario_reference(wl, cl, policy, seed=2, daemon_interval=500)
    assert_results_match(a, b, name)


def test_scan_matches_reference_padded_trace():
    """Trace length not divisible by daemon_interval exercises the fixed-shape
    padding (valid-masked) path of the fused engine."""
    wl = WorkloadConfig(num_requests=3_300, num_keys=150)
    cl = ClusterConfig()
    a = run_scenario(wl, cl, RedynisPolicy(), seed=1, daemon_interval=500)
    b = run_scenario_reference(wl, cl, RedynisPolicy(), seed=1, daemon_interval=500)
    assert_results_match(a, b, "padded")


def test_scan_matches_reference_wan5_topology():
    wl = wan5_workload(num_requests=4_000, num_keys=200)
    cl = wan5_cluster()
    a = run_scenario(wl, cl, RedynisPolicy(), seed=0, daemon_interval=500)
    b = run_scenario_reference(wl, cl, RedynisPolicy(), seed=0, daemon_interval=500)
    assert_results_match(a, b, "wan5")


def test_scan_matches_reference_finite_capacity():
    """Finite per-node replica budgets + a lognormal object-size distribution
    exercise the capacity-projection stage inside the scan body; the fused
    engine must still match the per-chunk oracle on every metric, including
    the new eviction/occupancy fields."""
    wl = WorkloadConfig(
        num_requests=4_000, num_keys=200, skewed=True, object_bytes_sigma=0.5
    )
    cl = ClusterConfig(capacity_bytes=24 * 1024.0)
    a = run_scenario(wl, cl, RedynisPolicy(), seed=2, daemon_interval=500)
    b = run_scenario_reference(wl, cl, RedynisPolicy(), seed=2, daemon_interval=500)
    assert_results_match(a, b, "capacity")
    assert a.capacity_evictions > 0


def test_scan_matches_reference_heterogeneous_capacity():
    """wan5 with one small edge node (heterogeneous budget tuple)."""
    from repro.kvsim import wan5_edge_cluster

    wl = wan5_workload(num_requests=4_000, num_keys=200)
    cl = wan5_edge_cluster(edge_capacity_bytes=8 * 1024.0)
    a = run_scenario(wl, cl, RedynisPolicy(), seed=0, daemon_interval=500)
    b = run_scenario_reference(wl, cl, RedynisPolicy(), seed=0, daemon_interval=500)
    assert_results_match(a, b, "wan5-edge")


def test_scan_matches_reference_daemon_options():
    """Expiry + decay + non-unit period take the due-masked branch of
    `masked_step`; they must still match the host-side daemon exactly."""
    wl = WorkloadConfig(num_requests=4_000, num_keys=200, skewed=True, affinity=0.8)
    cl = ClusterConfig()
    policy = RedynisPolicy(
        h=0.2,
        expiry=4,
        decay=0.5,
        period=2,  # odd chunks take masked_step's not-due branch
    )
    a = run_scenario(wl, cl, policy, seed=3, daemon_interval=250)
    b = run_scenario_reference(wl, cl, policy, seed=3, daemon_interval=250)
    assert_results_match(a, b, "daemon-options")


def test_masked_step_not_due_is_identity():
    """The scan-compatible daemon step must leave the store untouched and
    report zero moves on off ticks (the branch period>1 schedules exercise)."""
    import jax.numpy as jnp

    from repro.core.metadata import create_store, record_accesses
    from repro.core.placement import masked_step

    store = create_store(8, 3)._replace(live=jnp.ones((8,), bool))
    store = record_accesses(
        store, jnp.arange(8, dtype=jnp.int32), jnp.zeros((8,), jnp.int32), now=1
    )
    stats, out = masked_step(
        store, 2, jnp.bool_(False), h=1 / 3, expiry=5, decay=0.5
    )
    assert all(float(v) == 0.0 for v in stats), stats
    for field, a, b in zip(store._fields, store, out):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=field)


def test_flat_rtt_tuple_is_degenerate_topology():
    """An explicit flat [N, N] matrix must be indistinguishable from the
    legacy remote_ms/local_ms constants (the paper's testbed model)."""
    wl = WorkloadConfig(num_requests=5_000)
    implicit = ClusterConfig()
    explicit = ClusterConfig(rtt=flat_rtt(3, 100.0, 0.0))
    for name, policy in BASELINES.items():
        a = run_scenario(wl, implicit, policy, seed=0)
        b = run_scenario(wl, explicit, policy, seed=0)
        assert a.throughput_ops_s == b.throughput_ops_s, name
        assert a.hit_rate == b.hit_rate, name
        np.testing.assert_array_equal(a.node_busy_ms, b.node_busy_ms)


# Seed goldens: the pre-refactor engine's outputs on the default flat config
# (WorkloadConfig(num_requests=20_000), ClusterConfig(), seed=0). Pinning
# these guarantees the RTT-matrix generalisation reproduces the repo's
# original Fig 2/3 numbers as the degenerate topology.
SEED_GOLDENS = {
    "local": (292.95444558371173, 1.0, 10.0, 0.0),
    "remote": (26.632222325791975, 0.0, 110.0, 0.0),
    "optimized": (164.78536705940513, 0.92115, 17.885, 1000.0),
    "replicated": (292.95444558371173, 1.0, 10.0, 0.0),
}


@pytest.mark.parametrize("name", sorted(SEED_GOLDENS))
def test_flat_topology_reproduces_seed_goldens(name):
    wl = WorkloadConfig(num_requests=20_000)
    r = run_scenario(wl, ClusterConfig(), BASELINES[name], seed=0)
    tput, hit, mean_lat, moves = SEED_GOLDENS[name]
    np.testing.assert_allclose(r.throughput_ops_s, tput, rtol=1e-5)
    np.testing.assert_allclose(r.hit_rate, hit, rtol=1e-5)
    np.testing.assert_allclose(r.mean_latency_ms, mean_lat, rtol=1e-5)
    np.testing.assert_allclose(r.replication_moves, moves, rtol=0)
