"""Kernel ⇄ reference parity: the Pallas ``ownership_sweep`` must agree
bit-for-bit with ``core.placement.sweep`` on randomly generated metadata
stores — owners / add / drop / expired / f all compared, including the
starvation-guard rows (traffic but nobody meets H) and zero-traffic rows.
Runs in interpret mode on CPU (same kernel body, Python-executed), so CI
exercises the real tiling/masking logic. A fixed seeded grid always runs;
hypothesis widens the search when installed (CI does)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metadata import create_store
from repro.core.placement import sweep
from repro.kernels.ownership_sweep.kernel import ownership_sweep_call
from repro.kernels.ownership_sweep.ops import ownership_sweep

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


def _random_store(seed, k, n):
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 100, size=(k, n)).astype(np.int32)
    counts[rng.random(k) < 0.25] = 0  # zero-traffic rows keep placement
    hosts = rng.random((k, n)) < 0.4
    live = rng.random(k) < 0.85
    last = rng.integers(0, 120, size=k).astype(np.int32)
    return create_store(k, n)._replace(
        access_counts=jnp.asarray(counts),
        hosts=jnp.asarray(hosts),
        live=jnp.asarray(live),
        last_access=jnp.asarray(last),
    )


def check_call_matches_sweep(seed, n, k, expiry, h):
    """The raw kernel call vs the core engine's analysis pass."""
    store = _random_store(seed, k, n)
    now = 100
    plan, _ = sweep(store, h, now, expiry if expiry else None, backend="jax")
    tk = min(64, k)
    if k % tk:  # the raw call requires an even tiling; ops pads for us
        tk = k
    owners, add, drop, expired, f = ownership_sweep_call(
        store.access_counts.astype(jnp.float32),
        store.hosts,
        store.live,
        store.last_access,
        now,
        h=h,
        expiry=expiry,
        tk=tk,
        interpret=True,
    )
    np.testing.assert_array_equal(np.asarray(owners, bool), np.asarray(plan.owners))
    np.testing.assert_array_equal(np.asarray(add, bool), np.asarray(plan.to_add))
    np.testing.assert_array_equal(np.asarray(drop, bool), np.asarray(plan.to_drop))
    np.testing.assert_array_equal(
        np.asarray(expired, bool)[:, 0], np.asarray(plan.expired)
    )
    np.testing.assert_array_equal(np.asarray(f), np.asarray(plan.f))


def check_backend_dispatch_parity(seed, n, k, expiry, h):
    """The dispatch the simulator uses: sweep(backend="pallas") vs "jax" —
    full plan AND post-sweep store compared on identical stores (the ops
    wrapper pads odd K to the tile size)."""
    store = _random_store(seed, k, n)
    kw = dict(expiry=expiry if expiry else None)
    pj, sj = sweep(store, h, 100, backend="jax", **kw)
    pp, sp = sweep(store, h, 100, backend="pallas", **kw)
    for name, a, b in zip(pj._fields, pj, pp):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"plan.{name}"
        )
    for name, a, b in zip(sj._fields, sj, sp):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"store.{name}"
        )


# Fixed grid (always runs, no hypothesis needed): odd/even K around the tile
# size, expiry disabled (0) and enabled, H both below and above 1/n (above
# forces the starvation guard on every row with traffic).
PARITY_GRID = [
    (0, 3, 64, 0, 1 / 3),
    (1, 4, 57, 3, 0.5),  # odd K -> pad path; H > 1/n -> guard
    (2, 8, 80, 25, 0.125),
    (3, 2, 1, 0, 0.9),  # single key
    (4, 5, 33, 3, 0.05),
]


@pytest.mark.parametrize("params", PARITY_GRID)
def test_ownership_sweep_call_matches_placement_sweep(params):
    check_call_matches_sweep(*params)


@pytest.mark.parametrize("params", PARITY_GRID)
def test_sweep_backend_pallas_matches_jax(params):
    check_backend_dispatch_parity(*params)


if HAVE_HYPOTHESIS:
    store_strategy = st.tuples(
        st.integers(0, 2**31 - 1),  # numpy seed
        st.integers(2, 9),  # n nodes
        st.integers(1, 80),  # k keys (odd sizes exercise the pad path)
        st.sampled_from([0, 3, 25]),  # expiry (0 = disabled)
        st.floats(0.05, 0.9),  # h — values > 1/n force the starvation guard
    )

    @settings(max_examples=25, deadline=None)
    @given(store_strategy)
    def test_ownership_sweep_call_matches_placement_sweep_fuzz(params):
        check_call_matches_sweep(*params)

    @settings(max_examples=15, deadline=None)
    @given(store_strategy)
    def test_sweep_backend_pallas_matches_jax_fuzz(params):
        check_backend_dispatch_parity(*params)


def test_backend_parity_with_capacity_projection():
    """Capacity projection is an XLA post-pass on the kernel's outputs (fed
    by its f plane) — both backends must land on the same projected plan."""
    store = _random_store(11, 40, 4)
    obj = jnp.asarray(np.random.default_rng(11).integers(1, 300, 40), jnp.float32)
    cap = jnp.asarray([800.0, 400.0, jnp.inf, 150.0], jnp.float32)
    pj, _ = sweep(store, 0.25, 50, object_bytes=obj, capacity_bytes=cap, backend="jax")
    pp, _ = sweep(store, 0.25, 50, object_bytes=obj, capacity_bytes=cap, backend="pallas")
    for name, a, b in zip(pj._fields, pj, pp):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b), err_msg=f"plan.{name}"
        )


def test_ops_wrapper_pads_odd_sizes():
    """ops.ownership_sweep with K not divisible by the tile pads with dead
    zero rows that must not leak into the trimmed outputs."""
    store = _random_store(21, 70, 3)
    owners, add, drop, expired, f = ownership_sweep(
        store.access_counts.astype(jnp.float32),
        store.hosts, store.live, store.last_access, 0,
        h=1 / 3, tk=32,
    )
    plan, _ = sweep(store, 1 / 3, 0)
    np.testing.assert_array_equal(np.asarray(owners), np.asarray(plan.owners))
    assert owners.shape == (70, 3)


def test_run_scenario_pallas_backend_matches_jax():
    """The full fused engine with backend="pallas" (pallas_call inside the
    lax.scan body, interpret mode on CPU) must reproduce the jax backend's
    SimResult on the same trace — including under a finite capacity budget
    (projection as post-pass on kernel outputs)."""
    from repro.kvsim import ClusterConfig, RedynisPolicy, WorkloadConfig, run_scenario

    wl = WorkloadConfig(num_requests=2_000, num_keys=150, skewed=True)
    for cl in (ClusterConfig(), ClusterConfig(capacity_bytes=16 * 1024.0)):
        a = run_scenario(wl, cl, RedynisPolicy(backend="jax"), seed=3,
                         daemon_interval=500)
        b = run_scenario(wl, cl, RedynisPolicy(backend="pallas"), seed=3,
                         daemon_interval=500)
        for field, x, y in zip(a._fields, a, b):
            np.testing.assert_allclose(
                np.asarray(x), np.asarray(y), rtol=1e-6,
                err_msg=f"{cl.capacity_bytes} {field}",
            )


def test_starvation_guard_and_zero_traffic_rows_explicit():
    """Pinned corner rows: (a) traffic but H unreachable -> hottest node
    keeps the key on both backends; (b) zero traffic -> placement unchanged;
    (c) dead key -> no owners."""
    counts = jnp.asarray(
        [[5, 4, 0], [0, 0, 0], [7, 7, 7]], jnp.int32
    )
    hosts = jnp.asarray(
        [[False, False, True], [False, True, False], [True, False, False]]
    )
    live = jnp.asarray([True, True, False])
    store = create_store(3, 3)._replace(
        access_counts=counts, hosts=hosts, live=live,
    )
    for backend in ("jax", "pallas"):
        plan, _ = sweep(store, 0.99, 0, backend=backend)  # H ≫ any f
        owners = np.asarray(plan.owners)
        np.testing.assert_array_equal(
            owners[0], [True, False, False], err_msg=backend  # argmax guard
        )
        np.testing.assert_array_equal(
            owners[1], [False, True, False], err_msg=backend  # silence
        )
        assert not owners[2].any(), backend  # dead key
