"""Contention test tier (ISSUE-6 acceptance).

Pins the queueing-aware service-time model (``ServiceConfig`` — M/M/1-style
load factors from per-node demand folds and per-key object bytes):

1. Kernel ⇄ reference parity under contention: the Pallas chunk-replay
   kernel fed the canonical ``contention_extra_ms_ref`` pre-pass output must
   agree with the jnp oracle across load levels × object-size distributions
   × topologies — histograms bit-exact, busy/lat_sum allclose. Hypothesis
   widens the search over the busy-fold inputs when installed.
2. Busy-fold properties: the load factor equals an independent NumPy
   recomputation, respects the stability clamp, ignores invalid rows, and
   the M/M/1 wait is non-negative and monotone in rho.
3. Golden pinning: contention OFF (``service=None`` and
   ``ServiceConfig(enabled=False)``) compiles the exact pre-contention
   program — bit-identical results across both engines × both replay
   backends, still reproducing the seed Fig 2/3 goldens.
4. Engine agreement under contention: fused scan == per-chunk reference ==
   Pallas replay (and the static fast path == reference for frozen maps).
5. Monotonicity: hotter traffic concentration ⇒ higher load factor on the
   owning node (deterministic ref-level sweep + engine-level telemetry).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_replay.ops import chunk_replay
from repro.kernels.chunk_replay.ref import (
    READ_MODES,
    chunk_replay_ref,
    contention_extra_ms_ref,
    contention_wait_ref,
    load_factor_ref,
    service_demand_ref,
    serving_node_ref,
)
from repro.kvsim import (
    ClusterConfig,
    RedynisPolicy,
    ServiceConfig,
    SimResult,
    StaticPolicy,
    TelemetryConfig,
    WorkloadConfig,
    normalize_service,
    run_scenario,
    run_scenario_reference,
    wan5_cluster,
    wan5_edge_cluster,
    wan5_workload,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


TOPOLOGIES = {
    "flat": ClusterConfig().rtt_matrix(),
    "wan5": wan5_cluster().rtt_matrix(),
    "wan5_edge": wan5_edge_cluster().rtt_matrix(),
}

SERVICE_MS = 10.0


# ---------------------------------------------------------------------------
# 1. Kernel ⇄ reference parity under contention.
# ---------------------------------------------------------------------------


def _random_contended_chunk(seed, b, k, n, sigma, read_fraction=0.8):
    """Random frozen map + request slab + lognormal per-key object sizes
    (``sigma=0`` is the constant-size degenerate distribution)."""
    rng = np.random.default_rng(seed)
    hosts = rng.random((k, n)) < 0.4
    obj = (1024.0 * np.exp(rng.normal(0.0, sigma, k))).astype(np.float32)
    return (
        jnp.asarray(hosts),
        jnp.asarray(rng.integers(0, k, b).astype(np.int32)),
        jnp.asarray(rng.integers(0, n, b).astype(np.int32)),
        jnp.asarray(rng.random(b) < read_fraction),
        jnp.asarray(rng.random(b) < 0.9),  # valid mask (padding path)
        jnp.asarray(obj),
    )


def check_contended_kernel_matches_ref(
    rtt, seed, b, k, capacity_factor, sigma,
    read_mode="map", tr=256, tkey=128, rho_max=0.95,
):
    n = rtt.shape[0]
    hosts, keys, nodes, is_read, valid, obj = _random_contended_chunk(
        seed, b, k, n, sigma
    )
    service = ServiceConfig(
        serve_bytes_per_ms=512.0, capacity_factor=capacity_factor,
        rho_max=rho_max,
    )
    extra, rho = contention_extra_ms_ref(
        hosts, keys, nodes, is_read, valid, rtt, obj,
        read_mode=read_mode, service_ms=SERVICE_MS,
        serve_bytes_per_ms=service.serve_bytes_per_ms,
        capacity_ms=service.capacity_ms(b, SERVICE_MS),
        rho_max=service.rho_max,
    )
    assert float(jnp.max(rho)) <= rho_max + 1e-6
    assert float(jnp.min(extra)) >= 0.0
    kw = dict(
        service_ms=SERVICE_MS, master=0, xfer_read_ms=2.0, xfer_write_ms=3.0,
        read_mode=read_mode, num_bins=64, lo=1.0, hi=5_000.0,
    )
    ref = chunk_replay_ref(
        hosts, keys, nodes, is_read, valid, rtt, extra_ms=extra, **kw
    )
    ker = chunk_replay(
        hosts, keys, nodes, is_read, valid, rtt, extra_ms=extra,
        backend="pallas", tr=tr, tkey=tkey, interpret=True, **kw,
    )
    # busy / lat_sum: reductions re-associate across tiles -> allclose.
    np.testing.assert_allclose(
        np.asarray(ker[0]), np.asarray(ref[0]), rtol=1e-5, err_msg="busy"
    )
    np.testing.assert_allclose(
        float(ker[1]), float(ref[1]), rtol=1e-5, err_msg="lat_sum"
    )
    for i, name in ((2, "hits"), (3, "reads"), (4, "count")):
        assert float(ker[i]) == float(ref[i]), (name, ker[i], ref[i])
    # The kernel adds extra_ms in the oracle's elementwise position, so the
    # contended f32 latency bits — and the histogram buckets — match exactly.
    np.testing.assert_array_equal(np.asarray(ker[5]), np.asarray(ref[5]))
    np.testing.assert_allclose(float(jnp.sum(ker[5])), float(ker[4]))


# Load levels (capacity_factor: saturated -> light) × object-size
# distributions (sigma) × topologies; odd b/k exercise the pad paths.
PARITY_GRID = [
    (topo, cf, sigma)
    for topo in TOPOLOGIES
    for cf in (0.25, 1.0, 4.0)
    for sigma in (0.0, 1.2)
]


@pytest.mark.parametrize(
    "topo,cf,sigma", PARITY_GRID,
    ids=[f"{t}-cf{c}-sig{s}" for t, c, s in PARITY_GRID],
)
def test_contended_kernel_matches_ref(topo, cf, sigma):
    check_contended_kernel_matches_ref(
        TOPOLOGIES[topo], seed=hash((topo, cf, sigma)) % 2**32,
        b=777, k=333, capacity_factor=cf, sigma=sigma,
    )


@pytest.mark.parametrize("mode", READ_MODES)
def test_contended_kernel_matches_ref_all_read_modes(mode):
    check_contended_kernel_matches_ref(
        TOPOLOGIES["wan5"], seed=17, b=500, k=200,
        capacity_factor=0.5, sigma=0.8, read_mode=mode,
    )


if HAVE_HYPOTHESIS:
    fold_strategy = st.tuples(
        st.integers(0, 2**31 - 1),  # numpy seed
        st.integers(1, 400),  # b requests
        st.integers(1, 200),  # k keys
        st.integers(2, 8),  # n nodes
        st.floats(0.05, 4.0),  # capacity_factor (saturated -> light)
        st.floats(0.0, 2.0),  # object-size lognormal sigma
        st.sampled_from(READ_MODES),
        st.floats(0.5, 0.99),  # rho_max
    )

    @settings(max_examples=30, deadline=None)
    @given(fold_strategy)
    def test_busy_fold_properties_fuzz(params):
        """The pre-pass vs an independent NumPy recomputation of the
        per-serving-node demand fold."""
        seed, b, k, n, cf, sigma, mode, rho_max = params
        rtt = TOPOLOGIES["flat"]
        rng = np.random.default_rng(seed + 1)
        rtt = jnp.asarray(
            np.where(np.eye(n, dtype=bool), 0.0,
                     rng.uniform(1.0, 400.0, (n, n))).astype(np.float32)
        )
        hosts, keys, nodes, is_read, valid, obj = _random_contended_chunk(
            seed, b, k, n, sigma
        )
        capacity_ms = cf * b * SERVICE_MS
        serving = (
            np.asarray(nodes) if mode == "ideal"
            else np.asarray(serving_node_ref(
                hosts[keys], nodes, is_read, rtt, read_mode=mode
            ))
        )
        demand = np.asarray(service_demand_ref(
            obj[keys], service_ms=SERVICE_MS, serve_bytes_per_ms=512.0
        ))
        rho = np.asarray(load_factor_ref(
            jnp.asarray(serving), jnp.asarray(demand), valid,
            num_nodes=n, capacity_ms=capacity_ms, rho_max=rho_max,
        ))
        # Independent fold: demand summed per serving node, invalid rows
        # contributing nothing, clamped at the stability bound.
        fold = np.zeros(n, np.float32)
        np.add.at(fold, serving[np.asarray(valid)], demand[np.asarray(valid)])
        np.testing.assert_allclose(
            rho, np.minimum(fold / capacity_ms, rho_max), rtol=1e-5
        )
        assert (rho >= 0.0).all() and (rho <= rho_max + 1e-6).all()
        assert (serving >= 0).all() and (serving < n).all()
        assert (demand >= SERVICE_MS).all()
        wait = np.asarray(contention_wait_ref(
            jnp.asarray(demand), jnp.asarray(rho), jnp.asarray(serving)
        ))
        assert np.isfinite(wait).all() and (wait >= 0.0).all()
        # Monotone in rho: scaling every load factor up raises every wait.
        hotter = np.asarray(contention_wait_ref(
            jnp.asarray(demand),
            jnp.asarray(np.minimum(rho * 1.5, 0.99).astype(np.float32)),
            jnp.asarray(serving),
        ))
        assert (hotter >= wait - 1e-6).all()

    @settings(max_examples=15, deadline=None)
    @given(fold_strategy)
    def test_contended_kernel_matches_ref_fuzz(params):
        seed, b, k, n, cf, sigma, mode, rho_max = params
        rng = np.random.default_rng(seed + 1)
        rtt = jnp.asarray(
            np.where(np.eye(n, dtype=bool), 0.0,
                     rng.uniform(1.0, 400.0, (n, n))).astype(np.float32)
        )
        check_contended_kernel_matches_ref(
            rtt, seed=seed, b=b, k=k, capacity_factor=cf, sigma=sigma,
            read_mode=mode, tr=int(rng.choice([64, 256])),
            tkey=int(rng.choice([32, 128])), rho_max=rho_max,
        )


# ---------------------------------------------------------------------------
# 2. ServiceConfig validation + normalisation.
# ---------------------------------------------------------------------------


def test_service_config_validation():
    with pytest.raises(ValueError, match="serve_bytes_per_ms"):
        ServiceConfig(serve_bytes_per_ms=0.0).validate()
    with pytest.raises(ValueError, match="capacity_factor"):
        ServiceConfig(capacity_factor=-1.0).validate()
    with pytest.raises(ValueError, match="stability bound"):
        ServiceConfig(rho_max=1.0).validate()
    with pytest.raises(ValueError, match="stability bound"):
        ServiceConfig(rho_max=0.0).validate()
    assert normalize_service(None) is None
    assert normalize_service(ServiceConfig(enabled=False)) is None
    svc = ServiceConfig()
    assert normalize_service(svc) == svc
    assert svc.capacity_ms(1000, 10.0) == 10_000.0


# ---------------------------------------------------------------------------
# 3. Golden pinning: contention OFF is the exact pre-contention program.
# ---------------------------------------------------------------------------

BASELINES = {
    "local": StaticPolicy(mode="local"),
    "remote": StaticPolicy(mode="remote"),
    "optimized": RedynisPolicy(),
    "replicated": StaticPolicy(mode="replicated"),
}

# The seed Fig 2/3 goldens (see tests/test_simulate_equivalence.py) — the
# queueing model must leave them untouched while it is off.
SEED_GOLDENS = {
    "local": (292.95444558371173, 1.0, 10.0, 0.0),
    "remote": (26.632222325791975, 0.0, 110.0, 0.0),
    "optimized": (164.78536705940513, 0.92115, 17.885, 1000.0),
    "replicated": (292.95444558371173, 1.0, 10.0, 0.0),
}

ENGINES = [
    ("scan-jax", lambda wl, cl, pol: run_scenario(wl, cl, pol, seed=0)),
    ("scan-pallas", lambda wl, cl, pol: run_scenario(
        wl, cl, pol, seed=0, replay_backend="pallas")),
    ("reference", lambda wl, cl, pol: run_scenario_reference(wl, cl, pol, seed=0)),
]


def assert_results_equal(a: SimResult, b: SimResult, ctx: str):
    for field, x, y in zip(SimResult._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{ctx} {field}"
        )


@pytest.mark.parametrize("name", sorted(BASELINES))
@pytest.mark.parametrize("engine", [e[0] for e in ENGINES])
def test_service_off_is_bitexact_and_reproduces_goldens(name, engine):
    """service=None and ServiceConfig(enabled=False) are the SAME static
    (normalize_service collapses both), so the compiled program — and every
    result bit — is identical to the pre-ServiceConfig engine, which the
    seed goldens pin."""
    run = dict((label, fn) for label, fn in ENGINES)[engine]
    wl = WorkloadConfig(num_requests=20_000)
    plain = run(wl, ClusterConfig(), BASELINES[name])
    disabled = run(
        wl, ClusterConfig(service=ServiceConfig(enabled=False)), BASELINES[name]
    )
    assert_results_equal(plain, disabled, f"{engine}/{name}")
    tput, hit, mean_lat, moves = SEED_GOLDENS[name]
    np.testing.assert_allclose(plain.throughput_ops_s, tput, rtol=1e-4)
    np.testing.assert_allclose(plain.hit_rate, hit, rtol=1e-5)
    np.testing.assert_allclose(plain.mean_latency_ms, mean_lat, rtol=1e-4)
    np.testing.assert_allclose(plain.replication_moves, moves, rtol=0)


def test_contention_on_strictly_raises_latency():
    """Sanity direction: switching the queueing model on can only add wait."""
    wl = WorkloadConfig(num_requests=4_000, num_keys=200, skewed=True)
    off = run_scenario(wl, ClusterConfig(), RedynisPolicy(), seed=0)
    on = run_scenario(
        wl,
        ClusterConfig(service=ServiceConfig(
            serve_bytes_per_ms=512.0, capacity_factor=0.5
        )),
        RedynisPolicy(),
        seed=0,
    )
    assert on.mean_latency_ms > off.mean_latency_ms
    assert on.hit_rate == off.hit_rate  # contention delays, never re-routes


# ---------------------------------------------------------------------------
# 4. Engine agreement under contention.
# ---------------------------------------------------------------------------

_SVC = ServiceConfig(serve_bytes_per_ms=512.0, capacity_factor=0.5)


@pytest.mark.parametrize("topo", ["flat", "wan5"])
def test_engines_agree_under_contention(topo):
    """Fused scan == per-chunk reference == Pallas replay with the queueing
    model on (lognormal sizes load the size-aware demand term)."""
    if topo == "flat":
        wl = WorkloadConfig(
            num_requests=4_000, num_keys=200, skewed=True,
            object_bytes_sigma=0.8,
        )
        cl = ClusterConfig(service=_SVC)
    else:
        wl = wan5_workload(
            num_requests=4_000, num_keys=200, object_bytes_sigma=0.8
        )
        cl = wan5_cluster()._replace(service=_SVC)
    a = run_scenario(wl, cl, RedynisPolicy(), seed=2, daemon_interval=500)
    b = run_scenario_reference(
        wl, cl, RedynisPolicy(), seed=2, daemon_interval=500
    )
    c = run_scenario(
        wl, cl, RedynisPolicy(), seed=2, daemon_interval=500,
        replay_backend="pallas",
    )
    for field, x, y, z in zip(SimResult._fields, a, b, c):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=1e-4, err_msg=f"ref {field}"
        )
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(z), rtol=1e-4, err_msg=f"pallas {field}"
        )


@pytest.mark.parametrize("mode", ["local", "remote", "replicated"])
def test_static_fast_path_contention_matches_reference(mode):
    """Frozen maps take the vectorized whole-trace shortcut; its per-chunk
    contention vmap must agree with the reference engine's chunk loop."""
    wl = WorkloadConfig(
        num_requests=4_000, num_keys=200, skewed=True, object_bytes_sigma=0.5
    )
    cl = ClusterConfig(service=_SVC)
    a = run_scenario(
        wl, cl, StaticPolicy(mode=mode), seed=1, daemon_interval=500
    )
    b = run_scenario_reference(
        wl, cl, StaticPolicy(mode=mode), seed=1, daemon_interval=500
    )
    for field, x, y in zip(SimResult._fields, a, b):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=1e-4, err_msg=f"{mode} {field}"
        )


def test_contended_telemetry_histograms_match_across_backends():
    """With contention on, the jax and pallas replay paths see the same f32
    latency bits, so telemetry histograms stay bit-identical."""
    wl = wan5_workload(num_requests=3_000, num_keys=150, object_bytes_sigma=0.5)
    cl = wan5_cluster()._replace(service=_SVC)
    _, ta = run_scenario(
        wl, cl, RedynisPolicy(), seed=0, daemon_interval=500,
        telemetry=TelemetryConfig(),
    )
    _, tb = run_scenario(
        wl, cl, RedynisPolicy(), seed=0, daemon_interval=500,
        telemetry=TelemetryConfig(), replay_backend="pallas",
    )
    np.testing.assert_array_equal(ta.hist_group, tb.hist_group)
    np.testing.assert_allclose(ta.load_factor, tb.load_factor, rtol=1e-6)


# ---------------------------------------------------------------------------
# 5. Monotonicity: concentration ⇒ load factor on the owning node.
# ---------------------------------------------------------------------------


def test_load_factor_monotone_in_concentration_ref():
    """Deterministic sweep: key 0 lives only on node 0; shifting more of the
    chunk's reads onto key 0 monotonically raises node 0's load factor until
    the stability clamp."""
    n, k, b = 4, 8, 400
    rtt = ClusterConfig(num_nodes=n).rtt_matrix()
    hosts = np.zeros((k, n), bool)
    hosts[0, 0] = True
    for key in range(1, k):  # the rest spread over the other nodes
        hosts[key, 1 + (key % (n - 1))] = True
    obj = jnp.full((k,), 1024.0, jnp.float32)
    rhos = []
    for frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        hot = int(frac * b)
        keys = np.r_[np.zeros(hot), 1 + np.arange(b - hot) % (k - 1)]
        _, rho = contention_extra_ms_ref(
            jnp.asarray(hosts),
            jnp.asarray(keys.astype(np.int32)),
            jnp.asarray((np.arange(b) % n).astype(np.int32)),
            jnp.ones((b,), bool),
            jnp.ones((b,), bool),
            rtt, obj,
            read_mode="map", service_ms=SERVICE_MS,
            serve_bytes_per_ms=512.0, capacity_ms=2.0 * b * SERVICE_MS,
            rho_max=0.95,
        )
        rhos.append(float(rho[0]))
    assert rhos == sorted(rhos), rhos
    assert rhos[-1] > rhos[0]


def test_engine_load_factor_telemetry_and_concentration():
    """SimTrace.load_factor: [C, N], bounded by rho_max, all-zero with the
    model off — and a hotter (skewed) workload posts a higher peak load
    factor on the owning node than uniform traffic under the same
    single-replica placement."""
    svc = ServiceConfig(serve_bytes_per_ms=512.0, capacity_factor=3.0)
    peaks = {}
    for skew in (False, True):
        wl = WorkloadConfig(num_requests=4_000, num_keys=200, skewed=skew)
        _, tr = run_scenario(
            wl, ClusterConfig(service=svc), StaticPolicy(mode="remote"),
            seed=0, daemon_interval=500, telemetry=TelemetryConfig(),
        )
        assert tr.load_factor.shape == (8, 3)
        assert (tr.load_factor >= 0.0).all()
        assert (tr.load_factor <= svc.rho_max + 1e-6).all()
        peaks[skew] = float(tr.load_factor.max())
    assert peaks[True] > peaks[False], peaks
    # Model off -> the leaf is present but identically zero.
    wl = WorkloadConfig(num_requests=2_000, num_keys=100, skewed=True)
    _, off = run_scenario(
        wl, ClusterConfig(), StaticPolicy(mode="remote"), seed=0,
        daemon_interval=500, telemetry=TelemetryConfig(),
    )
    assert (off.load_factor == 0.0).all()
