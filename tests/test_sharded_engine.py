"""Key-sharded engine ⇄ single-device equivalence (the PR-7 acceptance
scenario): a 2-virtual-device ``shard_map`` run of the wan5/skewed scenario
must be bit-exact on histogram counts and move counters and allclose on f32
reductions (busy, latency sums, occupancy — they re-associate across
shards), for both replay backends × both trace modes, with the queueing
contention model enabled (its demand fold is psum'd inside
``load_factor_ref``).

Multi-rank runs use the ``run_multi_rank`` conftest fixture (fresh
subprocess with forced virtual devices); the validation surface
(topk/capacity rejection, device count) is tested in-process because it
raises before any mesh is touched. A non-dividing ``K % S != 0`` keyspace
is legal since PR 8: the final shard is padded with dead keys (zero bytes,
masked out of the live map) and must stay equivalent to the unsharded run.
"""

import pytest

from repro.kvsim import (
    RedynisPolicy,
    TopKPolicy,
    run_scenario,
    wan5_cluster,
    wan5_workload,
)

SHARDED_EQUIVALENCE_SCRIPT = r"""
import numpy as np
from repro.kvsim import (run_scenario, wan5_workload, wan5_cluster,
                         RedynisPolicy, StaticPolicy, TelemetryConfig,
                         ServiceConfig)

wl = wan5_workload(num_requests=20000, num_keys=NUM_KEYS)
cl = wan5_cluster()._replace(service=ServiceConfig(enabled=True))
CASES = [
    (StaticPolicy(mode='local'), 'jax', 'materialized'),
    (StaticPolicy(mode='local'), 'pallas', 'streamed'),
    (RedynisPolicy(), 'jax', 'materialized'),
    (RedynisPolicy(), 'jax', 'streamed'),
    (RedynisPolicy(), 'pallas', 'materialized'),
    (RedynisPolicy(), 'pallas', 'streamed'),
]
for pol, backend, trace_mode in CASES:
    kw = dict(seed=3, daemon_interval=1000, telemetry=TelemetryConfig(),
              replay_backend=backend, trace_mode=trace_mode)
    r1, t1 = run_scenario(wl, cl, pol, **kw)
    r2, t2 = run_scenario(wl, cl, pol, num_shards=NUM_SHARDS, **kw)
    # Integer-count surfaces: bit-exact under psum.
    np.testing.assert_array_equal(t1.hist_group, t2.hist_group)
    assert r1.hit_rate == r2.hit_rate
    assert r1.replication_moves == r2.replication_moves
    assert r1.deletion_moves == r2.deletion_moves
    assert r1.evictions == r2.evictions
    # f32 reductions: re-associated across shards, allclose.
    np.testing.assert_allclose(r1.node_busy_ms, r2.node_busy_ms, rtol=1e-4)
    np.testing.assert_allclose(
        r1.mean_latency_ms, r2.mean_latency_ms, rtol=1e-4
    )
    np.testing.assert_allclose(
        r1.throughput_ops_s, r2.throughput_ops_s, rtol=1e-4
    )
    np.testing.assert_allclose(
        r1.peak_occupancy_bytes, r2.peak_occupancy_bytes, rtol=1e-4
    )
    np.testing.assert_allclose(
        t1.occupancy_bytes, t2.occupancy_bytes, rtol=1e-4
    )
    np.testing.assert_allclose(t1.load_factor, t2.load_factor, rtol=1e-4)
    print('OK', type(pol).name, backend, trace_mode)
print('SHARDED_ENGINE_EQUIVALENCE_OK')
"""


def _script(num_shards: int, num_keys: int, cases: str | None = None) -> str:
    script = (
        SHARDED_EQUIVALENCE_SCRIPT
        .replace("NUM_SHARDS", str(num_shards))
        .replace("NUM_KEYS", str(num_keys))
    )
    if cases is not None:
        script = script.replace("CASES = [", f"CASES = {cases} or [")
    return script


def test_sharded_matches_single_device_two_ranks(run_multi_rank):
    out = run_multi_rank(_script(2, 500), num_devices=2, timeout=600)
    assert "SHARDED_ENGINE_EQUIVALENCE_OK" in out


@pytest.mark.slow
def test_sharded_matches_single_device_four_ranks(run_multi_rank):
    out = run_multi_rank(_script(4, 500), num_devices=4, timeout=600)
    assert "SHARDED_ENGINE_EQUIVALENCE_OK" in out


def test_sharded_non_dividing_keyspace_two_ranks(run_multi_rank):
    """PR-8 satellite: K=501 over 2 shards (ceil-division padding) must be
    bit-exact on counts and allclose on f32 reductions vs the unsharded
    run — active policy + static baseline, both trace modes."""
    cases = (
        "[(StaticPolicy(mode='local'), 'jax', 'materialized'),"
        " (RedynisPolicy(), 'jax', 'materialized'),"
        " (RedynisPolicy(), 'jax', 'streamed')]"
    )
    out = run_multi_rank(
        _script(2, 501, cases), num_devices=2, timeout=600
    )
    assert "SHARDED_ENGINE_EQUIVALENCE_OK" in out


def test_topk_rejected_sharded():
    wl = wan5_workload(num_requests=100, num_keys=500)
    with pytest.raises(ValueError, match="topk"):
        run_scenario(wl, wan5_cluster(), TopKPolicy(), seed=0, num_shards=2)


def test_finite_capacity_rejected_sharded():
    wl = wan5_workload(num_requests=100, num_keys=500)
    cl = wan5_cluster()._replace(capacity_bytes=10_000.0)
    with pytest.raises(ValueError, match="capacity"):
        run_scenario(wl, cl, RedynisPolicy(), seed=0, num_shards=2)


def test_unknown_trace_mode_rejected():
    wl = wan5_workload(num_requests=100, num_keys=500)
    with pytest.raises(ValueError, match="trace_mode"):
        run_scenario(wl, wan5_cluster(), RedynisPolicy(), seed=0, trace_mode="lazy")
