"""Faithful-reproduction validation: the kvsim must reproduce the paper's
§9/§10 claims (Optimized ≈ 10× Remote, near Local) on scaled-down traces."""

import numpy as np
import pytest

from repro.kvsim import (
    ClusterConfig,
    RedynisPolicy,
    StaticPolicy,
    WorkloadConfig,
    generate_trace,
    run_scenario,
)

LOCAL = StaticPolicy(mode="local")
REMOTE = StaticPolicy(mode="remote")


@pytest.mark.parametrize("skewed", [False, True])
def test_optimized_beats_remote(skewed):
    wl = WorkloadConfig(num_requests=20_000, skewed=skewed)
    cl = ClusterConfig()
    rem = run_scenario(wl, cl, REMOTE, seed=0)
    opt = run_scenario(wl, cl, RedynisPolicy(), seed=0)
    loc = run_scenario(wl, cl, LOCAL, seed=0)
    assert opt.throughput_ops_s > 4 * rem.throughput_ops_s
    assert opt.throughput_ops_s > 0.4 * loc.throughput_ops_s
    assert opt.hit_rate > 0.8  # daemon converges to local placement


def test_local_is_upper_bound():
    wl = WorkloadConfig(num_requests=10_000)
    cl = ClusterConfig()
    loc = run_scenario(wl, cl, LOCAL, seed=1)
    for pol in (REMOTE, RedynisPolicy()):
        r = run_scenario(wl, cl, pol, seed=1)
        assert r.throughput_ops_s <= loc.throughput_ops_s * 1.01


def test_write_heavy_keeps_advantage():
    """The optimized advantage over remote holds across the paper's full
    read-ratio grid (100% -> 50%): writes pay master-relay costs in both
    scenarios, so the ratio stays well above 1 (paper fig 2/3 shape)."""
    cl = ClusterConfig()
    for rf in (1.0, 0.75, 0.5):
        wl = WorkloadConfig(num_requests=15_000, read_fraction=rf, skewed=True)
        rem = run_scenario(wl, cl, REMOTE, seed=0)
        opt = run_scenario(wl, cl, RedynisPolicy(), seed=0)
        assert opt.throughput_ops_s > 3 * rem.throughput_ops_s, rf


def test_daemon_replicates_then_stabilises():
    wl = WorkloadConfig(num_requests=30_000, skewed=True)
    cl = ClusterConfig()
    r = run_scenario(wl, cl, RedynisPolicy(), seed=0)
    assert r.replication_moves > 0
    # moves are bounded: no thrashing (less than one move per key per sweep)
    assert r.replication_moves < wl.num_keys * 5


def test_golden_scenario_ordering():
    """Fig 2/3 golden ordering on a small seeded trace: the idealised LOCAL
    bound dominates OPTIMIZED, which dominates REMOTE, at every read ratio."""
    cl = ClusterConfig()
    for rf in (1.0, 0.75, 0.5):
        wl = WorkloadConfig(num_requests=10_000, read_fraction=rf, skewed=True)
        loc = run_scenario(wl, cl, LOCAL, seed=0)
        opt = run_scenario(wl, cl, RedynisPolicy(), seed=0)
        rem = run_scenario(wl, cl, REMOTE, seed=0)
        assert (
            loc.throughput_ops_s >= opt.throughput_ops_s >= rem.throughput_ops_s
        ), rf
        assert loc.hit_rate >= opt.hit_rate >= rem.hit_rate, rf


def test_hit_rate_monotone_in_ownership_coefficient():
    """Lowering H admits more hosts per key (paper eq. 2), so the OPTIMIZED
    hit rate must not decrease as the ownership coefficient decreases."""
    cl = ClusterConfig()
    wl = WorkloadConfig(num_requests=10_000, skewed=True, affinity=0.7)
    hit_rates = [
        run_scenario(wl, cl, RedynisPolicy(h=h), seed=0).hit_rate
        for h in (1.0 / 3.0, 0.25, 0.15, 0.05)
    ]
    for lo_h_hit, hi_h_hit in zip(hit_rates[1:], hit_rates[:-1]):
        assert lo_h_hit >= hi_h_hit - 1e-6, hit_rates


def test_trace_determinism_and_shape():
    wl = WorkloadConfig(num_requests=5_000, skewed=True)
    t1, t2 = generate_trace(wl, seed=3), generate_trace(wl, seed=3)
    np.testing.assert_array_equal(np.asarray(t1.keys), np.asarray(t2.keys))
    hot = np.asarray(t1.keys) < int(wl.num_keys * wl.hot_fraction)
    assert 0.85 < hot.mean() < 0.95  # zipfian 90/10 as described in §8.2
