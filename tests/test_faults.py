"""Failure-injection test tier (ISSUE-10 acceptance).

Pins the fault subsystem (``FaultConfig`` — declarative membership
timelines, degraded-mode serving, write failover, daemon re-replication,
availability/blast-radius telemetry):

1. Faults OFF (``faults=None``, ``FaultConfig(enabled=False)``, and an
   empty event list) compiles the exact pre-fault program — bit-identical
   results across both engines × both replay backends, still reproducing
   the seed Fig 2/3 goldens — and an all-up schedule (every event past the
   trace end) runs the fault machinery yet stays bit-exact with OFF (the
   ``x - x ≡ +0.0`` write-delta identity).
2. Schedule compiler: event/config validation, ``normalize_faults``
   off-collapse, window clipping, domain lowering (node/zone/region, flat
   fallback, labelling mismatches), the full-blackout rejection, and
   ``blast_radius_rows`` windows.
3. The canonical oracle ``fault_extra_ms_ref``: verdict invariants
   (unavailable/failover ⊆ valid, failovers are served writes under a dead
   master, reads never price a fault delta, all-up is bitwise zero) and
   availability-monotonicity (reviving nodes never creates new
   unavailability) — Hypothesis-fuzzed over random chunks when installed.
4. Engine agreement with faults ON: fused scan == per-chunk reference
   (fault counters bit-exact, latency allclose) == Pallas replay ==
   streamed traces, runs are deterministic, and the per-chunk telemetry
   series sum to the aggregate counters.
5. Degraded-mode behaviour: availability dips exactly inside the outage
   window and returns to 1.0 after it; blast-radius fractions live in
   [0, 1] and peak inside the window; redynis re-replicates crash-wiped
   keys (``repair_moves > 0``, finite ``recovery_chunks``) while a static
   policy never repairs.
6. 2-rank ``shard_map`` equivalence with faults on (``run_multi_rank``).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_replay.ref import fault_extra_ms_ref
from repro.kvsim import (
    ClusterConfig,
    FaultConfig,
    FaultEvent,
    RedynisPolicy,
    SimResult,
    StaticPolicy,
    TelemetryConfig,
    WorkloadConfig,
    blast_radius_rows,
    compile_schedule,
    normalize_faults,
    region_outage,
    run_scenario,
    run_scenario_reference,
    wan5_cluster,
    wan5_workload,
)
from repro.kvsim.faults import domain_nodes, event_windows

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


BASELINES = {
    "local": StaticPolicy(mode="local"),
    "remote": StaticPolicy(mode="remote"),
    "optimized": RedynisPolicy(),
    "replicated": StaticPolicy(mode="replicated"),
}

# The seed Fig 2/3 goldens (see tests/test_simulate_equivalence.py) — the
# fault tier must leave them untouched while it is off.
SEED_GOLDENS = {
    "local": (292.95444558371173, 1.0, 10.0, 0.0),
    "remote": (26.632222325791975, 0.0, 110.0, 0.0),
    "optimized": (164.78536705940513, 0.92115, 17.885, 1000.0),
    "replicated": (292.95444558371173, 1.0, 10.0, 0.0),
}

ENGINES = [
    ("scan-jax", lambda wl, cl, pol: run_scenario(wl, cl, pol, seed=0)),
    ("scan-pallas", lambda wl, cl, pol: run_scenario(
        wl, cl, pol, seed=0, replay_backend="pallas")),
    ("reference", lambda wl, cl, pol: run_scenario_reference(
        wl, cl, pol, seed=0)),
]

FAULT_COUNTERS = (
    "unavailable_reads", "unavailable_writes", "failovers", "repair_moves",
)


def assert_results_equal(a: SimResult, b: SimResult, ctx: str):
    for field, x, y in zip(SimResult._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{ctx} {field}"
        )


# A fault-rich scenario: region-skewed wan5 traffic, the hottest region
# (region 0, weight 0.35; each wan5 node is its own region) crashed for a
# mid-trace window, recovered before the end.
FAULT_INTERVAL = 100
NUM_CHUNKS = 200  # 20_000 requests / interval
OUTAGE_START, OUTAGE_LEN = 60, 40
OUTAGE_END = OUTAGE_START + OUTAGE_LEN


def _fault_scenario():
    return (
        wan5_workload(
            num_requests=20_000, num_keys=400, affinity=0.8,
            read_fraction=0.7,
        ),
        wan5_cluster(),
    )


def _outage():
    return region_outage(0, OUTAGE_START, OUTAGE_LEN, mode="crash")


# ---------------------------------------------------------------------------
# 1. Faults off is a structural no-op: seed goldens stay bit-exact.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(BASELINES))
@pytest.mark.parametrize("engine", [e[0] for e in ENGINES])
def test_fault_off_is_bitexact_and_reproduces_goldens(name, engine):
    """faults=None, FaultConfig(enabled=False), and an empty event list are
    the SAME static (normalize_faults collapses all three), so the compiled
    program — and every result bit — is identical to the pre-fault engine,
    which the seed goldens pin."""
    run = dict(ENGINES)[engine]
    wl = WorkloadConfig(num_requests=20_000)
    plain = run(wl, ClusterConfig(), BASELINES[name])
    for off in (FaultConfig(enabled=False), FaultConfig(events=())):
        disabled = run(wl, ClusterConfig(faults=off), BASELINES[name])
        assert_results_equal(plain, disabled, f"{engine}/{name}")
    for counter in FAULT_COUNTERS:
        assert getattr(plain, counter) == 0.0
    tput, hit, mean_lat, moves = SEED_GOLDENS[name]
    np.testing.assert_allclose(plain.throughput_ops_s, tput, rtol=1e-4)
    np.testing.assert_allclose(plain.hit_rate, hit, rtol=1e-5)
    np.testing.assert_allclose(plain.mean_latency_ms, mean_lat, rtol=1e-4)
    np.testing.assert_allclose(plain.replication_moves, moves, rtol=0)


@pytest.mark.parametrize("engine", ["scan-jax", "reference"])
def test_allup_schedule_is_bitexact_with_off(engine):
    """A schedule whose every event lies past the trace end keeps the fault
    machinery ON (avail ≡ True, crash ≡ False) yet must reproduce the OFF
    program bit-for-bit: the write-failover delta is ``x - x`` on identical
    f32 operands (+0.0), unavailability is identically False, and the zero
    extra folds through ``lat + 0.0`` unchanged."""
    run = dict(ENGINES)[engine]
    wl, cl = _fault_scenario()
    allup = FaultConfig(
        events=(FaultEvent(kind="node", target=1, start_chunk=10**6),)
    )
    plain = run(wl, cl, RedynisPolicy())
    noop = run(wl, cl._replace(faults=allup), RedynisPolicy())
    assert_results_equal(plain, noop, f"{engine}/all-up")


# ---------------------------------------------------------------------------
# 2. Schedule compiler: validation, windows, domains, blackout rejection.
# ---------------------------------------------------------------------------


def test_event_and_config_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultEvent(kind="rack").validate()
    with pytest.raises(ValueError, match="mode"):
        FaultEvent(mode="flaky").validate()
    with pytest.raises(ValueError, match="target"):
        FaultEvent(target=-1).validate()
    with pytest.raises(ValueError, match="start_chunk"):
        FaultEvent(start_chunk=-3).validate()
    with pytest.raises(TypeError, match="FaultEvent"):
        FaultConfig(events=("node-0-down",)).validate()


def test_normalize_faults_collapses_every_off_state():
    assert normalize_faults(None) is None
    assert normalize_faults(FaultConfig(enabled=False)) is None
    assert normalize_faults(FaultConfig(events=())) is None
    on = FaultConfig(events=(FaultEvent(target=1),))
    assert normalize_faults(on) is on


def test_compile_schedule_windows_and_crash_oneshot():
    cfg = FaultConfig(events=(
        FaultEvent(kind="node", target=1, start_chunk=3, duration_chunks=4,
                   mode="crash"),
        FaultEvent(kind="node", target=2, start_chunk=8, duration_chunks=0,
                   mode="partition"),
    ))
    avail, crash = compile_schedule(cfg, num_nodes=4, num_chunks=12)
    assert avail.shape == crash.shape == (12, 4)
    # Node 1 down exactly [3, 7); crash wipe only at the start chunk.
    assert not avail[3:7, 1].any() and avail[:3, 1].all() and avail[7:, 1].all()
    assert crash[3, 1] and not crash[4:, 1].any() and not crash[:3, 1].any()
    # Node 2 partitioned until the end (duration <= 0), never wiped.
    assert not avail[8:, 2].any() and avail[:8, 2].all()
    assert not crash[:, 2].any()
    # Untargeted nodes untouched.
    assert avail[:, 0].all() and avail[:, 3].all()


def test_compile_schedule_drops_events_past_trace_end():
    cfg = FaultConfig(events=(FaultEvent(target=0, start_chunk=50),))
    avail, crash = compile_schedule(cfg, num_nodes=3, num_chunks=10)
    assert avail.all() and not crash.any()
    assert event_windows(cfg, 10) == []


def test_domain_lowering_zone_region_and_flat_fallback():
    region_of = (0, 0, 1, 1, 2)
    ev = FaultEvent(kind="region", target=1, start_chunk=0,
                    duration_chunks=2)
    mask = domain_nodes(ev, num_nodes=5, region_of=region_of)
    np.testing.assert_array_equal(
        mask, [False, False, True, True, False]
    )
    # Absent labelling degrades to the flat hierarchy (node == region).
    np.testing.assert_array_equal(
        domain_nodes(ev, num_nodes=5), [False, True, False, False, False]
    )
    avail, _ = compile_schedule(
        FaultConfig(events=(ev,)), num_nodes=5, num_chunks=4,
        region_of=region_of,
    )
    assert not avail[0:2, 2:4].any() and avail[2:].all()
    with pytest.raises(ValueError, match="labels no node"):
        domain_nodes(FaultEvent(kind="zone", target=9), num_nodes=3,
                     zone_of=(0, 0, 1))
    with pytest.raises(ValueError, match="entries"):
        domain_nodes(FaultEvent(kind="zone", target=0), num_nodes=3,
                     zone_of=(0, 0))


def test_full_blackout_rejected():
    cfg = FaultConfig(events=(
        FaultEvent(kind="node", target=0, start_chunk=2, duration_chunks=3),
        FaultEvent(kind="node", target=1, start_chunk=4, duration_chunks=3),
    ))
    with pytest.raises(ValueError, match="chunk 4"):
        compile_schedule(cfg, num_nodes=2, num_chunks=10)


def test_blast_radius_rows_windows_and_peaks():
    cfg = FaultConfig(events=(
        FaultEvent(target=0, start_chunk=2, duration_chunks=3),
        FaultEvent(target=1, start_chunk=8, duration_chunks=0,
                   mode="partition"),
    ))
    unreach = np.zeros(10)
    unreach[3], unreach[9] = 0.25, 0.5
    wiped = np.zeros(10)
    wiped[4] = 0.125
    rows = blast_radius_rows(
        cfg, num_chunks=10, unreachable_frac=unreach, wiped_frac=wiped
    )
    assert [r["start_chunk"] for r in rows] == [2, 8]
    assert [r["end_chunk"] for r in rows] == [5, 10]
    assert rows[0]["blast_radius_unreachable"] == 0.25
    assert rows[0]["blast_radius_wiped"] == 0.125
    assert rows[1]["blast_radius_unreachable"] == 0.5
    assert rows[1]["blast_radius_wiped"] == 0.0
    assert rows[1]["mode"] == "partition"


# ---------------------------------------------------------------------------
# 3. The canonical fault oracle: verdict invariants.
# ---------------------------------------------------------------------------


def _random_fault_chunk(seed, b, k, n):
    rng = np.random.default_rng(seed)
    return (
        jnp.asarray(rng.random((k, n)) < 0.4),  # hosts
        jnp.asarray(rng.integers(0, k, b).astype(np.int32)),  # keys
        jnp.asarray(rng.integers(0, n, b).astype(np.int32)),  # nodes
        jnp.asarray(rng.random(b) < 0.7),  # is_read
        jnp.asarray(rng.random(b) < 0.9),  # valid
        jnp.asarray(rng.random(k) < 0.05),  # wiped
        rng,
    )


def check_fault_prepass_invariants(seed, b=256, k=64, n=5, read_mode="map"):
    hosts, keys, nodes, is_read, valid, wiped, rng = _random_fault_chunk(
        seed, b, k, n
    )
    avail_n = rng.random(n) < 0.6
    if not avail_n.any():
        avail_n[rng.integers(n)] = True  # engine guarantees >= 1 live node
    avail = jnp.asarray(avail_n)
    rtt = jnp.asarray(
        np.where(np.eye(n), 0.0, 40.0 + rng.random((n, n)) * 60.0)
    ).astype(jnp.float32)
    kw = dict(read_mode=read_mode, master=0, xfer_write_ms=10.0)
    extra, unav, fo = fault_extra_ms_ref(
        hosts, keys, nodes, is_read, valid, avail, rtt, wiped=wiped, **kw
    )
    extra_n, unav_n, fo_n = map(np.asarray, (extra, unav, fo))
    valid_n, read_n = np.asarray(valid), np.asarray(is_read)
    # Verdicts never escape the valid mask; refused requests price nothing.
    assert not np.any(unav_n & ~valid_n)
    assert not np.any(fo_n & ~valid_n)
    assert not np.any(fo_n & unav_n)
    # Failover is a served-write event, and only under a dead master.
    assert not np.any(fo_n & read_n)
    if avail_n[0]:
        assert not fo_n.any()
    # Reads never carry a fault delta (theirs is priced via hosts_eff).
    np.testing.assert_array_equal(extra_n[read_n], 0.0)
    assert np.all(np.isfinite(extra_n))
    # A down origin refuses everything it issues.
    origin_down = ~avail_n[np.asarray(nodes)]
    np.testing.assert_array_equal(
        unav_n[origin_down & valid_n], True
    )
    # Determinism: the oracle is a pure function (failover re-election
    # included).
    extra2, unav2, fo2 = fault_extra_ms_ref(
        hosts, keys, nodes, is_read, valid, avail, rtt, wiped=wiped, **kw
    )
    np.testing.assert_array_equal(extra_n, np.asarray(extra2))
    np.testing.assert_array_equal(unav_n, np.asarray(unav2))
    np.testing.assert_array_equal(fo_n, np.asarray(fo2))
    # Monotone in availability: reviving nodes never creates new
    # unavailability or new failovers.
    _, unav_up, fo_up = fault_extra_ms_ref(
        hosts, keys, nodes, is_read, valid, jnp.ones_like(avail), rtt,
        wiped=jnp.zeros_like(wiped), **kw
    )
    assert not np.asarray(unav_up).any()
    assert not np.asarray(fo_up).any()


@pytest.mark.parametrize("read_mode", ["map", "no_local", "ideal"])
def test_fault_prepass_invariants(read_mode):
    for seed in range(4):
        check_fault_prepass_invariants(seed, read_mode=read_mode)


def test_allup_prepass_is_bitwise_zero():
    """All nodes live + nothing wiped ⇒ the delta is x - x on identical f32
    operands: bitwise +0.0, no verdicts — the identity the engines' fault-on
    ≡ fault-off bit-exactness rests on."""
    hosts, keys, nodes, is_read, valid, _, rng = _random_fault_chunk(
        7, 512, 64, 5
    )
    rtt = jnp.asarray(
        np.where(np.eye(5), 0.0, 40.0 + rng.random((5, 5)) * 60.0)
    ).astype(jnp.float32)
    extra, unav, fo = fault_extra_ms_ref(
        hosts, keys, nodes, is_read, valid, jnp.ones((5,), bool), rtt,
        read_mode="map", master=0, xfer_write_ms=10.0,
    )
    assert not np.asarray(unav).any() and not np.asarray(fo).any()
    # Bitwise zero, positive sign — not merely allclose.
    assert np.array_equal(
        np.asarray(extra).view(np.uint32), np.zeros(512, np.uint32)
    )


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        b=st.integers(1, 128),
        k=st.integers(1, 64),
        n=st.integers(2, 6),
        read_mode=st.sampled_from(["map", "no_local", "ideal"]),
    )
    def test_fault_prepass_invariants_fuzzed(seed, b, k, n, read_mode):
        check_fault_prepass_invariants(seed, b=b, k=k, n=n,
                                       read_mode=read_mode)

    @settings(max_examples=50, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        num_nodes=st.integers(1, 6),
        num_chunks=st.integers(1, 24),
        num_events=st.integers(1, 5),
    )
    def test_compile_schedule_fuzzed(seed, num_nodes, num_chunks, num_events):
        """Random schedules either compile to consistent timelines or are
        rejected as full blackouts — never anything else."""
        rng = np.random.default_rng(seed)
        events = tuple(
            FaultEvent(
                kind="node",
                target=int(rng.integers(num_nodes)),
                start_chunk=int(rng.integers(num_chunks + 2)),
                duration_chunks=int(rng.integers(-1, num_chunks + 2)),
                mode=("crash", "partition")[int(rng.integers(2))],
            )
            for _ in range(num_events)
        )
        cfg = FaultConfig(events=events)
        try:
            avail, crash = compile_schedule(
                cfg, num_nodes=num_nodes, num_chunks=num_chunks
            )
        except ValueError as e:
            assert "no node available" in str(e)
            return
        assert avail.any(axis=1).all()  # never a fully-dark chunk
        assert not np.any(crash & avail)  # a wiping node is never serving
        # avail is exactly the complement of the event-window union.
        expect = np.ones((num_chunks, num_nodes), bool)
        starts = np.zeros((num_chunks, num_nodes), bool)
        for ev, start, end in event_windows(cfg, num_chunks):
            expect[start:end, ev.target] = False
            if ev.mode == "crash":
                starts[start, ev.target] = True
        np.testing.assert_array_equal(avail, expect)
        np.testing.assert_array_equal(crash, starts)


# ---------------------------------------------------------------------------
# 4. Engine agreement with faults on.
# ---------------------------------------------------------------------------


def _run_fault(engine_kwargs, policy=None, telemetry=None):
    wl, cl = _fault_scenario()
    return engine_kwargs["run"](
        wl, cl._replace(faults=_outage()), policy or RedynisPolicy(),
        daemon_interval=FAULT_INTERVAL, seed=0, telemetry=telemetry,
    )


def test_engines_agree_under_region_crash():
    wl, cl = _fault_scenario()
    cl = cl._replace(faults=_outage())
    kw = dict(daemon_interval=FAULT_INTERVAL, seed=0)
    scan = run_scenario(wl, cl, RedynisPolicy(), **kw)
    ref = run_scenario_reference(wl, cl, RedynisPolicy(), **kw)
    pallas = run_scenario(wl, cl, RedynisPolicy(),
                          replay_backend="pallas", **kw)
    streamed = run_scenario(wl, cl, RedynisPolicy(),
                            trace_mode="streamed", **kw)
    assert scan.unavailable_reads > 0.0  # the drill genuinely degrades
    assert scan.failovers > 0.0
    assert scan.repair_moves > 0.0
    for counter in FAULT_COUNTERS + ("hits", "replication_moves"):
        if not hasattr(scan, counter):
            continue
        assert getattr(scan, counter) == getattr(ref, counter), counter
        assert getattr(scan, counter) == getattr(pallas, counter), counter
    np.testing.assert_allclose(scan.hit_rate, ref.hit_rate, rtol=1e-6)
    np.testing.assert_allclose(
        scan.mean_latency_ms, ref.mean_latency_ms, rtol=1e-5
    )
    np.testing.assert_allclose(
        scan.mean_latency_ms, pallas.mean_latency_ms, rtol=1e-5
    )
    # Streamed trace generation is the same program: bit-exact.
    assert_results_equal(scan, streamed, "streamed")
    # And the whole thing is deterministic run-to-run.
    again = run_scenario(wl, cl, RedynisPolicy(), **kw)
    assert_results_equal(scan, again, "determinism")


def test_fault_telemetry_series_sum_to_counters():
    wl, cl = _fault_scenario()
    cl = cl._replace(faults=_outage())
    kw = dict(daemon_interval=FAULT_INTERVAL, seed=0,
              telemetry=TelemetryConfig())
    res, trace = run_scenario(wl, cl, RedynisPolicy(), **kw)
    np.testing.assert_allclose(
        trace.unavailable_reads.sum(), res.unavailable_reads
    )
    np.testing.assert_allclose(
        trace.unavailable_writes.sum(), res.unavailable_writes
    )
    np.testing.assert_allclose(trace.failovers.sum(), res.failovers)
    np.testing.assert_allclose(trace.repair_moves.sum(), res.repair_moves)
    # The reference engine's trace agrees chunk-for-chunk.
    _, ref_trace = run_scenario_reference(wl, cl, RedynisPolicy(), **kw)
    for leaf in ("unavailable_reads", "unavailable_writes", "failovers",
                 "repair_moves"):
        np.testing.assert_array_equal(
            getattr(trace, leaf), getattr(ref_trace, leaf), err_msg=leaf
        )
    np.testing.assert_allclose(
        trace.unreachable_frac, ref_trace.unreachable_frac, atol=1e-7
    )
    np.testing.assert_allclose(
        trace.wiped_frac, ref_trace.wiped_frac, atol=1e-7
    )


# ---------------------------------------------------------------------------
# 5. Degraded-mode behaviour: availability, blast radius, re-convergence.
# ---------------------------------------------------------------------------


def test_availability_dips_inside_outage_and_recovers():
    wl, cl = _fault_scenario()
    res, trace = run_scenario(
        wl, cl._replace(faults=_outage()), RedynisPolicy(),
        daemon_interval=FAULT_INTERVAL, seed=0,
        telemetry=TelemetryConfig(),
    )
    avail = trace.availability
    assert avail.shape == (NUM_CHUNKS,)
    np.testing.assert_array_equal(avail[:OUTAGE_START], 1.0)
    assert avail[OUTAGE_START:OUTAGE_END].min() < 1.0
    # After the region rejoins, one chunk of dark reads on still-wiped keys
    # remains (the daemon re-seeds at that chunk's END); from the next
    # chunk on nothing is refused.
    assert avail[OUTAGE_END] > avail[OUTAGE_START:OUTAGE_END].min()
    np.testing.assert_array_equal(avail[OUTAGE_END + 1:], 1.0)
    # Blast radius: fractions are sane, peak inside the window, and the
    # crash wiped a strictly positive slice of the keyspace.
    assert np.all((trace.unreachable_frac >= 0.0)
                  & (trace.unreachable_frac <= 1.0))
    assert np.all((trace.wiped_frac >= 0.0) & (trace.wiped_frac <= 1.0))
    np.testing.assert_array_equal(trace.unreachable_frac[:OUTAGE_START], 0.0)
    rows = blast_radius_rows(
        _outage(), num_chunks=NUM_CHUNKS,
        unreachable_frac=trace.unreachable_frac,
        wiped_frac=trace.wiped_frac,
    )
    assert len(rows) == 1
    assert rows[0]["blast_radius_unreachable"] > 0.0
    assert rows[0]["blast_radius_wiped"] > 0.0
    assert (rows[0]["blast_radius_wiped"]
            <= rows[0]["blast_radius_unreachable"])
    # Effective hit rate (unavailable reads count as misses) recovers to
    # 95% of its pre-outage steady state at a finite chunk.
    rec = trace.recovery_chunks(OUTAGE_START)
    assert rec >= 0
    assert OUTAGE_START + rec < NUM_CHUNKS


def test_redynis_repairs_static_cannot():
    wl, cl = _fault_scenario()
    cl = cl._replace(faults=_outage())
    kw = dict(daemon_interval=FAULT_INTERVAL, seed=0)
    dyn = run_scenario(wl, cl, RedynisPolicy(), **kw)
    static = run_scenario(wl, cl, StaticPolicy(mode="replicated"), **kw)
    # The daemon re-seeds crash-wiped keys; a static map never sweeps, so
    # its crashed copies stay lost for the rest of the trace.
    assert dyn.repair_moves > 0.0
    assert static.repair_moves == 0.0
    assert static.unavailable_reads > 0.0


def test_partition_is_loss_free():
    """The same outage as a partition refuses requests while it lasts but
    wipes nothing: no repair work exists even for redynis, and the map
    serves again the chunk the partition heals."""
    wl, cl = _fault_scenario()
    part = region_outage(0, OUTAGE_START, OUTAGE_LEN, mode="partition")
    res, trace = run_scenario(
        wl, cl._replace(faults=part), RedynisPolicy(),
        daemon_interval=FAULT_INTERVAL, seed=0,
        telemetry=TelemetryConfig(),
    )
    assert res.unavailable_reads > 0.0
    np.testing.assert_array_equal(trace.wiped_frac, 0.0)
    np.testing.assert_array_equal(trace.availability[OUTAGE_END:], 1.0)


# ---------------------------------------------------------------------------
# 6. Sharded equivalence with faults on (2 virtual ranks).
# ---------------------------------------------------------------------------


SHARDED_FAULT_SCRIPT = r"""
import numpy as np
from repro.kvsim import (run_scenario, wan5_workload, wan5_cluster,
                         RedynisPolicy, TelemetryConfig, region_outage)

wl = wan5_workload(num_requests=20000, num_keys=401, affinity=0.8,
                   read_fraction=0.7)
cl = wan5_cluster()._replace(faults=region_outage(0, 60, 40))
kw = dict(seed=3, daemon_interval=100, telemetry=TelemetryConfig())
r1, t1 = run_scenario(wl, cl, RedynisPolicy(), **kw)
r2, t2 = run_scenario(wl, cl, RedynisPolicy(), num_shards=2, **kw)
assert r1.unavailable_reads > 0.0 and r1.repair_moves > 0.0
# Counter surfaces: bit-exact under psum (K=401 exercises the
# ceil-division padding alongside the sharded wiped-key carry).
for f in ('unavailable_reads', 'unavailable_writes', 'failovers',
          'repair_moves', 'hit_rate', 'replication_moves'):
    assert getattr(r1, f) == getattr(r2, f), f
np.testing.assert_array_equal(t1.unavailable_reads, t2.unavailable_reads)
np.testing.assert_array_equal(t1.repair_moves, t2.repair_moves)
# The blast-radius fractions are emitted globally at the sample point, so
# shard counts must agree exactly too.
np.testing.assert_allclose(t1.unreachable_frac, t2.unreachable_frac,
                           atol=1e-7)
np.testing.assert_allclose(t1.wiped_frac, t2.wiped_frac, atol=1e-7)
np.testing.assert_allclose(r1.mean_latency_ms, r2.mean_latency_ms,
                           rtol=1e-4)
print('SHARDED_FAULT_EQUIVALENCE_OK')
"""


def test_sharded_faults_match_single_device(run_multi_rank):
    out = run_multi_rank(SHARDED_FAULT_SCRIPT, num_devices=2, timeout=600)
    assert "SHARDED_FAULT_EQUIVALENCE_OK" in out
