"""Trainer / data / checkpoint / fault / compression integration tests."""

import dataclasses
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline, write_token_file
from repro.models import build
from repro.train import checkpoint as ck
from repro.train.compress import (
    ErrorFeedback,
    dequantize_int8,
    quantize_int8,
    topk_decode,
)
from repro.train.fault import (
    HeartbeatMonitor,
    StragglerMonitor,
    StragglerPolicy,
    elastic_data_width,
)
from repro.train.optim import OptConfig, apply_updates, init_opt, lr_at
from repro.train.trainer import TrainConfig, Trainer


# --------------------------------------------------------------------- data
def test_pipeline_determinism_and_replay():
    cfg = DataConfig(vocab_size=97, seq_len=16, global_batch=4, seed=7)
    p = Pipeline(cfg)
    s = p.init_state()
    batches = []
    for _ in range(5):
        b, s = p.next(s)
        batches.append(b)
    s2 = p.seek(3)
    b3, _ = p.next(s2)
    np.testing.assert_array_equal(np.asarray(b3["tokens"]), np.asarray(batches[3]["tokens"]))
    # targets are next-token shifted
    np.testing.assert_array_equal(
        np.asarray(batches[0]["targets"][:, :-1]), np.asarray(batches[0]["tokens"][:, 1:])
    )


def test_pipeline_zipf_skew():
    cfg = DataConfig(vocab_size=1000, seq_len=256, global_batch=8, zipf_a=1.4)
    p = Pipeline(cfg)
    b, _ = p.next(p.init_state())
    toks = np.asarray(b["tokens"]).ravel()
    head = (toks < 100).mean()
    assert head > 0.5  # hot head catches most traffic


def test_memmap_source(tmp_path):
    path = str(tmp_path / "tokens.bin")
    write_token_file(path, np.arange(10_000) % 31)
    cfg = DataConfig(vocab_size=31, seq_len=8, global_batch=2, source="memmap", path=path)
    p = Pipeline(cfg)
    b, s = p.next(p.init_state())
    assert b["tokens"].shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(b["tokens"])[0], np.arange(8) % 31)


# ---------------------------------------------------------------- optimizer
def test_lr_schedule():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    assert float(lr_at(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(lr_at(cfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(lr_at(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=1e-3)


def test_adamw_reduces_quadratic():
    w = {"x": jnp.asarray([3.0, -2.0])}
    opt = init_opt(w)
    cfg = OptConfig(lr=0.1, warmup_steps=0, total_steps=200, weight_decay=0.0, clip_norm=100.0)
    for _ in range(100):
        g = {"x": 2 * w["x"]}
        w, opt, _ = apply_updates(cfg, w, g, opt)
    assert float(jnp.abs(w["x"]).max()) < 0.5


# ------------------------------------------------------------------ trainer
def test_train_loss_decreases_and_checkpoint_resume():
    with tempfile.TemporaryDirectory() as d:
        cfg = reduced(get_config("llama3.2-3b"))
        m = build(cfg)
        tcfg = TrainConfig(
            opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=40),
            checkpoint_dir=d,
            checkpoint_every=5,
            log_every=100,
        )
        tr = Trainer(m, tcfg)
        pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
        s0 = tr.init_state(jax.random.PRNGKey(0))
        s1, h1 = tr.run(s0, pipe, 10, log=False)
        assert h1[-1]["loss"] < h1[0]["loss"]
        # resume from checkpoint == continue uninterrupted
        s_rest = tr.restore(jax.random.PRNGKey(0))
        assert int(s_rest.opt.step) == 10 and s_rest.data_step == 10
        _, h2 = tr.run(s_rest, pipe, 5, log=False)
        _, h3 = tr.run(s1, pipe, 5, log=False)
        np.testing.assert_allclose(
            [x["loss"] for x in h2], [x["loss"] for x in h3], rtol=1e-5
        )


def test_train_with_daemons_and_microbatches():
    cfg = dataclasses.replace(
        reduced(get_config("granite-moe-1b-a400m")), sweep_period=4, hot_embed_rows=32
    )
    m = build(cfg)
    tr = Trainer(
        m,
        TrainConfig(opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=30), microbatches=2, log_every=100),
        num_nodes=2,
    )
    st = tr.init_state(jax.random.PRNGKey(0))
    pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4, zipf_a=1.3))
    st, hist = tr.run(st, pipe, 12, log=False)
    assert hist[-1]["loss"] < hist[0]["loss"]
    assert int(st.expert_placement.sweeps) >= 2
    assert int(st.hot_embed.sweeps) >= 2
    assert hist[-1]["moe_hot_frac"] > 0


# --------------------------------------------------------------- checkpoint
def test_checkpoint_atomicity_and_gc(tmp_path):
    root = str(tmp_path)
    tree = {"a": jnp.ones((4, 4), jnp.bfloat16), "b": {"c": jnp.arange(3)}}
    for step in (1, 2, 3, 4):
        ck.save_checkpoint(root, step, tree, metadata={"x": step})
    ck.gc_checkpoints(root, keep=2)
    steps = sorted(n for n in os.listdir(root) if n.startswith("step_"))
    assert len(steps) == 2
    assert ck.latest_step(root) == 4
    restored, manifest = ck.restore_checkpoint(root, template=tree)
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]), np.arange(3))
    assert restored["a"].dtype == np.asarray(tree["a"]).dtype
    assert manifest["metadata"]["x"] == 4


def test_checkpoint_shard_filter(tmp_path):
    root = str(tmp_path)
    tree = {"a": jnp.ones((2,)), "b": jnp.zeros((2,))}
    ck.save_checkpoint(root, 1, tree, shard_filter=lambda name: name == "a")
    d = os.path.join(root, "step_00000001")
    assert os.path.exists(os.path.join(d, "a.npy"))
    assert not os.path.exists(os.path.join(d, "b.npy"))


# -------------------------------------------------------------------- fault
def test_heartbeat_and_elastic_width():
    mon = HeartbeatMonitor(["n0", "n1", "n2", "n3"], timeout=10.0)
    assert len(mon.alive()) == 4
    mon.kill("n2")
    assert mon.dead() == ["n2"]
    assert elastic_data_width(3, model_parallel=1) == 3
    assert elastic_data_width(7, model_parallel=4) == 1
    assert elastic_data_width(3, model_parallel=4) == 0


def test_straggler_backup_dispatch():
    sm = StragglerMonitor(["a", "b", "c"], StragglerPolicy(deadline_factor=2.0, patience=2))
    assert sm.observe({"a": 1.0, "b": 1.0, "c": 5.0}) == []
    fired = sm.observe({"a": 1.0, "b": 1.0, "c": 5.0})
    assert fired and fired[0][0] == "c"


def test_elastic_restart_recovers_from_failure(tmp_path):
    """Kill a node mid-run; the runner restores the checkpoint, reseeks the
    data stream, and continues at reduced width."""
    root = str(tmp_path)
    cfg = reduced(get_config("qwen3-1.7b"))
    m = build(cfg)

    def make_trainer(width):
        tr = Trainer(
            m,
            TrainConfig(
                opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=60),
                checkpoint_dir=root,
                checkpoint_every=5,
                log_every=1000,
            ),
            num_nodes=max(width, 1),
        )
        pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
        return tr, tr.init_state(jax.random.PRNGKey(0)), pipe

    from repro.train.fault import ElasticRunner

    mon = HeartbeatMonitor(["n0", "n1", "n2", "n3"], timeout=1e9)
    runner = ElasticRunner(make_trainer, mon)
    tr, st, pipe = make_trainer(4)
    st, h1 = tr.run(st, pipe, 10, log=False)  # steps 1-10, ckpt at 10
    mon.kill("n3")
    runner.monitor = mon
    h2 = runner.run(total_steps=10, chunk=5)
    assert runner.restarts == 1
    assert len(h2) == 10
    assert h2[0]["step"] == 11  # resumed after the step-10 checkpoint


# -------------------------------------------------------------- compression
def test_int8_roundtrip_bound():
    g = jax.random.normal(jax.random.PRNGKey(3), (128, 64)) * 0.01
    qg = quantize_int8(g)
    err = float(jnp.max(jnp.abs(dequantize_int8(qg) - g)))
    assert err <= float(qg.scale) * 1.01
    assert qg.nbytes < g.size * 4 / 3.9


def test_int8_stochastic_rounding_unbiased():
    g = jnp.full((1000,), 0.3 * 0.01)
    qs = [
        dequantize_int8(quantize_int8(g, jax.random.PRNGKey(i))).mean()
        for i in range(30)
    ]
    assert abs(float(np.mean(qs)) - 0.003) < 2e-4


def test_topk_error_feedback_decomposition():
    g = jax.random.normal(jax.random.PRNGKey(5), (64, 32))
    grads = {"w": g}
    ef = ErrorFeedback.init(grads)
    sparse, ef2 = ef.compress_step(grads, k=100)
    dense = topk_decode(sparse["w"])
    np.testing.assert_allclose(
        np.asarray(dense + ef2.residual["w"]), np.asarray(g), atol=1e-6
    )
    assert int((np.asarray(dense) != 0).sum()) <= 100
