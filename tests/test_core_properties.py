"""Property-based tests (hypothesis) for the paper's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.metadata import create_store, record_accesses, record_new_keys
from repro.core.ownership import (
    eligible_hosts,
    max_coefficient,
    ownership_fraction,
    validate_coefficient,
)
from repro.core.placement import PlacementDaemon, sweep
from repro.core.costmodel import budget_plan

counts_strategy = st.integers(2, 24).flatmap(
    lambda n: st.integers(1, 64).flatmap(
        lambda k: st.lists(
            st.lists(st.integers(0, 1000), min_size=n, max_size=n),
            min_size=k,
            max_size=k,
        ).map(lambda rows: np.array(rows, np.float32))
    )
)


@settings(max_examples=40, deadline=None)
@given(counts_strategy)
def test_no_starvation(counts):
    """Eq. 3: with H <= 1/n every key with traffic keeps >= 1 eligible host."""
    n = counts.shape[1]
    h = max_coefficient(n)
    elig = np.asarray(eligible_hosts(jnp.asarray(counts), h))
    has_traffic = counts.sum(-1) > 0
    assert np.all(elig[has_traffic].any(-1)), "a live key lost all hosts"


@settings(max_examples=40, deadline=None)
@given(counts_strategy)
def test_fractions_sum_to_one(counts):
    f = np.asarray(ownership_fraction(jnp.asarray(counts)))
    s = f.sum(-1)
    has = counts.sum(-1) > 0
    np.testing.assert_allclose(s[has], 1.0, atol=1e-5)
    np.testing.assert_allclose(s[~has], 0.0, atol=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 32))
def test_uniform_traffic_qualifies_everyone(n):
    """Uniform access -> f = 1/n for all -> with H = 1/n all nodes qualify
    (the paper's degenerate-gracefully case for evenly-accessed objects)."""
    counts = jnp.full((5, n), 7.0)
    elig = np.asarray(eligible_hosts(counts, max_coefficient(n)))
    assert elig.all()


def test_validate_coefficient_bounds():
    validate_coefficient(0.25, 4)
    validate_coefficient(1.0 / 3.0, 3)
    with pytest.raises(ValueError):
        validate_coefficient(0.26, 4)  # H > 1/n violates eq. 3
    with pytest.raises(ValueError):
        validate_coefficient(0.0, 4)
    with pytest.raises(ValueError):
        validate_coefficient(0.1, 0)


@settings(max_examples=25, deadline=None)
@given(counts_strategy, st.floats(0.01, 0.5))
def test_sweep_invariants(counts, h_frac):
    """Algorithm 3 output invariants for any traffic matrix."""
    k, n = counts.shape
    h = min(h_frac, 1.0 / n)
    store = create_store(k, n)
    hosts = counts > np.median(counts)  # arbitrary current placement
    store = store._replace(
        access_counts=jnp.asarray(counts, jnp.int32),
        hosts=jnp.asarray(hosts),
        live=jnp.ones((k,), bool),
    )
    plan, new_store = sweep(store, h, now=0)
    owners = np.asarray(plan.owners)
    to_add = np.asarray(plan.to_add)
    to_drop = np.asarray(plan.to_drop)
    # adds and drops are disjoint and consistent with owners/current hosts
    assert not np.any(to_add & to_drop)
    assert np.all(to_add <= owners)
    assert np.all(to_add <= ~hosts)
    assert np.all(to_drop <= hosts)
    np.testing.assert_array_equal(owners, (hosts | to_add) & ~to_drop)
    # keys with traffic keep at least one replica (no starvation)
    has = counts.sum(-1) > 0
    assert np.all(owners[has].any(-1))
    # silence = no churn
    silent = ~has
    np.testing.assert_array_equal(owners[silent], hosts[silent])


def test_sweep_expiry():
    store = create_store(4, 3)
    store = store._replace(
        hosts=jnp.ones((4, 3), bool),
        live=jnp.ones((4,), bool),
        last_access=jnp.asarray([0, 50, 99, 100], jnp.int32),
    )
    plan, new_store = sweep(store, 1 / 3, now=100, expiry=10)
    np.testing.assert_array_equal(
        np.asarray(plan.expired), [True, True, False, False]
    )
    assert not np.asarray(new_store.live)[0]
    assert not np.asarray(plan.owners)[0].any()


@settings(max_examples=25, deadline=None)
@given(counts_strategy)
def test_budget_plan_infinite_is_identity(counts):
    k, n = counts.shape
    store = create_store(k, n)
    store = store._replace(
        access_counts=jnp.asarray(counts, jnp.int32),
        live=jnp.ones((k,), bool),
    )
    plan, _ = sweep(store, 1.0 / n, now=0)
    obj_bytes = jnp.ones((k,)) * 100.0
    trimmed = budget_plan(plan, jnp.asarray(counts), obj_bytes, float("inf"))
    np.testing.assert_array_equal(np.asarray(trimmed.to_add), np.asarray(plan.to_add))


def test_budget_plan_respects_budget():
    k, n = 10, 2
    counts = jnp.asarray(np.arange(k * n).reshape(k, n), jnp.float32)
    store = create_store(k, n)._replace(
        access_counts=jnp.asarray(np.arange(k * n).reshape(k, n), jnp.int32),
        live=jnp.ones((k,), bool),
    )
    plan, _ = sweep(store, 1.0 / n, now=0)
    obj_bytes = jnp.full((k,), 100.0)
    trimmed = budget_plan(plan, counts, obj_bytes, node_budget_bytes=250.0)
    per_node = np.asarray(trimmed.to_add).sum(0) * 100.0
    assert np.all(per_node <= 250.0)


def test_metadata_record_roundtrip():
    store = create_store(8, 3)
    keys = jnp.asarray([0, 1, 1, 7], jnp.int32)
    nodes = jnp.asarray([0, 1, 1, 2], jnp.int32)
    store = record_new_keys(store, keys, nodes, now=5)
    assert bool(store.live[0]) and bool(store.live[7]) and not bool(store.live[3])
    assert int(store.access_counts[1, 1]) == 2
    assert int(store.total_access_count()[1]) == 2
    store = record_accesses(store, keys, nodes, now=9)
    assert int(store.access_counts[1, 1]) == 4
    assert int(store.last_access[7]) == 9
