"""Beyond-paper optimizations: sort-based MoE dispatch, int8-served
weights, int8 gradient compression in the trainer, layout knob."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import build
from repro.models import moe as moe_lib
from repro.models.params import init_params
from repro.quant import dequant_leaf, is_quantized, quantize_leaf, quantize_tree
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def test_sort_dispatch_matches_einsum():
    cfg = dataclasses.replace(
        reduced(get_config("granite-moe-1b-a400m")),
        moe_capacity_factor=16.0,
        hot_expert_slots=0,
    )
    specs = moe_lib.moe_specs(cfg, ())
    params = init_params(specs, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model)).astype(
        jnp.bfloat16
    )
    y_e, s_e = moe_lib.moe_apply(
        params, x, dataclasses.replace(cfg, moe_impl="einsum")
    )
    y_s, s_s = moe_lib.moe_apply(
        params, x, dataclasses.replace(cfg, moe_impl="sort")
    )
    np.testing.assert_allclose(
        np.asarray(y_e, np.float32), np.asarray(y_s, np.float32), atol=0.05
    )
    assert float(s_e["dropped"]) == float(s_s["dropped"]) == 0.0
    np.testing.assert_array_equal(np.asarray(s_e["counts"]), np.asarray(s_s["counts"]))


def test_sort_dispatch_gradients():
    cfg = dataclasses.replace(
        reduced(get_config("granite-moe-1b-a400m")), moe_impl="sort", hot_expert_slots=0
    )
    specs = moe_lib.moe_specs(cfg, ())
    params = init_params(specs, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 32, cfg.d_model)).astype(
        jnp.bfloat16
    )
    g = jax.grad(
        lambda p: jnp.sum(moe_lib.moe_apply(p, x, cfg)[0].astype(jnp.float32) ** 2)
    )(params)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
    assert float(jnp.sum(jnp.abs(g["w_down"]))) > 0


def test_moe_token_conservation():
    """Every kept assignment lands in exactly one expert slot (dispatch mass
    = kept count) for both impls."""
    cfg = dataclasses.replace(reduced(get_config("deepseek-moe-16b")), hot_expert_slots=0)
    specs = moe_lib.moe_specs(cfg, ())
    params = init_params(specs, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model)).astype(jnp.bfloat16)
    for impl in ("einsum", "sort"):
        y, stats = moe_lib.moe_apply(params, x, dataclasses.replace(cfg, moe_impl=impl))
        tokens = 2 * 64
        assigned = float(stats["counts"].sum())
        assert assigned == tokens * cfg.top_k  # router always assigns k slots
        assert 0.0 <= float(stats["dropped"]) < 1.0
        assert np.isfinite(np.asarray(y, np.float32)).all()


def test_quantize_roundtrip_and_decode():
    w = jax.random.normal(jax.random.PRNGKey(0), (256, 512)).astype(jnp.bfloat16)
    q = quantize_leaf(w)
    assert is_quantized(q)
    back = dequant_leaf(q)
    err = float(jnp.max(jnp.abs(back.astype(jnp.float32) - w.astype(jnp.float32))))
    assert err < float(jnp.max(jnp.abs(w.astype(jnp.float32)))) / 64

    cfg = reduced(get_config("qwen3-1.7b"))
    m = build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    prompt = jnp.arange(10, dtype=jnp.int32)[None] % cfg.vocab_size
    logits, state = m.prefill(params, {"tokens": prompt}, cache_len=16)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    l_bf16, _ = m.decode_step(params, state, tok)
    l_int8, _ = m.decode_step(quantize_tree(params), state, tok)
    assert int(jnp.argmax(l_bf16, -1)[0]) == int(jnp.argmax(l_int8, -1)[0])
    rel = float(jnp.max(jnp.abs(l_bf16 - l_int8))) / float(jnp.max(jnp.abs(l_bf16)))
    assert rel < 0.2


def test_trainer_int8_grad_compression_converges():
    cfg = reduced(get_config("qwen3-1.7b"))
    m = build(cfg)
    pipe = Pipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4))
    finals = {}
    for mode in ("none", "int8"):
        tr = Trainer(
            m,
            TrainConfig(
                opt=OptConfig(lr=1e-3, warmup_steps=2, total_steps=30),
                grad_compression=mode,
                log_every=100,
            ),
        )
        st = tr.init_state(jax.random.PRNGKey(0))
        st, hist = tr.run(st, pipe, 15, log=False)
        finals[mode] = hist[-1]["loss"]
        assert hist[-1]["loss"] < hist[0]["loss"]
    # compressed run tracks the uncompressed trajectory closely
    assert abs(finals["int8"] - finals["none"]) < 0.5


def test_layout_field_plumbs_through():
    from repro.launch.sharding import make_dist, param_rules

    cfg = get_config("qwen3-1.7b")
    # AbstractMesh: rules/dist only read shape + axis names (1-device CI)
    try:
        mesh = jax.sharding.AbstractMesh((2, 2), ("data", "model"))
    except TypeError:  # older jax: AbstractMesh takes ((name, size), ...)
        mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 2)))
    tp = param_rules(cfg, mesh)
    assert tp["heads"] == "model" and tp["embed"] == "data"
    fsdp = param_rules(dataclasses.replace(cfg, layout="fsdp"), mesh)
    assert fsdp["heads"] is None and fsdp["vocab"] == "model"
    serve = param_rules(dataclasses.replace(cfg, layout="serve"), mesh)
    assert serve["embed"] is None and serve["heads"] == "model"
    d = make_dist(mesh, "fsdp")
    assert not d.tensor_parallel and d.loss_batch == ("data",)
    d2 = make_dist(mesh, "tp")
    assert d2.tensor_parallel and d2.loss_batch == ("data",)
