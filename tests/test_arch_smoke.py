"""Per-assigned-architecture smoke tests (reduced configs, CPU).

One forward/train step per arch asserting output shapes + no NaNs, plus a
decode-consistency check (greedy decode == repeated re-prefill) per family
representative. Full configs are exercised only via the dry-run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ShapeConfig, get_config, reduced
from repro.models import build

SMOKE = ShapeConfig("smoke", 64, 2, "train")


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = model.make_batch(SMOKE, jax.random.PRNGKey(1))
    batch["targets"] = batch["tokens"]
    (loss, metrics), grads = jax.jit(
        lambda p, b: jax.value_and_grad(lambda q: model.loss(q, b), has_aux=True)(p)
    )(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    # gradients flow to every parameter
    gnorms = jax.tree.map(lambda g: float(jnp.sum(jnp.abs(g))), grads)
    total = sum(jax.tree.leaves(gnorms))
    assert np.isfinite(total) and total > 0
    # the embedding gets gradient (vocab path wired)
    assert sum(jax.tree.leaves(gnorms["embed"] if isinstance(gnorms["embed"], dict) else [gnorms["embed"]])) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_smoke(arch):
    cfg = reduced(get_config(arch))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = ShapeConfig("s", 32, 2, "prefill")
    batch = model.make_batch(shape, jax.random.PRNGKey(2))
    logits, state = jax.jit(lambda p, b: model.prefill(p, b, cache_len=48))(
        params, batch
    )
    assert logits.shape == (2, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits[:, : cfg.vocab_size], np.float32)))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    step = jax.jit(lambda p, s, t: model.decode_step(p, s, t))
    for _ in range(3):
        logits, state = step(params, state, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert np.all(np.isfinite(np.asarray(logits[:, : cfg.vocab_size], np.float32)))
    assert int(tok.max()) < cfg.vocab_size  # padded vocab rows never sampled


@pytest.mark.parametrize(
    "arch",
    ["qwen3-1.7b", "rwkv6-1.6b", "recurrentgemma-2b", "whisper-base", "granite-moe-1b-a400m"],
)
def test_decode_matches_prefill(arch):
    """Greedy continuation via decode_step == greedy via re-prefill.

    MoE capacity is raised so no token drops — with finite capacity the
    drop pattern legitimately depends on batch composition, which would
    make decode-vs-reprefill equality impossible by design.
    """
    import dataclasses

    cfg = reduced(get_config(arch))
    if cfg.num_experts:
        cfg = dataclasses.replace(
            cfg, moe_capacity_factor=16.0, moe_cold_capacity=1.0, moe_hot_capacity=16.0
        )
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = list(np.arange(9) % cfg.vocab_size)

    def full_batch(seq):
        b = {"tokens": jnp.asarray(seq, jnp.int32)[None]}
        if cfg.family == "audio":
            b["frames"] = jnp.zeros((1, cfg.num_frames, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            b["patches"] = jnp.zeros((1, cfg.num_patches, cfg.d_model), jnp.bfloat16)
        return b

    logits, state = model.prefill(params, full_batch(prompt), cache_len=24)
    toks = [int(jnp.argmax(logits, -1)[0])]
    for _ in range(3):
        logits, state = model.decode_step(
            params, state, jnp.asarray([toks[-1]], jnp.int32)
        )
        toks.append(int(jnp.argmax(logits, -1)[0]))

    seq, ref = list(prompt), []
    for _ in range(4):
        logits, _ = model.prefill(params, full_batch(seq))
        t = int(jnp.argmax(logits, -1)[0])
        ref.append(t)
        seq.append(t)
    assert toks == ref, (arch, toks, ref)


def test_param_counts_in_range():
    """Full configs instantiate specs (no arrays) with plausible param counts."""
    expect = {
        "yi-9b": (8e9, 10e9),
        "qwen3-1.7b": (1.5e9, 2.4e9),
        "llama3.2-3b": (3e9, 4.1e9),
        "mistral-large-123b": (115e9, 130e9),
        "rwkv6-1.6b": (1.4e9, 2.2e9),
        "llava-next-34b": (32e9, 37e9),
        "recurrentgemma-2b": (2.3e9, 3.6e9),
        "whisper-base": (6e7, 1.6e8),
        "deepseek-moe-16b": (14e9, 19e9),
        "granite-moe-1b-a400m": (0.9e9, 1.6e9),
    }
    for arch, (lo, hi) in expect.items():
        n = build(get_config(arch)).num_params()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params outside [{lo/1e9}, {hi/1e9}]B"


def test_moe_active_params():
    m = build(get_config("deepseek-moe-16b"))
    assert m.active_params() < m.num_params() * 0.35
    g = build(get_config("granite-moe-1b-a400m"))
    assert g.active_params() < g.num_params()
