"""Policy-API regression guards.

1. The legacy ``Scenario`` enum spelling is *removed*: passing one to any
   runner raises with the exact policy replacement (the deprecation window
   closed after one release — see EXPERIMENTS.md §Deprecation timeline).
2. Every registered policy respects per-node capacity budgets: the shared
   projection stage is not optional (hypothesis property test).
3. The batched ``run_experiment(policies=[...])`` grid agrees with
   single-policy runs and vmaps same-family dynamic params into one
   compiled program.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.metadata import create_store
from repro.core.policy import (
    POLICIES,
    PolicyContext,
    RedynisPolicy,
    StaticPolicy,
    policy_sweep,
    split_policy,
)
from repro.kvsim import (
    ClusterConfig,
    Scenario,
    SimResult,
    WorkloadConfig,
    run_experiment,
    run_scenario,
    run_scenario_reference,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


def assert_results_equal(a: SimResult, b: SimResult, ctx: str = ""):
    """Bit-identical, not allclose: both spellings must be the same program."""
    for field, x, y in zip(SimResult._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{ctx} {field}"
        )


@pytest.mark.parametrize("runner", [run_scenario, run_scenario_reference])
@pytest.mark.parametrize(
    "scenario,replacement",
    [
        (Scenario.LOCAL, "StaticPolicy(mode='local')"),
        (Scenario.REMOTE, "StaticPolicy(mode='remote')"),
        (Scenario.REPLICATED, "StaticPolicy(mode='replicated')"),
        (Scenario.OPTIMIZED, "RedynisPolicy()"),
    ],
)
def test_legacy_scenario_enum_raises_with_replacement(runner, scenario, replacement):
    """The removed enum spelling fails fast on BOTH engines, and the error
    names the exact policy to paste in."""
    wl = WorkloadConfig(num_requests=500, num_keys=50)
    with pytest.raises(ValueError, match="removed") as exc:
        runner(wl, ClusterConfig(), scenario, seed=0)
    assert replacement in str(exc.value)


@pytest.mark.parametrize("runner", [run_scenario, run_scenario_reference])
def test_legacy_engine_kwargs_are_gone(runner):
    """The kwarg sprawl (ownership_coefficient/expiry_ticks/daemon_period/
    backend) left with the shim — TypeError, not a silent accept."""
    wl = WorkloadConfig(num_requests=500, num_keys=50)
    with pytest.raises(TypeError):
        runner(wl, ClusterConfig(), RedynisPolicy(), ownership_coefficient=0.2)
    with pytest.raises(TypeError):
        runner(wl, ClusterConfig(), RedynisPolicy(), backend="pallas")


def test_policy_scan_matches_reference_with_capacity():
    """Fused vs reference oracle for the NEW policies (the legacy ones are
    covered by test_simulate_equivalence) under a finite budget."""
    from repro.core.policy import CostGreedyPolicy, DecayLFUPolicy, TopKPolicy

    wl = WorkloadConfig(
        num_requests=3_000, num_keys=150, skewed=True, affinity=0.7,
        object_bytes_sigma=0.5,
    )
    cl = ClusterConfig(capacity_bytes=24 * 1024.0)
    for pol in (
        TopKPolicy(k=40, decay=0.8),
        CostGreedyPolicy(min_saved_ms_per_kib=500.0),
        DecayLFUPolicy(alpha=0.4, period=2),
    ):
        a = run_scenario(wl, cl, pol, seed=2, daemon_interval=500)
        b = run_scenario_reference(wl, cl, pol, seed=2, daemon_interval=500)
        for field, x, y in zip(SimResult._fields, a, b):
            np.testing.assert_allclose(
                np.asarray(x), np.asarray(y), rtol=1e-4,
                err_msg=f"{pol} {field}",
            )


def test_peak_occupancy_is_per_chunk_for_every_policy():
    """Unified sampling: static policies report the (constant) per-chunk
    peak — identical to the seed engine's initial-map value — and active
    policies report a genuine running max that dominates it."""
    wl = WorkloadConfig(num_requests=4_000, skewed=True)
    cl = ClusterConfig()
    full = run_scenario(wl, cl, StaticPolicy(mode="local"), seed=0)
    np.testing.assert_allclose(
        full.peak_occupancy_bytes, wl.num_keys * wl.object_bytes
    )
    offsite = run_scenario(wl, cl, StaticPolicy(mode="remote"), seed=0)
    assert offsite.peak_occupancy_bytes.max() <= wl.num_keys * wl.object_bytes
    opt = run_scenario(wl, cl, RedynisPolicy(), seed=0)
    # Replication grows occupancy past the one-replica-per-key start.
    assert opt.peak_occupancy_bytes.max() > offsite.peak_occupancy_bytes.max()


# ---------------------------------------------------------------------------
# Batched multi-policy grids.
# ---------------------------------------------------------------------------


def test_run_experiment_policy_grid_batches_one_call():
    """Acceptance: a >=4-policy x >=3-seed same-family grid runs as ONE
    batched program (policy axis vmapped alongside seeds) and returns
    per-policy SimResults."""
    policies = [RedynisPolicy(h=h) for h in (1 / 3, 0.25, 0.15, 0.05)]
    res = run_experiment(
        policies=policies,
        read_fractions=(1.0,),
        iterations=3,
        num_requests=3_000,
        num_keys=150,
        skewed=True,
        affinity=0.7,
    )
    assert res["num_batched_calls"] == 1
    assert len(res["policies"]) == 4
    hits = []
    for rows in res["policies"].values():
        (row,) = rows
        assert len(row["results"]) == 3
        assert all(isinstance(r, SimResult) for r in row["results"])
        assert np.isfinite(row["throughput"]) and row["throughput"] > 0
        hits.append(row["hit_rate"])
    # Lower H admits more hosts: hit rate monotone as H decreases.
    assert hits == sorted(hits), hits


def test_run_experiment_policy_grid_matches_single_runs():
    """Grid rows must equal the corresponding single-policy runs — the
    policy-axis vmap changes batching, not semantics."""
    policies = [RedynisPolicy(h=1 / 3), RedynisPolicy(h=0.1)]
    res = run_experiment(
        policies=policies,
        read_fractions=(0.9,),
        iterations=2,
        num_requests=2_000,
        num_keys=100,
        skewed=True,
        affinity=0.7,
    )
    for pol, (label, rows) in zip(policies, res["policies"].items()):
        for seed, got in enumerate(rows[0]["results"]):
            wl = WorkloadConfig(
                num_requests=2_000, num_keys=100, skewed=True, affinity=0.7,
                read_fraction=0.9,
            )
            want = run_scenario(wl, ClusterConfig(), pol, seed=seed)
            for field, x, y in zip(SimResult._fields, want, got):
                np.testing.assert_allclose(
                    np.asarray(x), np.asarray(y), rtol=1e-5,
                    err_msg=f"{label} seed={seed} {field}",
                )


def test_run_experiment_heterogeneous_policy_grid():
    from repro.core.policy import DecayLFUPolicy, TopKPolicy

    res = run_experiment(
        policies=[
            RedynisPolicy(),
            StaticPolicy(mode="local"),
            StaticPolicy(mode="remote"),
            TopKPolicy(k=20),
            DecayLFUPolicy(),
        ],
        read_fractions=(1.0,),
        iterations=2,
        num_requests=2_000,
        num_keys=100,
        skewed=True,
    )
    rows = {label: r[0] for label, r in res["policies"].items()}
    assert len(rows) == 5
    assert rows["static(mode='local')"]["hit_rate"] == 1.0
    assert rows["static(mode='remote')"]["hit_rate"] == 0.0
    assert all(0.0 <= r["hit_rate"] <= 1.0 for r in rows.values())


def test_run_experiment_requires_policies():
    """The implicit legacy scenario grid left with the shim: policies= is
    mandatory, and a stray enum in the list raises with its replacement."""
    with pytest.raises(ValueError, match="policies is required"):
        run_experiment(read_fractions=(1.0,), iterations=1, num_requests=500)
    with pytest.raises(ValueError, match="removed") as exc:
        run_experiment(
            policies=[RedynisPolicy(), Scenario.LOCAL],
            read_fractions=(1.0,),
            iterations=1,
            num_requests=500,
        )
    assert "StaticPolicy(mode='local')" in str(exc.value)


# ---------------------------------------------------------------------------
# Property: every registered policy respects capacity budgets.
# ---------------------------------------------------------------------------


def _active_policy_instances():
    out = []
    for name, cls in sorted(POLICIES.items()):
        pol = cls()
        if pol.is_active:
            out.append(pol)
        else:
            out.extend(cls(mode=m) for m in cls.MODES)
    return out


def check_policy_respects_budget(policy, seed: int, k: int, n: int, budget: float):
    rng = np.random.default_rng(seed)
    counts = rng.integers(0, 200, size=(k, n)).astype(np.int32)
    counts[rng.random(k) < 0.2] = 0
    store = create_store(k, n)._replace(
        access_counts=jnp.asarray(counts),
        hosts=jnp.asarray(rng.random((k, n)) < 0.5),
        live=jnp.asarray(rng.random(k) < 0.9),
        last_access=jnp.asarray(rng.integers(0, 50, k).astype(np.int32)),
    )
    obj = jnp.asarray(rng.uniform(10.0, 400.0, k), jnp.float32)
    cap = jnp.full((n,), budget, jnp.float32)
    rtt = jnp.asarray(
        np.where(np.eye(n, dtype=bool), 0.0, 100.0), jnp.float32
    )
    pol = policy.resolve(n)
    pol.validate(n)
    static, params = split_policy(pol)
    ctx = PolicyContext(rtt=rtt, object_bytes=obj, capacity_bytes=cap, params=params)
    state = static.init(store, ctx)
    plan, _, new_store = policy_sweep(static, state, store, 60, ctx)
    occupancy = np.asarray(
        jnp.sum(jnp.where(plan.owners, obj[:, None], 0.0), axis=0)
    )
    assert (occupancy <= budget + 1e-3).all(), (
        f"{type(policy).__name__}: node occupancy {occupancy} exceeds "
        f"budget {budget}"
    )
    np.testing.assert_array_equal(
        np.asarray(new_store.hosts), np.asarray(plan.owners)
    )


@pytest.mark.parametrize(
    "policy", _active_policy_instances(), ids=lambda p: type(p).__name__ + str(getattr(p, "mode", ""))
)
def test_every_registered_policy_respects_budget_fixed(policy):
    check_policy_respects_budget(policy, seed=7, k=60, n=4, budget=1500.0)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        st.integers(0, 2**31 - 1),
        st.integers(1, 50),
        st.integers(2, 6),
        st.floats(50.0, 5000.0),
        st.sampled_from(_active_policy_instances()),
    )
    def test_every_registered_policy_respects_budget_fuzz(
        seed, k, n, budget, policy
    ):
        check_policy_respects_budget(policy, seed=seed, k=k, n=n, budget=budget)
