import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration tests")


@pytest.fixture
def run_multi_rank():
    """Run a Python script in a subprocess with N virtual CPU devices.

    The repo convention for multi-rank CPU tests (since the PR-1
    ``publish_and_fill`` equivalence test): the main pytest process stays
    single-device, and anything needing a mesh forces
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` in a fresh
    interpreter BEFORE jax is imported (the flag is read once at backend
    initialisation). The fixture injects the flag and ``PYTHONPATH=src``,
    asserts a zero exit status (stdout+stderr on failure), and returns the
    script's stdout so callers can assert on printed markers.
    """

    def run(script: str, num_devices: int = 2, timeout: int = 600) -> str:
        env = dict(
            os.environ,
            PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""),
            XLA_FLAGS=(
                f"--xla_force_host_platform_device_count={num_devices}"
            ),
        )
        proc = subprocess.run(
            [sys.executable, "-c", script],
            capture_output=True,
            text=True,
            env=env,
            timeout=timeout,
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr
        return proc.stdout

    return run
