"""Chunk-replay fusion guard rails (ISSUE-5 acceptance).

1. Kernel ⇄ reference parity: the fused Pallas chunk-replay kernel
   (one-hot-matmul gather + latency + busy/histogram folds, interpret mode
   on CPU) must agree with the pure-jnp oracle across read modes ×
   topologies × read fractions — hit/read/count/histogram *bit-exactly*
   (integer counts, and the kernel replicates the oracle's f32 latency op
   sequence so buckets match), busy/lat_sum allclose (tile-order
   re-association only).
2. Hypothesis fuzz over random RTT matrices and replica maps.
3. Engine-level goldens: ``run_scenario(replay_backend="pallas")`` leaves
   SimResult within tolerance of the bit-exact jax backend on all four
   baseline policies, with telemetry histograms identical, and the
   batched ``run_experiment`` grid accepts the backend too.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_replay.ops import chunk_latency, chunk_replay
from repro.kernels.chunk_replay.ref import READ_MODES, chunk_replay_ref
from repro.kvsim import (
    REPLAY_BACKENDS,
    ClusterConfig,
    RedynisPolicy,
    SimResult,
    StaticPolicy,
    TelemetryConfig,
    WorkloadConfig,
    run_experiment,
    run_scenario,
    run_scenario_reference,
    wan5_cluster,
    wan5_edge_cluster,
    wan5_workload,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# 1. Kernel ⇄ reference parity.
# ---------------------------------------------------------------------------

# topology name -> [N, N] RTT matrix (as the engines see them).
TOPOLOGIES = {
    "flat": ClusterConfig().rtt_matrix(),
    "wan5": wan5_cluster().rtt_matrix(),
    "wan5_edge": wan5_edge_cluster().rtt_matrix(),
}


def _random_chunk(seed, b, k, n, read_fraction, empty_rows=0.0):
    """A random frozen map + request slab; ``empty_rows`` leaves some keys
    with no replica at all (the orphan worst-RTT path)."""
    rng = np.random.default_rng(seed)
    hosts = rng.random((k, n)) < 0.4
    if empty_rows:
        hosts[rng.random(k) < empty_rows] = False
    return (
        jnp.asarray(hosts),
        jnp.asarray(rng.integers(0, k, b).astype(np.int32)),
        jnp.asarray(rng.integers(0, n, b).astype(np.int32)),
        jnp.asarray(rng.random(b) < read_fraction),
        jnp.asarray(rng.random(b) < 0.9),  # valid mask (padding path)
    )


def check_kernel_matches_ref(
    rtt, seed, b, k, read_mode, read_fraction,
    num_bins=64, tr=256, tkey=128, empty_rows=0.0, master=0,
):
    n = rtt.shape[0]
    hosts, keys, nodes, is_read, valid = _random_chunk(
        seed, b, k, n, read_fraction, empty_rows
    )
    kw = dict(
        service_ms=10.0, master=master, xfer_read_ms=2.0, xfer_write_ms=3.0,
        read_mode=read_mode, num_bins=num_bins, lo=1.0, hi=5_000.0,
    )
    ref = chunk_replay_ref(hosts, keys, nodes, is_read, valid, rtt, **kw)
    ker = chunk_replay(
        hosts, keys, nodes, is_read, valid, rtt,
        backend="pallas", tr=tr, tkey=tkey, interpret=True, **kw,
    )
    # busy / lat_sum: reductions re-associate across tiles -> allclose.
    np.testing.assert_allclose(
        np.asarray(ker[0]), np.asarray(ref[0]), rtol=1e-5, err_msg="busy"
    )
    np.testing.assert_allclose(
        float(ker[1]), float(ref[1]), rtol=1e-5, err_msg="lat_sum"
    )
    # hits / reads / count: integer counts -> bit-exact.
    for i, name in ((2, "hits"), (3, "reads"), (4, "count")):
        assert float(ker[i]) == float(ref[i]), (name, ker[i], ref[i])
    # histogram: same f32 latency bits -> same buckets -> exact counts.
    np.testing.assert_array_equal(np.asarray(ker[5]), np.asarray(ref[5]))
    # conservation: every valid request lands in exactly one bucket.
    np.testing.assert_allclose(float(jnp.sum(ker[5])), float(ker[4]))


# read modes × topologies × read fractions, with odd sizes exercising the
# request/key padding paths and empty replica rows the orphan guard.
PARITY_GRID = [
    (topo, mode, rf)
    for topo in TOPOLOGIES
    for mode in READ_MODES
    for rf in (1.0, 0.75, 0.5)
]


@pytest.mark.parametrize(
    "topo,mode,rf", PARITY_GRID, ids=[f"{t}-{m}-{rf}" for t, m, rf in PARITY_GRID]
)
def test_chunk_replay_kernel_matches_ref(topo, mode, rf):
    check_kernel_matches_ref(
        TOPOLOGIES[topo], seed=hash((topo, mode, rf)) % 2**32,
        b=777, k=333, read_mode=mode, read_fraction=rf, empty_rows=0.1,
    )


def test_chunk_replay_without_histogram():
    """num_bins=0 (telemetry off) drops the histogram output entirely."""
    rtt = TOPOLOGIES["wan5"]
    hosts, keys, nodes, is_read, valid = _random_chunk(3, 500, 200, 5, 0.8)
    kw = dict(
        service_ms=10.0, master=2, xfer_read_ms=0.0, xfer_write_ms=0.0,
        read_mode="map", num_bins=0,
    )
    ref = chunk_replay_ref(hosts, keys, nodes, is_read, valid, rtt, **kw)
    ker = chunk_replay(
        hosts, keys, nodes, is_read, valid, rtt,
        backend="pallas", tr=128, tkey=64, interpret=True, **kw,
    )
    assert ref[5] is None and ker[5] is None
    np.testing.assert_allclose(np.asarray(ker[0]), np.asarray(ref[0]), rtol=1e-5)
    assert float(ker[2]) == float(ref[2])


def test_chunk_replay_single_tile_and_single_request():
    """Degenerate shapes: one request, one key tile."""
    rtt = TOPOLOGIES["flat"]
    check_kernel_matches_ref(
        rtt, seed=11, b=1, k=1, read_mode="map", read_fraction=1.0,
        tr=256, tkey=256,
    )


def test_chunk_replay_validates_inputs():
    rtt = TOPOLOGIES["flat"]
    hosts, keys, nodes, is_read, valid = _random_chunk(0, 8, 8, 3, 1.0)
    kw = dict(service_ms=1.0, master=0, xfer_read_ms=0.0, xfer_write_ms=0.0)
    with pytest.raises(ValueError, match="read_mode"):
        chunk_replay(hosts, keys, nodes, is_read, valid, rtt,
                     read_mode="bogus", **kw)
    with pytest.raises(ValueError, match="backend"):
        chunk_replay(hosts, keys, nodes, is_read, valid, rtt,
                     read_mode="map", backend="cuda", **kw)
    assert set(REPLAY_BACKENDS) == {"jax", "pallas"}


def test_chunk_latency_matches_flat_model():
    """The scalar-form latency pass reproduces the paper's flat model on a
    hand-built chunk: local hit = service, remote read = service + RTT."""
    hosts = jnp.asarray([[True, False, False], [True, True, True]])
    keys = jnp.asarray([0, 0, 1], jnp.int32)
    nodes = jnp.asarray([0, 1, 2], jnp.int32)
    is_read = jnp.asarray([True, True, False])
    rtt = ClusterConfig().rtt_matrix()
    lat, hits = chunk_latency(
        hosts, keys, nodes, is_read, rtt,
        service_ms=10.0, master=0, xfer_read_ms=0.0, xfer_write_ms=0.0,
        read_mode="map",
    )
    # key 0 at its home -> pure service; key 0 read remotely -> + 100 ms;
    # key 1 write from node 2 with 3 owners -> relay(100) + post(100).
    np.testing.assert_allclose(np.asarray(lat), [10.0, 110.0, 210.0])
    np.testing.assert_array_equal(np.asarray(hits), [True, False, False])


if HAVE_HYPOTHESIS:
    chunk_strategy = st.tuples(
        st.integers(0, 2**31 - 1),  # numpy seed
        st.integers(1, 500),  # b requests (odd sizes exercise the pad)
        st.integers(1, 300),  # k keys
        st.integers(2, 8),  # n nodes
        st.sampled_from(READ_MODES),
        st.floats(0.0, 1.0),  # read fraction
        st.sampled_from([64, 256]),  # request tile
        st.sampled_from([32, 128]),  # key tile
    )

    @settings(max_examples=25, deadline=None)
    @given(chunk_strategy)
    def test_chunk_replay_kernel_matches_ref_fuzz(params):
        seed, b, k, n, mode, rf, tr, tkey = params
        rng = np.random.default_rng(seed + 1)
        # Random asymmetric-free RTT: zero-ish diagonal, arbitrary WAN.
        rtt = rng.uniform(1.0, 400.0, (n, n))
        np.fill_diagonal(rtt, rng.uniform(0.0, 2.0, n))
        check_kernel_matches_ref(
            jnp.asarray(np.float32(rtt)), seed=seed, b=b, k=k,
            read_mode=mode, read_fraction=rf, tr=tr, tkey=tkey,
            empty_rows=0.3, master=int(rng.integers(0, n)),
        )


# ---------------------------------------------------------------------------
# 3. Engine-level goldens: replay_backend="pallas" vs the bit-exact engine.
# ---------------------------------------------------------------------------

RTOL = 1e-4


def assert_results_match(a: SimResult, b: SimResult, ctx: str = ""):
    for field, x, y in zip(SimResult._fields, a, b):
        np.testing.assert_allclose(
            np.asarray(x), np.asarray(y), rtol=RTOL, err_msg=f"{ctx} {field}"
        )


BASELINES = {
    "local": StaticPolicy(mode="local"),
    "remote": StaticPolicy(mode="remote"),
    "optimized": RedynisPolicy(),
    "replicated": StaticPolicy(mode="replicated"),
}


@pytest.mark.parametrize("name", sorted(BASELINES))
def test_pallas_replay_matches_jax_all_scenarios(name):
    """All four baseline policies: the fused kernel engine must leave
    SimResult within tolerance of the bit-exact jax replay path."""
    wl = WorkloadConfig(num_requests=4_000, num_keys=200, skewed=True)
    cl = ClusterConfig()
    a = run_scenario(wl, cl, BASELINES[name], seed=2, daemon_interval=500)
    b = run_scenario(
        wl, cl, BASELINES[name], seed=2, daemon_interval=500,
        replay_backend="pallas",
    )
    assert_results_match(a, b, name)


def test_pallas_replay_matches_reference_wan5_telemetry():
    """wan5 + telemetry: the kernel's fused histogram fold must reproduce
    the reference engine's histogram EXACTLY (same latency bits -> same
    buckets), and aggregates stay within tolerance."""
    wl = wan5_workload(num_requests=3_000, num_keys=150, affinity=0.8)
    cl = wan5_cluster()
    cfg = TelemetryConfig()
    a, ta = run_scenario(
        wl, cl, RedynisPolicy(h=0.2), seed=0, daemon_interval=500,
        telemetry=cfg, replay_backend="pallas",
    )
    b, tb = run_scenario_reference(
        wl, cl, RedynisPolicy(h=0.2), seed=0, daemon_interval=500,
        telemetry=cfg,
    )
    assert_results_match(a, b, "wan5-telemetry")
    np.testing.assert_array_equal(ta.hist_group, tb.hist_group)
    np.testing.assert_array_equal(ta.chunk_hist, tb.chunk_hist)


def test_pallas_replay_padded_trace_and_capacity():
    """Trace padding (valid-masked rows) + finite budgets + lognormal
    sizes all flow through the kernel path unchanged."""
    wl = WorkloadConfig(
        num_requests=3_300, num_keys=150, skewed=True, object_bytes_sigma=0.5
    )
    cl = ClusterConfig(capacity_bytes=24 * 1024.0)
    a = run_scenario(wl, cl, RedynisPolicy(), seed=1, daemon_interval=500)
    b = run_scenario(
        wl, cl, RedynisPolicy(), seed=1, daemon_interval=500,
        replay_backend="pallas",
    )
    assert_results_match(a, b, "padded-capacity")
    assert a.capacity_evictions > 0


def test_run_experiment_accepts_replay_backend():
    """The batched (seed-vmapped) engine threads replay_backend through,
    and rejects it on the reference engine (the jnp oracle)."""
    kw = dict(
        read_fractions=(0.9,), skewed=True, iterations=2,
        num_requests=2_000, num_keys=100,
    )
    a = run_experiment(policies=[RedynisPolicy()], **kw)
    b = run_experiment(
        policies=[RedynisPolicy()], replay_backend="pallas", **kw
    )
    (label,) = a["policies"]
    ra, rb = a["policies"][label][0], b["policies"][label][0]
    np.testing.assert_allclose(rb["throughput"], ra["throughput"], rtol=RTOL)
    np.testing.assert_allclose(rb["hit_rate"], ra["hit_rate"], rtol=RTOL)
    with pytest.raises(ValueError, match="reference"):
        run_experiment(
            policies=[RedynisPolicy()], engine="reference",
            replay_backend="pallas", **kw,
        )
    with pytest.raises(ValueError, match="replay_backend"):
        run_scenario(
            WorkloadConfig(num_requests=100, num_keys=10), ClusterConfig(),
            RedynisPolicy(), replay_backend="cuda",
        )


def test_experiment_hit_rate_is_seed_mean_with_ci():
    """ISSUE-5 satellite: rows report the seed-MEAN hit rate with a 99% CI
    band (the old seed-0 point estimate carried no uncertainty)."""
    res = run_experiment(
        read_fractions=(0.9,), skewed=True, iterations=3,
        num_requests=2_000, num_keys=100, affinity=0.8,
        policies=[RedynisPolicy()],
    )
    (label,) = res["policies"]
    row = res["policies"][label][0]
    per_seed = [r.hit_rate for r in row["results"]]
    np.testing.assert_allclose(row["hit_rate"], np.mean(per_seed), rtol=1e-12)
    assert row["hit_rate_ci99"] >= 0.0
    # The band actually reflects seed spread when there is any.
    if np.std(per_seed) > 0:
        assert row["hit_rate_ci99"] > 0.0
    # The reference engine carries the same surface (both engines share
    # the row-building path).
    ref = run_experiment(
        read_fractions=(1.0,), iterations=2, num_requests=1_000,
        engine="reference", policies=[StaticPolicy(mode="local")],
    )
    for rows in ref["policies"].values():
        assert "hit_rate_ci99" in rows[0]
