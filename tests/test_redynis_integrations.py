"""The three ML-state Redynis integrations: expert placement, hot-row
embedding, session routing — convergence, exactness, non-blocking commit."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.expert_placement import ExpertPlacement
from repro.core.hot_embedding import HotEmbedding, embed_with_cache
from repro.core.repartition import CommitState, create_cache, plan_moves, publish_and_fill
from repro.core.placement import PlacementPlan
from repro.models import moe as moe_lib
from repro.models.params import init_params
from repro.serving import SessionRouter


def test_expert_placement_tracks_hot_experts():
    ep = ExpertPlacement(num_layers=3, num_experts=16, num_nodes=4, slots=4, period=10)
    st = ep.init_state()
    rng = np.random.default_rng(0)
    for step in range(30):
        counts = np.zeros((3, 8, 16), np.float32)
        for l in range(3):
            for g in range(8):
                np.add.at(counts[l, g], rng.choice([3, 7, 11], 100), 1)
                np.add.at(counts[l, g], rng.integers(0, 16, 25), 1)
        st = ep.fold(st, jnp.asarray(counts), jnp.arange(8, dtype=jnp.int32) % 4)
        if ep.due(step + 1):
            st = ep.sweep(st)
    for l in range(3):
        assert {3, 7, 11} <= set(np.asarray(st.hot_ids)[l].tolist())
    assert float(ep.hit_rate(st)) > 0.7


def test_expert_placement_shift_reacts():
    """Traffic shifts -> EMA decay lets the replica set follow (beyond-paper
    extension; raw counters would pin the stale set)."""
    ep = ExpertPlacement(3, 16, 2, slots=2, period=5, decay=0.5)
    st = ep.init_state()
    def run(hot, steps):
        nonlocal st
        rng = np.random.default_rng(1)
        for s in range(steps):
            counts = np.zeros((3, 4, 16), np.float32)
            np.add.at(counts[:, :, hot], None, 50.0)
            st = ep.fold(st, jnp.asarray(counts), jnp.arange(4, dtype=jnp.int32) % 2)
            if ep.due(int(st.step)):
                st = ep.sweep(st)
    run(2, 10)
    assert 2 in np.asarray(st.hot_ids)[0]
    run(9, 20)
    assert 9 in np.asarray(st.hot_ids)[0]


def test_moe_hot_path_exact_at_full_capacity():
    cfg = reduced(get_config("deepseek-moe-16b"))
    cfg = dataclasses.replace(
        cfg, moe_capacity_factor=8.0, moe_cold_capacity=1.0, moe_hot_capacity=8.0
    )
    specs = moe_lib.moe_specs(cfg, ())
    params = init_params(specs, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model)).astype(jnp.bfloat16)
    y0, s0 = moe_lib.moe_apply(params, x, cfg)
    hot = jnp.arange(cfg.hot_expert_slots, dtype=jnp.int32)
    y1, s1 = moe_lib.moe_apply(params, x, cfg, None, hot)
    np.testing.assert_allclose(
        np.asarray(y0, np.float32), np.asarray(y1, np.float32), atol=2e-2
    )
    assert float(s1["hot_frac"]) > 0
    np.testing.assert_array_equal(np.asarray(s0["counts"]), np.asarray(s1["counts"]))


def test_moe_cold_capacity_shrinks_with_hot_cache():
    cfg = reduced(get_config("deepseek-moe-16b"))
    assert moe_lib.cold_capacity(cfg, 512) < moe_lib.cold_capacity(
        dataclasses.replace(cfg, hot_expert_slots=0), 512
    )


def test_hot_embedding_exactness_and_hit_rate():
    he = HotEmbedding(vocab=1000, num_nodes=4, rows=64, period=5)
    hs = he.init_state()
    rng = np.random.default_rng(0)
    for step in range(10):
        toks = np.where(
            rng.random((8, 128)) < 0.9,
            rng.integers(0, 64, (8, 128)),
            rng.integers(64, 1000, (8, 128)),
        )
        hs = he.fold(hs, jnp.asarray(toks, jnp.int32), jnp.arange(8, dtype=jnp.int32) % 4)
        if he.due(step + 1):
            hs = he.sweep(hs)
    assert float(he.hit_rate(hs)) > 0.8
    table = jax.random.normal(jax.random.PRNGKey(0), (1024, 32))
    # a batch drawn from the same zipfian stream the cache was tuned on
    toks = jnp.asarray(
        np.where(
            rng.random((2, 64)) < 0.9,
            rng.integers(0, 64, (2, 64)),
            rng.integers(64, 1000, (2, 64)),
        ),
        jnp.int32,
    )
    for kernel in (True, False):
        rows, hit = embed_with_cache(table, toks, hs, use_kernel=kernel)
        np.testing.assert_allclose(
            np.asarray(rows), np.asarray(jnp.take(table, toks, axis=0)), atol=1e-6
        )
    # the zipfian batch should mostly hit the cache
    assert float(hit.mean()) > 0.6


def test_session_router_migrates_and_elects():
    r = SessionRouter(num_pods=4, max_sessions=64, sweep_period=10, session_bytes=1e6)
    rng = np.random.default_rng(2)
    # sessions created on pod 0, then served from their true home pods:
    # the daemon must migrate them (paper: bring data to the request source)
    for i in range(16):
        r.route(f"sess{i}", 0)
    home = {f"sess{i}": i % 4 for i in range(16)}
    for t in range(300):
        s = f"sess{rng.integers(0, 16)}"
        r.route(s, home[s])
        r.tick()
    assert r.stats["migrations"] > 0
    assert r.hit_rate() > 0.5
    assert r.stats["migrated_bytes"] > 0
    lead = r.leader
    r.fail_pod(lead)
    r.tick()
    assert r.leader != lead and r.stats["elections"] == 1


def test_commit_state_non_blocking():
    """Consumers read the active cache while a sweep stages the next one;
    the flip is atomic at a step boundary."""
    cache = create_cache(4, (8,))
    cs = CommitState.create(cache)
    new = cache._replace(ids=cache.ids.at[0].set(42))
    staged = cs.stage(new)
    assert int(staged.active.ids[0]) == -1  # still the old view
    committed = staged.commit()
    assert int(committed.active.ids[0]) == 42


def test_publish_and_fill_moves_payloads():
    k, n, cap = 8, 2, 4
    owners = np.zeros((k, n), bool)
    owners[:, 0] = True  # home
    owners[[1, 3], 1] = True  # node 1 qualifies for keys 1 and 3
    plan = PlacementPlan(
        owners=jnp.asarray(owners),
        to_add=jnp.asarray(owners & ~np.eye(1, n, 0, dtype=bool)[[0] * k]),
        to_drop=jnp.zeros((k, n), bool),
        expired=jnp.zeros((k,), bool),
    )
    home = jnp.zeros((k,), jnp.int32)
    moves = plan_moves(plan, home, cap, max_moves=4, object_bytes=16.0)
    values = jnp.arange(k * 8, dtype=jnp.float32).reshape(k, 8)
    cache = create_cache(cap, (8,))
    filled = publish_and_fill(
        cache, moves, values, jnp.arange(k, dtype=jnp.int32), rank=1
    )
    ids = set(int(i) for i in filled.ids if int(i) >= 0)
    assert ids == {1, 3}
    slot = int(jnp.argmax(filled.ids == 1))
    np.testing.assert_allclose(np.asarray(filled.data[slot]), np.asarray(values[1]))
