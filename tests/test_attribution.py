"""Latency-provenance guard rails (cost attribution + flight recorder).

The PR-9 acceptance criteria:

  1. Reconstruction invariant at the oracle: ``chunk_components_ref``'s
     rows sum to ``chunk_latency_ref`` plus the engine-supplied surcharges,
     allclose under f32 (the decomposition re-associates the write path's
     grouping) — fuzzed over random RTT matrices when hypothesis is
     available.
  2. The same invariant end-to-end, across {scan, reference} x
     {jax, pallas} x service/routing on/off: the folded per-chunk component
     sums reconstruct the engine's total latency, and the per-request
     reference oracle (``SimTrace.raw_components``) sums row-wise to
     ``raw_latency_ms``. Attribution histograms are pure-jnp regardless of
     the replay backend, so they are bit-identical across engines AND
     backends, not merely close.
  3. Attribution/flight OFF is a bit-exact structural no-op: same
     ``SimResult`` and telemetry aggregates as the pre-attribution engine,
     for both spellings (absent sub-config, ``enabled=False`` sub-config).
     Attribution ON also never perturbs the aggregates — it only adds ys.
  4. Per-component quantiles read off the attribution histograms land
     within ONE relative bin width of ``np.percentile`` over the reference
     engine's raw per-request component arrays (paying requests only).
  5. 2-rank key-sharded runs assemble identical provenance: bit-exact
     component histogram counts and flight records, allclose f32 sums.
  6. The flight recorder agrees between engines, satisfies the per-record
     reconstruction invariant, and round-trips through the JSON-lines and
     Chrome trace-event exporters.
  7. The leaf-merge taxonomy is exhaustive: every ``TelemetryLeaves`` field
     declares its kind in ``LEAF_KINDS`` (so a new leaf cannot silently
     skip the shard fold or the batch merge), and each kind merges as
     documented (sum / mean / keep-row-0).
  8. The bench-trend dashboard's flatten/trend/gate logic on synthetic
     trajectories, plus a live-repo render smoke test.
"""

import importlib.util
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_replay.ref import (
    COMPONENTS,
    NUM_COMPONENTS,
    chunk_components_ref,
    chunk_latency_ref,
)
from repro.kvsim import (
    AttributionConfig,
    ClusterConfig,
    FlightRecorderConfig,
    RedynisPolicy,
    RoutingConfig,
    ServiceConfig,
    SimResult,
    StaticPolicy,
    TelemetryConfig,
    WorkloadConfig,
    chrome_trace_events,
    run_scenario,
    run_scenario_reference,
    wan5_cluster,
    wan5_workload,
    write_chrome_trace,
    write_jsonl,
)
from repro.kvsim.telemetry import (
    LEAF_KINDS,
    TelemetryLeaves,
    merge_leaves,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - CI installs hypothesis
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# 1. Oracle-level reconstruction: components sum to chunk_latency_ref.
# ---------------------------------------------------------------------------


def _random_case(seed, n, k, b):
    """Random replica map / chunk / RTT matrix (symmetric, zero diagonal)."""
    rng = np.random.default_rng(seed)
    hosts = rng.random((k, n)) < 0.4
    hosts[rng.integers(0, k), :] = False  # at least one orphan key
    keys = rng.integers(0, k, size=b).astype(np.int32)
    nodes = rng.integers(0, n, size=b).astype(np.int32)
    is_read = rng.random(b) < 0.7
    rtt = rng.uniform(1.0, 200.0, size=(n, n)).astype(np.float32)
    rtt = ((rtt + rtt.T) / 2).astype(np.float32)
    np.fill_diagonal(rtt, 0.0)
    return (
        jnp.asarray(hosts), jnp.asarray(keys), jnp.asarray(nodes),
        jnp.asarray(is_read), jnp.asarray(rtt),
    )


def check_components_reconstruct(seed, n, k, b, read_mode, with_extras):
    hosts, keys, nodes, is_read, rtt = _random_case(seed, n, k, b)
    scalars = dict(
        service_ms=0.5, master=int(seed) % n,
        xfer_read_ms=2.0, xfer_write_ms=3.0, read_mode=read_mode,
    )
    lat, _ = chunk_latency_ref(hosts, keys, nodes, is_read, rtt, **scalars)
    extras = {}
    total = np.asarray(lat, np.float64)
    if with_extras:
        rng = np.random.default_rng(seed + 1)
        for name in ("contention_ms", "routing_detour_ms",
                     "directory_fetch_ms"):
            e = (rng.uniform(0.0, 5.0, size=b)
                 * (rng.random(b) < 0.5)).astype(np.float32)
            extras[name] = jnp.asarray(e)
            total = total + e
    comps = np.asarray(
        chunk_components_ref(hosts, keys, nodes, is_read, rtt,
                             **scalars, **extras),
        np.float64,
    )
    assert comps.shape == (NUM_COMPONENTS, b)
    assert (comps >= 0.0).all()
    np.testing.assert_allclose(
        comps.sum(axis=0), total, rtol=1e-6, atol=1e-5,
        err_msg=f"read_mode={read_mode} extras={with_extras}",
    )
    # Reads never pay write legs and vice versa.
    rd = np.asarray(is_read)
    for row in ("write_relay", "write_broadcast"):
        assert (comps[COMPONENTS.index(row)][rd] == 0.0).all()
    assert (comps[COMPONENTS.index("read_rtt")][~rd] == 0.0).all()


@pytest.mark.parametrize("read_mode", ["map", "no_local", "ideal"])
@pytest.mark.parametrize("with_extras", [False, True])
def test_oracle_components_reconstruct_total(read_mode, with_extras):
    for seed in range(4):
        check_components_reconstruct(
            seed, n=5, k=40, b=64, read_mode=read_mode,
            with_extras=with_extras,
        )


if HAVE_HYPOTHESIS:

    @settings(max_examples=30, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n=st.integers(2, 8),
        b=st.integers(1, 96),
        read_mode=st.sampled_from(["map", "no_local"]),
    )
    def test_oracle_reconstruction_fuzz_rtt(seed, n, b, read_mode):
        """Hypothesis fuzz over topology size, chunk size and RTT matrices:
        the additive decomposition must hold for ANY geometry, not just the
        wan presets."""
        check_components_reconstruct(
            seed, n=n, k=16, b=b, read_mode=read_mode, with_extras=True,
        )


# ---------------------------------------------------------------------------
# 2. End-to-end reconstruction across engines x backends x surcharges.
# ---------------------------------------------------------------------------

ATTR_TELEMETRY = TelemetryConfig(
    attribution=AttributionConfig(),
    flight=FlightRecorderConfig(),
)


def _wan5_case(with_service, with_routing, num_requests=3_000):
    wl = wan5_workload(num_requests=num_requests, num_keys=200, affinity=0.8)
    cl = wan5_cluster()
    if with_service:
        cl = cl._replace(
            service=ServiceConfig(serve_bytes_per_ms=128.0,
                                  capacity_factor=2.0)
        )
    if with_routing:
        # Lagged publishes (detours) AND a bounded router cache (misses →
        # home fetches), so both routing component rows are live.
        cl = cl._replace(
            routing=RoutingConfig(publish_lag_chunks=2, cache_entries=64)
        )
    return wl, cl


@pytest.mark.parametrize("engine", ["scan", "reference"])
@pytest.mark.parametrize("backend", ["jax", "pallas"])
@pytest.mark.parametrize("surcharges", [False, True])
def test_component_sum_reconstructs_total(engine, backend, surcharges):
    """The folded per-chunk component sums must reconstruct the engine's
    total latency — with and without the contention/routing surcharge
    models (whose waits land in dedicated component rows)."""
    run = run_scenario if engine == "scan" else run_scenario_reference
    wl, cl = _wan5_case(with_service=surcharges, with_routing=surcharges)
    pol = RedynisPolicy(h=0.2, backend=backend)
    result, trace = run(
        wl, cl, pol, seed=0, daemon_interval=500, telemetry=ATTR_TELEMETRY,
    )
    total_requests = float(trace.requests.sum())
    assert total_requests == wl.num_requests
    comp_total = float(trace.attr_chunk_sum_ms.sum())
    np.testing.assert_allclose(
        comp_total / total_requests, result.mean_latency_ms, rtol=1e-5,
    )
    attr = trace.attribution
    np.testing.assert_allclose(
        sum(s["mean_ms"] for s in attr.values()),
        result.mean_latency_ms, rtol=1e-5,
    )
    if surcharges:
        # The surcharge rows are live (the whole point of the grid).
        assert attr["contention_wait"]["count"] > 0
        assert attr["routing_detour"]["count"] > 0
        assert attr["directory_fetch"]["count"] > 0
    else:
        for row in ("contention_wait", "routing_detour", "directory_fetch"):
            assert attr[row]["count"] == 0.0
    # Histogram conservation: each component row counts exactly its paying
    # requests, never more than the run's request count.
    per_comp = trace.attr_hist_group.sum(axis=(1, 2))
    assert (per_comp <= total_requests + 1e-6).all()
    assert per_comp[COMPONENTS.index("service")] == total_requests


def test_reference_raw_components_sum_to_raw_latency():
    """The per-request oracle: the reference engine's raw component matrix
    sums row-wise to its raw latency vector."""
    wl, cl = _wan5_case(with_service=True, with_routing=True)
    _, trace = run_scenario_reference(
        wl, cl, RedynisPolicy(h=0.2), seed=1, daemon_interval=500,
        telemetry=ATTR_TELEMETRY,
    )
    raw = trace.raw_latency_ms
    comps = trace.raw_components
    assert comps.shape == (NUM_COMPONENTS, raw.shape[0])
    np.testing.assert_allclose(
        comps.sum(axis=0), raw, rtol=1e-5, atol=1e-4,
    )


def test_attribution_bitexact_across_engines_and_backends():
    """Attribution histograms are folded by the pure-jnp helper regardless
    of replay backend, so counts are bit-identical — across the jax and
    pallas backends AND across the scan and reference engines."""
    wl, cl = _wan5_case(with_service=True, with_routing=True)
    kw = dict(seed=2, daemon_interval=500, telemetry=ATTR_TELEMETRY)
    runs = {
        "scan/jax": run_scenario(
            wl, cl, RedynisPolicy(h=0.2, backend="jax"), **kw),
        "scan/pallas": run_scenario(
            wl, cl, RedynisPolicy(h=0.2, backend="pallas"), **kw),
        "ref/jax": run_scenario_reference(
            wl, cl, RedynisPolicy(h=0.2, backend="jax"), **kw),
    }
    base = runs["scan/jax"][1]
    for label, (_, trace) in runs.items():
        np.testing.assert_array_equal(
            base.attr_hist_group, trace.attr_hist_group, err_msg=label,
        )
        np.testing.assert_allclose(
            base.attr_chunk_sum_ms, trace.attr_chunk_sum_ms,
            rtol=1e-6, err_msg=label,
        )
        np.testing.assert_array_equal(
            base.flight_meta, trace.flight_meta, err_msg=label,
        )
        np.testing.assert_allclose(
            base.flight_vals, trace.flight_vals, rtol=1e-6, atol=1e-5,
            err_msg=label,
        )


def test_static_fast_path_matches_reference():
    """The static whole-trace fast path prices attribution over the padded
    trace in one shot — it must agree with the chunked reference engine."""
    wl, cl = _wan5_case(with_service=True, with_routing=False)
    kw = dict(seed=4, daemon_interval=500, telemetry=ATTR_TELEMETRY)
    pol = StaticPolicy(mode="local")
    _, fast = run_scenario(wl, cl, pol, **kw)
    _, ref = run_scenario_reference(wl, cl, pol, **kw)
    np.testing.assert_array_equal(fast.attr_hist_group, ref.attr_hist_group)
    np.testing.assert_allclose(
        fast.attr_chunk_sum_ms, ref.attr_chunk_sum_ms, rtol=1e-6,
    )
    np.testing.assert_array_equal(fast.flight_meta, ref.flight_meta)
    np.testing.assert_allclose(
        fast.flight_vals, ref.flight_vals, rtol=1e-6, atol=1e-5,
    )


# ---------------------------------------------------------------------------
# 3. Off = bit-exact structural no-op; on never perturbs the aggregates.
# ---------------------------------------------------------------------------


def assert_results_equal(a: SimResult, b: SimResult, ctx: str):
    for field, x, y in zip(SimResult._fields, a, b):
        np.testing.assert_array_equal(
            np.asarray(x), np.asarray(y), err_msg=f"{ctx} {field}"
        )


@pytest.mark.parametrize("engine", ["scan", "reference"])
def test_attribution_off_is_bitexact(engine):
    """PR-8 goldens stay valid: absent and ``enabled=False`` sub-configs
    are the same program as plain telemetry, and turning attribution ON
    must not move a single aggregate bit either (it only adds ys)."""
    run = run_scenario if engine == "scan" else run_scenario_reference
    wl = WorkloadConfig(
        num_requests=2_000, num_keys=150, skewed=True, affinity=0.8
    )
    cl = ClusterConfig(capacity_bytes=24 * 1024.0)
    pol = RedynisPolicy(expiry=4, decay=0.5)
    kw = dict(seed=3, daemon_interval=500)
    base, base_trace = run(wl, cl, pol, telemetry=TelemetryConfig(), **kw)
    disabled, disabled_trace = run(
        wl, cl, pol,
        telemetry=TelemetryConfig(
            attribution=AttributionConfig(enabled=False),
            flight=FlightRecorderConfig(enabled=False),
        ),
        **kw,
    )
    assert_results_equal(base, disabled, f"{engine} disabled-subconfig")
    np.testing.assert_array_equal(
        base_trace.hist_group, disabled_trace.hist_group
    )
    assert disabled_trace.attr_hist_group is None
    assert disabled_trace.flight_meta is None
    on, on_trace = run(wl, cl, pol, telemetry=ATTR_TELEMETRY, **kw)
    assert_results_equal(base, on, f"{engine} attribution-on")
    np.testing.assert_array_equal(base_trace.hist_group, on_trace.hist_group)
    assert on_trace.attr_hist_group is not None


def test_attribution_views_raise_when_off():
    wl, cl = _wan5_case(False, False, num_requests=1_000)
    _, trace = run_scenario(
        wl, cl, RedynisPolicy(), seed=0, daemon_interval=500,
        telemetry=TelemetryConfig(),
    )
    with pytest.raises(ValueError, match="AttributionConfig"):
        trace.attribution
    with pytest.raises(ValueError, match="FlightRecorderConfig"):
        trace.flight_records()


def test_config_validation():
    from repro.kvsim.telemetry import normalize_telemetry

    with pytest.raises(ValueError, match="num_bins"):
        normalize_telemetry(
            TelemetryConfig(attribution=AttributionConfig(num_bins=2))
        )
    with pytest.raises(ValueError, match="samples_per_chunk"):
        FlightRecorderConfig(samples_per_chunk=0).validate()
    with pytest.raises(ValueError, match="sampling mode"):
        FlightRecorderConfig(mode="systematic").validate()
    # Disabled sub-configs collapse to None (the bit-exact off spelling) —
    # invalid-but-disabled must not raise.
    cfg = normalize_telemetry(TelemetryConfig(
        attribution=AttributionConfig(enabled=False, num_bins=2),
        flight=FlightRecorderConfig(enabled=False, samples_per_chunk=0),
    ))
    assert cfg.attribution is None and cfg.flight is None


# ---------------------------------------------------------------------------
# 4. Per-component quantiles vs the reference engine's raw oracle.
# ---------------------------------------------------------------------------


def test_component_quantiles_vs_raw_percentiles():
    """Interpolated per-component quantiles must land within one relative
    bin width of np.percentile over the PAYING requests' raw component
    values (the ``component > 0`` weighting the histograms fold)."""
    wl, cl = _wan5_case(with_service=True, with_routing=True,
                        num_requests=6_000)
    _, trace = run_scenario_reference(
        wl, cl, RedynisPolicy(h=0.2), seed=5, daemon_interval=500,
        telemetry=ATTR_TELEMETRY,
    )
    rho = float(trace.attr_edges[2] / trace.attr_edges[1])
    checked = 0
    for i, name in enumerate(COMPONENTS):
        paying = trace.raw_components[i]
        paying = paying[paying > 0.0]
        if paying.size < 200:  # too thin for a stable percentile
            continue
        checked += 1
        assert trace.attribution[name]["count"] == paying.size
        for q in (0.5, 0.9, 0.99):
            interp = trace.component_quantile(name, q)
            exact = float(np.percentile(paying, 100 * q))
            assert exact / rho <= interp <= exact * rho * (1 + 1e-9), (
                f"{name} q={q}: interpolated {interp} vs exact {exact} "
                f"(allowed factor {rho})"
            )
    assert checked >= 4  # service, read_rtt, write legs at minimum


# ---------------------------------------------------------------------------
# 5. 2-rank sharded provenance assembly.
# ---------------------------------------------------------------------------

SHARDED_ATTRIBUTION_SCRIPT = r"""
import numpy as np
from repro.kvsim import (run_scenario, wan5_workload, wan5_cluster,
                         RedynisPolicy, TelemetryConfig, AttributionConfig,
                         FlightRecorderConfig, ServiceConfig, RoutingConfig)

wl = wan5_workload(num_requests=12000, num_keys=500)
cl = wan5_cluster()._replace(
    service=ServiceConfig(enabled=True),
    routing=RoutingConfig(publish_lag_chunks=2),
)
for mode in ('stride', 'reservoir'):
    tel = TelemetryConfig(attribution=AttributionConfig(),
                          flight=FlightRecorderConfig(mode=mode))
    kw = dict(seed=3, daemon_interval=1000, telemetry=tel)
    r1, t1 = run_scenario(wl, cl, RedynisPolicy(), **kw)
    r2, t2 = run_scenario(wl, cl, RedynisPolicy(), num_shards=2, **kw)
    # Integer-count surfaces: bit-exact under psum.
    np.testing.assert_array_equal(t1.attr_hist_group, t2.attr_hist_group)
    np.testing.assert_array_equal(t1.flight_meta, t2.flight_meta)
    # f32 sums re-associate across shards; flight values are assembled by
    # a one-owner masked psum, so they stay essentially exact.
    np.testing.assert_allclose(t1.attr_chunk_sum_ms, t2.attr_chunk_sum_ms,
                               rtol=1e-4)
    np.testing.assert_allclose(t1.flight_vals, t2.flight_vals,
                               rtol=1e-5, atol=1e-4)
    rec1, rec2 = t1.flight_records(), t2.flight_records()
    assert len(rec1) == len(rec2) > 0
    assert [r['key'] for r in rec1] == [r['key'] for r in rec2]
    print('OK', mode)
print('SHARDED_ATTRIBUTION_OK')
"""


def test_sharded_attribution_two_ranks(run_multi_rank):
    out = run_multi_rank(SHARDED_ATTRIBUTION_SCRIPT, num_devices=2,
                         timeout=600)
    assert "SHARDED_ATTRIBUTION_OK" in out


# ---------------------------------------------------------------------------
# 6. Flight recorder semantics + exporters.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", ["stride", "reservoir"])
def test_flight_records_match_across_engines(mode):
    """Both sampling modes are deterministic functions of the chunk index,
    so the two engines must sample the SAME requests and report the same
    records."""
    wl, cl = _wan5_case(with_service=True, with_routing=True,
                        num_requests=2_500)
    tel = TelemetryConfig(
        attribution=AttributionConfig(),
        flight=FlightRecorderConfig(samples_per_chunk=4, mode=mode),
    )
    kw = dict(seed=6, daemon_interval=500, telemetry=tel)
    _, scan = run_scenario(wl, cl, RedynisPolicy(h=0.2), **kw)
    _, ref = run_scenario_reference(wl, cl, RedynisPolicy(h=0.2), **kw)
    a, b = scan.flight_records(), ref.flight_records()
    assert len(a) == len(b) > 0
    for ra, rb in zip(a, b):
        for field in ("pos", "chunk", "key", "node", "router", "is_read"):
            assert ra[field] == rb[field], (mode, field, ra, rb)
        assert ra["total_ms"] == pytest.approx(rb["total_ms"], rel=1e-6)
    # Per-record reconstruction invariant + routing tier is live so some
    # sampled requests carry a router id.
    for r in a:
        assert r["total_ms"] == pytest.approx(
            sum(r["components"].values()), rel=1e-5, abs=1e-4,
        )
        assert 0 <= r["node"] < cl.num_nodes
    assert all(r["router"] >= 0 for r in a)


def test_flight_router_is_minus_one_without_routing():
    wl, cl = _wan5_case(with_service=False, with_routing=False,
                        num_requests=1_000)
    _, trace = run_scenario(
        wl, cl, RedynisPolicy(), seed=0, daemon_interval=500,
        telemetry=ATTR_TELEMETRY,
    )
    records = trace.flight_records()
    assert records and all(r["router"] == -1 for r in records)


def test_flight_export_roundtrip(tmp_path):
    wl, cl = _wan5_case(with_service=True, with_routing=True,
                        num_requests=2_000)
    _, trace = run_scenario(
        wl, cl, RedynisPolicy(h=0.2), seed=7, daemon_interval=500,
        telemetry=ATTR_TELEMETRY,
    )
    records = trace.flight_records()
    jl = tmp_path / "flight.jsonl"
    assert write_jsonl(records, str(jl)) == len(records)
    back = [json.loads(line) for line in jl.read_text().splitlines()]
    assert back == json.loads(json.dumps(records))

    doc = chrome_trace_events(records)
    events = doc["traceEvents"]
    spans = [e for e in events if e["ph"] == "X"]
    assert len(spans) == len(records)
    assert doc["displayTimeUnit"] == "ms"
    for e, r in zip(spans, records):
        assert e["pid"] == r["node"]
        assert e["dur"] == pytest.approx(r["total_ms"] * 1000.0)
        assert set(COMPONENTS) <= set(e["args"])  # breakdown rides in args
    # Process-name metadata so Perfetto labels the per-node tracks.
    assert any(e.get("ph") == "M" for e in events)
    ct = tmp_path / "flight.trace.json"
    assert write_chrome_trace(records, str(ct)) == len(records)
    assert json.loads(ct.read_text())["traceEvents"]


# ---------------------------------------------------------------------------
# 7. Exhaustive leaf-merge taxonomy (the documented merge contract).
# ---------------------------------------------------------------------------


def test_leaf_taxonomy_is_exhaustive():
    """Every leaf must declare a merge kind — adding a TelemetryLeaves
    field without classifying it under LEAF_KINDS is a test failure, not a
    silently-dropped shard fold."""
    assert set(LEAF_KINDS) == set(TelemetryLeaves._fields)
    assert set(LEAF_KINDS.values()) == {"sum", "mean", "records"}


def test_merge_leaves_honours_kind_contract():
    """Synthetic 2-row batch: "sum" leaves add, "mean" leaves average,
    "records" leaves keep row 0, None leaves pass through."""
    rows = {
        name: np.array([[1.0], [3.0]]) for name in TelemetryLeaves._fields
    }
    merged = merge_leaves(TelemetryLeaves(**rows))
    for name, kind in LEAF_KINDS.items():
        got = float(np.asarray(getattr(merged, name)).squeeze())
        want = {"sum": 4.0, "mean": 2.0, "records": 1.0}[kind]
        assert got == want, (name, kind, got)
    # Disabled provenance leaves stay None through the merge.
    rows.update(attr_hist=None, attr_sum=None,
                flight_meta=None, flight_vals=None)
    merged = merge_leaves(TelemetryLeaves(**rows))
    assert merged.attr_hist is None and merged.flight_vals is None


# ---------------------------------------------------------------------------
# 8. Bench-trend dashboard logic (synthetic trajectories + live smoke).
# ---------------------------------------------------------------------------

_BT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "benchmarks", "bench_trend.py"
)


@pytest.fixture(scope="module")
def bench_trend():
    spec = importlib.util.spec_from_file_location("bench_trend", _BT_PATH)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_flatten_metrics_shapes(bench_trend):
    flat = bench_trend.flatten_metrics({
        "metrics": {
            "wall_time_s": 2.5,
            "checks": {"a_ok": True, "b_ok": False},
            "label": "dropped-string",
            "rows": [
                {"policy": "x", "mean_ms": 10.0, "passed": True},
                {"policy": "y", "mean_ms": 30.0, "passed": False},
            ],
        }
    })
    assert flat["wall_time_s"] == 2.5
    assert flat["checks.a_ok"] == 1.0 and flat["checks.b_ok"] == 0.0
    assert "label" not in flat
    assert flat["rows.len"] == 2.0
    assert flat["rows.mean.mean_ms"] == 20.0
    assert flat["rows.mean.passed"] == 0.5
    assert "rows.mean.policy" not in flat


def _points(bench_trend, *metric_dicts):
    return [
        bench_trend._point(f"rev{i}", {"bench": "attribution",
                                       "metrics": m})
        for i, m in enumerate(metric_dicts)
    ]


def test_trend_rows_flags_check_regression(bench_trend):
    """A checks.* boolean going truthy -> falsy between the last two points
    is a gated regression; a timing metric doubling is not."""
    pts = _points(
        bench_trend,
        {"checks": {"sum_ok": True}, "wall_time_s": 1.0},
        {"checks": {"sum_ok": True}, "wall_time_s": 1.5},
        {"checks": {"sum_ok": False}, "wall_time_s": 3.0},
    )
    rows = {r["metric"]: r for r in bench_trend.trend_rows(pts)}
    assert rows["checks.sum_ok"]["gated"]
    assert rows["checks.sum_ok"]["regressed"]
    assert not rows["wall_time_s"]["gated"]
    assert not rows["wall_time_s"]["regressed"]
    assert rows["wall_time_s"]["delta_pct"] == pytest.approx(100.0)
    # Recovery (falsy -> truthy) and steady-state truthy are not flagged.
    for series in ([False, True], [True, True]):
        pts = _points(
            bench_trend, *[{"checks": {"sum_ok": v}} for v in series]
        )
        assert not bench_trend.trend_rows(pts)[0]["regressed"]


def test_trend_rows_non_increase_gate(bench_trend):
    pts = _points(bench_trend, {"regressions": 0}, {"regressions": 2})
    (row,) = bench_trend.trend_rows(pts)
    assert row["gated"] and row["regressed"]
    pts = _points(bench_trend, {"regressions": 2}, {"regressions": 1})
    assert not bench_trend.trend_rows(pts)[0]["regressed"]


def test_trend_rows_single_point_never_regresses(bench_trend):
    pts = _points(bench_trend, {"checks": {"ok": False}, "x": 5.0})
    for row in bench_trend.trend_rows(pts):
        assert not row["regressed"]
        assert row["prev"] is None and row["delta_pct"] is None


def test_render_markdown_live_repo_smoke(bench_trend):
    """Against the real checked-in baselines: renders one table block per
    BENCH file with the rev span header, and the committed trajectory has
    no gated regressions (the CI gate this repo ships under)."""
    if not bench_trend.baseline_files():
        pytest.skip("no committed baselines")
    text, regressions = bench_trend.render_markdown()
    assert "| metric | first | prev | latest |" in text
    assert "**attribution**" in text or "**engine_throughput**" in text
    assert regressions == 0, text
