"""Policy registry / CLI parsing / validation / legacy-removal ergonomics."""

import numpy as np
import pytest

from repro.core.policy import (
    POLICIES,
    CostGreedyPolicy,
    DecayLFUPolicy,
    RedynisPolicy,
    SizeAwarePolicy,
    StaticPolicy,
    TopKPolicy,
    describe_policy,
    make_policy,
    parse_policy,
    policy_repr,
    split_policy,
)
from repro.kvsim import (
    ClusterConfig,
    Scenario,
    WorkloadConfig,
    run_scenario,
)


def test_registry_contains_all_builtins():
    assert set(POLICIES) >= {
        "redynis", "static", "topk", "costgreedy", "decaylfu", "sizeaware"
    }
    for name, cls in POLICIES.items():
        pol = cls().resolve(4)
        pol.validate(4)
        assert describe_policy(pol).startswith(name)


def test_parse_policy_specs():
    assert parse_policy("redynis") == RedynisPolicy()
    assert parse_policy("redynis:h=0.2,decay=0.9") == RedynisPolicy(h=0.2, decay=0.9)
    assert parse_policy("topk:k=50") == TopKPolicy(k=50)
    assert parse_policy("static:mode=remote") == StaticPolicy(mode="remote")
    assert parse_policy("decaylfu:alpha=0.3,period=2") == DecayLFUPolicy(
        alpha=0.3, period=2
    )
    assert parse_policy("sizeaware:size_threshold_bytes=2048,large_fanout=3") == (
        SizeAwarePolicy(size_threshold_bytes=2048, large_fanout=3)
    )
    # Bare scenario-style aliases.
    assert parse_policy("local") == StaticPolicy(mode="local")
    assert parse_policy("remote") == StaticPolicy(mode="remote")
    assert parse_policy("replicated") == StaticPolicy(mode="replicated")
    with pytest.raises(ValueError, match="unknown policy"):
        parse_policy("nope")
    with pytest.raises(ValueError, match="expected k=v"):
        parse_policy("redynis:h")
    with pytest.raises(ValueError, match="unknown policy"):
        make_policy("bogus")


def test_policies_are_distinct_by_class():
    """Equal field tuples across families must NOT compare equal (they are
    jit statics and grouping keys)."""
    a = TopKPolicy(k=1.0, decay=1.0, period=1)
    b = CostGreedyPolicy(min_saved_ms_per_kib=1.0, decay=1.0, period=1)
    assert tuple(a) == tuple(b)  # the trap this guards against
    assert a != b
    assert hash(a) != hash(b)
    assert a == TopKPolicy(k=1.0)
    sa, _ = split_policy(a)
    sb, _ = split_policy(b)
    assert sa != sb and len({sa, sb}) == 2


def test_validation_errors():
    with pytest.raises(ValueError, match="ownership coefficient"):
        RedynisPolicy(h=0.9).validate(3)
    with pytest.raises(ValueError, match="decay"):
        RedynisPolicy(decay=0.0).resolve(3).validate(3)
    with pytest.raises(ValueError, match="backend"):
        RedynisPolicy(backend="cuda").resolve(3).validate(3)
    with pytest.raises(ValueError, match="expiry"):
        RedynisPolicy(expiry=-1).resolve(3).validate(3)
    with pytest.raises(ValueError, match="mode"):
        StaticPolicy(mode="weird").validate(3)
    with pytest.raises(ValueError, match="alpha"):
        DecayLFUPolicy(alpha=1.5).resolve(3).validate(3)
    with pytest.raises(ValueError, match="non-negative"):
        TopKPolicy(k=-3).validate(3)
    with pytest.raises(ValueError, match="period"):
        TopKPolicy(period=0).validate(3)


def test_split_policy_round_trip():
    pol = RedynisPolicy(h=0.2, expiry=5, decay=0.7, period=3, backend="jax")
    static, params = split_policy(pol)
    assert params == {"h": 0.2, "decay": 0.7}
    assert static.expiry == 5 and static.period == 3
    # Same family, different knobs -> SAME static key (shared jit cache).
    static2, params2 = split_policy(RedynisPolicy(h=0.1, expiry=5, period=3))
    assert static == static2
    assert params2["h"] == 0.1


def test_describe_and_repr_show_non_defaults_only():
    assert describe_policy(RedynisPolicy()) == "redynis"
    assert describe_policy(RedynisPolicy(h=0.2)) == "redynis(h=0.2)"
    assert policy_repr(RedynisPolicy(h=0.2, decay=0.5)) == (
        "RedynisPolicy(h=0.2, decay=0.5)"
    )
    assert policy_repr(StaticPolicy(mode="remote")) == "StaticPolicy(mode='remote')"
    # mode is ALWAYS labelled, so the 'local' baseline is never ambiguous.
    assert describe_policy(StaticPolicy()) == "static(mode='local')"
    assert policy_repr(StaticPolicy()) == "StaticPolicy(mode='local')"


# ---------------------------------------------------------------------------
# Legacy-removal ergonomics (the deprecation window closed: the old enum /
# kwarg spellings now raise with the exact replacement to paste in).
# ---------------------------------------------------------------------------

_WL = WorkloadConfig(num_requests=500, num_keys=50)
_CL = ClusterConfig()


def test_legacy_scenario_raises_with_exact_replacement():
    with pytest.raises(ValueError, match="removed") as exc:
        run_scenario(_WL, _CL, Scenario.OPTIMIZED, seed=0)
    msg = str(exc.value)
    assert "policy=RedynisPolicy()" in msg
    assert "run_scenario" in msg


def test_legacy_scenario_raises_names_static_mode():
    for scenario, repl in [
        (Scenario.LOCAL, "StaticPolicy(mode='local')"),
        (Scenario.REMOTE, "StaticPolicy(mode='remote')"),
        (Scenario.REPLICATED, "StaticPolicy(mode='replicated')"),
    ]:
        with pytest.raises(ValueError, match="removed") as exc:
            run_scenario(_WL, _CL, scenario, seed=0)
        assert repl in str(exc.value), scenario


def test_policy_is_required():
    with pytest.raises(ValueError, match="policy is required"):
        run_scenario(_WL, _CL)


def test_legacy_kwargs_removed_from_signature():
    """policy_from_scenario and the kwarg sprawl left with the shim: the
    import is gone and the runner signature no longer accepts them."""
    with pytest.raises(ImportError):
        from repro.kvsim.simulate import policy_from_scenario  # noqa: F401
    with pytest.raises(TypeError):
        run_scenario(_WL, _CL, RedynisPolicy(), ownership_coefficient=0.2)
    with pytest.raises(TypeError):
        run_scenario(_WL, _CL, scenario=Scenario.OPTIMIZED)


# ---------------------------------------------------------------------------
# Behavioural sanity of the new decision rules.
# ---------------------------------------------------------------------------


def test_topk_replicates_globally_hottest_keys():
    wl = WorkloadConfig(num_requests=4_000, num_keys=100, skewed=True, affinity=0.5)
    cl = ClusterConfig()
    few = run_scenario(wl, cl, TopKPolicy(k=5), seed=0)
    many = run_scenario(wl, cl, TopKPolicy(k=100), seed=0)
    assert many.hit_rate > few.hit_rate
    assert many.replication_moves > few.replication_moves


def test_costgreedy_threshold_gates_growth():
    wl = WorkloadConfig(num_requests=4_000, num_keys=100, skewed=True, affinity=0.6)
    cl = ClusterConfig()
    eager = run_scenario(wl, cl, CostGreedyPolicy(min_saved_ms_per_kib=10.0), seed=0)
    frugal = run_scenario(
        wl, cl, CostGreedyPolicy(min_saved_ms_per_kib=1e6), seed=0
    )
    assert eager.replication_moves > frugal.replication_moves
    assert eager.hit_rate >= frugal.hit_rate
    assert frugal.replication_moves == 0.0  # nothing ever clears the bar


def test_decaylfu_chases_moving_traffic():
    """On a diurnal workload a fast-decaying LFU must beat raw counters
    (the same reason the engine-level count decay exists)."""
    from repro.kvsim import diurnal_workload, wan5_cluster

    wl = diurnal_workload(num_requests=8_000, num_keys=200)
    cl = wan5_cluster()
    sticky = run_scenario(wl, cl, DecayLFUPolicy(alpha=1.0), seed=0)
    chasing = run_scenario(wl, cl, DecayLFUPolicy(alpha=0.2), seed=0)
    assert chasing.hit_rate >= sticky.hit_rate - 1e-6
    assert np.isfinite(chasing.throughput_ops_s)
