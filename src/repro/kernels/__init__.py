"""Pallas TPU kernels for the perf-critical layers, each with a jit'd
wrapper (ops.py) and a pure-jnp oracle (ref.py):

  ownership_sweep   — the paper's Algorithm 3 analysis loop over [K, N]
  chunk_replay      — the simulator's fused per-chunk request path
                      (gather → latency → hits → busy → histogram)
  latency_histogram — grouped log-bin latency histogram fold (telemetry)
  flash_attention   — causal/windowed GQA flash attention (train/prefill)
  flash_decode      — one-token attention over a long KV cache (decode)
  moe_router        — fused softmax/top-k routing + Redynis traffic histogram
  hot_gather        — two-level (VMEM-hot / HBM-cold) embedding lookup
"""

from repro.kernels.chunk_replay.ops import chunk_latency, chunk_replay
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_decode.ops import flash_decode
from repro.kernels.hot_gather.ops import hot_gather
from repro.kernels.latency_histogram.ops import latency_histogram
from repro.kernels.moe_router.ops import moe_router
from repro.kernels.ownership_sweep.ops import ownership_sweep

__all__ = [
    "chunk_latency",
    "chunk_replay",
    "flash_attention",
    "flash_decode",
    "hot_gather",
    "latency_histogram",
    "moe_router",
    "ownership_sweep",
]
