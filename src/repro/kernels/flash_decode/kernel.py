"""Pallas flash decode (TPU): one query token against a long KV cache.

Decode attention is memory-bound: the whole cache streams through once per
step and the compute is a [G, Dh] × [Dh, Bk] matvec-batch. Layout:
q [B*KH, G, Dh] (G = q heads per kv head), cache k/v [B*KH, T, Dh]. Grid
(B*KH, T/Bk) with the kv axis innermost — (acc, m, l) scratch carries the
online softmax across cache blocks, and each k/v block is read exactly
once from HBM (the roofline-optimal schedule for this op).

Cache-length masking comes from a [B] lengths vector delivered per grid row
as a (1,1) SMEM-style block — positions ≥ length contribute nothing, so
ring-buffer caches (sliding window) mask correctly too.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF, compiler_params, pl, vmem_scratch

__all__ = ["flash_decode_kernel", "flash_decode_call"]

DEFAULT_BK = 512


def flash_decode_kernel(
    len_ref,  # [1] int32 — valid cache entries for this sequence
    q_ref,  # [G, Dh]
    k_ref,  # [Bk, Dh]
    v_ref,  # [Bk, Dh]
    o_ref,  # [G, Dh]
    acc_ref,  # VMEM [G, Dh] f32
    m_ref,  # VMEM [G, 1] f32
    l_ref,  # VMEM [G, 1] f32
    *,
    scale: float,
    bk: int,
    nk: int,
    g: int,
):
    kk = pl.program_id(1)

    @pl.when(kk == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    length = len_ref[0]
    k_pos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (g, bk), 1)
    ok = k_pos < length

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # [G, Bk]
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))[:, None]
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(kk == nk - 1)
    def finish():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode_call(
    q: jax.Array,  # [BKH, G, Dh]
    k: jax.Array,  # [BKH, T, Dh]
    v: jax.Array,
    lengths: jax.Array,  # [B] int32
    *,
    kv_heads: int,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    bkh, g, dh = q.shape
    t = k.shape[1]
    bk = min(bk, t)
    assert t % bk == 0, (t, bk)
    nk = t // bk
    kernel = functools.partial(
        flash_decode_kernel, scale=dh**-0.5, bk=bk, nk=nk, g=g
    )
    return pl.pallas_call(
        kernel,
        grid=(bkh, nk),
        in_specs=[
            pl.BlockSpec((1,), lambda i, kk: (i // kv_heads,)),
            pl.BlockSpec((None, g, dh), lambda i, kk: (i, 0, 0)),
            pl.BlockSpec((None, bk, dh), lambda i, kk: (i, kk, 0)),
            pl.BlockSpec((None, bk, dh), lambda i, kk: (i, kk, 0)),
        ],
        out_specs=pl.BlockSpec((None, g, dh), lambda i, kk: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bkh, g, dh), q.dtype),
        scratch_shapes=[
            vmem_scratch((g, dh), jnp.float32),
            vmem_scratch((g, 1), jnp.float32),
            vmem_scratch((g, 1), jnp.float32),
        ],
        compiler_params=compiler_params(("parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, q, k, v)
