"""jit'd wrapper for flash decode: model layout [B, H, Dh] + [B, T, KH, Dh]."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_default
from repro.kernels.flash_decode.kernel import flash_decode_call

__all__ = ["flash_decode"]


@partial(jax.jit, static_argnames=("bk", "interpret"))
def flash_decode(
    q: jax.Array,  # [B, H, Dh]
    k_cache: jax.Array,  # [B, T, KH, Dh]
    v_cache: jax.Array,
    lengths: jax.Array,  # [B] int32
    *,
    bk: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = interpret_default()
    b, h, dh = q.shape
    t, kh = k_cache.shape[1], k_cache.shape[2]
    g = h // kh
    qf = q.reshape(b, kh, g, dh).reshape(b * kh, g, dh)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * kh, t, dh)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * kh, t, dh)
    o = flash_decode_call(
        qf, kf, vf, lengths.astype(jnp.int32),
        kv_heads=kh, bk=bk, interpret=interpret,
    )
    return o.reshape(b, kh * g, dh)
