"""Pure-jnp oracle for flash decode (= models.attention.decode_attention)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30

__all__ = ["decode_ref"]


def decode_ref(
    q: jax.Array,  # [B, H, Dh]
    k_cache: jax.Array,  # [B, T, KH, Dh]
    v_cache: jax.Array,
    lengths: jax.Array,  # [B]
) -> jax.Array:
    kh = k_cache.shape[2]
    b, h, d = q.shape
    qg = q.reshape(b, kh, h // kh, d).astype(jnp.float32)
    s = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache.astype(jnp.float32)) * (d**-0.5)
    t = k_cache.shape[1]
    valid = jnp.arange(t)[None] < lengths[:, None]
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
