"""Pure-jnp oracle for the hot-row gather."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["hot_gather_ref"]


def hot_gather_ref(tokens: jax.Array, slot_map: jax.Array, hot_table: jax.Array):
    slots = slot_map[tokens]
    hit = slots >= 0
    rows = jnp.take(hot_table, jnp.maximum(slots, 0), axis=0)
    rows = jnp.where(hit[:, None], rows, 0).astype(hot_table.dtype)
    return rows, hit
