"""jit'd wrapper for the hot-row gather (pads T to the token tile).

The kernel is wrapped in a custom VJP: the backward pass is the transpose
scatter-add of the cotangent rows into the hit slots, so gradients flow
through the cache to the live embedding table (replica writes propagate to
the home copy — the paper's write-serialization concern, solved by autodiff).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_default
from repro.kernels.hot_gather.kernel import DEFAULT_TD, DEFAULT_TT, hot_gather_call

__all__ = ["hot_gather"]


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _hot_gather(tokens, slot_map, hot_table, tt: int, td: int, interpret: bool):
    t = tokens.shape[0]
    pad = (-t) % tt
    if pad:
        tokens = jnp.pad(tokens, (0, pad))
    rows, hit = hot_gather_call(
        tokens, slot_map, hot_table, tt=tt, td=td, interpret=interpret
    )
    return rows[:t], hit[:t].astype(bool)


def _fwd(tokens, slot_map, hot_table, tt, td, interpret):
    out = _hot_gather(tokens, slot_map, hot_table, tt, td, interpret)
    rows, hit = out
    slots = slot_map[tokens]
    return out, (slots, hit, hot_table)


def _bwd(tt, td, interpret, res, cts):
    slots, hit, hot_table = res
    g_rows, _ = cts  # hit is boolean — no cotangent
    r = hot_table.shape[0]
    dest = jnp.where(hit, slots, r)  # misses dropped
    g_table = (
        jnp.zeros(hot_table.shape, jnp.float32)
        .at[dest]
        .add(g_rows.astype(jnp.float32), mode="drop")
        .astype(hot_table.dtype)
    )
    return None, None, g_table


_hot_gather.defvjp(_fwd, _bwd)


@partial(jax.jit, static_argnames=("tt", "td", "interpret"))
def hot_gather(
    tokens: jax.Array,  # [T] int32
    slot_map: jax.Array,  # [V] int32 (-1 = cold)
    hot_table: jax.Array,  # [R, D]
    *,
    tt: int = DEFAULT_TT,
    td: int = DEFAULT_TD,
    interpret: bool | None = None,
):
    """Returns (rows [T, D] — zeros on miss, hit [T] bool)."""
    if interpret is None:
        interpret = interpret_default()
    tt = min(tt, tokens.shape[0])
    return _hot_gather(tokens, slot_map, hot_table, tt, td, interpret)
