"""Pallas hot-row gather (TPU): the Redynis replica cache as a VMEM table.

The paper brings values "closer to the frequent source of requests". On a
TPU chip the request source is the compute unit and the distance ladder is
VREG ⊂ VMEM ⊂ HBM ⊂ remote-chip-over-ICI. This kernel implements the first
hop of a two-level embedding lookup:

  slot_map [V] (int32, ~1 MB even at V = 256k) and the hot table's column
  tile [R, TD] are pinned in VMEM; each token's row is served from VMEM
  when its slot is populated (hit), and flagged as a miss otherwise. The
  cold/miss path (sharded HBM table + psum) runs outside, on the miss set.

Grid (T/TT, D/TD): token tiles × embedding-column tiles. The per-token row
fetch is a serial fori over the tile (a gather has no MXU shape), but each
fetch is a [TD]-wide VMEM read — the VPU load is the only cost, which is
the point: hot traffic never touches HBM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import compiler_params, pl

__all__ = ["hot_gather_kernel", "hot_gather_call"]

DEFAULT_TT = 256
DEFAULT_TD = 512


def hot_gather_kernel(
    tokens_ref,  # [TT, 1] i32
    slot_map_ref,  # [V, 1] i32 — vocab row -> hot slot (-1 = cold)
    table_ref,  # [R, TD] hot rows (this column tile)
    out_ref,  # [TT, TD]
    hit_ref,  # [TT, 1] i8
    *,
    tt: int,
):
    def body(i, _):
        tok = tokens_ref[i, 0]
        slot = slot_map_ref[tok, 0]
        safe = jnp.maximum(slot, 0)
        row = table_ref[pl.dslice(safe, 1), :]  # [1, TD] VMEM read
        hit = slot >= 0
        out_ref[pl.dslice(i, 1), :] = jnp.where(hit, row, jnp.zeros_like(row))
        hit_ref[pl.dslice(i, 1), :] = hit.astype(jnp.int8).reshape(1, 1)
        return 0

    jax.lax.fori_loop(0, tt, body, 0)


def hot_gather_call(
    tokens: jax.Array,  # [T] i32
    slot_map: jax.Array,  # [V] i32
    hot_table: jax.Array,  # [R, D]
    *,
    tt: int = DEFAULT_TT,
    td: int = DEFAULT_TD,
    interpret: bool = True,
):
    t = tokens.shape[0]
    v = slot_map.shape[0]
    r, d = hot_table.shape
    tt = min(tt, t)
    td = min(td, d)
    assert t % tt == 0 and d % td == 0, (t, tt, d, td)
    grid = (t // tt, d // td)
    kernel = functools.partial(hot_gather_kernel, tt=tt)
    out, hit = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((v, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((r, td), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tt, td), lambda i, j: (i, j)),
            pl.BlockSpec((tt, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, d), hot_table.dtype),
            jax.ShapeDtypeStruct((t, 1), jnp.int8),
        ],
        compiler_params=compiler_params(("parallel", "parallel")),
        interpret=interpret,
    )(tokens.astype(jnp.int32).reshape(t, 1), slot_map.astype(jnp.int32).reshape(v, 1), hot_table)
    return out, hit[:, 0]
