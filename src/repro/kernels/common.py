"""Shared Pallas utilities: TPU detection, compiler params, VMEM scratch.

Kernels in this package target TPU (Mosaic). On this CPU container they are
validated with ``interpret=True`` — the kernel body executes in Python with
identical semantics, so the allclose-vs-oracle tests exercise the real
tiling/masking logic. ``ops.py`` wrappers pick the mode automatically.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl

try:  # Mosaic-TPU extras (present in this jax build; guarded for portability)
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

__all__ = ["pl", "pltpu", "on_tpu", "interpret_default", "compiler_params", "vmem_scratch", "NEG_INF"]

NEG_INF = -1e30


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def interpret_default() -> bool:
    """Interpret mode unless running on a real TPU."""
    return not on_tpu()


def compiler_params(dimension_semantics: tuple[str, ...] | None = None):
    """Mosaic compiler params (dimension semantics drive pipelining)."""
    if pltpu is None or dimension_semantics is None:
        return None
    for cls_name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, cls_name, None)
        if cls is not None:
            try:
                return cls(dimension_semantics=dimension_semantics)
            except TypeError:  # pragma: no cover - signature drift
                continue
    return None  # pragma: no cover


def vmem_scratch(shape: tuple[int, ...], dtype=jnp.float32):
    """A VMEM scratch allocation (falls back to ANY in interpret mode)."""
    if pltpu is not None:
        return pltpu.VMEM(shape, dtype)
    return pl.BlockSpec(memory_space=None)  # pragma: no cover
