"""Pure-jnp oracle: matches repro.models.moe._top_k_gates + count fold."""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["router_ref"]


def router_ref(logits: jax.Array, k: int):
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    vals, idx = jax.lax.top_k(probs, k)
    gates = vals / jnp.maximum(jnp.sum(vals, -1, keepdims=True), 1e-9)
    e = logits.shape[-1]
    counts = jnp.zeros((e,), jnp.float32)
    for j in range(k):
        counts = counts + jnp.sum(jax.nn.one_hot(idx[..., j], e, dtype=jnp.float32), 0)
    return gates, idx.astype(jnp.int32), counts
