"""Pallas MoE router (TPU): fused softmax → top-k → traffic histogram.

This is the Redynis hook made free: the per-expert routing counts the
placement daemon feeds on are accumulated *inside* the routing kernel — the
paper's "web service logs usage heuristics per request" with zero extra HBM
passes (the logits tile is already in VMEM).

Grid over token tiles [TT, E]; top-k by k rounds of max+mask (k ≤ 8,
unrolled — E ≤ 64 so each round is one VPU reduction over lanes). Outputs:
renormalised gates [TT, K], expert ids [TT, K], and a per-tile histogram
[1, E] that the wrapper sums into the [E] traffic vector.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF, compiler_params, pl

__all__ = ["moe_router_kernel", "moe_router_call"]

DEFAULT_TT = 1024


def moe_router_kernel(
    logits_ref,  # [TT, E] f32
    gates_ref,  # out [TT, K] f32
    idx_ref,  # out [TT, K] i32
    counts_ref,  # out [1, E] f32 (per-tile partial histogram)
    *,
    k: int,
    e: int,
    tt: int,
):
    logits = logits_ref[...].astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    probs = p / jnp.sum(p, axis=-1, keepdims=True)

    iota_e = jax.lax.broadcasted_iota(jnp.int32, (tt, e), 1)
    masked = probs
    vals, ids, hist = [], [], jnp.zeros((1, e), jnp.float32)
    for _ in range(k):  # static unroll: k rounds of max + mask
        v = jnp.max(masked, axis=-1)
        a = jnp.argmax(masked, axis=-1).astype(jnp.int32)
        sel = iota_e == a[:, None]
        masked = jnp.where(sel, NEG_INF, masked)
        vals.append(v)
        ids.append(a)
        hist = hist + jnp.sum(sel.astype(jnp.float32), axis=0, keepdims=True)

    vals = jnp.stack(vals, axis=-1)  # [TT, K]
    gates_ref[...] = vals / jnp.maximum(jnp.sum(vals, -1, keepdims=True), 1e-9)
    idx_ref[...] = jnp.stack(ids, axis=-1)
    counts_ref[...] = hist


def moe_router_call(
    logits: jax.Array,  # [T, E] f32
    *,
    k: int,
    tt: int = DEFAULT_TT,
    interpret: bool = True,
):
    t, e = logits.shape
    tt = min(tt, t)
    assert t % tt == 0, (t, tt)
    nt = t // tt
    kernel = functools.partial(moe_router_kernel, k=k, e=e, tt=tt)
    return pl.pallas_call(
        kernel,
        grid=(nt,),
        in_specs=[pl.BlockSpec((tt, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tt, k), lambda i: (i, 0)),
            pl.BlockSpec((tt, k), lambda i: (i, 0)),
            pl.BlockSpec((1, e), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((t, k), jnp.float32),
            jax.ShapeDtypeStruct((t, k), jnp.int32),
            jax.ShapeDtypeStruct((nt, e), jnp.float32),
        ],
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(logits.astype(jnp.float32))
