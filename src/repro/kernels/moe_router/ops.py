"""jit'd wrapper: pads T to the tile size, sums the partial histograms."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF, interpret_default
from repro.kernels.moe_router.kernel import DEFAULT_TT, moe_router_call

__all__ = ["moe_router"]


@partial(jax.jit, static_argnames=("k", "tt", "interpret"))
def moe_router(
    logits: jax.Array,  # [T, E]
    *,
    k: int,
    tt: int = DEFAULT_TT,
    interpret: bool | None = None,
):
    """Returns (gates [T,K] f32, idx [T,K] i32, counts [E] f32)."""
    if interpret is None:
        interpret = interpret_default()
    t, e = logits.shape
    tt = min(tt, t)
    pad = (-t) % tt
    if pad:
        # Padding rows route deterministically to expert 0 with NEG_INF
        # logits elsewhere; their histogram contribution is subtracted.
        logits = jnp.pad(logits, ((0, pad), (0, 0)), constant_values=NEG_INF)
        logits = logits.at[t:, 0].set(0.0)
    gates, idx, hist = moe_router_call(logits, k=k, tt=tt, interpret=interpret)
    counts = jnp.sum(hist, axis=0)
    if pad:
        counts = counts.at[0].add(-float(pad))
        # Padded rows picked expert 0 first then arbitrary maxed-out slots;
        # remove their k-1 residual assignments too.
        resid = jnp.zeros_like(counts)
        for j in range(1, k):
            resid = resid + jnp.sum(
                jax.nn.one_hot(idx[t:, j], e, dtype=jnp.float32), axis=0
            )
        counts = counts - resid
    return gates[:t], idx[:t], counts
