"""Pallas flash attention (TPU): causal/windowed GQA, online softmax.

Layout: q [BH, S, Dh] (batch×q-heads fused), k/v [BKH, T, Dh] (batch×kv
heads). Grid (BH, S/Bq, T/Bk); the kv-block axis is the innermost
("arbitrary") dimension so the (acc, m, l) VMEM scratch carries across it.
GQA is pure indexing: the k/v BlockSpec index_map sends q-head ``h`` to kv
head ``h // group`` — kv blocks are never materialised per-q-head.

Block shapes are the VMEM working set: q (Bq, Dh) + k,v (Bk, Dh) + acc
(Bq, Dh) fp32 + scores (Bq, Bk) fp32. Bq = Bk = 128 and Dh ∈ {64..256}
keeps this « 1 MB — far under VMEM — while every matmul is 128-aligned for
the MXU. Causal/window masking is positional (block-level skips are a
compile-time grid choice, handled in ops.py by trimming the kv grid).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import NEG_INF, compiler_params, pl, vmem_scratch

__all__ = ["flash_attention_kernel", "flash_attention_call"]

DEFAULT_BQ = 128
DEFAULT_BK = 128


def flash_attention_kernel(
    q_ref,  # [Bq, Dh]
    k_ref,  # [Bk, Dh]
    v_ref,  # [Bk, Dh]
    o_ref,  # [Bq, Dh]
    acc_ref,  # VMEM scratch [Bq, Dh] f32
    m_ref,  # VMEM scratch [Bq, 1] f32
    l_ref,  # VMEM scratch [Bq, 1] f32
    *,
    scale: float,
    causal: bool,
    window: int,
    bq: int,
    bk: int,
    nk: int,
):
    j = pl.program_id(1)  # q block
    kk = pl.program_id(2)  # kv block

    @pl.when(kk == 0)
    def init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = j * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), bool)
    if causal:
        ok &= q_pos >= k_pos
    if window:
        ok &= (q_pos - k_pos) < window

    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]  # [Bq, 1]
    m_new = jnp.maximum(m_prev[:, 0], jnp.max(s, axis=-1))[:, None]
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    pv = jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = m_new

    @pl.when(kk == nk - 1)
    def finish():
        l = l_ref[...]
        o_ref[...] = (acc_ref[...] / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)


def flash_attention_call(
    q: jax.Array,  # [BH, S, Dh]
    k: jax.Array,  # [BKH, T, Dh]
    v: jax.Array,
    *,
    group: int,  # q heads per kv head
    heads: int,  # q heads per batch element
    kv_heads: int,
    causal: bool = True,
    window: int = 0,
    bq: int = DEFAULT_BQ,
    bk: int = DEFAULT_BK,
    interpret: bool = True,
) -> jax.Array:
    bh, s, dh = q.shape
    t = k.shape[1]
    bq = min(bq, s)
    bk = min(bk, t)
    assert s % bq == 0 and t % bk == 0, (s, t, bq, bk)
    nq, nk = s // bq, t // bk
    scale = dh**-0.5

    def kv_index(i, j, kk):
        b, h = i // heads, i % heads
        return (b * kv_heads + h // group, kk, 0)

    kernel = functools.partial(
        flash_attention_kernel,
        scale=scale, causal=causal, window=window, bq=bq, bk=bk, nk=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((None, bq, dh), lambda i, j, kk: (i, j, 0)),
            pl.BlockSpec((None, bk, dh), kv_index),
            pl.BlockSpec((None, bk, dh), kv_index),
        ],
        out_specs=pl.BlockSpec((None, bq, dh), lambda i, j, kk: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        scratch_shapes=[
            vmem_scratch((bq, dh), jnp.float32),
            vmem_scratch((bq, 1), jnp.float32),
            vmem_scratch((bq, 1), jnp.float32),
        ],
        compiler_params=compiler_params(("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
