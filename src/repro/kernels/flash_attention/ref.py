"""Pure-jnp oracle for the flash attention kernel (exact masked softmax)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30

__all__ = ["attention_ref"]


def attention_ref(
    q: jax.Array,  # [BH, S, Dh]
    k: jax.Array,  # [BKH, T, Dh]
    v: jax.Array,
    *,
    group: int,
    heads: int,
    kv_heads: int,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    bh, s, dh = q.shape
    b = bh // heads
    t = k.shape[1]
    qg = q.reshape(b, kv_heads, group, s, dh).astype(jnp.float32)
    kk = k.reshape(b, kv_heads, t, dh).astype(jnp.float32)
    vv = v.reshape(b, kv_heads, t, dh).astype(jnp.float32)
    scores = jnp.einsum("bkgsd,bktd->bkgst", qg, kk) * (dh**-0.5)
    q_pos = jnp.arange(s)[:, None]
    k_pos = jnp.arange(t)[None, :]
    ok = jnp.ones((s, t), bool)
    if causal:
        ok &= q_pos >= k_pos
    if window:
        ok &= (q_pos - k_pos) < window
    scores = jnp.where(ok, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bktd->bkgsd", p, vv)
    return out.reshape(bh, s, dh).astype(q.dtype)
