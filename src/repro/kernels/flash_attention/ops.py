"""jit'd public wrapper: model-layout in/out, kernel-layout inside.

``flash_attention(q, k, v)`` takes the model layout [B, S, H, Dh] /
[B, T, KH, Dh] and returns [B, S, H, Dh]. Causal runs trim the kv grid to
the blocks at or below the diagonal per q-block? No — the grid is shared
across q-blocks, so the trim is global: kv blocks beyond the last q
position contribute nothing and are dropped when T > S (cross/window
cases); intra-diagonal skipping stays positional masking (a Mosaic grid
with per-q-block kv extents is the recorded follow-up optimisation).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_default
from repro.kernels.flash_attention.kernel import flash_attention_call

__all__ = ["flash_attention"]


@partial(jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,  # [B, S, H, Dh]
    k: jax.Array,  # [B, T, KH, Dh]
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    if interpret is None:
        interpret = interpret_default()
    b, s, h, dh = q.shape
    t, kh = k.shape[1], k.shape[2]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, t, dh)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, t, dh)
    o = flash_attention_call(
        qf, kf, vf,
        group=h // kh, heads=h, kv_heads=kh,
        causal=causal, window=window, bq=bq, bk=bk, interpret=interpret,
    )
    return o.reshape(b, h, s, dh).transpose(0, 2, 1, 3)
