"""jit'd wrapper: R padded to the tile size transparently (weight-0 rows).

``lo`` / ``hi`` are *traced* arguments (the kernel reads them from scalar
input refs), so jitted telemetry pipelines can sweep bin ranges without
recompiling; ``num_groups`` / ``num_bins`` / ``tr`` / ``interpret`` stay
static. ``interpret=None`` auto-selects from the platform (interpret
off-TPU), matching the ``ownership_sweep`` convention.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.latency_histogram.kernel import (
    DEFAULT_TR,
    latency_histogram_call,
)

__all__ = ["latency_histogram"]


@partial(
    jax.jit, static_argnames=("num_groups", "num_bins", "tr", "interpret")
)
def latency_histogram(
    lat: jax.Array,  # [R] latency per request (ms)
    group: jax.Array,  # [R] int group id in [0, num_groups)
    weight: jax.Array,  # [R] weight per request (0 masks padding)
    *,
    num_groups: int,
    num_bins: int = 128,
    lo: jax.Array | float = 1.0,
    hi: jax.Array | float = 10_000.0,
    tr: int = DEFAULT_TR,
    interpret: bool | None = None,
):
    """Returns the ``[num_groups, num_bins]`` f32 grouped latency histogram."""
    r = lat.shape[0]
    tr = min(tr, r)
    pad = (-r) % tr
    if pad:
        zpad = lambda a: jnp.pad(a, (0, pad))
        lat, group, weight = zpad(lat), zpad(group), zpad(weight)
    return latency_histogram_call(
        lat, group, weight,
        num_groups=num_groups, num_bins=num_bins,
        lo=lo, hi=hi, tr=tr, interpret=interpret,
    )
