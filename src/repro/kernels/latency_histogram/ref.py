"""Pure-jnp oracle for the fused latency-histogram pass.

One chunk of per-request latencies is bucketized into log-spaced bins and
scatter-added into a ``[G, B]`` grouped histogram in a single pass. The
group id encodes (node, read/write) — ``g = node * 2 + is_read`` — so the
global, per-node, and read/write-split histograms the telemetry layer
exposes are all *sums over rows* of this one output, and histograms from
different chunks / seeds / policy rows merge by plain summation.

Binning scheme (shared with the Pallas kernel via :func:`bin_index`):
bin 0 is the underflow bucket (< ``lo``), bin ``B-1`` the overflow bucket
(>= ``hi``), and the ``B-2`` interior bins are log-spaced on ``[lo, hi)`` —
constant *relative* width ``(hi/lo)**(1/(B-2)) - 1``, which is what bounds
the quantile interpolation error (EXPERIMENTS.md §Telemetry).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["bin_index", "bin_edges", "latency_histogram_ref"]


def bin_index(lat, lo, hi, num_bins: int):
    """Log-spaced bucket index, elementwise (int32, same shape as ``lat``).

    The kernel inlines this exact expression, so the two implementations
    agree bit-for-bit on bucket boundaries (same f32 log/rounding path).
    """
    inner = num_bins - 2
    t = jnp.log(jnp.maximum(lat, 1e-30) / lo) / jnp.log(hi / lo)
    raw = jnp.floor(t * inner).astype(jnp.int32) + 1
    raw = jnp.clip(raw, 1, inner)
    return jnp.where(
        lat < lo, 0, jnp.where(lat >= hi, num_bins - 1, raw)
    ).astype(jnp.int32)


def bin_edges(lo: float, hi: float, num_bins: int):
    """Host-side ``[B+1]`` bin edges: ``[0, lo, ..., hi, inf]``."""
    import numpy as np

    inner = num_bins - 2
    interior = lo * (hi / lo) ** (np.arange(inner + 1) / inner)
    return np.concatenate([[0.0], interior, [np.inf]])


def latency_histogram_ref(
    lat: jnp.ndarray,  # [R] f32 per-request latency (ms)
    group: jnp.ndarray,  # [R] i32 group id in [0, G)
    weight: jnp.ndarray,  # [R] f32 per-request weight (0 masks padding)
    *,
    num_groups: int,
    num_bins: int,
    lo,
    hi,
):
    """Fused bucketize + grouped scatter-add: ``[G, B]`` f32 counts."""
    idx = bin_index(lat.astype(jnp.float32), lo, hi, num_bins)
    hist = jnp.zeros((num_groups, num_bins), jnp.float32)
    return hist.at[group, idx].add(weight.astype(jnp.float32))
