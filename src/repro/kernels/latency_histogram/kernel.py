"""Pallas latency histogram (TPU): fused bucketize + grouped scatter-add.

One grid step ingests a ``[TR]`` tile of per-request latencies and folds it
into a single ``[G, B]`` grouped histogram that lives in VMEM across the
whole grid (every step maps to the same output block; step 0 zeroes it).
Scatter-add is hostile to the VPU, so the accumulation is recast as a
matmul the MXU eats natively:

    onehot_g [TR, G] (weighted) ∙ onehot_b [TR, B]  ->  [G, B]

With 0/1 weights every partial sum is an integer, so f32 accumulation is
exact below 2**24 regardless of summation order — the kernel matches the
pure-jnp scatter-add oracle (``ref.py``) bit-for-bit, which the parity
tests pin. ``lo`` / ``hi`` arrive as scalar *inputs* (like the ownership
sweep's H) so a jitted telemetry pipeline can trace the bin range without
recompiling; ``num_bins`` / ``num_groups`` / ``tr`` stay static.

VMEM budget per step: lat/group/weight tiles (3·TR·4B) + the two one-hot
planes (TR·(G+B)·4B) + the [G, B] accumulator — TR = 1024 at B = 128,
G ≤ 32 is well under 1 MB, leaving the pipeline room to double-buffer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import compiler_params, interpret_default, pl
from repro.kernels.latency_histogram.ref import bin_index

__all__ = ["latency_histogram_kernel", "latency_histogram_call"]

DEFAULT_TR = 1024


def latency_histogram_kernel(
    lat_ref,  # [TR, 1] f32
    group_ref,  # [TR, 1] i32
    w_ref,  # [TR, 1] f32 (0 masks padded rows)
    lo_ref,  # [1, 1] f32 — lowest interior bin edge
    hi_ref,  # [1, 1] f32 — overflow threshold
    hist_ref,  # out [G, B] f32, accumulated across the whole grid
    *,
    num_groups: int,
    num_bins: int,
    tr: int,
):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    lo = lo_ref[0, 0]
    hi = hi_ref[0, 0]
    idx = bin_index(lat_ref[...], lo, hi, num_bins)  # [TR, 1]

    iota_b = jax.lax.broadcasted_iota(jnp.int32, (tr, num_bins), 1)
    onehot_b = (iota_b == idx).astype(jnp.float32)
    iota_g = jax.lax.broadcasted_iota(jnp.int32, (tr, num_groups), 1)
    onehot_g = (iota_g == group_ref[...]).astype(jnp.float32) * w_ref[...]

    hist_ref[...] += jax.lax.dot_general(
        onehot_g,
        onehot_b,
        (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def latency_histogram_call(
    lat: jax.Array,  # [R] f32
    group: jax.Array,  # [R] i32
    weight: jax.Array,  # [R] f32
    *,
    num_groups: int,
    num_bins: int,
    lo,
    hi,
    tr: int = DEFAULT_TR,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = interpret_default()
    r = lat.shape[0]
    tr = min(tr, r)
    assert r % tr == 0, (r, tr)
    grid = (r // tr,)
    kernel = functools.partial(
        latency_histogram_kernel,
        num_groups=num_groups,
        num_bins=num_bins,
        tr=tr,
    )
    row = lambda i: (i, 0)
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, 1), row),
            pl.BlockSpec((tr, 1), row),
            pl.BlockSpec((tr, 1), row),
            scalar,
            scalar,
        ],
        # Every grid step accumulates into the SAME [G, B] block, so the
        # grid dimension is sequential ("arbitrary"), not parallel.
        out_specs=pl.BlockSpec((num_groups, num_bins), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_groups, num_bins), jnp.float32),
        compiler_params=compiler_params(("arbitrary",)),
        interpret=interpret,
    )(
        lat.astype(jnp.float32).reshape(r, 1),
        group.astype(jnp.int32).reshape(r, 1),
        weight.astype(jnp.float32).reshape(r, 1),
        jnp.asarray(lo, jnp.float32).reshape(1, 1),
        jnp.asarray(hi, jnp.float32).reshape(1, 1),
    )
