"""jit'd chunk-replay wrappers: the simulation engines' per-chunk dispatch.

Two entry points, both with the latency-model scalars (service cost,
transfer charges, histogram bin range) *traced* so retuned clusters never
recompile, and ``read_mode`` / ``master`` / bin count / tile sizes static:

  * :func:`chunk_latency` — the per-request ``(lat [B], read_hits [B])``
    pass shared by both engines' pure-JAX path (and the reference engine's
    raw-latency oracle). A jit of ``ref.chunk_latency_ref`` — the engines
    keep their exact pre-fusion f32 op sequence (seed goldens pin bits).
  * :func:`chunk_replay` — the whole fused pass returning chunk
    aggregates ``(busy [N], lat_sum, hits, reads, count, hist)``;
    ``backend="jax"`` composes the oracle, ``backend="pallas"`` runs the
    one-pass Mosaic kernel with the request axis padded to the tile
    (weight-0 rows) and the key axis padded to the gather tile.
    ``interpret=None`` auto-selects from the platform (interpret off-TPU),
    matching the ``ownership_sweep`` convention.

Failure injection (``ClusterConfig.faults``) reaches both entry points as
DATA, never as new kernel math: the engines pass the availability-masked
replica map (``hosts & avail[None, :]``, so reads natively price on the
nearest LIVE replica), fold the write-failover delta from
``ref.fault_extra_ms_ref`` into the composed ``extra_ms`` operand, and
mask refused (unavailable) requests out of ``valid`` — weight-0 rows the
kernel already handles. With faults off the operands are bit-identical to
the pre-fault engine, so these wrappers and the Mosaic kernel needed no
change for PR 10.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.chunk_replay.kernel import (
    DEFAULT_TKEY,
    DEFAULT_TR,
    chunk_replay_call,
)
from repro.kernels.chunk_replay.ref import (
    READ_MODES,
    chunk_latency_ref,
    chunk_replay_ref,
)

__all__ = ["REPLAY_BACKENDS", "chunk_latency", "chunk_replay"]

REPLAY_BACKENDS = ("jax", "pallas")


@partial(jax.jit, static_argnames=("master", "read_mode"))
def chunk_latency(
    hosts: jax.Array,  # [K, N] bool
    keys: jax.Array,  # [B] i32
    nodes: jax.Array,  # [B] i32
    is_read: jax.Array,  # [B] bool
    rtt: jax.Array,  # [N, N] f32
    *,
    service_ms,
    master: int,
    xfer_read_ms,
    xfer_write_ms,
    read_mode: str,
):
    """Per-request latency + read-hit flags: ``(lat [B] f32, hits [B] bool)``."""
    return chunk_latency_ref(
        hosts, keys, nodes, is_read, rtt,
        service_ms=service_ms, master=master,
        xfer_read_ms=xfer_read_ms, xfer_write_ms=xfer_write_ms,
        read_mode=read_mode,
    )


@partial(
    jax.jit,
    static_argnames=(
        "master", "read_mode", "num_bins", "backend", "tr", "tkey", "interpret",
    ),
)
def chunk_replay(
    hosts: jax.Array,  # [K, N] bool frozen replica map
    keys: jax.Array,  # [B] i32
    nodes: jax.Array,  # [B] i32
    is_read: jax.Array,  # [B] bool
    valid: jax.Array,  # [B] bool (False masks padded rows)
    rtt: jax.Array,  # [N, N] f32
    *,
    service_ms,
    master: int,
    xfer_read_ms,
    xfer_write_ms,
    read_mode: str,
    num_bins: int = 0,
    lo=1.0,
    hi=10_000.0,
    backend: str = "jax",
    tr: int = DEFAULT_TR,
    tkey: int = DEFAULT_TKEY,
    interpret: bool | None = None,
    extra_ms: jax.Array | None = None,  # [B] f32 contention wait per request
):
    """One chunk's fused request path.

    Returns ``(busy [N], lat_sum, hits, reads, count, hist)`` — ``hist`` is
    the ``[2N, num_bins]`` grouped latency histogram, ``None`` when
    ``num_bins == 0`` (telemetry off).

    ``extra_ms`` (the ServiceConfig contention pre-pass output,
    ``ref.contention_extra_ms_ref``) is folded into every request's latency
    before the busy/stats/histogram reductions; ``None`` (the default)
    compiles the exact pre-contention program, so goldens stay bit-exact.
    """
    if read_mode not in READ_MODES:
        raise ValueError(
            f"unknown read_mode {read_mode!r}; expected one of {READ_MODES}"
        )
    if backend not in REPLAY_BACKENDS:
        raise ValueError(
            f"unknown chunk-replay backend {backend!r}; expected one of "
            f"{REPLAY_BACKENDS}"
        )
    if backend == "jax":
        return chunk_replay_ref(
            hosts, keys, nodes, is_read, valid, rtt,
            service_ms=service_ms, master=master,
            xfer_read_ms=xfer_read_ms, xfer_write_ms=xfer_write_ms,
            read_mode=read_mode, num_bins=num_bins, lo=lo, hi=hi,
            extra_ms=extra_ms,
        )

    b = keys.shape[0]
    k, n = hosts.shape
    tr = min(tr, b)
    pad_b = (-b) % tr
    if pad_b:
        zpad = lambda a: jnp.pad(a, (0, pad_b))
        keys, nodes = zpad(keys), zpad(nodes)
        is_read, valid = zpad(is_read), zpad(valid)
        if extra_ms is not None:
            extra_ms = zpad(extra_ms)
    tkey = min(tkey, k)
    pad_k = (-k) % tkey
    if pad_k:
        hosts = jnp.pad(hosts, ((0, pad_k), (0, 0)))
    out = chunk_replay_call(
        hosts, keys, nodes, is_read, valid, rtt,
        service_ms=service_ms, xfer_read_ms=xfer_read_ms,
        xfer_write_ms=xfer_write_ms, lo=lo, hi=hi,
        master=master, read_mode=read_mode, num_bins=num_bins,
        tr=tr, tkey=tkey, interpret=interpret, extra_ms=extra_ms,
    )
    busy, stats = out[0][0], out[1][0]
    hist = out[2] if num_bins > 0 else None
    return busy, stats[0], stats[1], stats[2], stats[3], hist
