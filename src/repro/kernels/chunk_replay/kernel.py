"""Pallas chunk-replay kernel (TPU): the simulator's whole per-chunk
request path fused into ONE pass over request tiles.

The pre-fusion engine materialised ``[B, N]`` HBM intermediates
(``replicas``, ``read_replicas``, owner masks) and walked them in four
separate passes (read path, write path, hit flags, busy scatter) before a
fifth pass folded the telemetry histogram. Here one grid step ingests a
``[TR]`` request tile and never leaves VMEM:

  replica gather  — ``hosts[keys]`` recast as a one-hot matmul the MXU eats
                    natively: ``onehot_k [TR, TKEY] ∙ hosts [TKEY, N]``,
                    accumulated across key tiles in a VMEM scratch (each key
                    lands in exactly one tile, so the sum IS the gather).
  read path       — RTT-row gather (again a one-hot matmul), masked
                    nearest-replica min, orphan worst-RTT guard, and the
                    size-aware remote transfer charge.
  write path      — Algorithm 2 over the RTT row: master relay + the
                    broadcast completing at the farthest owner (a masked
                    max over the owner plane).
  hit flags       — the requesting node's own column of the replica plane.
  busy fold       — per-node latency totals as ``lat [1, TR] ∙ onehot_n
                    [TR, N]`` instead of a VPU-hostile scatter.
  histogram fold  — the telemetry layer's grouped ``[2N, B]`` log-bin
                    histogram (``latency_histogram``'s one-hot matmul),
                    fused in so telemetry-on runs stop paying a separate
                    dispatch over the chunk.

Latency expressions replicate ``ref.chunk_latency_ref`` op-for-op (same
f32 sequence ⇒ same bits ⇒ identical histogram buckets); only the
*reductions* (busy, lat_sum) re-associate across tiles, so those are
allclose-vs-oracle while hit/read/count/histogram stay bit-exact for the
0/1 weights the engine uses — pinned by tests/test_chunk_replay.py.

Scalars (service/transfer charges, histogram bin range) arrive as scalar
*inputs* (the trio convention, like the ownership sweep's H), so jitted
pipelines can retune the latency model without recompiling; ``read_mode``
/ ``master`` / ``num_bins`` / tile sizes stay static.

VMEM budget per step: the two one-hot planes dominate — ``TR·TKEY`` for
the gather (512·1024·4B = 2 MB) + ``TR·(N + G + B)`` for the folds
(≈ 0.6 MB at N ≤ 64, B = 128) + the [TR, N] scratch; comfortably inside
16 MB with room to double-buffer.

Failure injection (PR 10) required NO kernel change: degraded-mode
serving arrives entirely through the operands — the engine hands this
kernel the availability-masked replica map (so the nearest-replica min
only sees LIVE copies), a ``valid`` mask with refused requests already
dropped (weight-0 rows), and the write-failover delta pre-folded into
``extra_ms`` by ``ref.fault_extra_ms_ref``. See ops.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import compiler_params, interpret_default, pl, vmem_scratch
from repro.kernels.latency_histogram.ref import bin_index

__all__ = ["chunk_replay_kernel", "chunk_replay_call"]

DEFAULT_TR = 512
DEFAULT_TKEY = 1024

# stats lane order in the [1, 4] output block.
STAT_FIELDS = ("lat_sum", "hits", "reads", "count")


def chunk_replay_kernel(
    keys_ref,  # [TR, 1] i32
    nodes_ref,  # [TR, 1] i32
    read_ref,  # [TR, 1] i32 (is_read)
    valid_ref,  # [TR, 1] i32 (0 masks padded rows)
    hosts_ref,  # [TKEY, N] f32 (0/1 replica map tile)
    rtt_ref,  # [N, N] f32
    service_ref,  # [1, 1] f32 — per-op service cost
    xfer_r_ref,  # [1, 1] f32 — remote read transfer charge
    xfer_w_ref,  # [1, 1] f32 — write transfer charge
    lo_ref,  # [1, 1] f32 — lowest interior histogram edge
    hi_ref,  # [1, 1] f32 — histogram overflow threshold
    *refs,  # [extra_ms input], outputs (busy, stats[, hist]), replica scratch
    read_mode: str,
    master: int,
    num_bins: int,
    n: int,
    tr: int,
    tkey: int,
    num_key_tiles: int,
    with_extra: bool = False,
):
    if with_extra:
        # [TR, 1] f32 per-request contention wait (ServiceConfig pre-pass).
        extra_ref, *refs = refs
    else:
        extra_ref = None
    with_hist = num_bins > 0
    if with_hist:
        busy_ref, stats_ref, hist_ref, replicas_ref = refs
    else:
        busy_ref, stats_ref, replicas_ref = refs
        hist_ref = None
    i = pl.program_id(0)  # request tile
    j = pl.program_id(1)  # key tile (inner loop)

    @pl.when((i == 0) & (j == 0))
    def _init():
        busy_ref[...] = jnp.zeros_like(busy_ref)
        stats_ref[...] = jnp.zeros_like(stats_ref)
        if with_hist:
            hist_ref[...] = jnp.zeros_like(hist_ref)

    @pl.when(j == 0)
    def _reset_gather():
        replicas_ref[...] = jnp.zeros_like(replicas_ref)

    # --- 1. replica-row gather as a one-hot matmul, one key tile at a time.
    local = keys_ref[...] - j * tkey  # [TR, 1]
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (tr, tkey), 1)
    onehot_k = (iota_k == local).astype(jnp.float32)
    replicas_ref[...] += jax.lax.dot_general(
        onehot_k,
        hosts_ref[...],
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(j == num_key_tiles - 1)
    def _replay():
        nodes = nodes_ref[...]  # [TR, 1]
        is_read = read_ref[...] != 0
        valid = valid_ref[...] != 0
        service = service_ref[0, 0]
        rtt = rtt_ref[...]

        iota_n = jax.lax.broadcasted_iota(jnp.int32, (tr, n), 1)
        onehot_n = (iota_n == nodes).astype(jnp.float32)

        if read_mode == "ideal":
            # The paper's theoretically-ideal scenario: pure service cost.
            lat = jnp.zeros((tr, 1), jnp.float32) + service
            hit = jnp.ones((tr, 1), dtype=bool)
        else:
            replicas_f = replicas_ref[...]  # [TR, N] exact 0/1
            replicas = replicas_f > 0.5
            # Own-node column of the replica plane (the hit flag).
            own = jnp.sum(
                replicas_f * onehot_n, axis=1, keepdims=True
            )  # exact 0/1
            hit = own > 0.5
            if read_mode == "no_local":
                read_replicas = replicas & (iota_n != nodes)
                hit = jnp.zeros_like(hit)
                has_local = jnp.zeros_like(hit)
            else:
                read_replicas = replicas
                has_local = hit

            # --- 2. read path: nearest visible replica over the RTT row.
            row = jax.lax.dot_general(
                onehot_n, rtt, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )  # [TR, N] — exact gather (one nonzero term per sum)
            masked = jnp.where(read_replicas, row, jnp.inf)
            nearest = jnp.min(masked, axis=1, keepdims=True)
            nearest = jnp.where(
                jnp.isfinite(nearest), nearest, jnp.max(rtt)
            )
            r_lat = service + nearest + jnp.where(
                has_local, 0.0, xfer_r_ref[0, 0]
            )

            # --- 3. write path: master relay + farthest-owner broadcast.
            owner_count = jnp.sum(replicas_f, axis=1, keepdims=True)
            sole_local = hit & (owner_count == 1.0)
            if read_mode == "no_local":
                sole_local = jnp.zeros_like(sole_local)
            relay = jnp.where(
                nodes == master, 0.0, row[:, master : master + 1]
            )
            non_master = replicas & (iota_n != master)
            post = jnp.max(
                jnp.where(non_master, rtt[master : master + 1, :], 0.0),
                axis=1,
                keepdims=True,
            )
            cost = relay + post
            cost = cost + jnp.where(cost > 0, xfer_w_ref[0, 0], 0.0)
            w_lat = service + jnp.where(sole_local, 0.0, cost)

            lat = jnp.where(is_read, r_lat, w_lat)

        # --- 4/5. hit flags + per-node busy fold (MXU, not a scatter).
        if extra_ref is not None:
            # Same elementwise add, same position as the oracle's, so the
            # histogram bucket of every request stays bit-identical.
            lat = lat + extra_ref[...]
        lat = jnp.where(valid, lat, 0.0)
        read_hits = hit & is_read & valid
        busy_ref[...] += jax.lax.dot_general(
            lat, onehot_n, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # [1, N]
        w = valid.astype(jnp.float32)
        stats_ref[...] += jnp.concatenate(
            [
                jnp.sum(lat).reshape(1, 1),
                jnp.sum(read_hits.astype(jnp.float32)).reshape(1, 1),
                jnp.sum((is_read & valid).astype(jnp.float32)).reshape(1, 1),
                jnp.sum(w).reshape(1, 1),
            ],
            axis=1,
        )

        # --- 6. grouped latency-histogram fold (telemetry on only).
        if with_hist:
            idx = bin_index(lat, lo_ref[0, 0], hi_ref[0, 0], num_bins)
            iota_b = jax.lax.broadcasted_iota(jnp.int32, (tr, num_bins), 1)
            onehot_b = (iota_b == idx).astype(jnp.float32)
            group = nodes * 2 + read_ref[...]
            iota_g = jax.lax.broadcasted_iota(jnp.int32, (tr, 2 * n), 1)
            onehot_g = (iota_g == group).astype(jnp.float32) * w
            hist_ref[...] += jax.lax.dot_general(
                onehot_g, onehot_b, (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )


def chunk_replay_call(
    hosts: jax.Array,  # [K, N] f32 0/1 (K padded to tkey)
    keys: jax.Array,  # [B] i32 (B padded to tr)
    nodes: jax.Array,  # [B] i32
    is_read: jax.Array,  # [B] i32
    valid: jax.Array,  # [B] i32
    rtt: jax.Array,  # [N, N] f32
    *,
    service_ms,
    xfer_read_ms,
    xfer_write_ms,
    lo,
    hi,
    master: int,
    read_mode: str,
    num_bins: int,
    tr: int = DEFAULT_TR,
    tkey: int = DEFAULT_TKEY,
    interpret: bool | None = None,
    extra_ms: jax.Array | None = None,  # [B] f32 contention wait per request
):
    if interpret is None:
        interpret = interpret_default()
    b = keys.shape[0]
    k, n = hosts.shape
    tr = min(tr, b)
    tkey = min(tkey, k)
    assert b % tr == 0, (b, tr)
    assert k % tkey == 0, (k, tkey)
    num_key_tiles = k // tkey
    grid = (b // tr, num_key_tiles)
    kernel = functools.partial(
        chunk_replay_kernel,
        read_mode=read_mode,
        master=master,
        num_bins=num_bins,
        n=n,
        tr=tr,
        tkey=tkey,
        num_key_tiles=num_key_tiles,
        with_extra=extra_ms is not None,
    )
    req = lambda i, j: (i, 0)
    acc = lambda i, j: (0, 0)
    scalar = pl.BlockSpec((1, 1), acc)
    out_specs = [
        pl.BlockSpec((1, n), acc),  # busy
        pl.BlockSpec((1, 4), acc),  # stats
    ]
    out_shape = [
        jax.ShapeDtypeStruct((1, n), jnp.float32),
        jax.ShapeDtypeStruct((1, 4), jnp.float32),
    ]
    if num_bins > 0:
        out_specs.append(pl.BlockSpec((2 * n, num_bins), acc))
        out_shape.append(jax.ShapeDtypeStruct((2 * n, num_bins), jnp.float32))
    in_specs = [
        pl.BlockSpec((tr, 1), req),
        pl.BlockSpec((tr, 1), req),
        pl.BlockSpec((tr, 1), req),
        pl.BlockSpec((tr, 1), req),
        pl.BlockSpec((tkey, n), lambda i, j: (j, 0)),
        pl.BlockSpec((n, n), acc),
        scalar,
        scalar,
        scalar,
        scalar,
        scalar,
    ]
    inputs = [
        keys.astype(jnp.int32).reshape(b, 1),
        nodes.astype(jnp.int32).reshape(b, 1),
        is_read.astype(jnp.int32).reshape(b, 1),
        valid.astype(jnp.int32).reshape(b, 1),
        hosts.astype(jnp.float32),
        rtt.astype(jnp.float32),
        jnp.asarray(service_ms, jnp.float32).reshape(1, 1),
        jnp.asarray(xfer_read_ms, jnp.float32).reshape(1, 1),
        jnp.asarray(xfer_write_ms, jnp.float32).reshape(1, 1),
        jnp.asarray(lo, jnp.float32).reshape(1, 1),
        jnp.asarray(hi, jnp.float32).reshape(1, 1),
    ]
    if extra_ms is not None:
        in_specs.append(pl.BlockSpec((tr, 1), req))
        inputs.append(extra_ms.astype(jnp.float32).reshape(b, 1))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[vmem_scratch((tr, n), jnp.float32)],
        # Every grid step accumulates into the SAME output blocks, so both
        # grid dimensions are sequential ("arbitrary"), not parallel.
        compiler_params=compiler_params(("arbitrary", "arbitrary")),
        interpret=interpret,
    )(*inputs)
