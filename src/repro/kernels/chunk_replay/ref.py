"""Pure-jnp oracle for the fused chunk-replay pass.

One simulation chunk is a ``[B]`` slab of requests replayed against a
``[K, N]`` replica map frozen at chunk start. The request path is:

  1. replica-row gather           ``replicas = hosts[keys]``        [B, N]
  2. nearest-replica read latency (Algorithm 1 over the RTT row, plus the
     size-aware transfer charge when the serving replica is remote)
  3. relay+broadcast write latency (Algorithm 2: relay to the master
     propagator, parallel post completing at the farthest owner)
  4. read-hit flags               ``replicas[b, nodes[b]]``
  5. per-node busy accumulation   ``busy[nodes[b]] += lat[b]``
  6. optional grouped ``[2N, B]`` latency-histogram fold
     (group id = node * 2 + is_read — the telemetry layer's encoding)

This module is the canonical scalar-argument form of the latency model:
``repro.kvsim.cluster.read_latency_geo`` / ``write_latency_geo`` delegate
here, and the simulation engines' per-chunk latency pass is exactly
:func:`chunk_latency_ref` — so the Pallas kernel (``kernel.py``), the
engines, and the standalone latency functions can never drift apart.
Expressions are kept in the precise order the pre-fusion engine used (the
f32 op sequence determines bits, and the seed goldens pin bits).

``read_mode`` semantics (paper §9 scenario definitions):

  * ``"map"``      reads consult the replica map (Redynis / replicated)
  * ``"no_local"`` the requesting node's own copy is invisible — every op
                   pays a WAN hop; an empty visible set charges the
                   topology's worst RTT (backing-store fetch)
  * ``"ideal"``    the paper's theoretically-ideal scenario: every request
                   is served locally at pure service cost
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.kernels.latency_histogram.ref import bin_index

__all__ = [
    "READ_MODES",
    "COMPONENTS",
    "NUM_COMPONENTS",
    "nearest_replica_rtt_ref",
    "read_latency_ref",
    "write_latency_ref",
    "chunk_latency_ref",
    "chunk_components_ref",
    "chunk_replay_ref",
    "serving_node_ref",
    "service_demand_ref",
    "load_factor_ref",
    "contention_wait_ref",
    "contention_extra_ms_ref",
    "routing_extra_ms_ref",
    "routing_extra_split_ref",
    "fault_extra_ms_ref",
]

READ_MODES = ("map", "no_local", "ideal")

# The latency-provenance taxonomy: every request's total latency is the sum
# of exactly these additive components, priced HERE (the canonical oracle)
# so the scan engine, the reference engine, both replay backends, the
# static fast path, and the sharded mesh can never disagree on attribution.
#
#   service         base per-op service cost (``service_ms`` — both paths)
#   read_rtt        nearest-visible-replica RTT (Algorithm 1, reads)
#   write_relay     requester -> master-propagator relay leg (Algorithm 2)
#   write_broadcast parallel post, completing at the farthest owner ack
#   transfer        payload transfer charge (reads with no local copy;
#                   writes whose relay+post genuinely crossed a link)
#   contention_wait M/M/1 residence-time excess (``contention_extra_ms_ref``)
#   routing_detour  stale-directory forward-hop + redirect detour
#   directory_fetch router cache-miss round trip to the home node
#
# ``service`` is not in the issue's seven named network components but is
# required for the reconstruction invariant (component sum == total
# latency); the remaining rows are zero wherever the request didn't pay
# them, so per-component histograms weight by ``component > 0``.
COMPONENTS = (
    "service",
    "read_rtt",
    "write_relay",
    "write_broadcast",
    "transfer",
    "contention_wait",
    "routing_detour",
    "directory_fetch",
)
NUM_COMPONENTS = len(COMPONENTS)


def nearest_replica_rtt_ref(rtt: Array, replicas: Array, nodes: Array) -> Array:
    """RTT from each requesting node to its nearest replica ``[B]``; an
    empty replica mask charges the worst RTT in the topology (the modelled
    backing-store fetch — see ``cluster.nearest_replica_rtt``)."""
    row = rtt[nodes]  # [B, N]
    masked = jnp.where(replicas, row, jnp.inf)
    nearest = jnp.min(masked, axis=-1)
    return jnp.where(jnp.isfinite(nearest), nearest, jnp.max(rtt))


def read_latency_ref(
    rtt: Array,
    replicas: Array,
    nodes: Array,
    *,
    service_ms,
    xfer_ms,
) -> Array:
    """Geo read path: service + RTT to the nearest replica, + the payload
    transfer charge when the requesting node holds no visible copy."""
    nearest = nearest_replica_rtt_ref(rtt, replicas, nodes)
    has_local = replicas[jnp.arange(replicas.shape[0]), nodes]
    return service_ms + nearest + jnp.where(has_local, 0.0, xfer_ms)


def write_latency_ref(
    rtt: Array,
    replicas: Array,
    nodes: Array,
    sole_local_owner: Array,
    *,
    service_ms,
    master: int,
    xfer_ms,
) -> Array:
    """Geo write path (Algorithm 2): relay to the master propagator, then a
    parallel post completing when the farthest owner acks; ``cost > 0``
    means a payload genuinely crossed a link and pays the transfer charge."""
    n = rtt.shape[0]
    relay = jnp.where(nodes == master, 0.0, rtt[nodes, master])
    non_master_owners = replicas & (jnp.arange(n)[None, :] != master)
    post = jnp.max(
        jnp.where(non_master_owners, rtt[master][None, :], 0.0), axis=-1
    )
    cost = relay + post
    cost = cost + jnp.where(cost > 0, xfer_ms, 0.0)
    return service_ms + jnp.where(sole_local_owner, 0.0, cost)


def chunk_latency_ref(
    hosts: Array,  # [K, N] bool frozen replica map
    keys: Array,  # [B] i32
    nodes: Array,  # [B] i32
    is_read: Array,  # [B] bool
    rtt: Array,  # [N, N] f32
    *,
    service_ms,
    master: int,
    xfer_read_ms,
    xfer_write_ms,
    read_mode: str,
) -> tuple[Array, Array]:
    """Per-request latency + read-hit flags for one chunk: ``(lat [B] f32,
    read_hits [B] bool)``. This is the engines' per-chunk latency pass."""
    b = keys.shape[0]
    if read_mode == "ideal":
        hit = jnp.ones_like(is_read)
        return jnp.full((b,), service_ms, jnp.float32), hit & is_read

    replicas = hosts[keys]  # [B, N]
    hit = replicas[jnp.arange(b), nodes]
    if read_mode == "no_local":
        read_replicas = replicas & (
            jnp.arange(hosts.shape[1])[None, :] != nodes[:, None]
        )
        hit = jnp.zeros_like(hit)
    else:
        read_replicas = replicas
    r_lat = read_latency_ref(
        rtt, read_replicas, nodes, service_ms=service_ms, xfer_ms=xfer_read_ms
    )

    owner_count = jnp.sum(replicas, axis=-1)
    sole_local = hit & (owner_count == 1)
    if read_mode == "no_local":
        sole_local = jnp.zeros_like(sole_local)
    w_lat = write_latency_ref(
        rtt, replicas, nodes, sole_local,
        service_ms=service_ms, master=master, xfer_ms=xfer_write_ms,
    )

    lat = jnp.where(is_read, r_lat, w_lat)
    return lat, hit & is_read


def chunk_components_ref(
    hosts: Array,  # [K, N] bool frozen replica map
    keys: Array,  # [B] i32
    nodes: Array,  # [B] i32
    is_read: Array,  # [B] bool
    rtt: Array,  # [N, N] f32
    *,
    service_ms,
    master: int,
    xfer_read_ms,
    xfer_write_ms,
    read_mode: str,
    contention_ms: Array | None = None,  # [B] f32 (contention_extra_ms_ref)
    routing_detour_ms: Array | None = None,  # [B] f32 (routing_extra_split_ref)
    directory_fetch_ms: Array | None = None,  # [B] f32 (routing_extra_split_ref)
    avail: Array | None = None,  # [N] bool (fault failover — see faults.py)
) -> Array:
    """Per-request latency decomposed along :data:`COMPONENTS`:
    ``[NUM_COMPONENTS, B] f32``.

    Recomputes the same sub-expressions :func:`chunk_latency_ref` composes
    (identical f32 bits per piece) and routes each into its named row, so
    ``components.sum(0) (+ valid mask)`` reconstructs
    ``chunk_latency_ref(...) + extra_ms`` — allclose under f32 (the sum
    re-associates the write path's ``(relay + post) + xfer`` grouping),
    with every row bit-identical across engines, backends, and shardings.
    The engine-supplied pre-pass surcharges (contention wait, routing
    detour, directory fetch) drop straight into their rows; ``None`` rows
    are structural zeros.

    With faults on the caller hands the availability-masked map plus this
    chunk's ``avail`` vector: the write legs are then priced through the
    same failover master :func:`fault_extra_ms_ref` elects, so the rows
    absorb the failover delta the engines fold via ``extra_ms`` and the
    reconstruction invariant holds under outages too (the delta lands in
    ``write_relay``/``write_broadcast``/``transfer``, not a new row).
    """
    b = keys.shape[0]
    zeros = jnp.zeros((b,), jnp.float32)
    service = jnp.full((b,), service_ms, jnp.float32)
    if read_mode == "ideal":
        read_rtt = write_relay = write_broadcast = transfer = zeros
    else:
        n = rtt.shape[0]
        replicas = hosts[keys]  # [B, N]
        hit = replicas[jnp.arange(b), nodes]
        if read_mode == "no_local":
            read_replicas = replicas & (
                jnp.arange(n)[None, :] != nodes[:, None]
            )
        else:
            read_replicas = replicas
        # Read legs — the exact pieces read_latency_ref sums.
        nearest = nearest_replica_rtt_ref(rtt, read_replicas, nodes)
        has_local = read_replicas[jnp.arange(b), nodes]
        r_xfer = jnp.where(has_local, 0.0, xfer_read_ms)
        # Write legs — the exact pieces write_latency_ref sums, with the
        # sole-local-owner short-circuit applied per leg.
        owner_count = jnp.sum(replicas, axis=-1)
        sole_local = hit & (owner_count == 1)
        if read_mode == "no_local":
            sole_local = jnp.zeros_like(sole_local)
        if avail is None:
            w_master = master
        else:
            w_master = jnp.where(
                avail[master], master, jnp.argmax(avail)
            ).astype(jnp.int32)
        relay = jnp.where(nodes == w_master, 0.0, rtt[nodes, w_master])
        non_master_owners = replicas & (jnp.arange(n)[None, :] != w_master)
        post = jnp.max(
            jnp.where(non_master_owners, rtt[w_master][None, :], 0.0), axis=-1
        )
        w_xfer = jnp.where(relay + post > 0, xfer_write_ms, 0.0)
        paid = ~sole_local
        read_rtt = jnp.where(is_read, nearest, 0.0)
        write_relay = jnp.where(is_read, 0.0, jnp.where(paid, relay, 0.0))
        write_broadcast = jnp.where(is_read, 0.0, jnp.where(paid, post, 0.0))
        transfer = jnp.where(
            is_read, r_xfer, jnp.where(paid, w_xfer, 0.0)
        )
    comps = [
        service,
        read_rtt.astype(jnp.float32),
        write_relay.astype(jnp.float32),
        write_broadcast.astype(jnp.float32),
        transfer.astype(jnp.float32),
        zeros if contention_ms is None else contention_ms,
        zeros if routing_detour_ms is None else routing_detour_ms,
        zeros if directory_fetch_ms is None else directory_fetch_ms,
    ]
    return jnp.stack(comps).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Queueing-aware contention (ServiceConfig — see cluster.py for the model).
# The pre-pass needs the whole chunk's per-node demand fold before any
# request's wait is known, so it runs as plain jnp ahead of the fused kernel
# and hands the kernel a per-request ``extra_ms`` to fold into the latency.
# ---------------------------------------------------------------------------


def serving_node_ref(
    replicas: Array,  # [B, N] bool
    nodes: Array,  # [B] i32
    is_read: Array,  # [B] bool
    rtt: Array,  # [N, N] f32
    *,
    read_mode: str,
) -> Array:
    """Per-request serving node ``[B] i32``: reads are served by the nearest
    *visible* replica (the requesting node itself when the visible set is
    empty — it performs the backing-store fetch), writes by the requesting
    node (Algorithm 2 commits at the requester before the master relay)."""
    if read_mode == "ideal":
        return nodes
    if read_mode == "no_local":
        visible = replicas & (
            jnp.arange(replicas.shape[1])[None, :] != nodes[:, None]
        )
    else:
        visible = replicas
    masked = jnp.where(visible, rtt[nodes], jnp.inf)
    nearest = jnp.argmin(masked, axis=-1).astype(jnp.int32)
    read_serving = jnp.where(jnp.any(visible, axis=-1), nearest, nodes)
    return jnp.where(is_read, read_serving, nodes).astype(jnp.int32)


def service_demand_ref(
    obj_bytes: Array, *, service_ms, serve_bytes_per_ms
) -> Array:
    """Per-request service demand in ms: base cost + size-proportional
    serve time (the Minos observation — large objects occupy the server)."""
    return (service_ms + obj_bytes / serve_bytes_per_ms).astype(jnp.float32)


def load_factor_ref(
    serving: Array,  # [B] i32
    demand: Array,  # [B] f32
    valid: Array,  # [B] bool
    *,
    num_nodes: int,
    capacity_ms,
    rho_max,
    axis_name: str | None = None,
) -> Array:
    """Per-node load factor ``rho [N]``: the chunk's demand folded per
    serving node over capacity, clamped below the stability bound.

    ``axis_name`` follows the ``publish_and_fill`` convention: ``None`` (the
    default) is the single-shard program, bit-exact with the goldens; under
    a key-sharded ``shard_map`` each shard folds only its own (valid-masked)
    requests and one ``psum`` assembles the global per-node demand before
    the clamp — the load factor is a *cluster* property, not a shard one.
    The psum re-associates the f32 fold, so sharded contention runs are
    allclose (not bit-exact) to single-device ones.
    """
    fold = jnp.zeros((num_nodes,), jnp.float32).at[serving].add(
        jnp.where(valid, demand, 0.0)
    )
    if axis_name is not None:
        fold = jax.lax.psum(fold, axis_name)
    return jnp.minimum(fold / capacity_ms, rho_max)


def contention_wait_ref(demand: Array, rho: Array, serving: Array) -> Array:
    """M/M/1 residence-time excess per request: ``d * rho / (1 - rho)`` at
    the request's serving node."""
    r = rho[serving]
    return demand * r / (1.0 - r)


def contention_extra_ms_ref(
    hosts: Array,  # [K, N] bool
    keys: Array,  # [B] i32
    nodes: Array,  # [B] i32
    is_read: Array,  # [B] bool
    valid: Array,  # [B] bool
    rtt: Array,  # [N, N] f32
    obj_bytes: Array,  # [K] f32 per-key object sizes
    *,
    read_mode: str,
    service_ms,
    serve_bytes_per_ms,
    capacity_ms,
    rho_max,
    axis_name: str | None = None,
) -> tuple[Array, Array]:
    """The whole contention pre-pass: ``(extra_ms [B] f32, rho [N] f32)``.

    Canonical for every consumer — both simulation engines, the static fast
    path, and the Pallas backend (which feeds ``extra_ms`` into the fused
    kernel) call exactly this composition, so contention cannot drift
    between backends any more than the base latency model can.

    Under a key-sharded engine (``axis_name`` set) the caller passes
    shard-local ``hosts``/``obj_bytes``, shard-local key ids, and a validity
    mask restricted to the shard's own requests; the demand fold psums
    across shards (see :func:`load_factor_ref`) so ``rho`` — and therefore
    each shard's ``extra_ms`` — reflects the whole cluster's load.
    """
    if read_mode == "ideal":
        serving = nodes.astype(jnp.int32)
    else:
        serving = serving_node_ref(
            hosts[keys], nodes, is_read, rtt, read_mode=read_mode
        )
    demand = service_demand_ref(
        obj_bytes[keys], service_ms=service_ms,
        serve_bytes_per_ms=serve_bytes_per_ms,
    )
    rho = load_factor_ref(
        serving, demand, valid,
        num_nodes=rtt.shape[0], capacity_ms=capacity_ms, rho_max=rho_max,
        axis_name=axis_name,
    )
    return contention_wait_ref(demand, rho, serving), rho


# ---------------------------------------------------------------------------
# Routing-tier pricing (RoutingConfig — see kvsim/routing.py for the model).
# Like contention, the routing penalty is a jnp pre-pass producing a
# per-request ``extra_ms`` that every consumer folds into the latency at the
# SAME canonical elementwise position (``lat = lat + extra`` before the valid
# mask) — so the jax scan, the reference engine, and the Pallas kernel (which
# receives the composed ``extra_ms`` input) can never drift, and the
# mis-route/fetch counters come from this one shared pass.
# ---------------------------------------------------------------------------


def routing_extra_ms_ref(
    hosts: Array,  # [K, N] bool — authoritative frozen map (true serving)
    pub_hosts: Array,  # [K, N] bool — published (lagged) directory view
    cached: Array,  # [B] bool — the consulted router caches this key
    fresh: Array,  # [B] bool — ... at the key's current publish version
    keys: Array,  # [B] i32
    nodes: Array,  # [B] i32
    is_read: Array,  # [B] bool
    valid: Array,  # [B] bool
    rtt: Array,  # [N, N] f32
    *,
    read_mode: str,
    home_node: int,
) -> tuple[Array, Array, Array, Array, Array]:
    """The whole routing-tier pre-pass: ``(extra_ms [B] f32, consults [B],
    fetches [B], stale [B], mis_routed [B])`` (the last four bool).

    Only requests that genuinely need ownership knowledge consult their
    router: reads without a local replica under ``read_mode="map"``, every
    read under ``"no_local"`` (the local copy is invisible by definition),
    none under ``"ideal"`` — and writes never (Algorithm 2 commits at the
    requester before the master relay resolves owners server-side).

    Pricing per consult:

      * fresh  — the cached row is current: route as today, 0 extra.
      * stale  — route via the *published* map: if the published serving
        node differs from the true one, pay the forward-hop + redirect
        detour ``rtt[x, s_pub] + rtt[s_pub, s_true] - rtt[x, s_true]``
        (the request still ultimately completes at the true serving
        replica, whose RTT the base latency model already charged).
      * miss   — a directory-fetch round trip to ``home_node`` first
        (``rtt[x, home]``), then the fetched row IS the published view, so
        the same detour applies on top.
    """
    detour_part, fetch_part, consult, fetches, stale, mis_routed = (
        routing_extra_split_ref(
            hosts, pub_hosts, cached, fresh, keys, nodes, is_read, valid,
            rtt, read_mode=read_mode, home_node=home_node,
        )
    )
    return detour_part + fetch_part, consult, fetches, stale, mis_routed


def routing_extra_split_ref(
    hosts: Array,  # [K, N] bool — authoritative frozen map (true serving)
    pub_hosts: Array,  # [K, N] bool — published (lagged) directory view
    cached: Array,  # [B] bool — the consulted router caches this key
    fresh: Array,  # [B] bool — ... at the key's current publish version
    keys: Array,  # [B] i32
    nodes: Array,  # [B] i32
    is_read: Array,  # [B] bool
    valid: Array,  # [B] bool
    rtt: Array,  # [N, N] f32
    *,
    read_mode: str,
    home_node: int,
) -> tuple[Array, Array, Array, Array, Array, Array]:
    """:func:`routing_extra_ms_ref` with the surcharge split into its two
    provenance components: ``(detour_ms [B] f32, fetch_ms [B] f32,
    consults [B], fetches [B], stale [B], mis_routed [B])``.

    ``detour_ms + fetch_ms`` is row-wise bit-identical to the combined
    ``extra_ms`` the un-split form always charged (per row the split is
    ``detour + fetch`` vs ``detour + where(cached, 0, fetch)`` with the same
    f32 add on the same operands), so the attribution layer reads the split
    while the engines' composed surcharge keeps its exact historical bits.
    """
    b = keys.shape[0]
    zeros_f = jnp.zeros((b,), jnp.float32)
    zeros_b = jnp.zeros((b,), bool)
    if read_mode == "ideal":
        # Ideal serves everything locally at pure service cost — there is
        # no ownership lookup to get stale.
        return zeros_f, zeros_f, zeros_b, zeros_b, zeros_b, zeros_b
    replicas = hosts[keys]  # [B, N]
    local = replicas[jnp.arange(b), nodes]
    if read_mode == "no_local":
        consult = is_read & valid
    else:
        consult = is_read & ~local & valid
    s_true = serving_node_ref(replicas, nodes, is_read, rtt, read_mode=read_mode)
    s_pub = serving_node_ref(
        pub_hosts[keys], nodes, is_read, rtt, read_mode=read_mode
    )
    mis = s_pub != s_true
    detour = jnp.where(
        mis, rtt[nodes, s_pub] + rtt[s_pub, s_true] - rtt[nodes, s_true], 0.0
    ).astype(jnp.float32)
    fetch = rtt[nodes, home_node].astype(jnp.float32)
    detour_part = jnp.where(consult & ~fresh, detour, 0.0).astype(jnp.float32)
    fetch_part = jnp.where(
        consult & ~fresh & ~cached, fetch, 0.0
    ).astype(jnp.float32)
    fetches = consult & ~cached
    stale = consult & cached & ~fresh
    mis_routed = consult & ~fresh & mis
    return detour_part, fetch_part, consult, fetches, stale, mis_routed


# ---------------------------------------------------------------------------
# Failure-injection pricing (FaultConfig — see kvsim/faults.py for the
# schedule model). Degraded-mode serving is priced HERE, once, as a third
# jnp pre-pass: the engines hand every downstream consumer the
# availability-masked map ``hosts_eff = hosts & avail[None, :]`` (so reads
# natively fall back to the nearest LIVE replica and the Pallas kernel needs
# no new math), and this pass contributes the only piece the masked map
# cannot express — the write-failover master delta — plus the
# per-request unavailability verdict that becomes the engines' valid mask.
# ---------------------------------------------------------------------------


def fault_extra_ms_ref(
    hosts: Array,  # [K, N] bool — authoritative map (crash losses applied)
    keys: Array,  # [B] i32
    nodes: Array,  # [B] i32
    is_read: Array,  # [B] bool
    valid: Array,  # [B] bool (False masks padded rows)
    avail: Array,  # [N] bool — this chunk's node availability
    rtt: Array,  # [N, N] f32
    *,
    read_mode: str,
    master: int,
    xfer_write_ms,
    wiped: Array | None = None,  # [K] bool — keys that lost every replica
) -> tuple[Array, Array, Array]:
    """The whole failure pre-pass: ``(extra_ms [B] f32, unavailable [B],
    failover [B])`` (the last two bool).

    Unavailability verdicts (a True row is excluded from every latency /
    hit / histogram fold by the engines' ``served = valid & ~unavailable``):

      * origin down — the requesting node itself is crashed or partitioned
        away; its users are offline (reads AND writes), every mode.
      * dark read — the key has surviving copies *somewhere* in the map but
        none on a live node (``mode="partition"``: temporarily unreachable),
        or the key is flagged ``wiped`` (``mode="crash"`` destroyed its last
        replica and the daemon has not re-seeded it from the backing store
        yet). A map-empty row that was never wiped keeps the base model's
        planned-eviction semantics: the worst-RTT backing-store fetch —
        which is what keeps an all-up schedule bit-exact with faults off.

    Served writes relay through a deterministic failover master when the
    static master is down: ``m* = master if avail[master] else
    argmin{n : avail[n]}``. The charge is priced as a *delta* against the
    static-master legs on the live replica set — exactly the legs
    :func:`chunk_latency_ref` computes when handed ``hosts_eff`` — so
    composing ``base + extra`` re-prices the write through ``m*`` while an
    all-up chunk contributes a bitwise ``+0.0`` (``x - x`` on identical f32
    operands), keeping the canonical ``lat = lat + extra`` fold bit-exact.
    """
    b = keys.shape[0]
    zeros_f = jnp.zeros((b,), jnp.float32)
    zeros_b = jnp.zeros((b,), bool)
    origin_down = ~avail[nodes]
    if read_mode == "ideal":
        # Ideal serves locally at pure service cost: no replica set to go
        # dark and no master relay — only a down origin can fail.
        return zeros_f, origin_down & valid, zeros_b
    n = rtt.shape[0]
    replicas = hosts[keys]  # [B, N]
    if read_mode == "no_local":
        vis_base = replicas & (jnp.arange(n)[None, :] != nodes[:, None])
    else:
        vis_base = replicas
    vis_live = vis_base & avail[None, :]
    read_dark = jnp.any(vis_base, axis=-1) & ~jnp.any(vis_live, axis=-1)
    if wiped is not None:
        read_dark = read_dark | wiped[keys]
    unavailable = (origin_down | (is_read & read_dark)) & valid

    live = replicas & avail[None, :]
    hit_live = live[jnp.arange(b), nodes]
    owner_count = jnp.sum(live, axis=-1)
    sole_local = hit_live & (owner_count == 1)
    if read_mode == "no_local":
        sole_local = jnp.zeros_like(sole_local)
    # Static-master write legs on the live set — bit-identical operands to
    # what chunk_latency_ref charges when handed hosts_eff.
    relay = jnp.where(nodes == master, 0.0, rtt[nodes, master])
    non_master_owners = live & (jnp.arange(n)[None, :] != master)
    post = jnp.max(
        jnp.where(non_master_owners, rtt[master][None, :], 0.0), axis=-1
    )
    cost = relay + post
    cost = cost + jnp.where(cost > 0, xfer_write_ms, 0.0)
    w_base = jnp.where(sole_local, 0.0, cost)
    # Failover-master legs: first live node by index when the master is down
    # (argmax over bool = lowest True index — deterministic re-election).
    m_star = jnp.where(avail[master], master, jnp.argmax(avail)).astype(
        jnp.int32
    )
    relay_d = jnp.where(nodes == m_star, 0.0, rtt[nodes, m_star])
    nmo_d = live & (jnp.arange(n)[None, :] != m_star)
    post_d = jnp.max(jnp.where(nmo_d, rtt[m_star][None, :], 0.0), axis=-1)
    cost_d = relay_d + post_d
    cost_d = cost_d + jnp.where(cost_d > 0, xfer_write_ms, 0.0)
    w_deg = jnp.where(sole_local, 0.0, cost_d)

    served_write = ~is_read & ~unavailable & valid
    extra = jnp.where(served_write, w_deg - w_base, 0.0).astype(jnp.float32)
    failover = served_write & ~avail[master] & ~sole_local
    return extra, unavailable, failover


def chunk_replay_ref(
    hosts: Array,  # [K, N] bool
    keys: Array,  # [B] i32
    nodes: Array,  # [B] i32
    is_read: Array,  # [B] bool
    valid: Array,  # [B] bool (False masks padded rows)
    rtt: Array,  # [N, N] f32
    *,
    service_ms,
    master: int,
    xfer_read_ms,
    xfer_write_ms,
    read_mode: str,
    num_bins: int = 0,
    lo=1.0,
    hi=10_000.0,
    extra_ms: Array | None = None,  # [B] f32 contention wait (ServiceConfig)
):
    """The whole fused pass as one jnp composition — the oracle the Pallas
    kernel is parity-pinned against.

    Returns ``(busy [N], lat_sum, hits, reads, count, hist)`` where ``hist``
    is the ``[2N, num_bins]`` grouped latency histogram (``None`` when
    ``num_bins == 0`` — telemetry off).
    """
    n = rtt.shape[0]
    lat, read_hits = chunk_latency_ref(
        hosts, keys, nodes, is_read, rtt,
        service_ms=service_ms, master=master,
        xfer_read_ms=xfer_read_ms, xfer_write_ms=xfer_write_ms,
        read_mode=read_mode,
    )
    if extra_ms is not None:
        lat = lat + extra_ms
    lat = jnp.where(valid, lat, 0.0)
    busy = jnp.zeros((n,), jnp.float32).at[nodes].add(lat)
    lat_sum = jnp.sum(lat)
    hits = jnp.sum((read_hits & valid).astype(jnp.float32))
    reads = jnp.sum((is_read & valid).astype(jnp.float32))
    w = valid.astype(jnp.float32)
    count = jnp.sum(w)
    if num_bins == 0:
        return busy, lat_sum, hits, reads, count, None
    group = nodes * 2 + is_read.astype(jnp.int32)
    idx = bin_index(lat.astype(jnp.float32), lo, hi, num_bins)
    hist = jnp.zeros((2 * n, num_bins), jnp.float32).at[group, idx].add(w)
    return busy, lat_sum, hits, reads, count, hist
