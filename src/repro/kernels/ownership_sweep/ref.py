"""Pure-jnp oracle: repro.core.placement.sweep restricted to the analysis
phase — identical semantics, arrays instead of a MetadataStore."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.ownership import eligible_hosts

__all__ = ["sweep_ref"]


def sweep_ref(counts, hosts, live, last_access, now, *, h: float, expiry: int = 0):
    counts = counts.astype(jnp.float32)
    hosts = hosts.astype(bool)
    live = live.astype(bool)
    elig = eligible_hosts(counts, h)
    touched = jnp.sum(counts, axis=-1) > 0
    owners = jnp.where(touched[:, None], elig, hosts)
    if expiry > 0:
        expired = live & ((jnp.asarray(now, jnp.int32) - last_access) > expiry)
    else:
        expired = jnp.zeros_like(live)
    owners = owners & live[:, None] & ~expired[:, None]
    total = jnp.sum(counts, axis=-1, keepdims=True)
    f = jnp.where(total > 0, counts / jnp.maximum(total, 1.0), 0.0)
    return owners, owners & ~hosts, hosts & ~owners, expired, f
