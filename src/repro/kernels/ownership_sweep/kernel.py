"""Pallas ownership sweep (TPU): the paper's Algorithm 3 analysis loop.

One grid step processes a [TK, N] tile of the metadata cluster entirely in
VMEM: ownership fractions (eq. 1), eligibility vs H (eq. 2) with the
argmax-fallback starvation guard (eq. 3's intent), expiry, and the
owner/add/drop deltas. All VPU work — no matmuls — so the kernel is
memory-bound by design and the tile size just has to keep the six [TK, N]
planes (~6·TK·N·4B) under VMEM; TK = 2048 at N ≤ 64 is ≈ 3 MB.

The ownership coefficient H arrives as a scalar *input* (like ``now``)
rather than a compile-time constant, so jitted pipelines can trace it —
``repro.core.placement.sweep(backend="pallas")`` routes through here with a
traced H. ``expiry`` stays static (``<= 0`` disables — the unified
convention; the branch compiles away when unused). ``interpret`` defaults to
auto-detection: interpret mode off-TPU, compiled Mosaic on TPU.

The daemon sweeps millions of keys per pass; this kernel is why the paper's
"constant time per key, no graph traversal" claim survives contact with a
TPU: one HBM read + one write per metadata byte. The ``f`` output plane
feeds the cost model's capacity projection directly (scored placement
pipeline), avoiding a second [K, N] pass.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import compiler_params, interpret_default, pl

__all__ = ["ownership_sweep_kernel", "ownership_sweep_call"]

DEFAULT_TK = 2048


def ownership_sweep_kernel(
    counts_ref,  # [TK, N] f32
    hosts_ref,  # [TK, N] i8
    live_ref,  # [TK, 1] i8
    last_ref,  # [TK, 1] i32
    now_ref,  # [1, 1] i32
    h_ref,  # [1, 1] f32 — ownership coefficient H
    owners_ref,  # out [TK, N] i8
    add_ref,  # out [TK, N] i8
    drop_ref,  # out [TK, N] i8
    expired_ref,  # out [TK, 1] i8
    f_ref,  # out [TK, N] f32 — ownership fractions (cost-model scoring)
    *,
    expiry: int,
    n: int,
    tk: int,
):
    counts = counts_ref[...]
    hosts = hosts_ref[...] != 0
    live = live_ref[...] != 0  # [TK, 1]
    h = h_ref[0, 0]

    total = jnp.sum(counts, axis=-1, keepdims=True)  # [TK, 1]
    f = jnp.where(total > 0, counts / jnp.maximum(total, 1.0), 0.0)
    elig = f >= h
    # Starvation guard: traffic but nobody qualifies -> hottest node keeps it.
    iota_n = jax.lax.broadcasted_iota(jnp.int32, (tk, n), 1)
    am = jnp.argmax(counts, axis=-1)[:, None]
    none_q = (total > 0) & ~jnp.any(elig, axis=-1, keepdims=True)
    elig = jnp.where(none_q, iota_n == am, elig)

    owners = jnp.where(total > 0, elig, hosts)  # silence = no churn
    if expiry > 0:
        now = now_ref[0, 0]
        expired = live & ((now - last_ref[...]) > expiry)
    else:
        expired = jnp.zeros_like(live)
    owners = owners & live & ~expired

    owners_ref[...] = owners.astype(jnp.int8)
    add_ref[...] = (owners & ~hosts).astype(jnp.int8)
    drop_ref[...] = (hosts & ~owners).astype(jnp.int8)
    expired_ref[...] = expired.astype(jnp.int8)
    f_ref[...] = f


def ownership_sweep_call(
    counts: jax.Array,  # [K, N] f32
    hosts: jax.Array,  # [K, N] bool/i8
    live: jax.Array,  # [K] bool/i8
    last_access: jax.Array,  # [K] i32
    now: jax.Array,  # [] or [1] i32
    *,
    h: jax.Array | float,
    expiry: int = 0,
    tk: int = DEFAULT_TK,
    interpret: bool | None = None,
):
    if interpret is None:
        interpret = interpret_default()
    k, n = counts.shape
    tk = min(tk, k)
    assert k % tk == 0, (k, tk)
    grid = (k // tk,)
    kernel = functools.partial(ownership_sweep_kernel, expiry=expiry, n=n, tk=tk)
    row = lambda i: (i, 0)
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tk, n), row),
            pl.BlockSpec((tk, n), row),
            pl.BlockSpec((tk, 1), row),
            pl.BlockSpec((tk, 1), row),
            scalar,
            scalar,
        ],
        out_specs=[
            pl.BlockSpec((tk, n), row),
            pl.BlockSpec((tk, n), row),
            pl.BlockSpec((tk, n), row),
            pl.BlockSpec((tk, 1), row),
            pl.BlockSpec((tk, n), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, n), jnp.int8),
            jax.ShapeDtypeStruct((k, n), jnp.int8),
            jax.ShapeDtypeStruct((k, n), jnp.int8),
            jax.ShapeDtypeStruct((k, 1), jnp.int8),
            jax.ShapeDtypeStruct((k, n), jnp.float32),
        ],
        compiler_params=compiler_params(("parallel",)),
        interpret=interpret,
    )(
        counts.astype(jnp.float32),
        hosts.astype(jnp.int8),
        live.astype(jnp.int8).reshape(k, 1),
        last_access.astype(jnp.int32).reshape(k, 1),
        jnp.asarray(now, jnp.int32).reshape(1, 1),
        jnp.asarray(h, jnp.float32).reshape(1, 1),
    )
    return out
