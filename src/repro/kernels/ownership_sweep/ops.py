"""jit'd wrapper: bool in/out, K padded to the tile size transparently.

``h`` is a *traced* argument (the kernel reads it from a scalar input ref),
so the scored placement pipeline can sweep with data-dependent coefficients
without recompiling; ``expiry`` / ``tk`` / ``interpret`` stay static.
``interpret=None`` auto-selects from the platform (interpret off-TPU).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ownership_sweep.kernel import DEFAULT_TK, ownership_sweep_call

__all__ = ["ownership_sweep"]


@partial(jax.jit, static_argnames=("expiry", "tk", "interpret"))
def ownership_sweep(
    counts: jax.Array,  # [K, N]
    hosts: jax.Array,  # [K, N] bool
    live: jax.Array,  # [K] bool
    last_access: jax.Array,  # [K] int32
    now,
    *,
    h: jax.Array | float,
    expiry: int = 0,
    tk: int = DEFAULT_TK,
    interpret: bool | None = None,
):
    """Returns (owners, to_add, to_drop, expired, f) — bool/bool/bool/bool/f32."""
    k, n = counts.shape
    tk = min(tk, k)
    pad = (-k) % tk
    if pad:
        zpad = lambda a: jnp.pad(a, [(0, pad)] + [(0, 0)] * (a.ndim - 1))
        counts, hosts = zpad(counts), zpad(hosts)
        live, last_access = zpad(live), zpad(last_access)
    owners, add, drop, expired, f = ownership_sweep_call(
        counts, hosts, live, last_access, now,
        h=h, expiry=expiry, tk=tk, interpret=interpret,
    )
    trim = lambda a: a[:k]
    return (
        trim(owners).astype(bool),
        trim(add).astype(bool),
        trim(drop).astype(bool),
        trim(expired)[:, 0].astype(bool),
        trim(f),
    )
