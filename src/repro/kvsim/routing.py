"""Routing tier with a stale-directory cache (TurboKV-style metadata tier).

Redynis's evaluation — and every engine in this repo before this module —
assumes requests teleport to the correct replica with perfectly fresh
ownership knowledge. TurboKV (2010.14931) models the directory as a
first-class tier: router sites hold a *popularity-aware cache* of the
ownership map, stale entries pay a mis-route penalty, and directory updates
propagate at a lag behind repartitioning decisions (DINOMO, 2209.08743,
shows that metadata freshness is the limiting factor during elastic
reconfiguration). This module is that tier:

  * **R router sites** (:class:`RoutingConfig.num_routers`; 0 = one router
    per cluster node). A request from node ``x`` consults router ``x % R``.
  * **Bounded, popularity-aware cache**: per router a ``[R, K]``
    eligibility mask + the directory *version* each entry was last
    refreshed at. Admission is decay-LFU over the consult stream: per chunk
    ``score = score * decay + consults`` and the top ``cache_entries``
    scores per router stay cached (ties at the threshold are all admitted —
    the capacity is a bound of ``cache_entries`` plus ties).
    ``cache_entries = 0`` (or >= the keyspace) is the *unbounded warm
    cache*: every entry is always cached, nothing is ever evicted.
  * **Versioned publishes**: every daemon placement commit bumps a per-key
    authoritative version (``repro.core.policy.publish_mask``). The
    directory *publishes* at ``publish_lag_chunks`` behind the daemon via a
    ring buffer folded through the engine's scan carry, so routers — and
    directory fetches — see the ownership map as it was L chunks ago.
  * **Consult outcomes** (priced by
    ``repro.kernels.chunk_replay.ref.routing_extra_ms_ref``, the single
    canonical latency oracle): a *fresh* hit routes as today (0 extra);
    a *stale* entry routes via the published map and pays the mis-route
    detour (forward hop to the stale owner + redirect to the true serving
    replica); a *miss* pays a directory-fetch round trip to
    ``home_node`` and then routes via the published (possibly still stale)
    row. Only requests that would actually consult the directory pay:
    reads without a local replica under ``read_mode="map"``, every read
    under ``"no_local"``, nothing under ``"ideal"`` — and writes never
    (Algorithm 2 commits at the requester before the master relay).

Modelling notes (documented approximations, pinned by tests):

  * Stale entries route via the *published* map — the directory tier's
    propagation horizon — rather than per-entry historical snapshots;
    each entry's individual age (authoritative version minus the version
    it was refreshed at) feeds the staleness-age histogram instead.
  * Detours add latency but do not shift the contention demand fold: the
    request is ultimately served by the true serving replica, so the
    queueing model keeps charging demand there.
  * ``publish_lag_chunks = 0`` with an unbounded cache prices every
    consult at exactly ``0.0`` extra — adding that to a non-negative f32
    latency is a bit-exact identity, which is what the zero-lag /
    infinite-cache equivalence property in tests/test_routing.py pins.

Off by default: ``ClusterConfig.routing = None`` (or
``RoutingConfig(enabled=False)``, collapsed by :func:`normalize_routing`)
compiles the exact pre-routing program, so every seed golden holds
bit-exact — the same structural-no-op contract as ``TelemetryConfig`` and
``ServiceConfig``.

This module must stay import-free of ``repro.kvsim.cluster`` (which
imports it to attach :class:`RoutingConfig` to ``ClusterConfig``).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

__all__ = [
    "STALE_AGE_BINS",
    "RoutingConfig",
    "RouterState",
    "normalize_routing",
    "router_of",
    "init_router_state",
    "published_view",
    "consult_probe",
    "router_cache_update",
    "publish_commit",
    "stale_age_fold",
]

# Staleness-age histogram width: ages (authoritative version minus the
# version a consulted entry was refreshed at) are counted into linear bins
# 0..STALE_AGE_BINS-2 with the last bin absorbing everything older.
STALE_AGE_BINS = 16


class RoutingConfig(NamedTuple):
    """Directory/routing-tier knobs (hashable — rides on ``ClusterConfig``,
    which is already a jit static, so no new static argnames are needed).

    Off by default at the cluster level (``routing=None``); constructing a
    config turns the tier on unless ``enabled=False``.
    """

    enabled: bool = True
    num_routers: int = 0  # router sites; 0 = one per cluster node
    cache_entries: int = 0  # per-router cache capacity; 0 = unbounded/warm
    publish_lag_chunks: int = 0  # directory publish lag behind the daemon
    home_node: int = 0  # directory home (miss round-trip destination)
    decay: float = 1.0  # per-chunk decay of the LFU admission score

    def validate(self) -> "RoutingConfig":
        if self.num_routers < 0:
            raise ValueError(
                f"num_routers must be >= 0 (0 = one per node), got "
                f"{self.num_routers}"
            )
        if self.cache_entries < 0:
            raise ValueError(
                f"cache_entries must be >= 0 (0 = unbounded), got "
                f"{self.cache_entries}"
            )
        if self.publish_lag_chunks < 0:
            raise ValueError(
                f"publish_lag_chunks must be >= 0, got "
                f"{self.publish_lag_chunks}"
            )
        if self.home_node < 0:
            raise ValueError(
                f"home_node must be a node index, got {self.home_node}"
            )
        if not 0.0 < self.decay <= 1.0:
            raise ValueError(
                f"decay must lie in (0, 1], got {self.decay}"
            )
        return self


def normalize_routing(routing: "RoutingConfig | None") -> "RoutingConfig | None":
    """Collapse disabled configs to ``None`` so ``routing=None`` and
    ``RoutingConfig(enabled=False)`` compile the identical program (the
    same contract as ``normalize_service`` / ``normalize_telemetry``)."""
    if routing is None or not routing.enabled:
        return None
    return routing.validate()


class RouterState(NamedTuple):
    """The routing tier's scan-carry state. ``None`` fields are empty
    pytree nodes, so each host-static configuration carries exactly the
    state it needs and nothing else:

      * ``cached``/``score`` are ``None`` for the unbounded warm cache
        (everything is always cached; no admission ranking runs).
      * ``ver`` is ``None`` under an inactive policy (a frozen map never
        publishes — every cached entry is trivially fresh).
      * the ring leaves are ``None`` at ``publish_lag_chunks == 0`` (the
        published view IS the current frozen map).
    """

    cached: Array | None  # [R, Kl] bool cache eligibility
    cached_ver: Array  # [R, Kl] i32 version each entry was refreshed at
    score: Array | None  # [R, Kl] f32 decay-LFU admission score
    ver: Array | None  # [Kl] i32 authoritative per-key publish version
    ring_hosts: Array | None  # [L+1, Kl, N] bool published-map ring
    ring_ver: Array | None  # [L+1, Kl] i32 published-version ring


def router_of(nodes: Array, num_routers: int) -> Array:
    """Router site consulted by each request ``[B] i32``: node ``x`` maps
    to router ``x % R`` (with R = N, the degenerate one-router-per-node
    deployment; smaller R models shared regional routers)."""
    return (nodes % num_routers).astype(jnp.int32)


def init_router_state(
    hosts0: Array,  # [Kl, N] initial (shard-local) replica map
    *,
    num_routers: int,
    cache_entries: int,
    publish_lag_chunks: int,
    active: bool,
    force_ring: bool = False,
) -> RouterState:
    """Cold-start router state for one engine run (shard-local shapes).

    ``force_ring`` materialises the publish ring even at
    ``publish_lag_chunks == 0`` (one slot, written at end-of-chunk and read
    the next chunk — value-identical to the ringless zero-lag path): the
    failure-injection layer needs a mutable published view to freeze while
    the directory home node is down, whatever the lag.
    """
    local_keys, _ = hosts0.shape
    bounded = cache_entries > 0
    lag = publish_lag_chunks
    ring = active and (lag > 0 or force_ring)
    return RouterState(
        cached=(
            jnp.zeros((num_routers, local_keys), bool) if bounded else None
        ),
        cached_ver=jnp.zeros((num_routers, local_keys), jnp.int32),
        score=(
            jnp.zeros((num_routers, local_keys), jnp.float32)
            if bounded else None
        ),
        ver=jnp.zeros((local_keys,), jnp.int32) if active else None,
        ring_hosts=(
            jnp.broadcast_to(hosts0, (lag + 1,) + hosts0.shape)
            if ring else None
        ),
        ring_ver=(
            jnp.zeros((lag + 1, local_keys), jnp.int32) if ring else None
        ),
    )


def published_view(
    rstate: RouterState,
    hosts: Array,  # [Kl, N] the chunk's frozen authoritative map
    chunk: Array,  # scalar i32 chunk index
    *,
    publish_lag_chunks: int,
) -> tuple[Array, Array]:
    """The directory's *published* ownership view at this chunk:
    ``(pub_hosts [Kl, N], pub_ver [Kl])`` — the authoritative state
    ``publish_lag_chunks`` chunks ago (clamped to the initial map for the
    first chunks). Inactive policies never publish, so their view is the
    frozen map at version zero. The slot count comes from the materialised
    ring itself (``force_ring`` allocates one slot at zero lag), so the
    ringless zero-lag fast path only runs when no ring exists."""
    if rstate.ver is None:
        return hosts, jnp.zeros((hosts.shape[0],), jnp.int32)
    if rstate.ring_hosts is None:
        return hosts, rstate.ver
    slot = chunk % rstate.ring_hosts.shape[0]
    return rstate.ring_hosts[slot], rstate.ring_ver[slot]


def consult_probe(
    rstate: RouterState,
    rb: Array,  # [B] i32 router site per request
    ck: Array,  # [B] i32 (shard-local) key per request
) -> tuple[Array, Array, Array]:
    """Per-request cache probe: ``(cached [B] bool, fresh [B] bool,
    age [B] i32)``. ``fresh`` means the entry's refresh version matches the
    key's authoritative version; ``age`` is the version gap on stale
    entries (0 elsewhere)."""
    ent_ver = rstate.cached_ver[rb, ck]
    if rstate.cached is None:
        ent_cached = jnp.ones(rb.shape, bool)
    else:
        ent_cached = rstate.cached[rb, ck]
    if rstate.ver is None:
        key_ver = jnp.zeros(rb.shape, jnp.int32)
    else:
        key_ver = rstate.ver[ck]
    fresh = ent_cached & (ent_ver >= key_ver)
    age = jnp.maximum(key_ver - ent_ver, 0)
    return ent_cached, fresh, age


def router_cache_update(
    rstate: RouterState,
    rb: Array,  # [B] i32 router site per request
    ck: Array,  # [B] i32 (shard-local) key per request
    consult: Array,  # [B] bool — requests that consulted the directory
    pub_ver: Array,  # [Kl] i32 published version (what a refresh installs)
    *,
    cache_entries: int,
    decay: float,
    axis_name: str | None = None,
) -> RouterState:
    """End-of-chunk cache maintenance (the state is frozen *during* a chunk,
    like the replica map): consulted entries refresh to the published
    version (a miss fetched the row, a stale consult learned the correct
    location after its redirect), the decay-LFU score folds the chunk's
    consults in, and — bounded — the per-router top-``cache_entries``
    scores stay cached.

    The admission threshold is the exact global C-th largest score per
    router: unsharded via one ``top_k``; key-sharded via local top-C +
    ``all_gather`` (the global top C is a subset of the union of local top
    Cs, so ranking the gathered candidates is exact, not approximate).
    """
    counts = jnp.zeros_like(rstate.cached_ver, jnp.float32).at[rb, ck].add(
        jnp.where(consult, 1.0, 0.0)
    )
    touched = counts > 0.0
    new_ver = jnp.where(touched, pub_ver[None, :], rstate.cached_ver)
    if cache_entries == 0:
        return rstate._replace(cached_ver=new_ver)
    local_keys = counts.shape[1]
    new_score = rstate.score * jnp.float32(decay) + counts
    candidates = jax.lax.top_k(new_score, min(cache_entries, local_keys))[0]
    if axis_name is not None:
        candidates = jax.lax.all_gather(
            candidates, axis_name, axis=1, tiled=True
        )
    kth = jax.lax.top_k(candidates, cache_entries)[0][:, -1]  # [R]
    new_cached = (new_score >= kth[:, None]) & (new_score > 0.0)
    return rstate._replace(
        cached=new_cached, cached_ver=new_ver, score=new_score
    )


def publish_commit(
    rstate: RouterState,
    changed: Array,  # [Kl] bool — keys whose replica row the daemon changed
    new_hosts: Array,  # [Kl, N] the map the NEXT chunk will see frozen
    chunk: Array,  # scalar i32 chunk index
    *,
    publish_lag_chunks: int,
    daemon_up: Array | None = None,  # scalar bool — directory home is live
) -> RouterState:
    """Fold one daemon step's versioned publish into the carry: bump the
    authoritative version of every changed key, and (lagged) overwrite the
    ring slot this chunk just read — it is next read ``publish_lag_chunks +
    1`` chunks from now, which is exactly what makes the published view the
    authoritative state L chunks ago.

    ``daemon_up`` (failure injection; ``None`` = the fault-free program)
    pauses the publish pipeline while the directory home node is down: the
    authoritative ``ver`` still bumps — placement genuinely changed — but
    the ring slot is rewritten with the view this chunk already served, so
    no post-outage map enters the published horizon until the home node
    recovers and routers go stale against the advancing authoritative
    version in the meantime."""
    if rstate.ver is None:
        return rstate
    ver = rstate.ver + changed.astype(jnp.int32)
    if rstate.ring_hosts is None:
        return rstate._replace(ver=ver)
    slot = chunk % rstate.ring_hosts.shape[0]
    write_hosts, write_ver = new_hosts, ver
    if daemon_up is not None:
        write_hosts = jnp.where(
            daemon_up, new_hosts, rstate.ring_hosts[slot]
        )
        write_ver = jnp.where(daemon_up, ver, rstate.ring_ver[slot])
    return rstate._replace(
        ver=ver,
        ring_hosts=rstate.ring_hosts.at[slot].set(write_hosts),
        ring_ver=rstate.ring_ver.at[slot].set(write_ver),
    )


def stale_age_fold(age: Array, stale: Array) -> Array:
    """One chunk's staleness-age histogram ``[STALE_AGE_BINS] f32``: the
    version gap of every *stale* consult, linear bins with the last bin
    absorbing ages ``>= STALE_AGE_BINS - 1``."""
    idx = jnp.clip(age, 0, STALE_AGE_BINS - 1)
    return jnp.zeros((STALE_AGE_BINS,), jnp.float32).at[idx].add(
        jnp.where(stale, 1.0, 0.0)
    )
