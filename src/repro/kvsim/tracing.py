"""Flight-recorder export: JSON-lines and Chrome trace-event format.

The simulator's flight recorder (``TelemetryConfig.flight``) samples a few
requests per chunk inside the fused scan and surfaces them as
``SimTrace.flight_records()`` — a list of plain dicts with the request's
identity (global position, key, serving node, router, read/write) and its
full latency-component vector (the 8-way provenance taxonomy from
``repro.kernels.chunk_replay.ref.COMPONENTS``). This module turns those
records into two on-disk formats:

* :func:`write_jsonl` — one JSON object per line, the grep/pandas-friendly
  spelling (``pd.read_json(path, lines=True)``).
* :func:`write_chrome_trace` — the Chrome trace-event JSON format, loadable
  in ``chrome://tracing`` and Perfetto (https://ui.perfetto.dev). Each
  sampled request becomes a complete event (``"ph": "X"``) on a *virtual*
  timeline: the simulator is trace-driven and has no wall clock, so a
  request's timestamp is its global trace position (1 position = 1 virtual
  ms) and its duration is its modelled latency. Events are laid out with
  ``pid`` = serving node and ``tid`` = router (or 0 when routing is off),
  so Perfetto's track grouping reads as "node → router lane"; the component
  vector rides in ``args`` where the UI shows it on click.

Both writers are pure-Python/stdlib-json over the already-host-side record
dicts — nothing here touches jax.
"""

from __future__ import annotations

import json
from typing import Iterable, Mapping

__all__ = [
    "chrome_trace_events",
    "write_chrome_trace",
    "write_jsonl",
]

# 1 trace position == 1 virtual millisecond == 1000 trace-event µs ticks.
_US_PER_POSITION = 1000.0


def write_jsonl(records: Iterable[Mapping], path: str) -> int:
    """Write flight records as JSON-lines; returns the record count."""
    n = 0
    with open(path, "w") as fh:
        for rec in records:
            fh.write(json.dumps(dict(rec)) + "\n")
            n += 1
    return n


def chrome_trace_events(records: Iterable[Mapping]) -> dict:
    """Flight records -> a Chrome trace-event JSON document (as a dict).

    Returns ``{"traceEvents": [...], "displayTimeUnit": "ms", ...}`` ready
    for ``json.dump``. See the module docstring for the virtual-timeline
    and pid/tid conventions.
    """
    events = []
    nodes = set()
    for rec in records:
        node = int(rec["node"])
        router = int(rec.get("router", -1))
        nodes.add(node)
        events.append(
            {
                "name": "read" if rec["is_read"] else "write",
                "cat": "request",
                "ph": "X",
                "ts": float(rec["pos"]) * _US_PER_POSITION,
                "dur": float(rec["total_ms"]) * 1000.0,
                "pid": node,
                "tid": max(router, 0),
                "args": {
                    "key": int(rec["key"]),
                    "chunk": int(rec["chunk"]),
                    "router": router,
                    **{
                        name: float(val)
                        for name, val in rec["components"].items()
                    },
                },
            }
        )
    # Metadata events name the node tracks so Perfetto shows "node 0" etc.
    meta = [
        {
            "name": "process_name",
            "ph": "M",
            "pid": node,
            "args": {"name": f"node {node}"},
        }
        for node in sorted(nodes)
    ]
    return {
        "traceEvents": meta + events,
        "displayTimeUnit": "ms",
        "otherData": {
            "source": "repro.kvsim flight recorder",
            "timeline": "virtual (1 trace position = 1 ms)",
        },
    }


def write_chrome_trace(records: Iterable[Mapping], path: str) -> int:
    """Write flight records as a Chrome/Perfetto trace file; returns the
    number of request events written."""
    doc = chrome_trace_events(records)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1)
    return sum(1 for e in doc["traceEvents"] if e["ph"] == "X")
