"""YCSB-style workload generation (paper §8.2) + geo workload presets.

The paper's workloads are permutations of:
  * read ratio: 100% (all reads) → 50% (write-heavy)
  * uniform vs skewed key access — skew = zipfian approximated as
    "10% of the data items requested 90% of the time" (paper's own wording,
    which we implement literally as a two-tier distribution)
  * 100,000 total requests

Geo-distribution model: each key has a *natural request source* (the node
closest to most of its clients — the paper's DNS-routing assumption, §4);
requests for a key arrive at that node with probability ``affinity`` and at a
uniformly random other node otherwise. ``affinity = 1/n`` reduces to fully
uniform sources. This is the knob that makes "bring data closer to the
frequent source" meaningful, and it is an *assumption the paper leaves
implicit* (documented in EXPERIMENTS.md §Repro-assumptions).

Beyond the paper's 3-node testbed, two geo workload classes pair with the
``[N, N]`` RTT topologies in ``cluster.py``:

  * **region-skewed** (``region_weights``): keys' natural sources are drawn
    from a non-uniform distribution over regions — most traffic originates
    in a couple of hot regions, as in real WAN deployments.
  * **diurnal** (``diurnal_shifts``): the hot region *rotates* across the
    trace ("follow the sun") — at phase p every request source is shifted p
    nodes around the ring, so placement must chase moving traffic. This is
    the workload the daemon's beyond-paper count decay exists for.

``generate_trace`` is pure JAX and accepts a traced seed, so the simulator
can ``vmap`` trace generation across CI iterations.

Streamed trace generation (scale-out fabric)
--------------------------------------------
``generate_trace`` materialises the whole ``[R]`` trace — O(R) HBM that caps
studies around ~1M requests. The streamed spelling splits the same PRNG
stream positionally instead of temporally:

  * :func:`generate_key_state` draws the per-key state (natural sources,
    object sizes) — O(K), drawn once per run; bit-identical to the
    corresponding ``Trace`` fields.
  * :func:`generate_trace_chunk` draws any window of request positions
    on demand — O(chunk) — and is **bit-identical to slicing the
    materialised ``generate_trace`` output** at those positions.

The equivalence works because jax's classic (non-partitionable) threefry
scheme is counter-based: ``random_bits(key, 32, (n,))`` encrypts the
counters ``0..n-1`` laid out as two half-length lanes (odd ``n`` pads one
zero counter). ``_sliced_bits`` reconstructs, for an arbitrary *position*
vector, exactly the (counter, lane) pair the full-length call would have
used and binds the threefry primitive on those counters directly — so any
slice of the stream costs O(slice), not O(n). ``_sliced_randint`` /
``_sliced_bernoulli`` then replicate ``jax.random``'s bit-to-value
transforms op-for-op on top. Positions ``>= num_requests`` produce
well-typed garbage (in-range keys/nodes) that callers must mask — the
simulation engine's padded-row ``valid`` mask already does.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array
from jax._src.prng import threefry2x32_p

__all__ = [
    "WorkloadConfig",
    "Trace",
    "TraceChunk",
    "generate_trace",
    "generate_key_state",
    "generate_trace_chunk",
    "wan5_workload",
    "diurnal_workload",
]


class WorkloadConfig(NamedTuple):
    num_requests: int = 100_000  # paper: uniform set of 100k requests
    # The paper does not state the key count; 1000 gives 100 accesses/key
    # under uniform traffic, enough for placement to converge within the
    # trace (calibration constant, see EXPERIMENTS.md §Repro-assumptions).
    num_keys: int = 1_000
    num_nodes: int = 3  # paper testbed: 3 nodes
    read_fraction: float = 1.0  # 1.0 .. 0.5
    skewed: bool = False  # False=uniform, True=zipfian 90/10
    hot_fraction: float = 0.10  # "10% of the data items ..."
    hot_traffic: float = 0.90  # "... 90% of the time"
    # P(request arrives at the key's natural node). The paper's DNS
    # assumption (§4) pins each client to its nearest server and a key's
    # clients are geo-clustered, so the faithful default is 1.0; the
    # affinity-sweep benchmark explores degradation below that.
    affinity: float = 1.0
    # P(natural node = i) per region; None = uniform over nodes. Length must
    # equal num_nodes (hashable tuple so the config stays a jit static).
    region_weights: tuple[float, ...] | None = None
    # >0: request sources rotate `diurnal_shifts` times across the trace —
    # requests in phase p originate (natural + p) % n, so the hot region
    # moves and stale placements decay in value.
    diurnal_shifts: int = 0
    # Per-key payload size distribution, consumed by the placement daemon's
    # capacity projection (per-node replica-byte budgets). Sizes are
    # lognormal: object_bytes × exp(sigma · N(0,1)), drawn once per key from
    # the trace seed; sigma = 0 (default) keeps every object at exactly
    # `object_bytes` — and with an infinite budget the sizes are inert, so
    # the paper's experiments are unchanged.
    object_bytes: float = 1024.0
    object_bytes_sigma: float = 0.0


class Trace(NamedTuple):
    keys: Array  # [R] int32
    nodes: Array  # [R] int32 requesting node
    is_read: Array  # [R] bool
    natural_node: Array  # [K] int32 per-key natural source (ground truth)
    object_bytes: Array  # [K] f32 per-key payload size


class TraceChunk(NamedTuple):
    """The per-request fields of one streamed window (per-key state lives in
    :func:`generate_key_state`; positions ``>= num_requests`` are garbage the
    caller must mask)."""

    keys: Array  # [B] int32
    nodes: Array  # [B] int32
    is_read: Array  # [B] bool


def _check_region_weights(cfg: WorkloadConfig) -> None:
    if cfg.region_weights is not None and len(cfg.region_weights) != cfg.num_nodes:
        raise ValueError(
            f"region_weights has {len(cfg.region_weights)} entries "
            f"for {cfg.num_nodes} nodes"
        )


def _workload_keys(seed: int | Array) -> tuple[Array, ...]:
    """The six per-field subkeys every trace spelling shares — splitting is
    O(1), so the streamed path re-derives them rather than threading key
    state around."""
    return tuple(jax.random.split(jax.random.PRNGKey(seed), 6))


def _natural_nodes(cfg: WorkloadConfig, k_nat: Array) -> Array:
    """Per-key natural request source ``[K] i32`` (the geo ground truth)."""
    k, n = cfg.num_keys, cfg.num_nodes
    if cfg.region_weights is not None:
        w = jnp.asarray(cfg.region_weights, jnp.float32)
        return jax.random.choice(k_nat, n, (k,), p=w / jnp.sum(w)).astype(
            jnp.int32
        )
    return jax.random.randint(k_nat, (k,), 0, n).astype(jnp.int32)


def _key_sizes(cfg: WorkloadConfig, k_other: Array) -> Array:
    """Per-key payload sizes ``[K] f32`` (lognormal when sigma > 0)."""
    k = cfg.num_keys
    if cfg.object_bytes_sigma > 0:
        # fold_in (not an extra split) so keys/nodes/reads are byte-identical
        # to traces generated before sizes existed (pinned seed goldens).
        k_size = jax.random.fold_in(k_other, 2)
        sizes = cfg.object_bytes * jnp.exp(
            cfg.object_bytes_sigma * jax.random.normal(k_size, (k,))
        )
    else:
        sizes = jnp.full((k,), cfg.object_bytes, jnp.float32)
    return sizes.astype(jnp.float32)


def generate_trace(cfg: WorkloadConfig, seed: int | Array = 0) -> Trace:
    _check_region_weights(cfg)
    k_hot, k_key, k_node, k_rw, k_nat, k_other = _workload_keys(seed)
    r, k, n = cfg.num_requests, cfg.num_keys, cfg.num_nodes

    if cfg.skewed:
        # Two-tier zipf approximation, exactly as the paper describes it:
        # hot 10% of keys serve 90% of requests.
        n_hot = max(1, int(k * cfg.hot_fraction))
        pick_hot = jax.random.bernoulli(k_hot, cfg.hot_traffic, (r,))
        hot_ids = jax.random.randint(k_key, (r,), 0, n_hot)
        cold_ids = jax.random.randint(
            jax.random.fold_in(k_key, 1), (r,), n_hot, k
        )
        keys = jnp.where(pick_hot, hot_ids, cold_ids).astype(jnp.int32)
    else:
        keys = jax.random.randint(k_key, (r,), 0, k).astype(jnp.int32)

    natural = _natural_nodes(cfg, k_nat)
    stay = jax.random.bernoulli(k_node, cfg.affinity, (r,))
    # A non-natural request lands uniformly on one of the other n-1 nodes.
    shift = jax.random.randint(k_other, (r,), 1, n)
    nat_of_key = natural[keys]
    nodes = jnp.where(stay, nat_of_key, (nat_of_key + shift) % n).astype(jnp.int32)

    if cfg.diurnal_shifts > 0:
        # "Follow the sun": phase p (p = 0..shifts-1) rotates every request
        # source p nodes around the ring.
        phase = (jnp.arange(r, dtype=jnp.int32) * cfg.diurnal_shifts) // r
        nodes = ((nodes + phase) % n).astype(jnp.int32)

    sizes = _key_sizes(cfg, k_other)
    is_read = jax.random.bernoulli(k_rw, cfg.read_fraction, (r,))
    return Trace(
        keys=keys,
        nodes=nodes,
        is_read=is_read,
        natural_node=natural,
        object_bytes=sizes,
    )


# ---------------------------------------------------------------------------
# Streamed trace generation: positional slices of the identical PRNG stream.
# ---------------------------------------------------------------------------


def _sliced_bits(key: Array, pos: Array, total: int) -> Array:
    """``jax.random.bits(key, (total,), uint32)[pos]`` in O(|pos|).

    jax's classic threefry layout for a length-``total`` draw: the counters
    ``iota(total)`` (odd sizes pad one zero) are split into two half-length
    lanes of ``h = (total+1)//2``, and block ``j`` encrypts the counter pair
    ``(j, j+h)``. Output position ``p < h`` is lane 0 of block ``p``;
    ``p >= h`` is lane 1 of block ``p - h``. We bind the threefry primitive
    on exactly those counters, so any position subset reproduces the full
    draw's bits without materialising it. Positions ``>= total`` fall into
    counter space the full draw never used — callers mask those rows.
    """
    h = (total + 1) // 2
    p = pos.astype(jnp.uint32)
    is_lo = pos < h
    j = jnp.where(is_lo, pos, pos - h).astype(jnp.uint32)
    # Lane-0 blocks pair with counter j+h — except the final odd block,
    # whose partner is the zero pad.
    c1 = jnp.where(is_lo, jnp.where(pos + h < total, p + h, 0), p)
    k0 = jnp.broadcast_to(key[0], pos.shape).astype(jnp.uint32)
    k1 = jnp.broadcast_to(key[1], pos.shape).astype(jnp.uint32)
    out_lo, out_hi = threefry2x32_p.bind(k0, k1, j, c1)
    return jnp.where(is_lo, out_lo, out_hi)


def _sliced_randint(
    key: Array, pos: Array, total: int, minval: int, maxval: int
) -> Array:
    """``jax.random.randint(key, (total,), minval, maxval)[pos]`` — the
    double-draw modular-reduction transform of ``jax._src.random._randint``
    replicated op-for-op on sliced bits (int32, static python bounds)."""
    k1, k2 = jax.random.split(key)
    higher = _sliced_bits(k1, pos, total)
    lower = _sliced_bits(k2, pos, total)
    span_i = maxval - minval if maxval > minval else 1
    span = np.uint32(span_i)
    multiplier = np.uint32(((2**16 % span_i) ** 2) % span_i)
    offset = ((higher % span) * multiplier + (lower % span)) % span
    return (minval + offset.astype(jnp.int32)).astype(jnp.int32)


def _sliced_uniform(key: Array, pos: Array, total: int) -> Array:
    """``jax.random.uniform(key, (total,))[pos]``: randomise the mantissa at
    exponent 1, shift to [0, 1) — bit-for-bit the ``_uniform`` transform."""
    bits = _sliced_bits(key, pos, total)
    float_bits = (bits >> np.uint32(9)) | np.float32(1.0).view(np.uint32)
    floats = jax.lax.bitcast_convert_type(float_bits, jnp.float32) - np.float32(1.0)
    return jax.lax.max(
        np.float32(0.0), floats * np.float32(1.0) + np.float32(0.0)
    )


def _sliced_bernoulli(key: Array, p, pos: Array, total: int) -> Array:
    """``jax.random.bernoulli(key, p, (total,))[pos]``."""
    return _sliced_uniform(key, pos, total) < jnp.float32(p)


def generate_key_state(
    cfg: WorkloadConfig, seed: int | Array = 0
) -> tuple[Array, Array]:
    """The per-key state of a trace — ``(natural_node [K] i32,
    object_bytes [K] f32)`` — bit-identical to the corresponding
    :func:`generate_trace` fields, without drawing any request. O(K), drawn
    once per streamed run."""
    _check_region_weights(cfg)
    _, _, _, _, k_nat, k_other = _workload_keys(seed)
    return _natural_nodes(cfg, k_nat), _key_sizes(cfg, k_other)


def _request_window(
    cfg: WorkloadConfig, keys6: tuple[Array, ...], pos: Array, natural: Array
) -> TraceChunk:
    """Per-request fields at arbitrary positions ``pos`` — the streamed
    engine's in-scan spelling (``keys6`` from :func:`_workload_keys`,
    ``natural`` the full ``[K]`` map from :func:`generate_key_state`)."""
    k_hot, k_key, k_node, k_rw, _, k_other = keys6
    r, k, n = cfg.num_requests, cfg.num_keys, cfg.num_nodes

    if cfg.skewed:
        n_hot = max(1, int(k * cfg.hot_fraction))
        pick_hot = _sliced_bernoulli(k_hot, cfg.hot_traffic, pos, r)
        hot_ids = _sliced_randint(k_key, pos, r, 0, n_hot)
        cold_ids = _sliced_randint(
            jax.random.fold_in(k_key, 1), pos, r, n_hot, k
        )
        keys = jnp.where(pick_hot, hot_ids, cold_ids).astype(jnp.int32)
    else:
        keys = _sliced_randint(k_key, pos, r, 0, k).astype(jnp.int32)

    stay = _sliced_bernoulli(k_node, cfg.affinity, pos, r)
    shift = _sliced_randint(k_other, pos, r, 1, n)
    nat_of_key = natural[keys]
    nodes = jnp.where(stay, nat_of_key, (nat_of_key + shift) % n).astype(jnp.int32)

    if cfg.diurnal_shifts > 0:
        phase = (pos.astype(jnp.int32) * cfg.diurnal_shifts) // r
        nodes = ((nodes + phase) % n).astype(jnp.int32)

    is_read = _sliced_bernoulli(k_rw, cfg.read_fraction, pos, r)
    return TraceChunk(keys=keys, nodes=nodes, is_read=is_read)


def generate_trace_chunk(
    cfg: WorkloadConfig,
    seed: int | Array,
    chunk_idx: int | Array,
    chunk_size: int,
    natural: Array | None = None,
) -> TraceChunk:
    """Request positions ``[chunk_idx*chunk_size, (chunk_idx+1)*chunk_size)``
    of the trace ``generate_trace(cfg, seed)`` would materialise —
    **bit-identical to slicing its output** (same ``fold_in`` stream), in
    O(chunk_size) memory.

    ``chunk_idx`` may be traced (the engine calls this inside ``lax.scan``).
    ``natural`` is the full ``[K]`` natural-source map from
    :func:`generate_key_state`; pass it to amortise the O(K) per-key draw
    across chunks (recomputed from ``seed`` when ``None``). Rows whose
    position is ``>= cfg.num_requests`` (a final chunk that overruns the
    trace) carry in-range garbage the caller must mask.
    """
    _check_region_weights(cfg)
    keys6 = _workload_keys(seed)
    if natural is None:
        natural = _natural_nodes(cfg, keys6[4])
    start = jnp.asarray(chunk_idx, jnp.int32) * chunk_size
    pos = start + jnp.arange(chunk_size, dtype=jnp.int32)
    return _request_window(cfg, keys6, pos, natural)


def wan5_workload(**kwargs) -> WorkloadConfig:
    """5-region WAN preset: skewed traffic whose natural sources concentrate
    in two hot regions (pairs with ``cluster.wan5_cluster``)."""
    kwargs.setdefault("num_nodes", 5)
    kwargs.setdefault("skewed", True)
    kwargs.setdefault("region_weights", (0.35, 0.25, 0.20, 0.12, 0.08))
    return WorkloadConfig(**kwargs)


def diurnal_workload(**kwargs) -> WorkloadConfig:
    """Diurnal hot-region preset: traffic concentrated in one region whose
    identity rotates across the trace (pairs with ``cluster.wan5_cluster``
    and a decaying placement daemon)."""
    kwargs.setdefault("num_nodes", 5)
    kwargs.setdefault("skewed", True)
    kwargs.setdefault("region_weights", (0.60, 0.10, 0.10, 0.10, 0.10))
    kwargs.setdefault("diurnal_shifts", 4)
    return WorkloadConfig(**kwargs)
