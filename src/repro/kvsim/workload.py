"""YCSB-style workload generation (paper §8.2) + geo workload presets.

The paper's workloads are permutations of:
  * read ratio: 100% (all reads) → 50% (write-heavy)
  * uniform vs skewed key access — skew = zipfian approximated as
    "10% of the data items requested 90% of the time" (paper's own wording,
    which we implement literally as a two-tier distribution)
  * 100,000 total requests

Geo-distribution model: each key has a *natural request source* (the node
closest to most of its clients — the paper's DNS-routing assumption, §4);
requests for a key arrive at that node with probability ``affinity`` and at a
uniformly random other node otherwise. ``affinity = 1/n`` reduces to fully
uniform sources. This is the knob that makes "bring data closer to the
frequent source" meaningful, and it is an *assumption the paper leaves
implicit* (documented in EXPERIMENTS.md §Repro-assumptions).

Beyond the paper's 3-node testbed, two geo workload classes pair with the
``[N, N]`` RTT topologies in ``cluster.py``:

  * **region-skewed** (``region_weights``): keys' natural sources are drawn
    from a non-uniform distribution over regions — most traffic originates
    in a couple of hot regions, as in real WAN deployments.
  * **diurnal** (``diurnal_shifts``): the hot region *rotates* across the
    trace ("follow the sun") — at phase p every request source is shifted p
    nodes around the ring, so placement must chase moving traffic. This is
    the workload the daemon's beyond-paper count decay exists for.

``generate_trace`` is pure JAX and accepts a traced seed, so the simulator
can ``vmap`` trace generation across CI iterations.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

__all__ = [
    "WorkloadConfig",
    "Trace",
    "generate_trace",
    "wan5_workload",
    "diurnal_workload",
]


class WorkloadConfig(NamedTuple):
    num_requests: int = 100_000  # paper: uniform set of 100k requests
    # The paper does not state the key count; 1000 gives 100 accesses/key
    # under uniform traffic, enough for placement to converge within the
    # trace (calibration constant, see EXPERIMENTS.md §Repro-assumptions).
    num_keys: int = 1_000
    num_nodes: int = 3  # paper testbed: 3 nodes
    read_fraction: float = 1.0  # 1.0 .. 0.5
    skewed: bool = False  # False=uniform, True=zipfian 90/10
    hot_fraction: float = 0.10  # "10% of the data items ..."
    hot_traffic: float = 0.90  # "... 90% of the time"
    # P(request arrives at the key's natural node). The paper's DNS
    # assumption (§4) pins each client to its nearest server and a key's
    # clients are geo-clustered, so the faithful default is 1.0; the
    # affinity-sweep benchmark explores degradation below that.
    affinity: float = 1.0
    # P(natural node = i) per region; None = uniform over nodes. Length must
    # equal num_nodes (hashable tuple so the config stays a jit static).
    region_weights: tuple[float, ...] | None = None
    # >0: request sources rotate `diurnal_shifts` times across the trace —
    # requests in phase p originate (natural + p) % n, so the hot region
    # moves and stale placements decay in value.
    diurnal_shifts: int = 0
    # Per-key payload size distribution, consumed by the placement daemon's
    # capacity projection (per-node replica-byte budgets). Sizes are
    # lognormal: object_bytes × exp(sigma · N(0,1)), drawn once per key from
    # the trace seed; sigma = 0 (default) keeps every object at exactly
    # `object_bytes` — and with an infinite budget the sizes are inert, so
    # the paper's experiments are unchanged.
    object_bytes: float = 1024.0
    object_bytes_sigma: float = 0.0


class Trace(NamedTuple):
    keys: Array  # [R] int32
    nodes: Array  # [R] int32 requesting node
    is_read: Array  # [R] bool
    natural_node: Array  # [K] int32 per-key natural source (ground truth)
    object_bytes: Array  # [K] f32 per-key payload size


def generate_trace(cfg: WorkloadConfig, seed: int | Array = 0) -> Trace:
    if cfg.region_weights is not None and len(cfg.region_weights) != cfg.num_nodes:
        raise ValueError(
            f"region_weights has {len(cfg.region_weights)} entries "
            f"for {cfg.num_nodes} nodes"
        )
    k_hot, k_key, k_node, k_rw, k_nat, k_other = jax.random.split(
        jax.random.PRNGKey(seed), 6
    )
    r, k, n = cfg.num_requests, cfg.num_keys, cfg.num_nodes

    if cfg.skewed:
        # Two-tier zipf approximation, exactly as the paper describes it:
        # hot 10% of keys serve 90% of requests.
        n_hot = max(1, int(k * cfg.hot_fraction))
        pick_hot = jax.random.bernoulli(k_hot, cfg.hot_traffic, (r,))
        hot_ids = jax.random.randint(k_key, (r,), 0, n_hot)
        cold_ids = jax.random.randint(
            jax.random.fold_in(k_key, 1), (r,), n_hot, k
        )
        keys = jnp.where(pick_hot, hot_ids, cold_ids).astype(jnp.int32)
    else:
        keys = jax.random.randint(k_key, (r,), 0, k).astype(jnp.int32)

    if cfg.region_weights is not None:
        w = jnp.asarray(cfg.region_weights, jnp.float32)
        natural = jax.random.choice(k_nat, n, (k,), p=w / jnp.sum(w)).astype(
            jnp.int32
        )
    else:
        natural = jax.random.randint(k_nat, (k,), 0, n).astype(jnp.int32)
    stay = jax.random.bernoulli(k_node, cfg.affinity, (r,))
    # A non-natural request lands uniformly on one of the other n-1 nodes.
    shift = jax.random.randint(k_other, (r,), 1, n)
    nat_of_key = natural[keys]
    nodes = jnp.where(stay, nat_of_key, (nat_of_key + shift) % n).astype(jnp.int32)

    if cfg.diurnal_shifts > 0:
        # "Follow the sun": phase p (p = 0..shifts-1) rotates every request
        # source p nodes around the ring.
        phase = (jnp.arange(r, dtype=jnp.int32) * cfg.diurnal_shifts) // r
        nodes = ((nodes + phase) % n).astype(jnp.int32)

    if cfg.object_bytes_sigma > 0:
        # fold_in (not an extra split) so keys/nodes/reads are byte-identical
        # to traces generated before sizes existed (pinned seed goldens).
        k_size = jax.random.fold_in(k_other, 2)
        sizes = cfg.object_bytes * jnp.exp(
            cfg.object_bytes_sigma * jax.random.normal(k_size, (k,))
        )
    else:
        sizes = jnp.full((k,), cfg.object_bytes, jnp.float32)

    is_read = jax.random.bernoulli(k_rw, cfg.read_fraction, (r,))
    return Trace(
        keys=keys,
        nodes=nodes,
        is_read=is_read,
        natural_node=natural,
        object_bytes=sizes.astype(jnp.float32),
    )


def wan5_workload(**kwargs) -> WorkloadConfig:
    """5-region WAN preset: skewed traffic whose natural sources concentrate
    in two hot regions (pairs with ``cluster.wan5_cluster``)."""
    kwargs.setdefault("num_nodes", 5)
    kwargs.setdefault("skewed", True)
    kwargs.setdefault("region_weights", (0.35, 0.25, 0.20, 0.12, 0.08))
    return WorkloadConfig(**kwargs)


def diurnal_workload(**kwargs) -> WorkloadConfig:
    """Diurnal hot-region preset: traffic concentrated in one region whose
    identity rotates across the trace (pairs with ``cluster.wan5_cluster``
    and a decaying placement daemon)."""
    kwargs.setdefault("num_nodes", 5)
    kwargs.setdefault("skewed", True)
    kwargs.setdefault("region_weights", (0.60, 0.10, 0.10, 0.10, 0.10))
    kwargs.setdefault("diurnal_shifts", 4)
    return WorkloadConfig(**kwargs)
