"""YCSB-style workload generation (paper §8.2).

The paper's workloads are permutations of:
  * read ratio: 100% (all reads) → 50% (write-heavy)
  * uniform vs skewed key access — skew = zipfian approximated as
    "10% of the data items requested 90% of the time" (paper's own wording,
    which we implement literally as a two-tier distribution)
  * 100,000 total requests

Geo-distribution model: each key has a *natural request source* (the node
closest to most of its clients — the paper's DNS-routing assumption, §4);
requests for a key arrive at that node with probability ``affinity`` and at a
uniformly random other node otherwise. ``affinity = 1/n`` reduces to fully
uniform sources. This is the knob that makes "bring data closer to the
frequent source" meaningful, and it is an *assumption the paper leaves
implicit* (documented in EXPERIMENTS.md §Repro-assumptions).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

__all__ = ["WorkloadConfig", "Trace", "generate_trace"]


class WorkloadConfig(NamedTuple):
    num_requests: int = 100_000  # paper: uniform set of 100k requests
    # The paper does not state the key count; 1000 gives 100 accesses/key
    # under uniform traffic, enough for placement to converge within the
    # trace (calibration constant, see EXPERIMENTS.md §Repro-assumptions).
    num_keys: int = 1_000
    num_nodes: int = 3  # paper testbed: 3 nodes
    read_fraction: float = 1.0  # 1.0 .. 0.5
    skewed: bool = False  # False=uniform, True=zipfian 90/10
    hot_fraction: float = 0.10  # "10% of the data items ..."
    hot_traffic: float = 0.90  # "... 90% of the time"
    # P(request arrives at the key's natural node). The paper's DNS
    # assumption (§4) pins each client to its nearest server and a key's
    # clients are geo-clustered, so the faithful default is 1.0; the
    # affinity-sweep benchmark explores degradation below that.
    affinity: float = 1.0


class Trace(NamedTuple):
    keys: Array  # [R] int32
    nodes: Array  # [R] int32 requesting node
    is_read: Array  # [R] bool
    natural_node: Array  # [K] int32 per-key natural source (ground truth)


def generate_trace(cfg: WorkloadConfig, seed: int = 0) -> Trace:
    k_hot, k_key, k_node, k_rw, k_nat, k_other = jax.random.split(
        jax.random.PRNGKey(seed), 6
    )
    r, k, n = cfg.num_requests, cfg.num_keys, cfg.num_nodes

    if cfg.skewed:
        # Two-tier zipf approximation, exactly as the paper describes it:
        # hot 10% of keys serve 90% of requests.
        n_hot = max(1, int(k * cfg.hot_fraction))
        pick_hot = jax.random.bernoulli(k_hot, cfg.hot_traffic, (r,))
        hot_ids = jax.random.randint(k_key, (r,), 0, n_hot)
        cold_ids = jax.random.randint(
            jax.random.fold_in(k_key, 1), (r,), n_hot, k
        )
        keys = jnp.where(pick_hot, hot_ids, cold_ids).astype(jnp.int32)
    else:
        keys = jax.random.randint(k_key, (r,), 0, k).astype(jnp.int32)

    natural = jax.random.randint(k_nat, (k,), 0, n).astype(jnp.int32)
    stay = jax.random.bernoulli(k_node, cfg.affinity, (r,))
    # A non-natural request lands uniformly on one of the other n-1 nodes.
    shift = jax.random.randint(k_other, (r,), 1, n)
    nat_of_key = natural[keys]
    nodes = jnp.where(stay, nat_of_key, (nat_of_key + shift) % n).astype(jnp.int32)

    is_read = jax.random.bernoulli(k_rw, cfg.read_fraction, (r,))
    return Trace(keys=keys, nodes=nodes, is_read=is_read, natural_node=natural)
