"""Trace-driven simulation of the paper's experiment (§8–§9).

Reproduces the three scenarios of Figure 2/3 — Local / Remote / Optimized —
on YCSB-style traces (``workload.py``) with the paper's latency model
generalised to an ``[N, N]`` RTT topology (``cluster.py``). The OPTIMIZED
scenario runs the *actual* core engine (metadata layer + ownership
coefficient + scored placement pipeline), not a model of it: requests fold
accesses into a :class:`repro.core.MetadataStore` and the placement daemon
sweeps between request chunks, exactly like the paper's offline
RedynisDaemon. With finite per-node replica budgets
(``ClusterConfig.capacity_bytes``) the sweep's capacity projection stage
trims adds and evicts cold replicas, and the run reports eviction /
occupancy metrics; at the default infinite budget the projection compiles
away and the engine is bit-identical to the paper's Algorithm 3.

Execution model
---------------
The trace is processed in chunks of ``daemon_interval`` requests. Within a
chunk every request sees the replica map *frozen at chunk start* — this is
the paper's non-blocking property: in-flight requests are never stalled by
the daemon; they observe the previous placement until the sweep commits.
Metadata updates (access logging) fold in continuously, as in Algorithm 1.
Per-node occupancy (replica bytes) is sampled on the same frozen map, and
``peak_occupancy_bytes`` is its running elementwise max.

Two engines with identical semantics:

  * ``run_scenario`` — the fused fast path: ONE ``jax.lax.scan`` over
    fixed-shape chunks with the daemon sweep ``due``-masked inside the scan
    body (``repro.core.placement.masked_step``), so a whole scenario is a
    single compiled program instead of one dispatch per chunk.
    ``run_experiment`` additionally ``vmap``s the seed (CI-iteration)
    dimension, so a full read-ratio row runs as one batched program.
    ``backend="pallas"`` routes the sweep's [K, N] pass through the
    ``kernels.ownership_sweep`` Pallas kernel (interpret mode off-TPU).
  * ``run_scenario_reference`` — the retained slow path: the original
    per-chunk Python loop. It exists as the regression oracle for the fused
    engine (see tests/test_simulate_equivalence.py) and accumulates in
    float64; equivalence is allclose, not bit-identical.

Throughput model
----------------
Nodes serve their request streams concurrently (the paper's three
application servers). Per-node busy time = Σ latency of requests arriving at
that node; makespan = max over nodes; throughput = R / makespan. The paper
does not state the YCSB per-op service cost; ``ClusterConfig.service_ms`` is
the calibration constant (documented in EXPERIMENTS.md §Repro-assumptions).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.metadata import create_store, record_accesses
from repro.core.placement import PlacementDaemon, masked_step
from repro.kvsim.cluster import (
    ClusterConfig,
    Scenario,
    read_latency_geo,
    write_latency_geo,
)
from repro.kvsim.workload import Trace, WorkloadConfig, generate_trace

__all__ = [
    "SimResult",
    "run_scenario",
    "run_scenario_reference",
    "run_experiment",
    "confidence_interval_99",
]


class SimResult(NamedTuple):
    """Aggregate metrics for one scenario run (one seed)."""

    throughput_ops_s: float
    hit_rate: float
    mean_latency_ms: float
    node_busy_ms: np.ndarray  # [N]
    replication_moves: float  # replicas created by the daemon
    deletion_moves: float  # replicas dropped by the daemon (all causes)
    evictions: float  # subset of deletions caused by key expiry
    capacity_evictions: float  # held replicas evicted by the budget projection
    peak_occupancy_bytes: np.ndarray  # [N] peak replica bytes per node


def _initial_hosts(natural_node: Array, num_keys: int, num_nodes: int, scenario: Scenario) -> Array:
    """Starting replica map per scenario (paper §9 scenario definitions)."""
    if scenario in (Scenario.LOCAL, Scenario.REPLICATED):
        return jnp.ones((num_keys, num_nodes), dtype=bool)
    # REMOTE / OPTIMIZED: each key starts on a single node that is *not* its
    # natural request source ("requests ... served not available on the local
    # key-value store"), so both start from the worst-case placement.
    home = (natural_node + 1) % num_nodes
    return jax.nn.one_hot(home, num_nodes, dtype=bool)


def _chunk_latency(
    hosts: Array,  # [K, N] frozen replica map
    keys: Array,  # [B]
    nodes: Array,  # [B]
    is_read: Array,  # [B]
    rtt: Array,  # [N, N]
    cluster: ClusterConfig,
    scenario: Scenario,
) -> tuple[Array, Array]:
    """Per-request latency + hit flags for one chunk under a frozen map."""
    b = keys.shape[0]
    if scenario is Scenario.LOCAL:
        # The paper's "theoretically ideal scenario": everything local.
        hit = jnp.ones_like(is_read)
        return jnp.full((b,), cluster.service_ms, jnp.float32), hit & is_read

    replicas = hosts[keys]  # [B, N]
    hit = replicas[jnp.arange(b), nodes]
    if scenario is Scenario.REMOTE:
        # "No local replicas ever": the requesting node's own copy (if any)
        # is invisible to reads, so every op pays a WAN hop; with an empty
        # visible set the orphan guard charges the topology's worst RTT —
        # exactly the flat model's unconditional remote_ms.
        read_replicas = replicas & (jnp.arange(hosts.shape[1])[None, :] != nodes[:, None])
        hit = jnp.zeros_like(hit)
    else:
        read_replicas = replicas
    r_lat = read_latency_geo(cluster, rtt, read_replicas, nodes)

    owner_count = jnp.sum(replicas, axis=-1)
    sole_local = hit & (owner_count == 1)
    if scenario is Scenario.REMOTE:
        sole_local = jnp.zeros_like(sole_local)
    w_lat = write_latency_geo(cluster, rtt, replicas, nodes, sole_local)

    lat = jnp.where(is_read, r_lat, w_lat)
    return lat, hit & is_read


_chunk_latency_jit = jax.jit(
    _chunk_latency, static_argnames=("cluster", "scenario")
)


def _node_occupancy(hosts: Array, object_bytes: Array) -> Array:
    """Per-node replica bytes ``[N]`` under a replica map (both engines use
    this exact expression so their peaks agree bit-for-bit)."""
    return jnp.sum(jnp.where(hosts, object_bytes[:, None], 0.0), axis=0)


def _make_daemon(
    workload: WorkloadConfig,
    ownership_coefficient: float | None,
    expiry_ticks: int | None,
    decay: float,
    period: int = 1,
    backend: str = "jax",
) -> PlacementDaemon:
    """Host-side construction so H is validated against N (paper eq. 3) and
    the sweep backend is validated before any tracing happens."""
    return PlacementDaemon(
        num_nodes=workload.num_nodes,
        h=ownership_coefficient,
        expiry=expiry_ticks,
        decay=decay,
        period=period,
        backend=backend,
    )


def _check_topology(workload: WorkloadConfig, cluster: ClusterConfig) -> None:
    if workload.num_nodes != cluster.num_nodes:
        raise ValueError(
            f"workload has {workload.num_nodes} nodes but cluster topology "
            f"has {cluster.num_nodes}"
        )
    if cluster.rtt is not None and len(cluster.rtt) != cluster.num_nodes:
        raise ValueError(
            f"rtt matrix is {len(cluster.rtt)}x{len(cluster.rtt[0])} but "
            f"num_nodes={cluster.num_nodes}"
        )
    if (
        isinstance(cluster.capacity_bytes, tuple)
        and len(cluster.capacity_bytes) != cluster.num_nodes
    ):
        raise ValueError(
            f"capacity_bytes has {len(cluster.capacity_bytes)} entries for "
            f"num_nodes={cluster.num_nodes}"
        )


def _seed_store(hosts: Array, num_keys: int, num_nodes: int):
    """Metadata layer seeded with the initial placement (Algorithm 1's
    "metadata == null -> generate metadata object" happened at load time)."""
    return create_store(num_keys, num_nodes)._replace(
        hosts=hosts,
        live=jnp.ones((num_keys,), dtype=bool),
        home=jnp.argmax(hosts, axis=-1).astype(jnp.int32),
    )


# ---------------------------------------------------------------------------
# Fused engine: one lax.scan over chunks, daemon due-masked inside the body.
# ---------------------------------------------------------------------------

_SIM_STATICS = (
    "cluster",
    "scenario",
    "daemon_interval",
    "h",
    "expiry",
    "decay",
    "period",
    "backend",
)


def _simulate(
    keys: Array,  # [R]
    nodes: Array,  # [R]
    is_read: Array,  # [R]
    natural: Array,  # [K]
    object_bytes: Array,  # [K]
    *,
    cluster: ClusterConfig,
    scenario: Scenario,
    daemon_interval: int,
    h: float,
    expiry: int | None,
    decay: float,
    period: int,
    backend: str,
):
    """Whole-scenario simulation as a single fixed-shape scan program.

    The trace is padded to ``num_chunks * daemon_interval`` with ``valid``-
    masked rows (zero latency, zero metadata weight), so every chunk has one
    shape and the Python loop collapses into ``jax.lax.scan``.
    """
    r = keys.shape[0]
    num_keys = natural.shape[0]
    n = cluster.num_nodes
    rtt = cluster.rtt_matrix()
    # Host-side static: at the default infinite budget the projection stage
    # is skipped entirely (capacity=None), keeping Algorithm 3 bit-exact.
    capacity = (
        cluster.capacity_vector() if cluster.has_finite_capacity else None
    )

    num_chunks = -(-r // daemon_interval)
    pad = num_chunks * daemon_interval - r

    def chunked(x: Array) -> Array:
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
        return x.reshape(num_chunks, daemon_interval)

    xs = (
        jnp.arange(num_chunks, dtype=jnp.int32),
        chunked(keys),
        chunked(nodes),
        chunked(is_read),
        (jnp.arange(num_chunks * daemon_interval) < r).reshape(
            num_chunks, daemon_interval
        ),
    )

    store = _seed_store(_initial_hosts(natural, num_keys, n, scenario), num_keys, n)
    obj = jnp.asarray(object_bytes, jnp.float32)
    zero = jnp.float32(0.0)
    init = (
        store,
        jnp.zeros((n,), jnp.float32),  # busy
        zero,  # lat_sum
        zero,  # hits
        zero,  # reads
        zero,  # repl
        zero,  # drop
        zero,  # evic (expiry)
        zero,  # cap_evic
        # Peak occupancy starts at the initial map; only OPTIMIZED mutates
        # the map, so only its scan body re-samples occupancy per chunk.
        _node_occupancy(store.hosts, obj),
    )

    def body(carry, x):
        store, busy, lat_sum, hits, reads, repl, drop, evic, cap_evic, peak = carry
        c, ck, cn, cr, cv = x
        lat, read_hits = _chunk_latency(store.hosts, ck, cn, cr, rtt, cluster, scenario)
        lat = jnp.where(cv, lat, 0.0)
        busy = busy.at[cn].add(lat)
        lat_sum = lat_sum + jnp.sum(lat)
        hits = hits + jnp.sum((read_hits & cv).astype(jnp.float32))
        reads = reads + jnp.sum((cr & cv).astype(jnp.float32))
        if scenario is Scenario.OPTIMIZED:
            # Occupancy is sampled on the same frozen-at-chunk-start map the
            # requests see (the initial placement seeds the peak).
            peak = jnp.maximum(peak, _node_occupancy(store.hosts, obj))
            # Algorithm 1 bookkeeping: log usage heuristics per request.
            store = record_accesses(store, ck, cn, now=c, valid=cv)
            stats, store = masked_step(
                store,
                c,
                (c % period) == 0,
                h=h,
                expiry=expiry,
                decay=decay,
                object_bytes=obj,
                capacity_bytes=capacity,
                backend=backend,
            )
            repl = repl + stats.adds
            drop = drop + stats.drops
            evic = evic + stats.expiry_evictions
            cap_evic = cap_evic + stats.capacity_evictions
        return (
            store, busy, lat_sum, hits, reads, repl, drop, evic, cap_evic, peak
        ), None

    (_, busy, lat_sum, hits, reads, repl, drop, evic, cap_evic, peak), _ = (
        jax.lax.scan(body, init, xs)
    )
    makespan_ms = jnp.max(busy)
    return (
        r / (makespan_ms / 1000.0),
        hits / jnp.maximum(reads, 1.0),
        lat_sum / r,
        busy,
        repl,
        drop,
        evic,
        cap_evic,
        peak,
    )


_simulate_jit = partial(jax.jit, static_argnames=_SIM_STATICS)(_simulate)


@partial(jax.jit, static_argnames=_SIM_STATICS)
def _simulate_batch(keys, nodes, is_read, natural, object_bytes, **statics):
    """Seed-batched fused engine: vmap over the leading (iteration) axis."""
    return jax.vmap(lambda a, b, c, d, e: _simulate(a, b, c, d, e, **statics))(
        keys, nodes, is_read, natural, object_bytes
    )


@partial(jax.jit, static_argnames=("cfg",))
def _traces_for_seeds(cfg: WorkloadConfig, seeds: Array) -> Trace:
    """Batched trace generation (seed axis leading on every field)."""
    return jax.vmap(lambda s: generate_trace(cfg, s))(seeds)


def run_scenario(
    workload: WorkloadConfig,
    cluster: ClusterConfig,
    scenario: Scenario,
    seed: int = 0,
    daemon_interval: int = 1000,
    ownership_coefficient: float | None = None,
    expiry_ticks: int | None = None,
    decay: float = 1.0,
    daemon_period: int = 1,
    backend: str = "jax",
) -> SimResult:
    """Simulate one scenario over one generated trace (fused scan engine).

    daemon_period: sweep every `daemon_period`-th chunk (1 = every chunk);
    off chunks take the not-due branch of `masked_step`.
    backend: "jax" or "pallas" — which sweep backend the daemon routes its
    [K, N] analysis pass through.
    """
    _check_topology(workload, cluster)
    daemon = _make_daemon(
        workload, ownership_coefficient, expiry_ticks, decay, daemon_period,
        backend,
    )
    trace = generate_trace(workload, seed)
    tput, hit, mean_lat, busy, repl, drop, evic, cap_evic, peak = _simulate_jit(
        trace.keys,
        trace.nodes,
        trace.is_read,
        trace.natural_node,
        trace.object_bytes,
        cluster=cluster,
        scenario=scenario,
        daemon_interval=daemon_interval,
        h=daemon.h,
        expiry=daemon.expiry,
        decay=daemon.decay,
        period=daemon.period,
        backend=daemon.backend,
    )
    return SimResult(
        throughput_ops_s=float(tput),
        hit_rate=float(hit),
        mean_latency_ms=float(mean_lat),
        node_busy_ms=np.asarray(busy, dtype=np.float64),
        replication_moves=float(repl),
        deletion_moves=float(drop),
        evictions=float(evic),
        capacity_evictions=float(cap_evic),
        peak_occupancy_bytes=np.asarray(peak, dtype=np.float64),
    )


# ---------------------------------------------------------------------------
# Reference engine: the original per-chunk Python loop, kept as the oracle.
# ---------------------------------------------------------------------------


def run_scenario_reference(
    workload: WorkloadConfig,
    cluster: ClusterConfig,
    scenario: Scenario,
    seed: int = 0,
    daemon_interval: int = 1000,
    ownership_coefficient: float | None = None,
    expiry_ticks: int | None = None,
    decay: float = 1.0,
    daemon_period: int = 1,
    backend: str = "jax",
) -> SimResult:
    """Slow-path reference: one host dispatch per chunk, daemon stepped with
    Python control flow. Semantically identical to :func:`run_scenario`."""
    _check_topology(workload, cluster)
    trace = generate_trace(workload, seed)
    k, n, r = workload.num_keys, workload.num_nodes, workload.num_requests
    rtt = cluster.rtt_matrix()
    capacity = (
        cluster.capacity_vector() if cluster.has_finite_capacity else None
    )

    daemon = _make_daemon(
        workload, ownership_coefficient, expiry_ticks, decay, daemon_period,
        backend,
    )
    store = _seed_store(
        _initial_hosts(trace.natural_node, k, n, scenario), k, n
    )

    total_lat = np.zeros((n,), dtype=np.float64)
    hits = 0.0
    reads = 0.0
    lat_sum = 0.0
    repl_moves = 0.0
    drop_moves = 0.0
    evictions = 0.0
    cap_evictions = 0.0
    peak_occ = np.asarray(
        _node_occupancy(store.hosts, trace.object_bytes), dtype=np.float64
    )

    num_chunks = (r + daemon_interval - 1) // daemon_interval
    for c in range(num_chunks):
        lo, hi = c * daemon_interval, min((c + 1) * daemon_interval, r)
        keys = trace.keys[lo:hi]
        nodes = trace.nodes[lo:hi]
        is_read = trace.is_read[lo:hi]

        lat, read_hits = _chunk_latency_jit(
            store.hosts, keys, nodes, is_read, rtt, cluster, scenario
        )
        busy = jnp.zeros((n,), jnp.float32).at[nodes].add(lat)
        total_lat += np.asarray(busy, dtype=np.float64)
        lat_sum += float(jnp.sum(lat))
        hits += float(jnp.sum(read_hits))
        reads += float(jnp.sum(is_read))

        if scenario is Scenario.OPTIMIZED:
            peak_occ = np.maximum(
                peak_occ,
                np.asarray(
                    _node_occupancy(store.hosts, trace.object_bytes),
                    dtype=np.float64,
                ),
            )
            # Algorithm 1 bookkeeping: log usage heuristics per request.
            store = record_accesses(store, keys, nodes, now=c)
            if daemon.due(c):
                plan, store = daemon.step(
                    store,
                    now=c,
                    object_bytes=trace.object_bytes,
                    capacity_bytes=capacity,
                )
                repl_moves += float(jnp.sum(plan.to_add))
                drop_moves += float(jnp.sum(plan.to_drop))
                evictions += float(
                    jnp.sum(plan.to_drop & plan.expired[:, None])
                )
                cap_evictions += float(jnp.sum(plan.capacity_evicted))

    makespan_ms = float(total_lat.max())
    return SimResult(
        throughput_ops_s=r / (makespan_ms / 1000.0),
        hit_rate=hits / max(reads, 1.0),
        mean_latency_ms=lat_sum / r,
        node_busy_ms=total_lat,
        replication_moves=repl_moves,
        deletion_moves=drop_moves,
        evictions=evictions,
        capacity_evictions=cap_evictions,
        peak_occupancy_bytes=peak_occ,
    )


def confidence_interval_99(samples: np.ndarray) -> tuple[float, float]:
    """Mean ± 99% CI half-width (normal approx — matches the paper's error
    bars over repeated iterations)."""
    mean = float(np.mean(samples))
    if len(samples) < 2:
        return mean, 0.0
    sem = float(np.std(samples, ddof=1) / np.sqrt(len(samples)))
    return mean, 2.576 * sem


def run_experiment(
    read_fractions: tuple[float, ...] = (1.0, 0.9, 0.75, 0.5),
    skewed: bool = False,
    iterations: int = 5,
    num_requests: int = 100_000,
    cluster: ClusterConfig | None = None,
    engine: str = "scan",
    daemon_interval: int = 1000,
    backend: str = "jax",
    **workload_kwargs,
) -> dict:
    """Paper Figure 2/3: all scenarios × read ratios, with 99% CIs.

    engine="scan" (default) runs every CI iteration of a read-ratio row as
    one vmapped program; engine="reference" replays the retained per-chunk
    Python loop (the oracle the equivalence tests pin the scan engine to).
    backend selects the daemon's sweep backend ("jax" | "pallas").
    """
    if cluster is None:
        cluster = ClusterConfig()
    workload_kwargs.setdefault("num_nodes", cluster.num_nodes)
    if engine not in ("scan", "reference"):
        raise ValueError(f"unknown engine {engine!r}")
    out: dict = {"skewed": skewed, "read_fractions": list(read_fractions), "scenarios": {}}
    for scenario in Scenario:
        rows = []
        for rf in read_fractions:
            wl = WorkloadConfig(
                num_requests=num_requests,
                read_fraction=rf,
                skewed=skewed,
                **workload_kwargs,
            )
            if engine == "reference":
                samples = np.array(
                    [
                        run_scenario_reference(
                            wl, cluster, scenario, seed=it,
                            daemon_interval=daemon_interval, backend=backend,
                        ).throughput_ops_s
                        for it in range(iterations)
                    ]
                )
                hit = run_scenario_reference(
                    wl, cluster, scenario, seed=0,
                    daemon_interval=daemon_interval, backend=backend,
                ).hit_rate
            else:
                _check_topology(wl, cluster)
                daemon = _make_daemon(wl, None, None, 1.0, 1, backend)
                traces = _traces_for_seeds(
                    wl, jnp.arange(iterations, dtype=jnp.int32)
                )
                tput, hit_b, *_ = _simulate_batch(
                    traces.keys,
                    traces.nodes,
                    traces.is_read,
                    traces.natural_node,
                    traces.object_bytes,
                    cluster=cluster,
                    scenario=scenario,
                    daemon_interval=daemon_interval,
                    h=daemon.h,
                    expiry=daemon.expiry,
                    decay=daemon.decay,
                    period=daemon.period,
                    backend=daemon.backend,
                )
                samples = np.asarray(tput, dtype=np.float64)
                hit = float(hit_b[0])
            mean, ci = confidence_interval_99(samples)
            rows.append(
                {"read_fraction": rf, "throughput": mean, "ci99": ci, "hit_rate": hit}
            )
        out["scenarios"][scenario.value] = rows
    return out
