"""Trace-driven simulation of the paper's experiment (§8–§9).

Reproduces the three scenarios of Figure 2/3 — Local / Remote / Optimized —
on YCSB-style traces (``workload.py``) with the paper's latency model
(``cluster.py``). The OPTIMIZED scenario runs the *actual* core engine
(metadata layer + ownership coefficient + placement daemon), not a model of
it: requests fold accesses into a :class:`repro.core.MetadataStore` and the
:class:`repro.core.PlacementDaemon` sweeps between request chunks, exactly
like the paper's offline RedynisDaemon.

Execution model
---------------
The trace is processed in chunks of ``daemon_interval`` requests. Within a
chunk every request sees the replica map *frozen at chunk start* — this is
the paper's non-blocking property: in-flight requests are never stalled by
the daemon; they observe the previous placement until the sweep commits.
Metadata updates (access logging) fold in continuously, as in Algorithm 1.

Throughput model
----------------
Nodes serve their request streams concurrently (the paper's three
application servers). Per-node busy time = Σ latency of requests arriving at
that node; makespan = max over nodes; throughput = R / makespan. The paper
does not state the YCSB per-op service cost; ``ClusterConfig.service_ms`` is
the calibration constant (documented in EXPERIMENTS.md §Repro-assumptions).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.metadata import MetadataStore, create_store, record_accesses
from repro.core.placement import PlacementDaemon
from repro.kvsim.cluster import ClusterConfig, Scenario, read_latency, write_latency
from repro.kvsim.workload import Trace, WorkloadConfig, generate_trace

__all__ = ["SimResult", "run_scenario", "run_experiment", "confidence_interval_99"]


class SimResult(NamedTuple):
    """Aggregate metrics for one scenario run (one seed)."""

    throughput_ops_s: float
    hit_rate: float
    mean_latency_ms: float
    node_busy_ms: np.ndarray  # [N]
    replication_moves: float  # replicas created by the daemon
    deletion_moves: float  # replicas dropped by the daemon


def _initial_hosts(trace: Trace, num_keys: int, num_nodes: int, scenario: Scenario) -> Array:
    """Starting replica map per scenario (paper §9 scenario definitions)."""
    if scenario in (Scenario.LOCAL, Scenario.REPLICATED):
        return jnp.ones((num_keys, num_nodes), dtype=bool)
    # REMOTE / OPTIMIZED: each key starts on a single node that is *not* its
    # natural request source ("requests ... served not available on the local
    # key-value store"), so both start from the worst-case placement.
    home = (trace.natural_node + 1) % num_nodes
    return jax.nn.one_hot(home, num_nodes, dtype=bool)


@partial(jax.jit, static_argnames=("cluster", "scenario"))
def _chunk_latency(
    hosts: Array,  # [K, N] frozen replica map
    keys: Array,  # [B]
    nodes: Array,  # [B]
    is_read: Array,  # [B]
    cluster: ClusterConfig,
    scenario: Scenario,
) -> tuple[Array, Array]:
    """Per-request latency + hit flags for one chunk under a frozen map."""
    if scenario is Scenario.LOCAL:
        # The paper's "theoretically ideal scenario": everything local.
        hit = jnp.ones_like(is_read)
        return jnp.full(keys.shape, cluster.service_ms, jnp.float32), hit & is_read
    if scenario is Scenario.REMOTE:
        hit = jnp.zeros_like(is_read)  # every request pays the RTT
    else:
        hit = hosts[keys, nodes]
    r_lat = read_latency(cluster, hit)

    owner_count = jnp.sum(hosts[keys], axis=-1)
    sole_local = hit & (owner_count == 1)
    if scenario is Scenario.REMOTE:
        sole_local = jnp.zeros_like(sole_local)
    owners_not_master = hosts[keys].at[:, cluster.master].set(False)
    any_remote_from_master = jnp.any(owners_not_master, axis=-1)
    w_lat = write_latency(cluster, nodes, sole_local, any_remote_from_master)

    lat = jnp.where(is_read, r_lat, w_lat)
    return lat, hit & is_read


def run_scenario(
    workload: WorkloadConfig,
    cluster: ClusterConfig,
    scenario: Scenario,
    seed: int = 0,
    daemon_interval: int = 1000,
    ownership_coefficient: float | None = None,
    expiry_ticks: int | None = None,
) -> SimResult:
    """Simulate one scenario over one generated trace."""
    trace = generate_trace(workload, seed)
    k, n, r = workload.num_keys, workload.num_nodes, workload.num_requests
    hosts = _initial_hosts(trace, k, n, scenario)

    daemon = PlacementDaemon(
        num_nodes=n,
        h=ownership_coefficient,
        expiry=expiry_ticks,
    )
    store = create_store(k, n)
    # Seed the metadata layer with the initial placement (Algorithm 1's
    # "metadata == null -> generate metadata object" happened at load time).
    store = store._replace(
        hosts=hosts,
        live=jnp.ones((k,), dtype=bool),
        home=jnp.argmax(hosts, axis=-1).astype(jnp.int32),
    )

    total_lat = np.zeros((n,), dtype=np.float64)
    hits = 0.0
    reads = 0.0
    lat_sum = 0.0
    repl_moves = 0.0
    drop_moves = 0.0

    num_chunks = (r + daemon_interval - 1) // daemon_interval
    for c in range(num_chunks):
        lo, hi = c * daemon_interval, min((c + 1) * daemon_interval, r)
        keys = trace.keys[lo:hi]
        nodes = trace.nodes[lo:hi]
        is_read = trace.is_read[lo:hi]

        lat, read_hits = _chunk_latency(
            store.hosts, keys, nodes, is_read, cluster, scenario
        )
        busy = jnp.zeros((n,), jnp.float32).at[nodes].add(lat)
        total_lat += np.asarray(busy, dtype=np.float64)
        lat_sum += float(jnp.sum(lat))
        hits += float(jnp.sum(read_hits))
        reads += float(jnp.sum(is_read))

        if scenario is Scenario.OPTIMIZED:
            # Algorithm 1 bookkeeping: log usage heuristics per request.
            store = record_accesses(store, keys, nodes, now=c)
            if daemon.due(c):
                plan, store = daemon.step(store, now=c)
                repl_moves += float(jnp.sum(plan.to_add))
                drop_moves += float(jnp.sum(plan.to_drop))

    makespan_ms = float(total_lat.max())
    return SimResult(
        throughput_ops_s=r / (makespan_ms / 1000.0),
        hit_rate=hits / max(reads, 1.0),
        mean_latency_ms=lat_sum / r,
        node_busy_ms=total_lat,
        replication_moves=repl_moves,
        deletion_moves=drop_moves,
    )


def confidence_interval_99(samples: np.ndarray) -> tuple[float, float]:
    """Mean ± 99% CI half-width (normal approx — matches the paper's error
    bars over repeated iterations)."""
    mean = float(np.mean(samples))
    if len(samples) < 2:
        return mean, 0.0
    sem = float(np.std(samples, ddof=1) / np.sqrt(len(samples)))
    return mean, 2.576 * sem


def run_experiment(
    read_fractions: tuple[float, ...] = (1.0, 0.9, 0.75, 0.5),
    skewed: bool = False,
    iterations: int = 5,
    num_requests: int = 100_000,
    **workload_kwargs,
) -> dict:
    """Paper Figure 2/3: all three scenarios × read ratios, with 99% CIs."""
    cluster = ClusterConfig()
    out: dict = {"skewed": skewed, "read_fractions": list(read_fractions), "scenarios": {}}
    for scenario in Scenario:
        rows = []
        for rf in read_fractions:
            wl = WorkloadConfig(
                num_requests=num_requests,
                read_fraction=rf,
                skewed=skewed,
                **workload_kwargs,
            )
            samples = np.array(
                [
                    run_scenario(wl, cluster, scenario, seed=it).throughput_ops_s
                    for it in range(iterations)
                ]
            )
            mean, ci = confidence_interval_99(samples)
            hit = run_scenario(wl, cluster, scenario, seed=0).hit_rate
            rows.append(
                {"read_fraction": rf, "throughput": mean, "ci99": ci, "hit_rate": hit}
            )
        out["scenarios"][scenario.value] = rows
    return out
