"""Trace-driven simulation of the paper's experiment (§8–§9).

Reproduces the paper's Figure 2/3 experiment on YCSB-style traces
(``workload.py``) with the latency model generalised to an ``[N, N]`` RTT
topology (``cluster.py``) — under any *placement policy* from
``repro.core.policy``. The decision rule is a first-class value::

    run_scenario(workload, cluster, RedynisPolicy(h=0.2))
    run_scenario(workload, cluster, StaticPolicy(mode="remote"))

The legacy ``Scenario`` enum spelling and its kwarg sprawl
(``ownership_coefficient`` / ``expiry_ticks`` / ``decay`` /
``daemon_period`` / ``backend``) were removed once their one-release
deprecation window closed; passing a ``Scenario`` where a policy belongs
now raises with the replacement spelled out.

An *active* policy (``policy.is_active``) runs the actual core engine —
requests fold accesses into a :class:`repro.core.MetadataStore` and the
policy decides between request chunks through the shared pipeline
(fractions → decide → expiry → capacity projection), exactly like the
paper's offline RedynisDaemon. With finite per-node replica budgets
(``ClusterConfig.capacity_bytes``) the projection stage trims adds and
evicts cold replicas uniformly for every policy; at the default infinite
budget it compiles away and ``RedynisPolicy`` is bit-identical to the
paper's Algorithm 3. Static policies freeze the replica map and the whole
decision machinery compiles away.

Execution model
---------------
The trace is processed in chunks of ``daemon_interval`` requests. Within a
chunk every request sees the replica map *frozen at chunk start* — this is
the paper's non-blocking property: in-flight requests are never stalled by
the daemon; they observe the previous placement until the sweep commits.
Metadata updates (access logging) fold in continuously, as in Algorithm 1.
Per-node occupancy (replica bytes) is sampled on the same frozen map for
*every* policy, and ``peak_occupancy_bytes`` is its running elementwise max
(static policies never mutate the map, so their per-chunk peak equals the
initial-map occupancy the seed engine reported).

Two engines with identical semantics:

  * ``run_scenario`` — the fused fast path: ONE ``jax.lax.scan`` over
    fixed-shape chunks with the policy step ``due``-masked inside the scan
    body (``repro.core.policy.policy_masked_step``), so a whole scenario is
    a single compiled program. The policy's *static key* is the jit static
    while its dynamic hyperparameters (H, decay, K, thresholds) are traced
    — re-running with new knob values never recompiles. ``run_experiment``
    additionally ``vmap``s the seed (CI-iteration) dimension, and its
    ``policies=[...]`` axis stacks same-family dynamic params and vmaps the
    *policy* dimension alongside seeds — a head-to-head grid as one batched
    program.
  * ``run_scenario_reference`` — the retained slow path: the original
    per-chunk Python loop. It exists as the regression oracle for the fused
    engine (see tests/test_simulate_equivalence.py) and accumulates in
    float64; equivalence is allclose, not bit-identical.

Chunk-replay backends
---------------------
The per-chunk request path (replica gather → read/write latency → hit
flags → busy accumulation → telemetry histogram fold) is the hot loop of
every experiment, and lives in the ``repro.kernels.chunk_replay`` trio.
``replay_backend`` selects its implementation, mirroring the ownership
sweep's backend plumbing:

  * ``"jax"`` (default) — the pure-jnp composition, kept op-for-op
    identical to the pre-fusion engine so every aggregate stays bit-exact
    with the seed goldens. The engine additionally hoists the O(K·N)
    per-chunk occupancy sample out of the scan body for *inactive*
    policies (a static map never changes, so its occupancy is a loop
    constant) — this is where static baselines win big.
  * ``"pallas"`` — the fused one-pass Mosaic kernel: one grid step per
    request tile, gathers and folds recast as MXU matmuls, and — with
    telemetry on — the grouped latency histogram folded in the same pass
    (subsuming the separate ``latency_histogram`` dispatch). Histogram
    counts stay bit-exact; busy/latency reductions re-associate across
    tiles, so engine-level results are allclose to the jax backend
    (pinned by tests/test_chunk_replay.py).

``run_scenario_reference`` always replays through the jnp path — it *is*
the oracle the kernel is pinned against.

Throughput model
----------------
Nodes serve their request streams concurrently (the paper's three
application servers). Per-node busy time = Σ latency of requests arriving at
that node; makespan = max over nodes; throughput = R / makespan. The paper
does not state the YCSB per-op service cost; ``ClusterConfig.service_ms`` is
the calibration constant (documented in EXPERIMENTS.md §Repro-assumptions).

Queueing model
--------------
With ``ClusterConfig.service`` set to an enabled
:class:`~repro.kvsim.cluster.ServiceConfig`, every request additionally
pays an M/M/1-style contention wait: each chunk's per-request service
demand ``d = service_ms + object_bytes / serve_bytes_per_ms`` folds at the
request's *serving* node into a load factor
``rho = min(demand_fold / capacity_ms, rho_max)``, and the request waits
``d * rho / (1 - rho)`` on top of its RTT-model latency. The pre-pass
(``kernels.chunk_replay.ref.contention_extra_ms_ref``) is canonical for
both engines, the static fast path, AND the Pallas replay backend — the
fused kernel consumes the per-request ``extra_ms`` it produces, so
contention can no more drift between backends than the base latency model
can. ``service=None`` (the default) compiles the exact pre-contention
program, so every seed golden holds bit-exact (pinned by
tests/test_service_time.py).
"""

from __future__ import annotations

from functools import lru_cache, partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.metadata import create_store, record_accesses
from repro.kernels.chunk_replay.ops import (
    REPLAY_BACKENDS,
    chunk_latency,
    chunk_replay,
)
from repro.kernels.chunk_replay.ref import (
    chunk_components_ref,
    contention_extra_ms_ref,
    fault_extra_ms_ref,
    routing_extra_split_ref,
)
from repro.kernels.latency_histogram.ref import bin_index
from repro.core.policy import (
    PolicyContext,
    describe_policy,
    policy_masked_step,
    policy_sweep,
    publish_mask,
    split_policy,
)
from repro.kvsim.cluster import ClusterConfig, Scenario, normalize_service
from repro.kvsim.faults import compile_schedule, normalize_faults
from repro.kvsim.routing import (
    STALE_AGE_BINS,
    consult_probe,
    init_router_state,
    normalize_routing,
    publish_commit,
    published_view,
    router_cache_update,
    router_of,
    stale_age_fold,
)
from repro.kvsim.telemetry import (
    NUM_COMPONENTS,
    SimTrace,
    TelemetryConfig,
    TelemetryLeaves,
    attribution_chunk_hist,
    attribution_trace_hist,
    build_trace,
    chunk_histogram,
    leaves_quantile,
    merge_leaves,
    normalize_telemetry,
    psum_leaves,
    trace_histogram,
)
from repro.kvsim.workload import (
    Trace,
    WorkloadConfig,
    _request_window,
    _workload_keys,
    generate_key_state,
    generate_trace,
)

__all__ = [
    "REPLAY_BACKENDS",
    "TRACE_MODES",
    "ShardSpec",
    "SimResult",
    "SimTrace",
    "TelemetryConfig",
    "run_scenario",
    "run_scenario_reference",
    "run_experiment",
    "confidence_interval_99",
]

TRACE_MODES = ("materialized", "streamed")


class ShardSpec(NamedTuple):
    """Keyspace sharding of the fused engine, following the
    ``publish_and_fill`` convention from ``core/repartition.py``:
    ``axis_name=None`` (the default) is the degenerate single-shard program
    — no collectives, no request masking, op-for-op the unsharded engine,
    so every seed golden holds bit-exact. With an axis name the engine runs
    inside a ``shard_map`` over a ``Mesh`` whose ``axis_name`` dimension
    splits the key axis into ``num_shards`` contiguous blocks: per-key
    state (metadata counts, replica map, sizes, policy EMA/decay state)
    lives shard-local, each shard replays only its own keys' requests, and
    ``psum`` assembles the global aggregates (busy fold, histograms, move
    counters, occupancy, the contention demand fold) exactly where the
    daemon needs cluster-wide values.

    ``pad`` lifts the historical ``K % S == 0`` restriction: when the key
    axis does not divide evenly, ``run_scenario`` pads ``natural`` /
    ``object_bytes`` with ``pad`` trailing dummy keys so every shard holds
    ``ceil(K / S)`` rows, and the engine masks the padded rows out of all
    per-key state (never live, never owned, zero bytes). Requests are drawn
    from the REAL keyspace, so no padded key is ever requested. ``pad == 0``
    (every dividing K, and the whole unsharded world) compiles the exact
    historical program — the field is a jit static, so it only splits the
    compile cache, never the math.
    """

    axis_name: str | None = None
    num_shards: int = 1
    pad: int = 0

    @property
    def active(self) -> bool:
        return self.axis_name is not None and self.num_shards > 1


class SimResult(NamedTuple):
    """Aggregate metrics for one scenario run (one seed)."""

    throughput_ops_s: float
    hit_rate: float
    mean_latency_ms: float
    node_busy_ms: np.ndarray  # [N]
    replication_moves: float  # replicas created by the daemon
    deletion_moves: float  # replicas dropped by the daemon (all causes)
    evictions: float  # subset of deletions caused by key expiry
    capacity_evictions: float  # held replicas evicted by the budget projection
    peak_occupancy_bytes: np.ndarray  # [N] peak replica bytes per node
    # Routing/directory-tier counters (all zero when ClusterConfig.routing
    # is off — the fields default so the pre-routing result shape is a
    # strict prefix and existing consumers are untouched).
    router_consults: float = 0.0  # directory consults
    directory_fetches: float = 0.0  # cache misses (home-node round trips)
    mis_routes: float = 0.0  # consults detoured by a stale ownership view
    stale_consults: float = 0.0  # consults that hit a stale cache entry
    # Failure-injection counters (all zero when ClusterConfig.faults is off
    # — same strict-prefix convention as the routing block above). With
    # faults on, hit_rate/mean_latency_ms cover SERVED requests only; the
    # unavailable_* counts are the excluded remainder.
    unavailable_reads: float = 0.0  # reads refused (origin down / no live copy)
    unavailable_writes: float = 0.0  # writes refused (origin node down)
    failovers: float = 0.0  # writes relayed through a stand-in master
    repair_moves: float = 0.0  # re-replications of copies lost to failures


def _initial_hosts(
    natural_node: Array, num_keys: int, num_nodes: int, placement: str
) -> Array:
    """Starting replica map (paper §9 scenario definitions): ``"full"`` is
    every-key-everywhere (the idealised baselines); ``"offsite"`` starts
    each key on a single node that is *not* its natural request source
    ("requests ... served not available on the local key-value store") —
    the worst-case placement adaptive policies must dig out of."""
    if placement == "full":
        return jnp.ones((num_keys, num_nodes), dtype=bool)
    home = (natural_node + 1) % num_nodes
    return jax.nn.one_hot(home, num_nodes, dtype=bool)


def _replay_scalars(cluster: ClusterConfig) -> dict:
    """The latency-model scalars the chunk-replay trio consumes (host-side
    floats — traced by the jit'd wrappers, so retuned clusters never
    recompile)."""
    return dict(
        service_ms=cluster.service_ms,
        master=cluster.master,
        xfer_read_ms=cluster.transfer_ms(cluster.value_bytes),
        xfer_write_ms=cluster.transfer_ms(cluster.value_bytes + cluster.key_bytes),
    )


def _flight_positions(fcfg, chunk_idx, chunk_size: int) -> Array:
    """In-chunk sample offsets ``[S] i32`` for the flight recorder.

    ``"stride"`` picks fixed equally-spaced offsets (chunk-independent, so
    the sample plan is a loop constant); ``"reservoir"`` draws uniform
    offsets from a counter-derived key (``fold_in(chunk)``) — deterministic
    per chunk, identical between the scan engine and the reference loop,
    and independent of the workload's request stream."""
    s = fcfg.samples_per_chunk
    if fcfg.mode == "stride":
        stride = max(chunk_size // s, 1)
        return (jnp.arange(s, dtype=jnp.int32) * stride) % chunk_size
    key = jax.random.fold_in(jax.random.PRNGKey(0x9E37), chunk_idx)
    return jax.random.randint(key, (s,), 0, chunk_size, dtype=jnp.int32)


def _chunk_latency(
    hosts: Array,  # [K, N] frozen replica map
    keys: Array,  # [B]
    nodes: Array,  # [B]
    is_read: Array,  # [B]
    rtt: Array,  # [N, N]
    cluster: ClusterConfig,
    read_mode: str,  # "ideal" | "no_local" | "map"
) -> tuple[Array, Array]:
    """Per-request latency + hit flags for one chunk under a frozen map —
    a thin dispatch onto ``repro.kernels.chunk_replay`` (the canonical
    implementation both engines and the Pallas kernel share)."""
    return chunk_latency(
        hosts, keys, nodes, is_read, rtt,
        read_mode=read_mode, **_replay_scalars(cluster),
    )


def _node_occupancy(hosts: Array, object_bytes: Array) -> Array:
    """Per-node replica bytes ``[N]`` under a replica map (both engines use
    this exact expression so their peaks agree bit-for-bit)."""
    return jnp.sum(jnp.where(hosts, object_bytes[:, None], 0.0), axis=0)


def _check_topology(workload: WorkloadConfig, cluster: ClusterConfig) -> None:
    if workload.num_nodes != cluster.num_nodes:
        raise ValueError(
            f"workload has {workload.num_nodes} nodes but cluster topology "
            f"has {cluster.num_nodes}"
        )
    if cluster.rtt is not None and len(cluster.rtt) != cluster.num_nodes:
        raise ValueError(
            f"rtt matrix is {len(cluster.rtt)}x{len(cluster.rtt[0])} but "
            f"num_nodes={cluster.num_nodes}"
        )
    if (
        isinstance(cluster.capacity_bytes, tuple)
        and len(cluster.capacity_bytes) != cluster.num_nodes
    ):
        raise ValueError(
            f"capacity_bytes has {len(cluster.capacity_bytes)} entries for "
            f"num_nodes={cluster.num_nodes}"
        )
    for name in ("zone_of", "region_of"):
        labels = getattr(cluster, name)
        if labels is not None and len(labels) != cluster.num_nodes:
            raise ValueError(
                f"{name} labels {len(labels)} nodes but "
                f"num_nodes={cluster.num_nodes}"
            )


def _seed_store(hosts: Array, num_keys: int, num_nodes: int):
    """Metadata layer seeded with the initial placement (Algorithm 1's
    "metadata == null -> generate metadata object" happened at load time)."""
    return create_store(num_keys, num_nodes)._replace(
        hosts=hosts,
        live=jnp.ones((num_keys,), dtype=bool),
        home=jnp.argmax(hosts, axis=-1).astype(jnp.int32),
    )


def _reject_scenario(caller: str, policy) -> None:
    """The PR-3 ``scenario=`` deprecation shim is gone (its one-release
    grace period ended with this release); keep the failure mode helpful by
    spelling out the exact policy replacement instead of an attribute
    error deep inside ``resolve``."""
    if isinstance(policy, Scenario):
        repl = (
            "RedynisPolicy()" if policy is Scenario.OPTIMIZED
            else f"StaticPolicy(mode={policy.value!r})"
        )
        raise ValueError(
            f"{caller}: the legacy scenario= spelling was removed (its "
            f"deprecation window is over); pass policy={repl} instead"
        )


def _prepare(workload, cluster, caller, policy):
    _check_topology(workload, cluster)
    _reject_scenario(caller, policy)
    if policy is None:
        raise ValueError(
            f"{caller}: a policy is required — e.g. RedynisPolicy() or "
            f"StaticPolicy(mode='local')"
        )
    policy = policy.resolve(workload.num_nodes)
    policy.validate(workload.num_nodes)
    return split_policy(policy)


def _contention_kwargs(
    cluster: ClusterConfig, read_mode: str, daemon_interval: int
) -> dict | None:
    """Host-side resolution of the queueing model: the kwargs
    ``contention_extra_ms_ref`` needs, or ``None`` when the cluster has no
    enabled :class:`ServiceConfig` (the bit-exact pre-contention path)."""
    service = normalize_service(cluster.service)
    if service is None:
        return None
    return dict(
        read_mode=read_mode,
        service_ms=cluster.service_ms,
        serve_bytes_per_ms=service.serve_bytes_per_ms,
        capacity_ms=service.capacity_ms(daemon_interval, cluster.service_ms),
        rho_max=service.rho_max,
    )


def _routing_kwargs(cluster: ClusterConfig, num_keys: int) -> dict | None:
    """Host-side resolution of the routing tier: the resolved knobs the
    engines consume, or ``None`` when the cluster has no enabled
    :class:`RoutingConfig` (the bit-exact pre-routing path — the same
    contract as :func:`_contention_kwargs`).

    ``num_routers = 0`` resolves to one router per cluster node, and a
    ``cache_entries`` at or beyond the keyspace collapses to 0 (the
    unbounded warm cache) so the admission ranking compiles away when it
    could never evict anything.
    """
    routing = normalize_routing(cluster.routing)
    if routing is None:
        return None
    if routing.home_node >= cluster.num_nodes:
        raise ValueError(
            f"routing.home_node={routing.home_node} is not a node index "
            f"(num_nodes={cluster.num_nodes})"
        )
    if routing.num_routers > cluster.num_nodes:
        raise ValueError(
            f"routing.num_routers={routing.num_routers} exceeds "
            f"num_nodes={cluster.num_nodes} (routers are consulted per "
            f"requesting node, node x -> router x % R)"
        )
    cache_entries = routing.cache_entries
    if cache_entries >= num_keys:
        cache_entries = 0
    return dict(
        num_routers=routing.num_routers or cluster.num_nodes,
        cache_entries=cache_entries,
        publish_lag_chunks=routing.publish_lag_chunks,
        home_node=routing.home_node,
        decay=routing.decay,
    )


def _fault_kwargs(cluster: ClusterConfig, num_chunks: int) -> dict | None:
    """Host-side resolution of the fault schedule: the per-chunk
    availability/crash timelines as device constants, or ``None`` when the
    cluster has no enabled :class:`FaultConfig` (the bit-exact no-fault
    path — the same contract as :func:`_contention_kwargs` /
    :func:`_routing_kwargs`).

    ``compile_schedule`` validates the declarative events against the
    cluster's failure-domain labelling (``zone_of``/``region_of``) and
    rejects any chunk in which every node would be down — the simulator
    models degraded service, not a total blackout."""
    faults = normalize_faults(cluster.faults)
    if faults is None:
        return None
    avail, crash = compile_schedule(
        faults,
        num_nodes=cluster.num_nodes,
        num_chunks=num_chunks,
        zone_of=cluster.zone_of,
        region_of=cluster.region_of,
    )
    return dict(avail=jnp.asarray(avail), crash=jnp.asarray(crash))


# ---------------------------------------------------------------------------
# Fused engine: one lax.scan over chunks, policy due-masked inside the body.
# ---------------------------------------------------------------------------

_SIM_STATICS = (
    "cluster", "policy", "daemon_interval", "telemetry", "replay_backend",
    "trace_mode", "workload", "shard",
)


def _check_replay_backend(caller: str, replay_backend: str) -> None:
    if replay_backend not in REPLAY_BACKENDS:
        raise ValueError(
            f"{caller}: unknown replay_backend {replay_backend!r}; expected "
            f"one of {REPLAY_BACKENDS}"
        )


def _simulate(
    keys: Array | None,  # [R] (None in streamed mode)
    nodes: Array | None,  # [R]
    is_read: Array | None,  # [R]
    natural: Array,  # [K] (always the FULL key axis; shards slice locally)
    object_bytes: Array,  # [K]
    params: dict,  # the policy's dynamic hyperparameters (traced)
    seed: Array | None = None,  # traced trace seed (streamed mode only)
    *,
    cluster: ClusterConfig,
    policy,  # static key from split_policy (hashable jit static)
    daemon_interval: int,
    telemetry: TelemetryConfig | None = None,
    replay_backend: str = "jax",
    trace_mode: str = "materialized",
    workload: WorkloadConfig | None = None,
    shard: ShardSpec | None = None,
):
    """Whole-scenario simulation as a single fixed-shape scan program.

    The trace is padded to ``num_chunks * daemon_interval`` with ``valid``-
    masked rows (zero latency, zero metadata weight), so every chunk has one
    shape and the Python loop collapses into ``jax.lax.scan``.

    Returns ``(aggregate leaves, telemetry leaves | None)``. With
    ``telemetry`` (a normalised :class:`TelemetryConfig` static) the scan
    body additionally folds each chunk's latencies into a grouped log-bin
    histogram and emits per-chunk series as the scan's ``ys``; the carry —
    and therefore every aggregate result — is untouched, which is what
    keeps the telemetry-off AND telemetry-on aggregates bit-exact with the
    pre-telemetry engine (pinned by tests/test_telemetry.py).

    ``trace_mode="streamed"`` drops the materialised ``[R]`` trace buffers
    entirely: each scan iteration regenerates its own chunk of requests
    in-scan from ``seed`` via ``workload._request_window`` — bit-identical
    to the slices the materialised path would have consumed (the sliced
    threefry emulation in ``workload.py``), so every aggregate and
    histogram matches the materialised engine exactly. Peak live memory
    falls from O(R + K) to O(daemon_interval + K).

    ``shard`` (a :class:`ShardSpec` static) runs the body per key-shard
    inside a caller-supplied ``shard_map`` — see the class docstring. The
    degenerate default compiles the identical unsharded program. Sharded
    f32 reductions (busy, latency sums, occupancy, contention folds)
    re-associate across shards and are allclose to single-device values;
    histogram counts and hit/read/move counters are integer sums and stay
    bit-exact.
    """
    shard = shard or ShardSpec()
    if trace_mode == "streamed":
        if workload is None:
            raise ValueError("trace_mode='streamed' requires workload=")
        r = workload.num_requests
        stream_keys = _workload_keys(seed)
    else:
        r = keys.shape[0]
        stream_keys = None
    num_keys = natural.shape[0]
    n = cluster.num_nodes
    rtt = cluster.rtt_matrix()
    obj = jnp.asarray(object_bytes, jnp.float32)
    if shard.active:
        # Contiguous block sharding of the key axis: shard i owns global
        # keys [i*kps, (i+1)*kps). natural/obj arrive replicated (requests
        # reference any key when generating/localising the trace); the
        # per-key STATE below is built from the local slice only.
        kps = num_keys // shard.num_shards
        shard_idx = jax.lax.axis_index(shard.axis_name)
        shard_base = shard_idx * kps
        nat_local = jax.lax.dynamic_slice(natural, (shard_base,), (kps,))
        obj_local = jax.lax.dynamic_slice(obj, (shard_base,), (kps,))
        local_keys = kps
    else:
        kps = num_keys
        nat_local, obj_local, local_keys = natural, obj, num_keys
    # Host-side static: at the default infinite budget the projection stage
    # is skipped entirely (capacity=None), keeping Algorithm 3 bit-exact.
    capacity = (
        cluster.capacity_vector() if cluster.has_finite_capacity else None
    )
    ctx = PolicyContext(
        rtt=rtt, object_bytes=obj_local, capacity_bytes=capacity, params=params
    )
    # Host-side static: with no enabled ServiceConfig the contention
    # pre-pass is absent from the compiled program entirely — the exact
    # pre-contention bits (goldens pinned by tests/test_service_time.py).
    contention = _contention_kwargs(cluster, policy.read_mode, daemon_interval)
    # Host-side static: with no enabled RoutingConfig the directory tier is
    # absent from the compiled program entirely — the exact pre-routing
    # bits (goldens pinned by tests/test_routing.py).
    routing = _routing_kwargs(cluster, num_keys - shard.pad)
    # Host-side statics: with attribution/flight off (the defaults —
    # normalize_telemetry collapses disabled sub-configs to None) their
    # leaves stay None, the scan emits NO extra ys, and the compiled
    # program is structurally identical to the pre-provenance engine.
    acfg = None if telemetry is None else telemetry.attribution
    fcfg = None if telemetry is None else telemetry.flight

    num_chunks = -(-r // daemon_interval)
    pad = num_chunks * daemon_interval - r
    # Host-side static: with no enabled FaultConfig the membership timeline,
    # degraded-mode pricing, and repair bookkeeping are absent from the
    # compiled program entirely — the exact no-fault bits (goldens pinned by
    # tests/test_faults.py). The [C, N] schedule constants embed in the
    # program and each scan iteration dynamic-indexes its own chunk row.
    fault = _fault_kwargs(cluster, num_chunks)

    if trace_mode == "streamed":
        # No materialised trace: the scan consumes only chunk indices and
        # each body iteration regenerates its own request window in-scan.
        pk = pn = pr = pv = None
        chunked = None
        xs = jnp.arange(num_chunks, dtype=jnp.int32)
    else:
        def padded(x: Array) -> Array:
            if pad:
                x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
            return x

        pk, pn, pr = padded(keys), padded(nodes), padded(is_read)
        pv = jnp.arange(num_chunks * daemon_interval) < r
        chunked = lambda x: x.reshape(num_chunks, daemon_interval)
        xs = (
            jnp.arange(num_chunks, dtype=jnp.int32),
            chunked(pk),
            chunked(pn),
            chunked(pr),
            chunked(pv),
        )

    hosts0 = _initial_hosts(nat_local, local_keys, n, policy.initial_placement)
    if shard.active and shard.pad:
        # Padded tail keys (ceil-division sharding, satellite of PR 8) are
        # dead weight: never live, never hosted, zero bytes — so no policy
        # sweep, occupancy sample, or counter ever sees them and the
        # non-dividing-K run stays bit-exact with the unsharded engine.
        real = (shard_base + jnp.arange(kps, dtype=jnp.int32)) < (
            num_keys - shard.pad
        )
        hosts0 = hosts0 & real[:, None]
    store = _seed_store(hosts0, local_keys, n)
    if shard.active and shard.pad:
        store = store._replace(live=real)
    pstate = policy.init(store, ctx)
    zero = jnp.float32(0.0)
    # The O(K·N) occupancy sample is a loop constant for inactive policies
    # (a static map never changes) — hoisted out of the scan body; active
    # policies re-sample it per chunk on the frozen-at-chunk-start map.
    # Sharded: occupancy is a cluster property, psum'd at the sample point
    # so the running peak is taken over the GLOBAL per-node vector.
    occ0 = _node_occupancy(store.hosts, obj_local)
    if shard.active:
        occ0 = jax.lax.psum(occ0, shard.axis_name)
    # Whole-trace replay materialises O(R·N) planes (one-hot busy fold,
    # replica/RTT rows); past this element budget (~256 MB of f32) the
    # per-chunk scan's bounded O(B·N) footprint is the safer trade. It
    # needs the materialised trace and an unsharded map by construction.
    static_fast = (
        r * n <= 64 * 1024 * 1024
        and trace_mode == "materialized"
        and not shard.active
        # A frozen map does NOT freeze the routing tier: router caches and
        # consult counters evolve per chunk, so routing always scans.
        and routing is None
        # Faults evolve the availability mask (and crashes mutate even a
        # static policy's map) per chunk, so fault runs always scan too.
        and fault is None
    )
    if not policy.is_active and replay_backend == "jax" and static_fast:
        # Static fast path: a frozen map makes the ENTIRE request path
        # loop-invariant, so the scan collapses into one vectorized pass
        # over the whole trace — no per-chunk program iterations at all
        # (the strong form of the occupancy hoist: the O(K·N) sample AND
        # the [B, N] latency passes leave the loop together). Latencies
        # come from the exact same _chunk_latency expressions (identical
        # f32 bits); the reductions below (matmul busy fold, whole-trace
        # sums) re-associate relative to the scan's per-chunk
        # accumulation, so aggregates are exact for integer-ms latency
        # sums below 2**24 (every golden config) and allclose otherwise
        # (pinned by the seed goldens and tests/test_chunk_replay.py).
        slot_idx = None
        if num_keys * n * 2 <= r:
            # A frozen map also makes latency a pure function of the
            # (key, node, is_read) triple — when that grid is smaller
            # than the trace, evaluate _chunk_latency ONCE per distinct
            # triple and gather per request (elementwise ops on the grid
            # produce the identical f32 bits the direct evaluation would).
            grid = jnp.arange(num_keys * n * 2, dtype=jnp.int32)
            tlat, thit = _chunk_latency(
                store.hosts,
                grid // (n * 2),
                (grid // 2) % n,
                (grid % 2).astype(bool),
                rtt, cluster, policy.read_mode,
            )
            slot_idx = pk * (n * 2) + pn * 2 + pr.astype(jnp.int32)
            lat, read_hits = tlat[slot_idx], thit[slot_idx]
        else:
            lat, read_hits = _chunk_latency(
                store.hosts, pk, pn, pr, rtt, cluster, policy.read_mode
            )
        rho_c = None
        if contention is not None:
            # Contention is NOT loop-invariant even under a frozen map —
            # rho folds over each chunk's own demand — so vmap the
            # canonical pre-pass over the chunk axis and fold the waits
            # into the whole-trace latencies (the grid shortcut above only
            # ever supplies the base RTT-model latency).
            extra_c, rho_c = jax.vmap(
                lambda ck, cn, cr, cv: contention_extra_ms_ref(
                    store.hosts, ck, cn, cr, cv, rtt, obj, **contention
                )
            )(chunked(pk), chunked(pn), chunked(pr), chunked(pv))
            lat = lat + extra_c.reshape(-1)
        if pad:
            # Padding exists only when the trace doesn't divide into
            # chunks; with none, the validity masks are static no-ops.
            lat = jnp.where(pv, lat, 0.0)
            read_hits = read_hits & pv
            read_flags = pr & pv
        else:
            read_flags = pr
        # Per-node busy fold as a [1, R] ∙ [R, N] one-hot matmul — an
        # order of magnitude faster than a length-R scatter on CPU, and
        # exact for the integer-ms latency sums the goldens pin.
        onehot_n = (pn[:, None] == jnp.arange(n)[None, :]).astype(jnp.float32)
        busy = jax.lax.dot_general(
            lat[None, :], onehot_n, (((1,), (0,)), ((), ())),
            # Full-f32 accumulation everywhere: TPU/GPU matmuls otherwise
            # truncate operands (bf16/TF32) and break the documented
            # exactness of static-policy aggregates vs the scan engine.
            precision=jax.lax.Precision.HIGHEST,
            preferred_element_type=jnp.float32,
        )[0]
        lat_sum = jnp.sum(lat)
        hits = jnp.sum(read_hits.astype(jnp.float32))
        reads = jnp.sum(read_flags.astype(jnp.float32))
        leaves = (
            r / (jnp.max(busy) / 1000.0),
            hits / jnp.maximum(reads, 1.0),
            lat_sum / r,
            busy,
            zero,  # repl
            zero,  # drop
            zero,  # evic
            zero,  # cap_evic
            occ0,  # a static map's peak IS the initial-map occupancy
        )
        if telemetry is None:
            return leaves, None
        w = pv.astype(jnp.float32)
        zeros_c = jnp.zeros((num_chunks,), jnp.float32)
        # Latency provenance on the fast path: price the WHOLE padded trace
        # through the component oracle in one pass (the frozen map makes
        # every component loop-invariant too; contention waits, the only
        # chunk-varying term, fold in from the vmapped pre-pass above).
        sa_hist = sa_sum = sf_meta = sf_vals = None
        if acfg is not None or fcfg is not None:
            with jax.named_scope("attribution_components"):
                comps = chunk_components_ref(
                    store.hosts, pk, pn, pr, rtt,
                    read_mode=policy.read_mode,
                    contention_ms=(
                        None if rho_c is None else extra_c.reshape(-1)
                    ),
                    **_replay_scalars(cluster),
                )
                if pad:
                    comps = jnp.where(pv[None, :], comps, 0.0)
        if acfg is not None:
            with jax.named_scope("attribution_fold"):
                sa_hist = attribution_trace_hist(
                    comps, pn * 2 + pr.astype(jnp.int32), w, acfg, n,
                    num_chunks,
                )
                sa_sum = jnp.sum(
                    comps.reshape(
                        NUM_COMPONENTS, num_chunks, daemon_interval
                    ),
                    axis=2,
                ).T
        if fcfg is not None:
            # Same sample plan as the scan body (gathered whole-trace here);
            # the routing column is -1: routing always forces the scan path.
            with jax.named_scope("flight_recorder"):
                cidx = jnp.arange(num_chunks, dtype=jnp.int32)
                jpos = jax.vmap(
                    lambda cc: _flight_positions(fcfg, cc, daemon_interval)
                )(cidx)
                gpos = cidx[:, None] * daemon_interval + jpos
                own = pv[gpos]
                mi32 = lambda v: jnp.where(own, v, 0).astype(jnp.int32)
                sf_meta = jnp.stack(
                    [
                        mi32(gpos),
                        mi32(pk[gpos]),
                        mi32(pn[gpos]),
                        mi32(jnp.full_like(gpos, -1)),
                        mi32(pr[gpos].astype(jnp.int32) | 2),
                    ],
                    axis=2,
                )
                scomps = comps[:, gpos]
                sf_vals = jnp.concatenate(
                    [jnp.sum(scomps, axis=0, keepdims=True), scomps],
                    axis=0,
                ).transpose(1, 2, 0)
        if (
            slot_idx is not None
            and telemetry.backend != "pallas"
            and contention is None
        ):
            # Bin indices are a pure function of the triple too: bucketize
            # the grid once, gather per request (saves R log evals). With
            # contention on, the per-chunk wait breaks the pure-function
            # property, so the full latencies are bucketized directly.
            bin_idx = bin_index(
                tlat, telemetry.lo_ms, telemetry.hi_ms, telemetry.num_bins
            )[slot_idx]
        else:
            bin_idx = None
        ys = TelemetryLeaves(
            # All C per-chunk histograms in ONE flat bincount pass (or the
            # vmapped Pallas kernel under backend="pallas").
            hist=trace_histogram(
                lat, pn * 2 + pr.astype(jnp.int32), w, telemetry, n,
                num_chunks, bin_idx=bin_idx,
            ),
            hits=jnp.sum(chunked(read_hits.astype(jnp.float32)), axis=1),
            reads=jnp.sum(chunked(read_flags.astype(jnp.float32)), axis=1),
            lat_sum=jnp.sum(chunked(lat), axis=1),
            count=jnp.sum(chunked(w), axis=1),
            adds=zeros_c,
            drops=zeros_c,
            expiry_evictions=zeros_c,
            capacity_evictions=zeros_c,
            occupancy=jnp.broadcast_to(occ0, (num_chunks, n)),
            load_factor=(
                jnp.zeros((num_chunks, n), jnp.float32)
                if rho_c is None else rho_c
            ),
            # Routing forces the scan path, so the fast path's routing
            # series are structurally zero (kept [C]-shaped for SimTrace).
            router_consults=zeros_c,
            directory_fetches=zeros_c,
            mis_routes=zeros_c,
            stale_consults=zeros_c,
            stale_age_hist=jnp.zeros(
                (num_chunks, STALE_AGE_BINS), jnp.float32
            ),
            attr_hist=sa_hist,
            attr_sum=sa_sum,
            flight_meta=sf_meta,
            flight_vals=sf_vals,
        )
        return leaves, ys

    if routing is None:
        # None is a legal (empty) pytree carry leaf: with routing off the
        # scan carry is structurally identical to the pre-routing program.
        rcarry0 = None
    else:
        rstate0 = init_router_state(
            store.hosts,
            num_routers=routing["num_routers"],
            cache_entries=routing["cache_entries"],
            publish_lag_chunks=routing["publish_lag_chunks"],
            active=policy.is_active,
            # Faults can pause the publish pipeline (directory home node
            # down), which needs a ring slot to freeze even at lag 0. The
            # forced 1-slot ring is value-identical under full availability.
            force_ring=fault is not None,
        )
        # RouterState + running consult/fetch/mis-route/stale counters.
        rcarry0 = (
            rstate0,
            zero,
            zero,
            zero,
            zero,
        )
    if fault is None:
        # None is a legal (empty) pytree carry leaf: with faults off the
        # scan carry is structurally identical to the pre-fault program.
        fcarry0 = None
    else:
        # wiped-keys mask + running unavailable-read/-write, failover and
        # repair-move counters.
        fcarry0 = (
            jnp.zeros((local_keys,), bool),
            zero,
            zero,
            zero,
            zero,
        )
    init = (
        store,
        pstate,
        jnp.zeros((n,), jnp.float32),  # busy
        zero,  # lat_sum
        zero,  # hits
        zero,  # reads
        zero,  # repl
        zero,  # drop
        zero,  # evic (expiry)
        zero,  # cap_evic
        occ0,  # peak (seeded by the initial map)
        rcarry0,
        fcarry0,
    )
    scalars = _replay_scalars(cluster)

    def body(carry, x):
        (
            store, pstate, busy, lat_sum, hits, reads, repl, drop, evic,
            cap_evic, peak, rcarry, fcarry,
        ) = carry
        if trace_mode == "streamed":
            # In-scan trace generation: this chunk's request window, drawn
            # at its global positions — bit-identical to the slices the
            # materialised path reshapes out of the full trace. The final
            # chunk's positions past R are garbage masked by cv.
            c = x
            pos = c * daemon_interval + jnp.arange(
                daemon_interval, dtype=jnp.int32
            )
            cv = pos < r
            ck, cn, cr = _request_window(workload, stream_keys, pos, natural)
        else:
            c, ck, cn, cr, cv = x
        if shard.active:
            # Each shard replays only requests for ITS contiguous key
            # block: localise the key id and fold foreign rows into the
            # validity mask (same masking machinery the trace padding
            # uses, so foreign rows cost zero everywhere downstream).
            mine = (ck // kps) == shard_idx
            ck = jnp.where(mine, ck - shard_base, 0)
            cv = cv & mine
        # Degraded-mode serving state for this chunk. With faults off these
        # aliases leave the program byte-identical: served IS cv, hosts_eff
        # IS the authoritative map — every downstream consumer below uses
        # the aliases, so the no-fault compile is structurally unchanged.
        served = cv
        hosts_eff = store.hosts
        avail_c = None
        f_extra = None
        if fault is not None:
            with jax.named_scope("fault_prepass"):
                wiped, f_unav_r, f_unav_w, f_fo, f_rep = fcarry
                avail_c = fault["avail"][c]
                crash_c = fault["crash"][c]
                # One-shot replica wipe at each crash's first chunk: the
                # crashed nodes' copies leave the authoritative map (data
                # loss, not just unreachability). Keys whose row emptied
                # are dark until the daemon re-seeds a live copy —
                # partitions, by contrast, never touch the map here.
                pre_hosts = store.hosts
                post_hosts = pre_hosts & ~crash_c[None, :]
                wiped = wiped | (
                    jnp.any(pre_hosts, axis=-1)
                    & ~jnp.any(post_hosts, axis=-1)
                )
                store = store._replace(hosts=post_hosts)
                # Canonical degraded-mode oracle: per-request unavailability
                # + the write-failover surcharge (reads reprice natively via
                # the hosts_eff mask below — see fault_extra_ms_ref).
                f_extra, unavail, failover = fault_extra_ms_ref(
                    store.hosts, ck, cn, cr, cv, avail_c, rtt,
                    read_mode=policy.read_mode,
                    master=scalars["master"],
                    xfer_write_ms=scalars["xfer_write_ms"],
                    wiped=wiped,
                )
                served = cv & ~unavail
                hosts_eff = store.hosts & avail_c[None, :]
        route = detour_part = fetch_part = None
        if routing is not None:
            # Routing pre-pass on the chunk's frozen map: consult the
            # region's router cache against the PUBLISHED (possibly lagged)
            # ownership view and price fresh hits / stale mis-routes /
            # directory fetches per request (routing_extra_split_ref is the
            # canonical oracle both replay backends consume; the
            # detour/fetch split is row-wise bit-identical to the fused
            # surcharge, so ``route`` carries the exact pre-split bits).
            with jax.named_scope("routing_prepass"):
                rstate, r_consults, r_fetches, r_mis, r_stale = rcarry
                pub_hosts, pub_ver = published_view(
                    rstate, store.hosts, c,
                    publish_lag_chunks=routing["publish_lag_chunks"],
                )
                rb = router_of(cn, routing["num_routers"])
                ent_cached, fresh, age = consult_probe(rstate, rb, ck)
                (
                    detour_part, fetch_part, consult, fetchb, staleb, misb,
                ) = routing_extra_split_ref(
                    # True serving happens on LIVE replicas; the published
                    # view stays the router's (liveness-blind) metadata.
                    # Refused requests never reach a router: valid=served.
                    hosts_eff, pub_hosts, ent_cached, fresh, ck, cn, cr,
                    served, rtt, read_mode=policy.read_mode,
                    home_node=routing["home_node"],
                )
                route = detour_part + fetch_part
        rho = None
        cont_extra = None
        if contention is not None:
            # Queueing pre-pass on the chunk's frozen map: per-request
            # contention wait + per-node load factor (the canonical
            # composition both replay backends consume). Sharded, each
            # shard folds its own requests' demand and the psum inside
            # load_factor_ref assembles the cluster-wide rho.
            with jax.named_scope("contention_prepass"):
                # Demand lands on LIVE serving replicas only, and refused
                # requests contribute no demand (valid=served) — a downed
                # node queues nothing.
                cont_extra, rho = contention_extra_ms_ref(
                    hosts_eff, ck, cn, cr, served, rtt, obj_local,
                    **contention,
                    axis_name=shard.axis_name if shard.active else None,
                )
        extra = cont_extra
        if route is not None:
            # Canonical composition order (routing first, ONE f32 add):
            # every engine and backend folds the same composed surcharge at
            # the same elementwise position, so the bits agree everywhere.
            extra = route if extra is None else route + extra
        if f_extra is not None:
            # Fault surcharge composes FIRST (prepended last) — the write
            # failover delta rides in front of routing + contention. Under
            # full availability the delta is exactly +0.0 per request, so
            # an all-up schedule stays bit-exact with faults off.
            extra = f_extra if extra is None else f_extra + extra
        comps = None
        if acfg is not None or fcfg is not None:
            # Latency provenance: re-price this chunk through the component
            # oracle (identical sub-expressions to chunk_latency_ref, so
            # the per-request component sum reconstructs the total — see
            # tests/test_attribution.py). Invalid/foreign rows zero out.
            with jax.named_scope("attribution_components"):
                comps = chunk_components_ref(
                    hosts_eff, ck, cn, cr, rtt,
                    read_mode=policy.read_mode,
                    contention_ms=cont_extra,
                    routing_detour_ms=detour_part,
                    directory_fetch_ms=fetch_part,
                    avail=avail_c,
                    **scalars,
                )
                comps = jnp.where(served[None, :], comps, 0.0)
        if replay_backend == "pallas":
            # The fused one-pass kernel: gather, latency, hit flags, busy
            # fold — and the telemetry histogram when enabled — in one
            # pass over request tiles (no [B, N] HBM intermediates).
            with jax.named_scope("chunk_replay"):
                (
                    d_busy, chunk_lat, chunk_hits, chunk_reads, chunk_count,
                    hist,
                ) = chunk_replay(
                    # Degraded mode reaches the kernel as DATA: the
                    # avail-masked map + served validity + the composed
                    # extra_ms (fault failover delta included) — no kernel
                    # math changes (see kernels/chunk_replay/ops.py).
                    hosts_eff, ck, cn, cr, served, rtt,
                    read_mode=policy.read_mode,
                    num_bins=0 if telemetry is None else telemetry.num_bins,
                    lo=1.0 if telemetry is None else telemetry.lo_ms,
                    hi=10_000.0 if telemetry is None else telemetry.hi_ms,
                    backend="pallas",
                    extra_ms=extra,
                    **scalars,
                )
            busy = busy + d_busy
        else:
            # Pure-jnp path, op-for-op the pre-fusion engine (bit-exact
            # with the seed goldens, including the carry-scatter busy).
            with jax.named_scope("chunk_replay"):
                lat, read_hits = _chunk_latency(
                    hosts_eff, ck, cn, cr, rtt, cluster, policy.read_mode
                )
                if extra is not None:
                    # Same elementwise position as chunk_replay_ref: after
                    # the base latency, before the validity mask —
                    # identical bits across engines and backends.
                    lat = lat + extra
                lat = jnp.where(served, lat, 0.0)
            chunk_lat = jnp.sum(lat)
            chunk_hits = jnp.sum((read_hits & served).astype(jnp.float32))
            chunk_reads = jnp.sum((cr & served).astype(jnp.float32))
            chunk_count = jnp.sum(served.astype(jnp.float32))
            busy = busy.at[cn].add(lat)
            hist = None
        lat_sum = lat_sum + chunk_lat
        hits = hits + chunk_hits
        reads = reads + chunk_reads
        zero = jnp.float32(0.0)
        if fault is not None:
            with jax.named_scope("fault_counters"):
                fsum_f = lambda m: jnp.sum(m.astype(jnp.float32))
                d_unav_r = fsum_f(unavail & cr)
                d_unav_w = fsum_f(unavail & ~cr)
                d_fo = fsum_f(failover)
                d_rep = zero  # set by the repair accounting below
                f_unav_r = f_unav_r + d_unav_r
                f_unav_w = f_unav_w + d_unav_w
                f_fo = f_fo + d_fo
                # Blast-radius point samples on THIS chunk's serving state:
                # the fraction of the keyspace with no live replica
                # (partition-dark or crash-wiped) and the wiped subset.
                # Emitted as already-global fractions — sharded, the key
                # counts psum at the sample point (LEAF_KINDS kind "mean").
                unreach = (
                    jnp.any(store.hosts, axis=-1)
                    & ~jnp.any(hosts_eff, axis=-1)
                ) | wiped
                cnt_u = fsum_f(unreach)
                cnt_w = fsum_f(wiped)
                if shard.active:
                    cnt_u = jax.lax.psum(cnt_u, shard.axis_name)
                    cnt_w = jax.lax.psum(cnt_w, shard.axis_name)
                real_keys = jnp.float32(num_keys - shard.pad)
                d_unreach = cnt_u / real_keys
                d_wiped = cnt_w / real_keys
        # Occupancy is sampled per chunk for EVERY policy, on the same
        # frozen-at-chunk-start map the requests see (the initial placement
        # seeds the peak); for inactive policies the sample is the hoisted
        # loop constant — numerically identical, O(K·N) cheaper per chunk.
        # Crashes mutate the map even under a static policy, so fault runs
        # always re-sample.
        if policy.is_active or fault is not None:
            occ = _node_occupancy(store.hosts, obj_local)
            if shard.active:
                occ = jax.lax.psum(occ, shard.axis_name)
        else:
            occ = occ0
        peak = jnp.maximum(peak, occ)
        if routing is not None:
            # Per-chunk routing diagnostics + decay-LFU cache refresh.
            # Consulted entries re-sync to the PUBLISHED version — a stale
            # router learns at most the lagged view, never the live map.
            fsum = lambda m: jnp.sum(m.astype(jnp.float32))
            d_consults, d_fetches = fsum(consult), fsum(fetchb)
            d_mis, d_stale = fsum(misb), fsum(staleb)
            d_age = stale_age_fold(age, staleb)
            r_consults = r_consults + d_consults
            r_fetches = r_fetches + d_fetches
            r_mis = r_mis + d_mis
            r_stale = r_stale + d_stale
            rstate = router_cache_update(
                rstate, rb, ck, consult, pub_ver,
                cache_entries=routing["cache_entries"],
                decay=routing["decay"],
                axis_name=shard.axis_name if shard.active else None,
            )
        chunk_moves = (zero, zero, zero, zero)
        if policy.is_active:
            # Algorithm 1 bookkeeping: log usage heuristics per request
            # (sharded: only the shard's own rows fold into its local
            # store — foreign rows are already masked out of cv).
            with jax.named_scope("policy_step"):
                # Down-origin users are offline: their requests leave no
                # demand signal. Dark reads from LIVE origins DO record —
                # that demand is how the daemon learns to repair wiped keys.
                demand_valid = cv if fault is None else cv & avail_c[cn]
                store = record_accesses(
                    store, ck, cn, now=c, valid=demand_valid
                )
                prev_hosts = store.hosts
                # The daemon sweeps against the chunk's availability mask:
                # down nodes take no new replicas and their held copies are
                # dropped from the map (rejoin-resync semantics).
                step_ctx = (
                    ctx if fault is None else ctx._replace(avail=avail_c)
                )
                stats, pstate, store = policy_masked_step(
                    policy, pstate, store, c, (c % policy.period) == 0,
                    step_ctx,
                )
            repl = repl + stats.adds
            drop = drop + stats.drops
            evic = evic + stats.expiry_evictions
            cap_evic = cap_evic + stats.capacity_evictions
            chunk_moves = (
                stats.adds, stats.drops, stats.expiry_evictions,
                stats.capacity_evictions,
            )
            if fault is not None:
                with jax.named_scope("repair_accounting"):
                    # Re-replication audit: replicas the sweep just created
                    # for keys that had lost every live copy (crash-wiped
                    # or partition-dark at chunk start) count as repairs.
                    added = store.hosts & ~prev_hosts
                    lost_live = jnp.any(prev_hosts, axis=-1) & ~jnp.any(
                        prev_hosts & avail_c[None, :], axis=-1
                    )
                    d_rep = jnp.sum(
                        (added & (wiped | lost_live)[:, None]).astype(
                            jnp.float32
                        )
                    )
                    f_rep = f_rep + d_rep
                    # A wiped key heals once any LIVE node holds it again.
                    wiped = wiped & ~jnp.any(
                        store.hosts & avail_c[None, :], axis=-1
                    )
            if routing is not None:
                # Versioned publish: keys the daemon just moved bump their
                # directory version and enter the publish queue; routers
                # see the new owners publish_lag_chunks later. With the
                # directory home node down, versions still bump but the
                # published ring slot freezes (see routing.publish_commit).
                rstate = publish_commit(
                    rstate, publish_mask(prev_hosts, store.hosts),
                    store.hosts, c,
                    publish_lag_chunks=routing["publish_lag_chunks"],
                    daemon_up=(
                        None if fault is None
                        else avail_c[routing["home_node"]]
                    ),
                )
        if telemetry is None:
            ys = None
        else:
            if hist is None:
                # jax replay path: fused bucketize+scatter-add over the
                # chunk (group id = node * 2 + is_read), padding masked by
                # weight 0 — dispatched per TelemetryConfig.backend. The
                # pallas replay path already folded the histogram inside
                # the chunk-replay kernel.
                # Refused (unavailable) requests carry weight 0: latency
                # histograms cover SERVED requests only.
                hist = chunk_histogram(
                    lat, cn * 2 + cr.astype(jnp.int32),
                    served.astype(jnp.float32), telemetry, n,
                )
            ahist = asum = fmeta = fvals = None
            if acfg is not None:
                # Per-component grouped histograms + per-chunk component
                # sums. ALWAYS the pure-jnp scatter-add, regardless of
                # replay backend — integer counts are bit-exact across
                # jax/pallas by construction.
                with jax.named_scope("attribution_fold"):
                    ahist = attribution_chunk_hist(
                        comps, cn * 2 + cr.astype(jnp.int32),
                        served.astype(jnp.float32), acfg, n,
                    )
                    asum = jnp.sum(comps, axis=1)
            if fcfg is not None:
                # Flight recorder: sample S in-chunk offsets and capture
                # each sampled request's identity + component vector.
                # EVERY field is masked by ownership/validity (zeros
                # otherwise, valid bit 0) — sharded, at most one shard
                # contributes a given slot and psum IS the assembly
                # (LEAF_KINDS kind "records").
                with jax.named_scope("flight_recorder"):
                    jpos = _flight_positions(fcfg, c, daemon_interval)
                    own = served[jpos]
                    gpos = c * daemon_interval + jpos
                    gkey = (
                        ck[jpos] + shard_base if shard.active else ck[jpos]
                    )
                    srouter = (
                        rb[jpos] if routing is not None
                        else jnp.full_like(jpos, -1)
                    )
                    mi32 = lambda v: jnp.where(own, v, 0).astype(jnp.int32)
                    fmeta = jnp.stack(
                        [
                            mi32(gpos),
                            mi32(gkey),
                            mi32(cn[jpos]),
                            mi32(srouter),
                            mi32(cr[jpos].astype(jnp.int32) | 2),
                        ],
                        axis=1,
                    )
                    scomps = comps[:, jpos]  # masked via comps' cv zeroing
                    fvals = jnp.concatenate(
                        [jnp.sum(scomps, axis=0, keepdims=True), scomps],
                        axis=0,
                    ).T
            ys = TelemetryLeaves(
                hist=hist,
                hits=chunk_hits,
                reads=chunk_reads,
                lat_sum=chunk_lat,
                count=chunk_count,
                adds=chunk_moves[0],
                drops=chunk_moves[1],
                expiry_evictions=chunk_moves[2],
                capacity_evictions=chunk_moves[3],
                occupancy=occ,
                load_factor=(
                    jnp.zeros((n,), jnp.float32) if rho is None else rho
                ),
                router_consults=zero if routing is None else d_consults,
                directory_fetches=zero if routing is None else d_fetches,
                mis_routes=zero if routing is None else d_mis,
                stale_consults=zero if routing is None else d_stale,
                stale_age_hist=(
                    jnp.zeros((STALE_AGE_BINS,), jnp.float32)
                    if routing is None else d_age
                ),
                unavailable_reads=zero if fault is None else d_unav_r,
                unavailable_writes=zero if fault is None else d_unav_w,
                failovers=zero if fault is None else d_fo,
                repair_moves=zero if fault is None else d_rep,
                unreachable_frac=zero if fault is None else d_unreach,
                wiped_frac=zero if fault is None else d_wiped,
                attr_hist=ahist,
                attr_sum=asum,
                flight_meta=fmeta,
                flight_vals=fvals,
            )
        rcarry = (
            None if routing is None
            else (rstate, r_consults, r_fetches, r_mis, r_stale)
        )
        fcarry = (
            None if fault is None
            else (wiped, f_unav_r, f_unav_w, f_fo, f_rep)
        )
        return (
            store, pstate, busy, lat_sum, hits, reads, repl, drop, evic,
            cap_evic, peak, rcarry, fcarry,
        ), ys

    (
        (_, _, busy, lat_sum, hits, reads, repl, drop, evic, cap_evic, peak,
         rcarry, fcarry),
        ys,
    ) = jax.lax.scan(body, init, xs)
    routing_totals = () if rcarry is None else tuple(rcarry[1:])
    fault_totals = () if fcarry is None else tuple(fcarry[1:])
    if shard.active:
        # One collective round after the scan assembles the global
        # aggregates from the per-shard partial sums (peak and the
        # telemetry occupancy/load_factor leaves are already global — they
        # were psum'd at the sample point inside the body).
        agg = (
            busy, lat_sum, hits, reads, repl, drop, evic, cap_evic,
        ) + routing_totals + fault_totals
        agg = jax.lax.psum(agg, shard.axis_name)
        busy, lat_sum, hits, reads, repl, drop, evic, cap_evic = agg[:8]
        routing_totals = agg[8:8 + len(routing_totals)]
        fault_totals = agg[8 + len(routing_totals):]
        if ys is not None:
            ys = psum_leaves(ys, shard.axis_name)
    makespan_ms = jnp.max(busy)
    if fault_totals:
        # Served-request mean: unavailable requests produced no latency, so
        # they leave the numerator AND the denominator (throughput keeps
        # dividing the full attempted count — the cluster's offered load).
        served_r = r - fault_totals[0] - fault_totals[1]
        mean_lat = lat_sum / jnp.maximum(served_r, 1.0)
        if not routing_totals:
            # SimResult is constructed positionally and the routing
            # counters are a strict prefix of the fault counters — fill
            # their slots with (traced) zeros when only faults are on.
            routing_totals = (zero,) * 4
    else:
        mean_lat = lat_sum / r
    return (
        r / (makespan_ms / 1000.0),
        hits / jnp.maximum(reads, 1.0),
        mean_lat,
        busy,
        repl,
        drop,
        evic,
        cap_evic,
        peak,
    ) + routing_totals + fault_totals, ys


@lru_cache(maxsize=1)
def _simulate_jit():
    """The jitted single-seed engine, built lazily so importing this
    module never initialises the XLA backend as a side effect.

    The trace buffers ([R] keys/nodes/is_read) are consumed by the
    reshape at the top of _simulate and never read again by the caller
    (run_scenario regenerates the trace per call), so they are donated —
    XLA reuses their HBM for the chunked copies instead of
    double-buffering a whole trace. Donation is a no-op (with a warning)
    on CPU, so it is gated on the backend. The batched/grid engines share
    traces across policy groups and must NOT donate."""
    donate = (0, 1, 2) if jax.default_backend() != "cpu" else ()
    return partial(
        jax.jit, static_argnames=_SIM_STATICS, donate_argnums=donate
    )(_simulate)


@partial(jax.jit, static_argnames=_SIM_STATICS)
def _simulate_batch(keys, nodes, is_read, natural, object_bytes, params, **statics):
    """Seed-batched fused engine: vmap over the leading (iteration) axis of
    the trace; the policy's dynamic params are broadcast."""
    return jax.vmap(
        lambda a, b, c, d, e: _simulate(a, b, c, d, e, params, **statics)
    )(keys, nodes, is_read, natural, object_bytes)


@partial(jax.jit, static_argnames=_SIM_STATICS)
def _simulate_grid(keys, nodes, is_read, natural, object_bytes, params, **statics):
    """Policy-grid engine: vmap the policy-parameter axis (leading ``[P]``
    on every ``params`` leaf) around the seed-batched engine — a whole
    same-family head-to-head grid as ONE compiled program, result leaves
    shaped ``[P, S, ...]``."""
    return jax.vmap(
        lambda p: jax.vmap(
            lambda a, b, c, d, e: _simulate(a, b, c, d, e, p, **statics)
        )(keys, nodes, is_read, natural, object_bytes)
    )(params)


@partial(jax.jit, static_argnames=("cfg",))
def _traces_for_seeds(cfg: WorkloadConfig, seeds: Array) -> Trace:
    """Batched trace generation (seed axis leading on every field)."""
    return jax.vmap(lambda s: generate_trace(cfg, s))(seeds)


# Single-seed trace generation, jitted: the eager spelling dispatched ~10
# device ops per call, a measurable slice of a warm 1M-request run (PRNG
# is deterministic, so the jitted trace is bit-identical).
_generate_trace_jit = partial(jax.jit, static_argnames=("cfg",))(generate_trace)

# Per-key state only (natural node + object sizes), for the streamed path:
# O(K) instead of the O(R) trace, same fold_in draws → identical bits.
_generate_key_state_jit = partial(jax.jit, static_argnames=("cfg",))(
    generate_key_state
)


@lru_cache(maxsize=None)
def _sharded_simulate_jit(num_shards: int):
    """The key-sharded engine: ``_simulate`` wrapped in ``shard_map`` over a
    1-D ``Mesh`` with a ``keys`` axis (grown from the ``publish_and_fill``
    2-rank seam in ``core/repartition.py``).

    Every INPUT is replicated (``in_specs=P()``): the O(R) trace (or the
    streamed seed) and the O(K) natural/object_bytes vectors are cheap and
    any shard's requests may reference any key; what shards is the O(K·N)
    per-key STATE built inside ``_simulate`` from each shard's
    ``dynamic_slice``. Outputs are psum-assembled global aggregates, so
    ``out_specs=P()`` (replicated) as well. ``check_rep=False`` because the
    body mixes shard-local intermediates with psum'd results inside a scan,
    which the replication checker cannot prove."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec

    devices = jax.devices()
    if len(devices) < num_shards:
        raise ValueError(
            f"num_shards={num_shards} needs {num_shards} devices, have "
            f"{len(devices)} (CPU: set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={num_shards} before "
            "importing jax)"
        )
    mesh = Mesh(np.array(devices[:num_shards]), ("keys",))
    replicated = PartitionSpec()

    def wrapped(keys, nodes, is_read, natural, object_bytes, params, seed,
                **statics):
        fn = shard_map(
            lambda a, b, c, d, e, f, g: _simulate(a, b, c, d, e, f, g,
                                                  **statics),
            mesh=mesh,
            in_specs=(replicated,) * 7,
            out_specs=replicated,
            check_rep=False,
        )
        return fn(keys, nodes, is_read, natural, object_bytes, params, seed)

    return partial(jax.jit, static_argnames=_SIM_STATICS)(wrapped)


def _check_scale_out(
    caller: str,
    workload: WorkloadConfig,
    cluster: ClusterConfig,
    static,
    trace_mode: str,
    num_shards: int,
) -> None:
    """Host-side validation for the scale-out engine options."""
    if trace_mode not in TRACE_MODES:
        raise ValueError(
            f"{caller}: trace_mode={trace_mode!r}; expected one of "
            f"{TRACE_MODES}"
        )
    if num_shards < 1:
        raise ValueError(f"{caller}: num_shards={num_shards} must be >= 1")
    if num_shards == 1:
        return
    if getattr(type(static), "name", "") == "topk":
        raise ValueError(
            f"{caller}: the topk policy ranks keys with a GLOBAL argsort "
            "and is not supported sharded (num_shards > 1)"
        )
    if cluster.has_finite_capacity:
        raise ValueError(
            f"{caller}: finite capacity_bytes needs the global projection "
            "sort and is not supported sharded (num_shards > 1)"
        )


def run_scenario(
    workload: WorkloadConfig,
    cluster: ClusterConfig,
    policy=None,
    seed: int = 0,
    daemon_interval: int = 1000,
    *,
    telemetry: TelemetryConfig | None = None,
    replay_backend: str = "jax",
    trace_mode: str = "materialized",
    num_shards: int = 1,
) -> SimResult | tuple[SimResult, SimTrace]:
    """Simulate one policy over one generated trace (fused scan engine).

    policy: a ``repro.core.policy`` instance — ``RedynisPolicy(...)``,
        ``StaticPolicy(mode=...)``, ``TopKPolicy(...)``, ... The policy
        carries every decision hyperparameter (H, expiry, decay, period,
        sweep backend); ``daemon_interval`` stays an engine argument (the
        chunking granularity both engines share). The legacy ``Scenario``
        spelling was removed; passing one raises with the replacement.
    telemetry: optional :class:`TelemetryConfig`. When enabled the scan
        additionally accumulates grouped log-bin latency histograms and
        per-chunk convergence series *inside* the fused program and the
        return value becomes ``(SimResult, SimTrace)``; when ``None`` (the
        default) the engine and its results are bit-identical to the
        pre-telemetry code path.
    replay_backend: the per-chunk request-path implementation — ``"jax"``
        (the bit-exact jnp composition, default) or ``"pallas"`` (the
        fused one-pass ``kernels.chunk_replay`` kernel; aggregates are
        allclose, histogram counts bit-exact). See the module docstring.

    Queueing-aware contention rides on the cluster: set
    ``cluster.service=ServiceConfig(...)`` and every request pays the
    M/M/1-style wait on top of its RTT-model latency (see the module
    docstring §Queueing model).

    trace_mode: ``"materialized"`` (default — generate the full ``[R]``
        trace up front, the historical path) or ``"streamed"`` — regenerate
        each chunk's requests *inside* the scan from the same fold_in
        stream, bit-identical results with peak live memory
        O(daemon_interval + K) instead of O(R + K).
    num_shards: shard the key axis across this many devices via
        ``shard_map`` (1 = the degenerate single-device program, compiled
        identically to previous releases). Requires ``num_keys %
        num_shards == 0`` and that many visible devices; the ``topk``
        policy and finite ``capacity_bytes`` need global sorts and are
        rejected sharded. Histogram counts and move counters stay
        bit-exact; f32 reductions (busy, latency sums) re-associate across
        shards and are allclose.
    """
    _check_replay_backend("run_scenario", replay_backend)
    static, params = _prepare(workload, cluster, "run_scenario", policy)
    telemetry = normalize_telemetry(telemetry)
    _check_scale_out(
        "run_scenario", workload, cluster, static, trace_mode, num_shards
    )
    if num_shards > 1:
        # Ceil-division block sharding: a non-dividing K pads the final
        # shard with dead keys (zero bytes, masked out of the live map
        # inside _simulate) so every shard holds the same block length.
        kps = -(-workload.num_keys // num_shards)
        shard = ShardSpec("keys", num_shards, kps * num_shards - workload.num_keys)
    else:
        shard = ShardSpec()
    if trace_mode == "streamed":
        keys = nodes = is_read = None
        natural, object_bytes = _generate_key_state_jit(workload, seed)
        stream_seed = jnp.asarray(seed, jnp.int32)
        stream_workload = workload
    else:
        trace = _generate_trace_jit(workload, seed)
        keys, nodes, is_read = trace.keys, trace.nodes, trace.is_read
        natural, object_bytes = trace.natural_node, trace.object_bytes
        stream_seed = None
        stream_workload = None
    if shard.pad:
        natural = jnp.concatenate(
            [natural, jnp.zeros((shard.pad,), natural.dtype)]
        )
        object_bytes = jnp.concatenate(
            [object_bytes, jnp.zeros((shard.pad,), object_bytes.dtype)]
        )
    engine = (
        _sharded_simulate_jit(num_shards) if shard.active else _simulate_jit()
    )
    leaves, telem = engine(
        keys,
        nodes,
        is_read,
        natural,
        object_bytes,
        params,
        stream_seed,
        cluster=cluster,
        policy=static,
        daemon_interval=daemon_interval,
        telemetry=telemetry,
        replay_backend=replay_backend,
        trace_mode=trace_mode,
        workload=stream_workload,
        shard=shard,
    )
    (
        tput, hit, mean_lat, busy, repl, drop, evic, cap_evic, peak,
        *routing_totals,
    ) = leaves
    result = SimResult(
        float(tput),
        float(hit),
        float(mean_lat),
        np.asarray(busy, dtype=np.float64),
        float(repl),
        float(drop),
        float(evic),
        float(cap_evic),
        np.asarray(peak, dtype=np.float64),
        # Routing counters, then fault counters — each optional block is a
        # strict prefix extension, and the engine zero-fills the routing
        # slots whenever the fault block is present, so the positional
        # tail is always length 0, 4, or 8 and the defaults fill the rest.
        *[float(x) for x in routing_totals],
    )
    if telemetry is None:
        return result
    return result, build_trace(telem, telemetry)


# ---------------------------------------------------------------------------
# Reference engine: the original per-chunk Python loop, kept as the oracle.
# ---------------------------------------------------------------------------


def _reference_engine(
    workload: WorkloadConfig,
    cluster: ClusterConfig,
    static,
    params: dict,
    seed: int,
    daemon_interval: int,
    telemetry: TelemetryConfig | None,
) -> tuple[
    SimResult, TelemetryLeaves | None, np.ndarray | None, np.ndarray | None
]:
    """The retained per-chunk Python loop. Returns ``(result, telemetry
    leaves | None, raw per-request latencies | None, raw per-request
    component matrix | None)`` — the raw latencies are what the
    histogram-quantile tests compare ``np.percentile`` against, and only
    this engine materialises them (the fused scan never leaves the
    device). The raw ``[NUM_COMPONENTS, R]`` component matrix is the
    attribution analogue (present only with attribution/flight enabled)."""
    trace = generate_trace(workload, seed)
    k, n, r = workload.num_keys, workload.num_nodes, workload.num_requests
    rtt = cluster.rtt_matrix()
    capacity = (
        cluster.capacity_vector() if cluster.has_finite_capacity else None
    )
    obj = jnp.asarray(trace.object_bytes, jnp.float32)
    ctx = PolicyContext(
        rtt=rtt, object_bytes=obj, capacity_bytes=capacity, params=params
    )

    store = _seed_store(
        _initial_hosts(trace.natural_node, k, n, static.initial_placement), k, n
    )
    pstate = static.init(store, ctx)
    contention = _contention_kwargs(cluster, static.read_mode, daemon_interval)
    routing = _routing_kwargs(cluster, k)
    num_chunks = (r + daemon_interval - 1) // daemon_interval
    fault = _fault_kwargs(cluster, num_chunks)
    sc = _replay_scalars(cluster)
    rstate = None
    history: list = []
    if routing is not None:
        rstate = init_router_state(
            store.hosts,
            num_routers=routing["num_routers"],
            cache_entries=routing["cache_entries"],
            publish_lag_chunks=routing["publish_lag_chunks"],
            active=static.is_active,
            force_ring=fault is not None,
        )
    r_consults = r_fetches = r_mis = r_stale = 0.0
    # Fault-run carry: wiped-keys mask + availability/repair counters
    # (Python floats — the reference engine is the float64 oracle).
    wiped = None if fault is None else jnp.zeros((k,), bool)
    unav_r = unav_w = failover_total = repair_total = 0.0

    total_lat = np.zeros((n,), dtype=np.float64)
    hits = 0.0
    reads = 0.0
    lat_sum = 0.0
    repl_moves = 0.0
    drop_moves = 0.0
    evictions = 0.0
    cap_evictions = 0.0
    peak_occ = np.asarray(
        _node_occupancy(store.hosts, obj), dtype=np.float64
    )
    telem: list = []
    raw_lats: list = []
    raw_comps: list = []
    acfg = None if telemetry is None else telemetry.attribution
    fcfg = None if telemetry is None else telemetry.flight

    for c in range(num_chunks):
        lo, hi = c * daemon_interval, min((c + 1) * daemon_interval, r)
        keys = trace.keys[lo:hi]
        nodes = trace.nodes[lo:hi]
        is_read = trace.is_read[lo:hi]
        cv = jnp.ones(keys.shape, bool)

        # Degraded-mode serving state, mirroring the scan body exactly:
        # with faults off these aliases ARE the pre-fault operands.
        served = cv
        hosts_eff = store.hosts
        avail_c = None
        f_extra = None
        if fault is not None:
            avail_c = fault["avail"][c]
            crash_c = fault["crash"][c]
            pre_hosts = store.hosts
            post_hosts = pre_hosts & ~crash_c[None, :]
            wiped = wiped | (
                jnp.any(pre_hosts, axis=-1) & ~jnp.any(post_hosts, axis=-1)
            )
            store = store._replace(hosts=post_hosts)
            f_extra, unavail, failover = fault_extra_ms_ref(
                store.hosts, keys, nodes, is_read, cv, avail_c, rtt,
                read_mode=static.read_mode,
                master=sc["master"],
                xfer_write_ms=sc["xfer_write_ms"],
                wiped=wiped,
            )
            served = cv & ~unavail
            hosts_eff = store.hosts & avail_c[None, :]

        lat, read_hits = _chunk_latency(
            hosts_eff, keys, nodes, is_read, rtt, cluster, static.read_mode
        )
        route = detour_part = fetch_part = None
        if routing is not None:
            # Same routing pre-pass as the fused engine. The published view
            # is reconstructed from a Python history of (hosts, version)
            # chunk-start snapshots: the view at chunk c is the snapshot
            # taken publish_lag_chunks earlier (clamped to the initial map)
            # — exactly what the scan's ring buffer holds.
            lag = routing["publish_lag_chunks"]
            if static.is_active:
                if fault is not None:
                    # Fault runs publish through the REAL ring machinery
                    # (publish_commit below can freeze it while the home
                    # node is down); the slot arithmetic reproduces the
                    # history reconstruction exactly when nothing freezes.
                    pub_hosts, pub_ver = published_view(
                        rstate, store.hosts, c, publish_lag_chunks=lag
                    )
                else:
                    history.append((store.hosts, rstate.ver))
                    pub_hosts, pub_ver = history[max(c - lag, 0)]
            else:
                pub_hosts = store.hosts
                pub_ver = jnp.zeros((k,), jnp.int32)
            rb = router_of(nodes, routing["num_routers"])
            ent_cached, fresh, age = consult_probe(rstate, rb, keys)
            (
                detour_part, fetch_part, consult, fetchb, staleb, misb,
            ) = routing_extra_split_ref(
                hosts_eff, pub_hosts, ent_cached, fresh, keys, nodes,
                is_read, served, rtt,
                read_mode=static.read_mode, home_node=routing["home_node"],
            )
            route = detour_part + fetch_part
        rho = None
        cont_extra = None
        if contention is not None:
            # Same pre-pass, same elementwise position as the fused engine
            # (reference chunks carry no padding — every row is valid).
            cont_extra, rho = contention_extra_ms_ref(
                hosts_eff, keys, nodes, is_read,
                served, rtt, obj, **contention,
            )
        extra = cont_extra
        if route is not None:
            # Canonical composition order (routing first, ONE f32 add).
            extra = route if extra is None else route + extra
        if f_extra is not None:
            # Fault surcharge composes FIRST — same order as the scan body.
            extra = f_extra if extra is None else f_extra + extra
        if extra is not None:
            lat = lat + extra
        if fault is not None:
            # The scan body's validity mask: refused requests cost nothing.
            lat = jnp.where(served, lat, 0.0)
        comps = None
        if acfg is not None or fcfg is not None:
            # Same component oracle as the fused engine, on the same frozen
            # map and pre-pass outputs (reference chunks have no padding).
            comps = chunk_components_ref(
                hosts_eff, keys, nodes, is_read, rtt,
                read_mode=static.read_mode,
                contention_ms=cont_extra,
                routing_detour_ms=detour_part,
                directory_fetch_ms=fetch_part,
                avail=avail_c,
                **sc,
            )
            if fault is not None:
                comps = jnp.where(served[None, :], comps, 0.0)
        busy = jnp.zeros((n,), jnp.float32).at[nodes].add(lat)
        total_lat += np.asarray(busy, dtype=np.float64)
        chunk_lat = float(jnp.sum(lat))
        chunk_hits = float(jnp.sum(read_hits & served))
        chunk_reads = float(jnp.sum(is_read & served))
        lat_sum += chunk_lat
        hits += chunk_hits
        reads += chunk_reads
        c_unav_r = c_unav_w = c_fo = c_rep = 0.0
        c_unreach = c_wiped = 0.0
        if fault is not None:
            c_unav_r = float(jnp.sum(unavail & is_read))
            c_unav_w = float(jnp.sum(unavail & ~is_read))
            c_fo = float(jnp.sum(failover))
            unav_r += c_unav_r
            unav_w += c_unav_w
            failover_total += c_fo
            unreach = (
                jnp.any(store.hosts, axis=-1) & ~jnp.any(hosts_eff, axis=-1)
            ) | wiped
            c_unreach = float(jnp.sum(unreach)) / k
            c_wiped = float(jnp.sum(wiped)) / k

        # Per-chunk occupancy sample on the frozen map, for every policy.
        occ = np.asarray(_node_occupancy(store.hosts, obj), np.float64)
        peak_occ = np.maximum(peak_occ, occ)
        chunk_routing = (0.0, 0.0, 0.0, 0.0)
        age_hist = np.zeros((STALE_AGE_BINS,), np.float64)
        if routing is not None:
            chunk_routing = (
                float(jnp.sum(consult)),
                float(jnp.sum(fetchb)),
                float(jnp.sum(misb)),
                float(jnp.sum(staleb)),
            )
            r_consults += chunk_routing[0]
            r_fetches += chunk_routing[1]
            r_mis += chunk_routing[2]
            r_stale += chunk_routing[3]
            age_hist = np.asarray(stale_age_fold(age, staleb), np.float64)
            rstate = router_cache_update(
                rstate, rb, keys, consult, pub_ver,
                cache_entries=routing["cache_entries"],
                decay=routing["decay"],
            )
        chunk_moves = (0.0, 0.0, 0.0, 0.0)
        if static.is_active:
            # Algorithm 1 bookkeeping: log usage heuristics per request
            # (down-origin users are offline and leave no demand signal).
            store = record_accesses(
                store, keys, nodes, now=c,
                valid=None if fault is None else avail_c[nodes],
            )
            prev_hosts = store.hosts
            if c % static.period == 0:
                step_ctx = (
                    ctx if fault is None else ctx._replace(avail=avail_c)
                )
                plan, pstate, store = policy_sweep(
                    static, pstate, store, c, step_ctx
                )
                chunk_moves = (
                    float(jnp.sum(plan.to_add)),
                    float(jnp.sum(plan.to_drop)),
                    float(jnp.sum(plan.to_drop & plan.expired[:, None])),
                    float(jnp.sum(plan.capacity_evicted)),
                )
                repl_moves += chunk_moves[0]
                drop_moves += chunk_moves[1]
                evictions += chunk_moves[2]
                cap_evictions += chunk_moves[3]
            if fault is not None:
                # Re-replication audit + wiped-key healing, mirroring the
                # scan body's repair accounting exactly.
                added = store.hosts & ~prev_hosts
                lost_live = jnp.any(prev_hosts, axis=-1) & ~jnp.any(
                    prev_hosts & avail_c[None, :], axis=-1
                )
                c_rep = float(
                    jnp.sum(added & (wiped | lost_live)[:, None])
                )
                repair_total += c_rep
                wiped = wiped & ~jnp.any(
                    store.hosts & avail_c[None, :], axis=-1
                )
            if routing is not None:
                changed = publish_mask(prev_hosts, store.hosts)
                if fault is not None:
                    # The real publish pipeline: versions always bump, the
                    # ring slot freezes while the directory home is down.
                    rstate = publish_commit(
                        rstate, changed, store.hosts, c,
                        publish_lag_chunks=routing["publish_lag_chunks"],
                        daemon_up=avail_c[routing["home_node"]],
                    )
                else:
                    # Versioned publish — same bump the fused engine
                    # applies after its masked policy step.
                    rstate = rstate._replace(
                        ver=rstate.ver + changed.astype(jnp.int32)
                    )
        if telemetry is not None:
            group = nodes * 2 + is_read.astype(jnp.int32)
            # Refused requests carry weight 0 (identical ones when off).
            w = served.astype(jnp.float32)
            ahist = asum = fmeta = fvals = None
            if acfg is not None:
                ahist = np.asarray(
                    attribution_chunk_hist(comps, group, w, acfg, n),
                    np.float64,
                )
                asum = np.asarray(jnp.sum(comps, axis=1), np.float64)
            if fcfg is not None:
                # Same per-chunk sample plan as the scan engine; offsets
                # past this (possibly short, final) chunk's length are
                # masked exactly like the scan masks its padded tail.
                b = int(lat.shape[0])
                jpos = np.asarray(
                    _flight_positions(fcfg, c, daemon_interval)
                )
                jc0 = np.minimum(jpos, b - 1)
                own = (jpos < b) & np.asarray(served)[jc0]
                jc = np.minimum(jpos, b - 1)
                mi = lambda v: np.where(own, v, 0).astype(np.int64)
                router_np = (
                    np.asarray(rb, np.int64) if routing is not None
                    else np.full((b,), -1, np.int64)
                )
                fmeta = np.stack(
                    [
                        mi(lo + jpos),
                        mi(np.asarray(keys)[jc]),
                        mi(np.asarray(nodes)[jc]),
                        mi(router_np[jc]),
                        mi(np.asarray(is_read)[jc].astype(np.int64) | 2),
                    ],
                    axis=1,
                )
                comps_np = np.asarray(comps, np.float64)
                scomps = np.where(own[None, :], comps_np[:, jc], 0.0)
                fvals = np.concatenate(
                    [scomps.sum(axis=0, keepdims=True), scomps], axis=0
                ).T
            telem.append(TelemetryLeaves(
                hist=np.asarray(
                    chunk_histogram(lat, group, w, telemetry, n), np.float64
                ),
                hits=chunk_hits,
                reads=chunk_reads,
                lat_sum=chunk_lat,
                count=(
                    float(lat.shape[0]) if fault is None
                    else float(jnp.sum(served))
                ),
                adds=chunk_moves[0],
                drops=chunk_moves[1],
                expiry_evictions=chunk_moves[2],
                capacity_evictions=chunk_moves[3],
                occupancy=occ,
                load_factor=(
                    np.zeros((n,), np.float64) if rho is None
                    else np.asarray(rho, np.float64)
                ),
                router_consults=chunk_routing[0],
                directory_fetches=chunk_routing[1],
                mis_routes=chunk_routing[2],
                stale_consults=chunk_routing[3],
                stale_age_hist=age_hist,
                unavailable_reads=c_unav_r,
                unavailable_writes=c_unav_w,
                failovers=c_fo,
                repair_moves=c_rep,
                unreachable_frac=c_unreach,
                wiped_frac=c_wiped,
                attr_hist=ahist,
                attr_sum=asum,
                flight_meta=fmeta,
                flight_vals=fvals,
            ))
            raw_lats.append(np.asarray(lat, np.float64))
            if comps is not None:
                raw_comps.append(np.asarray(comps, np.float64))

    makespan_ms = float(total_lat.max())
    served_r = r if fault is None else max(r - unav_r - unav_w, 1.0)
    result = SimResult(
        throughput_ops_s=r / (makespan_ms / 1000.0),
        hit_rate=hits / max(reads, 1.0),
        mean_latency_ms=lat_sum / served_r,
        node_busy_ms=total_lat,
        replication_moves=repl_moves,
        deletion_moves=drop_moves,
        evictions=evictions,
        capacity_evictions=cap_evictions,
        peak_occupancy_bytes=peak_occ,
        router_consults=r_consults,
        directory_fetches=r_fetches,
        mis_routes=r_mis,
        stale_consults=r_stale,
        unavailable_reads=unav_r,
        unavailable_writes=unav_w,
        failovers=failover_total,
        repair_moves=repair_total,
    )
    if telemetry is None:
        return result, None, None, None
    leaves = jax.tree_util.tree_map(lambda *xs: np.stack(xs), *telem)
    raw_c = np.concatenate(raw_comps, axis=1) if raw_comps else None
    return result, leaves, np.concatenate(raw_lats), raw_c


def run_scenario_reference(
    workload: WorkloadConfig,
    cluster: ClusterConfig,
    policy=None,
    seed: int = 0,
    daemon_interval: int = 1000,
    *,
    telemetry: TelemetryConfig | None = None,
) -> SimResult | tuple[SimResult, SimTrace]:
    """Slow-path reference: one host dispatch per chunk, the policy stepped
    with Python control flow. Semantically identical to :func:`run_scenario`
    (same policy protocol, same shared stages, same queueing model via
    ``cluster.service``). With ``telemetry`` the return value becomes
    ``(SimResult, SimTrace)``, and the trace carries ``raw_latency_ms`` —
    the exact per-request latencies (contention wait included) the
    histogram quantiles are validated against."""
    static, params = _prepare(
        workload, cluster, "run_scenario_reference", policy
    )
    telemetry = normalize_telemetry(telemetry)
    result, leaves, raw, raw_c = _reference_engine(
        workload, cluster, static, params, seed, daemon_interval, telemetry
    )
    if telemetry is None:
        return result
    return result, build_trace(
        leaves, telemetry, raw_latency_ms=raw, raw_components=raw_c
    )


def confidence_interval_99(samples: np.ndarray) -> tuple:
    """Mean ± 99% CI half-width (normal approx — matches the paper's error
    bars over repeated iterations).

    ``samples`` is per-seed: a ``[S]`` vector of scalars (the legacy
    throughput use) or an ``[S, ...]`` stack of per-seed statistic vectors —
    e.g. per-seed quantile samples ``[S, Q]`` — reduced along axis 0, in
    which case the mean/half-width come back as arrays of the trailing
    shape. Scalars still return plain floats."""
    samples = np.asarray(samples, dtype=np.float64)
    s = samples.shape[0]
    mean = np.mean(samples, axis=0)
    if s < 2:
        ci = np.zeros_like(mean)
    else:
        sem = np.std(samples, axis=0, ddof=1) / np.sqrt(s)
        ci = 2.576 * sem
    if mean.ndim == 0:
        return float(mean), float(ci)
    return mean, ci


# ---------------------------------------------------------------------------
# Batched experiments: seeds vmapped, same-family policy params vmapped too.
# ---------------------------------------------------------------------------


def _result_from_leaves(leaves, seed_idx: int) -> SimResult:
    (
        tput, hit, mean_lat, busy, repl, drop, evic, cap_evic, peak,
        *routing_totals,
    ) = leaves
    return SimResult(
        float(tput[seed_idx]),
        float(hit[seed_idx]),
        float(mean_lat[seed_idx]),
        np.asarray(busy[seed_idx], dtype=np.float64),
        float(repl[seed_idx]),
        float(drop[seed_idx]),
        float(evic[seed_idx]),
        float(cap_evic[seed_idx]),
        np.asarray(peak[seed_idx], dtype=np.float64),
        *[float(x[seed_idx]) for x in routing_totals],
    )


def _batched_policy_rows(
    policies, wl, cluster, iterations, daemon_interval, telemetry=None,
    replay_backend="jax",
):
    """All policies × all seeds for one workload: same-family policies
    (identical static key) have their dynamic params stacked and the policy
    axis vmapped alongside the seed axis. Returns ``(per-policy
    (aggregate leaves, telemetry leaves | None), number of compiled-program
    invocations)`` — telemetry histograms vmap across seeds (and the policy
    axis) exactly like the aggregates, so each policy row's leaves carry a
    leading ``[S]`` seed axis that merges by summation."""
    traces = _traces_for_seeds(wl, jnp.arange(iterations, dtype=jnp.int32))
    trace_args = (
        traces.keys, traces.nodes, traces.is_read, traces.natural_node,
        traces.object_bytes,
    )
    statics = dict(
        cluster=cluster, daemon_interval=daemon_interval, telemetry=telemetry,
        replay_backend=replay_backend,
    )

    groups: dict = {}  # static key -> list of (position, params)
    for i, pol in enumerate(policies):
        static, params = split_policy(pol)
        groups.setdefault(static, []).append((i, params))

    out: list = [None] * len(policies)
    calls = 0
    for static, members in groups.items():
        if members[0][1] and len(members) > 1:
            # Same family, different knobs: stack each dynamic field into a
            # [P] vector and vmap the policy axis — ONE batched program.
            stacked = {
                key: jnp.asarray([params[key] for _, params in members],
                                 jnp.float32)
                for key in members[0][1]
            }
            leaves = _simulate_grid(
                *trace_args, stacked, policy=static, **statics
            )
            calls += 1
            for p, (i, _) in enumerate(members):
                out[i] = jax.tree_util.tree_map(lambda leaf: leaf[p], leaves)
        else:
            for i, params in members:
                out[i] = _simulate_batch(
                    *trace_args, params, policy=static, **statics
                )
                calls += 1
    return out, calls


def run_experiment(
    read_fractions: tuple[float, ...] = (1.0, 0.9, 0.75, 0.5),
    skewed: bool = False,
    iterations: int = 5,
    num_requests: int = 100_000,
    cluster: ClusterConfig | None = None,
    engine: str = "scan",
    daemon_interval: int = 1000,
    policies=None,
    telemetry: TelemetryConfig | None = None,
    replay_backend: str = "jax",
    **workload_kwargs,
) -> dict:
    """Paper Figure 2/3 grid — and its generalisation to arbitrary policy
    head-to-heads — with 99% CIs over repeated iterations.

    policies: required list of ``repro.core.policy`` instances. The result
        dict maps each policy's label (``describe_policy``) to its
        read-fraction rows under ``"policies"``, each row carrying the
        aggregate stats AND the per-seed :class:`SimResult`s under
        ``"results"``. Same-family policies (e.g. four ``RedynisPolicy``
        variants) are batched into ONE compiled program per read ratio: the
        dynamic-parameter axis is vmapped alongside the seed axis
        (``"num_batched_calls"`` reports how many programs actually ran).
        The legacy no-``policies`` scenario grid was removed with the
        ``scenario=`` shim.
    engine: "scan" (default) runs every CI iteration as one vmapped
        program; "reference" replays the retained per-chunk Python loop
        (the oracle the equivalence tests pin the scan engine to).
    replay_backend: the scan engine's per-chunk request path —
        ``"jax"`` (bit-exact jnp, default) or ``"pallas"`` (the fused
        ``kernels.chunk_replay`` kernel). The reference engine is the jnp
        oracle by definition and rejects ``"pallas"``.
    telemetry: optional :class:`TelemetryConfig`. When enabled each row
        additionally reports ``p99_latency_ms`` with a ``p99_ci99`` CI band
        (99% CI over the per-seed interpolated P99 samples), the canonical
        ``quantiles`` block, and a seed-merged :class:`SimTrace` under
        ``"trace"`` (histograms summed across seeds — the merge the
        associativity tests pin).
    """
    if cluster is None:
        cluster = ClusterConfig()
    workload_kwargs.setdefault("num_nodes", cluster.num_nodes)
    if engine not in ("scan", "reference"):
        raise ValueError(f"unknown engine {engine!r}")
    _check_replay_backend("run_experiment", replay_backend)
    if engine == "reference" and replay_backend != "jax":
        raise ValueError(
            "run_experiment: engine='reference' is the jnp oracle and only "
            "supports replay_backend='jax'"
        )
    telemetry = normalize_telemetry(telemetry)

    if policies is None:
        raise ValueError(
            "run_experiment: policies is required — e.g. policies=["
            "StaticPolicy(mode='remote'), RedynisPolicy()] (the legacy "
            "scenario grid was removed with the scenario= shim)"
        )
    named = []
    for pol in policies:
        _reject_scenario("run_experiment", pol)
        pol = pol.resolve(cluster.num_nodes)
        pol.validate(cluster.num_nodes)
        named.append((describe_policy(pol), pol))
    if len({label for label, _ in named}) != len(named):
        raise ValueError(
            f"duplicate policy labels in {[l for l, _ in named]}; "
            f"vary at least one hyperparameter per entry"
        )
    labels = [label for label, _ in named]
    pols = [pol for _, pol in named]

    out: dict = {
        "skewed": skewed,
        "read_fractions": list(read_fractions),
        "policies": {label: [] for label in labels},
        "num_batched_calls": 0,
    }
    table = out["policies"]
    for rf in read_fractions:
        wl = WorkloadConfig(
            num_requests=num_requests,
            read_fraction=rf,
            skewed=skewed,
            **workload_kwargs,
        )
        _check_topology(wl, cluster)
        if engine == "reference":
            per_policy, per_telem = [], []
            for pol in pols:
                static, params = split_policy(pol)
                results, leaves = [], []
                for it in range(iterations):
                    res, lv, _, _ = _reference_engine(
                        wl, cluster, static, params, it, daemon_interval,
                        telemetry,
                    )
                    results.append(res)
                    leaves.append(lv)
                per_policy.append(results)
                per_telem.append(
                    None if telemetry is None
                    else jax.tree_util.tree_map(
                        lambda *xs: np.stack(xs), *leaves
                    )
                )
        else:
            rows_leaves, calls = _batched_policy_rows(
                pols, wl, cluster, iterations, daemon_interval, telemetry,
                replay_backend,
            )
            out["num_batched_calls"] += calls
            per_policy = [
                [_result_from_leaves(sim, it) for it in range(iterations)]
                for sim, _ in rows_leaves
            ]
            per_telem = [telem for _, telem in rows_leaves]
        for label, results, telem in zip(labels, per_policy, per_telem):
            samples = np.array([r.throughput_ops_s for r in results])
            mean, ci = confidence_interval_99(samples)
            # hit_rate is the seed MEAN with its own 99% CI band — the
            # seed-0 point estimate it replaces was biased for any policy
            # whose convergence depends on the trace (EXPERIMENTS.md
            # §Engine-performance notes the change).
            hit_mean, hit_ci = confidence_interval_99(
                np.array([r.hit_rate for r in results])
            )
            row = {
                "read_fraction": rf,
                "throughput": mean,
                "ci99": ci,
                "hit_rate": hit_mean,
                "hit_rate_ci99": hit_ci,
                "mean_latency_ms": float(
                    np.mean([r.mean_latency_ms for r in results])
                ),
                "results": results,
            }
            if telemetry is not None:
                # Per-seed P99 samples feed the CI band; the row's trace is
                # the seed-merged aggregate (histograms sum across seeds).
                p99s = np.array([
                    leaves_quantile(
                        jax.tree_util.tree_map(lambda a, s=s: a[s], telem),
                        telemetry, 0.99,
                    )
                    for s in range(iterations)
                ])
                p99_mean, p99_ci = confidence_interval_99(p99s)
                trace = build_trace(merge_leaves(telem), telemetry)
                row["p99_latency_ms"] = p99_mean
                row["p99_ci99"] = p99_ci
                row["quantiles"] = trace.tail_summary()
                row["trace"] = trace
            table[label].append(row)
    return out
