"""Failure-injection schedules: membership timelines for the simulator.

A :class:`FaultConfig` is a declarative list of :class:`FaultEvent`\\ s —
node crashes, zone/region partitions — that
:func:`compile_schedule` lowers into two host-side ``[C, N]`` boolean
timelines aligned to the engine's chunk axis:

  * ``avail[c, n]`` — node ``n`` serves during chunk ``c``. The engines
    fold this through the ``lax.scan`` as a constant indexed by the traced
    chunk counter: every downstream consumer (read fallback, contention,
    routing, attribution) prices against the availability-masked map
    ``hosts_eff = hosts & avail[c]``, and the write-failover delta plus the
    per-request unavailability verdict come from the one canonical pass
    ``kernels.chunk_replay.ref.fault_extra_ms_ref``.
  * ``crash[c, n]`` — node ``n``'s local replicas are destroyed at the
    *start* of chunk ``c`` (True only at a crash event's first chunk).
    ``mode="crash"`` loses data: the node's copies leave the authoritative
    map, keys whose last replica died go dark until the placement daemon
    re-seeds them from the durable backing store on its next due tick.
    ``mode="partition"`` is loss-free: the map is untouched and the node's
    copies serve again the chunk the partition heals.

Failure domains: ``kind="node"`` targets one node id; ``kind="zone"`` /
``"region"`` target every node whose label in the cluster's
``zone_of`` / ``region_of`` hierarchy labelling matches — the Crux-style
correlated blast radius. When a labelling is absent each node is its own
zone and its own region (a flat hierarchy), so domain kinds degrade
gracefully on unlabelled clusters.

Like ``routing.py``, this module is pure schedule/state machinery and must
stay import-free of ``repro.kvsim.cluster`` (which imports it to hang
``FaultConfig`` off ``ClusterConfig.faults``).

Off state: ``faults=None`` (or ``enabled=False``, or an empty event list)
normalises to ``None`` and the engines compile the exact PR-9 program —
``None`` carry leaves, zero-valued fault telemetry, goldens bit-exact.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

__all__ = [
    "FAULT_KINDS",
    "FAULT_MODES",
    "FaultEvent",
    "FaultConfig",
    "normalize_faults",
    "default_labels",
    "domain_nodes",
    "compile_schedule",
    "event_windows",
    "region_outage",
    "blast_radius_rows",
]

FAULT_KINDS = ("node", "zone", "region")
FAULT_MODES = ("crash", "partition")


class FaultEvent(NamedTuple):
    """One scheduled failure: ``target`` (a node id or a zone/region label,
    per ``kind``) goes down at ``start_chunk`` for ``duration_chunks``
    chunks (``<= 0`` = until the end of the trace)."""

    kind: str = "node"
    target: int = 0
    start_chunk: int = 0
    duration_chunks: int = 0
    mode: str = "crash"

    def validate(self) -> "FaultEvent":
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"FaultEvent.kind must be one of {FAULT_KINDS}, "
                f"got {self.kind!r}"
            )
        if self.mode not in FAULT_MODES:
            raise ValueError(
                f"FaultEvent.mode must be one of {FAULT_MODES}, "
                f"got {self.mode!r}"
            )
        if self.target < 0:
            raise ValueError(f"FaultEvent.target must be >= 0, got {self.target}")
        if self.start_chunk < 0:
            raise ValueError(
                f"FaultEvent.start_chunk must be >= 0, got {self.start_chunk}"
            )
        return self


class FaultConfig(NamedTuple):
    """Declarative fault schedule (hangs off ``ClusterConfig.faults``).

    Hashable (a jit-static rides on the cluster config) and off-by-default:
    ``normalize_faults`` collapses disabled/empty configs to ``None``.
    """

    enabled: bool = True
    events: tuple[FaultEvent, ...] = ()

    def validate(self) -> "FaultConfig":
        for ev in self.events:
            if not isinstance(ev, FaultEvent):
                raise TypeError(
                    "FaultConfig.events must be FaultEvent instances, "
                    f"got {type(ev).__name__}"
                )
            ev.validate()
        return self


def normalize_faults(faults: "FaultConfig | None") -> "FaultConfig | None":
    """Collapse every off state to ``None`` (the house off-by-default
    pattern): ``None``, ``enabled=False``, and an empty event list all
    compile the identical fault-free program."""
    if faults is None:
        return None
    faults.validate()
    if not faults.enabled or not faults.events:
        return None
    return faults


def default_labels(num_nodes: int) -> tuple[int, ...]:
    """The flat hierarchy: each node is its own zone and its own region."""
    return tuple(range(num_nodes))


def _labels_for(
    kind: str,
    num_nodes: int,
    zone_of: tuple[int, ...] | None,
    region_of: tuple[int, ...] | None,
) -> tuple[int, ...]:
    if kind == "node":
        return default_labels(num_nodes)
    labels = zone_of if kind == "zone" else region_of
    return default_labels(num_nodes) if labels is None else tuple(labels)


def domain_nodes(
    event: FaultEvent,
    *,
    num_nodes: int,
    zone_of: tuple[int, ...] | None = None,
    region_of: tuple[int, ...] | None = None,
) -> np.ndarray:
    """``[N] bool`` — the nodes inside the event's failure domain."""
    labels = _labels_for(event.kind, num_nodes, zone_of, region_of)
    if len(labels) != num_nodes:
        raise ValueError(
            f"{event.kind} labelling has {len(labels)} entries for "
            f"{num_nodes} nodes"
        )
    mask = np.asarray(labels) == event.target
    if not mask.any():
        raise ValueError(
            f"FaultEvent targets {event.kind} {event.target}, which labels "
            "no node"
        )
    return mask


def event_windows(
    faults: FaultConfig, num_chunks: int
) -> list[tuple[FaultEvent, int, int]]:
    """Each event clipped to the trace: ``(event, start, end)`` half-open
    chunk windows (events entirely past the trace end are dropped)."""
    out = []
    for ev in faults.events:
        start = ev.start_chunk
        if start >= num_chunks:
            continue
        end = num_chunks if ev.duration_chunks <= 0 else min(
            num_chunks, start + ev.duration_chunks
        )
        if end > start:
            out.append((ev, start, end))
    return out


def compile_schedule(
    faults: FaultConfig,
    *,
    num_nodes: int,
    num_chunks: int,
    zone_of: tuple[int, ...] | None = None,
    region_of: tuple[int, ...] | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Lower the declarative schedule to ``(avail [C, N], crash [C, N])``
    boolean timelines (host-side numpy; the engines embed them as scan
    constants). ``avail`` ANDs over every active event's domain; ``crash``
    is True only at a crash event's start chunk (the one-shot replica wipe
    — re-crashing an already-down node is idempotent)."""
    faults.validate()
    avail = np.ones((num_chunks, num_nodes), dtype=bool)
    crash = np.zeros((num_chunks, num_nodes), dtype=bool)
    for ev, start, end in event_windows(faults, num_chunks):
        mask = domain_nodes(
            ev, num_nodes=num_nodes, zone_of=zone_of, region_of=region_of
        )
        avail[start:end, mask] = False
        if ev.mode == "crash":
            crash[start, mask] = True
    if not avail.any(axis=1).all():
        dark = int(np.argmin(avail.any(axis=1)))
        raise ValueError(
            f"fault schedule leaves no node available at chunk {dark} — "
            "the failover master election needs at least one live node"
        )
    return avail, crash


def region_outage(
    target: int,
    start_chunk: int,
    duration_chunks: int,
    *,
    mode: str = "crash",
) -> FaultConfig:
    """Convenience: the bench's canonical single-region outage drill."""
    return FaultConfig(
        events=(
            FaultEvent(
                kind="region",
                target=target,
                start_chunk=start_chunk,
                duration_chunks=duration_chunks,
                mode=mode,
            ),
        )
    )


def blast_radius_rows(
    faults: FaultConfig,
    *,
    num_chunks: int,
    unreachable_frac: np.ndarray,  # [C] fraction of keys with no live replica
    wiped_frac: np.ndarray,  # [C] fraction of keys that lost every replica
) -> list[dict]:
    """Per-scheduled-failure blast radius: for each event window, the peak
    fraction of keys left with no live replica (``unreachable``) and no
    surviving replica at all (``wiped``) — read off the engine's per-chunk
    fault telemetry series."""
    rows = []
    for ev, start, end in event_windows(faults, num_chunks):
        rows.append(
            {
                "kind": ev.kind,
                "target": int(ev.target),
                "mode": ev.mode,
                "start_chunk": int(start),
                "end_chunk": int(end),
                "blast_radius_unreachable": float(
                    np.max(unreachable_frac[start:end])
                ),
                "blast_radius_wiped": float(np.max(wiped_frac[start:end])),
            }
        )
    return rows
