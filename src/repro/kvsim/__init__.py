"""Faithful reproduction of the paper's testbed (§8) as a trace-driven simulator.

3 nodes × {RedynisService, Redis data instance, Redis metadata instance} +
one master propagator for write serialization + the RedynisDaemon — with the
paper's latency model: 100 ms simulated remote penalty, 0 ms local (§8.2).

The simulator runs the *same* core engine (metadata/ownership/placement) that
the ML integrations use; only the latency bookkeeping is simulation-specific.
"""

from repro.kvsim.workload import Trace, WorkloadConfig, generate_trace
from repro.kvsim.cluster import ClusterConfig, Scenario
from repro.kvsim.simulate import SimResult, run_scenario, run_experiment

__all__ = [
    "Trace",
    "WorkloadConfig",
    "generate_trace",
    "ClusterConfig",
    "Scenario",
    "SimResult",
    "run_scenario",
    "run_experiment",
]
