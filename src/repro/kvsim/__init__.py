"""Faithful reproduction of the paper's testbed (§8) as a trace-driven simulator.

3 nodes × {RedynisService, Redis data instance, Redis metadata instance} +
one master propagator for write serialization + the RedynisDaemon — with the
paper's latency model generalised to an ``[N, N]`` inter-node RTT matrix:
the paper's flat 100 ms remote penalty is the degenerate topology (§8.2);
``wan5_cluster`` + the region-skewed / diurnal workload presets open the
geo-distributed scenarios the paper motivates but never measures.

The simulator runs the *same* core engine (metadata/ownership/placement) that
the ML integrations use; only the latency bookkeeping is simulation-specific.
``run_scenario`` is a single fused ``lax.scan`` program per *policy*
(``repro.core.policy`` — the legacy ``Scenario`` enum spelling was removed
after its deprecation window; passing one raises with the replacement);
``run_scenario_reference`` retains the per-chunk Python loop as the
oracle. ``ClusterConfig.service`` (a ``ServiceConfig``) turns on the
M/M/1-style queueing model — per-chunk load factors from object bytes and
serving-node demand folds. ``telemetry=TelemetryConfig()`` makes
either engine additionally accumulate log-bin latency histograms and
per-chunk convergence series *inside* the scan, returned as a ``SimTrace``
(tail quantiles P50–P99.9, convergence/oscillation diagnostics — see
``telemetry.py``). ``ClusterConfig.faults`` (a ``FaultConfig``) turns on
failure injection — a declarative membership timeline (node/zone/region
crashes and partitions at chunk boundaries) with degraded-mode serving,
write failover, daemon re-replication, and availability/blast-radius
telemetry (see ``faults.py``). The placement policies are re-exported here
for convenience.
"""

from repro.core.policy import (
    POLICIES,
    CostGreedyPolicy,
    DecayLFUPolicy,
    RedynisPolicy,
    SizeAwarePolicy,
    StaticPolicy,
    TopKPolicy,
    describe_policy,
    make_policy,
    parse_policy,
)

from repro.kvsim.workload import (
    Trace,
    TraceChunk,
    WorkloadConfig,
    diurnal_workload,
    generate_key_state,
    generate_trace,
    generate_trace_chunk,
    wan5_workload,
)
from repro.kvsim.cluster import (
    WAN5_REGIONS,
    WAN5_RTT_MS,
    ClusterConfig,
    FaultConfig,
    FaultEvent,
    Scenario,
    RoutingConfig,
    ServiceConfig,
    flat_rtt,
    normalize_faults,
    normalize_routing,
    normalize_service,
    wan5_cluster,
    wan5_edge_cluster,
)
from repro.kvsim.faults import (
    FAULT_KINDS,
    FAULT_MODES,
    blast_radius_rows,
    compile_schedule,
    region_outage,
)
from repro.kvsim.simulate import (
    REPLAY_BACKENDS,
    TRACE_MODES,
    ShardSpec,
    SimResult,
    confidence_interval_99,
    run_experiment,
    run_scenario,
    run_scenario_reference,
)
from repro.kvsim.telemetry import (
    COMPONENTS,
    NUM_COMPONENTS,
    QUANTILE_LABELS,
    AttributionConfig,
    FlightRecorderConfig,
    SimTrace,
    TelemetryConfig,
    histogram_quantile,
)
from repro.kvsim.tracing import (
    chrome_trace_events,
    write_chrome_trace,
    write_jsonl,
)

__all__ = [
    "Trace",
    "TraceChunk",
    "WorkloadConfig",
    "generate_trace",
    "generate_trace_chunk",
    "generate_key_state",
    "wan5_workload",
    "diurnal_workload",
    "TRACE_MODES",
    "ShardSpec",
    "ClusterConfig",
    "Scenario",
    "ServiceConfig",
    "normalize_service",
    "RoutingConfig",
    "normalize_routing",
    "FaultConfig",
    "FaultEvent",
    "normalize_faults",
    "FAULT_KINDS",
    "FAULT_MODES",
    "region_outage",
    "compile_schedule",
    "blast_radius_rows",
    "flat_rtt",
    "wan5_cluster",
    "wan5_edge_cluster",
    "WAN5_REGIONS",
    "WAN5_RTT_MS",
    "REPLAY_BACKENDS",
    "SimResult",
    "SimTrace",
    "TelemetryConfig",
    "AttributionConfig",
    "FlightRecorderConfig",
    "COMPONENTS",
    "NUM_COMPONENTS",
    "histogram_quantile",
    "QUANTILE_LABELS",
    "chrome_trace_events",
    "write_chrome_trace",
    "write_jsonl",
    "run_scenario",
    "run_scenario_reference",
    "run_experiment",
    "confidence_interval_99",
    "POLICIES",
    "CostGreedyPolicy",
    "DecayLFUPolicy",
    "RedynisPolicy",
    "SizeAwarePolicy",
    "StaticPolicy",
    "TopKPolicy",
    "describe_policy",
    "make_policy",
    "parse_policy",
]
