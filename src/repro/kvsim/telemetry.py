"""In-scan telemetry: fused latency histograms + per-tick convergence traces.

The paper's stated objective is protecting *end-user response latency*, yet
a whole simulated run used to collapse into one ``mean_latency_ms`` — and
means hide exactly the tail behaviour geo-distributed round-trips inflate
(Didona & Zwaenepoel, 1802.00696, argue P95/P99 are the metric that matters
for in-memory KV stores; TurboKV, 2010.14931, evaluates repartitioning by
latency *distribution*). This module is the observability layer both
simulation engines share:

  * **Latency histograms**, accumulated *inside* the fused ``lax.scan``
    (no trace re-walk, no host round-trips): per chunk the engine folds the
    request latencies into a ``[2N, B]`` grouped histogram whose group id
    encodes ``(node, read/write)`` — global, per-node, and read/write-split
    views are all row-sums of that one array, so histograms merge across
    chunks, seeds, and vmapped policy rows by plain summation. The hot path
    is the ``kernels/latency_histogram`` trio (bucketize + grouped
    scatter-add fused into one pass, MXU-friendly one-hot matmul on TPU);
    ``TelemetryConfig.backend`` selects the pure-JAX reference or the
    Pallas kernel, parity-pinned by tests.

  * **Per-chunk time series** (hit rate, mean/p99 latency, moves applied,
    occupancy, evictions — and, with an enabled ``ServiceConfig``, the
    per-node serving load factor), emitted as the scan's ``ys`` — the
    convergence / oscillation diagnostics a repartitioning policy is
    judged by.

Both surface as a :class:`SimTrace` returned alongside ``SimResult``.
Telemetry is **off by default** and the disabled path is structurally
identical to the pre-telemetry engine (no extra carry entries, no ys), so
results stay bit-exact — pinned by tests/test_telemetry.py.

Quantiles are interpolated from the log-spaced histogram: bins have
constant *relative* width ``rho = (hi/lo)**(1/(B-2))``, so any interpolated
quantile is within one bin width (a factor of ``rho``) of the exact
order-statistic — at the default 128 bins over [1 ms, 10 s] that is ~7.6%
relative error, and the acceptance tests verify P99 against
``np.percentile`` of the reference engine's raw latencies.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.kernels.latency_histogram.ref import (
    bin_edges,
    bin_index,
    latency_histogram_ref,
)

__all__ = [
    "TelemetryConfig",
    "TelemetryLeaves",
    "SimTrace",
    "chunk_histogram",
    "trace_histogram",
    "merge_leaves",
    "psum_leaves",
    "build_trace",
    "leaves_quantile",
    "histogram_quantile",
    "histogram_quantile_rows",
    "quantile_summary",
    "normalize_telemetry",
    "QUANTILE_LABELS",
]

TELEMETRY_BACKENDS = ("jax", "pallas")

# The canonical report quantiles: label -> q.
QUANTILE_LABELS = {"p50": 0.5, "p90": 0.9, "p95": 0.95, "p99": 0.99, "p999": 0.999}


class TelemetryConfig(NamedTuple):
    """Histogram/trace collection knobs (hashable — a valid jit static).

    Telemetry is off by default at the engine level (``telemetry=None``);
    constructing a config turns it on unless ``enabled=False`` (useful for
    threading one kwarg through sweep drivers). ``num_bins`` includes the
    underflow (< ``lo_ms``) and overflow (>= ``hi_ms``) buckets; the
    ``num_bins - 2`` interior bins are log-spaced, so the quantile
    interpolation error is one *relative* bin width
    ``(hi_ms/lo_ms)**(1/(num_bins-2))``. ``backend`` routes the per-chunk
    bucketize+scatter-add through the pure-JAX reference or the Pallas
    ``latency_histogram`` kernel (interpret auto-selected off-TPU).
    """

    enabled: bool = True
    num_bins: int = 128
    lo_ms: float = 1.0
    hi_ms: float = 10_000.0
    backend: str = "jax"

    def validate(self) -> None:
        if self.num_bins < 4:
            raise ValueError(
                f"num_bins must be >= 4 (2 interior + under/overflow), "
                f"got {self.num_bins}"
            )
        if not (0.0 < self.lo_ms < self.hi_ms):
            raise ValueError(
                f"need 0 < lo_ms < hi_ms, got lo_ms={self.lo_ms} "
                f"hi_ms={self.hi_ms}"
            )
        if self.backend not in TELEMETRY_BACKENDS:
            raise ValueError(
                f"unknown telemetry backend {self.backend!r}; expected one "
                f"of {TELEMETRY_BACKENDS}"
            )

    def edges(self) -> np.ndarray:
        """Host-side ``[num_bins + 1]`` bin edges: ``[0, lo, ..., hi, inf]``."""
        return bin_edges(self.lo_ms, self.hi_ms, self.num_bins)


def normalize_telemetry(telemetry) -> TelemetryConfig | None:
    """``None``-or-disabled collapses to ``None`` so the jit static cache
    (and the structural no-op guarantee) treats both spellings identically."""
    if telemetry is None or not telemetry.enabled:
        return None
    telemetry.validate()
    return telemetry


class TelemetryLeaves(NamedTuple):
    """Raw per-chunk accumulators, the scan's ``ys`` (leading axis = chunk;
    batched engines add seed / policy axes in front). Every field is a sum
    over requests — except ``occupancy``, a point sample of the chunk's
    frozen map — so merging across seeds or policy rows sums the counters
    and averages the occupancy (:func:`merge_leaves`); associativity of
    the merge is pinned by tests."""

    hist: Array  # [C, 2N, B] grouped latency histogram per chunk
    hits: Array  # [C] read hits
    reads: Array  # [C] valid reads
    lat_sum: Array  # [C] summed latency (ms)
    count: Array  # [C] valid requests
    adds: Array  # [C] replicas created by the policy sweep
    drops: Array  # [C] replicas dropped (all causes)
    expiry_evictions: Array  # [C] drops caused by key expiry
    capacity_evictions: Array  # [C] held replicas evicted by the budget
    occupancy: Array  # [C, N] replica bytes on the chunk's frozen map
    # [C, N] per-chunk serving-node load factor rho (ServiceConfig); all
    # zeros when contention is off. A point sample like occupancy: merges
    # by averaging, not summing.
    load_factor: Array | float = 0.0
    # Routing/directory-tier counters (RoutingConfig — repro.kvsim.routing);
    # all zeros when the tier is off. Plain additive counters: they merge
    # and psum like hits/reads.
    router_consults: Array | float = 0.0  # [C] directory consults
    directory_fetches: Array | float = 0.0  # [C] cache misses (home fetches)
    mis_routes: Array | float = 0.0  # [C] consults detoured by staleness
    stale_consults: Array | float = 0.0  # [C] consults on stale entries
    stale_age_hist: Array | float = 0.0  # [C, STALE_AGE_BINS] version-gap ages


def chunk_histogram(
    lat: Array,  # [R] per-request latency (ms)
    group: Array,  # [R] i32 group id = node * 2 + is_read
    weight: Array,  # [R] f32, 0 masks padded rows
    cfg: TelemetryConfig,
    num_nodes: int,
) -> Array:
    """One chunk's ``[2N, B]`` grouped histogram via the configured backend."""
    kwargs = dict(
        num_groups=2 * num_nodes,
        num_bins=cfg.num_bins,
        lo=jnp.float32(cfg.lo_ms),
        hi=jnp.float32(cfg.hi_ms),
    )
    if cfg.backend == "pallas":
        from repro.kernels.latency_histogram.ops import latency_histogram

        return latency_histogram(lat, group, weight, **kwargs)
    return latency_histogram_ref(lat, group, weight, **kwargs)


def trace_histogram(
    lat: Array,  # [C * B] whole-trace latencies (chunk-major)
    group: Array,  # [C * B] i32 group id = node * 2 + is_read
    weight: Array,  # [C * B] f32, 0 masks padded rows
    cfg: TelemetryConfig,
    num_nodes: int,
    num_chunks: int,
    bin_idx: Array | None = None,
) -> Array:
    """The whole trace's ``[C, 2N, B]`` per-chunk grouped histograms in ONE
    pass — the static-fast-path companion of :func:`chunk_histogram`.

    With a frozen replica map the engine replays the entire trace outside
    the scan, so the per-chunk histograms become one flat ``bincount`` over
    the combined ``(chunk, group, bin)`` index (an order of magnitude
    faster on CPU than a per-chunk scatter loop; counts are integers, so
    the result is bit-identical to C separate :func:`chunk_histogram`
    calls — pinned by tests). The ``backend="pallas"`` config instead
    vmaps the fused histogram kernel over the chunk axis (the TPU path).
    ``bin_idx`` lets the caller supply precomputed bucket indices (the
    static path gathers them from its (key, node, is_read) grid).
    """
    g = 2 * num_nodes
    b = lat.shape[0] // num_chunks
    if cfg.backend == "pallas":
        resh = lambda x: x.reshape(num_chunks, b)
        return jax.vmap(
            lambda l, gr, w: chunk_histogram(l, gr, w, cfg, num_nodes)
        )(resh(lat), resh(group), resh(weight))
    idx = bin_idx if bin_idx is not None else bin_index(
        lat.astype(jnp.float32), cfg.lo_ms, cfg.hi_ms, cfg.num_bins
    )
    chunk = jnp.arange(lat.shape[0], dtype=jnp.int32) // b
    flat = (chunk * g + group) * cfg.num_bins + idx
    hist = jnp.bincount(
        flat, weights=weight.astype(jnp.float32),
        length=num_chunks * g * cfg.num_bins,
    )
    return hist.reshape(num_chunks, g, cfg.num_bins).astype(jnp.float32)


def merge_leaves(leaves: TelemetryLeaves, axis: int = 0) -> TelemetryLeaves:
    """Merge a batch axis away (seeds, policy rows). Histograms and
    counters are additive and *sum*; the derived rates/quantiles are then
    recomputed from the merged sums by :func:`build_trace`. ``occupancy``
    and ``load_factor`` are point samples, not counters — summing would
    inflate them by the batch size — so they *average* across the batch
    instead."""
    n = np.asarray(leaves.occupancy).shape[axis]
    merged = jax.tree_util.tree_map(
        lambda a: np.asarray(a, dtype=np.float64).sum(axis=axis), leaves
    )
    return merged._replace(
        occupancy=merged.occupancy / n,
        load_factor=merged.load_factor / n,
    )


def psum_leaves(leaves: TelemetryLeaves, axis_name: str) -> TelemetryLeaves:
    """Merge per-shard telemetry into global telemetry inside a key-sharded
    ``shard_map`` program — the collective twin of :func:`merge_leaves`.

    Every additive leaf (histograms, hit/read/latency/request counters,
    daemon move counters) psums across the shard axis; histogram counts are
    integer-valued f32 sums, so the psum is *exact* and sharded histograms
    stay bit-identical to single-device ones (the merge is sum-associative
    — the same property the seed-merge tests pin). ``occupancy`` and
    ``load_factor`` pass through untouched: the engine already assembles
    those as global values inside the scan body (occupancy is psum'd at the
    sample point so the running *peak* is taken over the global vector;
    the load factor's demand fold psums inside the contention pre-pass)."""
    summed = jax.lax.psum(
        (
            leaves.hist, leaves.hits, leaves.reads, leaves.lat_sum,
            leaves.count, leaves.adds, leaves.drops,
            leaves.expiry_evictions, leaves.capacity_evictions,
            leaves.router_consults, leaves.directory_fetches,
            leaves.mis_routes, leaves.stale_consults, leaves.stale_age_hist,
        ),
        axis_name,
    )
    return leaves._replace(
        hist=summed[0], hits=summed[1], reads=summed[2], lat_sum=summed[3],
        count=summed[4], adds=summed[5], drops=summed[6],
        expiry_evictions=summed[7], capacity_evictions=summed[8],
        router_consults=summed[9], directory_fetches=summed[10],
        mis_routes=summed[11], stale_consults=summed[12],
        stale_age_hist=summed[13],
    )


# ---------------------------------------------------------------------------
# Quantile interpolation on log-spaced histograms.
# ---------------------------------------------------------------------------


def histogram_quantile(hist: np.ndarray, edges: np.ndarray, q: float) -> float:
    """Interpolated quantile from binned counts.

    Within the target bucket the mass is spread geometrically (uniform in
    log-latency — the natural prior for log-spaced bins), so the result is
    within one bin width of the exact order statistic. The unbounded
    under/overflow buckets clamp to their finite edge. Delegates to the
    row-vectorised form so the two can never drift.
    """
    hist = np.asarray(hist, dtype=np.float64)
    return float(histogram_quantile_rows(hist[None, :], edges, q)[0])


def histogram_quantile_rows(
    hists: np.ndarray, edges: np.ndarray, q: float
) -> np.ndarray:
    """:func:`histogram_quantile` vectorised over a ``[C, B]`` stack of
    histograms (same per-row arithmetic, so results match the scalar form
    exactly) — ``build_trace`` uses it for the per-chunk P99 series, which
    a Python loop made the dominant host-side cost of a large fused run."""
    hists = np.asarray(hists, dtype=np.float64)
    total = hists.sum(axis=1)
    safe_total = np.maximum(total, 1e-300)
    target = q * safe_total
    cum = np.cumsum(hists, axis=1)
    b = np.minimum(
        (cum < target[:, None]).sum(axis=1), hists.shape[1] - 1
    )
    rows = np.arange(hists.shape[0])
    prev = np.where(b > 0, cum[rows, np.maximum(b - 1, 0)], 0.0)
    frac = np.clip(
        (target - prev) / np.maximum(hists[rows, b], 1e-12), 0.0, 1.0
    )
    lo_e = edges[b]
    hi_e = edges[b + 1]
    overflow = ~np.isfinite(hi_e)
    hi_safe = np.where(overflow, 1.0, hi_e)  # masked out below
    lo_safe = np.maximum(lo_e, 1e-300)
    interior = np.where(
        lo_e <= 0.0,
        hi_safe * frac,  # degenerate [0, lo) bucket: linear
        lo_e * (hi_safe / lo_safe) ** frac,
    )
    out = np.where(
        b == 0,
        edges[1],  # underflow bucket: clamp to lo
        np.where(overflow, lo_e, interior),  # overflow bucket: clamp to hi
    )
    return np.where(total > 0, out, np.nan)


def quantile_summary(hist: np.ndarray, edges: np.ndarray) -> dict:
    """The canonical P50/P90/P95/P99/P99.9 block (BENCH json ``quantiles``)."""
    return {
        label: histogram_quantile(hist, edges, q)
        for label, q in QUANTILE_LABELS.items()
    }


def leaves_quantile(
    leaves: TelemetryLeaves, cfg: TelemetryConfig, q: float
) -> float:
    """Global quantile straight from raw leaves (no SimTrace built) — the
    per-seed samples ``run_experiment`` feeds into the p99 CI bands."""
    hist = np.asarray(leaves.hist, dtype=np.float64)  # [C, 2N, B]
    return histogram_quantile(hist.sum(axis=(0, 1)), cfg.edges(), q)


# ---------------------------------------------------------------------------
# SimTrace: the user-facing view.
# ---------------------------------------------------------------------------


class SimTrace(NamedTuple):
    """Telemetry for one run (or a seed-merged aggregate): the grouped
    latency histogram plus per-chunk convergence/oscillation time series.

    ``hist_group`` rows follow ``g = node * 2 + is_read``: even rows are
    writes, odd rows reads; the ``hist`` / ``hist_read`` / ``hist_write`` /
    ``hist_node`` views are row-sums. ``raw_latency_ms`` is populated only
    by the reference engine (the oracle the quantile tests compare
    against); the fused engine never materialises per-request latencies.
    """

    edges: np.ndarray  # [B+1] bin edges (ms): [0, lo, ..., hi, inf]
    hist_group: np.ndarray  # [2N, B] whole-run grouped histogram
    chunk_hist: np.ndarray  # [C, B] global histogram per chunk
    hit_rate: np.ndarray  # [C] per-chunk read hit rate
    mean_latency_ms: np.ndarray  # [C]
    p99_latency_ms: np.ndarray  # [C] interpolated per-chunk P99
    moves: np.ndarray  # [C] replicas created per chunk
    drops: np.ndarray  # [C] replicas dropped per chunk
    evictions: np.ndarray  # [C] expiry evictions per chunk
    capacity_evictions: np.ndarray  # [C]
    occupancy_bytes: np.ndarray  # [C, N] frozen-map replica bytes
    requests: np.ndarray  # [C] valid requests per chunk
    raw_latency_ms: np.ndarray | None = None  # reference engine only
    # [C, N] per-chunk serving-node load factor rho (all zeros when the
    # cluster has no enabled ServiceConfig — contention off).
    load_factor: np.ndarray | None = None
    # Routing/directory-tier per-chunk series (all zeros when the cluster
    # has no enabled RoutingConfig): consults, misses that paid a home-node
    # fetch, stale-entry consults, staleness-detoured consults, and the
    # [C, STALE_AGE_BINS] version-gap age histogram of stale consults.
    router_consults: np.ndarray | None = None  # [C]
    directory_fetches: np.ndarray | None = None  # [C]
    mis_routes: np.ndarray | None = None  # [C]
    stale_consults: np.ndarray | None = None  # [C]
    stale_age_hist: np.ndarray | None = None  # [C, STALE_AGE_BINS]

    # -- histogram views (all simple row-sums of hist_group) ---------------

    @property
    def num_nodes(self) -> int:
        return self.hist_group.shape[0] // 2

    @property
    def hist(self) -> np.ndarray:
        """Global ``[B]`` latency histogram."""
        return self.hist_group.sum(axis=0)

    @property
    def hist_read(self) -> np.ndarray:
        return self.hist_group[1::2].sum(axis=0)

    @property
    def hist_write(self) -> np.ndarray:
        return self.hist_group[0::2].sum(axis=0)

    @property
    def hist_node(self) -> np.ndarray:
        """``[N, B]`` per-requesting-node histogram (reads + writes)."""
        b = self.hist_group.shape[1]
        return self.hist_group.reshape(self.num_nodes, 2, b).sum(axis=1)

    @property
    def relative_bin_width(self) -> float:
        """One interior bin's relative width — the quantile error bound."""
        return float(self.edges[2] / self.edges[1]) - 1.0

    # -- quantiles ----------------------------------------------------------

    def _select(self, split) -> np.ndarray:
        if isinstance(split, (int, np.integer)):
            return self.hist_node[int(split)]
        return {"all": self.hist, "read": self.hist_read,
                "write": self.hist_write}[split]

    def quantile(self, q: float, split="all") -> float:
        """Interpolated latency quantile; ``split`` is ``"all"`` / ``"read"``
        / ``"write"`` or a node index."""
        return histogram_quantile(self._select(split), self.edges, q)

    def quantiles(self, qs, split="all") -> list[float]:
        hist = self._select(split)
        return [histogram_quantile(hist, self.edges, q) for q in qs]

    def tail_summary(self, split="all") -> dict:
        """P50/P90/P95/P99/P99.9 as a dict (the BENCH ``quantiles`` block)."""
        return quantile_summary(self._select(split), self.edges)

    # -- routing-tier diagnostics -------------------------------------------

    @property
    def mis_route_rate(self) -> np.ndarray:
        """``[C]`` fraction of each chunk's directory consults that were
        detoured by a stale ownership view (0 where nothing consulted)."""
        return self.mis_routes / np.maximum(self.router_consults, 1.0)

    # -- convergence / oscillation diagnostics ------------------------------

    def convergence_chunk(self, eps: float = 0.01) -> int:
        """First chunk whose hit rate is within ``eps`` of the terminal
        (final-chunk) hit rate — the convergence-time definition in
        EXPERIMENTS.md §Telemetry. The final chunk trivially qualifies."""
        terminal = self.hit_rate[-1]
        within = np.abs(self.hit_rate - terminal) <= eps
        return int(np.argmax(within))

    def post_convergence_moves(self, eps: float = 0.01) -> float:
        """Replica moves committed *after* convergence — an oscillation
        index: a stable policy goes quiet once placement has converged, an
        oscillating one keeps churning replicas. On a seed-merged trace the
        move counters are summed across seeds; divide by the seed count for
        an iteration-invariant per-run figure (benchmarks do)."""
        return float(self.moves[self.convergence_chunk(eps):].sum())


def build_trace(
    leaves: TelemetryLeaves,
    cfg: TelemetryConfig,
    raw_latency_ms: np.ndarray | None = None,
) -> SimTrace:
    """Materialise a :class:`SimTrace` from raw (chunk-leading) leaves —
    either one run's, or a seed-merged aggregate from :func:`merge_leaves`."""
    edges = cfg.edges()
    hist_c = np.asarray(leaves.hist, dtype=np.float64)  # [C, 2N, B]
    chunk_hist = hist_c.sum(axis=1)  # [C, B]
    reads = np.asarray(leaves.reads, dtype=np.float64)
    count = np.asarray(leaves.count, dtype=np.float64)
    return SimTrace(
        edges=edges,
        hist_group=hist_c.sum(axis=0),
        chunk_hist=chunk_hist,
        hit_rate=np.asarray(leaves.hits, np.float64) / np.maximum(reads, 1.0),
        mean_latency_ms=(
            np.asarray(leaves.lat_sum, np.float64) / np.maximum(count, 1.0)
        ),
        p99_latency_ms=histogram_quantile_rows(chunk_hist, edges, 0.99),
        moves=np.asarray(leaves.adds, np.float64),
        drops=np.asarray(leaves.drops, np.float64),
        evictions=np.asarray(leaves.expiry_evictions, np.float64),
        capacity_evictions=np.asarray(leaves.capacity_evictions, np.float64),
        occupancy_bytes=np.asarray(leaves.occupancy, np.float64),
        requests=count,
        raw_latency_ms=raw_latency_ms,
        load_factor=np.asarray(leaves.load_factor, np.float64),
        router_consults=np.asarray(leaves.router_consults, np.float64),
        directory_fetches=np.asarray(leaves.directory_fetches, np.float64),
        mis_routes=np.asarray(leaves.mis_routes, np.float64),
        stale_consults=np.asarray(leaves.stale_consults, np.float64),
        stale_age_hist=np.asarray(leaves.stale_age_hist, np.float64),
    )
