"""In-scan telemetry: fused latency histograms + per-tick convergence traces.

The paper's stated objective is protecting *end-user response latency*, yet
a whole simulated run used to collapse into one ``mean_latency_ms`` — and
means hide exactly the tail behaviour geo-distributed round-trips inflate
(Didona & Zwaenepoel, 1802.00696, argue P95/P99 are the metric that matters
for in-memory KV stores; TurboKV, 2010.14931, evaluates repartitioning by
latency *distribution*). This module is the observability layer both
simulation engines share:

  * **Latency histograms**, accumulated *inside* the fused ``lax.scan``
    (no trace re-walk, no host round-trips): per chunk the engine folds the
    request latencies into a ``[2N, B]`` grouped histogram whose group id
    encodes ``(node, read/write)`` — global, per-node, and read/write-split
    views are all row-sums of that one array, so histograms merge across
    chunks, seeds, and vmapped policy rows by plain summation. The hot path
    is the ``kernels/latency_histogram`` trio (bucketize + grouped
    scatter-add fused into one pass, MXU-friendly one-hot matmul on TPU);
    ``TelemetryConfig.backend`` selects the pure-JAX reference or the
    Pallas kernel, parity-pinned by tests.

  * **Per-chunk time series** (hit rate, mean/p99 latency, moves applied,
    occupancy, evictions — and, with an enabled ``ServiceConfig``, the
    per-node serving load factor), emitted as the scan's ``ys`` — the
    convergence / oscillation diagnostics a repartitioning policy is
    judged by.

Both surface as a :class:`SimTrace` returned alongside ``SimResult``.
Telemetry is **off by default** and the disabled path is structurally
identical to the pre-telemetry engine (no extra carry entries, no ys), so
results stay bit-exact — pinned by tests/test_telemetry.py.

Quantiles are interpolated from the log-spaced histogram: bins have
constant *relative* width ``rho = (hi/lo)**(1/(B-2))``, so any interpolated
quantile is within one bin width (a factor of ``rho``) of the exact
order-statistic — at the default 128 bins over [1 ms, 10 s] that is ~7.6%
relative error, and the acceptance tests verify P99 against
``np.percentile`` of the reference engine's raw latencies.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.kernels.chunk_replay.ref import COMPONENTS, NUM_COMPONENTS
from repro.kernels.latency_histogram.ref import (
    bin_edges,
    bin_index,
    latency_histogram_ref,
)

__all__ = [
    "AttributionConfig",
    "FlightRecorderConfig",
    "TelemetryConfig",
    "TelemetryLeaves",
    "LEAF_KINDS",
    "SimTrace",
    "chunk_histogram",
    "trace_histogram",
    "attribution_chunk_hist",
    "attribution_trace_hist",
    "merge_leaves",
    "psum_leaves",
    "build_trace",
    "leaves_quantile",
    "histogram_quantile",
    "histogram_quantile_rows",
    "quantile_summary",
    "normalize_telemetry",
    "QUANTILE_LABELS",
    "COMPONENTS",
    "NUM_COMPONENTS",
    "FLIGHT_META_FIELDS",
]

TELEMETRY_BACKENDS = ("jax", "pallas")
FLIGHT_SAMPLING_MODES = ("stride", "reservoir")

# Column order of the flight recorder's integer record plane (see
# :class:`FlightRecorderConfig`): ``flags`` packs bit 0 = is_read,
# bit 1 = valid (a cleared valid bit marks an unsampled / padded slot).
FLIGHT_META_FIELDS = ("pos", "key", "node", "router", "flags")

# The canonical report quantiles: label -> q.
QUANTILE_LABELS = {"p50": 0.5, "p90": 0.9, "p95": 0.95, "p99": 0.99, "p999": 0.999}


class AttributionConfig(NamedTuple):
    """Latency-provenance knobs (hashable — nests inside the
    :class:`TelemetryConfig` jit static).

    When enabled the engines decompose every request's latency along the
    canonical :data:`~repro.kernels.chunk_replay.ref.COMPONENTS` taxonomy
    (priced in ``kernels/chunk_replay/ref.py``) and fold per-component
    grouped ``[2N, num_bins]`` histograms plus per-chunk component sums
    through the scan. Components get their own bin range: the default
    ``lo_ms=0.01`` floor sits two decades below the total-latency floor
    because individual legs (base service cost, short detours) are often
    sub-millisecond and would otherwise all collapse into the underflow
    bucket. Per-component histograms weight by ``component > 0`` — a row
    counts only the requests that actually paid that component.
    Off (``None`` on the telemetry config) by default: the compiled
    program is structurally identical and results stay bit-exact.
    """

    enabled: bool = True
    num_bins: int = 64
    lo_ms: float = 0.01
    hi_ms: float = 10_000.0

    def validate(self) -> None:
        if self.num_bins < 4:
            raise ValueError(
                f"attribution num_bins must be >= 4, got {self.num_bins}"
            )
        if not (0.0 < self.lo_ms < self.hi_ms):
            raise ValueError(
                f"attribution needs 0 < lo_ms < hi_ms, got lo_ms="
                f"{self.lo_ms} hi_ms={self.hi_ms}"
            )

    def edges(self) -> np.ndarray:
        """Host-side ``[num_bins + 1]`` bin edges: ``[0, lo, ..., hi, inf]``."""
        return bin_edges(self.lo_ms, self.hi_ms, self.num_bins)


class FlightRecorderConfig(NamedTuple):
    """Sampled per-request structured records (hashable — nests inside the
    :class:`TelemetryConfig` jit static).

    Each chunk contributes ``samples_per_chunk`` records captured as scan
    ``ys``: an integer plane (:data:`FLIGHT_META_FIELDS` — global request
    position, key, requesting node, router or -1, is_read/valid flags) and
    a float plane ``[1 + NUM_COMPONENTS]`` (total latency followed by the
    component vector, so every record satisfies the reconstruction
    invariant by construction). ``mode="stride"`` samples fixed equally
    spaced in-chunk offsets (deterministic, identical across engines,
    backends, and shardings); ``"reservoir"`` draws uniform in-chunk
    offsets from a counter-based fold of the chunk index (still
    deterministic per chunk, but unbiased across in-chunk position for
    periodic workloads). Export via ``repro.kvsim.tracing`` (JSON-lines or
    Chrome trace-event format).
    """

    enabled: bool = True
    samples_per_chunk: int = 8
    mode: str = "stride"

    def validate(self) -> None:
        if self.samples_per_chunk < 1:
            raise ValueError(
                f"flight samples_per_chunk must be >= 1, got "
                f"{self.samples_per_chunk}"
            )
        if self.mode not in FLIGHT_SAMPLING_MODES:
            raise ValueError(
                f"unknown flight sampling mode {self.mode!r}; expected one "
                f"of {FLIGHT_SAMPLING_MODES}"
            )


class TelemetryConfig(NamedTuple):
    """Histogram/trace collection knobs (hashable — a valid jit static).

    Telemetry is off by default at the engine level (``telemetry=None``);
    constructing a config turns it on unless ``enabled=False`` (useful for
    threading one kwarg through sweep drivers). ``num_bins`` includes the
    underflow (< ``lo_ms``) and overflow (>= ``hi_ms``) buckets; the
    ``num_bins - 2`` interior bins are log-spaced, so the quantile
    interpolation error is one *relative* bin width
    ``(hi_ms/lo_ms)**(1/(num_bins-2))``. ``backend`` routes the per-chunk
    bucketize+scatter-add through the pure-JAX reference or the Pallas
    ``latency_histogram`` kernel (interpret auto-selected off-TPU).
    """

    enabled: bool = True
    num_bins: int = 128
    lo_ms: float = 1.0
    hi_ms: float = 10_000.0
    backend: str = "jax"
    # Latency-provenance sub-layers, both off by default (None — the
    # structurally-identical bit-exact program). normalize_telemetry
    # collapses a disabled sub-config to None so both spellings hit the
    # same jit cache entry.
    attribution: AttributionConfig | None = None
    flight: FlightRecorderConfig | None = None

    def validate(self) -> None:
        if self.num_bins < 4:
            raise ValueError(
                f"num_bins must be >= 4 (2 interior + under/overflow), "
                f"got {self.num_bins}"
            )
        if not (0.0 < self.lo_ms < self.hi_ms):
            raise ValueError(
                f"need 0 < lo_ms < hi_ms, got lo_ms={self.lo_ms} "
                f"hi_ms={self.hi_ms}"
            )
        if self.backend not in TELEMETRY_BACKENDS:
            raise ValueError(
                f"unknown telemetry backend {self.backend!r}; expected one "
                f"of {TELEMETRY_BACKENDS}"
            )

    def edges(self) -> np.ndarray:
        """Host-side ``[num_bins + 1]`` bin edges: ``[0, lo, ..., hi, inf]``."""
        return bin_edges(self.lo_ms, self.hi_ms, self.num_bins)


def normalize_telemetry(telemetry) -> TelemetryConfig | None:
    """``None``-or-disabled collapses to ``None`` so the jit static cache
    (and the structural no-op guarantee) treats both spellings identically.
    The nested attribution/flight sub-configs get the same treatment:
    disabled collapses to ``None`` (their bit-exact off state)."""
    if telemetry is None or not telemetry.enabled:
        return None
    telemetry.validate()
    attribution = telemetry.attribution
    if attribution is not None and not attribution.enabled:
        attribution = None
    if attribution is not None:
        attribution.validate()
    flight = telemetry.flight
    if flight is not None and not flight.enabled:
        flight = None
    if flight is not None:
        flight.validate()
    return telemetry._replace(attribution=attribution, flight=flight)


class TelemetryLeaves(NamedTuple):
    """Raw per-chunk accumulators, the scan's ``ys`` (leading axis = chunk;
    batched engines add seed / policy axes in front). Every field is a sum
    over requests — except ``occupancy``, a point sample of the chunk's
    frozen map — so merging across seeds or policy rows sums the counters
    and averages the occupancy (:func:`merge_leaves`); associativity of
    the merge is pinned by tests."""

    hist: Array  # [C, 2N, B] grouped latency histogram per chunk
    hits: Array  # [C] read hits
    reads: Array  # [C] valid reads
    lat_sum: Array  # [C] summed latency (ms)
    count: Array  # [C] valid requests
    adds: Array  # [C] replicas created by the policy sweep
    drops: Array  # [C] replicas dropped (all causes)
    expiry_evictions: Array  # [C] drops caused by key expiry
    capacity_evictions: Array  # [C] held replicas evicted by the budget
    occupancy: Array  # [C, N] replica bytes on the chunk's frozen map
    # [C, N] per-chunk serving-node load factor rho (ServiceConfig); all
    # zeros when contention is off. A point sample like occupancy: merges
    # by averaging, not summing.
    load_factor: Array | float = 0.0
    # Routing/directory-tier counters (RoutingConfig — repro.kvsim.routing);
    # all zeros when the tier is off. Plain additive counters: they merge
    # and psum like hits/reads.
    router_consults: Array | float = 0.0  # [C] directory consults
    directory_fetches: Array | float = 0.0  # [C] cache misses (home fetches)
    mis_routes: Array | float = 0.0  # [C] consults detoured by staleness
    stale_consults: Array | float = 0.0  # [C] consults on stale entries
    stale_age_hist: Array | float = 0.0  # [C, STALE_AGE_BINS] version-gap ages
    # Failure-injection counters/series (FaultConfig — repro.kvsim.faults);
    # all zeros when faults are off. The first four are plain additive
    # counters; the two fractions are *global* point samples (the sharded
    # engine psums their key counts at the sample point and divides by the
    # global keyspace before emitting), so they merge by averaging.
    unavailable_reads: Array | float = 0.0  # [C] reads denied service
    unavailable_writes: Array | float = 0.0  # [C] writes denied service
    failovers: Array | float = 0.0  # [C] writes relayed via a failover master
    repair_moves: Array | float = 0.0  # [C] replicas re-seeded after loss
    unreachable_frac: Array | float = 0.0  # [C] frac keys w/ no live replica
    wiped_frac: Array | float = 0.0  # [C] frac keys w/ no replica anywhere
    # Latency-provenance leaves (AttributionConfig / FlightRecorderConfig —
    # None when the sub-layer is off: a None field is an EMPTY pytree node,
    # so the disabled scan emits no extra ys and the compiled program stays
    # structurally identical to the pre-attribution engine).
    attr_hist: Array | None = None  # [C, NUM_COMPONENTS, 2N, Ba] counts
    attr_sum: Array | None = None  # [C, NUM_COMPONENTS] summed ms
    flight_meta: Array | None = None  # [C, S, 5] i32 (FLIGHT_META_FIELDS)
    flight_vals: Array | None = None  # [C, S, 1 + NUM_COMPONENTS] f32


# The single merge contract every leaf declares itself under (the
# exhaustive taxonomy test pins LEAF_KINDS == TelemetryLeaves._fields, so a
# new leaf CANNOT silently skip the shard fold or the batch merge):
#
#   "sum"     additive counter/histogram. Shard fold: ``psum`` (integer-
#             valued f32 counts sum exactly, so sharded histograms stay
#             bit-identical). Batch merge (seeds / policy rows): sum.
#   "mean"    point sample of global state (occupancy, load factor) —
#             already psum-assembled at the sample point inside the scan
#             body, so the shard fold passes it through untouched; the
#             batch merge averages (summing would inflate by batch size).
#   "records" structured samples (flight recorder). Shard fold: ``psum``
#             IS the assembly — every sampled slot is contributed by at
#             most the one shard owning its request (others send zeros),
#             so the sum reconstructs the record exactly. Batch merge:
#             keep row 0's records (summing across seeds would corrupt
#             them; a merged trace documents seed/policy-row 0's flight).
LEAF_KINDS = {
    "hist": "sum",
    "hits": "sum",
    "reads": "sum",
    "lat_sum": "sum",
    "count": "sum",
    "adds": "sum",
    "drops": "sum",
    "expiry_evictions": "sum",
    "capacity_evictions": "sum",
    "occupancy": "mean",
    "load_factor": "mean",
    "router_consults": "sum",
    "directory_fetches": "sum",
    "mis_routes": "sum",
    "stale_consults": "sum",
    "stale_age_hist": "sum",
    "unavailable_reads": "sum",
    "unavailable_writes": "sum",
    "failovers": "sum",
    "repair_moves": "sum",
    "unreachable_frac": "mean",
    "wiped_frac": "mean",
    "attr_hist": "sum",
    "attr_sum": "sum",
    "flight_meta": "records",
    "flight_vals": "records",
}


def chunk_histogram(
    lat: Array,  # [R] per-request latency (ms)
    group: Array,  # [R] i32 group id = node * 2 + is_read
    weight: Array,  # [R] f32, 0 masks padded rows
    cfg: TelemetryConfig,
    num_nodes: int,
) -> Array:
    """One chunk's ``[2N, B]`` grouped histogram via the configured backend."""
    kwargs = dict(
        num_groups=2 * num_nodes,
        num_bins=cfg.num_bins,
        lo=jnp.float32(cfg.lo_ms),
        hi=jnp.float32(cfg.hi_ms),
    )
    if cfg.backend == "pallas":
        from repro.kernels.latency_histogram.ops import latency_histogram

        return latency_histogram(lat, group, weight, **kwargs)
    return latency_histogram_ref(lat, group, weight, **kwargs)


def trace_histogram(
    lat: Array,  # [C * B] whole-trace latencies (chunk-major)
    group: Array,  # [C * B] i32 group id = node * 2 + is_read
    weight: Array,  # [C * B] f32, 0 masks padded rows
    cfg: TelemetryConfig,
    num_nodes: int,
    num_chunks: int,
    bin_idx: Array | None = None,
) -> Array:
    """The whole trace's ``[C, 2N, B]`` per-chunk grouped histograms in ONE
    pass — the static-fast-path companion of :func:`chunk_histogram`.

    With a frozen replica map the engine replays the entire trace outside
    the scan, so the per-chunk histograms become one flat ``bincount`` over
    the combined ``(chunk, group, bin)`` index (an order of magnitude
    faster on CPU than a per-chunk scatter loop; counts are integers, so
    the result is bit-identical to C separate :func:`chunk_histogram`
    calls — pinned by tests). The ``backend="pallas"`` config instead
    vmaps the fused histogram kernel over the chunk axis (the TPU path).
    ``bin_idx`` lets the caller supply precomputed bucket indices (the
    static path gathers them from its (key, node, is_read) grid).
    """
    g = 2 * num_nodes
    b = lat.shape[0] // num_chunks
    if cfg.backend == "pallas":
        resh = lambda x: x.reshape(num_chunks, b)
        return jax.vmap(
            lambda l, gr, w: chunk_histogram(l, gr, w, cfg, num_nodes)
        )(resh(lat), resh(group), resh(weight))
    idx = bin_idx if bin_idx is not None else bin_index(
        lat.astype(jnp.float32), cfg.lo_ms, cfg.hi_ms, cfg.num_bins
    )
    chunk = jnp.arange(lat.shape[0], dtype=jnp.int32) // b
    flat = (chunk * g + group) * cfg.num_bins + idx
    hist = jnp.bincount(
        flat, weights=weight.astype(jnp.float32),
        length=num_chunks * g * cfg.num_bins,
    )
    return hist.reshape(num_chunks, g, cfg.num_bins).astype(jnp.float32)


def attribution_chunk_hist(
    comps: Array,  # [NUM_COMPONENTS, B] per-request component ms (masked)
    group: Array,  # [B] i32 group id = node * 2 + is_read
    weight: Array,  # [B] f32, 0 masks padded/foreign rows
    acfg: AttributionConfig,
    num_nodes: int,
) -> Array:
    """One chunk's ``[NUM_COMPONENTS, 2N, Ba]`` per-component grouped
    histograms. Always the pure-jnp scatter-add, whatever the replay
    backend: a component count is an integer fold, so one shared
    implementation is what makes attribution histograms bit-identical
    across the jax/pallas backends (and across shardings, via psum). Each
    component row weights by ``component > 0`` — only requests that
    actually paid the component are counted in its distribution."""

    def one(comp: Array) -> Array:
        w = weight * (comp > 0).astype(jnp.float32)
        return latency_histogram_ref(
            comp, group, w,
            num_groups=2 * num_nodes, num_bins=acfg.num_bins,
            lo=jnp.float32(acfg.lo_ms), hi=jnp.float32(acfg.hi_ms),
        )

    return jax.vmap(one)(comps)


def attribution_trace_hist(
    comps: Array,  # [NUM_COMPONENTS, C * B] whole-trace components (masked)
    group: Array,  # [C * B] i32 group id = node * 2 + is_read
    weight: Array,  # [C * B] f32, 0 masks padded rows
    acfg: AttributionConfig,
    num_nodes: int,
    num_chunks: int,
) -> Array:
    """The whole trace's ``[C, NUM_COMPONENTS, 2N, Ba]`` per-chunk
    attribution histograms in ONE flat bincount — the static-fast-path
    companion of :func:`attribution_chunk_hist` (counts are integers, so
    the result is bit-identical to C per-chunk scatter-adds)."""
    g = 2 * num_nodes
    ncomp, rp = comps.shape
    b = rp // num_chunks
    chunk = jnp.arange(rp, dtype=jnp.int32) // b
    idx = bin_index(comps, acfg.lo_ms, acfg.hi_ms, acfg.num_bins)
    w = weight[None, :] * (comps > 0).astype(jnp.float32)
    comp_ids = jnp.arange(ncomp, dtype=jnp.int32)[:, None]
    flat = (
        (chunk[None, :] * ncomp + comp_ids) * g + group[None, :]
    ) * acfg.num_bins + idx
    hist = jnp.bincount(
        flat.reshape(-1), weights=w.reshape(-1),
        length=num_chunks * ncomp * g * acfg.num_bins,
    )
    return hist.reshape(
        num_chunks, ncomp, g, acfg.num_bins
    ).astype(jnp.float32)


def merge_leaves(leaves: TelemetryLeaves, axis: int = 0) -> TelemetryLeaves:
    """Merge a batch axis away (seeds, policy rows), leaf-by-leaf per the
    :data:`LEAF_KINDS` contract: ``"sum"`` leaves sum (the derived
    rates/quantiles are recomputed from the merged sums by
    :func:`build_trace`), ``"mean"`` point samples average (summing would
    inflate them by the batch size), ``"records"`` keep batch row 0's
    samples. ``None`` leaves (disabled sub-layers) pass through."""
    n = np.asarray(leaves.occupancy).shape[axis]
    merged = {}
    for name, kind in LEAF_KINDS.items():
        leaf = getattr(leaves, name)
        if leaf is None:
            merged[name] = None
            continue
        a = np.asarray(leaf, dtype=np.float64)
        if a.ndim == 0:
            merged[name] = a  # disabled scalar leaf: nothing to merge
        elif kind == "sum":
            merged[name] = a.sum(axis=axis)
        elif kind == "mean":
            merged[name] = a.sum(axis=axis) / n
        else:  # records
            merged[name] = np.take(a, 0, axis=axis)
    return TelemetryLeaves(**merged)


def psum_leaves(leaves: TelemetryLeaves, axis_name: str) -> TelemetryLeaves:
    """Merge per-shard telemetry into global telemetry inside a key-sharded
    ``shard_map`` program — the collective twin of :func:`merge_leaves`,
    driven by the same :data:`LEAF_KINDS` contract so a new leaf cannot
    skip the shard fold by omission (the taxonomy test fails instead).

    ``"sum"`` leaves (histograms, hit/read/latency/request counters, daemon
    move counters, attribution counters) psum across the shard axis;
    counts are integer-valued f32 sums, so the psum is *exact* and sharded
    histograms stay bit-identical to single-device ones (the merge is
    sum-associative — the same property the seed-merge tests pin).
    ``"records"`` leaves also psum: the engine masks each flight slot to
    the single shard owning its request (all others contribute zeros), so
    the collective sum IS the record assembly, exactly. ``"mean"`` leaves
    (occupancy, load factor) pass through untouched: the engine already
    assembles those as global values inside the scan body (occupancy is
    psum'd at the sample point so the running *peak* is taken over the
    global vector; the load factor's demand fold psums inside the
    contention pre-pass)."""
    folded = {
        name: jax.lax.psum(getattr(leaves, name), axis_name)
        for name, kind in LEAF_KINDS.items()
        if kind in ("sum", "records") and getattr(leaves, name) is not None
    }
    return leaves._replace(**folded)


# ---------------------------------------------------------------------------
# Quantile interpolation on log-spaced histograms.
# ---------------------------------------------------------------------------


def histogram_quantile(hist: np.ndarray, edges: np.ndarray, q: float) -> float:
    """Interpolated quantile from binned counts.

    Within the target bucket the mass is spread geometrically (uniform in
    log-latency — the natural prior for log-spaced bins), so the result is
    within one bin width of the exact order statistic. The unbounded
    under/overflow buckets clamp to their finite edge. Delegates to the
    row-vectorised form so the two can never drift.
    """
    hist = np.asarray(hist, dtype=np.float64)
    return float(histogram_quantile_rows(hist[None, :], edges, q)[0])


def histogram_quantile_rows(
    hists: np.ndarray, edges: np.ndarray, q: float
) -> np.ndarray:
    """:func:`histogram_quantile` vectorised over a ``[C, B]`` stack of
    histograms (same per-row arithmetic, so results match the scalar form
    exactly) — ``build_trace`` uses it for the per-chunk P99 series, which
    a Python loop made the dominant host-side cost of a large fused run."""
    hists = np.asarray(hists, dtype=np.float64)
    total = hists.sum(axis=1)
    safe_total = np.maximum(total, 1e-300)
    target = q * safe_total
    cum = np.cumsum(hists, axis=1)
    b = np.minimum(
        (cum < target[:, None]).sum(axis=1), hists.shape[1] - 1
    )
    rows = np.arange(hists.shape[0])
    prev = np.where(b > 0, cum[rows, np.maximum(b - 1, 0)], 0.0)
    frac = np.clip(
        (target - prev) / np.maximum(hists[rows, b], 1e-12), 0.0, 1.0
    )
    lo_e = edges[b]
    hi_e = edges[b + 1]
    overflow = ~np.isfinite(hi_e)
    hi_safe = np.where(overflow, 1.0, hi_e)  # masked out below
    lo_safe = np.maximum(lo_e, 1e-300)
    interior = np.where(
        lo_e <= 0.0,
        hi_safe * frac,  # degenerate [0, lo) bucket: linear
        lo_e * (hi_safe / lo_safe) ** frac,
    )
    out = np.where(
        b == 0,
        edges[1],  # underflow bucket: clamp to lo
        np.where(overflow, lo_e, interior),  # overflow bucket: clamp to hi
    )
    return np.where(total > 0, out, np.nan)


def quantile_summary(hist: np.ndarray, edges: np.ndarray) -> dict:
    """The canonical P50/P90/P95/P99/P99.9 block (BENCH json ``quantiles``)."""
    return {
        label: histogram_quantile(hist, edges, q)
        for label, q in QUANTILE_LABELS.items()
    }


def leaves_quantile(
    leaves: TelemetryLeaves, cfg: TelemetryConfig, q: float
) -> float:
    """Global quantile straight from raw leaves (no SimTrace built) — the
    per-seed samples ``run_experiment`` feeds into the p99 CI bands."""
    hist = np.asarray(leaves.hist, dtype=np.float64)  # [C, 2N, B]
    return histogram_quantile(hist.sum(axis=(0, 1)), cfg.edges(), q)


# ---------------------------------------------------------------------------
# SimTrace: the user-facing view.
# ---------------------------------------------------------------------------


class SimTrace(NamedTuple):
    """Telemetry for one run (or a seed-merged aggregate): the grouped
    latency histogram plus per-chunk convergence/oscillation time series.

    ``hist_group`` rows follow ``g = node * 2 + is_read``: even rows are
    writes, odd rows reads; the ``hist`` / ``hist_read`` / ``hist_write`` /
    ``hist_node`` views are row-sums. ``raw_latency_ms`` is populated only
    by the reference engine (the oracle the quantile tests compare
    against); the fused engine never materialises per-request latencies.
    """

    edges: np.ndarray  # [B+1] bin edges (ms): [0, lo, ..., hi, inf]
    hist_group: np.ndarray  # [2N, B] whole-run grouped histogram
    chunk_hist: np.ndarray  # [C, B] global histogram per chunk
    hit_rate: np.ndarray  # [C] per-chunk read hit rate
    mean_latency_ms: np.ndarray  # [C]
    p99_latency_ms: np.ndarray  # [C] interpolated per-chunk P99
    moves: np.ndarray  # [C] replicas created per chunk
    drops: np.ndarray  # [C] replicas dropped per chunk
    evictions: np.ndarray  # [C] expiry evictions per chunk
    capacity_evictions: np.ndarray  # [C]
    occupancy_bytes: np.ndarray  # [C, N] frozen-map replica bytes
    requests: np.ndarray  # [C] valid requests per chunk
    raw_latency_ms: np.ndarray | None = None  # reference engine only
    # [C, N] per-chunk serving-node load factor rho (all zeros when the
    # cluster has no enabled ServiceConfig — contention off).
    load_factor: np.ndarray | None = None
    # Routing/directory-tier per-chunk series (all zeros when the cluster
    # has no enabled RoutingConfig): consults, misses that paid a home-node
    # fetch, stale-entry consults, staleness-detoured consults, and the
    # [C, STALE_AGE_BINS] version-gap age histogram of stale consults.
    router_consults: np.ndarray | None = None  # [C]
    directory_fetches: np.ndarray | None = None  # [C]
    mis_routes: np.ndarray | None = None  # [C]
    stale_consults: np.ndarray | None = None  # [C]
    stale_age_hist: np.ndarray | None = None  # [C, STALE_AGE_BINS]
    # Failure-injection series (all zeros when the cluster has no enabled
    # FaultConfig): denied reads/writes, failover-mastered writes, replicas
    # re-seeded after loss, the fraction of keys with no *live* replica,
    # the fraction with no surviving replica at all, and the hit rate with
    # unavailable reads counted as misses (== hit_rate when faults are off).
    unavailable_reads: np.ndarray | None = None  # [C]
    unavailable_writes: np.ndarray | None = None  # [C]
    failovers: np.ndarray | None = None  # [C]
    repair_moves: np.ndarray | None = None  # [C]
    unreachable_frac: np.ndarray | None = None  # [C]
    wiped_frac: np.ndarray | None = None  # [C]
    effective_hit_rate: np.ndarray | None = None  # [C]
    # Latency-provenance views (populated only with an enabled
    # AttributionConfig / FlightRecorderConfig on the telemetry config).
    attr_edges: np.ndarray | None = None  # [Ba+1] component bin edges (ms)
    attr_hist_group: np.ndarray | None = None  # [NUM_COMPONENTS, 2N, Ba]
    attr_chunk_sum_ms: np.ndarray | None = None  # [C, NUM_COMPONENTS]
    attr_chunk_mean_ms: np.ndarray | None = None  # [C, NUM_COMPONENTS] /req
    flight_meta: np.ndarray | None = None  # [C, S, 5] (FLIGHT_META_FIELDS)
    flight_vals: np.ndarray | None = None  # [C, S, 1 + NUM_COMPONENTS]
    # [NUM_COMPONENTS, R] raw per-request components — reference engine
    # only (the oracle the per-component quantile tests compare against).
    raw_components: np.ndarray | None = None

    # -- histogram views (all simple row-sums of hist_group) ---------------

    @property
    def num_nodes(self) -> int:
        return self.hist_group.shape[0] // 2

    @property
    def hist(self) -> np.ndarray:
        """Global ``[B]`` latency histogram."""
        return self.hist_group.sum(axis=0)

    @property
    def hist_read(self) -> np.ndarray:
        return self.hist_group[1::2].sum(axis=0)

    @property
    def hist_write(self) -> np.ndarray:
        return self.hist_group[0::2].sum(axis=0)

    @property
    def hist_node(self) -> np.ndarray:
        """``[N, B]`` per-requesting-node histogram (reads + writes)."""
        b = self.hist_group.shape[1]
        return self.hist_group.reshape(self.num_nodes, 2, b).sum(axis=1)

    @property
    def relative_bin_width(self) -> float:
        """One interior bin's relative width — the quantile error bound."""
        return float(self.edges[2] / self.edges[1]) - 1.0

    # -- quantiles ----------------------------------------------------------

    def _select(self, split) -> np.ndarray:
        if isinstance(split, (int, np.integer)):
            return self.hist_node[int(split)]
        return {"all": self.hist, "read": self.hist_read,
                "write": self.hist_write}[split]

    def quantile(self, q: float, split="all") -> float:
        """Interpolated latency quantile; ``split`` is ``"all"`` / ``"read"``
        / ``"write"`` or a node index."""
        return histogram_quantile(self._select(split), self.edges, q)

    def quantiles(self, qs, split="all") -> list[float]:
        hist = self._select(split)
        return [histogram_quantile(hist, self.edges, q) for q in qs]

    def tail_summary(self, split="all") -> dict:
        """P50/P90/P95/P99/P99.9 as a dict (the BENCH ``quantiles`` block)."""
        return quantile_summary(self._select(split), self.edges)

    # -- latency provenance (cost attribution + flight recorder) ------------

    def _comp_index(self, component) -> int:
        if isinstance(component, (int, np.integer)):
            return int(component)
        return COMPONENTS.index(component)

    def component_hist(self, component, split="all") -> np.ndarray:
        """One component's ``[Ba]`` histogram (by name or index); ``split``
        follows :meth:`quantile` (``"all"``/``"read"``/``"write"``/node)."""
        rows = self.attr_hist_group[self._comp_index(component)]  # [2N, Ba]
        if isinstance(split, (int, np.integer)):
            return rows[int(split) * 2 : int(split) * 2 + 2].sum(axis=0)
        return {
            "all": rows.sum(axis=0),
            "read": rows[1::2].sum(axis=0),
            "write": rows[0::2].sum(axis=0),
        }[split]

    def component_quantile(self, component, q: float, split="all") -> float:
        """Interpolated per-component latency quantile — over the requests
        that actually paid the component (the ``component > 0`` weighting
        the attribution histograms fold)."""
        return histogram_quantile(
            self.component_hist(component, split), self.attr_edges, q
        )

    @property
    def attribution(self) -> dict:
        """The per-component provenance summary: for every
        :data:`COMPONENTS` name a dict with ``count`` (requests that paid
        it), ``mean_ms`` (averaged over ALL valid requests — the additive
        decomposition of the run's mean latency), ``share`` (fraction of
        total latency), and interpolated P50–P99.9 over the paying
        requests. Requires an enabled AttributionConfig."""
        if self.attr_hist_group is None:
            raise ValueError(
                "attribution requires TelemetryConfig(attribution="
                "AttributionConfig())"
            )
        total_requests = float(self.requests.sum())
        comp_sums = self.attr_chunk_sum_ms.sum(axis=0)  # [NUM_COMPONENTS]
        total_ms = float(comp_sums.sum())
        out = {}
        for i, name in enumerate(COMPONENTS):
            hist = self.attr_hist_group[i].sum(axis=0)
            out[name] = {
                "count": float(hist.sum()),
                "mean_ms": float(comp_sums[i]) / max(total_requests, 1.0),
                "share": float(comp_sums[i]) / max(total_ms, 1e-300),
                **{
                    label: histogram_quantile(hist, self.attr_edges, q)
                    for label, q in QUANTILE_LABELS.items()
                },
            }
        return out

    def flight_records(self) -> list[dict]:
        """The flight recorder's sampled requests as structured dicts
        (valid samples only), ordered by global request position. Each
        record carries the :data:`FLIGHT_META_FIELDS` integers (``router``
        is -1 with no routing tier), ``is_read``, ``chunk``, ``total_ms``,
        and the per-component breakdown under ``components``. Requires an
        enabled FlightRecorderConfig."""
        if self.flight_meta is None:
            raise ValueError(
                "flight_records requires TelemetryConfig(flight="
                "FlightRecorderConfig())"
            )
        meta = np.asarray(self.flight_meta, np.int64)  # [C, S, 5]
        vals = np.asarray(self.flight_vals, np.float64)  # [C, S, 1+NCOMP]
        records = []
        for c in range(meta.shape[0]):
            for s in range(meta.shape[1]):
                pos, key, node, router, flags = meta[c, s]
                if not (flags >> 1) & 1:  # valid bit clear: unsampled slot
                    continue
                records.append({
                    "pos": int(pos),
                    "chunk": int(c),
                    "key": int(key),
                    "node": int(node),
                    "router": int(router),
                    "is_read": bool(flags & 1),
                    "total_ms": float(vals[c, s, 0]),
                    "components": {
                        name: float(vals[c, s, 1 + i])
                        for i, name in enumerate(COMPONENTS)
                    },
                })
        records.sort(key=lambda r: r["pos"])
        return records

    # -- routing-tier diagnostics -------------------------------------------

    @property
    def mis_route_rate(self) -> np.ndarray:
        """``[C]`` fraction of each chunk's directory consults that were
        detoured by a stale ownership view (0 where nothing consulted)."""
        return self.mis_routes / np.maximum(self.router_consults, 1.0)

    # -- availability / failure diagnostics ---------------------------------

    @property
    def availability(self) -> np.ndarray:
        """``[C]`` fraction of each chunk's *attempted* requests that were
        served (1.0 where nothing was attempted — and everywhere when
        faults are off, since the unavailable counters are then zero)."""
        unav = np.asarray(self.unavailable_reads, np.float64) + np.asarray(
            self.unavailable_writes, np.float64
        )
        attempted = self.requests + unav
        return np.where(
            attempted > 0, self.requests / np.maximum(attempted, 1.0), 1.0
        )

    def recovery_chunks(
        self, outage_start: int, target_frac: float = 0.95
    ) -> int:
        """Chunks from ``outage_start`` until the *effective* hit rate
        (unavailable reads count as misses) first recovers to
        ``target_frac`` of its pre-outage steady state —
        ``convergence_chunk`` re-aimed at the post-recovery frontier, the
        re-convergence yardstick for membership change. The baseline is
        the MEDIAN over the pre-outage chunks, not the mean: an adaptive
        policy's cold-start chunks (hit rate near zero while it digs out
        of the initial placement) would otherwise drag a mean baseline
        low enough to make recovery trivially instant. Returns -1 if the
        trace ends before recovery."""
        eff = self.effective_hit_rate
        baseline = (
            float(np.median(eff[:outage_start])) if outage_start > 0 else 1.0
        )
        ok = eff[outage_start:] >= target_frac * baseline
        if not ok.any():
            return -1
        return int(np.argmax(ok))

    # -- convergence / oscillation diagnostics ------------------------------

    def convergence_chunk(self, eps: float = 0.01) -> int:
        """First chunk whose hit rate is within ``eps`` of the terminal
        (final-chunk) hit rate — the convergence-time definition in
        EXPERIMENTS.md §Telemetry. The final chunk trivially qualifies."""
        terminal = self.hit_rate[-1]
        within = np.abs(self.hit_rate - terminal) <= eps
        return int(np.argmax(within))

    def post_convergence_moves(self, eps: float = 0.01) -> float:
        """Replica moves committed *after* convergence — an oscillation
        index: a stable policy goes quiet once placement has converged, an
        oscillating one keeps churning replicas. On a seed-merged trace the
        move counters are summed across seeds; divide by the seed count for
        an iteration-invariant per-run figure (benchmarks do)."""
        return float(self.moves[self.convergence_chunk(eps):].sum())


def build_trace(
    leaves: TelemetryLeaves,
    cfg: TelemetryConfig,
    raw_latency_ms: np.ndarray | None = None,
    raw_components: np.ndarray | None = None,
) -> SimTrace:
    """Materialise a :class:`SimTrace` from raw (chunk-leading) leaves —
    either one run's, or a seed-merged aggregate from :func:`merge_leaves`."""
    edges = cfg.edges()
    hist_c = np.asarray(leaves.hist, dtype=np.float64)  # [C, 2N, B]
    chunk_hist = hist_c.sum(axis=1)  # [C, B]
    reads = np.asarray(leaves.reads, dtype=np.float64)
    count = np.asarray(leaves.count, dtype=np.float64)
    attr: dict = {}
    if cfg.attribution is not None and leaves.attr_hist is not None:
        attr_hist = np.asarray(leaves.attr_hist, np.float64)  # [C,NC,2N,Ba]
        attr_sum = np.asarray(leaves.attr_sum, np.float64)  # [C, NC]
        attr = dict(
            attr_edges=cfg.attribution.edges(),
            attr_hist_group=attr_hist.sum(axis=0),
            attr_chunk_sum_ms=attr_sum,
            attr_chunk_mean_ms=attr_sum / np.maximum(count, 1.0)[:, None],
        )
    if cfg.flight is not None and leaves.flight_meta is not None:
        attr.update(
            flight_meta=np.asarray(leaves.flight_meta, np.int64),
            flight_vals=np.asarray(leaves.flight_vals, np.float64),
        )
    return SimTrace(
        **attr,
        raw_components=raw_components,
        edges=edges,
        hist_group=hist_c.sum(axis=0),
        chunk_hist=chunk_hist,
        hit_rate=np.asarray(leaves.hits, np.float64) / np.maximum(reads, 1.0),
        mean_latency_ms=(
            np.asarray(leaves.lat_sum, np.float64) / np.maximum(count, 1.0)
        ),
        p99_latency_ms=histogram_quantile_rows(chunk_hist, edges, 0.99),
        moves=np.asarray(leaves.adds, np.float64),
        drops=np.asarray(leaves.drops, np.float64),
        evictions=np.asarray(leaves.expiry_evictions, np.float64),
        capacity_evictions=np.asarray(leaves.capacity_evictions, np.float64),
        occupancy_bytes=np.asarray(leaves.occupancy, np.float64),
        requests=count,
        raw_latency_ms=raw_latency_ms,
        load_factor=np.asarray(leaves.load_factor, np.float64),
        router_consults=np.asarray(leaves.router_consults, np.float64),
        directory_fetches=np.asarray(leaves.directory_fetches, np.float64),
        mis_routes=np.asarray(leaves.mis_routes, np.float64),
        stale_consults=np.asarray(leaves.stale_consults, np.float64),
        stale_age_hist=np.asarray(leaves.stale_age_hist, np.float64),
        unavailable_reads=np.asarray(leaves.unavailable_reads, np.float64),
        unavailable_writes=np.asarray(leaves.unavailable_writes, np.float64),
        failovers=np.asarray(leaves.failovers, np.float64),
        repair_moves=np.asarray(leaves.repair_moves, np.float64),
        unreachable_frac=np.asarray(leaves.unreachable_frac, np.float64),
        wiped_frac=np.asarray(leaves.wiped_frac, np.float64),
        effective_hit_rate=(
            np.asarray(leaves.hits, np.float64)
            / np.maximum(
                reads + np.asarray(leaves.unavailable_reads, np.float64), 1.0
            )
        ),
    )
