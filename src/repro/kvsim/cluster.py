"""Cluster latency model + the experimental scenarios (paper §9).

  * LOCAL      — the paper's "theoretically ideal scenario": every request
                 (read or write) is served by the local key-value store.
  * REMOTE     — no local replicas ever; every op pays the remote RTT.
  * OPTIMIZED  — Redynis: reads consult the replica map maintained by the
                 placement daemon; usage statistics are logged per access and
                 the daemon replicates/purges on the fly.
  * REPLICATED — beyond-paper 4th bar: the "naive global replication of all
                 keys" the paper's hypothesis argues against (§9/§10). Reads
                 are local, but every write pays master relay + broadcast —
                 the cost LOCAL's idealisation hides.

Latency model (paper §8.2, generalised): the cluster is described by an
``[N, N]`` inter-node RTT matrix. The paper's 3-node testbed is the
*degenerate flat topology* — ``local_ms`` on the diagonal, ``remote_ms``
(100 ms) everywhere else — and is the default (``rtt=None``). Geo presets
(5-region WAN) live here; region-skewed / diurnal traffic presets live in
``workload.py``. Service time is the YCSB-side per-op cost; the paper does
not state it, so it is a calibration constant chosen to land the
LOCAL:REMOTE throughput ratio near the paper's reported ~10x (see
EXPERIMENTS.md §Repro-assumptions).

Read path (Algorithm 1, geo-generalised): a read at node x is served by the
*nearest* replica — ``min_j rtt[x, j]`` over the key's replica set. A local
replica has ``rtt[x, x] = local_ms``, reproducing the flat model's hit path.

Write path (Algorithm 2): a write at node x for a key whose replica set is
{x} commits locally; otherwise it is relayed to the master propagator
(``rtt[x, master]``) which posts the value to every owner host in parallel
(``max_j rtt[master, j]`` over owners — the broadcast completes when the
farthest owner acks).

Per-key payload cost (size-aware, after Didona & Zwaenepoel): when
``transfer_ms_per_kb > 0`` every remote hop additionally pays
``value_bytes``-proportional serialisation/transfer time. The default of 0
keeps the paper's pure-RTT model (and the exact Fig 2/3 numbers).
"""

from __future__ import annotations

import enum
import math
from typing import NamedTuple

import jax.numpy as jnp
from jax import Array

from repro.kernels.chunk_replay.ref import (
    nearest_replica_rtt_ref,
    read_latency_ref,
    write_latency_ref,
)
from repro.kvsim.faults import FaultConfig, FaultEvent, normalize_faults
from repro.kvsim.routing import RoutingConfig, normalize_routing

__all__ = [
    "ClusterConfig",
    "Scenario",
    "ServiceConfig",
    "RoutingConfig",
    "FaultConfig",
    "FaultEvent",
    "normalize_service",
    "normalize_routing",
    "normalize_faults",
    "read_latency",
    "write_latency",
    "nearest_replica_rtt",
    "read_latency_geo",
    "write_latency_geo",
    "flat_rtt",
    "wan5_cluster",
    "wan5_edge_cluster",
    "WAN5_REGIONS",
    "WAN5_RTT_MS",
]


class Scenario(enum.Enum):
    LOCAL = "local"
    REMOTE = "remote"
    OPTIMIZED = "optimized"
    REPLICATED = "replicated"


def flat_rtt(
    num_nodes: int = 3, remote_ms: float = 100.0, local_ms: float = 0.0
) -> tuple[tuple[float, ...], ...]:
    """The paper's testbed topology: a uniform ``remote_ms`` between every
    pair of distinct nodes (the degenerate ``[N, N]`` case)."""
    return tuple(
        tuple(local_ms if i == j else remote_ms for j in range(num_nodes))
        for i in range(num_nodes)
    )


# 5-region WAN preset: approximate public-cloud inter-region RTTs in ms.
WAN5_REGIONS = ("us-east", "us-west", "eu-west", "ap-southeast", "ap-northeast")
WAN5_RTT_MS: tuple[tuple[float, ...], ...] = (
    (0.0, 65.0, 75.0, 230.0, 170.0),
    (65.0, 0.0, 140.0, 165.0, 105.0),
    (75.0, 140.0, 0.0, 160.0, 220.0),
    (230.0, 165.0, 160.0, 0.0, 70.0),
    (170.0, 105.0, 220.0, 70.0, 0.0),
)


class ServiceConfig(NamedTuple):
    """Queueing-aware service-time model (M/M/1-style, after Minos
    1802.00696: service time — not just placement — dominates the tail once
    large objects queue behind small ones).

    Per request the *service demand* is

        d = service_ms + object_bytes[key] / serve_bytes_per_ms

    folded per **serving** node over each request chunk (reads are served by
    the nearest visible replica, writes by the requesting node). A node's
    per-chunk *load factor* is

        rho[x] = min(demand_fold[x] / capacity_ms, rho_max)

    where ``capacity_ms = capacity_factor * chunk_size * service_ms`` is the
    service capacity the node can absorb per chunk (``capacity_factor`` is
    chunk-size invariant: 1.0 means one node could serve the whole chunk's
    base service time alone). Each request then waits

        w = d * rho[serving] / (1 - rho[serving])

    on top of its RTT latency — the M/M/1 (processor-sharing) residence-time
    excess, clamped at ``rho_max`` so an overloaded node prices requests at a
    finite ``d * rho_max / (1 - rho_max)`` instead of diverging.

    Off by default (``ClusterConfig.service = None``): the latency path is
    bit-exact to the pure-RTT model and all goldens hold.
    """

    enabled: bool = True
    serve_bytes_per_ms: float = 1024.0  # node service bandwidth (bytes/ms)
    capacity_factor: float = 1.0  # node capacity per chunk, in chunks
    rho_max: float = 0.95  # stability clamp (must stay < 1)

    def validate(self) -> "ServiceConfig":
        if not self.serve_bytes_per_ms > 0:
            raise ValueError(
                f"serve_bytes_per_ms must be positive, got {self.serve_bytes_per_ms}"
            )
        if not self.capacity_factor > 0:
            raise ValueError(
                f"capacity_factor must be positive, got {self.capacity_factor}"
            )
        if not 0.0 < self.rho_max < 1.0:
            raise ValueError(
                f"rho_max must lie in (0, 1) (the M/M/1 stability bound), "
                f"got {self.rho_max}"
            )
        return self

    def capacity_ms(self, chunk_size: int, service_ms: float) -> float:
        """Per-node service capacity for one chunk, in ms of demand."""
        return self.capacity_factor * chunk_size * service_ms


def normalize_service(service: "ServiceConfig | None") -> "ServiceConfig | None":
    """Collapse disabled configs to None so ``service=None`` and
    ``ServiceConfig(enabled=False)`` compile the identical program."""
    if service is None or not service.enabled:
        return None
    return service.validate()


class ClusterConfig(NamedTuple):
    num_nodes: int = 3  # paper: 3-node testbed
    remote_ms: float = 100.0  # paper: simulated geo-distributed RTT
    local_ms: float = 0.0
    service_ms: float = 10.0  # per-op service cost (calibration constant)
    master: int = 0  # master propagator (write serializer)
    value_bytes: float = 1024.0  # size(value) >> size(key), paper §4
    key_bytes: float = 16.0
    # [N][N] pairwise RTT in ms (hashable nested tuple so the config stays a
    # valid jit static). None -> the degenerate flat topology built from
    # remote_ms / local_ms — byte-identical to the paper's model.
    rtt: tuple[tuple[float, ...], ...] | None = None
    # Size-aware per-key transfer cost on remote hops; 0 = pure-RTT model.
    transfer_ms_per_kb: float = 0.0
    # Per-node replica-byte budget enforced by the placement daemon's
    # capacity projection stage (OPTIMIZED scenario only — LOCAL/REPLICATED
    # are idealised full-replication baselines and ignore it). A scalar
    # applies to every node; an [N] tuple models heterogeneous clusters
    # (e.g. one small edge node). inf (default) = the paper's Algorithm 3
    # exactly — no projection runs at all.
    capacity_bytes: tuple[float, ...] | float = float("inf")
    # Queueing-aware service-time model (None = pure-RTT latency, the
    # paper's model and the bit-exact golden path). A ServiceConfig is a
    # nested NamedTuple, so the ClusterConfig stays a valid jit static.
    service: ServiceConfig | None = None
    # Routing/directory tier (None = requests teleport to the right replica
    # with free, fresh ownership knowledge — the paper's model and the
    # bit-exact golden path). See repro.kvsim.routing for the TurboKV-style
    # cached-directory model; also a nested NamedTuple, so the config stays
    # a valid jit static.
    routing: RoutingConfig | None = None
    # Crux-style locality hierarchy labelling: zone_of[n] / region_of[n]
    # give node n's zone / region label. None = the flat hierarchy (each
    # node its own zone and region). Only consulted to resolve correlated
    # zone/region fault domains — the RTT matrix stays the latency truth.
    zone_of: tuple[int, ...] | None = None
    region_of: tuple[int, ...] | None = None
    # Failure-injection schedule (None = the fixed all-up membership of the
    # paper's model and the bit-exact golden path). See repro.kvsim.faults
    # for the crash/partition timeline; also a nested NamedTuple, so the
    # config stays a valid jit static.
    faults: FaultConfig | None = None

    def rtt_matrix(self) -> Array:
        """The ``[N, N]`` RTT matrix as a device array."""
        if self.rtt is None:
            return jnp.asarray(
                flat_rtt(self.num_nodes, self.remote_ms, self.local_ms),
                jnp.float32,
            )
        return jnp.asarray(self.rtt, jnp.float32)

    def transfer_ms(self, payload_bytes: float | None = None) -> float:
        """Payload serialisation/transfer time for one remote hop."""
        if payload_bytes is None:
            payload_bytes = self.value_bytes
        return self.transfer_ms_per_kb * (payload_bytes / 1024.0)

    def capacity_tuple(self) -> tuple[float, ...]:
        """Per-node budgets as an ``[N]`` tuple (scalar broadcast)."""
        if isinstance(self.capacity_bytes, tuple):
            return tuple(float(c) for c in self.capacity_bytes)
        return (float(self.capacity_bytes),) * self.num_nodes

    def capacity_vector(self) -> Array:
        """The ``[N]`` per-node budget as a device array."""
        return jnp.asarray(self.capacity_tuple(), jnp.float32)

    @property
    def has_finite_capacity(self) -> bool:
        """True iff any node has a finite replica budget (host-side static,
        so the projection stage compiles away entirely at inf)."""
        return any(math.isfinite(c) for c in self.capacity_tuple())


def wan5_cluster(service_ms: float = 10.0, **kwargs) -> ClusterConfig:
    """5-region WAN preset (``WAN5_REGIONS`` RTTs), master in us-east."""
    return ClusterConfig(
        num_nodes=5, rtt=WAN5_RTT_MS, service_ms=service_ms, **kwargs
    )


def wan5_edge_cluster(
    edge_capacity_bytes: float = 64 * 1024.0,
    edge_node: int = 4,
    **kwargs,
) -> ClusterConfig:
    """Heterogeneous WAN preset: the 5-region topology with one small *edge*
    node (default: ap-northeast) whose replica budget is finite while the
    core regions are unconstrained — the capacity projection evicts the edge
    node's coldest replicas instead of letting the daemon overfill it."""
    caps = tuple(
        float(edge_capacity_bytes) if i == edge_node else float("inf")
        for i in range(5)
    )
    return wan5_cluster(capacity_bytes=caps, **kwargs)


# ---------------------------------------------------------------------------
# Flat-model latency functions (paper §8.2 verbatim; retained for the
# degenerate topology and as the reference the geo model must collapse to).
# ---------------------------------------------------------------------------


def read_latency(cfg: ClusterConfig, hit: Array) -> Array:
    """Per-request read latency: service + RTT on local miss (Algorithm 1)."""
    return cfg.service_ms + jnp.where(hit, cfg.local_ms, cfg.remote_ms)


def write_latency(
    cfg: ClusterConfig,
    node: Array,
    sole_local_owner: Array,
    any_owner_remote_from_master: Array,
) -> Array:
    """Per-request write latency (Algorithm 2), flat topology.

    sole_local_owner: replica set == {requesting node} -> commit locally.
    Otherwise: relay to master (RTT if requester != master) + master posts to
    owner hosts (RTT if any owner is not the master itself).
    """
    relay = jnp.where(node == cfg.master, 0.0, cfg.remote_ms)
    post = jnp.where(any_owner_remote_from_master, cfg.remote_ms, 0.0)
    return cfg.service_ms + jnp.where(sole_local_owner, 0.0, relay + post)


# ---------------------------------------------------------------------------
# Geo latency functions: the [N, N] generalisation used by the simulator.
# ---------------------------------------------------------------------------


def nearest_replica_rtt(rtt: Array, replicas: Array, nodes: Array) -> Array:
    """RTT from each requesting node to its *nearest* replica.

    rtt:      [N, N] pairwise RTT matrix.
    replicas: [B, N] bool replica mask per request.
    nodes:    [B]    requesting node per request.

    A request whose replica mask is empty pays the worst RTT in the
    topology rather than producing an inf. With infinite budgets the
    metadata layer's starvation guard makes the empty set unreachable; with
    finite ``capacity_bytes`` the projection stage may evict a key's last
    replica, and this worst-RTT charge *is* the modelled cost of fetching
    it from the backing store (in the flat testbed: exactly ``remote_ms``,
    an ordinary miss).

    The canonical expression lives in ``repro.kernels.chunk_replay.ref``
    (the oracle the fused Pallas kernel is parity-pinned against); this is
    the config-level spelling of the same math.
    """
    return nearest_replica_rtt_ref(rtt, replicas, nodes)


def read_latency_geo(
    cfg: ClusterConfig, rtt: Array, replicas: Array, nodes: Array
) -> Array:
    """Geo read path: service + RTT to the nearest replica (+ payload cost
    when the serving replica is remote — i.e. the requesting node holds no
    visible copy; a nonzero RTT diagonal models intra-node latency, not a
    network hop, so it never triggers the transfer charge)."""
    return read_latency_ref(
        rtt, replicas, nodes,
        service_ms=cfg.service_ms,
        xfer_ms=cfg.transfer_ms(cfg.value_bytes),
    )


def write_latency_geo(
    cfg: ClusterConfig,
    rtt: Array,
    replicas: Array,
    nodes: Array,
    sole_local_owner: Array,
) -> Array:
    """Geo write path (Algorithm 2 over the RTT matrix).

    Relay to the master costs ``rtt[node, master]``; the master's parallel
    post to the owner set completes when the farthest owner acks
    (``max`` over the owner row). A master-origin write relays for free and
    the master's own replica posts for free — as in the flat model — even
    when a nonzero RTT diagonal models intra-node latency, so ``cost > 0``
    means a payload genuinely crossed a link (and pays the transfer charge).
    """
    return write_latency_ref(
        rtt, replicas, nodes, sole_local_owner,
        service_ms=cfg.service_ms,
        master=cfg.master,
        xfer_ms=cfg.transfer_ms(cfg.value_bytes + cfg.key_bytes),
    )
