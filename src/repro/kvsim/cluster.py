"""Cluster latency model + the experimental scenarios (paper §9).

  * LOCAL      — the paper's "theoretically ideal scenario": every request
                 (read or write) is served by the local key-value store.
  * REMOTE     — no local replicas ever; every op pays the remote RTT.
  * OPTIMIZED  — Redynis: reads consult the replica map maintained by the
                 placement daemon; usage statistics are logged per access and
                 the daemon replicates/purges on the fly.
  * REPLICATED — beyond-paper 4th bar: the "naive global replication of all
                 keys" the paper's hypothesis argues against (§9/§10). Reads
                 are local, but every write pays master relay + broadcast —
                 the cost LOCAL's idealisation hides.

Latency model (paper §8.2): remote request penalty 100 ms, local penalty 0.
Service time is the YCSB-side per-op cost; the paper does not state it, so it
is a calibration constant chosen to land the LOCAL:REMOTE throughput ratio
near the paper's reported ~10x (see EXPERIMENTS.md §Repro-assumptions).

Write path (Algorithm 2): a write at node x for a key whose replica set is
{x} commits locally; otherwise it is relayed to the master propagator
(one RTT if x != master) which posts the value to every owner host
(one parallel RTT if any owner is remote from the master).
"""

from __future__ import annotations

import enum
from typing import NamedTuple

import jax.numpy as jnp
from jax import Array

__all__ = ["ClusterConfig", "Scenario", "read_latency", "write_latency"]


class Scenario(enum.Enum):
    LOCAL = "local"
    REMOTE = "remote"
    OPTIMIZED = "optimized"
    REPLICATED = "replicated"


class ClusterConfig(NamedTuple):
    num_nodes: int = 3  # paper: 3-node testbed
    remote_ms: float = 100.0  # paper: simulated geo-distributed RTT
    local_ms: float = 0.0
    service_ms: float = 10.0  # per-op service cost (calibration constant)
    master: int = 0  # master propagator (write serializer)
    value_bytes: float = 1024.0  # size(value) >> size(key), paper §4
    key_bytes: float = 16.0


def read_latency(cfg: ClusterConfig, hit: Array) -> Array:
    """Per-request read latency: service + RTT on local miss (Algorithm 1)."""
    return cfg.service_ms + jnp.where(hit, cfg.local_ms, cfg.remote_ms)


def write_latency(
    cfg: ClusterConfig,
    node: Array,
    sole_local_owner: Array,
    any_owner_remote_from_master: Array,
) -> Array:
    """Per-request write latency (Algorithm 2).

    sole_local_owner: replica set == {requesting node} -> commit locally.
    Otherwise: relay to master (RTT if requester != master) + master posts to
    owner hosts (RTT if any owner is not the master itself).
    """
    relay = jnp.where(node == cfg.master, 0.0, cfg.remote_ms)
    post = jnp.where(any_owner_remote_from_master, cfg.remote_ms, 0.0)
    return cfg.service_ms + jnp.where(sole_local_owner, 0.0, relay + post)
