"""Session-affinity router — Redynis integration #3 (serving control plane).

Objects are sessions (their KV/recurrent decode state), nodes are pods,
traffic is request arrivals. The router keeps the paper's metadata layer
(per-session per-pod access counts, last-access time), and its placement
daemon decides which pod *owns* each session's cache — migrating caches
toward the pods that serve them most and expiring idle sessions, with the
migration payload charged at real decode-state byte sizes.

Leader election (paper §11, "future work"): the write-serializer (the node
that commits placement changes) is chosen by a bully election over the
heartbeat table — highest-id live pod wins; a dead leader is replaced on
the next ``tick()``. Placement sweeps only run on the leader, exactly like
the paper's single RedynisDaemon node.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.metadata import create_store, record_accesses, record_new_keys
from repro.core.placement import PlacementDaemon
from repro.train.fault import HeartbeatMonitor

__all__ = ["RouteResult", "SessionRouter"]


class RouteResult(NamedTuple):
    pod: int  # pod that serves the request
    local_hit: bool  # session cache already on that pod
    migrated: bool  # placement moved the cache here first


class SessionRouter:
    def __init__(
        self,
        num_pods: int,
        max_sessions: int,
        *,
        h: float | None = None,
        expiry_ticks: int | None = 10_000,
        sweep_period: int = 100,
        session_bytes: float = 0.0,
    ):
        self.num_pods = num_pods
        self.max_sessions = max_sessions
        self.daemon = PlacementDaemon(
            num_pods, h=h, expiry=expiry_ticks, period=sweep_period
        )
        self.store = create_store(max_sessions, num_pods)
        self.session_bytes = session_bytes
        self._sid: dict[str, int] = {}  # session name -> key index
        self._free = list(range(max_sessions - 1, -1, -1))
        self.monitor = HeartbeatMonitor([f"pod-{i}" for i in range(num_pods)])
        self.leader = self._elect()
        self.tick_count = 0
        self.stats = {
            "requests": 0,
            "local_hits": 0,
            "migrations": 0,
            "migrated_bytes": 0.0,
            "expired": 0,
            "elections": 0,
        }

    # ------------------------------------------------------------ election
    def _elect(self) -> int:
        """Bully election: highest-id live pod becomes the write serializer."""
        alive = self.monitor.alive()
        if not alive:
            raise RuntimeError("no live pods")
        return max(int(n.split("-")[1]) for n in alive)

    def fail_pod(self, pod: int) -> None:
        """Simulated pod failure: sessions homed there lose their replicas;
        a dead leader triggers re-election on the next tick."""
        self.monitor.kill(f"pod-{pod}")
        hosts = self.store.hosts.at[:, pod].set(False)
        # Sessions that lost their only replica must re-prefill somewhere.
        orphan = ~jnp.any(hosts, axis=-1) & self.store.live
        self.store = self.store._replace(hosts=hosts, live=self.store.live & ~orphan)

    # ------------------------------------------------------------ routing
    def _key_of(self, session: str) -> int:
        if session not in self._sid:
            if not self._free:
                raise RuntimeError("session table full")
            self._sid[session] = self._free.pop()
        return self._sid[session]

    def route(self, session: str, source_pod: int) -> RouteResult:
        """Algorithm 1, serving flavour: serve locally when the cache is
        here; otherwise serve from the owner pod (remote penalty) while the
        metadata layer logs the miss — the daemon migrates hot sessions at
        the next sweep."""
        alive = {int(n.split("-")[1]) for n in self.monitor.alive()}
        if source_pod not in alive:
            source_pod = min(alive)
        key = self._key_of(session)
        k = jnp.asarray([key], jnp.int32)
        n = jnp.asarray([source_pod], jnp.int32)
        self.stats["requests"] += 1

        live = bool(self.store.live[key])
        if not live:  # new session: cache built where the request landed
            self.store = record_new_keys(self.store, k, n, now=self.tick_count)
            return RouteResult(pod=source_pod, local_hit=False, migrated=False)

        self.store = record_accesses(self.store, k, n, now=self.tick_count)
        hosts = np.asarray(self.store.hosts[key])
        if hosts[source_pod]:
            self.stats["local_hits"] += 1
            return RouteResult(pod=source_pod, local_hit=True, migrated=False)
        owner = int(np.argmax(hosts))
        return RouteResult(pod=owner, local_hit=False, migrated=False)

    # ------------------------------------------------------------ daemon
    def tick(self) -> None:
        """Advance logical time; on the period boundary the *leader* sweeps."""
        self.tick_count += 1
        for i in range(self.num_pods):  # healthy pods heartbeat every tick
            self.monitor.beat(f"pod-{i}")
        if int(self.leader) not in {
            int(n.split("-")[1]) for n in self.monitor.alive()
        }:
            self.leader = self._elect()
            self.stats["elections"] += 1
        if self.tick_count % self.daemon.period == 0:
            plan, self.store = self.daemon.step(self.store, now=self.tick_count)
            moves = float(jnp.sum(plan.to_add))
            self.stats["migrations"] += int(moves)
            self.stats["migrated_bytes"] += moves * self.session_bytes
            self.stats["expired"] += int(jnp.sum(plan.expired))

    # ------------------------------------------------------------ metrics
    def hit_rate(self) -> float:
        r = max(self.stats["requests"], 1)
        return self.stats["local_hits"] / r
