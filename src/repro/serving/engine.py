"""Batched decode engine: prefill requests into lanes, step all lanes.

One engine ≈ one pod's serving deployment (the paper's RedynisService +
Redis instance). The engine is deliberately model-family-agnostic: it only
calls ``model.prefill`` / ``model.decode_step`` and carries the opaque
decode-state pytree, so dense GQA, MoE (with live hot-expert sets), RWKV,
RecurrentGemma and Whisper all serve through the same code path.

Lane packing: decode states are stored *stacked over lanes* exactly as the
model produces them for a full batch; a new prefill writes its lane slice
via index update. All lanes advance together each ``step()`` (continuous
batching at lane granularity).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.dist import DistSpec
from repro.models.model import Model
from repro.serving.kvcache import LaneTable, state_bytes

__all__ = ["Request", "ServeEngine"]


class Request(NamedTuple):
    session: str
    tokens: np.ndarray  # prompt token ids [S]
    max_new: int = 16


def _write_lane(state, lane_state, lane: int, num_lanes: int):
    """Copy a single-lane decode state into lane ``lane`` of the batch state.

    The lane dim of each leaf is the first axis that is ``num_lanes`` wide
    in the batch state and 1 wide in the single-lane state — dim 0 for flat
    [B, ...] leaves, dim 1 for layer-stacked [L, B, ...] leaves.
    """

    def upd(full, single):
        for d in range(full.ndim):
            if full.shape[d] == num_lanes and single.shape[d] == 1:
                idx = tuple([slice(None)] * d + [slice(lane, lane + 1)])
                return full.at[idx].set(single.astype(full.dtype))
        raise ValueError((full.shape, single.shape, num_lanes))

    return jax.tree.map(upd, state, lane_state)


class ServeEngine:
    def __init__(
        self,
        model: Model,
        params: dict,
        num_lanes: int,
        cache_len: int,
        dist: Optional[DistSpec] = None,
        hot_ids: Array | None = None,
        temperature: float = 0.0,
        seed: int = 0,
    ):
        self.model = model
        self.params = params
        self.dist = dist
        self.hot_ids = hot_ids
        self.cache_len = cache_len
        self.temperature = temperature
        self.lanes = LaneTable(num_lanes)
        self.num_lanes = num_lanes
        self.state = model.init_state(num_lanes, cache_len)
        self.last_token = jnp.zeros((num_lanes,), jnp.int32)
        self.remaining = np.zeros((num_lanes,), np.int64)
        self.outputs: dict[str, list[int]] = {}
        self._rng = jax.random.PRNGKey(seed)
        self.steps = 0
        self.tokens_out = 0

        self._decode = jax.jit(
            lambda p, s, t, h: model.decode_step(p, s, t, self.dist, hot_ids=h)
        )
        self._prefill_cache: dict[int, Any] = {}

    # -------------------------------------------------------------- prefill
    def admit(self, req: Request) -> int:
        """Prefill a request into a lane. Returns the lane index."""
        lane, evicted = self.lanes.bind(req.session)
        if evicted is not None:
            self.outputs.setdefault(evicted, [])
        s = len(req.tokens)
        batch = {"tokens": jnp.asarray(req.tokens, jnp.int32)[None, :]}
        if self.model.cfg.family == "vlm":
            batch["patches"] = jnp.zeros(
                (1, self.model.cfg.num_patches, self.model.cfg.d_model),
                jnp.bfloat16,
            )
        if self.model.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (1, self.model.cfg.num_frames, self.model.cfg.d_model),
                jnp.bfloat16,
            )
        fn = self._prefill_cache.get(s)
        if fn is None:
            fn = jax.jit(
                lambda p, b: self.model.prefill(
                    p, b, self.dist, cache_len=self.cache_len, hot_ids=self.hot_ids
                )
            )
            self._prefill_cache[s] = fn
        logits, lane_state = fn(self.params, batch)
        self.state = _write_lane(self.state, lane_state, lane, self.num_lanes)
        tok = self._sample(logits)[0]
        self.last_token = self.last_token.at[lane].set(tok)
        self.remaining[lane] = req.max_new
        self.outputs[req.session] = [int(tok)]
        return lane

    # -------------------------------------------------------------- decode
    def _sample(self, logits: Array) -> Array:
        if self.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        self._rng, k = jax.random.split(self._rng)
        return jax.random.categorical(k, logits / self.temperature, -1).astype(
            jnp.int32
        )

    def step(self) -> dict[str, int]:
        """One decode step for every active lane. Returns {session: token}."""
        active = {s: l for s, l in self.lanes.active.items() if self.remaining[l] > 0}
        if not active:
            return {}
        logits, self.state = self._decode(
            self.params, self.state, self.last_token, self.hot_ids
        )
        toks = self._sample(logits)
        self.last_token = toks
        out = {}
        for session, lane in active.items():
            t = int(toks[lane])
            self.outputs[session].append(t)
            self.remaining[lane] -= 1
            out[session] = t
            if self.remaining[lane] == 0:
                self.lanes.release(session)
        self.steps += 1
        self.tokens_out += len(out)
        return out

    def run_to_completion(self, max_steps: int = 10_000) -> dict[str, list[int]]:
        for _ in range(max_steps):
            if not self.step():
                break
        return dict(self.outputs)

    # -------------------------------------------------------------- stats
    def cache_bytes(self) -> int:
        return state_bytes(self.state)
