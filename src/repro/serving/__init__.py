from repro.serving.engine import Request, ServeEngine
from repro.serving.kvcache import LaneTable, state_bytes
from repro.serving.router import RouteResult, SessionRouter
