"""Session-slot KV cache management for batched decoding.

The engine owns a fixed number of *lanes* (batch slots); each lane is bound
to one session. Lane state is whatever the model family's decode state is
(KV cache / recurrent state / enc-dec state) — this module only manages the
binding, LRU eviction of idle sessions, and the byte accounting the Redynis
session router charges migrations with.
"""

from __future__ import annotations

import time
from typing import Any, NamedTuple, Optional

import jax
import numpy as np

__all__ = ["LaneTable", "state_bytes"]


def state_bytes(state) -> int:
    """Total decode-state bytes (the migration payload for one full batch)."""
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(state))


class LaneTable:
    """session_id <-> lane binding with LRU eviction."""

    def __init__(self, num_lanes: int):
        self.num_lanes = num_lanes
        self._lane_of: dict[str, int] = {}
        self._session_of: dict[int, str] = {}
        self._last_used: dict[int, float] = {}

    def lookup(self, session: str) -> Optional[int]:
        lane = self._lane_of.get(session)
        if lane is not None:
            self._last_used[lane] = time.monotonic()
        return lane

    def bind(self, session: str) -> tuple[int, Optional[str]]:
        """Assign a lane (evicting the LRU session if full).

        Returns (lane, evicted_session|None).
        """
        if session in self._lane_of:
            return self._lane_of[session], None
        free = set(range(self.num_lanes)) - set(self._session_of)
        evicted = None
        if free:
            lane = min(free)
        else:
            lane = min(self._last_used, key=self._last_used.get)
            evicted = self._session_of.pop(lane)
            del self._lane_of[evicted]
        self._lane_of[session] = lane
        self._session_of[lane] = session
        self._last_used[lane] = time.monotonic()
        return lane, evicted

    def release(self, session: str) -> None:
        lane = self._lane_of.pop(session, None)
        if lane is not None:
            self._session_of.pop(lane, None)
            self._last_used.pop(lane, None)

    @property
    def active(self) -> dict[str, int]:
        return dict(self._lane_of)
