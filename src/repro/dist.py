"""Distribution context threaded through the models.

Models are written as pure functions over params and activations; every
placement decision funnels through a :class:`DistSpec` so the same model code
runs (a) un-distributed on CPU for smoke tests (``dist=None`` — every helper
degenerates to plain jnp), (b) under ``pjit`` on the production mesh, where
the helpers emit sharding constraints and the two genuinely placement-
sensitive ops — vocab-sharded embedding lookup and vocab-sharded softmax
cross-entropy — are implemented explicitly rather than left to the SPMD
partitioner's gather heuristics (which may all-gather a multi-GB table).

This module is the seam between the model layer and the launch layer:
``launch/sharding.py`` builds the DistSpec; models only consume it.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import Array
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

__all__ = [
    "DistSpec",
    "local_dist",
    "constrain",
    "embed_lookup",
    "softmax_xent",
    "unembed_logits",
]


class DistSpec(NamedTuple):
    """Mesh + logical-axis bindings for one run.

    batch_axes: mesh axes the global batch is split over — ``("data",)``
                single-pod, ``("pod", "data")`` multi-pod.
    model_axis: mesh axis for tensor/expert/vocab parallelism (None = off).
    """

    mesh: Optional[Mesh] = None
    batch_axes: tuple[str, ...] = ()
    model_axis: Optional[str] = None

    @property
    def batch(self):  # PartitionSpec entry for the batch dim
        return self.batch_axes if self.batch_axes else None

    @property
    def tensor_parallel(self) -> bool:
        """True when the model axis is free for TP (not consumed by batch).
        The fsdp layout spreads the batch over the model axis too; head/
        expert constraints must then stay unsharded."""
        return self.model_axis is not None and self.model_axis not in self.batch_axes

    @property
    def loss_batch(self):
        """Row spec for vocab-sharded ops (embedding lookup, xent): the
        batch axes minus the model axis — vocab occupies the model axis, so
        token rows reshard off it for the loss path."""
        axes = tuple(a for a in self.batch_axes if a != self.model_axis)
        return axes if axes else None

    @property
    def model_size(self) -> int:
        if self.mesh is None or self.model_axis is None:
            return 1
        return self.mesh.shape[self.model_axis]

    @property
    def batch_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n


def local_dist() -> DistSpec:
    """The no-mesh context used by CPU smoke tests."""
    return DistSpec()


def constrain(x: Array, dist: Optional[DistSpec], *spec) -> Array:
    """``with_sharding_constraint`` that no-ops without a mesh.

    ``spec`` entries are mesh-axis names / tuples / None, one per dim of x.
    """
    if dist is None or dist.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(dist.mesh, P(*spec))
    )


# ---------------------------------------------------------------------------
# Vocab-sharded embedding lookup.
#
# table [V, D] is sharded V-over-model. A plain jnp.take would leave the SPMD
# partitioner to choose between all-gathering the table (V up to 256k rows —
# gigabytes) and the masked-local-gather + psum pattern; we write the latter
# explicitly with shard_map so the collective is one all-reduce over the
# [tokens, D] activation, never the table.


def embed_lookup(table: Array, tokens: Array, dist: Optional[DistSpec]) -> Array:
    """tokens [B, S] int32 -> [B, S, D]; table [V, D] (V sharded over model)."""
    if dist is None or dist.mesh is None or dist.model_axis is None:
        return jnp.take(table, tokens, axis=0)

    axis = dist.model_axis
    n_shards = dist.model_size
    v = table.shape[0]
    assert v % n_shards == 0, (v, n_shards)
    v_local = v // n_shards

    def local_lookup(tab: Array, tok: Array) -> Array:
        lo = jax.lax.axis_index(axis) * v_local
        idx = tok - lo
        ok = (idx >= 0) & (idx < v_local)
        rows = jnp.take(tab, jnp.clip(idx, 0, v_local - 1), axis=0)
        rows = jnp.where(ok[..., None], rows, 0).astype(tab.dtype)
        return jax.lax.psum(rows, axis)

    # Batches too small to split (long_500k decodes one stream) replicate.
    lb = dist.loss_batch
    n_rows = 1
    if lb:
        for a in lb:
            n_rows *= dist.mesh.shape[a]
    bspec = lb if tokens.shape[0] % max(n_rows, 1) == 0 else None
    return shard_map(
        local_lookup,
        mesh=dist.mesh,
        in_specs=(P(axis, None), P(bspec, None)),
        out_specs=P(bspec, None, None),
        check_rep=False,
    )(table, tokens)


# ---------------------------------------------------------------------------
# Vocab-sharded softmax cross-entropy (the LM head + loss, fused).
#
# logits [T, V] for T ~ 1M tokens and V ~ 128k would be ~0.5-1 GB *per chip*
# if materialised at once, and an all-gathered version would be 16x that. We
# (a) keep logits sharded over V (the matmul needs no comm: x is replicated
# over model, the table shard produces the local logit shard), (b) reduce
# over V with psum-backed logsumexp, (c) pick the label logit with a fused
# masked reduce (never a gather across the sharded axis), and (d) scan over
# token chunks so only one chunk of logits is ever live.


def unembed_logits(
    x: Array, table: Array, dist: Optional[DistSpec], vocab_size: int = 0
) -> Array:
    """x [..., D] @ table.T -> logits [..., V], V-sharded when distributed.

    ``vocab_size``: real vocab; rows beyond it (table padding for shard
    divisibility) are masked to -inf so samplers never pick them.
    """
    logits = jnp.einsum(
        "...d,vd->...v", x, table, preferred_element_type=jnp.float32
    )
    v = table.shape[0]
    if vocab_size and vocab_size < v:
        pad_mask = jnp.arange(v) >= vocab_size
        logits = jnp.where(pad_mask, -1e30, logits)
    if dist is not None and dist.mesh is not None:
        spec = [None] * (logits.ndim - 1) + [dist.model_axis]
        spec[0] = dist.loss_batch
        logits = constrain(logits, dist, *spec)
    return logits


def _xent_chunk(
    x: Array,  # [C, D] activations for this chunk
    targets: Array,  # [C] int32
    mask: Array,  # [C] bool (loss mask)
    table: Array,  # [V, D]
    dist: Optional[DistSpec],
    vocab_size: int,
) -> tuple[Array, Array]:
    """Sum of token losses + correct-token count for one chunk."""
    logits = jnp.einsum(
        "cd,vd->cv", x, table, preferred_element_type=jnp.float32
    )
    v = logits.shape[-1]
    if vocab_size and vocab_size < v:
        logits = jnp.where(jnp.arange(v) >= vocab_size, -1e30, logits)
    if dist is not None and dist.mesh is not None:
        # Chunk rows shard over the non-model batch axes; vocab over model.
        # A None row spec here would FORCE replication — i.e. all-gather
        # the logits (an early bug the roofline analyser caught).
        logits = constrain(logits, dist, dist.loss_batch, dist.model_axis)
    m = jnp.max(logits, axis=-1, keepdims=True)  # psum-max under SPMD
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[:, 0]
    onehot_sel = jnp.arange(v, dtype=targets.dtype)[None, :] == targets[:, None]
    label_logit = jnp.sum(jnp.where(onehot_sel, logits, 0.0), axis=-1)
    loss = (lse - label_logit) * mask
    return jnp.sum(loss), jnp.sum(mask.astype(jnp.float32))


def softmax_xent(
    x: Array,  # [B, S, D] final hidden states
    table: Array,  # [V, D] embedding/unembedding table
    targets: Array,  # [B, S] int32
    dist: Optional[DistSpec] = None,
    mask: Array | None = None,
    num_chunks: int = 8,
    vocab_size: int = 0,
) -> Array:
    """Mean cross-entropy over masked tokens, chunked over the token dim.

    The chunk body is rematerialised on the backward pass (jax.checkpoint),
    so peak logits memory is one chunk forward + one chunk backward.
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    tf = targets.reshape(t)
    mf = (
        jnp.ones((t,), jnp.float32)
        if mask is None
        else mask.reshape(t).astype(jnp.float32)
    )
    num_chunks = min(num_chunks, t)
    while t % num_chunks:
        num_chunks -= 1
    c = t // num_chunks

    chunk_fn = jax.checkpoint(
        lambda xa, ta, ma: _xent_chunk(xa, ta, ma, table, dist, vocab_size)
    )

    def body(carry, args):
        tot, cnt = carry
        l, n = chunk_fn(*args)
        return (tot + l, cnt + n), None

    (tot, cnt), _ = jax.lax.scan(
        body,
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (
            xf.reshape(num_chunks, c, d),
            tf.reshape(num_chunks, c),
            mf.reshape(num_chunks, c),
        ),
    )
    return tot / jnp.maximum(cnt, 1.0)
