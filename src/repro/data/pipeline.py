"""Deterministic, shardable, exactly-replayable data pipeline.

Requirements it serves:
  * fault tolerance — the stream position is a single integer; restoring a
    checkpoint replays from the recorded step with bit-identical batches
    (every batch is a pure function of (seed, step)).
  * elasticity — batches are generated per data shard from the same global
    (seed, step), so changing the data-parallel width re-slices the same
    global batch instead of changing the data distribution.
  * Redynis-relevant traffic — token frequencies are zipfian (natural-text
    skew; also exactly the paper's skewed workload), so the hot-row
    embedding cache and MoE routing skew have something real to chase.

Two sources: ``synthetic`` (zipfian LM stream with local n-gram structure so
the loss actually falls) and ``memmap`` (a token file produced by
``write_token_file`` — the stub for a production tokenised corpus).
"""

from __future__ import annotations

import os
from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

__all__ = ["DataConfig", "PipelineState", "Pipeline", "write_token_file"]


class DataConfig(NamedTuple):
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "synthetic"  # synthetic | memmap
    path: str = ""  # token file for memmap source
    zipf_a: float = 1.2  # zipf exponent for synthetic token frequencies
    pad_id: int = -1


class PipelineState(NamedTuple):
    step: Array  # [] int32 — the only state; checkpointable as one int


class Pipeline:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.source == "memmap":
            self._tokens = np.memmap(cfg.path, dtype=np.int32, mode="r")
        else:
            self._tokens = None
        # Zipfian unigram table (stable across runs for a fixed vocab/a).
        ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._probs = jnp.asarray(p / p.sum(), jnp.float32)

    def init_state(self) -> PipelineState:
        return PipelineState(step=jnp.zeros((), jnp.int32))

    # -- batch generation -----------------------------------------------------
    def _synthetic(self, step: Array) -> Array:
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        b, s = cfg.global_batch, cfg.seq_len
        base = jax.random.choice(
            key, cfg.vocab_size, (b, s + 1), p=self._probs
        ).astype(jnp.int32)
        # Local structure: with p=0.5 a token repeats its left neighbour
        # shifted by 1 (mod vocab) — gives the model a learnable signal.
        k2 = jax.random.fold_in(key, 1)
        copy = jax.random.bernoulli(k2, 0.5, (b, s + 1))
        shifted = jnp.roll(base, 1, axis=1)
        toks = jnp.where(copy, (shifted + 1) % cfg.vocab_size, base)
        return toks

    def _memmap(self, step: Array) -> Array:
        cfg = self.cfg
        b, s = cfg.global_batch, cfg.seq_len
        need = b * (s + 1)
        total = len(self._tokens) - need
        start = (int(step) * need) % max(total, 1)
        flat = np.asarray(self._tokens[start : start + need], dtype=np.int32)
        return jnp.asarray(flat.reshape(b, s + 1))

    def next(self, state: PipelineState) -> tuple[dict, PipelineState]:
        """Returns (batch {tokens, targets}, next_state)."""
        toks = (
            self._memmap(state.step)
            if self.cfg.source == "memmap"
            else self._synthetic(state.step)
        )
        batch = {"tokens": toks[:, :-1], "targets": toks[:, 1:]}
        return batch, PipelineState(step=state.step + 1)

    def seek(self, step: int) -> PipelineState:
        """Exact replay position for restore-after-failure."""
        return PipelineState(step=jnp.asarray(step, jnp.int32))

    def __iter__(self) -> Iterator[dict]:
        st = self.init_state()
        while True:
            batch, st = self.next(st)
            yield batch


def write_token_file(path: str, tokens: np.ndarray) -> None:
    """Persist a tokenised corpus for the memmap source (atomic)."""
    tmp = path + ".tmp"
    np.asarray(tokens, dtype=np.int32).tofile(tmp)
    os.replace(tmp, path)
