"""Unified model assembly: one ``Model`` facade over the five block families.

``build(cfg)`` returns a :class:`Model` whose methods are pure functions
(suitable for jit/pjit) dispatching on ``cfg.family``:

  dense | moe | vlm  -> decoder-only transformer stack (GQA; MoE FFN when
                        cfg.num_experts; vlm prepends stub patch embeddings)
  ssm                -> RWKV-6 stack (attention-free)
  hybrid             -> RecurrentGemma stack (RG-LRU + local attention)
  audio              -> Whisper encoder-decoder (stub frame embeddings)

Interface (shapes per the assignment's cells):

  loss(params, batch, dist, hot_ids)        — train_step objective
  prefill(params, batch, dist, cache_len)   — full-sequence, builds state
  decode_step(params, state, tokens, dist)  — serve_step: one new token
  init_state(batch, cache_len, abstract)    — decode-state pytree / SDS tree
  input_specs(shape)                        — ShapeDtypeStruct batch stand-ins

Every embedding/unembedding goes through ``repro.dist`` so vocab sharding
never all-gathers a table, and MoE layers emit the routing histograms the
Redynis placement daemon feeds on.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import Array

from repro.configs.base import ModelConfig, ShapeConfig
from repro.dist import DistSpec, embed_lookup, softmax_xent, unembed_logits
from repro.models import encdec, rglru, rwkv6
from repro.models import transformer as tfm
from repro.models.layers import apply_norm, norm_specs
from repro.models.params import (
    ParamSpec,
    abstract_params,
    count_params,
    embed_init,
    init_params,
)

__all__ = ["Model", "build"]


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self._specs = self._build_specs()

    # ------------------------------------------------------------- params
    def _build_specs(self) -> dict:
        cfg = self.cfg
        d, v = cfg.d_model, cfg.padded_vocab
        specs: dict[str, Any] = {
            "embed": ParamSpec((v, d), ("vocab", "embed_rep"), embed_init(0.02)),
            "ln_f": norm_specs(d, cfg.norm),
        }
        if not cfg.tie_embeddings:
            specs["head"] = ParamSpec((v, d), ("vocab", "embed_rep"), embed_init(0.02))
        fam = cfg.family
        if fam in ("dense", "moe", "vlm"):
            specs["blocks"] = tfm.stacked_block_specs(cfg)
        elif fam == "ssm":
            specs["blocks"] = rwkv6.rwkv_block_specs(cfg)
        elif fam == "hybrid":
            specs["blocks"] = rglru.rglru_block_specs(cfg)
        elif fam == "audio":
            specs["blocks"] = encdec.encdec_specs(cfg)
        else:
            raise ValueError(f"unknown family {fam!r}")
        return specs

    def param_specs(self) -> dict:
        return self._specs

    def init(self, rng: Array) -> dict:
        return init_params(self._specs, rng)

    def abstract_params(self) -> dict:
        return abstract_params(self._specs)

    def num_params(self) -> int:
        return count_params(self._specs)

    def active_params(self) -> int:
        """Parameters touched per token (MoE: shared + top_k of routed)."""
        cfg = self.cfg
        total = self.num_params()
        if not cfg.num_experts:
            return total
        expert = 3 * cfg.d_model * cfg.d_ff  # one routed expert's FFN
        routed_all = cfg.num_layers * cfg.num_experts * expert
        routed_active = cfg.num_layers * cfg.top_k * expert
        return total - routed_all + routed_active

    # ------------------------------------------------------------- embed
    def _head_table(self, params: dict) -> Array:
        return params["embed"] if self.cfg.tie_embeddings else params["head"]

    def embed_tokens(
        self,
        params: dict,
        tokens: Array,
        dist: Optional[DistSpec],
        hot_embed=None,  # HotEmbeddingState — Redynis hot-row cache
    ) -> Array:
        if hot_embed is not None and self.cfg.hot_embed_rows:
            from repro.core.hot_embedding import embed_with_cache

            h, _ = embed_with_cache(params["embed"], tokens, hot_embed, dist)
            h = h.astype(jnp.bfloat16)
        else:
            h = embed_lookup(params["embed"], tokens, dist).astype(jnp.bfloat16)
        if self.cfg.pos == "sinusoidal":
            s, d = tokens.shape[-1], self.cfg.d_model
            h = h + encdec.sinusoid(s, d).astype(h.dtype)[None]
        return h

    # ------------------------------------------------------------- train
    def loss(
        self,
        params: dict,
        batch: dict,
        dist: Optional[DistSpec] = None,
        hot_ids: Array | None = None,
        hot_embed=None,
    ) -> tuple[Array, dict]:
        """Mean next-token xent (+ MoE aux). Returns (loss, metrics)."""
        cfg = self.cfg
        tokens, targets = batch["tokens"], batch["targets"]
        h = self.embed_tokens(params, tokens, dist, hot_embed)
        moe_stats = None

        if cfg.family in ("dense", "moe"):
            h, _, moe_stats = tfm.run_decoder(
                params["blocks"], h, cfg, dist,
                mode="train", window=cfg.window, attn_chunk=cfg.attn_chunk,
                hot_ids=hot_ids,
            )
        elif cfg.family == "vlm":
            p = batch["patches"].astype(h.dtype)  # [B, P, D] stub frontend
            h = jnp.concatenate([p, h], axis=1)
            h, _, moe_stats = tfm.run_decoder(
                params["blocks"], h, cfg, dist,
                mode="train", window=cfg.window, attn_chunk=cfg.attn_chunk,
                hot_ids=hot_ids,
            )
            h = h[:, batch["patches"].shape[1] :]
        elif cfg.family == "ssm":
            h, _ = rwkv6.rwkv_forward(params["blocks"], h, cfg, dist)
        elif cfg.family == "hybrid":
            h, _ = rglru.rglru_forward(params["blocks"], h, cfg, dist)
        elif cfg.family == "audio":
            memory = encdec.encode(params["blocks"], batch["frames"].astype(h.dtype), cfg, dist)
            h, _, _ = encdec.decode_prefill(params["blocks"], h, memory, cfg, dist)
        else:
            raise ValueError(cfg.family)

        h = apply_norm(params["ln_f"], h, cfg.norm)
        mask = targets >= 0
        xent = softmax_xent(
            h,
            self._head_table(params),
            jnp.where(mask, targets, 0),
            dist,
            mask=mask,
            num_chunks=cfg.xent_chunks,
            vocab_size=cfg.vocab_size,
        )
        metrics: dict[str, Any] = {"xent": xent}
        loss = xent
        if moe_stats is not None:
            loss = loss + cfg.moe_aux_weight * moe_stats["aux"]
            metrics.update(
                moe_counts=moe_stats["counts"],
                moe_aux=moe_stats["aux"],
                moe_dropped=moe_stats["dropped"],
                moe_hot_frac=moe_stats["hot_frac"],
            )
        metrics["loss"] = loss
        return loss, metrics

    # ------------------------------------------------------------- serve
    def init_state(self, batch: int, cache_len: int, abstract: bool = False):
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            sds = tfm.init_cache_specs(cfg, batch, cache_len)
            if abstract:
                return sds
            return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), sds)
        if cfg.family == "ssm":
            return rwkv6.init_rwkv_state(cfg, batch, abstract)
        if cfg.family == "hybrid":
            return rglru.init_rglru_state(cfg, batch, abstract)
        if cfg.family == "audio":
            return encdec.init_encdec_state(cfg, batch, cache_len, abstract)
        raise ValueError(cfg.family)

    def prefill(
        self,
        params: dict,
        batch: dict,
        dist: Optional[DistSpec] = None,
        cache_len: int | None = None,
        hot_ids: Array | None = None,
    ):
        """Full-sequence pass building decode state. Returns (logits, state).

        ``cache_len`` pads the KV cache beyond the prompt for generation.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        cache_len = cache_len or s
        h = self.embed_tokens(params, tokens, dist)

        if cfg.family in ("dense", "moe", "vlm"):
            if cfg.family == "vlm":
                h = jnp.concatenate([batch["patches"].astype(h.dtype), h], axis=1)
            h, cache, _ = tfm.run_decoder(
                params["blocks"], h, cfg, dist,
                mode="prefill", window=cfg.window, attn_chunk=cfg.attn_chunk,
                hot_ids=hot_ids,
            )
            if cache_len > cache.k.shape[2]:
                pad = cache_len - cache.k.shape[2]
                padw = ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))
                cache = cache._replace(
                    k=jnp.pad(cache.k, padw), v=jnp.pad(cache.v, padw)
                )
            state = cache
        elif cfg.family == "ssm":
            h, state = rwkv6.rwkv_forward(params["blocks"], h, cfg, dist)
        elif cfg.family == "hybrid":
            h, state = rglru.rglru_forward(
                params["blocks"], h, cfg, dist, collect_cache=True
            )
        elif cfg.family == "audio":
            memory = encdec.encode(params["blocks"], batch["frames"].astype(h.dtype), cfg, dist)
            h, (sk, sv), (ck, cv) = encdec.decode_prefill(params["blocks"], h, memory, cfg, dist)
            if cache_len > s:
                pad = ((0, 0), (0, 0), (0, cache_len - s), (0, 0), (0, 0))
                sk, sv = jnp.pad(sk, pad), jnp.pad(sv, pad)
            state = encdec.EncDecState(
                self_k=sk, self_v=sv, cross_k=ck, cross_v=cv,
                length=jnp.full((b,), s, jnp.int32),
            )
        else:
            raise ValueError(cfg.family)

        h_last = apply_norm(params["ln_f"], h[:, -1:], cfg.norm)[:, 0]
        logits = unembed_logits(h_last, self._head_table(params), dist, self.cfg.vocab_size)
        return logits, state

    def decode_step(
        self,
        params: dict,
        state,
        tokens: Array,  # [B] int32 — the most recent token per sequence
        dist: Optional[DistSpec] = None,
        hot_ids: Array | None = None,
    ):
        """serve_step: one new token against the decode state."""
        cfg = self.cfg
        from repro.quant import dequant_leaf, is_quantized

        if any(is_quantized(params.get(k)) for k in ("embed", "head")):
            # top-level tables dequantize once (small when sharded); block
            # weights stay int8 and dequantize per layer inside the scan.
            params = {
                k: (dequant_leaf(v) if k != "blocks" and is_quantized(v) else v)
                for k, v in params.items()
            }
        h = embed_lookup(params["embed"], tokens[:, None], dist)[:, 0]
        h = h.astype(jnp.bfloat16)

        if cfg.family in ("dense", "moe", "vlm"):
            if cfg.pos == "sinusoidal":
                h = h + encdec.sinusoid_at(state.length, cfg.d_model).astype(h.dtype)
            h, state, _ = tfm.run_decode_step(
                params["blocks"], h, state, cfg, dist,
                window=cfg.window, hot_ids=hot_ids,
            )
        elif cfg.family == "ssm":
            h, state = rwkv6.rwkv_decode_step(params["blocks"], h, cfg, state, dist)
        elif cfg.family == "hybrid":
            h, state = rglru.rglru_decode_step(params["blocks"], h, cfg, state, dist)
        elif cfg.family == "audio":
            h = h + encdec.sinusoid_at(state.length, cfg.d_model).astype(h.dtype)
            h, state = encdec.encdec_decode_step(params["blocks"], h, state, cfg, dist)
        else:
            raise ValueError(cfg.family)

        h = apply_norm(params["ln_f"], h[:, None, :], cfg.norm)[:, 0]
        logits = unembed_logits(h, self._head_table(params), dist, cfg.vocab_size)
        return logits, state

    # ------------------------------------------------------------- shapes
    def input_specs(self, shape: ShapeConfig) -> dict:
        """ShapeDtypeStruct stand-ins for one batch of this cell."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        tok = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.int32)
        emb = lambda *sh: jax.ShapeDtypeStruct(sh, jnp.bfloat16)
        if shape.kind == "decode":
            return {"tokens": tok(b)}
        if cfg.family == "vlm":
            p = cfg.num_patches
            st = max(s - p, 1)
            out = {"tokens": tok(b, st), "patches": emb(b, p, cfg.d_model)}
        elif cfg.family == "audio":
            out = {
                "tokens": tok(b, s),
                "frames": emb(b, cfg.num_frames, cfg.d_model),
            }
        else:
            out = {"tokens": tok(b, s)}
        if shape.kind == "train":
            out["targets"] = jax.ShapeDtypeStruct(out["tokens"].shape, jnp.int32)
        return out

    def make_batch(self, shape: ShapeConfig, rng: Array) -> dict:
        """Materialise a synthetic batch matching input_specs (smoke tests)."""
        specs = self.input_specs(shape)
        out = {}
        for k, sds in specs.items():
            rng, sub = jax.random.split(rng)
            if sds.dtype == jnp.int32:
                out[k] = jax.random.randint(sub, sds.shape, 0, self.cfg.vocab_size)
            else:
                out[k] = jax.random.normal(sub, sds.shape, jnp.float32).astype(sds.dtype)
        return out


def build(cfg: ModelConfig) -> Model:
    return Model(cfg)
