"""Shared neural-net layers: norms, RoPE, MLPs (pure functions over params).

Conventions:
  * activations ``[B, S, D]`` bf16 (cfg.dtype); norm/softmax math in fp32.
  * every layer is ``f(params_subtree, x) -> y`` — no classes, no state.
  * ParamSpec builders (``*_specs``) sit next to the apply functions so the
    declaration and use of every parameter are adjacent.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

from repro.models.params import ParamSpec, dense_init, ones_init, zeros_init

__all__ = [
    "rmsnorm",
    "layernorm",
    "norm_specs",
    "apply_norm",
    "rope",
    "swiglu_specs",
    "swiglu",
    "gelu_mlp_specs",
    "gelu_mlp",
]


def rmsnorm(scale: Array, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(scale: Array, bias: Array, x: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm_specs(d: int, kind: str, prefix_axes: tuple = ()) -> dict:
    """``kind``: 'rmsnorm' | 'layernorm'. prefix_axes stacks (e.g. layers)."""
    shape = tuple(s for s, _ in prefix_axes) + (d,)
    axes = tuple(a for _, a in prefix_axes) + (None,)
    if kind == "rmsnorm":
        return {"scale": ParamSpec(shape, axes, ones_init, jnp.float32)}
    return {
        "scale": ParamSpec(shape, axes, ones_init, jnp.float32),
        "bias": ParamSpec(shape, axes, zeros_init, jnp.float32),
    }


def apply_norm(p: dict, x: Array, kind: str) -> Array:
    if kind == "rmsnorm":
        return rmsnorm(p["scale"], x)
    return layernorm(p["scale"], p["bias"], x)


def rope(x: Array, positions: Array, theta: float = 1e4) -> Array:
    """Rotary embedding. x ``[..., S, ..., D]`` with positions ``[S]`` or
    ``[B, S]`` broadcastable to x's sequence dim; x layout ``[B, S, H, D]``."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq  # [S, half] or [B,S,half]
    if ang.ndim == 2:  # [S, half] -> broadcast over batch and heads
        ang = ang[None, :, None, :]
    else:  # [B, S, half]
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs


def swiglu_specs(d_model: int, d_ff: int, prefix_axes: tuple = ()) -> dict:
    ps = tuple(s for s, _ in prefix_axes)
    pa = tuple(a for _, a in prefix_axes)
    return {
        "w_gate": ParamSpec(ps + (d_model, d_ff), pa + ("embed", "mlp"), dense_init(d_model)),
        "w_up": ParamSpec(ps + (d_model, d_ff), pa + ("embed", "mlp"), dense_init(d_model)),
        "w_down": ParamSpec(ps + (d_ff, d_model), pa + ("mlp", "embed"), dense_init(d_ff)),
    }


def swiglu(p: dict, x: Array) -> Array:
    gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    return jnp.einsum("bsf,fd->bsd", hidden, p["w_down"])


def gelu_mlp_specs(d_model: int, d_ff: int, prefix_axes: tuple = ()) -> dict:
    ps = tuple(s for s, _ in prefix_axes)
    pa = tuple(a for _, a in prefix_axes)
    return {
        "w_in": ParamSpec(ps + (d_model, d_ff), pa + ("embed", "mlp"), dense_init(d_model)),
        "b_in": ParamSpec(ps + (d_ff,), pa + ("mlp",), zeros_init),
        "w_out": ParamSpec(ps + (d_ff, d_model), pa + ("mlp", "embed"), dense_init(d_ff)),
        "b_out": ParamSpec(ps + (d_model,), pa + ("embed",), zeros_init),
    }


def gelu_mlp(p: dict, x: Array) -> Array:
    h = jnp.einsum("bsd,df->bsf", x, p["w_in"]) + p["b_in"].astype(x.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", h, p["w_out"]) + p["b_out"].astype(x.dtype)
