"""Attention: GQA projections + three interchangeable inner implementations.

  * ``dense``      — full masked scores; simplest, O(S^2) memory. Smoke tests
                     and short sequences.
  * ``blockwise``  — flash-style exact attention in pure JAX: outer unrolled
                     loop over query chunks (static slice bounds), inner scan
                     over key chunks with online softmax. O(chunk^2) memory,
                     and — unlike a masked dense pass — performs only the
                     ~S^2/2 causal FLOPs (the outer loop's kv range stops at
                     the diagonal; window attention stops at the window edge).
                     This is the XLA analogue of the Pallas flash kernel in
                     ``repro.kernels.flash_attention`` and serves as the
                     shape- compatible stand-in on the dry-run path (Mosaic
                     is TPU-only).
  * ``decode``     — one-token query against a (possibly sequence-sharded)
                     KV cache; masked by cache length.

GQA is computed grouped (``[B, S, KH, G, Dh]`` vs ``[B, T, KH, Dh]``) —
KV heads are never materialised ``G``-fold.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

NEG_INF = -1e30

__all__ = ["dense_attention", "blockwise_attention", "decode_attention"]


def _split_groups(q: Array, num_kv: int) -> Array:
    """[B, S, H, D] -> [B, S, KH, G, D]"""
    b, s, h, d = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, d)


def _merge_groups(x: Array) -> Array:
    """[B, S, KH, G, D] -> [B, S, H, D]"""
    b, s, kh, g, d = x.shape
    return x.reshape(b, s, kh * g, d)


def _mask(
    q_pos: Array, k_pos: Array, causal: bool, window: int, kv_len: int = 0
) -> Array:
    """[Sq, Sk] bool — True = attend. Causal / sliding-window / kv padding."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window:
        ok &= q_pos[:, None] - k_pos[None, :] < window
    if kv_len:
        ok &= k_pos[None, :] < kv_len
    return ok


def dense_attention(
    q: Array,  # [B, Sq, H, D]
    k: Array,  # [B, Sk, KH, D]
    v: Array,  # [B, Sk, KH, D]
    *,
    causal: bool = True,
    window: int = 0,
    q_offset: int = 0,
) -> Array:
    """Full masked attention (fp32 softmax). q_offset: q's global position of
    index 0 relative to k (cross-attention uses causal=False, offset=0)."""
    kh = k.shape[2]
    qg = _split_groups(q, kh)
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k, preferred_element_type=jnp.float32)
    q_pos = jnp.arange(q.shape[1]) + q_offset
    k_pos = jnp.arange(k.shape[1])
    m = _mask(q_pos, k_pos, causal, window)
    s = jnp.where(m[None, None, None], s * scale, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)
    return _merge_groups(out)


def _block(qg, kc, vc, q_pos, k_pos, carry, causal, window, scale, kv_len=0):
    """One (q-chunk, k-chunk) online-softmax step.

    qg [B, C, KH, G, D]; kc/vc [B, C, KH, D]; carry = (acc, m, l)."""
    acc, m, l = carry
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, kc, preferred_element_type=jnp.float32)
    s = s * scale
    ok = _mask(q_pos, k_pos, causal, window, kv_len)
    s = jnp.where(ok[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, jnp.max(s, axis=-1))
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[..., None])
    l = l * alpha + jnp.sum(p, axis=-1)
    pv = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(vc.dtype), vc).astype(jnp.float32)
    acc = acc * alpha[..., None] + pv
    return acc, m_new, l


def blockwise_attention(
    q: Array,  # [B, S, H, D]
    k: Array,  # [B, T, KH, D]
    v: Array,
    *,
    causal: bool = True,
    window: int = 0,
    chunk: int = 1024,
    q_offset: int = 0,
) -> Array:
    """Exact flash-style attention; see module docstring. ``chunk`` must
    divide the query length; the kv length is padded up internally and the
    padding masked (cross-attention memories are rarely chunk-aligned)."""
    b, sq, h, d = q.shape
    t = k.shape[1]
    kh = k.shape[2]
    chunk = min(chunk, sq, t)
    q_pad = (-sq) % chunk
    if q_pad:  # encoder memories (e.g. 1500 frames) are rarely aligned
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
        sq_padded = sq + q_pad
    else:
        sq_padded = sq
    kv_len = 0
    if t % chunk:
        kv_len = t  # real length, for masking
        pad = chunk - t % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        t = t + pad
    nq, nk = sq_padded // chunk, t // chunk
    sq = sq_padded
    scale = d**-0.5
    g = h // kh

    out_chunks = []
    for i in range(nq):
        q_lo = i * chunk
        q_pos = jnp.arange(chunk) + q_lo + q_offset
        qg = _split_groups(q[:, q_lo : q_lo + chunk], kh)
        # Static kv chunk range: stop at the causal diagonal, start at the
        # window edge — skipped chunks cost zero FLOPs.
        hi = nk if not causal else min(nk, (q_lo + q_offset + chunk + chunk - 1) // chunk)
        lo = 0 if not window else max(0, (q_lo + q_offset - window + 1) // chunk)
        acc = jnp.zeros((b, kh, g, chunk, d), jnp.float32)
        m = jnp.full((b, kh, g, chunk), NEG_INF, jnp.float32)
        l = jnp.zeros((b, kh, g, chunk), jnp.float32)
        n_blocks = hi - lo
        if n_blocks > 1:
            # All-but-diagonal chunks via scan (bounded HLO size).
            ks = k[:, lo * chunk : (hi - 1) * chunk].reshape(b, n_blocks - 1, chunk, kh, d)
            vs = v[:, lo * chunk : (hi - 1) * chunk].reshape(b, n_blocks - 1, chunk, kh, d)
            idx = jnp.arange(lo, hi - 1)

            def body(carry, xs):
                kc, vc, j = xs
                k_pos = jnp.arange(chunk) + j * chunk
                return (
                    _block(
                        qg, kc, vc, q_pos, k_pos, carry, causal, window, scale, kv_len
                    ),
                    None,
                )

            (acc, m, l), _ = jax.lax.scan(
                body,
                (acc, m, l),
                (ks.swapaxes(0, 1), vs.swapaxes(0, 1), idx),
            )
        # Diagonal (or final) chunk — masked.
        jlast = hi - 1
        k_pos = jnp.arange(chunk) + jlast * chunk
        kc = k[:, jlast * chunk : (jlast + 1) * chunk]
        vc = v[:, jlast * chunk : (jlast + 1) * chunk]
        acc, m, l = _block(
            qg, kc, vc, q_pos, k_pos, (acc, m, l), causal, window, scale, kv_len
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        out_chunks.append(
            _merge_groups(out.transpose(0, 3, 1, 2, 4)).astype(q.dtype)
        )  # [B, C, H, D]
    result = jnp.concatenate(out_chunks, axis=1)
    return result[:, : sq - q_pad] if q_pad else result


def decode_attention(
    q: Array,  # [B, H, D] — one new token per sequence
    k_cache: Array,  # [B, T, KH, D]
    v_cache: Array,  # [B, T, KH, D]
    length: Array,  # [B] int32 — valid cache entries (including new token)
) -> Array:
    """Single-position attention over a KV cache, masked to ``length``.

    Pure jnp — with the cache sequence-sharded over the model axis, XLA's
    SPMD partitioner turns the masked softmax + contraction into partial
    reductions combined with small all-reduces (see launch/sharding.py)."""
    kh = k_cache.shape[2]
    b, h, d = q.shape
    qg = q.reshape(b, kh, h // kh, d)
    s = jnp.einsum(
        "bkgd,btkd->bkgt", qg, k_cache, preferred_element_type=jnp.float32
    ) * (d**-0.5)
    t = k_cache.shape[1]
    valid = jnp.arange(t)[None] < length[:, None]  # [B, T]
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p.astype(v_cache.dtype), v_cache)
    return out.reshape(b, h, d)
