"""RecurrentGemma blocks (arXiv:2402.19427): RG-LRU recurrence + local
attention in a 1:2 pattern (every ``attention_period``-th layer attends over
a sliding window; the rest are gated linear recurrences).

Recurrent block: x -> RMSNorm -> {linear->conv1d(4)->RG-LRU} ⊙ gelu(linear)
-> linear -> residual. RG-LRU (paper Eq. 5-7)::

    r_t = sigmoid(W_a y_t + b_a)          (recurrence gate, block-diagonal W)
    i_t = sigmoid(W_x y_t + b_x)          (input gate)
    log a_t = -c * softplus(Λ) * r_t      (c = 8)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ y_t)

Training/prefill evaluates the recurrence with an associative scan (prefix
affine composition) — O(log S) depth, fully parallel; decode is the literal
one-step update. Combined with the 2048-token attention window this is a
sub-quadratic architecture, hence it runs the ``long_500k`` cell.

The layer pattern is heterogeneous, so this stack is unrolled (26 layers)
rather than scanned — bounded HLO, and each layer body is rematerialised
under ``cfg.remat``.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist import DistSpec
from repro.models.layers import apply_norm, norm_specs
from repro.models.params import ParamSpec, dense_init, ones_init, zeros_init
from repro.models import transformer as tfm

__all__ = [
    "layer_kinds",
    "rglru_block_specs",
    "RGLRUState",
    "init_rglru_state",
    "rglru_forward",
    "rglru_decode_step",
]

CONV_WIDTH = 4
LRU_C = 8.0


def layer_kinds(cfg) -> list[str]:
    """['rec', 'rec', 'attn', ...] — every period-th layer attends."""
    p = cfg.attention_period
    return [
        "attn" if p and (i % p == p - 1) else "rec" for i in range(cfg.num_layers)
    ]


class RGLRUState(NamedTuple):
    """Decode-time state. Lists indexed by rec/attn layer ordinal."""

    conv: list  # per rec layer [B, CONV_WIDTH-1, W]
    h: list  # per rec layer [B, W] fp32
    caches: list  # per attn layer (k, v) ring buffers [B, window, KH, Dh]
    length: Array  # [B] int32 tokens generated so far


def init_rglru_state(cfg, batch: int, abstract: bool = False):
    w = cfg.lru_width or cfg.d_model
    kinds = layer_kinds(cfg)
    window = cfg.window or 2048
    kh, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    mk = (
        (lambda s, d: jax.ShapeDtypeStruct(s, d))
        if abstract
        else (lambda s, d: jnp.zeros(s, d))
    )
    return RGLRUState(
        conv=[mk((batch, CONV_WIDTH - 1, w), jnp.bfloat16) for k in kinds if k == "rec"],
        h=[mk((batch, w), jnp.float32) for k in kinds if k == "rec"],
        caches=[
            (mk((batch, window, kh, dh), jnp.bfloat16), mk((batch, window, kh, dh), jnp.bfloat16))
            for k in kinds
            if k == "attn"
        ],
        length=mk((batch,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Parameter declarations (per layer — the stack is a list, not stacked arrays)


def _rec_specs(cfg) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    nb = cfg.num_heads  # block-diagonal gate blocks
    bs = w // nb
    return {
        "ln": norm_specs(d, cfg.norm),
        "w_in": ParamSpec((d, w), ("embed", "state"), dense_init(d)),
        "w_gate_in": ParamSpec((d, w), ("embed", "state"), dense_init(d)),
        "conv_w": ParamSpec((CONV_WIDTH, w), (None, "state"), dense_init(CONV_WIDTH)),
        "conv_b": ParamSpec((w,), ("state",), zeros_init),
        "gate_a": ParamSpec((nb, bs, bs), (None, None, None), dense_init(bs)),
        "gate_a_b": ParamSpec((w,), ("state",), zeros_init),
        "gate_x": ParamSpec((nb, bs, bs), (None, None, None), dense_init(bs)),
        "gate_x_b": ParamSpec((w,), ("state",), zeros_init),
        "lam": ParamSpec((w,), ("state",), ones_init, jnp.float32),
        "w_out": ParamSpec((w, d), ("state", "embed"), dense_init(w)),
    }


def _mlp_specs(cfg) -> dict:
    # RecurrentGemma uses a GeGLU MLP — same shapes as swiglu, gelu gate.
    from repro.models.layers import swiglu_specs

    return {"ln": norm_specs(cfg.d_model, cfg.norm), **swiglu_specs(cfg.d_model, cfg.d_ff)}


def rglru_block_specs(cfg) -> dict:
    kinds = layer_kinds(cfg)
    return {
        "rec": [_rec_specs(cfg) for k in kinds if k == "rec"],
        "attn": [tfm.attn_specs(cfg, ()) for k in kinds if k == "attn"],
        "mlp": [_mlp_specs(cfg) for _ in kinds],
    }


# ---------------------------------------------------------------------------
# RG-LRU core


def _block_diag_gate(w: Array, b: Array, y: Array) -> Array:
    """Block-diagonal linear + sigmoid: y [..., W] -> [..., W]."""
    nb, bs, _ = w.shape
    yb = y.reshape(*y.shape[:-1], nb, bs)
    out = jnp.einsum("...nb,nbc->...nc", yb, w.astype(y.dtype))
    return jax.nn.sigmoid(
        out.reshape(*y.shape).astype(jnp.float32) + b.astype(jnp.float32)
    )


def _lru_coeffs(p: dict, y: Array) -> tuple[Array, Array]:
    """Per-token decay a_t and input b_t (both fp32 [B, S, W])."""
    r = _block_diag_gate(p["gate_a"], p["gate_a_b"], y)
    i = _block_diag_gate(p["gate_x"], p["gate_x_b"], y)
    log_a = -LRU_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * y.astype(jnp.float32))
    return a, b


def _causal_conv(p: dict, y: Array, carry: Array | None) -> tuple[Array, Array]:
    """Depthwise causal conv, width 4. carry: [B, 3, W] previous inputs."""
    b, s, w = y.shape
    if carry is None:
        carry = jnp.zeros((b, CONV_WIDTH - 1, w), y.dtype)
    ext = jnp.concatenate([carry.astype(y.dtype), y], axis=1)  # [B, S+3, W]
    out = sum(
        ext[:, i : i + s] * p["conv_w"][i].astype(y.dtype)
        for i in range(CONV_WIDTH)
    )
    return out + p["conv_b"].astype(y.dtype), ext[:, -(CONV_WIDTH - 1) :]


def rec_block(
    p: dict,
    x: Array,  # [B, S, D]
    cfg,
    conv_carry: Array | None = None,
    h0: Array | None = None,
) -> tuple[Array, Array, Array]:
    """Recurrent block over a full sequence. Returns (y, conv_carry', h_last)."""
    xn = apply_norm(p["ln"], x, cfg.norm)
    y = jnp.einsum("bsd,dw->bsw", xn, p["w_in"])
    gate = jax.nn.gelu(
        jnp.einsum("bsd,dw->bsw", xn, p["w_gate_in"]).astype(jnp.float32)
    )
    y, conv_carry = _causal_conv(p, y, conv_carry)
    a, bb = _lru_coeffs(p, y)

    # Prefix affine composition: h_t = A_t h0 + B_t.
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    va, vb = jax.lax.associative_scan(combine, (a, bb), axis=1)
    if h0 is None:
        h = vb
    else:
        h = va * h0[:, None].astype(jnp.float32) + vb
    out = h * gate
    y_out = jnp.einsum("bsw,wd->bsd", out.astype(x.dtype), p["w_out"])
    return x + y_out, conv_carry, h[:, -1]


def rec_block_step(
    p: dict, x: Array, cfg, conv_carry: Array, h0: Array
) -> tuple[Array, Array, Array]:
    """One decode step of the recurrent block. x [B, D]."""
    y, conv_carry, h = rec_block(p, x[:, None, :], cfg, conv_carry, h0)
    return y[:, 0], conv_carry, h


def mlp_block(p: dict, x: Array, cfg) -> Array:
    """GeGLU MLP with pre-norm."""
    xn = apply_norm(p["ln"], x, cfg.norm)
    g = jnp.einsum("bsd,df->bsf", xn, p["w_gate"])
    u = jnp.einsum("bsd,df->bsf", xn, p["w_up"])
    h = jax.nn.gelu(g.astype(jnp.float32)).astype(x.dtype) * u
    return x + jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ---------------------------------------------------------------------------
# Stack execution (unrolled heterogeneous pattern)


def rglru_forward(
    blocks: dict,
    h: Array,
    cfg,
    dist: Optional[DistSpec] = None,
    state: RGLRUState | None = None,
    collect_cache: bool = False,
) -> tuple[Array, Optional[RGLRUState]]:
    """Full-sequence forward. With ``collect_cache`` builds the decode state
    (ring-buffer window caches + final recurrent states)."""
    b, s, _ = h.shape
    kinds = layer_kinds(cfg)
    window = cfg.window or 2048
    ri = ai = 0
    conv_out, h_out, cache_out = [], [], []
    positions = jnp.arange(s)

    for li, kind in enumerate(kinds):
        if kind == "rec":
            p = blocks["rec"][ri]
            conv0 = state.conv[ri] if state else None
            h0 = state.h[ri] if state else None
            fn = jax.checkpoint(rec_block, static_argnums=(2,)) if cfg.remat == "full" else rec_block
            h_seq, conv1, hl = fn(p, h, cfg, conv0, h0)
            h = h_seq
            if collect_cache:
                conv_out.append(conv1)
                h_out.append(hl)
            ri += 1
        else:
            p = blocks["attn"][ai]
            fn = (
                jax.checkpoint(tfm.attn_full, static_argnums=(2, 3, 5, 6))
                if cfg.remat == "full"
                else tfm.attn_full
            )
            h, (k, v) = fn(p, h, cfg, dist, positions, window, cfg.attn_chunk)
            if collect_cache:
                # Last ``window`` tokens into the ring buffer, slot = pos % window.
                take = min(window, s)
                pos = positions[-take:]
                slots = pos % window
                kc = jnp.zeros((b, window, *k.shape[2:]), k.dtype).at[:, slots].set(k[:, -take:])
                vc = jnp.zeros((b, window, *v.shape[2:]), v.dtype).at[:, slots].set(v[:, -take:])
                cache_out.append((kc, vc))
            ai += 1
        h = mlp_block(blocks["mlp"][li], h, cfg)

    new_state = None
    if collect_cache:
        new_state = RGLRUState(
            conv=conv_out, h=h_out, caches=cache_out, length=jnp.full((b,), s, jnp.int32)
        )
    return h, new_state


def rglru_decode_step(
    blocks: dict,
    x: Array,  # [B, D]
    cfg,
    state: RGLRUState,
    dist: Optional[DistSpec] = None,
) -> tuple[Array, RGLRUState]:
    kinds = layer_kinds(cfg)
    window = cfg.window or 2048
    ri = ai = 0
    conv_out, h_out, cache_out = [], [], []
    for li, kind in enumerate(kinds):
        if kind == "rec":
            p = blocks["rec"][ri]
            x, conv1, h1 = rec_block_step(p, x, cfg, state.conv[ri], state.h[ri])
            conv_out.append(conv1)
            h_out.append(h1)
            ri += 1
        else:
            p = blocks["attn"][ai]
            kc, vc = state.caches[ai]
            x, (kc, vc) = tfm.attn_decode(
                p, x, kc, vc, state.length, cfg, dist, window=window
            )
            cache_out.append((kc, vc))
            ai += 1
        x = mlp_block(blocks["mlp"][li], x[:, None, :], cfg)[:, 0]
    return x, RGLRUState(
        conv=conv_out, h=h_out, caches=cache_out, length=state.length + 1
    )
