"""Decoder-only transformer blocks (dense + MoE FFN), GQA, three run modes.

The block stack is declared once (``stacked_block_specs`` — all parameters
carry a leading ``layers`` dim) and executed with ``jax.lax.scan`` so HLO
size and compile time stay bounded at 88 layers × 512 devices. Modes:

  * train/prefill — full-sequence blockwise (flash-style) attention; prefill
    additionally returns the per-layer KV cache.
  * decode        — one new token per sequence against a KV cache
                    (cache layout ``[L, B, T, KH, Dh]``, sequence dim
                    shardable over the model axis).

Redynis hook: when ``cfg.num_experts > 0`` the FFN is the MoE layer from
``repro.models.moe``, which emits per-(expert, data-group) routing counts —
the traffic statistics the placement daemon consumes.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist import DistSpec, constrain
from repro.models import moe as moe_lib
from repro.models.attention import blockwise_attention, decode_attention
from repro.models.layers import (
    apply_norm,
    norm_specs,
    rope,
    swiglu,
    swiglu_specs,
    gelu_mlp,
    gelu_mlp_specs,
)
from repro.models.params import ParamSpec, dense_init, ones_init

__all__ = ["KVCache", "init_cache_specs", "stacked_block_specs", "run_decoder"]

LAYERS = ("layers",)


class KVCache(NamedTuple):
    """Per-layer KV cache. ``k``/``v``: [L, B, T, KH, Dh]; length: [B]."""

    k: Array
    v: Array
    length: Array  # [B] int32 — valid entries per sequence

    @property
    def max_len(self) -> int:
        return self.k.shape[2]


def init_cache_specs(
    cfg, batch: int, cache_len: int, layers: int | None = None
) -> KVCache:
    """ShapeDtypeStruct cache (dry-run) — materialise with jnp.zeros_like."""
    kh, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    l = cfg.num_layers if layers is None else layers
    shape = (l, batch, cache_len, kh, dh)
    dt = jnp.bfloat16
    return KVCache(
        k=jax.ShapeDtypeStruct(shape, dt),
        v=jax.ShapeDtypeStruct(shape, dt),
        length=jax.ShapeDtypeStruct((batch,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Parameter declarations


def attn_specs(cfg, prefix: tuple) -> dict:
    d, h, kh, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ps = tuple(s for s, _ in prefix)
    pa = tuple(a for _, a in prefix)
    specs = {
        "ln": norm_specs(d, cfg.norm, prefix),
        "wq": ParamSpec(ps + (d, h, dh), pa + ("embed", "heads", "head_dim"), dense_init(d)),
        "wk": ParamSpec(ps + (d, kh, dh), pa + ("embed", "kv_heads", "head_dim"), dense_init(d)),
        "wv": ParamSpec(ps + (d, kh, dh), pa + ("embed", "kv_heads", "head_dim"), dense_init(d)),
        "wo": ParamSpec(ps + (h, dh, d), pa + ("heads", "head_dim", "embed"), dense_init(h * dh)),
    }
    if cfg.qk_norm:
        specs["q_norm"] = ParamSpec(ps + (dh,), pa + (None,), ones_init, jnp.float32)
        specs["k_norm"] = ParamSpec(ps + (dh,), pa + (None,), ones_init, jnp.float32)
    return specs


def mlp_specs(cfg, prefix: tuple) -> dict:
    specs = {"ln": norm_specs(cfg.d_model, cfg.norm, prefix)}
    if cfg.num_experts:
        specs.update(moe_lib.moe_specs(cfg, prefix))
    elif cfg.act == "gelu":
        specs.update(gelu_mlp_specs(cfg.d_model, cfg.d_ff, prefix))
    else:
        specs.update(swiglu_specs(cfg.d_model, cfg.d_ff, prefix))
    return specs


def stacked_block_specs(cfg, layers: int | None = None) -> dict:
    l = cfg.num_layers if layers is None else layers
    prefix = ((l, "layers"),)
    return {"attn": attn_specs(cfg, prefix), "mlp": mlp_specs(cfg, prefix)}


# ---------------------------------------------------------------------------
# Attention block application


def _rmsnorm_head(scale: Array, x: Array, eps: float = 1e-6) -> Array:
    """qwen3-style per-head q/k RMSNorm over head_dim."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def _project_qkv(p: dict, xn: Array, cfg) -> tuple[Array, Array, Array]:
    q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", xn, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", xn, p["wv"])
    if cfg.qk_norm:
        q = _rmsnorm_head(p["q_norm"], q)
        k = _rmsnorm_head(p["k_norm"], k)
    return q, k, v


def attn_full(
    p: dict,
    x: Array,  # [B, S, D]
    cfg,
    dist: Optional[DistSpec],
    positions: Array,  # [S]
    window: int = 0,
    chunk: int = 1024,
    causal: bool = True,
) -> tuple[Array, tuple[Array, Array]]:
    """Full-sequence attention (train / prefill). Returns (y, (k, v))."""
    xn = apply_norm(p["ln"], x, cfg.norm)
    q, k, v = _project_qkv(p, xn, cfg)
    if cfg.pos == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    tp = dist.model_axis if (dist and dist.tensor_parallel) else None
    q = constrain(q, dist, dist.batch if dist else None, None, tp, None)
    o = blockwise_attention(q, k, v, causal=causal, window=window, chunk=chunk)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return x + y, (k, v)


def cross_attn(
    p: dict,
    x: Array,  # [B, S, D] decoder side
    memory_kv: tuple[Array, Array],  # precomputed (k, v) [B, F, KH, Dh]
    cfg,
    dist: Optional[DistSpec],
) -> Array:
    """Encoder-decoder cross attention against precomputed memory K/V."""
    xn = apply_norm(p["ln"], x, cfg.norm)
    q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"])
    k, v = memory_kv
    o = blockwise_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return x + y


def cross_attn_kv(p: dict, memory: Array, cfg) -> tuple[Array, Array]:
    """Project encoder output once into cross-attention K/V."""
    k = jnp.einsum("bfd,dhk->bfhk", memory, p["wk"])
    v = jnp.einsum("bfd,dhk->bfhk", memory, p["wv"])
    return k, v


def attn_decode(
    p: dict,
    x: Array,  # [B, D] — one token per sequence
    k_cache: Array,  # [B, T, KH, Dh]
    v_cache: Array,
    length: Array,  # [B] — cache entries BEFORE this token
    cfg,
    dist: Optional[DistSpec],
    window: int = 0,
) -> tuple[Array, tuple[Array, Array]]:
    """One decode step. Returns (y, (k_cache', v_cache'))."""
    b = x.shape[0]
    xn = apply_norm(p["ln"], x[:, None, :], cfg.norm)
    q, k, v = _project_qkv(p, xn, cfg)
    pos = length.astype(jnp.int32)  # new token's position, per sequence
    if cfg.pos == "rope":
        q = rope(q, pos[:, None], cfg.rope_theta)
        k = rope(k, pos[:, None], cfg.rope_theta)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]
    t = k_cache.shape[1]
    slot = jnp.where(window > 0, pos % t, pos) if window else pos
    bi = jnp.arange(b)
    k_cache = k_cache.at[bi, slot].set(k.astype(k_cache.dtype))
    v_cache = v_cache.at[bi, slot].set(v.astype(v_cache.dtype))
    valid = jnp.minimum(length + 1, t) if window else length + 1
    o = decode_attention(q, k_cache, v_cache, valid)
    y = jnp.einsum("bhk,hkd->bd", o, p["wo"])
    return x + y, (k_cache, v_cache)


def mlp_apply(
    p: dict,
    x: Array,
    cfg,
    dist: Optional[DistSpec],
    hot_ids: Array | None = None,
) -> tuple[Array, dict | None]:
    """Pre-norm FFN (dense or MoE). Returns (y, moe_stats|None)."""
    xn = apply_norm(p["ln"], x, cfg.norm)
    stats = None
    if cfg.num_experts:
        y, stats = moe_lib.moe_apply(p, xn, cfg, dist, hot_ids)
    elif cfg.act == "gelu":
        y = gelu_mlp(p, xn)
    else:
        y = swiglu(p, xn)
    return x + y, stats


# ---------------------------------------------------------------------------
# Layer-stack execution


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat == "full" else fn


def _reduce_layer_stats(stats: dict | None) -> dict | None:
    """Aggregate per-layer MoE stats stacked [L, ...] by the scan.

    Routing counts keep their layer resolution — the paper's key universe is
    (layer, expert): each layer's hot set is decided independently.
    """
    if stats is None:
        return None
    return {
        "counts": stats["counts"],  # [L, G, E]
        "aux": jnp.mean(stats["aux"]),
        "dropped": jnp.mean(stats["dropped"]),
        "hot_frac": jnp.mean(stats["hot_frac"]),
    }


def run_decoder(
    blocks: dict,
    h: Array,  # [B, S, D] embedded inputs
    cfg,
    dist: Optional[DistSpec] = None,
    *,
    mode: str = "train",  # train | prefill
    positions: Array | None = None,
    window: int = 0,
    attn_chunk: int = 1024,
    hot_ids: Array | None = None,  # [L, R] per-layer replica sets
) -> tuple[Array, Optional[KVCache], Optional[dict]]:
    """Scan the stacked blocks over ``h``.

    Returns (hidden, cache|None, moe_stats|None). ``moe_stats['counts']`` is
    the [G, E] routing histogram summed over layers — the Redynis traffic
    feed for the placement daemon.
    """
    b, s, d = h.shape
    if positions is None:
        positions = jnp.arange(s)

    collect_cache = mode == "prefill"
    has_moe = bool(cfg.num_experts)
    xs = (blocks, hot_ids) if hot_ids is not None else (blocks,)

    def body(carry, xs_slice):
        x = carry
        layer_params = xs_slice[0]
        hids = xs_slice[1] if len(xs_slice) > 1 else None
        x, (k, v) = attn_full(
            layer_params["attn"], x, cfg, dist, positions, window, attn_chunk
        )
        x, stats = mlp_apply(layer_params["mlp"], x, cfg, dist, hids)
        x = constrain(x, dist, dist.batch if dist else None, None, None)
        if collect_cache and dist is not None and dist.mesh is not None:
            # Cache layout for decode: batch over data, kv-heads over model
            # when they divide (MHA), else sequence over model — without
            # this the stacked prefill cache replicates T per chip.
            m = dist.model_size
            kh = k.shape[2]
            bs = dist.batch if k.shape[0] % max(dist.batch_size, 1) == 0 else None
            if kh % m == 0:
                spec = (bs, None, dist.model_axis, None)
            else:
                spec = (bs, dist.model_axis if k.shape[1] % m == 0 else None, None, None)
            k = constrain(k, dist, *spec)
            v = constrain(v, dist, *spec)
        ys = (
            (k, v) if collect_cache else None,
            stats if has_moe else None,
        )
        return x, ys

    body = _maybe_remat(body, cfg)
    h, ys = jax.lax.scan(body, h, xs)
    kv, stats = ys

    cache = None
    if collect_cache:
        k, v = kv  # [L, B, S, KH, Dh]
        cache = KVCache(k=k, v=v, length=jnp.full((b,), s, jnp.int32))
    return h, cache, _reduce_layer_stats(stats if has_moe else None)


def run_decode_step(
    blocks: dict,
    x: Array,  # [B, D] — embedded new token
    cache: KVCache,
    cfg,
    dist: Optional[DistSpec] = None,
    *,
    window: int = 0,
    hot_ids: Array | None = None,  # [L, R]
) -> tuple[Array, KVCache, Optional[dict]]:
    """One token through all layers.

    The full [L, B, T, KH, Dh] cache travels in the scan CARRY and each
    layer scatters exactly one [B, KH, Dh] row into it — with donated
    buffers this is a true in-place update (per-step HBM write = one row
    per layer, not a layer slice; the unavoidable read is the attention
    pass over the layer's cache slice)."""
    has_moe = bool(cfg.num_experts)
    b = x.shape[0]
    t = cache.max_len
    length = cache.length
    pos = length.astype(jnp.int32)
    slot = jnp.where(window > 0, pos % t, pos) if window else pos
    valid = jnp.minimum(length + 1, t) if window else length + 1
    bi = jnp.arange(b)
    layer_idx = jnp.arange(cfg.num_layers)
    xs = (blocks, layer_idx, hot_ids) if hot_ids is not None else (blocks, layer_idx)

    def body(carry, xs_slice):
        x, k_all, v_all = carry
        layer_params, li = xs_slice[:2]
        hids = xs_slice[2] if len(xs_slice) > 2 else None
        # int8-served weights dequantize per layer inside the scan, so only
        # one layer's bf16 copy is ever live (see repro/quant.py).
        from repro.quant import dequant_tree

        layer_params = dequant_tree(layer_params)
        p = layer_params["attn"]
        xn = apply_norm(p["ln"], x[:, None, :], cfg.norm)
        q, k, v = _project_qkv(p, xn, cfg)
        if cfg.pos == "rope":
            q = rope(q, pos[:, None], cfg.rope_theta)
            k = rope(k, pos[:, None], cfg.rope_theta)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
        k_all = k_all.at[li, bi, slot].set(k.astype(k_all.dtype))
        v_all = v_all.at[li, bi, slot].set(v.astype(v_all.dtype))
        kc = jax.lax.dynamic_index_in_dim(k_all, li, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_all, li, 0, keepdims=False)
        o = decode_attention(q, kc, vc, valid)
        x = x + jnp.einsum("bhk,hkd->bd", o, p["wo"])
        y, stats = mlp_apply(layer_params["mlp"], x[:, None, :], cfg, dist, hids)
        return (y[:, 0], k_all, v_all), (stats if has_moe else None)

    (x, k, v), stats = jax.lax.scan(body, (x, cache.k, cache.v), xs)
    new_cache = KVCache(k=k, v=v, length=cache.length + 1)
    return x, new_cache, _reduce_layer_stats(stats if has_moe else None)
