"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, F, D] (F = 1500 for 30 s of
audio). Encoder: bidirectional self-attention + GELU MLP; decoder: causal
self-attention + cross-attention over the encoder memory + GELU MLP; both
pre-LayerNorm, sinusoidal positions (parameter-free — the real model's
learned table is a deviation noted in DESIGN.md).

Decode state = decoder self-attention KV cache + the cross-attention K/V
projected once from the encoder memory at prefill.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist import DistSpec
from repro.models.layers import apply_norm, norm_specs
from repro.models import transformer as tfm

__all__ = [
    "EncDecState",
    "encdec_specs",
    "init_encdec_state",
    "sinusoid",
    "encode",
    "decode_prefill",
    "encdec_decode_step",
]


class EncDecState(NamedTuple):
    self_k: Array  # [Ld, B, T, KH, Dh]
    self_v: Array
    cross_k: Array  # [Ld, B, F, KH, Dh]
    cross_v: Array
    length: Array  # [B]


def init_encdec_state(cfg, batch: int, cache_len: int, abstract: bool = False):
    kh, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    l, f = cfg.num_layers, cfg.num_frames
    mk = (
        (lambda s, d: jax.ShapeDtypeStruct(s, d))
        if abstract
        else (lambda s, d: jnp.zeros(s, d))
    )
    return EncDecState(
        self_k=mk((l, batch, cache_len, kh, dh), jnp.bfloat16),
        self_v=mk((l, batch, cache_len, kh, dh), jnp.bfloat16),
        cross_k=mk((l, batch, f, kh, dh), jnp.bfloat16),
        cross_v=mk((l, batch, f, kh, dh), jnp.bfloat16),
        length=mk((batch,), jnp.int32),
    )


def encdec_specs(cfg) -> dict:
    enc_prefix = ((cfg.encoder_layers, "layers"),)
    dec_prefix = ((cfg.num_layers, "layers"),)
    return {
        "encoder": {
            "attn": tfm.attn_specs(cfg, enc_prefix),
            "mlp": tfm.mlp_specs(cfg, enc_prefix),
            "ln_post": norm_specs(cfg.d_model, cfg.norm),
        },
        "decoder": {
            "attn": tfm.attn_specs(cfg, dec_prefix),
            "cross": tfm.attn_specs(cfg, dec_prefix),
            "mlp": tfm.mlp_specs(cfg, dec_prefix),
        },
    }


def sinusoid(length: int, d: int) -> Array:
    """Parameter-free sinusoidal position table [length, d] (fp32)."""
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / max(d // 2 - 1, 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def sinusoid_at(positions: Array, d: int) -> Array:
    """Sinusoidal embedding at dynamic positions [B] -> [B, d]."""
    pos = positions.astype(jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-dim * (jnp.log(10000.0) / max(d // 2 - 1, 1)))
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def encode(params: dict, frames: Array, cfg, dist: Optional[DistSpec] = None) -> Array:
    """frames [B, F, D] (stub embeddings) -> encoder memory [B, F, D]."""
    b, f, d = frames.shape
    h = frames + sinusoid(f, d).astype(frames.dtype)[None]
    positions = jnp.arange(f)
    enc = params["encoder"]

    def body(carry, layer):
        x = carry
        x, _ = tfm.attn_full(
            layer["attn"], x, cfg, dist, positions, 0, cfg.attn_chunk, causal=False
        )
        x, _ = tfm.mlp_apply(layer["mlp"], x, cfg, dist)
        return x, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, {"attn": enc["attn"], "mlp": enc["mlp"]})
    return apply_norm(enc["ln_post"], h, cfg.norm)


def decode_prefill(
    params: dict,
    tokens_embedded: Array,  # [B, S, D] (+ positions already added)
    memory: Array,  # [B, F, D] encoder output
    cfg,
    dist: Optional[DistSpec] = None,
) -> tuple[Array, tuple[Array, Array], tuple[Array, Array]]:
    """Full decoder pass. Returns (hidden, (self_k, self_v), (cross_k, cross_v))."""
    b, s, d = tokens_embedded.shape
    positions = jnp.arange(s)
    dec = params["decoder"]

    def body(carry, layer):
        x = carry
        x, (k, v) = tfm.attn_full(
            layer["attn"], x, cfg, dist, positions, 0, cfg.attn_chunk, causal=True
        )
        ck, cv = tfm.cross_attn_kv(layer["cross"], memory, cfg)
        x = tfm.cross_attn(layer["cross"], x, (ck, cv), cfg, dist)
        x, _ = tfm.mlp_apply(layer["mlp"], x, cfg, dist)
        return x, (k, v, ck, cv)

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    h, (k, v, ck, cv) = jax.lax.scan(body, tokens_embedded, dec)
    return h, (k, v), (ck, cv)


def encdec_decode_step(
    params: dict,
    x: Array,  # [B, D] embedded new token (position added by caller)
    state: EncDecState,
    cfg,
    dist: Optional[DistSpec] = None,
) -> tuple[Array, EncDecState]:
    """Self-attn cache travels in the scan carry and is updated in place
    (one row per layer); cross K/V are read-only scan xs."""
    dec = params["decoder"]
    b = x.shape[0]
    pos = state.length.astype(jnp.int32)
    bi = jnp.arange(b)
    layer_idx = jnp.arange(cfg.num_layers)

    def body(carry, xs):
        x, k_all, v_all = carry
        layer, li, ck, cv = xs
        p = layer["attn"]
        xn = apply_norm(p["ln"], x[:, None, :], cfg.norm)
        q, k, v = tfm._project_qkv(p, xn, cfg)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
        k_all = k_all.at[li, bi, pos].set(k.astype(k_all.dtype))
        v_all = v_all.at[li, bi, pos].set(v.astype(v_all.dtype))
        kc = jax.lax.dynamic_index_in_dim(k_all, li, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(v_all, li, 0, keepdims=False)
        from repro.models.attention import decode_attention

        o = decode_attention(q, kc, vc, state.length + 1)
        x = x + jnp.einsum("bhk,hkd->bd", o, p["wo"])
        y = tfm.cross_attn(layer["cross"], x[:, None, :], (ck, cv), cfg, dist)
        y, _ = tfm.mlp_apply(layer["mlp"], y, cfg, dist)
        return (y[:, 0], k_all, v_all), None

    (x, k, v), _ = jax.lax.scan(
        body,
        (x, state.self_k, state.self_v),
        (dec, layer_idx, state.cross_k, state.cross_v),
    )
    return x, state._replace(self_k=k, self_v=v, length=state.length + 1)
