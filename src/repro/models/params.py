"""Declarative parameter system.

Every parameter is declared exactly once as a :class:`ParamSpec` — shape,
dtype, initializer, and *logical* axis names. From that single declaration we
derive, always consistently:

  * ``init_params``      — RNG-split initialization (real arrays)
  * ``abstract_params``  — ShapeDtypeStruct tree (dry-run, no allocation)
  * ``partition_specs``  — PartitionSpec tree via logical→mesh axis rules

so a sharding tree can never drift out of sync with the parameter tree.
(The container has no flax; this ~150-line system is all the models need.)

Logical axes used by the models:

  layers, vocab, embed, heads, kv_heads, head_dim, mlp, experts,
  state (recurrent width), frames (audio), patches (vlm)
"""

from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax import Array
from jax.sharding import PartitionSpec as P

__all__ = [
    "ParamSpec",
    "dense_init",
    "embed_init",
    "zeros_init",
    "ones_init",
    "init_params",
    "abstract_params",
    "partition_specs",
    "count_params",
]


class ParamSpec(NamedTuple):
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis name per dim (None = replicated)
    init: Callable[[Array, tuple[int, ...], Any], Array]
    dtype: Any = jnp.bfloat16

    def __post_init__(self):  # pragma: no cover - NamedTuple has no post_init
        pass


def dense_init(fan_in: int, scale: float = 1.0):
    """Truncated-normal with 1/sqrt(fan_in) std — the standard matmul init."""

    def f(key: Array, shape: tuple[int, ...], dtype) -> Array:
        std = scale / math.sqrt(max(fan_in, 1))
        return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * std).astype(dtype)

    return f


def embed_init(scale: float = 1.0):
    def f(key: Array, shape: tuple[int, ...], dtype) -> Array:
        return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)

    return f


def zeros_init(key: Array, shape: tuple[int, ...], dtype) -> Array:
    return jnp.zeros(shape, dtype)


def ones_init(key: Array, shape: tuple[int, ...], dtype) -> Array:
    return jnp.ones(shape, dtype)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, rng: Array):
    """Materialise a ParamSpec tree into arrays, one fresh key per leaf."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(rng, len(leaves))
    arrays = [s.init(k, s.shape, s.dtype) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(specs):
    """ShapeDtypeStruct tree — for .lower() dry-runs, never allocates."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=_is_spec
    )


def partition_specs(specs, rules: dict[str, Any]):
    """Logical axes -> PartitionSpec via ``rules`` (logical name -> mesh axis,
    mesh-axis tuple, or None). Unknown logical names are an error — sharding
    must be a conscious decision for every axis."""

    def one(s: ParamSpec) -> P:
        parts = []
        for ax in s.axes:
            if ax is None:
                parts.append(None)
            elif ax in rules:
                parts.append(rules[ax])
            else:
                raise KeyError(f"no sharding rule for logical axis {ax!r}")
        return P(*parts)

    return jax.tree.map(one, specs, is_leaf=_is_spec)


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=_is_spec)
    return sum(math.prod(s.shape) for s in leaves)
