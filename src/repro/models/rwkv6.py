"""RWKV-6 "Finch" blocks (arXiv:2404.05892) — attention-free token mixing
with data-dependent per-channel decay.

Structure per layer: TimeMix (the wkv6 recurrence) + ChannelMix, both with
pre-LayerNorm and token-shift data-dependent interpolation (ddlerp with a
shared low-rank adapter, the paper's Eq. 10-13 shape).

The wkv6 recurrence, per head (Dh = 64)::

    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S: [Dh, Dh] state)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

Training runs the *chunked* form (linear-attention chunking): within a chunk
of C tokens the intra-chunk contribution is a masked matmul with per-channel
decay weighting, and the state propagates once per chunk — O(S·C·Dh) instead
of an S-step sequential scan, and the matmuls are MXU-shaped. Chunk math in
fp32 (decay ratios are exponentials; C = 32 keeps them bounded).

Decode is the recurrence taken literally, one step per token — O(1) state,
which is why this arch runs the ``long_500k`` cell.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist import DistSpec
from repro.models.layers import layernorm
from repro.models.params import ParamSpec, dense_init, ones_init, zeros_init

__all__ = ["RWKVState", "rwkv_block_specs", "rwkv_forward", "rwkv_decode_step", "init_rwkv_state"]

LORA_MIX = 32  # shared ddlerp adapter rank
LORA_DECAY = 64  # decay adapter rank
CHUNK = 32  # chunked-recurrence block length


class RWKVState(NamedTuple):
    """Per-layer recurrent state, stacked [L, ...]."""

    x_tm: Array  # [L, B, D] last input seen by TimeMix (token shift)
    x_cm: Array  # [L, B, D] last input seen by ChannelMix
    wkv: Array  # [L, B, H, Dh, Dh] recurrence state (fp32)


def init_rwkv_state(cfg, batch: int, abstract: bool = False):
    h = cfg.d_model // cfg.rwkv_head_dim
    shapes = dict(
        x_tm=((cfg.num_layers, batch, cfg.d_model), jnp.bfloat16),
        x_cm=((cfg.num_layers, batch, cfg.d_model), jnp.bfloat16),
        wkv=((cfg.num_layers, batch, h, cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32),
    )
    mk = (
        (lambda s, d: jax.ShapeDtypeStruct(s, d))
        if abstract
        else (lambda s, d: jnp.zeros(s, d))
    )
    return RWKVState(**{k: mk(s, d) for k, (s, d) in shapes.items()})


def rwkv_block_specs(cfg) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    l = cfg.num_layers
    pre = ((l, "layers"),)
    ps, pa = (l,), ("layers",)

    def vec(name_axis=None, init=zeros_init, dtype=jnp.float32):
        return ParamSpec(ps + (d,), pa + (name_axis,), init, dtype)

    ln = lambda: {
        "scale": ParamSpec(ps + (d,), pa + (None,), ones_init, jnp.float32),
        "bias": ParamSpec(ps + (d,), pa + (None,), zeros_init, jnp.float32),
    }
    return {
        "tm": {
            "ln": ln(),
            "mu_x": vec(),
            "mu": ParamSpec(ps + (5, d), pa + (None, None), zeros_init, jnp.float32),
            "lora_a": ParamSpec(ps + (d, 5 * LORA_MIX), pa + ("embed", None), dense_init(d)),
            "lora_b": ParamSpec(ps + (5, LORA_MIX, d), pa + (None, None, "embed"), zeros_init),
            "w_r": ParamSpec(ps + (d, d), pa + ("embed", "heads"), dense_init(d)),
            "w_k": ParamSpec(ps + (d, d), pa + ("embed", "heads"), dense_init(d)),
            "w_v": ParamSpec(ps + (d, d), pa + ("embed", "heads"), dense_init(d)),
            "w_g": ParamSpec(ps + (d, d), pa + ("embed", "heads"), dense_init(d)),
            "w_o": ParamSpec(ps + (d, d), pa + ("heads", "embed"), dense_init(d)),
            "decay_base": vec(),  # w0
            "decay_a": ParamSpec(ps + (d, LORA_DECAY), pa + ("embed", None), dense_init(d)),
            "decay_b": ParamSpec(ps + (LORA_DECAY, d), pa + (None, "embed"), zeros_init),
            "bonus": vec(init=zeros_init),  # u, flattened [D] = [H*Dh]
            "ln_x": ln(),  # per-head group norm params (applied over Dh)
        },
        "cm": {
            "ln": ln(),
            "mu_r": vec(),
            "mu_k": vec(),
            "w_r": ParamSpec(ps + (d, d), pa + ("embed", "mlp"), dense_init(d)),
            "w_k": ParamSpec(ps + (d, f), pa + ("embed", "mlp"), dense_init(d)),
            "w_v": ParamSpec(ps + (f, d), pa + ("mlp", "embed"), dense_init(f)),
        },
    }


# ---------------------------------------------------------------------------
# TimeMix


def _ddlerp(p: dict, x: Array, xx: Array) -> list[Array]:
    """Data-dependent lerp producing the 5 mixed inputs (r, k, v, g, w)."""
    base = x + xx * p["mu_x"].astype(x.dtype)
    lo = jnp.einsum("bsd,dr->bsr", base, p["lora_a"].astype(x.dtype))
    lo = jnp.tanh(lo.astype(jnp.float32)).reshape(*lo.shape[:-1], 5, LORA_MIX)
    delta = jnp.einsum("bsir,ird->bsid", lo, p["lora_b"].astype(jnp.float32))
    mix = p["mu"].astype(jnp.float32)[None, None] + delta  # [B, S, 5, D]
    out = x[..., None, :] + xx[..., None, :] * mix.astype(x.dtype)
    return [out[..., i, :] for i in range(5)]


def _decay(p: dict, xw: Array) -> Array:
    """Per-channel log-decay in (-inf, 0): logw = -exp(w0 + lora(xw))."""
    lo = jnp.einsum("bsd,dr->bsr", xw, p["decay_a"].astype(xw.dtype))
    lo = jnp.einsum(
        "bsr,rd->bsd", jnp.tanh(lo.astype(jnp.float32)), p["decay_b"].astype(jnp.float32)
    )
    return -jnp.exp(jnp.clip(p["decay_base"].astype(jnp.float32) + lo, -8.0, 4.0))


def _heads(x: Array, dh: int) -> Array:
    return x.reshape(*x.shape[:-1], x.shape[-1] // dh, dh)


def _wkv_chunk(r, k, v, logw, u, s0):
    """One chunk of the wkv6 recurrence (all fp32).

    r,k,v: [B, C, H, Dh]; logw: [B, C, H, Dh]; u: [H, Dh];
    s0: [B, H, Dh, Dh]. Returns (o [B, C, H, Dh], s1).
    """
    cum = jnp.cumsum(logw, axis=1)  # inclusive per-channel decay log-prod
    total = cum[:, -1]  # [B, H, Dh]
    # Keys normalised to chunk start, queries to t-1 (state before token t).
    q_t = r * jnp.exp(cum - logw)  # r_t * A_{t-1}
    k_i = k * jnp.exp(-cum)  # k_i / A_i
    scores = jnp.einsum("bthd,bihd->bhti", q_t, k_i)
    c = r.shape[1]
    mask = jnp.tril(jnp.ones((c, c), bool), k=-1)  # strictly i < t
    intra = jnp.einsum(
        "bhti,bihd->bthd", jnp.where(mask[None, None], scores, 0.0), v
    )
    diag = jnp.einsum("bthd,bthd->bth", r * u[None, None], k)[..., None] * v
    inter = jnp.einsum("bthd,bhde->bthe", q_t, s0)
    o = intra + diag + inter
    s1 = s0 * jnp.exp(total)[..., None] + jnp.einsum(
        "bihd,bihe->bhde", k * jnp.exp(total[:, None] - cum), v
    )
    return o, s1


def _group_norm(p: dict, x: Array, dh: int, eps: float = 1e-5) -> Array:
    """Per-head LayerNorm over Dh (rwkv's GroupNorm(H))."""
    shape = x.shape
    xh = x.reshape(*shape[:-1], shape[-1] // dh, dh).astype(jnp.float32)
    mean = jnp.mean(xh, -1, keepdims=True)
    var = jnp.var(xh, -1, keepdims=True)
    xh = ((xh - mean) * jax.lax.rsqrt(var + eps)).reshape(shape)
    return xh * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)


def time_mix(
    p: dict,
    x: Array,  # [B, S, D]
    cfg,
    x_prev: Array,  # [B, D] carry-in for token shift
    s0: Array,  # [B, H, Dh, Dh]
) -> tuple[Array, Array, Array]:
    """Full-sequence TimeMix. Returns (y, x_last, s_out)."""
    b, s, d = x.shape
    dh = cfg.rwkv_head_dim
    xn = layernorm(p["ln"]["scale"], p["ln"]["bias"], x)
    shifted = jnp.concatenate([x_prev[:, None].astype(xn.dtype), xn[:, :-1]], axis=1)
    xx = shifted - xn
    xr, xk, xv, xg, xw = _ddlerp(p, xn, xx)

    r = _heads(jnp.einsum("bsd,de->bse", xr, p["w_r"]), dh).astype(jnp.float32)
    k = _heads(jnp.einsum("bsd,de->bse", xk, p["w_k"]), dh).astype(jnp.float32)
    v = _heads(jnp.einsum("bsd,de->bse", xv, p["w_v"]), dh).astype(jnp.float32)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["w_g"]).astype(jnp.float32))
    logw = _heads(_decay(p, xw), dh)  # [B, S, H, Dh]
    u = _heads(p["bonus"].astype(jnp.float32)[None], dh)[0]  # [H, Dh]

    n_chunks = max(1, s // CHUNK)
    assert s % CHUNK == 0 or s < CHUNK, (s, CHUNK)
    if s < CHUNK:
        o, s_out = _wkv_chunk(r, k, v, logw, u, s0)
    else:
        resh = lambda a: a.reshape(b, n_chunks, CHUNK, *a.shape[2:]).swapaxes(0, 1)

        def body(carry, xs):
            rc, kc, vc, wc = xs
            o, s1 = _wkv_chunk(rc, kc, vc, wc, u, carry)
            return s1, o

        s_out, o = jax.lax.scan(body, s0, (resh(r), resh(k), resh(v), resh(logw)))
        o = o.swapaxes(0, 1).reshape(b, s, -1, dh)

    o = o.reshape(b, s, d)
    y = _group_norm(p["ln_x"], o, dh) * g
    y = jnp.einsum("bse,ed->bsd", y.astype(x.dtype), p["w_o"])
    return x + y, xn[:, -1], s_out


def channel_mix(
    p: dict, x: Array, x_prev: Array
) -> tuple[Array, Array]:
    """ChannelMix (rwkv FFN). Returns (y, x_last)."""
    xn = layernorm(p["ln"]["scale"], p["ln"]["bias"], x)
    shifted = jnp.concatenate([x_prev[:, None].astype(xn.dtype), xn[:, :-1]], axis=1)
    xx = shifted - xn
    xr = xn + xx * p["mu_r"].astype(xn.dtype)
    xk = xn + xx * p["mu_k"].astype(xn.dtype)
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_r"]).astype(jnp.float32))
    kk = jnp.einsum("bsd,df->bsf", xk, p["w_k"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    vv = jnp.einsum("bsf,fd->bsd", kk, p["w_v"])
    return x + (rr.astype(x.dtype) * vv), xn[:, -1]


# ---------------------------------------------------------------------------
# Stack execution


def rwkv_forward(
    blocks: dict,
    h: Array,  # [B, S, D]
    cfg,
    dist: Optional[DistSpec] = None,
    state: RWKVState | None = None,
) -> tuple[Array, RWKVState]:
    """Run all layers over a full sequence (train/prefill). ``state`` carries
    in (zeros for a fresh sequence) and the updated state carries out."""
    b = h.shape[0]
    if state is None:
        state = init_rwkv_state(cfg, b)

    def body(carry, xs):
        x = carry
        p, x_tm, x_cm, wkv = xs
        x, x_tm, wkv = time_mix(p["tm"], x, cfg, x_tm, wkv)
        x, x_cm = channel_mix(p["cm"], x, x_cm)
        return x, (x_tm, x_cm, wkv)

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    h, (x_tm, x_cm, wkv) = jax.lax.scan(
        body, h, (blocks, state.x_tm, state.x_cm, state.wkv)
    )
    return h, RWKVState(x_tm=x_tm, x_cm=x_cm, wkv=wkv)


def rwkv_decode_step(
    blocks: dict,
    x: Array,  # [B, D] one token's embedding
    cfg,
    state: RWKVState,
    dist: Optional[DistSpec] = None,
) -> tuple[Array, RWKVState]:
    """One literal recurrence step per layer (O(1) in context length)."""

    def body(carry, xs):
        xt = carry
        p, x_tm, x_cm, wkv = xs
        y, x_tm2, wkv2 = time_mix(p["tm"], xt[:, None, :], cfg, x_tm, wkv)
        y, x_cm2 = channel_mix(p["cm"], y, x_cm)
        return y[:, 0], (x_tm2, x_cm2, wkv2)

    x, (x_tm, x_cm, wkv) = jax.lax.scan(
        body, x, (blocks, state.x_tm, state.x_cm, state.wkv)
    )
    return x, RWKVState(x_tm=x_tm, x_cm=x_cm, wkv=wkv)
