"""Mixture-of-Experts FFN with the Redynis hot-expert replica path.

Baseline (paper-agnostic): GShard-style capacity routing. Tokens are split
into groups of ``cfg.moe_group_size``; the group dim is sharded over *both*
the data and model mesh axes, experts over the model axis, so the dispatch
einsum ``gsec,gsd->egcd`` lowers to exactly one all-to-all over the model
(EP) axis — the "remote request" of the paper's cost model.

Redynis path (``hot_ids`` provided): the placement daemon promotes experts
whose ownership fraction exceeds H into a replica set of R slots. Replica
weights are *gathered from the live params inside the forward pass*
(``w[hot_ids]``) — so replicas are never stale during training and autodiff
routes replica gradients back to the home copy for free. Tokens routed to a
hot expert dispatch into a local (group-sharded) buffer and never touch the
all-to-all; the cold path runs with a reduced static capacity, shrinking the
all-to-all payload — the TPU translation of "maximize hits on the local
store". Token dropping on capacity overflow is standard MoE semantics; the
drop rate is reported in the stats and bounded by the benchmarks.

Emitted stats (the Redynis traffic feed):
  counts  [G, E] — tokens each group routed to each expert (g(O, x))
  aux     []     — switch-style load-balance loss
  dropped []     — fraction of (token, slot) assignments dropped
  hot_frac []    — fraction of assignments served by the replica cache
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import Array

from repro.dist import DistSpec, constrain
from repro.models.layers import swiglu, swiglu_specs
from repro.models.params import ParamSpec, dense_init

__all__ = ["moe_specs", "moe_apply", "cold_capacity", "hot_capacity"]


def moe_specs(cfg, prefix: tuple) -> dict:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ps = tuple(s for s, _ in prefix)
    pa = tuple(a for _, a in prefix)
    specs = {
        "router": ParamSpec(ps + (d, e), pa + ("embed", "experts"), dense_init(d), jnp.float32),
        "w_gate": ParamSpec(ps + (e, d, f), pa + ("experts", "embed", "expert_mlp"), dense_init(d)),
        "w_up": ParamSpec(ps + (e, d, f), pa + ("experts", "embed", "expert_mlp"), dense_init(d)),
        "w_down": ParamSpec(ps + (e, f, d), pa + ("experts", "expert_mlp", "embed"), dense_init(f)),
    }
    if cfg.num_shared_experts:
        specs["shared"] = swiglu_specs(d, f * cfg.num_shared_experts, prefix)
    return specs


def _round4(x: int) -> int:
    return max(4, 4 * math.ceil(x / 4))


def cold_capacity(cfg, group: int) -> int:
    """Static per-expert capacity for the all-to-all (cold) path."""
    scale = cfg.moe_cold_capacity if cfg.hot_expert_slots else 1.0
    return _round4(
        math.ceil(group * cfg.top_k / cfg.num_experts * cfg.moe_capacity_factor * scale)
    )


def hot_capacity(cfg, group: int) -> int:
    """Static per-replica-slot capacity for the local (hot) path."""
    return _round4(math.ceil(group * cfg.top_k * cfg.moe_hot_capacity / cfg.hot_expert_slots))


def _top_k_gates(logits: Array, k: int) -> tuple[Array, Array]:
    """softmax -> top-k -> renormalised gates. logits [G, S, E] fp32."""
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, k)  # [G, S, K]
    gates = vals / jnp.maximum(jnp.sum(vals, -1, keepdims=True), 1e-9)
    return gates, idx


def _dispatch_combine(
    idx: Array,  # [G, S] expert/slot choice for ONE top-k slot
    gate: Array,  # [G, S] gate value for this slot
    active: Array,  # [G, S] bool — route this assignment here at all
    prior: Array,  # [G, E'] tokens already placed per target
    n_targets: int,
    capacity: int,
    dtype,
) -> tuple[Array, Array, Array, Array]:
    """One GShard dispatch slot: position-in-target via cumsum, capacity mask.

    Returns (dispatch [G,S,E',C], combine [G,S,E',C], new_prior, kept [G,S]).
    """
    oh = jax.nn.one_hot(idx, n_targets, dtype=jnp.float32) * active[..., None]
    pos = jnp.cumsum(oh, axis=1) - oh + prior[:, None, :]  # [G, S, E']
    pos_tok = jnp.sum(pos * oh, axis=-1).astype(jnp.int32)  # [G, S]
    keep = active & (pos_tok < capacity)
    slot_oh = jax.nn.one_hot(pos_tok, capacity, dtype=jnp.float32)
    disp = (oh * keep[..., None].astype(jnp.float32))[..., None] * slot_oh[..., None, :]
    comb = gate[..., None, None].astype(jnp.float32) * disp
    return disp.astype(dtype), comb.astype(dtype), prior + jnp.sum(oh, axis=1), keep


def sort_dispatch(
    xg: Array,  # [G, S, D]
    idx: Array,  # [G, S, K] expert choice per slot
    gates: Array,  # [G, S, K]
    active: Array,  # [G, S, K] bool
    e: int,
    capacity: int,
) -> tuple[Array, Array, Array, Array]:
    """Sort-based dispatch (moe_impl='sort'): no [G,S,E,C] one-hot matmuls.

    Flattens (token, slot) assignments per group, sorts by expert id, takes
    position-in-expert from the sorted order, and scatters token rows into
    the [E, C, D] buffers / gathers them back. O(S·K log S·K) integer work +
    pure gather/scatter data movement instead of the 2·S·E·C·D dispatch and
    combine matmuls — the FLOPs win measured as §Perf B5.

    Returns (expert_in [E, G, C, D], src_tok [G, S*K], dest [G, S*K],
    keep_gates [G, S*K]) — combine is a segment-sum back over the same maps.
    """
    g, s, k = idx.shape
    d = xg.shape[-1]
    flat_e = jnp.where(active, idx, e).reshape(g, s * k)  # inactive sorts last
    order = jnp.argsort(flat_e, axis=1, stable=True)  # [G, S*K]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    # position within expert = rank - first-rank-of-this-expert
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(sorted_e)
    pos = jnp.arange(s * k)[None, :] - jnp.take_along_axis(
        starts, jnp.minimum(sorted_e, e - 1), axis=1
    )
    keep = (sorted_e < e) & (pos < capacity)
    dest = jnp.where(keep, sorted_e * capacity + pos, e * capacity)  # drop slot
    src_tok = order // k  # token index of each sorted assignment

    rows = jnp.take_along_axis(
        xg, src_tok[..., None], axis=1
    )  # [G, S*K, D] gather
    buf = jnp.zeros((g, e * capacity + 1, d), xg.dtype)
    buf = jax.vmap(lambda b, dd, r: b.at[dd].add(r))(buf, dest, rows)
    expert_in = (
        buf[:, : e * capacity].reshape(g, e, capacity, d).transpose(1, 0, 2, 3)
    )
    sorted_gates = jnp.take_along_axis(gates.reshape(g, s * k), order, axis=1)
    keep_gates = jnp.where(keep, sorted_gates, 0.0)
    return expert_in, src_tok, dest, keep_gates


def sort_combine(
    expert_out: Array,  # [E, G, C, D] (already gate-scaled)
    src_tok: Array,  # [G, S*K]
    dest: Array,  # [G, S*K]
    s: int,
) -> Array:
    """Gather expert outputs back to token rows and segment-sum per token."""
    e, g, c, d = expert_out.shape
    flat = expert_out.transpose(1, 0, 2, 3).reshape(g, e * c, d)
    flat = jnp.concatenate([flat, jnp.zeros((g, 1, d), flat.dtype)], axis=1)
    contrib = jnp.take_along_axis(flat, dest[..., None], axis=1)  # [G, S*K, D]
    y = jnp.zeros((g, s, d), flat.dtype)
    return jax.vmap(lambda yy, t, cb: yy.at[t].add(cb))(y, src_tok, contrib)


def _expert_ffn(
    w_gate: Array, w_up: Array, w_down: Array, x: Array, spec: str, e: str
) -> Array:
    """Batched swiglu over an explicit expert layout.

    spec 'egcd', e 'e' — cold path: x [E, G, C, D], weights [E, D, F]
    spec 'grcd', e 'r' — hot path:  x [G, R, C, D], weights [R, D, F]
    """
    up_spec = f"{spec},{e}df->{spec[:-1]}f"
    down_spec = f"{spec[:-1]}f,{e}fd->{spec}"
    g = jnp.einsum(up_spec, x, w_gate)
    u = jnp.einsum(up_spec, x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum(down_spec, h, w_down)


def moe_apply(
    p: dict,
    x: Array,  # [B, S, D]
    cfg,
    dist: Optional[DistSpec] = None,
    hot_ids: Array | None = None,  # [R] int32 expert ids in the replica cache (-1 empty)
) -> tuple[Array, dict]:
    """MoE FFN. See module docstring. Returns (y, stats)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    tokens = b * s
    group = min(cfg.moe_group_size, tokens)
    while tokens % group:
        group -= 1
    g = tokens // group
    xg = x.reshape(g, group, d)
    # Group dim sharded over the batch (data) axes only; activations stay
    # replicated over the model axis, so the dispatch einsum is fully LOCAL
    # (each EP rank masks out its own experts' tokens) and the combine is
    # one [G_local, S, D] psum over the model axis — the same collective a
    # dense TP FFN pays. (§Perf B2: the earlier G-over-(data×model)
    # sharding triggered GSPMD "involuntary full rematerialization" on the
    # backward reshard — 4.9 TB/chip/step of fallback all-gathers.)
    g_spec = None
    if dist is not None and dist.mesh is not None:
        if dist.batch_size > 1 and g % dist.batch_size == 0:
            g_spec = dist.batch
        if g_spec is not None:
            xg = constrain(xg, dist, g_spec, None, None)
        elif g == 1:
            xg = constrain(xg, dist, None, dist.batch, None)

    logits = jnp.einsum(
        "gsd,de->gse", xg, p["router"], preferred_element_type=jnp.float32
    )
    gates, idx = _top_k_gates(logits, k)  # [G, S, K]

    counts = jnp.zeros((g, e), jnp.float32)
    for j in range(k):
        counts = counts + jnp.sum(jax.nn.one_hot(idx[..., j], e, dtype=jnp.float32), axis=1)
    # Switch-style load-balance aux: E * sum_e frac_tokens_e * mean_prob_e.
    frac_tok = counts / jnp.maximum(jnp.sum(counts, -1, keepdims=True), 1.0)
    mean_prob = jnp.mean(jax.nn.softmax(logits, -1), axis=1)
    aux = e * jnp.mean(jnp.sum(frac_tok * mean_prob, axis=-1))

    use_hot = hot_ids is not None and cfg.hot_expert_slots > 0
    r = cfg.hot_expert_slots if use_hot else 0

    if use_hot:
        # Which assignments hit the replica cache, and which slot.
        hit = idx[..., None] == hot_ids[None, None, None, :]  # [G, S, K, R]
        is_hot = jnp.any(hit, axis=-1) & (idx >= 0)
        hot_slot = jnp.argmax(hit, axis=-1)  # [G, S, K]
    else:
        is_hot = jnp.zeros(idx.shape, bool)
        hot_slot = jnp.zeros(idx.shape, jnp.int32)

    c_cold = cold_capacity(cfg, group)
    kept_total = jnp.zeros((), jnp.float32)

    # ---- cold path: capacity dispatch + all-to-all over the EP axis ----
    def _ep_constrain(t):
        if dist is not None and dist.mesh is not None and dist.tensor_parallel:
            gdim = (
                dist.batch
                if (g_spec is not None and g % dist.batch_size == 0)
                else None
            )
            return constrain(t, dist, dist.model_axis, gdim, None, None)
        return t

    if cfg.moe_impl == "sort":
        # §Perf B5: argsort routing — no [G,S,E,C] one-hot matmuls at all.
        expert_in, src_tok, dest, keep_gates = sort_dispatch(
            xg, idx, gates, ~is_hot, e, c_cold
        )
        expert_in = _ep_constrain(expert_in)
        expert_out = _expert_ffn(
            p["w_gate"], p["w_up"], p["w_down"], expert_in, "egcd", "e"
        )
        # gate scaling on the expert side (same trick as the einsum path)
        gate_buf = jnp.zeros((g, e * c_cold + 1), jnp.float32)
        gate_buf = jax.vmap(lambda b, dd, kg: b.at[dd].add(kg))(
            gate_buf, dest, keep_gates.astype(jnp.float32)
        )
        gate_ec = (
            gate_buf[:, : e * c_cold].reshape(g, e, c_cold).transpose(1, 0, 2)
        )
        expert_out = expert_out * gate_ec[..., None].astype(expert_out.dtype)
        y = sort_combine(expert_out, src_tok, dest, group)
        kept_total = kept_total + jnp.sum(
            (jax.lax.stop_gradient(keep_gates) > 0).astype(jnp.float32)
        )
    else:
        # The dispatch tensor is a one-hot routing mask — structurally zero
        # gradient — so it is stop_gradient'ed and the gate scaling moves
        # to the (small) expert side as gate_ec [E, G, C]. Without this,
        # autodiff materialises a [G, S, E, C] f32 cotangent for the
        # combine whose resharding GSPMD can only do by full replication
        # ("involuntary full rematerialization") — measured at ~3 TB/chip/
        # step on the deepseek train cell before the rewrite (§Perf B1).
        disp = jnp.zeros((g, group, e, c_cold), xg.dtype)
        gate_ec = jnp.zeros((e, g, c_cold), jnp.float32)
        prior = jnp.zeros((g, e), jnp.float32)
        for j in range(k):
            dj, cj, prior, kept = _dispatch_combine(
                idx[..., j], gates[..., j], ~is_hot[..., j], prior, e, c_cold, xg.dtype
            )
            disp = disp + dj
            gate_ec = gate_ec + jnp.einsum(
                "gsec,gs->egc",
                jax.lax.stop_gradient(dj).astype(jnp.float32),
                gates[..., j].astype(jnp.float32),
            )
            kept_total = kept_total + jnp.sum(kept)
        disp = jax.lax.stop_gradient(disp)

        expert_in = jnp.einsum("gsec,gsd->egcd", disp, xg)  # a2a happens here
        expert_in = _ep_constrain(expert_in)
        expert_out = _expert_ffn(
            p["w_gate"], p["w_up"], p["w_down"], expert_in, "egcd", "e"
        )
        expert_out = expert_out * gate_ec[..., None].astype(expert_out.dtype)
        y = jnp.einsum("gsec,egcd->gsd", disp, expert_out)  # and back

    # ---- hot path: local dispatch against in-forward-gathered replicas ----
    hot_kept = jnp.zeros((), jnp.float32)
    if use_hot:
        c_hot = hot_capacity(cfg, group)
        safe_ids = jnp.clip(hot_ids, 0, e - 1)
        hw_gate = jnp.take(p["w_gate"], safe_ids, axis=0)  # [R, D, F] replicated
        hw_up = jnp.take(p["w_up"], safe_ids, axis=0)
        hw_down = jnp.take(p["w_down"], safe_ids, axis=0)

        hdisp = jnp.zeros((g, group, r, c_hot), xg.dtype)
        hgate = jnp.zeros((g, r, c_hot), jnp.float32)
        hprior = jnp.zeros((g, r), jnp.float32)
        for j in range(k):
            dj, cj, hprior, kept = _dispatch_combine(
                hot_slot[..., j], gates[..., j], is_hot[..., j], hprior, r, c_hot, xg.dtype
            )
            hdisp = hdisp + dj
            hgate = hgate + jnp.einsum(
                "gsrc,gs->grc",
                jax.lax.stop_gradient(dj).astype(jnp.float32),
                gates[..., j].astype(jnp.float32),
            )
            hot_kept = hot_kept + jnp.sum(kept)
        hdisp = jax.lax.stop_gradient(hdisp)
        hot_in = jnp.einsum("gsrc,gsd->grcd", hdisp, xg)  # g-sharded: NO collective
        hot_out = _expert_ffn(hw_gate, hw_up, hw_down, hot_in, "grcd", "r")
        hot_out = hot_out * hgate[..., None].astype(hot_out.dtype)
        y = y + jnp.einsum("gsrc,grcd->gsd", hdisp, hot_out)
        kept_total = kept_total + hot_kept

    if cfg.num_shared_experts:
        y = y + swiglu(p["shared"], xg)

    n_assign = jnp.asarray(g * group * k, jnp.float32)
    stats = {
        "counts": counts,
        "aux": aux,
        "dropped": 1.0 - kept_total / n_assign,
        "hot_frac": hot_kept / n_assign,
    }
    return y.reshape(b, s, d), stats
