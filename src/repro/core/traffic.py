"""Traffic statistics for ML-state objects (experts / embedding rows / sessions).

The paper's metadata layer tracks raw access counters per (key, node). For
ML state the natural "access" events are:

  * MoE:        tokens from data-parallel group ``r`` routed to expert ``e``
  * embeddings: lookups of row ``v`` by data shard ``r``
  * serving:    requests for session ``s`` arriving at pod ``p``

All three reduce to the same ``[K, N]`` count matrix the core engine already
understands. This module provides the accumulator that the forward pass folds
into (an O(1)-per-event side effect, like the paper's web-service layer
logging to the metadata store), with optional EMA decay so placement reacts
to traffic *shifts* — a beyond-paper extension motivated by ML traffic being
far burstier than CDN-style key traffic.

The accumulator is a pytree carried through jitted steps (donated), so stats
collection adds zero host round-trips — the TPU analogue of the paper's
"optimizations need to be non-blocking" requirement.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

__all__ = ["TrafficStats", "create_stats", "fold_counts", "fold_events", "decay_stats"]


class TrafficStats(NamedTuple):
    counts: Array  # [K, N] float32 (EMA-decayed access counts g(O, x))
    last_access: Array  # [K] int32 tick of last access
    total_events: Array  # [] float32 running event count (for diagnostics)

    @property
    def num_objects(self) -> int:
        return self.counts.shape[0]

    @property
    def num_nodes(self) -> int:
        return self.counts.shape[1]


def create_stats(num_objects: int, num_nodes: int) -> TrafficStats:
    return TrafficStats(
        counts=jnp.zeros((num_objects, num_nodes), jnp.float32),
        last_access=jnp.zeros((num_objects,), jnp.int32),
        total_events=jnp.zeros((), jnp.float32),
    )


def fold_counts(stats: TrafficStats, delta: Array, now: Array | int) -> TrafficStats:
    """Fold a dense ``[K, N]`` count delta (e.g. per-expert routing histogram
    produced inside the jitted train step) into the stats."""
    delta = delta.astype(jnp.float32)
    touched = jnp.sum(delta, axis=-1) > 0
    return TrafficStats(
        counts=stats.counts + delta,
        last_access=jnp.where(
            touched, jnp.asarray(now, jnp.int32), stats.last_access
        ),
        total_events=stats.total_events + jnp.sum(delta),
    )


def fold_events(
    stats: TrafficStats,
    objects: Array,
    nodes: Array,
    now: Array | int,
    weights: Array | None = None,
) -> TrafficStats:
    """Fold sparse access events ``(object_id, node_id)`` — scatter-add form."""
    k, n = stats.counts.shape
    if weights is None:
        weights = jnp.ones_like(objects, dtype=jnp.float32)
    flat = objects.astype(jnp.int32) * n + nodes.astype(jnp.int32)
    counts = stats.counts.reshape(-1).at[flat].add(
        weights.astype(jnp.float32), mode="drop"
    )
    last = stats.last_access.at[objects].max(
        jnp.asarray(now, jnp.int32), mode="drop"
    )
    return TrafficStats(
        counts=counts.reshape(k, n),
        last_access=last,
        total_events=stats.total_events + jnp.sum(weights),
    )


def decay_stats(stats: TrafficStats, decay: float) -> TrafficStats:
    """EMA decay (1.0 = paper-faithful raw counters, <1 = reactive)."""
    return stats._replace(counts=stats.counts * decay)
