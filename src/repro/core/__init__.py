"""Core Redynis engine: traffic-aware dynamic repartitioning, in JAX.

The paper's contribution as a composable library:

  ownership   — ownership coefficient math (eqs. 1-3)
  metadata    — the per-key metadata layer (paper §6.2), struct-of-arrays
  placement   — Algorithm 3 sweep + the offline placement daemon
  policy      — first-class placement policies (registry + shared stages)
  traffic     — access-statistics accumulators for ML-state objects
  costmodel   — TPU replication economics (beyond-paper, reduces to Alg. 3)
  repartition — plan → fused-collective enforcement with double buffering
"""

from repro.core.costmodel import (
    TPU_V5E,
    HardwareModel,
    budget_plan,
    project_capacity,
    replication_gain,
)
from repro.core.metadata import (
    MetadataStore,
    create_store,
    local_hit,
    owner_of,
    record_accesses,
    record_new_keys,
)
from repro.core.ownership import (
    eligible_hosts,
    max_coefficient,
    ownership_fraction,
    validate_coefficient,
)
from repro.core.placement import (
    PlacementDaemon,
    PlacementPlan,
    SweepStats,
    apply_plan,
    masked_step,
    redynis_candidates,
    sweep,
)
from repro.core.policy import (
    POLICIES,
    CostGreedyPolicy,
    DecayLFUPolicy,
    PolicyContext,
    RedynisPolicy,
    StaticPolicy,
    TopKPolicy,
    describe_policy,
    make_policy,
    parse_policy,
    policy_masked_step,
    policy_sweep,
    register_policy,
    split_policy,
)
from repro.core.repartition import (
    CommitState,
    Moves,
    ReplicaCache,
    create_cache,
    plan_moves,
    publish_and_fill,
)
from repro.core.traffic import (
    TrafficStats,
    create_stats,
    decay_stats,
    fold_counts,
    fold_events,
)

__all__ = [
    "TPU_V5E",
    "HardwareModel",
    "budget_plan",
    "project_capacity",
    "replication_gain",
    "MetadataStore",
    "create_store",
    "local_hit",
    "owner_of",
    "record_accesses",
    "record_new_keys",
    "eligible_hosts",
    "max_coefficient",
    "ownership_fraction",
    "validate_coefficient",
    "PlacementDaemon",
    "PlacementPlan",
    "SweepStats",
    "apply_plan",
    "masked_step",
    "redynis_candidates",
    "sweep",
    "POLICIES",
    "CostGreedyPolicy",
    "DecayLFUPolicy",
    "PolicyContext",
    "RedynisPolicy",
    "StaticPolicy",
    "TopKPolicy",
    "describe_policy",
    "make_policy",
    "parse_policy",
    "policy_masked_step",
    "policy_sweep",
    "register_policy",
    "split_policy",
    "CommitState",
    "Moves",
    "ReplicaCache",
    "create_cache",
    "plan_moves",
    "publish_and_fill",
    "TrafficStats",
    "create_stats",
    "decay_stats",
    "fold_counts",
    "fold_events",
]
