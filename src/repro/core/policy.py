"""First-class placement policies — the decision algorithm as a value.

Redynis's contribution is a *decision algorithm* (Algorithm 3), but the
policy space around it is wide: size-aware sharding scores placements by
bytes moved per latency saved (Didona & Zwaenepoel, 1802.00696), Crux
preserves locality structurally (1405.0637), and classic caches rank by
decayed frequency. This module makes the decision rule a first-class,
composable value instead of a hardwired enum + kwarg sprawl:

    policy = RedynisPolicy(h=0.2, decay=0.9)
    run_scenario(workload, cluster, policy)

Protocol
--------
A placement policy is a registered ``NamedTuple`` of hyperparameters with
two pure hooks::

    init(store, ctx)                 -> state          # pytree, () if stateless
    decide(state, store, f, now, ctx) -> (owners, state)

``f`` is the ``[K, N]`` ownership-fraction matrix (eq. 1), computed once by
the engine; ``owners`` is the *candidate* replica set. Both hooks are pure
fixed-shape JAX, so the fused ``lax.scan`` simulation engine calls the
policy inside its scan body with zero Python in the hot loop. A policy
whose backend already produces ``f`` (the Pallas ownership-sweep kernel)
may set ``supplies_fractions`` and implement
``decide_fused(state, store, now, ctx) -> (owners, f, state)`` — the
engine then skips its own fractions stage and reuses the supplied ``f``
for scoring, with no ``[K, N]`` recompute. Every policy
then flows through the same shared stages, in order::

    fractions ─► decide ─► live/expiry mask ─► capacity projection ─► plan

so expiry semantics and per-node replica-byte budgets apply uniformly — a
policy cannot opt out of the cluster's memory limits.

Static vs dynamic hyperparameters
---------------------------------
Each policy class names its ``DYNAMIC_FIELDS`` — float-valued knobs (H,
decay, K, thresholds) that are *traced*, not compiled in. ``split_policy``
divides an instance into a hashable static key (used as the jit static) and
a dict of traced params, so (a) re-running with a new H never recompiles,
and (b) ``run_experiment(policies=[...])`` can stack the params of
same-family policies and ``vmap`` the policy axis alongside the seed axis —
a whole head-to-head grid as one batched program. Inside ``decide``,
dynamic knobs are read from ``ctx.params``, never from ``self``.

Built-ins
---------
========== ==================================================================
redynis    Algorithm 3 (ownership coefficient), bit-exact with the legacy
           OPTIMIZED path; ``backend="pallas"`` routes the [K, N] pass
           through the ``kernels.ownership_sweep`` TPU kernel.
static     The non-adaptive baselines: ``mode="local" | "remote" |
           "replicated"`` absorb the three legacy ``Scenario`` enum values.
topk       Replicate the K globally hottest keys everywhere; cold keys
           collapse to their modal request source.
costgreedy Size-aware greedy growth: add a replica where the RTT saved per
           byte moved clears a threshold (the Didona & Zwaenepoel angle).
decaylfu   Redynis's eligibility rule on an exponentially-decayed access
           EMA — a *stateful* policy that tracks traffic shifts without
           mutating the metadata layer's raw counters.
sizeaware  Minos-style small/large pools (1802.00696): small objects
           replicate wide (cheap bytes, served anywhere), large objects
           keep a bounded fanout of their hottest request sources — the
           placement that spreads queueing load under ``ServiceConfig``
           contention instead of piling large-object demand on one node.
========== ==================================================================

Registry: ``POLICIES`` maps names to classes; ``parse_policy`` turns CLI
specs (``"redynis:h=0.2,decay=0.9"``, or bare aliases ``"local"``) into
instances for the benchmark drivers.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.costmodel import project_capacity
from repro.core.metadata import MetadataStore
from repro.core.ownership import (
    eligible_from_fractions,
    ownership_fraction,
    validate_coefficient,
)
from repro.core.placement import (
    SWEEP_BACKENDS,
    PlacementPlan,
    SweepStats,
    redynis_candidates,
)

__all__ = [
    "POLICIES",
    "PolicyContext",
    "RedynisPolicy",
    "StaticPolicy",
    "TopKPolicy",
    "CostGreedyPolicy",
    "DecayLFUPolicy",
    "SizeAwarePolicy",
    "register_policy",
    "make_policy",
    "parse_policy",
    "split_policy",
    "describe_policy",
    "policy_repr",
    "policy_sweep",
    "policy_masked_step",
    "publish_mask",
]


class _Vmapped:
    """Singleton placeholder a dynamic field holds on a *static key* — the
    actual value travels in ``PolicyContext.params`` (traced / vmapped)."""

    _instance = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self) -> str:  # pragma: no cover - cosmetic
        return "<vmapped>"


VMAPPED = _Vmapped()


class PolicyContext(NamedTuple):
    """Trace-time inputs every policy hook receives.

    rtt:            ``[N, N]`` pairwise RTT matrix (ms).
    object_bytes:   ``[K]`` per-key payload size.
    capacity_bytes: ``[N]`` per-node replica-byte budget, or ``None`` when
                    every budget is infinite (the projection stage then
                    compiles away — bit-exact Algorithm 3).
    params:         dict of this policy's *dynamic* hyperparameters
                    (``DYNAMIC_FIELDS``), traced scalars — or ``[P]``
                    vectors under the batched policy-grid vmap.
    avail:          ``[N] bool`` node availability this chunk under failure
                    injection, or ``None`` (the default) for the fault-free
                    program — the sweep then compiles with no membership
                    mask at all (the bit-exact golden path).
    """

    rtt: Array
    object_bytes: Array
    capacity_bytes: Array | None
    params: dict
    avail: Array | None = None


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

POLICIES: dict[str, type] = {}
_ALIASES: dict[str, tuple[str, dict]] = {
    # Bare scenario-style shorthands for CLI ergonomics.
    "local": ("static", {"mode": "local"}),
    "remote": ("static", {"mode": "remote"}),
    "replicated": ("static", {"mode": "replicated"}),
}


def register_policy(cls: type) -> type:
    """Class decorator: add ``cls`` to the registry under ``cls.name``.

    Also makes equality/hash *class-aware*: NamedTuple inherits plain tuple
    semantics, under which two different policy families with equal field
    tuples would compare equal — colliding as grouping keys and, fatally,
    in the jit static-argument cache.
    """

    def __eq__(self, other):
        return type(other) is type(self) and tuple.__eq__(self, other) is True

    def __ne__(self, other):
        return not self.__eq__(other)

    def __hash__(self):
        return hash((type(self).__qualname__,) + tuple(self))

    cls.__eq__ = __eq__
    cls.__ne__ = __ne__
    cls.__hash__ = __hash__
    POLICIES[cls.name] = cls
    return cls


def make_policy(name: str, **kwargs):
    """Instantiate a registered policy by name (aliases resolved)."""
    if name in _ALIASES:
        base, preset = _ALIASES[name]
        return POLICIES[base](**{**preset, **kwargs})
    if name not in POLICIES:
        known = sorted(set(POLICIES) | set(_ALIASES))
        raise ValueError(f"unknown policy {name!r}; expected one of {known}")
    return POLICIES[name](**kwargs)


def _coerce(text: str):
    low = text.lower()
    if low == "none":
        return None
    if low in ("true", "false"):
        return low == "true"
    for cast in (int, float):
        try:
            return cast(text)
        except ValueError:
            pass
    return text


def parse_policy(spec: str):
    """Parse a CLI policy spec: ``name[:k=v,...]``.

    Examples: ``"redynis"``, ``"redynis:h=0.2,decay=0.9"``,
    ``"topk:k=50"``, ``"static:mode=remote"``, or the bare aliases
    ``"local" | "remote" | "replicated"``.
    """
    name, _, tail = spec.partition(":")
    kwargs = {}
    if tail:
        for item in tail.split(","):
            key, eq, value = item.partition("=")
            if not eq:
                raise ValueError(
                    f"bad policy spec {spec!r}: expected k=v, got {item!r}"
                )
            kwargs[key.strip()] = _coerce(value.strip())
    return make_policy(name.strip(), **kwargs)


def split_policy(policy) -> tuple:
    """Split an instance into ``(static_key, params)``.

    ``static_key`` is the policy with every dynamic field replaced by the
    ``VMAPPED`` sentinel — hashable, shared across a whole family, the jit
    static. ``params`` maps each dynamic field to a float, ready to be
    traced (or stacked into ``[P]`` vectors for a batched policy grid).
    """
    dyn = type(policy).DYNAMIC_FIELDS
    params = {name: float(getattr(policy, name)) for name in dyn}
    static = policy._replace(**{name: VMAPPED for name in dyn})
    return static, params


def _label_fields(policy) -> list[str]:
    """``k=v`` parts for labels/reprs: non-default fields, plus any field
    the class lists in ``ALWAYS_LABEL`` (e.g. StaticPolicy's mode, so the
    'local' baseline is never an ambiguous bare ``static``)."""
    cls = type(policy)
    always = getattr(cls, "ALWAYS_LABEL", ())
    return [
        f"{name}={getattr(policy, name)!r}"
        for name in cls._fields
        if name in always
        or getattr(policy, name) != cls._field_defaults.get(name)
    ]


def describe_policy(policy) -> str:
    """Compact registry-name label: ``redynis(h=0.2)``."""
    parts = _label_fields(policy)
    return f"{type(policy).name}({', '.join(parts)})" if parts else type(policy).name


def policy_repr(policy) -> str:
    """Constructor spelling — the exact replacement quoted by the
    ``scenario=`` deprecation warning."""
    return f"{type(policy).__name__}({', '.join(_label_fields(policy))})"


def _validate_common(policy, *, decay=None, period=None, backend=None):
    if decay is not None and not (0.0 < decay <= 1.0):
        raise ValueError(f"{type(policy).__name__}: decay must be in (0, 1], got {decay}")
    if period is not None and period < 1:
        raise ValueError(f"{type(policy).__name__}: period must be >= 1, got {period}")
    if backend is not None and backend not in SWEEP_BACKENDS:
        raise ValueError(
            f"{type(policy).__name__}: unknown sweep backend {backend!r}; "
            f"expected one of {SWEEP_BACKENDS}"
        )


# ---------------------------------------------------------------------------
# Built-in policies.
# ---------------------------------------------------------------------------


@register_policy
class RedynisPolicy(NamedTuple):
    """Paper Algorithm 3: replicate where the ownership fraction clears H.

    Bit-exact with the legacy ``Scenario.OPTIMIZED`` path (pinned by the
    seed goldens and the policy-equivalence tests). ``h=None`` resolves to
    the starvation-safe maximum ``1/n`` at run time.
    """

    h: float | None = None  # ownership coefficient (eq. 2); None -> 1/n
    expiry: int = 0  # ticks before untouched keys are purged; 0 disables
    decay: float = 1.0  # post-sweep count decay (1.0 = paper's raw counters)
    period: int = 1  # sweep every `period`-th tick
    backend: str = "jax"  # "jax" | "pallas" ([K, N] pass routing)

    name = "redynis"
    DYNAMIC_FIELDS = ("h", "decay")
    is_active = True
    read_mode = "map"
    initial_placement = "offsite"

    def resolve(self, num_nodes: int) -> "RedynisPolicy":
        return self if self.h is not None else self._replace(h=1.0 / num_nodes)

    def validate(self, num_nodes: int) -> None:
        validate_coefficient(self.h, num_nodes)
        if self.expiry < 0:
            raise ValueError(
                f"expiry must be a non-negative tick count, got {self.expiry} "
                f"(0 disables expiry)"
            )
        _validate_common(
            self, decay=self.decay, period=self.period, backend=self.backend
        )

    @property
    def supplies_fractions(self) -> bool:
        """The Pallas kernel emits ``f`` alongside ``owners``; the engine
        skips its own fractions stage and reuses it (no [K, N] recompute —
        the PR-2 'f output feeds the scoring' property, preserved)."""
        return self.backend == "pallas"

    def init(self, store: MetadataStore, ctx: PolicyContext):
        return ()

    def decide_fused(self, state, store: MetadataStore, now, ctx: PolicyContext):
        from repro.kernels.ownership_sweep.ops import ownership_sweep

        owners, _, _, _, f = ownership_sweep(
            store.access_counts,
            store.hosts,
            store.live,
            store.last_access,
            now,
            h=ctx.params["h"],
            expiry=self.expiry,
        )
        return owners, f, state

    def decide(self, state, store: MetadataStore, f: Array, now, ctx: PolicyContext):
        if self.backend == "pallas":
            owners, _, state = self.decide_fused(state, store, now, ctx)
            return owners, state
        return redynis_candidates(store, f, ctx.params["h"]), state


@register_policy
class StaticPolicy(NamedTuple):
    """The non-adaptive baselines (paper §9), absorbing the legacy enum:

    mode="local"       the idealised everything-local scenario
    mode="remote"      no local replicas ever; every op pays a WAN hop
    mode="replicated"  naive full replication — local reads, broadcast writes

    Static policies never run the daemon loop: the replica map is frozen at
    its initial placement and the whole decision machinery compiles away.
    """

    mode: str = "local"

    name = "static"
    MODES = ("local", "remote", "replicated")
    DYNAMIC_FIELDS = ()
    ALWAYS_LABEL = ("mode",)
    is_active = False

    @property
    def read_mode(self) -> str:
        return {"local": "ideal", "remote": "no_local", "replicated": "map"}[
            self.mode
        ]

    @property
    def initial_placement(self) -> str:
        return "offsite" if self.mode == "remote" else "full"

    def resolve(self, num_nodes: int) -> "StaticPolicy":
        return self

    def validate(self, num_nodes: int) -> None:
        if self.mode not in self.MODES:
            raise ValueError(
                f"unknown StaticPolicy mode {self.mode!r}; expected one of "
                f"{self.MODES}"
            )

    def init(self, store: MetadataStore, ctx: PolicyContext):
        return ()

    def decide(self, state, store: MetadataStore, f: Array, now, ctx: PolicyContext):
        return store.hosts, state  # never called (is_active=False); identity


@register_policy
class TopKPolicy(NamedTuple):
    """Replicate the K globally hottest keys on every node; each cold key
    collapses to its modal request source (the node issuing most of its
    accesses). A global-frequency baseline: no per-node fractions, so it
    wins when hotness is global (every node hammers the same keys) and loses
    to Redynis when hotness is regional."""

    k: float = 100.0  # number of globally-hottest keys to replicate
    decay: float = 1.0
    period: int = 1

    name = "topk"
    DYNAMIC_FIELDS = ("k", "decay")
    is_active = True
    read_mode = "map"
    initial_placement = "offsite"

    def resolve(self, num_nodes: int) -> "TopKPolicy":
        return self

    def validate(self, num_nodes: int) -> None:
        if self.k < 0:
            raise ValueError(f"k must be non-negative, got {self.k}")
        _validate_common(self, decay=self.decay, period=self.period)

    def init(self, store: MetadataStore, ctx: PolicyContext):
        return ()

    def decide(self, state, store: MetadataStore, f: Array, now, ctx: PolicyContext):
        counts = store.access_counts
        total = jnp.sum(counts, axis=-1)
        # Dense rank by total accesses, hottest first; ties break to the
        # lower key id (argsort is stable), so the cut is deterministic.
        order = jnp.argsort(-total)
        ranks = jnp.zeros_like(order).at[order].set(
            jnp.arange(total.shape[0], dtype=order.dtype)
        )
        touched = total > 0
        # The rank cut alone would sweep zero-traffic keys into the hot set
        # whenever k exceeds the touched count — silence keeps placement.
        hot = (ranks < ctx.params["k"]) & touched
        modal = (
            jnp.arange(counts.shape[1], dtype=jnp.int32)
            == jnp.argmax(counts, axis=-1).astype(jnp.int32)[:, None]
        )
        cold = jnp.where(touched[:, None], modal, store.hosts)
        owners = jnp.where(hot[:, None], jnp.ones_like(store.hosts), cold)
        return owners, state


@register_policy
class CostGreedyPolicy(NamedTuple):
    """Size-aware greedy growth (after Didona & Zwaenepoel, 1802.00696):
    add a replica of O on x when the RTT milliseconds its traffic would save
    per KiB moved clears ``min_saved_ms_per_kib``. Saved ms = accesses from
    x × (current nearest-replica RTT − local RTT). The policy only *grows*
    the replica set — shrinking is delegated to the shared expiry and
    capacity-projection stages, so a finite budget evicts the coldest
    replicas exactly as for every other policy.

    Memory note: scoring materialises a ``[K, N, N]`` intermediate; sized
    for simulator-scale K (thousands), not the 1e6-key daemon benches.
    """

    min_saved_ms_per_kib: float = 100.0
    decay: float = 1.0
    period: int = 1

    name = "costgreedy"
    DYNAMIC_FIELDS = ("min_saved_ms_per_kib", "decay")
    is_active = True
    read_mode = "map"
    initial_placement = "offsite"

    def resolve(self, num_nodes: int) -> "CostGreedyPolicy":
        return self

    def validate(self, num_nodes: int) -> None:
        if self.min_saved_ms_per_kib < 0:
            raise ValueError(
                f"min_saved_ms_per_kib must be non-negative, got "
                f"{self.min_saved_ms_per_kib}"
            )
        _validate_common(self, decay=self.decay, period=self.period)

    def init(self, store: MetadataStore, ctx: PolicyContext):
        return ()

    def decide(self, state, store: MetadataStore, f: Array, now, ctx: PolicyContext):
        rtt = ctx.rtt
        hosts = store.hosts
        # Current read cost from node x: nearest replica in the key's set;
        # an empty set pays the topology's worst RTT (backing-store fetch).
        cost_now = jnp.min(
            jnp.where(hosts[:, None, :], rtt[None, :, :], jnp.inf), axis=-1
        )  # [K, N]
        cost_now = jnp.where(jnp.isfinite(cost_now), cost_now, jnp.max(rtt))
        local = jnp.diagonal(rtt)  # [N]
        saved_ms = store.access_counts.astype(jnp.float32) * jnp.maximum(
            cost_now - local[None, :], 0.0
        )
        per_kib = saved_ms / (ctx.object_bytes[:, None] / 1024.0)
        owners = hosts | (per_kib >= ctx.params["min_saved_ms_per_kib"])
        return owners, state


@register_policy
class DecayLFUPolicy(NamedTuple):
    """Redynis's eligibility rule computed on an exponentially-decayed
    access EMA the policy keeps in its *own state* (the metadata layer's
    raw counters stay untouched). Each sweep folds the accesses since the
    last committed sweep into ``ema = alpha * ema + delta`` and replicates
    where the EMA fraction clears H — reactive to traffic shifts like the
    engine-level count decay, but per-policy and composable."""

    h: float | None = None  # eligibility threshold on EMA fractions
    alpha: float = 0.5  # EMA retention per sweep (1.0 = raw counts)
    period: int = 1

    name = "decaylfu"
    DYNAMIC_FIELDS = ("h", "alpha")
    is_active = True
    read_mode = "map"
    initial_placement = "offsite"

    def resolve(self, num_nodes: int) -> "DecayLFUPolicy":
        return self if self.h is not None else self._replace(h=1.0 / num_nodes)

    def validate(self, num_nodes: int) -> None:
        validate_coefficient(self.h, num_nodes)
        if not (0.0 < self.alpha <= 1.0):
            raise ValueError(f"alpha must be in (0, 1], got {self.alpha}")
        _validate_common(self, period=self.period)

    def init(self, store: MetadataStore, ctx: PolicyContext):
        shape = store.access_counts.shape
        ema = jnp.zeros(shape, jnp.float32)
        prev = store.access_counts.astype(jnp.float32)
        return (ema, prev)

    def decide(self, state, store: MetadataStore, f: Array, now, ctx: PolicyContext):
        ema, prev = state
        counts = store.access_counts.astype(jnp.float32)
        ema = ema * ctx.params["alpha"] + (counts - prev)
        f_ema = ownership_fraction(ema)
        eligible = eligible_from_fractions(f_ema, ema, ctx.params["h"])
        touched = jnp.sum(ema, axis=-1) > 0
        owners = jnp.where(touched[:, None], eligible, store.hosts)
        return owners, (ema, counts)


@register_policy
class SizeAwarePolicy(NamedTuple):
    """Minos-style size-aware sharding (Didona & Zwaenepoel, 1802.00696):
    partition keys into small/large *pools* by object size and condition
    replica admission on the pool.

    Small objects (``object_bytes <= size_threshold_bytes``) replicate on
    every node once touched — they are cheap to hold and any node can then
    serve them locally, keeping the small-request pool free of queueing
    behind large transfers. Large objects keep a bounded fanout: the
    ``large_fanout`` nodes issuing most of their accesses (their modal
    source always included), which spreads each large object's service
    demand across its hottest sources instead of concentrating it — under
    ``ServiceConfig`` contention this is exactly the placement that keeps
    per-node load factors low, where ``costgreedy``'s per-KiB threshold
    refuses to replicate large objects at all and piles their demand onto
    a single serving node. Untouched keys keep their current placement."""

    size_threshold_bytes: float = 4096.0  # small/large pool cut
    large_fanout: float = 2.0  # replicas kept per touched large object
    decay: float = 1.0  # post-sweep count decay (shared stage)
    period: int = 1

    name = "sizeaware"
    DYNAMIC_FIELDS = ("size_threshold_bytes", "large_fanout", "decay")
    is_active = True
    read_mode = "map"
    initial_placement = "offsite"

    def resolve(self, num_nodes: int) -> "SizeAwarePolicy":
        return self

    def validate(self, num_nodes: int) -> None:
        if self.size_threshold_bytes < 0:
            raise ValueError(
                f"size_threshold_bytes must be non-negative, got "
                f"{self.size_threshold_bytes}"
            )
        if self.large_fanout < 1:
            raise ValueError(
                f"large_fanout must be >= 1 (every touched large object "
                f"keeps at least its modal source), got {self.large_fanout}"
            )
        _validate_common(self, decay=self.decay, period=self.period)

    def init(self, store: MetadataStore, ctx: PolicyContext):
        return ()

    def decide(self, state, store: MetadataStore, f: Array, now, ctx: PolicyContext):
        counts = store.access_counts  # [K, N]
        k, n = counts.shape
        touched = jnp.sum(counts, axis=-1) > 0
        small = ctx.object_bytes <= ctx.params["size_threshold_bytes"]
        # Per-key dense rank of nodes by access count, hottest first
        # (argsort is stable, so ties break to the lower node id).
        order = jnp.argsort(-counts, axis=-1)
        ranks = jnp.zeros_like(order).at[
            jnp.arange(k)[:, None], order
        ].set(jnp.arange(n, dtype=order.dtype)[None, :])
        # The rank cut alone would admit zero-traffic nodes whenever the
        # fanout exceeds a key's distinct sources — require real traffic,
        # but always keep the modal source (fanout >= 1 by validate()).
        modal = (
            jnp.arange(n, dtype=jnp.int32)
            == jnp.argmax(counts, axis=-1).astype(jnp.int32)[:, None]
        )
        narrow = ((ranks < ctx.params["large_fanout"]) & (counts > 0)) | modal
        pool = jnp.where(small[:, None], jnp.ones_like(store.hosts), narrow)
        owners = jnp.where(touched[:, None], pool, store.hosts)
        return owners, state


# ---------------------------------------------------------------------------
# The shared policy engine: decide + uniform expiry / capacity stages.
# ---------------------------------------------------------------------------


def _policy_sweep(
    policy,
    state,
    store: MetadataStore,
    now: Array | int,
    ctx: PolicyContext,
) -> tuple[PlacementPlan, object, MetadataStore]:
    """One full decision pass for any policy: fractions → ``decide`` →
    live/expiry mask → capacity projection → plan + store update (+ the
    policy's optional post-sweep count decay). ``policy`` must be a *static
    key* from :func:`split_policy`; dynamic knobs come from ``ctx.params``.
    """
    counts, hosts, live = store.access_counts, store.hosts, store.live

    if getattr(policy, "supplies_fractions", False):
        # Stages 1+2 fused: the policy's backend already produces f (the
        # Pallas ownership-sweep kernel) — reuse it, no [K, N] recompute.
        owners, f, state = policy.decide_fused(state, store, now, ctx)
    else:
        f = ownership_fraction(counts)  # stage 1: eq. 1, shared
        owners, state = policy.decide(state, store, f, now, ctx)  # stage 2

    # Stage 3 (uniform): dead keys own nothing; expiry purges silence.
    expiry = getattr(policy, "expiry", 0)
    if expiry and expiry > 0:
        expired = live & (
            (jnp.asarray(now, jnp.int32) - store.last_access) > expiry
        )
    else:
        expired = jnp.zeros_like(live)
    owners = owners & live[:, None] & ~expired[:, None]

    # Stage 3b (failure injection, compiled away at ctx.avail=None): the
    # daemon never places replicas on down nodes, and drops the copies a
    # down node still notionally holds — a rejoining node resyncs from
    # scratch, and a *crashed* node's lost copies get re-seeded onto live
    # nodes here, capped by the same capacity projection as any other move.
    if ctx.avail is not None:
        owners = owners & ctx.avail[None, :]

    # Stage 4 (uniform): per-node replica-byte budgets. Skipped entirely at
    # infinite budget (ctx.capacity_bytes is None — host-side static).
    if ctx.capacity_bytes is None:
        evicted = jnp.zeros_like(owners)
    else:
        owners, evicted, _ = project_capacity(
            owners, hosts, f, ctx.object_bytes, ctx.capacity_bytes
        )

    plan = PlacementPlan(
        owners=owners,
        to_add=owners & ~hosts,
        to_drop=hosts & ~owners,
        expired=expired,
        f=f,
        capacity_evicted=evicted,
    )
    new_counts = jnp.where(expired[:, None], 0, counts)
    if "decay" in ctx.params:
        # floor(count * decay) is an exact identity at decay == 1.0 for any
        # count below 2**24 (int32 -> f32 is exact there), so the legacy
        # static decay==1.0 fast path and this traced form are bit-equal.
        new_counts = jnp.floor(
            new_counts.astype(jnp.float32) * ctx.params["decay"]
        ).astype(jnp.int32)
    new_store = store._replace(
        hosts=owners,
        live=live & ~expired,
        access_counts=new_counts,
    )
    return plan, state, new_store


policy_sweep = partial(jax.jit, static_argnames=("policy",))(_policy_sweep)


def publish_mask(old_hosts: Array, new_hosts: Array) -> Array:
    """Per-key ``[K] bool``: which keys' replica rows a daemon step actually
    changed — the *versioned publish* a placement commit emits toward the
    directory tier (``repro.kvsim.routing``). Due-masked steps that commit
    nothing publish nothing, so directory versions only advance on real
    placement changes."""
    return jnp.any(old_hosts != new_hosts, axis=-1)


def policy_masked_step(
    policy,
    state,
    store: MetadataStore,
    now: Array | int,
    due: Array,
    ctx: PolicyContext,
) -> tuple[SweepStats, object, MetadataStore]:
    """Scan-compatible policy step: the sweep is always computed but only
    *committed* (store AND policy state) where ``due`` — the policy-generic
    analogue of :func:`repro.core.placement.masked_step`, safe inside
    ``lax.scan`` / ``vmap`` bodies with no data-dependent control flow."""
    plan, new_state, new_store = _policy_sweep(policy, state, store, now, ctx)
    new_state, new_store = jax.tree_util.tree_map(
        lambda a, b: jnp.where(due, a, b), (new_state, new_store), (state, store)
    )
    gate = lambda v: jnp.where(due, v.astype(jnp.float32), 0.0)
    stats = SweepStats(
        adds=gate(jnp.sum(plan.to_add)),
        drops=gate(jnp.sum(plan.to_drop)),
        expiry_evictions=gate(jnp.sum(plan.to_drop & plan.expired[:, None])),
        capacity_evictions=gate(jnp.sum(plan.capacity_evicted)),
    )
    return stats, new_state, new_store
