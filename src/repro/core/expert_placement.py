"""Traffic-aware MoE expert placement — Redynis integration #1 (flagship).

Objects are (layer, expert) pairs, nodes are EP ranks (the mesh's model
axis), traffic is the per-layer routing histogram the MoE layer emits every
step. The daemon runs the paper's full pipeline:

  1. fold routing counts into the [L·E, N] metadata (EMA-decayed),
  2. sweep with the ownership coefficient (the Pallas ``ownership_sweep``
     kernel — pure-JAX fallback off-TPU is the same oracle the tests pin),
  3. budget the plan to R replica slots per layer (costmodel.budget_plan —
     the paper's "minimal memory usage" assumption made explicit),
  4. emit per-layer hot sets ``hot_ids [L, R]`` which the MoE layer consumes
     — replica weights are gathered from live params inside the forward
     pass, so placement changes commit at a step boundary without ever
     blocking a step (the paper's non-blocking requirement).

Zipfian expert traffic is near-uniform across EP ranks (every rank sees the
same hot experts), so the ownership test typically qualifies *all* ranks for
a hot expert — global replication — exactly the regime the H ≤ 1/n
constraint (eq. 3) was designed for. The machinery still handles skewed
per-rank traffic (e.g. domain-sharded data) for free, which the property
tests exercise.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.ownership import validate_coefficient

__all__ = ["ExpertPlacementState", "ExpertPlacement"]


class ExpertPlacementState(NamedTuple):
    counts: Array  # [L, E, N] f32 EMA traffic g((l,e), n)
    hot_ids: Array  # [L, R] int32 current replica sets (-1 = empty slot)
    step: Array  # [] int32 steps folded since start
    sweeps: Array  # [] int32 sweeps performed
    moved: Array  # [] f32 replica slots changed by the last sweep


class ExpertPlacement:
    """Host-side daemon driver; all math is jitted device code."""

    def __init__(
        self,
        num_layers: int,
        num_experts: int,
        num_nodes: int,
        slots: int,
        *,
        h: float | None = None,
        decay: float = 0.98,
        period: int = 50,
        use_kernel: bool = True,
    ) -> None:
        if h is None or h <= 0:
            h = 1.0 / num_nodes
        validate_coefficient(h, num_nodes)
        self.l, self.e, self.n = num_layers, num_experts, num_nodes
        self.r = slots
        self.h = h
        self.decay = decay
        self.period = period
        self.use_kernel = use_kernel

    def init_state(self) -> ExpertPlacementState:
        # Start with an arbitrary warm set (experts 0..R-1) so the reduced
        # cold capacity is never starved before the first sweep.
        hot = jnp.broadcast_to(
            jnp.arange(self.r, dtype=jnp.int32)[None, :], (self.l, self.r)
        )
        return ExpertPlacementState(
            counts=jnp.zeros((self.l, self.e, self.n), jnp.float32),
            hot_ids=hot,
            step=jnp.zeros((), jnp.int32),
            sweeps=jnp.zeros((), jnp.int32),
            moved=jnp.zeros((), jnp.float32),
        )

    # -- step-time fold (cheap, inside or right after the train step) -------
    def fold(
        self, state: ExpertPlacementState, layer_counts: Array, group_nodes: Array
    ) -> ExpertPlacementState:
        """layer_counts [L, G, E] from the model; group_nodes [G] int32 maps
        dispatch groups to EP ranks (launch layer knows the mesh layout)."""
        onehot = jax.nn.one_hot(group_nodes, self.n, dtype=jnp.float32)  # [G, N]
        delta = jnp.einsum("lge,gn->len", layer_counts, onehot)
        return state._replace(counts=state.counts + delta, step=state.step + 1)

    def due(self, step: int) -> bool:
        return step > 0 and step % self.period == 0

    # -- sweep (Algorithm 3 + replica budget), jitted ------------------------
    @partial(jax.jit, static_argnums=(0,))
    def sweep(self, state: ExpertPlacementState) -> ExpertPlacementState:
        l, e, n, r = self.l, self.e, self.n, self.r
        flat = state.counts.reshape(l * e, n)

        if self.use_kernel:
            from repro.kernels.ownership_sweep.ops import ownership_sweep

            owners, _, _, _, f = ownership_sweep(
                flat,
                jnp.zeros((l * e, n), bool),
                jnp.ones((l * e,), bool),
                jnp.zeros((l * e,), jnp.int32),
                0,
                h=self.h,
            )
        else:
            from repro.kernels.ownership_sweep.ref import sweep_ref

            owners, _, _, _, f = sweep_ref(
                flat,
                jnp.zeros((l * e, n), bool),
                jnp.ones((l * e,), bool),
                jnp.zeros((l * e,), jnp.int32),
                0,
                h=self.h,
            )

        # Replication demand: an expert wants replicas where it qualifies.
        # Budget: R slots per layer, hottest (by total traffic) first — the
        # costmodel trim specialised to equal-sized objects.
        qualify = jnp.any(owners, axis=-1).reshape(l, e)
        total = jnp.sum(state.counts, axis=-1)  # [L, E]
        score = jnp.where(qualify & (total > 0), total, -1.0)
        _, top = jax.lax.top_k(score, r)  # [L, R]
        valid = jnp.take_along_axis(score, top, axis=-1) > 0
        new_hot = jnp.where(valid, top, -1).astype(jnp.int32)

        # Keep the previous set on layers with no traffic at all (no churn
        # on silence — same rule as placement.sweep).
        layer_touched = jnp.sum(total, axis=-1, keepdims=True) > 0
        new_hot = jnp.where(layer_touched, new_hot, state.hot_ids)

        moved = jnp.sum(
            jnp.all(new_hot[:, :, None] != state.hot_ids[:, None, :], axis=-1)
        ).astype(jnp.float32)
        return ExpertPlacementState(
            counts=state.counts * self.decay,
            hot_ids=new_hot,
            step=state.step,
            sweeps=state.sweeps + 1,
            moved=moved,
        )

    # -- diagnostics ---------------------------------------------------------
    def hit_rate(self, state: ExpertPlacementState) -> Array:
        """Fraction of (EMA) traffic the current replica sets would serve."""
        total = jnp.sum(state.counts, axis=(-1, -2))  # [L]
        safe_ids = jnp.clip(state.hot_ids, 0, self.e - 1)
        per_layer = jnp.sum(state.counts, axis=-1)  # [L, E]
        hot_traffic = jnp.sum(
            jnp.take_along_axis(per_layer, safe_ids, axis=-1)
            * (state.hot_ids >= 0),
            axis=-1,
        )
        return jnp.sum(hot_traffic) / jnp.maximum(jnp.sum(total), 1.0)
