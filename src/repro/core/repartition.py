"""Repartition execution — turning a PlacementPlan into scheduled data moves.

The paper's daemon "enforces changes to the key-value store instances" with
per-key RPCs. On a TPU mesh the payloads are tensors and the transport is a
collective, so enforcement becomes: publish the objects that gained replicas
this sweep with ONE fused all-gather over the owning mesh axis, then have
each rank copy the slots it now owns into its local replica cache.

Two properties the paper requires are preserved:

  * **non-blocking** — the plan is computed offline (sweep) and committed at
    a step boundary; until commit, consumers read the previous replica map
    (double buffering — ``CommitState`` below).
  * **bounded memory** — the replica cache has a fixed slot count, and the
    plans this layer consumes are *post-projection*: the sweep's capacity
    stage (costmodel.project_capacity) has already evicted what doesn't fit
    a node's byte budget, so ``plan.owners`` never schedules an evicted
    replica into a slot and ``publish_ids`` never carries a rejected add.
    ``Moves.slot_bytes`` reports the resulting per-rank cache residency.

The functions are written to be used either inside ``shard_map`` (axis_name
set, real collectives) or host-side in the simulator (axis_name None).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.placement import PlacementPlan

__all__ = [
    "ReplicaCache",
    "create_cache",
    "plan_moves",
    "publish_and_fill",
    "CommitState",
]


class ReplicaCache(NamedTuple):
    """Fixed-capacity per-rank replica store for K-object state.

    ids:  [C] int32 — object id held in each slot (-1 = empty)
    data: [C, ...]  — payloads
    """

    ids: Array
    data: Array

    @property
    def capacity(self) -> int:
        return self.ids.shape[0]

    def lookup(self, object_id: Array) -> Array:
        """Slot index holding ``object_id`` or -1 — O(C) compare, C is small."""
        hit = self.ids == object_id[..., None]
        return jnp.where(jnp.any(hit, -1), jnp.argmax(hit, -1), -1).astype(jnp.int32)


def create_cache(capacity: int, payload_shape: tuple, dtype=jnp.float32) -> ReplicaCache:
    return ReplicaCache(
        ids=jnp.full((capacity,), -1, jnp.int32),
        data=jnp.zeros((capacity, *payload_shape), dtype),
    )


class Moves(NamedTuple):
    """Static-shape move schedule for one sweep (padded to max_moves)."""

    publish_ids: Array  # [M] int32 object ids this sweep publishes (-1 pad)
    slot_ids: Array  # [N, C] int32 desired cache contents per rank (-1 empty)
    moved_bytes: Array  # [] float32 total bytes the fused all-gather carries
    slot_bytes: Array  # [N] f32 bytes resident per rank's cache post-move


def plan_moves(
    plan: PlacementPlan,
    home: Array,  # [K] int32 home rank of each object
    cache_capacity: int,
    max_moves: int,
    object_bytes: Array | float,
    priority: Array | None = None,  # [K] float; higher = keep first
) -> Moves:
    """Compile a PlacementPlan into a static-shape move schedule.

    Replicas beyond the home shard live in caches; the desired cache contents
    of rank ``n`` are the objects with ``owners[k, n] & (home[k] != n)``,
    truncated to capacity (a capacity-projected plan already fits — the
    sweep's projection stage evicted anything over the node's byte budget,
    so slot truncation is a backstop, not the budget mechanism). With
    ``priority`` (e.g. total access counts) the truncation keeps the hottest
    objects first, ties broken by object id; without it the order is object
    id — deterministic either way. Newly published objects are those
    appearing in any rank's adds.
    """
    k, n = plan.owners.shape
    arange_k = jnp.arange(k, dtype=jnp.int32)
    obj_k = jnp.broadcast_to(jnp.asarray(object_bytes, jnp.float32), (k,))

    if priority is None:
        rank = arange_k  # id order
    else:
        # Dense rank by descending priority (stable -> ties by id).
        pos = jnp.argsort(-jnp.asarray(priority, jnp.float32), stable=True)
        rank = jnp.zeros((k,), jnp.int32).at[pos].set(arange_k)

    want = plan.owners & (home[:, None] != jnp.arange(n)[None, :])  # [K, N]
    # Per-rank desired slots: stable top-capacity by rank (deterministic).
    def slots_for(col: Array) -> Array:
        score = jnp.where(col, rank, k)  # unwanted sorts last
        order = jnp.argsort(score)[:cache_capacity]  # key ids, best first
        return jnp.where(score[order] < k, order.astype(jnp.int32), -1)

    slot_ids = jax.vmap(slots_for, in_axes=1, out_axes=0)(want)  # [N, C]

    added_any = jnp.any(plan.to_add, axis=-1)  # [K]
    pub = jnp.where(added_any, arange_k, k)
    pub = jnp.sort(pub)[:max_moves]
    publish_ids = jnp.where(pub < k, pub, -1).astype(jnp.int32)

    nbytes = jnp.sum(jnp.where(added_any, obj_k, 0.0))
    slot_bytes = jnp.sum(
        jnp.where(slot_ids >= 0, obj_k[jnp.clip(slot_ids, 0)], 0.0), axis=-1
    )
    return Moves(
        publish_ids=publish_ids,
        slot_ids=slot_ids,
        moved_bytes=nbytes,
        slot_bytes=slot_bytes,
    )


def publish_and_fill(
    cache: ReplicaCache,
    moves: Moves,
    local_objects: Array,  # [K_local, ...] this rank's home shard
    local_ids: Array,  # [K_local] global object ids of the home shard
    rank: Array | int,
    axis_name: str | None = None,
) -> ReplicaCache:
    """Execute one sweep's moves: every rank contributes the published objects
    it homes (zeros elsewhere), a single all-reduce materialises the publish
    buffer everywhere, and each rank refreshes its cache slots.

    With ``axis_name=None`` (simulator / single process) the publish buffer is
    built directly — semantics identical, no collective.
    """
    m = moves.publish_ids.shape[0]
    payload_shape = local_objects.shape[1:]

    # Gather my contribution: for each publish slot, my local copy if I home it.
    eq = moves.publish_ids[:, None] == local_ids[None, :]  # [M, K_local]
    have = jnp.any(eq, axis=-1)
    src = jnp.argmax(eq, axis=-1)
    contrib = jnp.where(
        have.reshape(m, *([1] * len(payload_shape))),
        local_objects[src],
        jnp.zeros((m, *payload_shape), local_objects.dtype),
    )
    if axis_name is not None:
        # Exactly one rank homes each object -> sum == broadcast. One fused
        # collective for the whole sweep (the paper's per-key RPCs, batched).
        publish = jax.lax.psum(contrib, axis_name)
    else:
        publish = contrib

    # Refresh cache: slots whose desired object was just published get new
    # data; others keep old contents if still desired, else empty.
    desired = moves.slot_ids[rank] if moves.slot_ids.ndim == 2 else moves.slot_ids
    c = cache.capacity
    pub_hit = desired[:, None] == moves.publish_ids[None, :]  # [C, M]
    from_pub = jnp.any(pub_hit, axis=-1) & (desired >= 0)
    pub_src = jnp.argmax(pub_hit, axis=-1)

    old_hit = desired[:, None] == cache.ids[None, :]  # [C, C]
    from_old = jnp.any(old_hit, axis=-1) & (desired >= 0) & ~from_pub
    old_src = jnp.argmax(old_hit, axis=-1)

    exp = lambda v: v.reshape(c, *([1] * len(payload_shape)))
    data = jnp.where(
        exp(from_pub),
        publish[pub_src],
        jnp.where(exp(from_old), cache.data[old_src], 0),
    ).astype(cache.data.dtype)
    ids = jnp.where(from_pub | from_old, desired, -1).astype(jnp.int32)
    return ReplicaCache(ids=ids, data=data)


class CommitState(NamedTuple):
    """Double-buffered replica map: consumers read ``active`` while the daemon
    prepares ``staged``; ``commit`` flips at a step boundary (non-blocking)."""

    active: ReplicaCache
    staged: ReplicaCache

    @staticmethod
    def create(cache: ReplicaCache) -> "CommitState":
        return CommitState(active=cache, staged=cache)

    def stage(self, new: ReplicaCache) -> "CommitState":
        return self._replace(staged=new)

    def commit(self) -> "CommitState":
        return CommitState(active=self.staged, staged=self.staged)
