"""Ownership coefficient — the heart of Redynis (paper §6.1).

For an object ``O`` and node ``x``::

    g(O, x) = count(accesses on O by x)
    f(O, x) = g(O, x) / g(O, all nodes)                      (eq. 1)

Node ``x`` is entitled to a local replica of ``O`` iff ``f(O, x) - H >= 0``
(eq. 2), under the starvation-avoidance constraint ``H - 1/n <= 0`` (eq. 3):
with ``H <= 1/n`` the pigeonhole principle guarantees at least one node always
qualifies (``max_x f(O, x) >= 1/n``), so a live key can never lose *all* of
its replicas to the placement daemon.

Everything here is pure, vectorised JAX over ``[K, N]`` count matrices
(K objects × N nodes) so a full-cluster analysis pass is a single fused
device computation — this is the paper's "constant time per key" claim,
realised as O(K·N) total work with no graph traversal.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import Array

__all__ = [
    "validate_coefficient",
    "max_coefficient",
    "ownership_fraction",
    "eligible_hosts",
    "eligible_from_fractions",
]


def validate_coefficient(h: float, n_nodes: int) -> None:
    """Enforce the paper's eq. 3 constraint ``H <= 1/n`` (host-side check)."""
    if n_nodes <= 0:
        raise ValueError(f"n_nodes must be positive, got {n_nodes}")
    if not (0.0 < h <= 1.0 / n_nodes + 1e-12):
        raise ValueError(
            f"ownership coefficient H={h} violates 0 < H <= 1/n "
            f"(n={n_nodes}, 1/n={1.0 / n_nodes:.6f}); see paper eq. 3"
        )


def max_coefficient(n_nodes: int) -> float:
    """Largest admissible H for an ``n_nodes`` cluster (= 1/n)."""
    return 1.0 / n_nodes


def ownership_fraction(counts: Array) -> Array:
    """Eq. 1: per-node access fraction ``f(O, x)``.

    counts: ``[..., N]`` access counts ``g(O, x)``.
    Returns ``f`` with the convention ``f = 0`` where the object has never
    been accessed (total == 0) — callers keep the existing replica set in
    that case rather than churning.
    """
    counts = counts.astype(jnp.float32)
    total = jnp.sum(counts, axis=-1, keepdims=True)
    return jnp.where(total > 0, counts / jnp.maximum(total, 1.0), 0.0)


def eligible_hosts(counts: Array, h: Array | float) -> Array:
    """Eq. 2 vectorised: boolean ``[..., N]`` mask of nodes with ``f >= H``.

    A numeric starvation guard mirrors eq. 3's intent: if (through a
    misconfigured H or float round-off) no node qualifies for an object that
    *has* traffic, the argmax node is forced eligible so the object never
    becomes unreachable. (The guard governs *eligibility* only; a finite
    replica-byte budget downstream may still evict the last replica — see
    the last-replica note in ``costmodel.py``.)
    """
    return eligible_from_fractions(ownership_fraction(counts), counts, h)


def eligible_from_fractions(f: Array, counts: Array, h: Array | float) -> Array:
    """Eligibility stage of the placement pipeline, from *precomputed*
    fractions (eq. 1 output). Splitting this from :func:`eligible_hosts`
    lets backends that already produce ``f`` (the Pallas ownership-sweep
    kernel) feed the scoring/eligibility stages without recomputing it.
    Semantics are identical to ``eligible_hosts(counts, h)``.
    """
    mask = f >= jnp.asarray(h, dtype=f.dtype)
    total = jnp.sum(counts, axis=-1, keepdims=True)
    has_traffic = jnp.squeeze(total > 0, axis=-1)
    none_qualify = has_traffic & ~jnp.any(mask, axis=-1)
    argmax_hot = jnp.argmax(counts, axis=-1)
    fallback = jax_one_hot_like(mask, argmax_hot)
    return jnp.where(none_qualify[..., None], fallback, mask)


def jax_one_hot_like(mask: Array, idx: Array) -> Array:
    """Boolean one-hot along the last axis, same shape as ``mask``."""
    n = mask.shape[-1]
    return jnp.arange(n, dtype=idx.dtype) == idx[..., None]
