"""Placement daemon — the paper's Algorithm 3 as a scored placement pipeline.

The paper's daemon loops over all keys, and per key:

    1. expire:   if now > lastAccessed + expiry  -> delete key everywhere
    2. analyse:  f(O, x) = hostAccesses[x] / totalAccesses
                 f >= H  -> owner_hosts  ;  f < H -> delete_hosts
    3. plan:     new_hosts      = owner_hosts  - current_hosts     (replicate)
                 obsolete_hosts = current_hosts ∩ delete_hosts     (drop)
    4. enforce:  update metadata + move data

Here steps 1–3 are a staged pipeline over the ``[K, N]`` metadata arrays:

    fractions ──► eligibility ──► capacity projection ──► plan
      (eq. 1)      (eq. 2 + guard      (costmodel.project_capacity:
                    + expiry)           per-node replica-byte budgets)

with a pluggable *sweep backend* for the dominant ``[K, N]`` pass:

    backend="jax"     fractions + eligibility in pure jnp (XLA)
    backend="pallas"  the ``repro.kernels.ownership_sweep`` TPU kernel; its
                      ``f`` output feeds the projection's scoring directly
                      (no recompute), and the capacity projection runs as an
                      XLA post-pass on the kernel outputs.

The projection stage is skipped entirely when ``capacity_bytes is None``
(compiled away — bit-exact Algorithm 3), and is a bit-exact identity at an
infinite budget (pinned by property tests). Under byte pressure it may
evict a key's *last* replica — the budget outranks the eligibility layer's
starvation guard; see the last-replica note in ``costmodel.py`` (replicas
are a bounded cache over a backing store, and replica-less reads pay the
topology's worst RTT in the simulator). Step 4 is split out
(`apply_plan`) so the enforcement can run *offline / non-blocking* exactly
as the paper requires: the serving or training step keeps using the old
replica map until the plan is committed at a step boundary (see
``repro/core/repartition.py`` double-buffering).

Expiry convention (unified across backends): ``expiry in (None, 0)`` means
*disabled*; a positive value purges keys untouched for more than ``expiry``
ticks. ``PlacementDaemon`` validates this at construction.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.costmodel import project_capacity
from repro.core.metadata import MetadataStore
from repro.core.ownership import (
    eligible_from_fractions,
    ownership_fraction,
    validate_coefficient,
)

__all__ = [
    "PlacementPlan",
    "SweepStats",
    "SWEEP_BACKENDS",
    "redynis_candidates",
    "sweep",
    "apply_plan",
    "masked_step",
    "PlacementDaemon",
]

SWEEP_BACKENDS = ("jax", "pallas")


class PlacementPlan(NamedTuple):
    """Output of one analysis pass (Algorithm 3 steps 1-3)."""

    owners: Array  # [K, N] bool  -- post-sweep replica set (owner_hosts)
    to_add: Array  # [K, N] bool  -- new_hosts      = owners - current
    to_drop: Array  # [K, N] bool -- obsolete_hosts = current ∩ delete
    expired: Array  # [K]   bool  -- keys past expiry (deleted everywhere)
    # Scored-pipeline extras (None on hand-built plans):
    f: Array | None = None  # [K, N] f32 ownership fractions (the score)
    capacity_evicted: Array | None = None  # [K, N] bool held replicas evicted

    def replication_bytes(self, object_bytes: Array | float) -> Array:
        """Bytes the enforcement phase must move (adds × object size)."""
        per_key = jnp.sum(self.to_add, axis=-1).astype(jnp.float32)
        return jnp.sum(per_key * object_bytes)


class SweepStats(NamedTuple):
    """Scalar move accounting for one (possibly masked) daemon step."""

    adds: Array  # f32 — replicas created
    drops: Array  # f32 — replicas dropped (threshold + expiry + capacity)
    expiry_evictions: Array  # f32 — drops attributable to key expiry
    capacity_evictions: Array  # f32 — held replicas evicted by projection


def _expiry_enabled(expiry: int | None) -> bool:
    """Unified convention: ``None`` and ``0`` both disable expiry."""
    return expiry is not None and expiry > 0


def redynis_candidates(store: MetadataStore, f: Array, h: Array | float) -> Array:
    """Algorithm 3's candidate replica set from precomputed fractions:
    eligibility (eq. 2 + starvation guard), silence keeps the current
    placement, dead keys own nothing. This is the *decide* stage shared by
    the legacy ``sweep`` jax path and ``core.policy.RedynisPolicy`` — one
    definition so the two can never drift."""
    counts, hosts, live = store.access_counts, store.hosts, store.live
    eligible = eligible_from_fractions(f, counts, h)
    touched = jnp.sum(counts, axis=-1) > 0
    # Keys with no traffic keep their current placement (no churn on silence).
    owners = jnp.where(touched[:, None], eligible, hosts)
    return owners & live[:, None]


@partial(jax.jit, static_argnames=("expiry", "backend"))
def sweep(
    store: MetadataStore,
    h: Array | float,
    now: Array | int,
    expiry: int | None = None,
    *,
    object_bytes: Array | None = None,
    capacity_bytes: Array | None = None,
    backend: str = "jax",
    avail: Array | None = None,
) -> tuple[PlacementPlan, MetadataStore]:
    """One full-cluster analysis pass. Returns the plan and the metadata
    store with the plan already reflected (hosts/live updated, counts of
    expired keys cleared) — the *data* movement is the caller's step 4.

    h:      ownership coefficient (validated against N by the daemon).
    expiry: ticks after which an untouched key is purged; ``None`` or ``0``
            disables (static so the expiry branch compiles away when unused).
    object_bytes:   ``[K]`` per-key payload size (defaults to 1.0 each —
            budgets then count replicas), used by the projection stage.
    capacity_bytes: ``[N]`` (or scalar) per-node replica-byte budget; ``None``
            skips the projection stage entirely (bit-exact Algorithm 3), and
            an infinite budget is a bit-exact identity.
    backend: "jax" (pure-XLA) or "pallas" (``kernels.ownership_sweep``; the
            kernel's ``f`` output feeds the projection scoring directly).
    avail:  ``[N] bool`` node availability under failure injection; ``None``
            (the default, fault-free) compiles with no membership mask. A
            present mask keeps the daemon off down nodes and drops the
            copies they held — capped by the same capacity projection.
    """
    counts, hosts, live = store.access_counts, store.hosts, store.live
    k = store.num_keys

    if backend == "pallas":
        from repro.kernels.ownership_sweep.ops import ownership_sweep

        owners, _, _, expired, f = ownership_sweep(
            counts,
            hosts,
            live,
            store.last_access,
            now,
            h=h,
            expiry=expiry if _expiry_enabled(expiry) else 0,
        )
    elif backend == "jax":
        f = ownership_fraction(counts)  # stage 1: fractions (eq. 1)
        owners = redynis_candidates(store, f, h)  # stage 2: eq. 2 + guard

        if _expiry_enabled(expiry):
            expired = live & (
                (jnp.asarray(now, jnp.int32) - store.last_access) > expiry
            )
        else:
            expired = jnp.zeros_like(live)
        owners = owners & ~expired[:, None]
    else:
        raise ValueError(
            f"unknown sweep backend {backend!r}; expected one of {SWEEP_BACKENDS}"
        )

    # Stage 2b (failure injection, compiled away at avail=None): never place
    # on down nodes; a down node's notional copies drop (rejoin = resync).
    if avail is not None:
        owners = owners & avail[None, :]

    # Stage 3: capacity projection (per-node replica-byte budgets).
    if capacity_bytes is None:
        evicted = jnp.zeros_like(owners)
    else:
        ob = (
            jnp.ones((k,), jnp.float32)
            if object_bytes is None
            else jnp.asarray(object_bytes, jnp.float32)
        )
        owners, evicted, _ = project_capacity(
            owners, hosts, f, ob, capacity_bytes
        )

    plan = PlacementPlan(
        owners=owners,
        to_add=owners & ~hosts,
        to_drop=hosts & ~owners,
        expired=expired,
        f=f,
        capacity_evicted=evicted,
    )
    new_store = store._replace(
        hosts=owners,
        live=live & ~expired,
        access_counts=jnp.where(expired[:, None], 0, counts),
    )
    return plan, new_store


def apply_plan(values_present: Array, plan: PlacementPlan) -> Array:
    """Enforce a plan on a ``[K, N]`` presence mask of actual value replicas
    (the data layer's view). Kept separate from `sweep` so enforcement can be
    deferred / overlapped; see repartition.py for the tensor-payload version.
    """
    present = values_present | plan.to_add
    present = present & ~plan.to_drop & ~plan.expired[:, None]
    return present


def _decay_counts(store: MetadataStore, decay: float) -> MetadataStore:
    """Beyond-paper: exponential decay keeps the heuristics reactive to
    traffic *shifts* (the paper's raw counters saturate — an object hot
    yesterday and cold today keeps stale ownership for a long time).
    Applied post-sweep so each sweep sees fresh-ish counts. Shared by the
    host-side daemon and the scan-compatible `masked_step` so the fused
    engine and its reference oracle cannot desynchronize."""
    if decay >= 1.0:
        return store
    return store._replace(
        access_counts=jnp.floor(
            store.access_counts.astype(jnp.float32) * decay
        ).astype(jnp.int32)
    )


def masked_step(
    store: MetadataStore,
    now: Array | int,
    due: Array,
    *,
    h: Array | float,
    expiry: int | None = None,
    decay: float = 1.0,
    object_bytes: Array | None = None,
    capacity_bytes: Array | None = None,
    backend: str = "jax",
    avail: Array | None = None,
) -> tuple[SweepStats, MetadataStore]:
    """Scan-compatible daemon step: fixed-shape replacement for the host-side
    ``if daemon.due(tick): daemon.step(...)`` pattern.

    The sweep is always computed but only *committed* where ``due`` (a traced
    bool) — off ticks return the store unchanged, so the step can live inside
    ``jax.lax.scan`` / ``vmap`` bodies with no data-dependent control flow.

    Returns ``(stats, store)``: a :class:`SweepStats` of replicas created /
    dropped / evicted this tick (all 0.0 when not due) and the
    conditionally-updated metadata store.
    """
    plan, swept = sweep(
        store,
        h,
        now,
        expiry,
        object_bytes=object_bytes,
        capacity_bytes=capacity_bytes,
        backend=backend,
        avail=avail,
    )
    swept = _decay_counts(swept, decay)
    new_store = jax.tree_util.tree_map(
        lambda a, b: jnp.where(due, a, b), swept, store
    )
    gate = lambda v: jnp.where(due, v.astype(jnp.float32), 0.0)
    stats = SweepStats(
        adds=gate(jnp.sum(plan.to_add)),
        drops=gate(jnp.sum(plan.to_drop)),
        expiry_evictions=gate(jnp.sum(plan.to_drop & plan.expired[:, None])),
        capacity_evictions=gate(jnp.sum(plan.capacity_evicted)),
    )
    return stats, new_store


class PlacementDaemon:
    """Periodic offline repartitioner (paper §5.1 'Placement Daemon').

    Host-side driver: holds H (validated against the cluster size), the decay
    and expiry policy, the sweep backend, and runs `sweep` every ``period``
    ticks. It is deliberately *stateless between sweeps* apart from the
    metadata store it is handed — mirroring the paper's daemon, which only
    reads the metadata layer and enforces changes.
    """

    def __init__(
        self,
        num_nodes: int,
        h: float | None = None,
        expiry: int | None = None,
        period: int = 1,
        decay: float = 1.0,
        backend: str = "jax",
    ) -> None:
        if h is None:
            h = 1.0 / num_nodes
        validate_coefficient(h, num_nodes)
        if not (0.0 < decay <= 1.0):
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        if expiry is not None and expiry < 0:
            raise ValueError(
                f"expiry must be None or a non-negative tick count, got "
                f"{expiry} (0 disables expiry, on every backend)"
            )
        if backend not in SWEEP_BACKENDS:
            raise ValueError(
                f"unknown sweep backend {backend!r}; expected one of "
                f"{SWEEP_BACKENDS}"
            )
        self.num_nodes = num_nodes
        self.h = h
        self.expiry = expiry
        self.period = period
        self.decay = decay
        self.backend = backend

    def due(self, tick: int) -> bool:
        return tick % self.period == 0

    def step(
        self,
        store: MetadataStore,
        now: Array | int,
        *,
        object_bytes: Array | None = None,
        capacity_bytes: Array | None = None,
        avail: Array | None = None,
    ) -> tuple[PlacementPlan, MetadataStore]:
        plan, store = sweep(
            store,
            self.h,
            now,
            self.expiry,
            object_bytes=object_bytes,
            capacity_bytes=capacity_bytes,
            backend=self.backend,
            avail=avail,
        )
        return plan, _decay_counts(store, self.decay)

    def masked_step(
        self,
        store: MetadataStore,
        now: Array | int,
        due: Array,
        *,
        object_bytes: Array | None = None,
        capacity_bytes: Array | None = None,
        avail: Array | None = None,
    ) -> tuple[SweepStats, MetadataStore]:
        """Scan-compatible `step`: commit only where ``due`` (traced bool)."""
        return masked_step(
            store,
            now,
            due,
            h=self.h,
            expiry=self.expiry,
            decay=self.decay,
            object_bytes=object_bytes,
            capacity_bytes=capacity_bytes,
            backend=self.backend,
            avail=avail,
        )
