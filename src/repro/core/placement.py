"""Placement daemon — the paper's Algorithm 3, vectorised.

The paper's daemon loops over all keys, and per key:

    1. expire:   if now > lastAccessed + expiry  -> delete key everywhere
    2. analyse:  f(O, x) = hostAccesses[x] / totalAccesses
                 f >= H  -> owner_hosts  ;  f < H -> delete_hosts
    3. plan:     new_hosts      = owner_hosts  - current_hosts     (replicate)
                 obsolete_hosts = current_hosts ∩ delete_hosts     (drop)
    4. enforce:  update metadata + move data

Here steps 1–3 are a single fused sweep over the ``[K, N]`` metadata arrays
(`sweep`, pure JAX — a Pallas kernel with identical semantics lives in
``repro.kernels.ownership_sweep`` for the TPU hot path), producing a
:class:`PlacementPlan`. Step 4 is split out (`apply_plan`) so the enforcement
can run *offline / non-blocking* exactly as the paper requires: the serving
or training step keeps using the old replica map until the plan is committed
at a step boundary (see ``repro/core/repartition.py`` double-buffering).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.metadata import MetadataStore
from repro.core.ownership import eligible_hosts, validate_coefficient

__all__ = ["PlacementPlan", "sweep", "apply_plan", "masked_step", "PlacementDaemon"]


class PlacementPlan(NamedTuple):
    """Output of one analysis pass (Algorithm 3 steps 1-3)."""

    owners: Array  # [K, N] bool  -- post-sweep replica set (owner_hosts)
    to_add: Array  # [K, N] bool  -- new_hosts      = owners - current
    to_drop: Array  # [K, N] bool -- obsolete_hosts = current ∩ delete
    expired: Array  # [K]   bool  -- keys past expiry (deleted everywhere)

    def replication_bytes(self, object_bytes: Array | float) -> Array:
        """Bytes the enforcement phase must move (adds × object size)."""
        per_key = jnp.sum(self.to_add, axis=-1).astype(jnp.float32)
        return jnp.sum(per_key * object_bytes)


@partial(jax.jit, static_argnames=("expiry",))
def sweep(
    store: MetadataStore,
    h: Array | float,
    now: Array | int,
    expiry: int | None = None,
) -> tuple[PlacementPlan, MetadataStore]:
    """One full-cluster analysis pass. Returns the plan and the metadata
    store with the plan already reflected (hosts/live updated, counts of
    expired keys cleared) — the *data* movement is the caller's step 4.

    h:      ownership coefficient (validated against N by the daemon).
    expiry: ticks after which an untouched key is purged; ``None`` disables
            (static so the expiry branch compiles away when unused).
    """
    counts, hosts, live = store.access_counts, store.hosts, store.live

    eligible = eligible_hosts(counts, h)  # eq. 2 over all K keys at once
    touched = jnp.sum(counts, axis=-1) > 0
    # Keys with no traffic keep their current placement (no churn on silence).
    owners = jnp.where(touched[:, None], eligible, hosts)
    owners = owners & live[:, None]

    if expiry is not None:
        expired = live & ((jnp.asarray(now, jnp.int32) - store.last_access) > expiry)
    else:
        expired = jnp.zeros_like(live)
    owners = owners & ~expired[:, None]

    plan = PlacementPlan(
        owners=owners,
        to_add=owners & ~hosts,
        to_drop=hosts & ~owners,
        expired=expired,
    )
    new_store = store._replace(
        hosts=owners,
        live=live & ~expired,
        access_counts=jnp.where(expired[:, None], 0, counts),
    )
    return plan, new_store


def apply_plan(values_present: Array, plan: PlacementPlan) -> Array:
    """Enforce a plan on a ``[K, N]`` presence mask of actual value replicas
    (the data layer's view). Kept separate from `sweep` so enforcement can be
    deferred / overlapped; see repartition.py for the tensor-payload version.
    """
    present = values_present | plan.to_add
    present = present & ~plan.to_drop & ~plan.expired[:, None]
    return present


def _decay_counts(store: MetadataStore, decay: float) -> MetadataStore:
    """Beyond-paper: exponential decay keeps the heuristics reactive to
    traffic *shifts* (the paper's raw counters saturate — an object hot
    yesterday and cold today keeps stale ownership for a long time).
    Applied post-sweep so each sweep sees fresh-ish counts. Shared by the
    host-side daemon and the scan-compatible `masked_step` so the fused
    engine and its reference oracle cannot desynchronize."""
    if decay >= 1.0:
        return store
    return store._replace(
        access_counts=jnp.floor(
            store.access_counts.astype(jnp.float32) * decay
        ).astype(jnp.int32)
    )


def masked_step(
    store: MetadataStore,
    now: Array | int,
    due: Array,
    *,
    h: Array | float,
    expiry: int | None = None,
    decay: float = 1.0,
) -> tuple[Array, Array, MetadataStore]:
    """Scan-compatible daemon step: fixed-shape replacement for the host-side
    ``if daemon.due(tick): daemon.step(...)`` pattern.

    The sweep is always computed but only *committed* where ``due`` (a traced
    bool) — off ticks return the store unchanged, so the step can live inside
    ``jax.lax.scan`` / ``vmap`` bodies with no data-dependent control flow.

    Returns ``(adds, drops, store)``: replicas created / dropped this tick
    (0.0 when not due) and the conditionally-updated metadata store.
    """
    plan, swept = sweep(store, h, now, expiry)
    swept = _decay_counts(swept, decay)
    new_store = jax.tree_util.tree_map(
        lambda a, b: jnp.where(due, a, b), swept, store
    )
    adds = jnp.where(due, jnp.sum(plan.to_add).astype(jnp.float32), 0.0)
    drops = jnp.where(due, jnp.sum(plan.to_drop).astype(jnp.float32), 0.0)
    return adds, drops, new_store


class PlacementDaemon:
    """Periodic offline repartitioner (paper §5.1 'Placement Daemon').

    Host-side driver: holds H (validated against the cluster size), the decay
    and expiry policy, and runs `sweep` every ``period`` ticks. It is
    deliberately *stateless between sweeps* apart from the metadata store it
    is handed — mirroring the paper's daemon, which only reads the metadata
    layer and enforces changes.
    """

    def __init__(
        self,
        num_nodes: int,
        h: float | None = None,
        expiry: int | None = None,
        period: int = 1,
        decay: float = 1.0,
    ) -> None:
        if h is None:
            h = 1.0 / num_nodes
        validate_coefficient(h, num_nodes)
        if not (0.0 < decay <= 1.0):
            raise ValueError(f"decay must be in (0, 1], got {decay}")
        self.num_nodes = num_nodes
        self.h = h
        self.expiry = expiry
        self.period = period
        self.decay = decay

    def due(self, tick: int) -> bool:
        return tick % self.period == 0

    def step(
        self, store: MetadataStore, now: Array | int
    ) -> tuple[PlacementPlan, MetadataStore]:
        plan, store = sweep(store, self.h, now, self.expiry)
        return plan, _decay_counts(store, self.decay)

    def masked_step(
        self, store: MetadataStore, now: Array | int, due: Array
    ) -> tuple[Array, Array, MetadataStore]:
        """Scan-compatible `step`: commit only where ``due`` (traced bool)."""
        return masked_step(
            store, now, due, h=self.h, expiry=self.expiry, decay=self.decay
        )
