"""Key metadata store — device-resident analogue of the paper's metadata layer.

The paper keeps, per key (§6.2)::

    { totalAccessCount, hosts (set), hostAccesses (dict), lastAccessedDate }

Here the whole metadata cluster is a struct-of-dense-arrays over a fixed key
universe of size K and N nodes, so every operation the paper performs per-key
in O(1) becomes a vectorised O(batch) device op with no host round-trips:

    access_counts [K, N] int32   -- hostAccesses  (g(O, x))
    hosts         [K, N] bool    -- replica set
    last_access   [K]    int32   -- lastAccessedDate, in *ticks* (see note)
    live          [K]    bool    -- key exists
    home          [K]    int32   -- node that first stored the key (write home)

``totalAccessCount`` is derived (= access_counts.sum(-1)) rather than stored,
removing a redundancy in the paper's format.

Timestamp note: the paper stores epoch-milliseconds (int64). JAX defaults to
32-bit ints; rather than force x64 globally we store *relative ticks* (ms
since store creation, or step indices) — semantics are identical for the
expiry test ``now - last_access > expiry``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

__all__ = [
    "MetadataStore",
    "create_store",
    "record_accesses",
    "record_new_keys",
    "local_hit",
    "owner_of",
]


class MetadataStore(NamedTuple):
    """Dense metadata for K keys × N nodes (paper §6.2, vectorised)."""

    access_counts: Array  # [K, N] int32
    hosts: Array  # [K, N] bool
    last_access: Array  # [K] int32 ticks
    live: Array  # [K] bool
    home: Array  # [K] int32

    @property
    def num_keys(self) -> int:
        return self.access_counts.shape[0]

    @property
    def num_nodes(self) -> int:
        return self.access_counts.shape[1]

    def total_access_count(self) -> Array:
        """The paper's ``totalAccessCount`` (derived)."""
        return jnp.sum(self.access_counts, axis=-1)


def create_store(num_keys: int, num_nodes: int) -> MetadataStore:
    """Empty metadata cluster for a fixed key universe."""
    return MetadataStore(
        access_counts=jnp.zeros((num_keys, num_nodes), dtype=jnp.int32),
        hosts=jnp.zeros((num_keys, num_nodes), dtype=bool),
        last_access=jnp.zeros((num_keys,), dtype=jnp.int32),
        live=jnp.zeros((num_keys,), dtype=bool),
        home=jnp.zeros((num_keys,), dtype=jnp.int32),
    )


def record_accesses(
    store: MetadataStore,
    keys: Array,
    nodes: Array,
    now: Array | int,
    weights: Array | None = None,
    valid: Array | None = None,
) -> MetadataStore:
    """Fold a batch of accesses into the metadata (Algorithm 1's bookkeeping).

    keys, nodes: ``[B]`` int32 — key accessed / node that served the request.
    weights: optional ``[B]`` int32 multiplicity (e.g. tokens per route).
    valid: optional ``[B]`` bool — False rows are ignored entirely (counts
        *and* last_access). Lets fixed-shape callers (``lax.scan`` over padded
        request chunks) fold partial batches without host-side slicing.

    The paper updates metadata per request over HTTP; we fold the whole batch
    with one scatter-add — this is the "non-blocking, off the critical path"
    property taken to its limit (the update *is* part of the fused step).
    """
    k, n = store.access_counts.shape
    if weights is None:
        weights = jnp.ones_like(keys, dtype=jnp.int32)
    sel = keys
    if valid is not None:
        weights = jnp.where(valid, weights, 0)
        sel = jnp.where(valid, keys, k)  # out-of-range rows drop below
    flat = sel.astype(jnp.int32) * n + nodes.astype(jnp.int32)
    counts = store.access_counts.reshape(-1)
    counts = counts.at[flat].add(weights.astype(jnp.int32), mode="drop")
    last = store.last_access.at[sel].max(
        jnp.asarray(now, dtype=jnp.int32), mode="drop"
    )
    return store._replace(
        access_counts=counts.reshape(k, n),
        last_access=last,
    )


def record_new_keys(
    store: MetadataStore,
    keys: Array,
    nodes: Array,
    now: Array | int,
) -> MetadataStore:
    """Algorithm 1 'metadata == null' branch / Algorithm 2 local store.

    New keys are stored on the node that received the request (its *home*),
    a metadata object is generated, and the access is logged. Existing keys
    are left untouched (mask applied), so replaying a mixed batch is safe.
    """
    is_new = ~store.live[keys]
    sel = jnp.where(is_new, keys, store.num_keys)  # out-of-range rows drop
    hosts = store.hosts.at[sel, nodes].set(True, mode="drop")
    live = store.live.at[sel].set(True, mode="drop")
    home = store.home.at[sel].set(nodes.astype(jnp.int32), mode="drop")
    store = store._replace(hosts=hosts, live=live, home=home)
    return record_accesses(store, keys, nodes, now)


def local_hit(store: MetadataStore, keys: Array, nodes: Array) -> Array:
    """``[B]`` bool — does the requesting node hold a replica? (Alg. 1 test)."""
    return store.hosts[keys, nodes] & store.live[keys]


def owner_of(store: MetadataStore, keys: Array) -> Array:
    """An arbitrary-but-deterministic owner for remote fetches: the home node
    if it still holds a replica, else the lowest-indexed replica holder."""
    home_ok = store.hosts[keys, store.home[keys]]
    first = jnp.argmax(store.hosts[keys], axis=-1)
    return jnp.where(home_ok, store.home[keys], first).astype(jnp.int32)
