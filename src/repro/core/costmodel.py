"""Replication cost model — TPU adaptation of the paper's 100 ms WAN penalty.

The paper replicates whenever ``f >= H`` because its remote:local cost ratio
is enormous (100 ms WAN RTT vs ~0 local). On a TPU pod the ratio is finite
(ICI hop vs HBM read), and HBM is the scarce resource the paper's assumption
"minimal memory usage on each node is desirable" maps onto. So beyond the
paper's threshold rule we gate replication with an explicit budget:

    gain(O, x)  = traffic(O, x) × bytes_saved_per_access × steps_per_sweep
    cost(O, x)  = object_bytes(O)        (one ICI broadcast + HBM residency)

and we keep, per node, the highest-gain adds whose cumulative size fits the
node's replica budget. With an infinite budget this reduces exactly to the
paper's Algorithm 3 (the property tests assert this).
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import Array

from repro.core.placement import PlacementPlan

__all__ = ["HardwareModel", "TPU_V5E", "replication_gain", "budget_plan"]


class HardwareModel(NamedTuple):
    """Per-chip hardware constants (defaults: TPU v5e, the assignment target)."""

    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # bytes/s
    ici_bw: float = 50e9  # bytes/s per link
    hbm_bytes: float = 16e9


TPU_V5E = HardwareModel()


def replication_gain(
    counts: Array,  # [K, N] traffic g(O, x)
    bytes_saved_per_access: Array | float,  # e.g. tokens × d_model × dtype
    steps_per_sweep: float,
    object_bytes: Array,  # [K] payload size
    hw: HardwareModel = TPU_V5E,
) -> Array:
    """Net seconds saved per sweep period by replicating O onto x — ``[K, N]``.

    Remote access cost is modelled as ICI transfer of the access payload;
    replication cost as a one-time ICI move of the object.
    """
    saved = counts.astype(jnp.float32) * bytes_saved_per_access / hw.ici_bw
    move = object_bytes.astype(jnp.float32)[:, None] / hw.ici_bw
    return saved * steps_per_sweep - move


def budget_plan(
    plan: PlacementPlan,
    counts: Array,  # [K, N]
    object_bytes: Array,  # [K]
    node_budget_bytes: float,
) -> PlacementPlan:
    """Trim a plan's adds to fit each node's replica-byte budget, keeping the
    hottest candidates (by access fraction) first. Drops/expiry untouched —
    freeing memory is always allowed. Infinite budget => identity.
    """
    if node_budget_bytes == float("inf"):
        return plan
    f = counts.astype(jnp.float32)
    f = f / jnp.maximum(jnp.sum(f, axis=-1, keepdims=True), 1.0)
    score = jnp.where(plan.to_add, f, -1.0)  # [K, N]
    # Per node: sort candidate adds by score desc, admit while cumsum fits.
    order = jnp.argsort(-score, axis=0)  # [K, N]
    sz = jnp.take_along_axis(
        jnp.broadcast_to(object_bytes[:, None], score.shape), order, axis=0
    ).astype(jnp.float32)
    is_cand = jnp.take_along_axis(score, order, axis=0) >= 0.0
    cum = jnp.cumsum(jnp.where(is_cand, sz, 0.0), axis=0)
    admit_sorted = is_cand & (cum <= node_budget_bytes)
    # Scatter the admit decision back to key order.
    admit = jnp.zeros_like(admit_sorted)
    admit = admit.at[order, jnp.arange(score.shape[1])[None, :]].set(admit_sorted)
    to_add = plan.to_add & admit
    owners = (plan.owners & ~plan.to_add) | to_add
    return plan._replace(owners=owners, to_add=to_add)
