"""Replication cost model — TPU adaptation of the paper's 100 ms WAN penalty.

The paper replicates whenever ``f >= H`` because its remote:local cost ratio
is enormous (100 ms WAN RTT vs ~0 local). On a TPU pod the ratio is finite
(ICI hop vs HBM read), and HBM is the scarce resource the paper's assumption
"minimal memory usage on each node is desirable" maps onto. So beyond the
paper's threshold rule we gate replication with an explicit budget:

    gain(O, x)  = traffic(O, x) × bytes_saved_per_access × steps_per_sweep
    cost(O, x)  = object_bytes(O)        (one ICI broadcast + HBM residency)

and we keep, per node, the highest-score replicas whose cumulative size fits
the node's replica-byte budget (:func:`project_capacity` — the *capacity
projection* stage of the placement pipeline). With an infinite budget this
reduces bit-exactly to the paper's Algorithm 3 (pinned by property tests).

Admission rule (per node, scan/jit-compatible — no data-dependent shapes):

  1. rank every owned candidate by ownership fraction ``f`` descending;
     at equal ``f`` a *held* replica beats a new add (less churn), further
     ties broken by object id (deterministic);
  2. admit candidates while the running byte total fits the node budget —
     so the hottest adds that fit are admitted and, when the node is over
     budget, its coldest held replicas are evicted;
  3. everything else is rejected: held-but-rejected replicas are *capacity
     evictions*, add-but-rejected candidates simply never materialise.

Freeing memory (threshold drops, expiry) is always allowed — the projection
only ever shrinks a plan's replica set, never grows it.

Last-replica semantics: under byte pressure the projection may evict a
key's *last* replica — the budget outranks the eligibility layer's
starvation guard by design. The replica set is a bounded cache over an
implicit backing store, not the sole copy of the data: the simulator
charges replica-less reads the topology's worst RTT (the backing-store
fetch — in the paper's flat testbed that is exactly ``remote_ms``, i.e. an
ordinary miss), and a key whose access counts persist is re-admitted by a
later sweep as soon as it ranks above the budget line again.
"""

from __future__ import annotations

import math
from typing import TYPE_CHECKING, NamedTuple

import jax.numpy as jnp
from jax import Array

from repro.core.ownership import ownership_fraction

if TYPE_CHECKING:  # typing only — placement imports this module at runtime
    from repro.core.placement import PlacementPlan

__all__ = [
    "HardwareModel",
    "TPU_V5E",
    "replication_gain",
    "project_capacity",
    "budget_plan",
]


class HardwareModel(NamedTuple):
    """Per-chip hardware constants (defaults: TPU v5e, the assignment target)."""

    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # bytes/s
    ici_bw: float = 50e9  # bytes/s per link
    hbm_bytes: float = 16e9


TPU_V5E = HardwareModel()


def replication_gain(
    counts: Array,  # [K, N] traffic g(O, x)
    bytes_saved_per_access: Array | float,  # e.g. tokens × d_model × dtype
    steps_per_sweep: float,
    object_bytes: Array,  # [K] payload size
    hw: HardwareModel = TPU_V5E,
) -> Array:
    """Net seconds saved per sweep period by replicating O onto x — ``[K, N]``.

    Remote access cost is modelled as ICI transfer of the access payload;
    replication cost as a one-time ICI move of the object.
    """
    saved = counts.astype(jnp.float32) * bytes_saved_per_access / hw.ici_bw
    move = object_bytes.astype(jnp.float32)[:, None] / hw.ici_bw
    return saved * steps_per_sweep - move


def project_capacity(
    owners: Array,  # [K, N] bool — post-eligibility replica set
    hosts: Array,  # [K, N] bool — replica set *before* this sweep
    f: Array,  # [K, N] f32 — ownership fractions (the score)
    object_bytes: Array,  # [K] f32 per-key payload size
    capacity_bytes: Array | float,  # [N] (or scalar) per-node byte budget
) -> tuple[Array, Array, Array]:
    """Capacity projection: trim ``owners`` to fit each node's byte budget.

    Returns ``(projected_owners, evicted, rejected)`` — all ``[K, N]`` bool:
    ``evicted`` are held replicas (``owners & hosts``) that no longer fit,
    ``rejected`` are planned adds that were never admitted.

    Pure fixed-shape JAX (three stable sorts + a cumsum per node), so it runs
    unchanged inside ``jax.lax.scan`` / ``vmap`` bodies and as an XLA
    post-pass on the Pallas kernel's outputs. ``capacity_bytes = inf`` is a
    bit-exact identity: every finite cumulative sum fits, so the admit mask
    equals ``owners``.
    """
    k, n = owners.shape
    held = owners & hosts
    obj = jnp.broadcast_to(
        jnp.asarray(object_bytes, jnp.float32).reshape(k, 1), (k, n)
    )
    budget = jnp.broadcast_to(jnp.asarray(capacity_bytes, jnp.float32), (n,))

    # Per-node lexicographic order via a chain of stable sorts, least- to
    # most-significant key; the initial id-ordered permutation supplies the
    # final tiebreak. Most significant: owned candidates first, then f
    # descending, then held-before-add.
    perm = jnp.broadcast_to(jnp.arange(k, dtype=jnp.int32)[:, None], (k, n))
    for key in ((~held).astype(jnp.float32), -f, (~owners).astype(jnp.float32)):
        kp = jnp.take_along_axis(key, perm, axis=0)
        perm = jnp.take_along_axis(
            perm, jnp.argsort(kp, axis=0, stable=True), axis=0
        )

    owned_sorted = jnp.take_along_axis(owners, perm, axis=0)
    size_sorted = jnp.where(owned_sorted, jnp.take_along_axis(obj, perm, axis=0), 0.0)
    cum = jnp.cumsum(size_sorted, axis=0)
    admit_sorted = owned_sorted & (cum <= budget[None, :])

    admit = jnp.zeros_like(admit_sorted)
    admit = admit.at[perm, jnp.arange(n, dtype=jnp.int32)[None, :]].set(admit_sorted)
    projected = owners & admit
    return projected, held & ~admit, (owners & ~hosts) & ~admit


def budget_plan(
    plan: "PlacementPlan",
    counts: Array,  # [K, N]
    object_bytes: Array,  # [K]
    node_budget_bytes: Array | float,
) -> "PlacementPlan":
    """Project a plan onto per-node replica-byte budgets (plan-level wrapper
    around :func:`project_capacity`; scores are ownership fractions of
    ``counts``). The hottest candidates are kept first; when a node is over
    budget its coldest held replicas are evicted (``to_drop`` grows and the
    evictions are recorded in ``capacity_evicted``). Infinite budget =>
    identity.
    """
    if isinstance(node_budget_bytes, (int, float)) and math.isinf(
        node_budget_bytes
    ):
        return plan
    f = ownership_fraction(counts)
    hosts = (plan.owners & ~plan.to_add) | plan.to_drop  # pre-sweep replica set
    projected, evicted, _ = project_capacity(
        plan.owners, hosts, f, object_bytes, node_budget_bytes
    )
    return plan._replace(
        owners=projected,
        to_add=projected & ~hosts,
        to_drop=hosts & ~projected,
        capacity_evicted=evicted,
    )
