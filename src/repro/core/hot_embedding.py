"""Traffic-aware hot-row embedding cache — Redynis integration #2.

Objects are vocabulary rows, nodes are data shards, traffic is token
frequency (zipfian in natural text — the paper's skewed workload, verbatim).
The daemon promotes the hottest rows with ``f ≥ H`` into a bounded replica
cache; lookups consult the cache first (the Pallas ``hot_gather`` kernel
keeps it VMEM-resident on TPU) and fall back to the vocab-sharded table +
psum for misses.

TPU adaptation note (DESIGN.md §2.3): the paper's "remote node" maps to the
*memory hierarchy*, not just other chips — VMEM ⊂ HBM-local ⊂ HBM-remote.
Hot hits skip the HBM row read; the cross-chip psum payload is unchanged
(exactness forbids dropping rows), so the win shows up in the roofline
memory term and in the hot_embedding benchmark's analytic HBM-bytes-saved,
not in the collective term. Replica freshness during training is free: the
hot table is gathered from the live embedding inside the forward pass, so
the cache can never serve stale rows and gradients flow to the home copy.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import Array

from repro.core.ownership import validate_coefficient
from repro.dist import DistSpec, embed_lookup

__all__ = ["HotEmbeddingState", "HotEmbedding", "embed_with_cache"]


class HotEmbeddingState(NamedTuple):
    counts: Array  # [V, N] f32 EMA token traffic per data shard
    hot_ids: Array  # [R] int32 cached vocab rows (-1 = empty)
    slot_map: Array  # [V] int32 row -> cache slot (-1 = cold)
    sweeps: Array  # [] int32


class HotEmbedding:
    def __init__(
        self,
        vocab: int,
        num_nodes: int,
        rows: int,
        *,
        h: float | None = None,
        decay: float = 0.98,
        period: int = 50,
    ) -> None:
        if h is None or h <= 0:
            h = 1.0 / num_nodes
        validate_coefficient(h, num_nodes)
        self.v, self.n, self.r = vocab, num_nodes, rows
        self.h = h
        self.decay = decay
        self.period = period

    def init_state(self) -> HotEmbeddingState:
        return HotEmbeddingState(
            counts=jnp.zeros((self.v, self.n), jnp.float32),
            hot_ids=jnp.full((self.r,), -1, jnp.int32),
            slot_map=jnp.full((self.v,), -1, jnp.int32),
            sweeps=jnp.zeros((), jnp.int32),
        )

    @partial(jax.jit, static_argnums=(0,))
    def fold(
        self, state: HotEmbeddingState, tokens: Array, token_nodes: Array
    ) -> HotEmbeddingState:
        """tokens [B, S] and token_nodes [B] (data shard of each row)."""
        b, s = tokens.shape
        flat_tok = tokens.reshape(-1)
        flat_node = jnp.repeat(token_nodes, s)
        idx = flat_tok * self.n + flat_node
        counts = state.counts.reshape(-1).at[idx].add(1.0, mode="drop")
        return state._replace(counts=counts.reshape(self.v, self.n))

    def due(self, step: int) -> bool:
        return step > 0 and step % self.period == 0

    @partial(jax.jit, static_argnums=(0,))
    def sweep(self, state: HotEmbeddingState) -> HotEmbeddingState:
        """Ownership test + top-R budget -> new cache contents."""
        total = jnp.sum(state.counts, axis=-1)  # [V]
        f = state.counts / jnp.maximum(total[:, None], 1.0)
        qualify = jnp.any(f >= self.h, axis=-1) & (total > 0)
        score = jnp.where(qualify, total, -1.0)
        _, top = jax.lax.top_k(score, self.r)
        valid = jnp.take_along_axis(score, top, axis=0) > 0
        hot_ids = jnp.where(valid, top, -1).astype(jnp.int32)
        slot_map = jnp.full((self.v,), -1, jnp.int32)
        slot_map = slot_map.at[jnp.where(valid, top, self.v)].set(
            jnp.arange(self.r, dtype=jnp.int32), mode="drop"
        )
        return HotEmbeddingState(
            counts=state.counts * self.decay,
            hot_ids=hot_ids,
            slot_map=slot_map,
            sweeps=state.sweeps + 1,
        )

    def hit_rate(self, state: HotEmbeddingState) -> Array:
        total = jnp.sum(state.counts)
        hot = jnp.sum(
            jnp.sum(state.counts, -1)[jnp.clip(state.hot_ids, 0, self.v - 1)]
            * (state.hot_ids >= 0)
        )
        return hot / jnp.maximum(total, 1.0)


def embed_with_cache(
    table: Array,  # [Vp, D] (vocab-sharded under pjit)
    tokens: Array,  # [B, S] int32
    state: HotEmbeddingState,
    dist: Optional[DistSpec] = None,
    use_kernel: bool = True,
) -> tuple[Array, Array]:
    """Two-level lookup. Returns (rows [B, S, D], hit [B, S] bool).

    Hot rows come from the in-forward-gathered cache (VMEM via the Pallas
    kernel); misses take the sharded cold path. Exact: hit rows equal the
    cold path's answer bit-for-bit because the cache is gathered from the
    live table.
    """
    b, s = tokens.shape
    flat = tokens.reshape(-1)
    safe_hot = jnp.clip(state.hot_ids, 0, table.shape[0] - 1)
    hot_table = jnp.take(table, safe_hot, axis=0)  # [R, D] fresh every step

    if use_kernel:
        from repro.kernels.hot_gather.ops import hot_gather

        rows_hot, hit = hot_gather(flat, state.slot_map, hot_table)
    else:
        slots = state.slot_map[flat]
        hit = slots >= 0
        rows_hot = jnp.where(
            hit[:, None], jnp.take(hot_table, jnp.maximum(slots, 0), axis=0), 0
        )

    cold_tokens = jnp.where(hit, 0, flat).reshape(b, s)
    rows_cold = embed_lookup(table, cold_tokens, dist).reshape(b * s, -1)
    rows = jnp.where(hit[:, None], rows_hot.astype(rows_cold.dtype), rows_cold)
    return rows.reshape(b, s, -1), hit.reshape(b, s)
