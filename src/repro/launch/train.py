"""Training driver: ``python -m repro.launch.train --arch <id> [--full]``.

Default runs the REDUCED config end-to-end on local devices (CPU demo /
smoke); ``--full`` uses the assigned architecture at full size (requires a
real TPU slice — on this container it would only make sense via the
dry-run, see launch/dryrun.py). The Redynis daemons (expert placement +
hot-row embedding) run inside the loop whenever the arch enables them.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduced
from repro.data.pipeline import DataConfig, Pipeline
from repro.models import build
from repro.train.optim import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="deepseek-moe-16b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--full", action="store_true", help="full-size config")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    model = build(cfg)
    print(
        f"arch={cfg.name} family={cfg.family} params={model.num_params()/1e6:.1f}M "
        f"active={model.active_params()/1e6:.1f}M devices={jax.device_count()}"
    )

    trainer = Trainer(
        model,
        TrainConfig(
            opt=OptConfig(lr=args.lr, warmup_steps=min(50, args.steps // 5 + 1),
                          total_steps=args.steps),
            microbatches=args.microbatches,
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=args.checkpoint_every,
        ),
        num_nodes=max(jax.device_count(), 1),
    )
    pipe = Pipeline(
        DataConfig(
            vocab_size=cfg.vocab_size,
            seq_len=args.seq,
            global_batch=args.batch,
            seed=args.seed,
        )
    )
    state = (
        trainer.restore(jax.random.PRNGKey(args.seed))
        if args.checkpoint_dir
        else trainer.init_state(jax.random.PRNGKey(args.seed))
    )
    state, hist = trainer.run(state, pipe, args.steps)
    print(
        f"done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
        f"over {len(hist)} steps"
    )
    if state.expert_placement is not None:
        hr = float(trainer.expert_daemon.hit_rate(state.expert_placement))
        print(f"expert replica hit rate (EMA traffic): {hr:.3f}")
    if state.hot_embed is not None:
        hr = float(trainer.embed_daemon.hit_rate(state.hot_embed))
        print(f"hot-row embedding hit rate (EMA traffic): {hr:.3f}")


if __name__ == "__main__":
    main()
