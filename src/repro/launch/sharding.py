"""Sharding rules: logical parameter axes -> mesh axes, per architecture.

Parallelism layout (16 data × 16 model per pod; pods are pure DP):

  params       FSDP: 'embed' dim over data; TP: heads/mlp/experts/state
               over model; vocab over model (embedding + LM head + sharded
               xent — logits are never all-gathered).
  activations  batch over (pod, data); attention heads over model (uneven
               head counts padded by GSPMD — waste shows up in the
               MODEL_FLOPS/HLO_FLOPS roofline ratio and is documented);
               MoE dispatch groups over (data, model) so the dispatch
               einsum lowers to one all-to-all on the model (EP) axis.
  decode       KV cache: batch over data, sequence over model (flash-decode
               partial-softmax combines via psum); recurrent state: width
               over model.

Divisibility: mesh-sharded PARAM dims must divide exactly (pjit boundary
rule), so archs whose head count is not a multiple of 16 (llama3.2 24H,
llava 56H, recurrentgemma 10H, whisper 8H) shard head_dim instead — always
64/128/256 — and leave heads unsharded in params while the activation
constraint still splits heads (unevenly, padded) across the model axis.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist import DistSpec
from repro.models.model import Model
from repro.models.params import partition_specs

__all__ = [
    "make_dist",
    "param_rules",
    "param_shardings",
    "batch_shardings",
    "state_shardings",
    "opt_shardings",
    "MODEL_AXIS_SIZE",
]

MODEL_AXIS_SIZE = 16


def make_dist(mesh: Mesh, layout: str = "tp") -> DistSpec:
    axes = mesh.axis_names
    if layout == "fsdp":
        # ZeRO-3: the batch spreads over every axis (no tensor parallelism
        # for the blocks — DistSpec.tensor_parallel is False because the
        # model axis is consumed by the batch), but the model axis still
        # carries the vocab sharding for the loss path: without it the
        # embedding-gradient matmul replicates on every chip (refuted
        # hypothesis A1 in EXPERIMENTS.md §Perf).
        batch_axes = tuple(a for a in ("pod", "data", "model") if a in axes)
        model_axis = "model" if "model" in axes else None
        return DistSpec(mesh=mesh, batch_axes=batch_axes, model_axis=model_axis)
    batch_axes = tuple(a for a in ("pod", "data") if a in axes)
    model_axis = "model" if "model" in axes else None
    return DistSpec(mesh=mesh, batch_axes=batch_axes, model_axis=model_axis)


def param_rules(cfg: ModelConfig, mesh: Mesh) -> dict:
    """Logical axis -> mesh axis map for this arch on this mesh.

    Three layouts (cfg.layout — the §Perf hillclimb knob):
      tp    — FSDP('embed'→data) × TP(heads/mlp/experts/vocab→model)
      fsdp  — params fully sharded over (data, model) on 'embed'; no TP
      serve — TP only; params replicated over data (weights-stationary
              decode: no per-step FSDP all-gathers)
    """
    m = int(mesh.shape["model"]) if "model" in mesh.axis_names else 1
    d_axes = tuple(a for a in ("data", "model") if a in mesh.axis_names)

    if cfg.layout == "fsdp":
        if d_axes and cfg.d_model % _axes_size(mesh, d_axes) == 0:
            emb = d_axes
        elif "data" in mesh.axis_names and cfg.d_model % int(mesh.shape["data"]) == 0:
            emb = "data"
        else:
            emb = None
        return {
            "layers": None,
            "vocab": "model" if "model" in mesh.axis_names else None,
            "embed_rep": None,
            "embed": emb,
            "heads": None,
            "head_dim": None,
            "kv_heads": None,
            "mlp": None,
            "experts": None,
            "expert_mlp": None,
            "state": None,
        }

    heads_ok = cfg.num_heads % m == 0
    rules = {
        "layers": None,
        "vocab": "model",
        "embed_rep": None,
        "embed": None if cfg.layout == "serve" else "data",
        "heads": "model" if heads_ok else None,
        "head_dim": None if heads_ok else "model",
        # MHA archs (kv == m·k) shard kv heads; GQA kv counts (1-8) < 16
        # stay replicated and the decode cache shards its sequence instead.
        "kv_heads": "model" if cfg.num_kv_heads % m == 0 else None,
        "mlp": "model" if cfg.d_ff % m == 0 else None,
        "experts": "model" if cfg.num_experts and cfg.num_experts % m == 0 else None,
        "expert_mlp": None,
        "state": "model" if (cfg.lru_width or cfg.d_model) % m == 0 else None,
    }
    if "data" not in mesh.axis_names:
        rules["embed"] = None
    if "model" not in mesh.axis_names:
        for k, v in rules.items():
            if v == "model":
                rules[k] = None
    return rules


def _axes_size(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= int(mesh.shape[a])
    return n


def param_shardings(model: Model, mesh: Mesh):
    """NamedSharding tree matching the param tree."""
    rules = param_rules(model.cfg, mesh)
    specs = partition_specs(model.param_specs(), rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def quantized_param_shardings(model: Model, mesh: Mesh, abstract_params):
    """Shardings for an int8-quantized param tree (repro.quant): quantized
    leaves become {"q": <weight sharding>, "s": <same minus last dim>}."""
    from repro.quant import abstract_quantize_tree

    p_sh = param_shardings(model, mesh)
    q_sds = abstract_quantize_tree(abstract_params)

    def f(sh, sds):
        if isinstance(sds, dict) and set(sds.keys()) == {"q", "s"}:
            spec = list(sh.spec) + [None] * (len(sds["q"].shape) - len(sh.spec))
            return {
                "q": sh,
                "s": NamedSharding(mesh, P(*spec[:-1], None)),
            }
        return sh

    is_q = lambda x: isinstance(x, dict) and set(x.keys()) == {"q", "s"}
    sh_tree = jax.tree.map(
        f, p_sh, q_sds, is_leaf=lambda x: isinstance(x, NamedSharding)
    )
    return sh_tree, q_sds


def opt_shardings(model: Model, mesh: Mesh, opt_state_template):
    """Optimizer m/v follow the param specs; step is replicated."""
    ps = param_shardings(model, mesh)
    return type(opt_state_template)(
        m=ps, v=ps, step=NamedSharding(mesh, P())
    )


def batch_shardings(model: Model, mesh: Mesh, batch_specs: dict):
    """Batch dim over (pod, data); everything else replicated. Batches too
    small to split (long_500k has global_batch=1) stay replicated — the
    cell is latency-bound by design and the model axis still splits state."""
    dist = make_dist(mesh, model.cfg.layout)
    out = {}
    for k, sds in batch_specs.items():
        spec = [None] * len(sds.shape)
        if sds.shape and sds.shape[0] % max(dist.batch_size, 1) == 0:
            spec[0] = dist.batch
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def state_shardings(model: Model, mesh: Mesh, state_template):
    """Decode-state shardings per family (see module docstring)."""
    dist = make_dist(mesh, model.cfg.layout)
    mdl = dist.model_axis
    cfg = model.cfg
    m = int(mesh.shape[mdl]) if mdl else 1
    bs = max(dist.batch_size, 1)

    def bspec(nbatch: int):
        return dist.batch if nbatch % bs == 0 else None

    def kv_cache_spec(leaf):
        # [L, B, T, KH, Dh]: batch over data; kv-heads over model when they
        # divide (MHA — fully local decode attention), else sequence over
        # model (flash-decode partial-softmax psum combine).
        if leaf.ndim == 5:
            t, kh = leaf.shape[2], leaf.shape[3]
            if kh % m == 0:
                return P(None, bspec(leaf.shape[1]), None, mdl, None)
            return P(None, bspec(leaf.shape[1]), mdl if t % m == 0 else None, None, None)
        if leaf.ndim == 1:  # lengths [B]
            return P(bspec(leaf.shape[0]))
        return P(*([None] * leaf.ndim))

    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        spec_tree = jax.tree.map(kv_cache_spec, state_template)
    elif fam == "ssm":

        def rwkv_spec(leaf):
            if leaf.ndim == 3:  # x_tm/x_cm [L, B, D]
                return P(None, bspec(leaf.shape[1]), mdl if leaf.shape[2] % m == 0 else None)
            if leaf.ndim == 5:  # wkv [L, B, H, dk, dv]
                return P(None, bspec(leaf.shape[1]), mdl if leaf.shape[2] % m == 0 else None, None, None)
            return P(*([None] * leaf.ndim))

        spec_tree = jax.tree.map(rwkv_spec, state_template)
    elif fam == "hybrid":

        def rglru_spec(leaf):
            if leaf.ndim == 3:  # conv [B, 3, W]
                return P(bspec(leaf.shape[0]), None, mdl if leaf.shape[2] % m == 0 else None)
            if leaf.ndim == 2:  # h [B, W]
                return P(bspec(leaf.shape[0]), mdl if leaf.shape[1] % m == 0 else None)
            if leaf.ndim == 4:  # window kv [B, W, KH, Dh]
                return P(bspec(leaf.shape[0]), mdl if leaf.shape[1] % m == 0 else None, None, None)
            if leaf.ndim == 1:
                return P(bspec(leaf.shape[0]))
            return P(*([None] * leaf.ndim))

        spec_tree = jax.tree.map(rglru_spec, state_template)
    elif fam == "audio":
        spec_tree = jax.tree.map(kv_cache_spec, state_template)
    else:
        raise ValueError(fam)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)
