"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Spins up a batched decode engine on the reduced config, drives it with a
zipfian stream of session requests through the Redynis session router
(paper workload, serving flavour), and reports throughput + the router's
local-hit rate / migration volume. ``--fail-pod`` kills a pod mid-run to
demonstrate the leader re-election (paper §11).
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build
from repro.serving import Request, ServeEngine, SessionRouter
from repro.serving.kvcache import state_bytes


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--sessions", type=int, default=16)
    ap.add_argument("--pods", type=int, default=4)
    ap.add_argument("--lanes", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--fail-pod", type=int, default=-1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = ServeEngine(model, params, num_lanes=args.lanes, cache_len=256)
    router = SessionRouter(
        num_pods=args.pods,
        max_sessions=args.sessions * 2,
        sweep_period=16,
        session_bytes=state_bytes(engine.state) / args.lanes,
    )
    rng = np.random.default_rng(args.seed)
    # zipfian session popularity + geo affinity: each session has a home pod
    home = {f"s{i}": i % args.pods for i in range(args.sessions)}
    ranks = np.arange(1, args.sessions + 1, dtype=np.float64) ** -1.2
    popularity = ranks / ranks.sum()

    import time

    t0 = time.perf_counter()
    for i in range(args.requests):
        sid = f"s{rng.choice(args.sessions, p=popularity)}"
        route = router.route(sid, home[sid])
        if engine.lanes.lookup(sid) is None:
            prompt = rng.integers(0, cfg.vocab_size, args.prompt_len)
            engine.admit(Request(session=sid, tokens=prompt, max_new=args.max_new))
        engine.step()
        router.tick()
        if args.fail_pod >= 0 and i == args.requests // 2:
            print(f"!! killing pod {args.fail_pod} (leader={router.leader})")
            router.fail_pod(args.fail_pod)
    engine.run_to_completion()
    dt = time.perf_counter() - t0

    print(
        f"served {engine.tokens_out} tokens in {dt:.2f}s "
        f"({engine.tokens_out / dt:.1f} tok/s on CPU reduced config)"
    )
    print(
        f"router: hit_rate={router.hit_rate():.3f} "
        f"migrations={router.stats['migrations']} "
        f"migrated={router.stats['migrated_bytes'] / 1e6:.1f}MB "
        f"elections={router.stats['elections']} leader={router.leader}"
    )


if __name__ == "__main__":
    main()
