"""Roofline analysis from compiled (post-SPMD) HLO — no hardware needed.

``compiled.cost_analysis()`` visits while-loop bodies ONCE (verified
empirically: a 4-layer scan reports 1 layer of FLOPs), so naive use
under-counts scanned models by num_layers ×. This module analyses
``compiled.as_text()`` directly:

  1. parse computations into op records (name, type, op, operands),
  2. find `while` ops and their ``known_trip_count`` backend configs,
     propagating nested multipliers to called computations,
  3. FLOPs      = Σ over dot ops: 2 · numel(output) · contraction-size · mult
     (elementwise FLOPs ignored — sub-1% next to the matmuls),
  4. HBM bytes  = fusion-boundary accounting with a TPU-faithful byte model:
       * dot/fusion: output + operands, where an operand consumed only via
         dynamic-slice / dynamic-update-slice / in-place scatter inside the
         fusion is charged at its SLICE size (scan bodies slice per-layer
         weights and update caches in place — charging the full stack per
         iteration would overcount by num_layers ×),
       * dynamic-slice: 2 × slice; dynamic-update-slice / scatter:
         2 × update (read-modify-write of the touched region only),
       * pure converts are free (the CPU backend materialises bf16→f32
         copies that the TPU MXU fuses into the matmul; charging them
         would poison the memory term with a backend artifact),
  5. collective bytes = Σ over all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute: output bytes · mult (× 2 for
     all-reduce: reduce-scatter + all-gather phases of a ring).

The compiled module is already per-device (SPMD-partitioned shapes), so all
sums are per-chip. Terms (TPU v5e):

  compute    = flops / 197e12        memory = hbm_bytes / 819e9
  collective = coll_bytes / 50e9     (one ICI link, conservative)
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field

import numpy as np

__all__ = ["HloAnalysis", "analyze_hlo", "roofline_terms", "HW"]

HW = {
    "peak_flops": 197e12,  # bf16 FLOP/s per v5e chip
    "hbm_bw": 819e9,  # bytes/s
    "ici_bw": 50e9,  # bytes/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\("
)
_SLICE_OPS = ("dynamic-slice", "dynamic-update-slice", "scatter")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    return [int(d) for d in m.group(2).split(",") if d] if m else []


@dataclass
class OpRec:
    name: str
    type_str: str
    op: str
    operands: list
    line: str


@dataclass
class HloAnalysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    by_collective: dict = field(default_factory=dict)
    dot_count: int = 0
    collective_count: int = 0
    while_trip_counts: list = field(default_factory=list)

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "by_collective": self.by_collective,
            "dot_count": self.dot_count,
            "collective_count": self.collective_count,
            "while_trip_counts": self.while_trip_counts,
        }


def _parse_ops(lines: list[str]) -> dict[str, OpRec]:
    out: dict[str, OpRec] = {}
    for line in lines:
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.group(1), m.group(2), m.group(3)
        op = re.sub(r"\.\d+$", "", op)
        tail = line[m.end() - 1 :]
        args = tail[1 : tail.find(")")] if ")" in tail else ""
        operands = [a.strip().lstrip("%") for a in args.split(",") if a.strip()]
        out[name] = OpRec(name, type_str, op, operands, line)
    return out


def _split_computations(hlo: str) -> dict[str, dict[str, OpRec]]:
    comps: dict[str, dict[str, OpRec]] = {}
    cur_lines: list[str] = []
    cur = None
    for line in hlo.splitlines():
        if line[:1] in ("%", "E") and line.rstrip().endswith("{") and "->" in line:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", line)
            if m:
                cur = m.group(1)
                cur_lines = []
                comps[cur] = cur_lines
                continue
        stripped = line.strip()
        if stripped.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in stripped:
            cur_lines.append(stripped)
    return {k: _parse_ops(v) for k, v in comps.items()}


def _called_computations(line: str) -> list[str]:
    out = []
    for key in ("body=", "condition=", "to_apply=", "calls="):
        for m in re.finditer(re.escape(key) + r"%?([\w\.\-]+)", line):
            out.append(m.group(1))
    return out


def _trip_count(line: str) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
    return int(m.group(1)) if m else 1


def _dot_flops(rec: OpRec, tab: dict[str, OpRec]) -> float:
    out_numel = float(np.prod(_shape_dims(rec.type_str)) or 1)
    mm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rec.line)
    cdims = [int(d) for d in mm.group(1).split(",") if d] if mm else []
    csize = 1.0
    if rec.operands and cdims:
        lhs = tab.get(rec.operands[0])
        if lhs is not None:
            dims = _shape_dims(lhs.type_str)
            for c in cdims:
                if c < len(dims):
                    csize *= dims[c]
    return 2.0 * out_numel * csize


def _fusion_param_charges(
    frec: OpRec, body: dict[str, OpRec]
) -> dict[int, float]:
    """Per-operand byte charge override for a fusion op.

    Operand i is charged at slice granularity when the fusion body consumes
    parameter(i) ONLY through dynamic-slice / dynamic-update-slice /
    scatter-operand-0 (the in-place cases)."""
    # parameter name -> operand index
    pidx: dict[str, int] = {}
    for rec in body.values():
        if rec.op == "parameter":
            m = re.search(r"parameter\((\d+)\)", rec.line)
            if m:
                pidx[rec.name] = int(m.group(1))
    charges: dict[int, float] = {}
    for pname, i in pidx.items():
        uses = [r for r in body.values() if pname in r.operands]
        if not uses:
            charges[i] = 0.0
            continue
        total = 0.0
        ok = True
        for u in uses:
            if u.op == "dynamic-slice" and u.operands and u.operands[0] == pname:
                total += 2.0 * _shape_bytes(u.type_str)
            elif u.op == "dynamic-update-slice" and u.operands and u.operands[0] == pname:
                upd = body.get(u.operands[1]) if len(u.operands) > 1 else None
                total += 2.0 * (_shape_bytes(upd.type_str) if upd else 0)
            elif u.op == "scatter" and u.operands and u.operands[0] == pname:
                upd = body.get(u.operands[-1])
                total += 2.0 * (_shape_bytes(upd.type_str) if upd else 0)
            else:
                ok = False
                break
        if ok:
            charges[i] = total
    return charges


def _is_convert_only(body: dict[str, OpRec]) -> bool:
    return all(
        r.op in ("parameter", "convert", "bitcast", "copy", "reshape", "tuple")
        for r in body.values()
    )


_FEEDER_OPS = (
    "parameter", "convert", "bitcast", "copy", "reshape", "tuple",
    "dynamic-slice", "transpose", "broadcast", "constant",
)


def _is_feeder(body: dict[str, OpRec]) -> bool:
    """Slicing/layout/dtype-only fusion: on TPU these fold into the consumer
    (MXU reads bf16 slices with arbitrary layout); the consumer charges the
    data once at its effective (slice × min-dtype) size."""
    return bool(body) and all(r.op in _FEEDER_OPS for r in body.values())


def _root_is_inplace(body: dict[str, OpRec]) -> bool:
    """Fusion whose root (through converts) is a dynamic-update-slice or
    scatter on a parameter — the write is the update region only; the full-
    stack output is the in-place aliased buffer, not traffic."""
    roots = [r for r in body.values() if "ROOT" in r.line]
    if not roots:
        return False
    r = roots[0]
    hop = 0
    while r.op in ("convert", "bitcast", "copy") and r.operands and hop < 4:
        nxt = body.get(r.operands[0])
        if nxt is None:
            return False
        r = nxt
        hop += 1
    return r.op in ("dynamic-update-slice", "scatter")


def analyze_hlo(hlo: str) -> HloAnalysis:
    comps = _split_computations(hlo)

    entry = next((c for c in comps if c.startswith("main")), None)
    if entry is None:
        entry = next(iter(comps))

    # pass 1: multipliers via call graph; mark fusion bodies
    mult: dict[str, float] = {entry: 1.0}
    analysis = HloAnalysis()
    fusion_bodies: set[str] = set()
    order, seen = [entry], {entry}
    while order:
        cname = order.pop(0)
        m = mult.get(cname, 1.0)
        for rec in comps.get(cname, {}).values():
            called = _called_computations(rec.line)
            tc = 1
            if rec.op == "while":
                tc = _trip_count(rec.line)
                analysis.while_trip_counts.append(tc)
            if rec.op == "fusion":
                fusion_bodies.update(called)
            for sub in called:
                mult[sub] = mult.get(sub, 0.0) + m * tc
                if sub not in seen:
                    seen.add(sub)
                    order.append(sub)

    # pass 2: accumulate with final multipliers
    for cname, tab in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0 or cname in fusion_bodies:
            continue

        def body_of(rec: OpRec) -> dict[str, OpRec]:
            called = _called_computations(rec.line)
            return comps.get(called[0], {}) if called else {}

        def operand_bytes(name: str) -> float:
            """Effective read bytes of an operand: follow feeder chains
            (convert / slice / transpose fusions the TPU folds into the
            consumer) — charge the operand's numel at the smallest dtype
            seen along the chain."""
            rec = tab.get(name)
            if rec is None:
                return 0.0
            numel = float(np.prod(_shape_dims(rec.type_str)) or 1)
            dt = _shape_bytes(rec.type_str) / max(numel, 1.0)
            src, hop = rec, 0
            while src is not None and hop < 6:
                if src.op == "convert" and src.operands:
                    nxt = tab.get(src.operands[0])
                elif src.op == "fusion" and _is_feeder(body_of(src)):
                    nxt = tab.get(src.operands[0]) if src.operands else None
                else:
                    break
                if nxt is None:
                    break
                n2 = float(np.prod(_shape_dims(nxt.type_str)) or 1)
                dt = min(dt, _shape_bytes(nxt.type_str) / max(n2, 1.0))
                src = nxt
                hop += 1
            return numel * dt

        for rec in tab.values():
            out_bytes = _shape_bytes(rec.type_str)
            if rec.op in ("dot", "convolution"):
                analysis.flops += m * _dot_flops(rec, tab)
                analysis.dot_count += 1
                analysis.hbm_bytes += m * (
                    out_bytes + sum(operand_bytes(o) for o in rec.operands)
                )
            elif rec.op == "fusion":
                body = body_of(rec)
                if _is_feeder(body):
                    continue  # folded into the consumer on TPU
                charges = _fusion_param_charges(rec, body)
                b = 0.0 if _root_is_inplace(body) else out_bytes
                for i, o in enumerate(rec.operands):
                    b += charges.get(i, operand_bytes(o))
                analysis.hbm_bytes += m * b
            elif rec.op == "dynamic-slice":
                analysis.hbm_bytes += m * 2.0 * out_bytes
            elif rec.op in ("dynamic-update-slice", "scatter"):
                upd = tab.get(rec.operands[1 if rec.op == "dynamic-update-slice" else -1]) if rec.operands else None
                analysis.hbm_bytes += m * 2.0 * (
                    _shape_bytes(upd.type_str) if upd else out_bytes
                )
            elif rec.op == "copy":
                # Copies of params / while results are donation-aliasing
                # artifacts (elided on TPU); others pay one write.
                src = tab.get(rec.operands[0]) if rec.operands else None
                if src is not None and src.op not in ("parameter", "get-tuple-element"):
                    analysis.hbm_bytes += m * out_bytes
            elif any(rec.op.startswith(c) for c in _COLLECTIVES):
                kind = next(c for c in _COLLECTIVES if rec.op.startswith(c))
                factor = 2.0 if kind == "all-reduce" else 1.0
                # Effective payload: the CPU backend promotes bf16 math to
                # f32 (``*_promoted`` reducers) and feeds collectives
                # through converts; a TPU moves the original dtype. Charge
                # numel × min dtype along the feeder chain.
                eff = out_bytes
                if rec.operands:
                    numel = float(np.prod(_shape_dims(rec.type_str)) or 1)
                    ob = operand_bytes(rec.operands[0])
                    o_rec = tab.get(rec.operands[0])
                    if o_rec is not None:
                        o_numel = float(
                            np.prod(_shape_dims(o_rec.type_str)) or 1
                        )
                        if o_numel > 0:
                            eff = min(eff, numel * ob / o_numel)
                b = m * eff * factor
                analysis.collective_bytes += b
                analysis.by_collective[kind] = (
                    analysis.by_collective.get(kind, 0.0) + b
                )
                analysis.collective_count += 1
                analysis.hbm_bytes += m * eff
    return analysis


def roofline_terms(
    analysis: HloAnalysis, model_flops_per_chip: float = 0.0
) -> dict:
    """Three roofline terms (seconds per step, per chip) + diagnosis."""
    compute = analysis.flops / HW["peak_flops"]
    memory = analysis.hbm_bytes / HW["hbm_bw"]
    collective = analysis.collective_bytes / HW["ici_bw"]
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    bound = max(compute, memory, collective)
    out = {
        **terms,
        "dominant": dom.replace("_s", ""),
        "step_time_bound_s": bound,
        "hlo_flops": analysis.flops,
        "hlo_bytes": analysis.hbm_bytes,
        "collective_bytes": analysis.collective_bytes,
        "by_collective": analysis.by_collective,
    }
    if model_flops_per_chip:
        out["model_flops"] = model_flops_per_chip
        out["useful_flops_frac"] = model_flops_per_chip / max(analysis.flops, 1.0)
        # roofline fraction: useful model FLOPs over what the chip could do
        # in the bound time — the score this report optimises.
        out["roofline_frac"] = (
            model_flops_per_chip / HW["peak_flops"] / max(bound, 1e-12)
        )
    return out
