"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be run as a module entry point (``python -m repro.launch.dryrun``) —
the first two lines below pin 512 placeholder host devices BEFORE any jax
initialisation, so ``make_production_mesh`` can build the 16×16 and 2×16×16
meshes. Smoke tests/benches never import this module and keep 1 device.

Per cell this script:
  1. builds the model + abstract params/opt-state/batch (ShapeDtypeStructs,
     zero allocation),
  2. jits the cell's step — train_step (loss+grad+AdamW update), prefill,
     or serve_step (one-token decode against a full-length cache) — with
     explicit in_shardings from launch/sharding.py,
  3. ``.lower().compile()`` under the mesh — any sharding mismatch,
     compile-time OOM or unsupported collective fails the cell,
  4. records memory_analysis / cost_analysis / the §Roofline terms parsed
     from the compiled HLO into a JSON blob for EXPERIMENTS.md.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, cells, get_config, get_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import analyze_hlo, roofline_terms  # noqa: E402
from repro.launch.sharding import (  # noqa: E402
    batch_shardings,
    make_dist,
    opt_shardings,
    param_shardings,
    state_shardings,
)
from repro.models.model import build  # noqa: E402
from repro.train.optim import OptConfig, OptState, apply_updates  # noqa: E402

# Grad-accumulation microbatch count per arch for the train_4k cell — keeps
# per-chip live activations inside v5e HBM (validated via memory_analysis).
TRAIN_MICROBATCHES = {
    "yi-9b": 8,
    "qwen3-1.7b": 4,
    "llama3.2-3b": 4,
    "mistral-large-123b": 16,
    "rwkv6-1.6b": 4,
    "llava-next-34b": 16,
    "recurrentgemma-2b": 4,
    "whisper-base": 2,
    "deepseek-moe-16b": 4,
    "granite-moe-1b-a400m": 2,
}


def _abstract_opt(params_sds) -> OptState:
    f32 = lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32)
    return OptState(
        m=jax.tree.map(f32, params_sds),
        v=jax.tree.map(f32, params_sds),
        step=jax.ShapeDtypeStruct((), jnp.int32),
    )


def analytic_memory_per_chip(model, shape, mesh, kind: str, micro: int = 1) -> dict:
    """TPU-native per-chip memory estimate (bf16 params/activations, fp32
    optimizer) — the CPU backend's memory_analysis is inflated by its
    bf16->f32 promotion pass, so we report both and judge fit on this one.
    """
    import numpy as np
    from repro.launch.sharding import param_rules
    from repro.models.params import ParamSpec

    cfg = model.cfg
    rules = param_rules(cfg, mesh)
    axis_size = {a: int(mesh.shape[a]) for a in mesh.axis_names}

    def leaf_bytes(spec: ParamSpec) -> float:
        n = float(np.prod(spec.shape))
        shards = 1
        for ax in spec.axes:
            mesh_ax = rules.get(ax) if ax else None
            if mesh_ax:
                shards *= axis_size.get(mesh_ax, 1)
        return n * np.dtype(spec.dtype).itemsize / shards

    leaves = jax.tree.leaves(
        model.param_specs(), is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    params_b = sum(leaf_bytes(s) for s in leaves)
    params_n = sum(
        float(np.prod(s.shape))
        / np.prod([axis_size.get(rules.get(a) or "", 1) for a in s.axes if a])
        for s in leaves
    )
    out = {"params_bytes": params_b}
    d = cfg.d_model
    chips = mesh.devices.size
    data_sh = axis_size.get("data", 1) * axis_size.get("pod", 1)
    if kind == "train":
        out["opt_bytes"] = params_n * 12  # m+v fp32 + grad fp32
        tokens_chip = shape.global_batch * shape.seq_len / micro / data_sh
        layers = cfg.num_layers + (cfg.encoder_layers or 0)
        # remat saves one [tokens, d] input per layer + ~4x working set
        out["act_bytes"] = tokens_chip * d * 2 * (layers + 4 * 3)
        out["logit_chunk_bytes"] = (
            shape.global_batch * shape.seq_len / max(cfg.xent_chunks, 1) / data_sh
            * cfg.padded_vocab / max(axis_size.get("model", 1), 1) * 4
        )
    elif kind == "prefill":
        tokens_chip = shape.global_batch * shape.seq_len / data_sh
        out["act_bytes"] = tokens_chip * d * 2 * 6
        kh, dh = cfg.num_kv_heads, cfg.resolved_head_dim
        m = axis_size.get("model", 1)
        kv_div = m if (kh % m == 0 or shape.seq_len % m == 0) else 1
        out["cache_bytes"] = (
            cfg.num_layers * tokens_chip * kh * dh * 2 * 2 / kv_div
        )
    else:  # decode
        state = model.init_state(shape.global_batch, shape.seq_len, abstract=True)
        from repro.launch.sharding import state_shardings

        shardings = state_shardings(model, mesh, state)
        total = 0.0
        for leaf, sh in zip(jax.tree.leaves(state), jax.tree.leaves(shardings)):
            n = float(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
            shards = 1
            for entry in sh.spec:
                if entry is None:
                    continue
                for ax in entry if isinstance(entry, tuple) else (entry,):
                    shards *= axis_size.get(ax, 1)
            total += n / shards
        out["state_bytes"] = total
    out["total_bytes"] = sum(v for v in out.values())
    out["fits_16GB"] = out["total_bytes"] < 16e9
    return out


def model_flops_per_chip(model, shape, mesh, kind: str) -> float:
    """6·N_active·tokens (train) / 2·N_active·tokens (inference), per chip."""
    n = model.active_params()
    chips = mesh.devices.size
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens / chips
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens / chips
    return 2.0 * n * shape.global_batch / chips  # decode: one token per seq


def build_cell(
    arch: str,
    shape_name: str,
    mesh,
    layout: str | None = None,
    quant: bool = False,
    micro: int = 0,
):
    """Returns (jitted_fn, abstract_args) for one cell."""
    cfg = get_config(arch)
    if layout:
        cfg = dataclasses.replace(cfg, layout=layout)
    if os.environ.get("DRYRUN_REMAT"):
        cfg = dataclasses.replace(cfg, remat=os.environ["DRYRUN_REMAT"])
    if os.environ.get("DRYRUN_OVERRIDES"):
        import json as _json

        cfg = dataclasses.replace(cfg, **_json.loads(os.environ["DRYRUN_OVERRIDES"]))
    shape = get_shape(shape_name)
    model = build(cfg)
    dist = make_dist(mesh, cfg.layout)
    p_sh = param_shardings(model, mesh)
    p_sds = model.abstract_params()
    if quant:  # int8-served weights (decode cells only)
        from repro.launch.sharding import quantized_param_shardings

        assert shape.kind == "decode", "--quant targets serve_step cells"
        p_sh, p_sds = quantized_param_shardings(model, mesh, p_sds)
    repl = NamedSharding(mesh, P())

    hot_args, hot_sh = (), ()
    if cfg.num_experts and cfg.hot_expert_slots:
        hot_args = (
            jax.ShapeDtypeStruct((cfg.num_layers, cfg.hot_expert_slots), jnp.int32),
        )
        hot_sh = (repl,)

    if shape.kind == "train":
        micro = micro or TRAIN_MICROBATCHES.get(arch, 1)
        o_sds = _abstract_opt(p_sds)
        o_sh = opt_shardings(model, mesh, o_sds)
        b_sds = model.input_specs(shape)
        b_sh = batch_shardings(model, mesh, b_sds)
        opt_cfg = OptConfig()

        def train_step(params, opt_state, batch, *hot):
            hot_ids = hot[0] if hot else None

            def loss_fn(p, mb):
                return model.loss(p, mb, dist, hot_ids=hot_ids)

            if micro > 1:
                mb_batch = jax.tree.map(
                    lambda x: x.reshape(micro, x.shape[0] // micro, *x.shape[1:]),
                    batch,
                )

                def body(acc, mb):
                    (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                    return (
                        jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc[0], g),
                        acc[1] + l,
                    ), None

                g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (grads, loss), _ = jax.lax.scan(body, (g0, 0.0), mb_batch)
                grads = jax.tree.map(lambda g: g / micro, grads)
                loss = loss / micro
            else:
                (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, batch
                )
            params2, opt2, _ = apply_updates(opt_cfg, params, grads, opt_state)
            return params2, opt2, loss

        fn = jax.jit(
            train_step,
            in_shardings=(p_sh, o_sh, b_sh) + hot_sh,
            donate_argnums=(0, 1),
        )
        return fn, (p_sds, o_sds, b_sds) + hot_args

    if shape.kind == "prefill":
        b_sds = model.input_specs(shape)
        b_sh = batch_shardings(model, mesh, b_sds)

        def prefill(params, batch, *hot):
            hot_ids = hot[0] if hot else None
            return model.prefill(params, batch, dist, hot_ids=hot_ids)

        fn = jax.jit(prefill, in_shardings=(p_sh, b_sh) + hot_sh)
        return fn, (p_sds, b_sds) + hot_args

    # decode: serve_step — one token against a seq_len cache
    s_sds = model.init_state(shape.global_batch, shape.seq_len, abstract=True)
    s_sh = state_shardings(model, mesh, s_sds)
    t_sds = jax.ShapeDtypeStruct((shape.global_batch,), jnp.int32)
    bspec = make_dist(mesh).batch
    if shape.global_batch % make_dist(mesh).batch_size:
        bspec = None
    t_sh = NamedSharding(mesh, P(bspec))

    def serve_step(params, state, tokens, *hot):
        hot_ids = hot[0] if hot else None
        return model.decode_step(params, state, tokens, dist, hot_ids=hot_ids)

    fn = jax.jit(
        serve_step, in_shardings=(p_sh, s_sh, t_sh) + hot_sh, donate_argnums=(1,)
    )
    return fn, (p_sds, s_sds, t_sds) + hot_args


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    layout: str | None = None,
    quant: bool = False,
    micro: int = 0,
) -> dict:
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build(get_config(arch))
    t0 = time.time()
    fn, args = build_cell(arch, shape_name, mesh, layout, quant, micro)
    with mesh:
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis() or {}
        hlo = compiled.as_text()
    ana = analyze_hlo(hlo)
    mf = model_flops_per_chip(model, shape, mesh, shape.kind)
    terms = roofline_terms(ana, mf)
    chips = mesh.devices.size
    peak_bytes = (
        mem.argument_size_in_bytes
        + mem.temp_size_in_bytes
        + mem.output_size_in_bytes
        - mem.alias_size_in_bytes
    )
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "chips": chips,
        "params": model.num_params(),
        "active_params": model.active_params(),
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "args_bytes": mem.argument_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_bytes_per_device": peak_bytes,
            "fits_16GB": peak_bytes < 16e9,
        },
        # TPU-native estimate (the CPU backend's f32-promotion pass inflates
        # peak_bytes_per_device by up to 2x for bf16 models; see DESIGN.md).
        "analytic_memory": analytic_memory_per_chip(
            model, shape, mesh, shape.kind,
            TRAIN_MICROBATCHES.get(arch, 1) if shape.kind == "train" else 1,
        ),
        "xla_cost_analysis": {
            k: cost.get(k) for k in ("flops", "bytes accessed") if k in cost
        },
        "roofline": terms,
        "hlo_stats": {
            "dot_ops": ana.dot_count,
            "collective_ops": ana.collective_count,
            "while_trip_counts": ana.while_trip_counts,
        },
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--layout", default="", help="override cfg.layout (tp|fsdp|serve)")
    ap.add_argument("--quant", action="store_true", help="int8-served weights (decode)")
    ap.add_argument("--micro", type=int, default=0, help="override train microbatches")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    if args.shape not in cells(args.arch):
        res = {
            "arch": args.arch,
            "shape": args.shape,
            "mesh": "2x16x16" if args.multi_pod else "16x16",
            "ok": True,
            "skipped": "long_500k requires sub-quadratic attention "
            "(full-attention arch; see DESIGN.md shape-cell skips)",
        }
    else:
        try:
            res = run_cell(
                args.arch, args.shape, args.multi_pod, args.layout or None,
                args.quant, args.micro,
            )
            if args.layout:
                res["layout"] = args.layout
            if args.quant:
                res["quant"] = True
        except Exception as e:  # a failing cell is a bug we must surface
            res = {
                "arch": args.arch,
                "shape": args.shape,
                "mesh": "2x16x16" if args.multi_pod else "16x16",
                "ok": False,
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:],
            }
    blob = json.dumps(res, indent=1, default=float)
    print(blob)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(blob)
    if not res.get("ok"):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
