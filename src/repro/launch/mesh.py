"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then calls this; smoke tests never call it and see 1 device.

Mesh shapes (TPU v5e, 256 chips/pod):
  single-pod: (16, 16)    axes (data, model)
  multi-pod:  (2, 16, 16) axes (pod, data, model)

Axis roles: ``data`` = FSDP + batch, ``model`` = TP/EP/vocab/sequence,
``pod`` = pure data parallelism across pods (params replicated per pod,
gradients all-reduced over the slow inter-pod links — where the gradient
compression of train/compress.py applies).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types (Auto matches the older default)
    from jax.sharding import AxisType

    def _axis_kwargs(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}

except ImportError:  # older jax: Auto is the only behaviour, kwarg absent

    def _axis_kwargs(n: int) -> dict:
        return {}


__all__ = ["make_production_mesh", "make_mesh", "mesh_num_nodes"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(shape)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (tests use small ones on forced host devices)."""
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(shape)))


def mesh_num_nodes(mesh: Mesh, axis: str = "model") -> int:
    """Redynis 'node' count for a mesh (EP ranks along the model axis)."""
    return int(mesh.shape[axis])
