"""Int8 weight quantization for serving (hillclimb C / §Perf).

Decode is bandwidth-bound: every step streams the full (sharded) weight set
through the chip once. Quantizing matrices to int8 with per-output-channel
scales halves/quarters both the HBM traffic and — when weights would
otherwise be FSDP-gathered per step — the collective traffic, and lets a
123B model serve weights-stationary (replicated over the data axis) inside
16 GB/chip.

Representation: a quantized leaf is the dict ``{"q": int8[...], "s":
f32[..., 1]}`` (scale broadcast over the last dim). ``dequant_tree`` maps
them back to bf16 — called INSIDE the layer scan body so only one layer's
weights materialise at a time. Norm/bias/router (small, precision-critical)
leaves stay in their original dtype.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import Array

__all__ = ["quantize_leaf", "quantize_tree", "is_quantized", "dequant_leaf", "dequant_tree", "abstract_quantize_tree"]

_MIN_QUANT_SIZE = 1 << 16  # leave small tensors (norms, biases) alone


def quantize_leaf(w: Array) -> dict:
    """Per-row (last-dim) symmetric int8: w ≈ q * s."""
    wf = w.astype(jnp.float32)
    s = jnp.max(jnp.abs(wf), axis=-1, keepdims=True) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(wf / s), -127, 127).astype(jnp.int8)
    return {"q": q, "s": s}


def is_quantized(leaf) -> bool:
    return isinstance(leaf, dict) and set(leaf.keys()) == {"q", "s"}


def dequant_leaf(leaf, dtype=jnp.bfloat16):
    if is_quantized(leaf):
        return (leaf["q"].astype(jnp.float32) * leaf["s"]).astype(dtype)
    return leaf


def _should_quantize(x) -> bool:
    return (
        hasattr(x, "ndim")
        and x.ndim >= 2
        and x.size >= _MIN_QUANT_SIZE
        and x.dtype in (jnp.bfloat16, jnp.float32, jnp.float16)
    )


def quantize_tree(tree):
    """Quantize every large matrix leaf; keep small/precision leaves."""
    return jax.tree.map(
        lambda x: quantize_leaf(x) if _should_quantize(x) else x, tree
    )


def abstract_quantize_tree(tree):
    """ShapeDtypeStruct version (dry-run: what the quantized tree looks like)."""

    def f(x):
        if _should_quantize(x):
            return {
                "q": jax.ShapeDtypeStruct(x.shape, jnp.int8),
                "s": jax.ShapeDtypeStruct(x.shape[:-1] + (1,), jnp.float32),
            }
        return x

    return jax.tree.map(f, tree)


def dequant_tree(tree, dtype=jnp.bfloat16):
    """Dequantize a (sub)tree — call inside the per-layer scan body."""
    return jax.tree.map(
        lambda x: dequant_leaf(x, dtype), tree, is_leaf=is_quantized
    )
