from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, reduced
from repro.configs.registry import ARCH_IDS, get_config, get_shape, cells
