"""whisper-base — enc-dec audio backbone, conv frontend stubbed [arXiv:2212.04356]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    num_layers=6,  # decoder
    encoder_layers=6,
    d_model=512,
    num_heads=8,
    num_kv_heads=8,  # MHA
    d_ff=2048,
    vocab_size=51865,
    head_dim=64,
    norm="layernorm",
    act="gelu",
    pos="sinusoidal",
    num_frames=1500,  # 30 s of audio after the (stubbed) conv frontend
)
