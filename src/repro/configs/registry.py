"""--arch registry: assigned-architecture ids -> ModelConfig."""

from __future__ import annotations

import importlib

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, reduced

_MODULES = {
    "yi-9b": "yi_9b",
    "qwen3-1.7b": "qwen3_1_7b",
    "llama3.2-3b": "llama3_2_3b",
    "mistral-large-123b": "mistral_large_123b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "llava-next-34b": "llava_next_34b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "whisper-base": "whisper_base",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cells(arch: str) -> list[str]:
    """The shape cells this arch runs (assignment skip rules)."""
    cfg = get_config(arch)
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        out.append("long_500k")  # sub-quadratic archs only
    return out


__all__ = ["ARCH_IDS", "get_config", "get_shape", "cells", "reduced", "SHAPES"]
