"""llava-next-34b — VLM, anyres tiling (stub frontend) [hf:llava-hf/llava-v1.6]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    num_layers=60,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    head_dim=128,
    rope_theta=5e6,
    num_patches=2880,  # anyres: up to ~2880 image tokens (stub embeddings)
    hot_embed_rows=2048,
)
