"""recurrentgemma-2b — RG-LRU + local attention, 1:2 [arXiv:2402.19427; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,  # MQA
    d_ff=7680,
    vocab_size=256000,
    head_dim=256,
    window=2048,  # local attention window
    attention_period=3,  # (rec, rec, attn) repeating
    lru_width=2560,
    hot_embed_rows=8192,  # 256000-row table, heaviest embedding of the pool
)
