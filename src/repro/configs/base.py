"""Config system: architecture + shape-cell + run configs.

One :class:`ModelConfig` per assigned architecture lives in
``repro/configs/<id>.py``; the four assigned input-shape cells are global
(:data:`SHAPES`). ``repro.configs.registry`` resolves ``--arch`` ids.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "reduced"]


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # -- attention details --
    qk_norm: bool = False  # qwen3-style RMSNorm on q/k heads
    rope_theta: float = 1e4
    window: int = 0  # sliding-window size for local attention (0 = full)
    pos: str = "rope"  # rope | sinusoidal (whisper-style, added at embed)

    # -- MoE --
    num_experts: int = 0  # routed experts (0 = dense FFN)
    num_shared_experts: int = 0
    top_k: int = 0

    # -- hybrid (RecurrentGemma-style) --
    attention_period: int = 0  # every k-th layer is (local) attention, rest RG-LRU
    lru_width: int = 0  # recurrence width (0 -> d_model)

    # -- ssm (RWKV6) --
    rwkv_head_dim: int = 64

    # -- encoder-decoder (Whisper-style) --
    encoder_layers: int = 0
    num_frames: int = 0  # stub audio frontend: precomputed frame embeddings

    # -- vlm (LLaVA-style) --
    num_patches: int = 0  # stub vision frontend: precomputed patch embeddings

    # -- norms / activations --
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    act: str = "swiglu"  # swiglu | gelu

    # -- MoE routing (GShard-style capacity dispatch) --
    moe_group_size: int = 512  # tokens per dispatch group
    moe_capacity_factor: float = 1.25
    # einsum — one-hot dispatch/combine matmuls (GShard baseline)
    # sort   — argsort + gather/scatter (no dispatch matmul FLOPs; §Perf B5)
    moe_impl: str = "einsum"
    # With the Redynis replica cache on, the cold (all-to-all) capacity
    # shrinks to this fraction and the hot local path absorbs the rest.
    moe_cold_capacity: float = 0.5
    moe_hot_capacity: float = 0.75
    moe_aux_weight: float = 0.01  # load-balance aux loss weight

    # -- Redynis integration --
    hot_expert_slots: int = 0  # R replica slots per layer (0 = technique off)
    hot_embed_rows: int = 0  # hot-row embedding cache size (0 = off)
    sweep_period: int = 50  # steps between placement-daemon sweeps
    ownership_h: float = 0.0  # ownership coefficient (0 -> 1/n at runtime)
    traffic_decay: float = 0.98  # EMA decay of traffic stats per sweep

    # -- distribution layout (hillclimb knob; see launch/sharding.py) --
    #   tp    — Megatron-style: FSDP over data × TP over model (baseline)
    #   fsdp  — ZeRO-3-pure: params sharded over (data×model) jointly,
    #           batch over all axes, no tensor parallelism (activation
    #           all-reduces vanish; per-layer param all-gathers instead)
    #   serve — weights-stationary decode: params replicated over data,
    #           TP over model (no per-step FSDP gathers at inference)
    layout: str = "tp"

    # -- numerics / training --
    dtype: str = "bfloat16"
    remat: str = "full"  # none | full  (activation checkpointing per layer)
    tie_embeddings: bool = False
    xent_chunks: int = 8  # token chunks for the vocab-sharded loss
    attn_chunk: int = 1024  # q/kv block size for blockwise attention

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows: vocab rounded up to 512 so the table always
        splits across the model axis (and rows stay MXU-aligned). Logits for
        the padding rows are masked to -inf in repro.dist."""
        return ((self.vocab_size + 511) // 512) * 512

    @property
    def is_subquadratic(self) -> bool:
        """Eligible for the long_500k cell (SSM / hybrid-local-attention)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decode(self) -> bool:
        """Encoder-only archs have none; everything assigned here decodes."""
        return True


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (small layers/width/vocab,
    few experts) — structure preserved, scale removed."""
    small = dict(
        num_layers=min(cfg.num_layers, 4 if cfg.attention_period else 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads else 0,
        d_ff=256,
        vocab_size=512,
        head_dim=32,
        num_experts=min(cfg.num_experts, 8),
        num_shared_experts=min(cfg.num_shared_experts, 2),
        top_k=min(cfg.top_k, 2),
        lru_width=128 if cfg.lru_width else 0,
        window=min(cfg.window, 64) if cfg.window else 0,
        encoder_layers=min(cfg.encoder_layers, 2),
        num_frames=min(cfg.num_frames, 32),
        num_patches=min(cfg.num_patches, 16),
        hot_expert_slots=min(cfg.hot_expert_slots, 4),
        hot_embed_rows=min(cfg.hot_embed_rows, 64),
        remat="none",
    )
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
