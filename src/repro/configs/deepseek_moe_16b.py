"""deepseek-moe-16b — fine-grained MoE, 2 shared + 64 routed top-6
[arXiv:2401.06066; hf]. The flagship Redynis arch: many small experts with
zipfian routing traffic are exactly the paper's key-value population."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,  # MHA
    d_ff=1408,  # per-expert width (fine-grained)
    vocab_size=102400,
    head_dim=128,
    rope_theta=1e4,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    hot_expert_slots=8,  # Redynis replica cache (R slots per layer)
    hot_embed_rows=2048,
)
