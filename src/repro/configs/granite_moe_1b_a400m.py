"""granite-moe-1b-a400m — 32 experts top-8 [hf:ibm-granite/granite-3.0-1b]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,  # per-expert width
    vocab_size=49155,
    head_dim=64,
    num_experts=32,
    top_k=8,
    tie_embeddings=True,
    hot_expert_slots=6,
    hot_embed_rows=1024,
)
