"""AdamW + schedule + clipping, as pure pytree transforms (no optax here).

Optimizer state mirrors the param tree (m, v in fp32 regardless of param
dtype), so the launch layer shards it with the same PartitionSpecs as the
params — optimizer memory scales down with FSDP exactly like the weights.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

__all__ = ["OptConfig", "OptState", "init_opt", "apply_updates", "lr_at", "global_norm"]


class OptConfig(NamedTuple):
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    m: dict  # fp32, like params
    v: dict  # fp32, like params
    step: Array  # [] int32


def init_opt(params) -> OptState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        step=jnp.zeros((), jnp.int32),
    )


def lr_at(cfg: OptConfig, step: Array) -> Array:
    """Linear warmup -> cosine decay to min_lr_frac."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(math.pi * prog))
    frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def apply_updates(
    cfg: OptConfig, params, grads, state: OptState
) -> tuple[dict, OptState, dict]:
    """One AdamW step. Returns (params', state', metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        # Decoupled weight decay on matrices only (ndim >= 2).
        if p.ndim >= 2:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(m=new_m, v=new_v, step=step), metrics
