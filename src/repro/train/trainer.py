"""Training loop: grad-accumulated step + the Redynis daemon in the loop.

The jitted step is pure and donated (params/opt-state buffers reused); the
host loop around it does only paper-daemon things: fold traffic statistics,
trigger sweeps at the period boundary, checkpoint asynchronously. Placement
changes (new ``hot_ids`` / hot-row cache) feed the *next* step's inputs —
the non-blocking property: a sweep never stalls the step that overlaps it.
"""

from __future__ import annotations

import time
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import Array

from repro.core.expert_placement import ExpertPlacement, ExpertPlacementState
from repro.core.hot_embedding import HotEmbedding, HotEmbeddingState
from repro.data.pipeline import Pipeline
from repro.dist import DistSpec
from repro.models.model import Model
from repro.train import checkpoint as ckpt_lib
from repro.train.optim import OptConfig, OptState, apply_updates, init_opt

__all__ = ["TrainConfig", "TrainState", "Trainer"]


class TrainConfig(NamedTuple):
    opt: OptConfig = OptConfig()
    microbatches: int = 1
    checkpoint_dir: str = ""
    checkpoint_every: int = 0
    keep_checkpoints: int = 3
    log_every: int = 10
    # Cross-pod gradient compression (train/compress.py): "none" | "int8".
    # In a multi-pod deployment this wraps the inter-pod all-reduce; here it
    # is applied to the global gradient with stochastic rounding so the
    # convergence impact is the same thing the pods would see.
    grad_compression: str = "none"


class TrainState(NamedTuple):
    params: dict
    opt: OptState
    expert_placement: Optional[ExpertPlacementState]
    hot_embed: Optional[HotEmbeddingState]
    data_step: int  # pipeline position (host int — exact replay key)


class Trainer:
    def __init__(
        self,
        model: Model,
        cfg: TrainConfig,
        dist: Optional[DistSpec] = None,
        num_nodes: int = 1,
    ):
        self.model = model
        self.cfg = cfg
        self.dist = dist
        self.num_nodes = num_nodes
        mcfg = model.cfg

        self.expert_daemon = None
        if mcfg.num_experts and mcfg.hot_expert_slots:
            self.expert_daemon = ExpertPlacement(
                mcfg.num_layers,
                mcfg.num_experts,
                num_nodes,
                mcfg.hot_expert_slots,
                h=mcfg.ownership_h or None,
                decay=mcfg.traffic_decay,
                period=mcfg.sweep_period,
            )
        self.embed_daemon = None
        if mcfg.hot_embed_rows:
            self.embed_daemon = HotEmbedding(
                mcfg.padded_vocab,
                num_nodes,
                mcfg.hot_embed_rows,
                h=mcfg.ownership_h or None,
                decay=mcfg.traffic_decay,
                period=mcfg.sweep_period,
            )
        self._step_fn = self._build_step()

    # ------------------------------------------------------------------ init
    def init_state(self, rng: Array) -> TrainState:
        params = self.model.init(rng)
        return TrainState(
            params=params,
            opt=init_opt(params),
            expert_placement=(
                self.expert_daemon.init_state() if self.expert_daemon else None
            ),
            hot_embed=(
                self.embed_daemon.init_state() if self.embed_daemon else None
            ),
            data_step=0,
        )

    # ------------------------------------------------------------------ step
    def _build_step(self):
        model, cfg = self.model, self.cfg

        def loss_fn(params, mb, hot_ids, hot_embed):
            return model.loss(
                params, mb, self.dist, hot_ids=hot_ids, hot_embed=hot_embed
            )

        def step(params, opt_state, batch, hot_ids, hot_embed):
            m = cfg.microbatches
            if m > 1:
                batch = jax.tree.map(
                    lambda x: x.reshape(m, x.shape[0] // m, *x.shape[1:]), batch
                )

                def micro(carry, mb):
                    g_acc, metr_acc = carry
                    (loss, metrics), g = jax.value_and_grad(
                        loss_fn, has_aux=True
                    )(params, mb, hot_ids, hot_embed)
                    g_acc = jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g
                    )
                    metr_acc = jax.tree.map(lambda a, b: a + b, metr_acc, metrics)
                    return (g_acc, metr_acc), None

                g0 = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                mb0 = jax.tree.map(lambda x: x[0], batch)
                _, metrics_sds = jax.eval_shape(
                    loss_fn, params, mb0, hot_ids, hot_embed
                )
                metr0 = jax.tree.map(
                    lambda s: jnp.zeros(s.shape, s.dtype), metrics_sds
                )
                (grads, metrics), _ = jax.lax.scan(micro, (g0, metr0), batch)
                grads = jax.tree.map(lambda g: g / m, grads)
                metrics = jax.tree.map(lambda x: x / m, metrics)
            else:
                (loss, metrics), grads = jax.value_and_grad(
                    loss_fn, has_aux=True
                )(params, batch, hot_ids, hot_embed)

            if cfg.grad_compression == "int8":
                from repro.train.compress import dequantize_int8, quantize_int8

                key = jax.random.fold_in(
                    jax.random.PRNGKey(12), opt_state.step
                )
                leaves, treedef = jax.tree.flatten(grads)
                keys = jax.random.split(key, len(leaves))
                grads = treedef.unflatten(
                    [
                        dequantize_int8(quantize_int8(g, k))
                        for g, k in zip(leaves, keys)
                    ]
                )
            params2, opt2, opt_metrics = apply_updates(
                cfg.opt, params, grads, opt_state
            )
            metrics.update(opt_metrics)
            return params2, opt2, metrics

        return jax.jit(step, donate_argnums=(0, 1))

    # ------------------------------------------------------------------ run
    def run(
        self,
        state: TrainState,
        pipeline: Pipeline,
        steps: int,
        log: bool = True,
    ) -> tuple[TrainState, list[dict]]:
        cfg = self.cfg
        pstate = pipeline.seek(state.data_step)
        history: list[dict] = []
        pending_save = None

        for i in range(steps):
            batch, pstate = pipeline.next(pstate)
            hot_ids = (
                state.expert_placement.hot_ids
                if state.expert_placement is not None
                else None
            )
            t0 = time.perf_counter()
            params, opt, metrics = self._step_fn(
                state.params, state.opt, batch, hot_ids, state.hot_embed
            )
            step_idx = int(opt.step)
            dt = time.perf_counter() - t0

            # ---- Redynis daemon: fold traffic, sweep on period ------------
            ep, he = state.expert_placement, state.hot_embed
            if self.expert_daemon is not None and "moe_counts" in metrics:
                g = metrics["moe_counts"].shape[1]
                group_nodes = self._group_nodes(g)
                ep = self.expert_daemon.fold(ep, metrics["moe_counts"], group_nodes)
                if self.expert_daemon.due(step_idx):
                    ep = self.expert_daemon.sweep(ep)
            if self.embed_daemon is not None:
                tok_nodes = self._token_nodes(batch["tokens"].shape[0])
                he = self.embed_daemon.fold(he, batch["tokens"], tok_nodes)
                if self.embed_daemon.due(step_idx):
                    he = self.embed_daemon.sweep(he)

            state = TrainState(
                params=params,
                opt=opt,
                expert_placement=ep,
                hot_embed=he,
                data_step=int(pstate.step),
            )

            # ---- checkpoint / log -----------------------------------------
            if cfg.checkpoint_every and step_idx % cfg.checkpoint_every == 0:
                if pending_save is not None:
                    pending_save.wait()
                pending_save = ckpt_lib.save_async(
                    cfg.checkpoint_dir,
                    step_idx,
                    {"params": state.params, "opt": state.opt},
                    metadata={"data_step": state.data_step},
                )
                ckpt_lib.gc_checkpoints(cfg.checkpoint_dir, cfg.keep_checkpoints)

            scalars = {
                k: float(v)
                for k, v in metrics.items()
                if hasattr(v, "ndim") and v.ndim == 0
            }
            scalars["step"] = step_idx
            scalars["step_time_s"] = dt
            history.append(scalars)
            if log and (step_idx % cfg.log_every == 0 or i == steps - 1):
                msg = f"step {step_idx}: loss={scalars.get('loss', 0):.4f}"
                if "moe_hot_frac" in scalars:
                    msg += f" hot_frac={scalars['moe_hot_frac']:.3f}"
                print(msg, flush=True)

        if pending_save is not None:
            pending_save.wait()
        return state, history

    # ------------------------------------------------------------------ maps
    def _group_nodes(self, g: int) -> Array:
        """Dispatch-group -> EP-rank map (data-major block layout)."""
        per = max(g // max(self.num_nodes, 1), 1)
        return (jnp.arange(g, dtype=jnp.int32) // per) % self.num_nodes

    def _token_nodes(self, b: int) -> Array:
        per = max(b // max(self.num_nodes, 1), 1)
        return (jnp.arange(b, dtype=jnp.int32) // per) % self.num_nodes

    # ------------------------------------------------------------------ ckpt
    def restore(self, rng: Array) -> TrainState:
        """Restore from the latest checkpoint (fresh init if none)."""
        state = self.init_state(rng)
        if not self.cfg.checkpoint_dir:
            return state
        try:
            tree, manifest = ckpt_lib.restore_checkpoint(
                self.cfg.checkpoint_dir,
                template={"params": state.params, "opt": state.opt},
            )
        except FileNotFoundError:
            return state
        return state._replace(
            params=jax.tree.map(jnp.asarray, tree["params"]),
            opt=jax.tree.map(jnp.asarray, tree["opt"]),
            data_step=int(manifest["metadata"].get("data_step", 0)),
        )
