"""Sharded, atomic, async checkpointing with resharding restore.

Layout (one directory per step)::

    <root>/step_00000123/
        manifest.json          # tree structure, shapes, dtypes, metadata
        <leaf-path>.npy        # one file per pytree leaf
    <root>/LATEST              # atomically-updated pointer

Properties required at 1000-node scale, and how they're met here:
  * atomic    — writes go to ``step_N.tmp-<pid>`` then os.replace (POSIX
                rename atomicity); LATEST is written last, same trick. A
                crash mid-save can never corrupt a previous checkpoint.
  * sharded   — ``shard_filter`` lets each host write only the leaves it
                owns (process_index-based in a real multi-host run); the
                manifest is written by host 0.
  * async     — ``save_async`` snapshots to host memory (device_get) and
                writes on a worker thread; the train loop never blocks on
                the filesystem.
  * reshard   — restore returns host numpy; the caller device_puts with
                *its* shardings (mesh shape may differ from save time —
                elastic restart).
"""

from __future__ import annotations

import json
import os
import threading
from typing import Any, Callable, NamedTuple

import jax
import numpy as np

__all__ = ["save_checkpoint", "save_async", "restore_checkpoint", "latest_step", "gc_checkpoints"]

_SEP = "__"


def _leaf_paths(tree) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((name, leaf))
    return out


def save_checkpoint(
    root: str,
    step: int,
    tree,
    metadata: dict | None = None,
    shard_filter: Callable[[str], bool] | None = None,
) -> str:
    """Blocking sharded save. Returns the checkpoint directory."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = f"{final}.tmp-{os.getpid()}"
    os.makedirs(tmp, exist_ok=True)

    leaves = _leaf_paths(tree)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for name, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        manifest["leaves"][name] = {
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
        if shard_filter is None or shard_filter(name):
            np.save(os.path.join(tmp, name + ".npy"), arr)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)

    if os.path.exists(final):  # idempotent re-save
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)

    latest_tmp = os.path.join(root, f".LATEST.tmp-{os.getpid()}")
    with open(latest_tmp, "w") as f:
        f.write(str(step))
    os.replace(latest_tmp, os.path.join(root, "LATEST"))
    return final


class AsyncSave(NamedTuple):
    thread: threading.Thread

    def wait(self) -> None:
        self.thread.join()


def save_async(root: str, step: int, tree, metadata: dict | None = None) -> AsyncSave:
    """Snapshot to host now, write on a worker thread (non-blocking save)."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    t = threading.Thread(
        target=save_checkpoint, args=(root, step, host_tree, metadata), daemon=True
    )
    t.start()
    return AsyncSave(thread=t)


def latest_step(root: str) -> int | None:
    p = os.path.join(root, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return int(f.read().strip())


def restore_checkpoint(root: str, step: int | None = None, template=None):
    """Load a checkpoint as host numpy.

    With ``template`` (any pytree of matching structure) the result is
    unflattened into that structure; otherwise a flat {leaf-path: array}
    dict is returned. metadata comes back alongside.
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    def _load(name: str) -> np.ndarray:
        arr = np.load(os.path.join(d, name + ".npy"))
        want = manifest["leaves"][name]["dtype"]
        if str(arr.dtype) != want:
            # Extension dtypes (bfloat16 etc.) round-trip as raw void bytes.
            import ml_dtypes

            arr = arr.view(np.dtype(getattr(ml_dtypes, want, want)))
        return arr

    flat = {name: _load(name) for name in manifest["leaves"]}
    if template is None:
        return flat, manifest
    names = [n for n, _ in _leaf_paths(template)]
    leaves = [flat[n] for n in names]
    treedef = jax.tree.structure(template)
    return jax.tree.unflatten(treedef, leaves), manifest


def gc_checkpoints(root: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` checkpoints."""
    import shutil

    if not os.path.isdir(root):
        return
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(root) if n.startswith("step_") and not n.endswith((".tmp", ".npy")) and "tmp" not in n
    )
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"), ignore_errors=True)
