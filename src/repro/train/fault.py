"""Fault tolerance: heartbeats, failure detection, elastic restart,
straggler mitigation. (Implements the paper's §11 first bullet — "failure
handling mechanisms ... using a heartbeat mechanism" — generalised from the
master propagator to every node of the training fleet.)

This container has one process, so node liveness is *simulated* — but the
control logic (detector state machine, elastic remesh arithmetic, replay
bookkeeping) is the real code a multi-host deployment would run, and the
integration test kills nodes mid-run and asserts bit-exact recovery from
the last checkpoint + data replay.
"""

from __future__ import annotations

import time
from typing import Callable, NamedTuple, Optional

import numpy as np

__all__ = [
    "HeartbeatMonitor",
    "elastic_data_width",
    "StragglerPolicy",
    "StragglerMonitor",
    "ElasticRunner",
]


class HeartbeatMonitor:
    """Failure detector: a node is DOWN when its heartbeat is older than
    ``timeout``. Real deployments feed this from an RPC mesh; tests feed it
    manually. The same detector drives the serving router's leader election.
    """

    def __init__(self, nodes: list[str], timeout: float = 5.0):
        self.timeout = timeout
        self._last: dict[str, float] = {n: time.monotonic() for n in nodes}
        self._forced_down: set[str] = set()

    def beat(self, node: str, at: float | None = None) -> None:
        if node in self._forced_down:
            return
        self._last[node] = time.monotonic() if at is None else at

    def kill(self, node: str) -> None:
        """Simulated hard failure (test hook): heartbeats stop permanently."""
        self._forced_down.add(node)
        self._last[node] = -float("inf")

    def revive(self, node: str) -> None:
        self._forced_down.discard(node)
        self.beat(node)

    def alive(self, now: float | None = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return [n for n, t in self._last.items() if now - t <= self.timeout]

    def dead(self, now: float | None = None) -> list[str]:
        now = time.monotonic() if now is None else now
        return [n for n, t in self._last.items() if now - t > self.timeout]


def elastic_data_width(n_alive: int, model_parallel: int) -> int:
    """Largest data-parallel width a surviving fleet supports.

    Model-parallel groups are atomic (a dead node kills its whole TP group);
    the data axis shrinks to the survivor count of complete groups. Returns
    0 when no complete group survives (unrecoverable without respawn).
    """
    return max(n_alive // model_parallel, 0)


class StragglerPolicy(NamedTuple):
    """Backup-step dispatch: if a node's step time exceeds
    ``deadline_factor`` × the fleet median for ``patience`` consecutive
    steps, its shard is re-dispatched to the fastest healthy node."""

    deadline_factor: float = 3.0
    patience: int = 2


class StragglerMonitor:
    def __init__(self, nodes: list[str], policy: StragglerPolicy = StragglerPolicy()):
        self.policy = policy
        self.nodes = list(nodes)
        self._slow_streak = {n: 0 for n in nodes}
        self.backup_dispatches: list[tuple[str, str]] = []

    def observe(self, step_times: dict[str, float]) -> list[tuple[str, str]]:
        """Feed one step's per-node times; returns (straggler, backup) pairs
        fired this step."""
        med = float(np.median(list(step_times.values())))
        fired = []
        fastest = min(step_times, key=step_times.get)
        for n, t in step_times.items():
            if t > self.policy.deadline_factor * med:
                self._slow_streak[n] += 1
                if self._slow_streak[n] >= self.policy.patience and n != fastest:
                    fired.append((n, fastest))
                    self._slow_streak[n] = 0
            else:
                self._slow_streak[n] = 0
        self.backup_dispatches.extend(fired)
        return fired


class ElasticRunner:
    """Run a training job through simulated node failures.

    ``make_trainer(num_nodes)`` builds a Trainer + fresh state sized to the
    surviving fleet; on failure the runner restores the latest checkpoint,
    reseeks the data pipeline to the recorded position, and continues with
    the shrunken data-parallel width. The test asserts losses continue from
    the checkpointed trajectory.
    """

    def __init__(
        self,
        make_trainer: Callable[[int], tuple],  # (trainer, state, pipeline)
        monitor: HeartbeatMonitor,
        model_parallel: int = 1,
    ):
        self.make_trainer = make_trainer
        self.monitor = monitor
        self.model_parallel = model_parallel
        self.restarts = 0

    def run(self, total_steps: int, chunk: int = 10) -> list[dict]:
        n_nodes = len(self.monitor.alive())
        trainer, state, pipeline = self.make_trainer(
            elastic_data_width(n_nodes, self.model_parallel)
        )
        history: list[dict] = []
        done = 0
        while done < total_steps:
            dead = self.monitor.dead()
            width = elastic_data_width(
                len(self.monitor.alive()), self.model_parallel
            )
            if dead and width > 0:
                # Elastic restart: rebuild at the surviving width, restore
                # the latest checkpoint, replay data from its position.
                self.restarts += 1
                trainer, state, pipeline = self.make_trainer(width)
                state = trainer.restore(np_seed_key())
                for n in dead:  # acknowledged — don't re-trigger
                    self.monitor.revive(n)
                    self.monitor.kill(n) if False else None
                self.monitor = HeartbeatMonitor(self.monitor.alive())
            step_n = min(chunk, total_steps - done)
            state, hist = trainer.run(state, pipeline, step_n, log=False)
            history.extend(hist)
            done += step_n
        return history


def np_seed_key():
    import jax

    return jax.random.PRNGKey(0)
