"""Gradient compression for cross-pod reduction (beyond-paper, scale kit).

Two composable codecs, both with exact size accounting so the launch layer
can trade collective bytes for steps-to-converge:

  * int8 quantisation — per-tensor symmetric scale, 4x byte reduction on
    fp32 grads (2x on bf16); unbiased via stochastic rounding.
  * top-k sparsification with error feedback — keeps the k largest-|g|
    entries per tensor, accumulates the residual locally (Stich et al.
    error feedback), so the sparsification bias vanishes over steps.

Intended placement: *between pods* (the slow DCI hops), not inside a pod —
mirrors the paper's geo-distributed remote-penalty asymmetry.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import Array

__all__ = [
    "QuantGrad",
    "quantize_int8",
    "dequantize_int8",
    "TopKGrad",
    "topk_encode",
    "topk_decode",
    "ErrorFeedback",
]


class QuantGrad(NamedTuple):
    q: Array  # int8 payload
    scale: Array  # [] f32

    @property
    def nbytes(self) -> int:
        return self.q.size + 4


def quantize_int8(g: Array, key: Array | None = None) -> QuantGrad:
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    x = gf / scale
    if key is not None:  # stochastic rounding -> unbiased
        x = jnp.floor(x + jax.random.uniform(key, x.shape))
    else:
        x = jnp.round(x)
    return QuantGrad(q=jnp.clip(x, -127, 127).astype(jnp.int8), scale=scale)


def dequantize_int8(qg: QuantGrad) -> Array:
    return qg.q.astype(jnp.float32) * qg.scale


class TopKGrad(NamedTuple):
    idx: Array  # [k] int32 flat indices
    val: Array  # [k] f32
    shape: tuple

    @property
    def nbytes(self) -> int:
        return self.idx.size * 4 + self.val.size * 4


def topk_encode(g: Array, k: int) -> tuple[TopKGrad, Array]:
    """Returns (sparse grad, residual to fold into error feedback)."""
    gf = g.astype(jnp.float32).reshape(-1)
    k = min(k, gf.size)
    val, idx = jax.lax.top_k(jnp.abs(gf), k)
    picked = gf[idx]
    dense_kept = jnp.zeros_like(gf).at[idx].set(picked)
    residual = (gf - dense_kept).reshape(g.shape)
    return TopKGrad(idx=idx.astype(jnp.int32), val=picked, shape=g.shape), residual


def topk_decode(tg: TopKGrad) -> Array:
    size = 1
    for s in tg.shape:
        size *= s
    return jnp.zeros((size,), jnp.float32).at[tg.idx].set(tg.val).reshape(tg.shape)


class ErrorFeedback(NamedTuple):
    """Per-tensor residual memory for top-k (init zeros_like(grads))."""

    residual: dict

    @staticmethod
    def init(grads) -> "ErrorFeedback":
        return ErrorFeedback(
            residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
        )

    def compress_step(self, grads, k: int):
        """grads + residual -> (sparse tree, new feedback)."""
        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = treedef.flatten_up_to(self.residual)
        enc, res = [], []
        for g, r in zip(flat_g, flat_r):
            e, nr = topk_encode(g.astype(jnp.float32) + r, k)
            enc.append(e)
            res.append(nr)
        return treedef.unflatten(enc), ErrorFeedback(residual=treedef.unflatten(res))
