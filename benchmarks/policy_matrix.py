"""Policy head-to-head matrix on the 5-region WAN — the experiment grid the
policy API exists for: every registered decision rule (Algorithm 3, the
static baselines, top-K replication, size-aware cost-greedy, decayed-LFU)
on the same skewed geo workload, seeds batched and same-family dynamic
params vmapped into one program per family (``run_experiment(policies=...)``).

Emits per-policy hit-rate / mean-latency / throughput rows and persists
``BENCH_policy_matrix.json``.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import (
    WAN5_WORKLOAD_KWARGS,
    banner,
    dedupe_policies,
    emit,
    write_bench_json,
)
from repro.kvsim import (
    TelemetryConfig,
    parse_policy,
    run_experiment,
    wan5_cluster,
)

# Spec strings (registry-parsed) so the matrix is CLI-overridable.
DEFAULT_POLICIES = (
    "local",
    "remote",
    "replicated",
    "redynis",
    "redynis:h=0.05,decay=0.9",
    "topk:k=100",
    "costgreedy",
    "decaylfu:alpha=0.5",
)


def main(
    num_requests: int = 30_000,
    iterations: int = 3,
    read_fraction: float = 0.9,
    policy_specs=DEFAULT_POLICIES,
    policy=None,
    replay_backend: str = "jax",
) -> dict:
    banner("policy_matrix: policy head-to-head on the wan5 geo cluster")
    candidates = [parse_policy(s) for s in policy_specs]
    if policy is not None:
        candidates.append(policy)
    policies = dedupe_policies(candidates, 5)
    t_start = time.perf_counter()
    res = run_experiment(
        read_fractions=(read_fraction,),
        skewed=True,
        iterations=iterations,
        num_requests=num_requests,
        cluster=wan5_cluster(),
        policies=policies,
        telemetry=TelemetryConfig(),
        replay_backend=replay_backend,
        **WAN5_WORKLOAD_KWARGS,
    )
    rows, quantiles = [], {}
    for label, policy_rows in res["policies"].items():
        row = policy_rows[0]
        emit(
            "policy_matrix",
            round(row["hit_rate"], 4),
            "hit_rate",
            policy=label,
            mean_latency_ms=round(row["mean_latency_ms"], 2),
            p99_latency_ms=round(row["p99_latency_ms"], 2),
            p99_ci99=round(row["p99_ci99"], 2),
            throughput=round(row["throughput"], 2),
            ci99=round(row["ci99"], 2),
        )
        quantiles[label] = row["quantiles"]
        rows.append(
            {
                "policy": label,
                "read_fraction": row["read_fraction"],
                "hit_rate": row["hit_rate"],
                "mean_latency_ms": row["mean_latency_ms"],
                "p99_latency_ms": row["p99_latency_ms"],
                "p99_ci99": row["p99_ci99"],
                "throughput_ops_s": row["throughput"],
                "ci99": row["ci99"],
            }
        )
    write_bench_json(
        "policy_matrix",
        {
            "rows": rows,
            "num_batched_calls": res["num_batched_calls"],
            "wall_time_s": time.perf_counter() - t_start,
        },
        quantiles=quantiles,
        num_requests=num_requests,
        iterations=iterations,
        read_fraction=read_fraction,
        cluster="wan5",
        replay_backend=replay_backend,
    )
    return res


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num-requests", type=int, default=30_000)
    ap.add_argument("--iterations", type=int, default=3)
    ap.add_argument("--read-fraction", type=float, default=0.9)
    ap.add_argument(
        "--policies", nargs="+", default=list(DEFAULT_POLICIES),
        metavar="NAME[:k=v,...]",
        help="registry policy specs to race (default: all built-ins)",
    )
    ap.add_argument(
        "--replay-backend", choices=["jax", "pallas"], default="jax",
        help="chunk-replay backend for the fused engine",
    )
    args = ap.parse_args()
    main(
        num_requests=args.num_requests,
        iterations=args.iterations,
        read_fraction=args.read_fraction,
        policy_specs=tuple(args.policies),
        replay_backend=args.replay_backend,
    )
