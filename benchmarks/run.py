"""Benchmark entry point: ``python -m benchmarks.run [names...]``.

One module per paper table/figure + the beyond-paper integration benches:

  fig2_uniform      paper Figure 2 (uniform access, Local/Remote/Optimized)
  fig3_skewed       paper Figure 3 (zipfian 90/10) + affinity sweep
  daemon_sweep      Algorithm 3 analysis throughput (pure JAX vs Pallas)
  capacity_sweep    hit-rate vs per-node replica budget (beyond paper)
  policy_matrix     registered-policy head-to-head on the wan5 geo cluster
  tail_latency      P50/P99/P99.9 per policy x topology (in-scan telemetry)
  moe_placement     hot-expert replica cache on the reduced MoE
  hot_embedding     hot-row cache hit rates + HBM bytes saved
  serving_sessions  session-cache migration vs static placement
  roofline          aggregate the dry-run sweep into the §Roofline table

``--policy NAME[:k=v,...]`` selects a placement policy from the
``repro.core.policy`` registry (e.g. ``--policy redynis:h=0.05`` or
``--policy topk:k=50``) and is forwarded to every selected bench whose
``main`` accepts a ``policy`` kwarg (daemon_sweep, capacity_sweep,
policy_matrix, tail_latency). ``--replay-backend jax|pallas`` selects the
fused engine's chunk-replay backend the same way (fig2_uniform,
fig3_skewed, policy_matrix, tail_latency, engine_throughput).

Every line of output in ``RESULT,name,value,unit,k=v`` form is machine
collectable; EXPERIMENTS.md quotes them directly. The figure / sweep
benches additionally persist ``BENCH_<name>.json`` (throughput, hit-rate,
wall-time) — the perf-trajectory files CI uploads as artifacts; set
``$BENCH_DIR`` to redirect them.
"""

from __future__ import annotations

import inspect
import sys
import time

MODULES = [
    "fig2_uniform",
    "fig3_skewed",
    "daemon_sweep",
    "capacity_sweep",
    "policy_matrix",
    "tail_latency",
    "engine_throughput",
    "moe_placement",
    "hot_embedding",
    "serving_sessions",
    "roofline",
]

# CPU-friendly iteration counts for the figure benches (full fidelity is
# iterations=5, num_requests=100_000 — the EXPERIMENTS.md numbers).
FAST_KWARGS = {
    "fig2_uniform": {"iterations": 3, "num_requests": 50_000},
    "fig3_skewed": {"iterations": 3, "num_requests": 50_000},
    "capacity_sweep": {"num_requests": 20_000},
    "policy_matrix": {"num_requests": 10_000},
    "tail_latency": {"num_requests": 10_000, "iterations": 2},
    "engine_throughput": {"num_requests": 50_000, "repeats": 3},
}


def main() -> None:
    args = sys.argv[1:]
    policy = None
    if "--policy" in args:
        from repro.core.policy import parse_policy

        at = args.index("--policy")
        if at + 1 >= len(args):
            raise SystemExit("--policy requires a spec, e.g. redynis:h=0.2")
        policy = parse_policy(args[at + 1])
        del args[at : at + 2]
    replay_backend = None
    if "--replay-backend" in args:
        at = args.index("--replay-backend")
        if at + 1 >= len(args):
            raise SystemExit("--replay-backend requires jax or pallas")
        replay_backend = args[at + 1]
        del args[at : at + 2]
    full = "--full" in args
    names = [n for n in args if not n.startswith("--")]
    if not names:
        names = MODULES
    t0 = time.time()
    for name in names:
        mod = __import__(f"benchmarks.{name}", fromlist=["main"])
        kwargs = {} if full else dict(FAST_KWARGS.get(name, {}))
        sig = inspect.signature(mod.main).parameters
        if policy is not None and "policy" in sig:
            kwargs["policy"] = policy
        if replay_backend is not None and "replay_backend" in sig:
            kwargs["replay_backend"] = replay_backend
        mod.main(**kwargs)
    print(f"\nall benchmarks done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
