"""Placement-daemon analysis throughput — the paper's "constant time per
key" claim, measured: keys/second for Algorithm 3 sweeps at growing key
counts, pure-JAX vs the Pallas ownership_sweep kernel (interpret mode on
CPU, so the Pallas numbers here validate semantics; MXU-free VPU tiling is
what the kernel buys on real TPU)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import banner, emit, time_fn
from repro.core.metadata import create_store
from repro.core.placement import masked_step, sweep
from repro.kernels.ownership_sweep.ops import ownership_sweep


def main(sizes=(1_000, 10_000, 100_000, 1_000_000), n_nodes: int = 16) -> None:
    banner("daemon_sweep: Algorithm 3 analysis throughput")
    for k in sizes:
        ks = jax.random.split(jax.random.PRNGKey(k % 2**31), 3)
        counts = jax.random.randint(ks[0], (k, n_nodes), 0, 100).astype(jnp.int32)
        hosts = jax.random.uniform(ks[1], (k, n_nodes)) > 0.8
        store = create_store(k, n_nodes)._replace(
            access_counts=counts,
            hosts=hosts,
            live=jnp.ones((k,), bool),
        )
        h = 1.0 / n_nodes

        t_jax = time_fn(
            lambda: jax.block_until_ready(sweep(store, h, 0)[0].owners), iters=5
        )
        emit("daemon_sweep_purejax", round(k / t_jax / 1e6, 3), "Mkeys/s", keys=k)

        # Scan-compatible (due-masked) step: the form the fused simulation
        # engine runs inside lax.scan — masking must not cost throughput.
        masked = jax.jit(lambda s, due: masked_step(s, 0, due, h=h)[2].hosts)
        t_masked = time_fn(
            lambda: jax.block_until_ready(masked(store, jnp.bool_(True))), iters=5
        )
        emit(
            "daemon_sweep_masked_step",
            round(k / t_masked / 1e6, 3),
            "Mkeys/s",
            keys=k,
        )

        fcounts = counts.astype(jnp.float32)
        live = jnp.ones((k,), bool)
        last = jnp.zeros((k,), jnp.int32)
        t_pl = time_fn(
            lambda: jax.block_until_ready(
                ownership_sweep(fcounts, hosts, live, last, 0, h=h)[0]
            ),
            iters=3,
        )
        emit(
            "daemon_sweep_pallas_interp",
            round(k / t_pl / 1e6, 3),
            "Mkeys/s",
            keys=k,
            note="interpret-mode-on-CPU",
        )


if __name__ == "__main__":
    main()
