"""Placement-daemon analysis throughput — the paper's "constant time per
key" claim, measured: keys/second for Algorithm 3 sweeps at growing key
counts, through the scored pipeline's pluggable backends (``--backend
jax|pallas|both``; Pallas runs in interpret mode on CPU, so its numbers
here validate semantics — MXU-free VPU tiling is what the kernel buys on
real TPU). Also times the scan-compatible masked step and the capacity
projection stage, and persists ``BENCH_daemon_sweep.json``."""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.common import banner, emit, time_fn, write_bench_json
from repro.core.metadata import create_store
from repro.core.placement import masked_step, sweep
from repro.core.policy import (
    PolicyContext,
    describe_policy,
    parse_policy,
    policy_masked_step,
    split_policy,
)


def main(
    sizes=(1_000, 10_000, 100_000, 1_000_000),
    n_nodes: int = 16,
    backend: str = "both",
    policy=None,
) -> list[dict]:
    banner(f"daemon_sweep: Algorithm 3 analysis throughput (backend={backend})")
    backends = ("jax", "pallas") if backend == "both" else (backend,)
    if policy is not None:
        policy = policy.resolve(n_nodes)
        policy.validate(n_nodes)
    rows: list[dict] = []
    t_start = time.perf_counter()
    for k in sizes:
        ks = jax.random.split(jax.random.PRNGKey(k % 2**31), 3)
        counts = jax.random.randint(ks[0], (k, n_nodes), 0, 100).astype(jnp.int32)
        hosts = jax.random.uniform(ks[1], (k, n_nodes)) > 0.8
        store = create_store(k, n_nodes)._replace(
            access_counts=counts,
            hosts=hosts,
            live=jnp.ones((k,), bool),
        )
        h = 1.0 / n_nodes
        obj = jax.random.uniform(ks[2], (k,), minval=64.0, maxval=4096.0)
        cap = jnp.full((n_nodes,), 0.3 * float(jnp.sum(obj)) / n_nodes)

        for bk in backends:
            t_sweep = time_fn(
                lambda: sweep(store, h, 0, backend=bk)[0].owners, iters=3
            )
            emit(
                f"daemon_sweep_{bk}",
                round(k / t_sweep / 1e6, 3),
                "Mkeys/s",
                keys=k,
                note="interpret-mode-on-CPU" if bk == "pallas" else "",
            )
            rows.append(
                {"name": f"sweep_{bk}", "keys": k, "mkeys_per_s": k / t_sweep / 1e6}
            )

            # Capacity-projected sweep: the full scored pipeline with a
            # finite per-node byte budget (projection = 3 sorts + cumsum).
            t_capped = time_fn(
                lambda: sweep(
                    store, h, 0, object_bytes=obj, capacity_bytes=cap,
                    backend=bk,
                )[0].owners,
                iters=3,
            )
            emit(
                f"daemon_sweep_{bk}_capacity",
                round(k / t_capped / 1e6, 3),
                "Mkeys/s",
                keys=k,
            )
            rows.append(
                {
                    "name": f"sweep_{bk}_capacity",
                    "keys": k,
                    "mkeys_per_s": k / t_capped / 1e6,
                }
            )

            # Scan-compatible (due-masked) step: the form the fused
            # simulation engine runs inside lax.scan — masking must not
            # cost throughput (measured per backend, like the sweep).
            masked = jax.jit(
                lambda s, due: masked_step(s, 0, due, h=h, backend=bk)[1].hosts
            )
            t_masked = time_fn(
                lambda: masked(store, jnp.bool_(True)), iters=5
            )
            emit(
                f"daemon_sweep_masked_step_{bk}",
                round(k / t_masked / 1e6, 3),
                "Mkeys/s",
                keys=k,
            )
            rows.append(
                {
                    "name": f"masked_step_{bk}",
                    "keys": k,
                    "mkeys_per_s": k / t_masked / 1e6,
                }
            )

        if policy is not None:
            # Generic policy engine: decide + shared expiry/capacity stages
            # through `core.policy.policy_masked_step` (the form the fused
            # simulator runs for any registered policy).
            label = describe_policy(policy)
            static, params = split_policy(policy)
            rtt = jnp.where(
                jnp.eye(n_nodes, dtype=bool), 0.0,
                jnp.full((n_nodes, n_nodes), 100.0),
            )
            ctx = PolicyContext(
                rtt=rtt, object_bytes=obj, capacity_bytes=cap, params=params
            )
            pstate = static.init(store, ctx)
            stepped = jax.jit(
                lambda s, ps, due: policy_masked_step(static, ps, s, 0, due, ctx)[
                    2
                ].hosts
            )
            t_policy = time_fn(
                lambda: stepped(store, pstate, jnp.bool_(True)), iters=5
            )
            emit(
                "daemon_sweep_policy",
                round(k / t_policy / 1e6, 3),
                "Mkeys/s",
                keys=k,
                policy=label,
            )
            rows.append(
                {
                    "name": "policy_masked_step",
                    "policy": label,
                    "keys": k,
                    "mkeys_per_s": k / t_policy / 1e6,
                }
            )

    write_bench_json(
        "daemon_sweep",
        {"rows": rows, "wall_time_s": time.perf_counter() - t_start},
        backend=backend,
        n_nodes=n_nodes,
    )
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--backend", choices=("jax", "pallas", "both"), default="both",
        help="sweep backend(s) to measure",
    )
    ap.add_argument(
        "--sizes", type=int, nargs="+",
        default=[1_000, 10_000, 100_000, 1_000_000],
    )
    ap.add_argument("--nodes", type=int, default=16)
    ap.add_argument(
        "--policy", type=parse_policy, default=None, metavar="NAME[:k=v,...]",
        help="additionally time core.policy.policy_masked_step for this "
        "registry spec (e.g. redynis:h=0.05, topk:k=500, decaylfu)",
    )
    args = ap.parse_args()
    main(
        sizes=tuple(args.sizes), n_nodes=args.nodes, backend=args.backend,
        policy=args.policy,
    )
