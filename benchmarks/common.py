"""Shared benchmark utilities: timing, CSV/report emission, and persisted
``BENCH_<name>.json`` result files (the perf trajectory CI archives)."""

from __future__ import annotations

import json
import os
import time
from typing import Callable

import jax
import numpy as np

__all__ = [
    "time_fn",
    "emit",
    "banner",
    "git_commit",
    "write_bench_json",
    "json_rows",
    "dedupe_policies",
    "BENCH_SCHEMA_VERSION",
    "WAN5_WORKLOAD_KWARGS",
]

# Version stamp for the BENCH_*.json payload shape; bench_trend.py uses it
# (with the git commit) to align and order trajectory points. Bump when a
# top-level payload key changes meaning.
BENCH_SCHEMA_VERSION = 1

# The wan5 geo-traffic preset the policy benchmarks share (policy_matrix,
# tail_latency): skewed sources concentrated in two hot regions. Kept here
# so the cross-benchmark numbers stay comparable; run_experiment builds its
# own WorkloadConfig per read fraction from these kwargs.
WAN5_WORKLOAD_KWARGS = dict(
    num_nodes=5,
    region_weights=(0.35, 0.25, 0.20, 0.12, 0.08),
    affinity=0.8,
)


def dedupe_policies(candidates, num_nodes: int) -> list:
    """Drop policies whose *resolved* label (at this cluster size) repeats —
    a forwarded ``--policy`` that coincides with a default entry must not
    trip ``run_experiment``'s duplicate-label check."""
    from repro.kvsim import describe_policy

    seen, out = set(), []
    for p in candidates:
        label = describe_policy(p.resolve(num_nodes))
        if label not in seen:
            seen.add(label)
            out.append(p)
    return out


def json_rows(table: dict) -> dict:
    """``run_experiment`` rows minus the non-JSON leaves (the per-seed
    ``SimResult`` list, the merged ``SimTrace``) — the shape the
    ``BENCH_*.json`` artifacts persist."""
    skip = ("results", "trace")
    return {
        label: [{k: v for k, v in row.items() if k not in skip} for row in rows]
        for label, rows in table.items()
    }


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-time (seconds) of ``fn(*args)`` with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, value, unit: str = "", **extra) -> None:
    """One CSV-ish result line: ``name,value,unit,k=v,...``"""
    tail = "".join(f",{k}={v}" for k, v in extra.items())
    print(f"RESULT,{name},{value},{unit}{tail}", flush=True)


def banner(title: str) -> None:
    print(f"\n=== {title} ===", flush=True)


def git_commit() -> str | None:
    """The repo's HEAD commit hash, or ``None`` outside a git checkout (the
    bench files must stay writable from exported tarballs)."""
    import subprocess

    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True,
            text=True,
            timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        )
    except (OSError, subprocess.SubprocessError):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def write_bench_json(
    name: str, metrics: dict, quantiles: dict | None = None, **meta
) -> str:
    """Persist one benchmark's results as ``BENCH_<name>.json``.

    metrics: the measured values (throughput, hit-rate, wall-time, ... —
        anything JSON-serialisable; numpy scalars are coerced via float).
    quantiles: optional top-level tail-latency block — per-entry
        P50/P90/P95/P99/P99.9 dicts in ms (``SimTrace.tail_summary()``
        shape), keyed however the benchmark groups them (policy label,
        topology, ...). Kept out of ``metrics`` so trajectory scrapers can
        diff the distribution summaries without parsing benchmark-specific
        row schemas.
    meta: run parameters worth keeping next to the numbers (backend,
        num_requests, ...).
    Output directory: ``$BENCH_DIR`` if set, else the current directory.
    Returns the written path (also printed as a ``WROTE,`` line so log
    scrapers can find the artifacts).
    """
    out_dir = os.environ.get("BENCH_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    payload = {
        "bench": name,
        "schema_version": BENCH_SCHEMA_VERSION,
        "git_commit": git_commit(),
        "unix_time": time.time(),
        **meta,
        "metrics": metrics,
    }
    if quantiles is not None:
        payload["quantiles"] = quantiles
    with open(path, "w") as fh:
        json.dump(payload, fh, indent=2, sort_keys=True, default=float)
        fh.write("\n")
    print(f"WROTE,{path}", flush=True)
    return path
