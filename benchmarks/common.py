"""Shared benchmark utilities: timing, CSV/report emission."""

from __future__ import annotations

import time
from typing import Callable

import jax
import numpy as np

__all__ = ["time_fn", "emit", "banner"]


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall-time (seconds) of ``fn(*args)`` with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def emit(name: str, value, unit: str = "", **extra) -> None:
    """One CSV-ish result line: ``name,value,unit,k=v,...``"""
    tail = "".join(f",{k}={v}" for k, v in extra.items())
    print(f"RESULT,{name},{value},{unit}{tail}", flush=True)


def banner(title: str) -> None:
    print(f"\n=== {title} ===", flush=True)
