"""Beyond-paper: session placement for serving (Redynis integration #3).

The paper's experiment, serving flavour: zipfian session popularity with
geo-affinity, comparing static placement (sessions pinned where they were
created — the paper's REMOTE analogue) vs the Redynis router migrating
caches toward request sources. Reports local-hit rate and migrated bytes.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import banner, emit
from repro.serving import SessionRouter


def run(migrate: bool, requests: int = 3000, pods: int = 4, sessions: int = 64) -> dict:
    router = SessionRouter(
        num_pods=pods,
        max_sessions=sessions * 2,
        sweep_period=50 if migrate else 10**9,  # daemon off = static placement
        session_bytes=32e6,  # ~a 32k-cache session at 2B widths
    )
    rng = np.random.default_rng(0)
    ranks = np.arange(1, sessions + 1) ** -1.2
    pop = ranks / ranks.sum()
    home = {i: int(rng.integers(0, pods)) for i in range(sessions)}
    # all sessions first created on pod 0 (a deploy/failover artefact)
    for i in range(sessions):
        router.route(f"s{i}", 0)
    for _ in range(requests):
        i = int(rng.choice(sessions, p=pop))
        router.route(f"s{i}", home[i])
        router.tick()
    return {
        "hit_rate": router.hit_rate(),
        "migrations": router.stats["migrations"],
        "migrated_GB": router.stats["migrated_bytes"] / 1e9,
        "elections": router.stats["elections"],
    }


def main() -> None:
    banner("serving_sessions: static vs Redynis-migrated session placement")
    static = run(migrate=False)
    dyn = run(migrate=True)
    emit("serving_sessions", round(static["hit_rate"], 4), "hit_rate", mode="static")
    emit(
        "serving_sessions",
        round(dyn["hit_rate"], 4),
        "hit_rate",
        mode="redynis",
        migrations=dyn["migrations"],
        migrated_GB=round(dyn["migrated_GB"], 2),
    )
    emit(
        "serving_sessions_gain",
        round(dyn["hit_rate"] / max(static["hit_rate"], 1e-9), 2),
        "x_hit_rate",
    )


if __name__ == "__main__":
    main()
