"""Tail-latency matrix: P50/P99/P99.9 per policy × topology.

The experiment the telemetry subsystem exists for: means hide exactly the
tail behaviour geo-distributed round-trips inflate, so this sweep races the
registered policies across the flat 3-node testbed, the 5-region WAN, and
the heterogeneous WAN-with-edge-node topology, reading interpolated
quantiles off the in-scan latency histograms (one fused program per policy
family — the trace is never re-walked). Emits per-(topology, policy) rows
and persists ``BENCH_tail_latency.json`` with the schema's top-level
``quantiles`` block.

The contention-on grid (``ServiceConfig``) re-races the size/cost policies
on wan5 with the M/M/1 queueing model enabled: lognormal object sizes load
the size-proportional service demand, and capacity_factor sets the load
level. Region weights are balanced there so the tail isolates size-driven
queueing (cost-per-KiB admission strands hot large objects on one owner
node) rather than regional traffic imbalance.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import (
    WAN5_WORKLOAD_KWARGS,
    banner,
    dedupe_policies,
    emit,
    write_bench_json,
)
from repro.kvsim import (
    ClusterConfig,
    ServiceConfig,
    TelemetryConfig,
    parse_policy,
    run_experiment,
    wan5_cluster,
    wan5_edge_cluster,
)

DEFAULT_POLICIES = (
    "remote",
    "replicated",
    "redynis",
    "redynis:h=0.05,decay=0.9",
    "topk:k=100",
    "costgreedy",
    "decaylfu:alpha=0.5",
)

# Contention-on grid: the size-aware sharding head-to-head. Light and
# moderate load (capacity_factor 2.0 / 1.0) keep the load factors below the
# stability clamp so the queueing mechanism — not the rho_max ceiling —
# separates the policies.
CONTENTION_POLICIES = (
    "sizeaware",
    "sizeaware:large_fanout=3",
    "costgreedy",
    "redynis",
)
CONTENTION_CAPACITY_FACTORS = (2.0, 1.0)
CONTENTION_SERVE_BYTES_PER_MS = 128.0
CONTENTION_SIGMA = 1.0
CONTENTION_WORKLOAD_KWARGS = dict(
    num_nodes=5,
    region_weights=(0.2, 0.2, 0.2, 0.2, 0.2),
    affinity=0.8,
)

# topology name -> (cluster, per-topology workload kwargs)
TOPOLOGIES = {
    "flat": (ClusterConfig(), dict(num_nodes=3, affinity=0.8)),
    "wan5": (wan5_cluster(), dict(WAN5_WORKLOAD_KWARGS)),
    "wan5_edge": (
        wan5_edge_cluster(edge_capacity_bytes=64 * 1024.0),
        dict(WAN5_WORKLOAD_KWARGS),
    ),
}


def main(
    num_requests: int = 30_000,
    iterations: int = 3,
    read_fraction: float = 0.9,
    policy_specs=DEFAULT_POLICIES,
    topologies=tuple(TOPOLOGIES),
    num_bins: int = 128,
    policy=None,
    replay_backend: str = "jax",
    contention: bool = True,
    contention_capacity_factors=CONTENTION_CAPACITY_FACTORS,
) -> dict:
    banner("tail_latency: P50/P99/P99.9 per policy x topology")
    telemetry = TelemetryConfig(num_bins=num_bins)
    rows, quantiles, out = [], {}, {}
    t_start = time.perf_counter()
    for topo in topologies:
        cluster, wl_kwargs = TOPOLOGIES[topo]
        candidates = [parse_policy(s) for s in policy_specs]
        if policy is not None:
            candidates.append(policy)
        policies = dedupe_policies(candidates, cluster.num_nodes)
        res = run_experiment(
            read_fractions=(read_fraction,),
            skewed=True,
            iterations=iterations,
            num_requests=num_requests,
            cluster=cluster,
            policies=policies,
            telemetry=telemetry,
            replay_backend=replay_backend,
            **wl_kwargs,
        )
        out[topo] = res
        for label, policy_rows in res["policies"].items():
            row = policy_rows[0]
            q = row["quantiles"]
            # The reported P99 is the mean of per-seed interpolated P99s —
            # the estimator row["p99_ci99"] is the CI band *of* — not the
            # pooled-histogram quantile (which lives in the quantiles
            # block); pairing the band with a different estimator could
            # print a point outside its own interval.
            p99 = row["p99_latency_ms"]
            emit(
                "tail_latency",
                round(p99, 2),
                "p99_ms",
                topology=topo,
                policy=label,
                p50=round(q["p50"], 2),
                p999=round(q["p999"], 2),
                p99_ci99=round(row["p99_ci99"], 2),
                hit_rate=round(row["hit_rate"], 4),
            )
            quantiles[f"{topo}/{label}"] = q
            rows.append(
                {
                    "topology": topo,
                    "policy": label,
                    "read_fraction": row["read_fraction"],
                    "hit_rate": row["hit_rate"],
                    "mean_latency_ms": row["mean_latency_ms"],
                    "throughput_ops_s": row["throughput"],
                    "p50_ms": q["p50"],
                    "p99_ms": p99,
                    "p999_ms": q["p999"],
                    "p99_ci99": row["p99_ci99"],
                    "convergence_chunk": row["trace"].convergence_chunk(),
                    # Per-seed average so the oscillation column is
                    # comparable across runs with different --iterations.
                    "post_convergence_moves_per_seed": row[
                        "trace"
                    ].post_convergence_moves() / iterations,
                }
            )
    contention_rows = []
    if contention:
        banner("tail_latency: contention-on grid (ServiceConfig, wan5)")
        for cf in contention_capacity_factors:
            svc = ServiceConfig(
                serve_bytes_per_ms=CONTENTION_SERVE_BYTES_PER_MS,
                capacity_factor=cf,
            )
            cluster = wan5_cluster()._replace(service=svc)
            policies = dedupe_policies(
                [parse_policy(s) for s in CONTENTION_POLICIES],
                cluster.num_nodes,
            )
            res = run_experiment(
                read_fractions=(1.0,),  # read-path contention, no broadcasts
                skewed=True,
                iterations=iterations,
                num_requests=num_requests,
                cluster=cluster,
                policies=policies,
                telemetry=telemetry,
                replay_backend=replay_backend,
                object_bytes_sigma=CONTENTION_SIGMA,
                **CONTENTION_WORKLOAD_KWARGS,
            )
            out[f"contention/cf{cf}"] = res
            for label, policy_rows in res["policies"].items():
                row = policy_rows[0]
                q = row["quantiles"]
                p99 = row["p99_latency_ms"]
                peak_rho = float(row["trace"].load_factor.max())
                emit(
                    "tail_latency_contention",
                    round(p99, 2),
                    "p99_ms",
                    capacity_factor=cf,
                    policy=label,
                    p50=round(q["p50"], 2),
                    p999=round(q["p999"], 2),
                    p99_ci99=round(row["p99_ci99"], 2),
                    hit_rate=round(row["hit_rate"], 4),
                    peak_load_factor=round(peak_rho, 4),
                )
                quantiles[f"contention/cf{cf}/{label}"] = q
                contention_rows.append(
                    {
                        "capacity_factor": cf,
                        "policy": label,
                        "hit_rate": row["hit_rate"],
                        "mean_latency_ms": row["mean_latency_ms"],
                        "p50_ms": q["p50"],
                        "p99_ms": p99,
                        "p999_ms": q["p999"],
                        "p99_ci99": row["p99_ci99"],
                        "peak_load_factor": peak_rho,
                    }
                )

    write_bench_json(
        "tail_latency",
        {
            "rows": rows,
            "contention": {
                "rows": contention_rows,
                "capacity_factors": list(contention_capacity_factors),
                "serve_bytes_per_ms": CONTENTION_SERVE_BYTES_PER_MS,
                "object_bytes_sigma": CONTENTION_SIGMA,
                "policies": list(CONTENTION_POLICIES),
            },
            "wall_time_s": time.perf_counter() - t_start,
        },
        quantiles=quantiles,
        num_requests=num_requests,
        iterations=iterations,
        read_fraction=read_fraction,
        num_bins=num_bins,
        topologies=list(topologies),
        replay_backend=replay_backend,
    )
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num-requests", type=int, default=30_000)
    ap.add_argument("--iterations", type=int, default=3)
    ap.add_argument("--read-fraction", type=float, default=0.9)
    ap.add_argument("--num-bins", type=int, default=128)
    ap.add_argument(
        "--topologies", nargs="+", default=list(TOPOLOGIES),
        choices=list(TOPOLOGIES),
    )
    ap.add_argument(
        "--policies", nargs="+", default=list(DEFAULT_POLICIES),
        metavar="NAME[:k=v,...]",
        help="registry policy specs to race (default: the matrix built-ins)",
    )
    ap.add_argument(
        "--replay-backend", choices=["jax", "pallas"], default="jax",
        help="chunk-replay backend for the fused engine",
    )
    ap.add_argument(
        "--no-contention", action="store_true",
        help="skip the ServiceConfig contention-on grid",
    )
    ap.add_argument(
        "--contention-capacity-factors", nargs="+", type=float,
        default=list(CONTENTION_CAPACITY_FACTORS), metavar="CF",
        help="load levels for the contention grid (capacity_factor values)",
    )
    args = ap.parse_args()
    main(
        num_requests=args.num_requests,
        iterations=args.iterations,
        read_fraction=args.read_fraction,
        policy_specs=tuple(args.policies),
        topologies=tuple(args.topologies),
        num_bins=args.num_bins,
        replay_backend=args.replay_backend,
        contention=not args.no_contention,
        contention_capacity_factors=tuple(args.contention_capacity_factors),
    )
