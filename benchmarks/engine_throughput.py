"""Engine-throughput benchmark: simulator requests/sec across engines.

The scan engine is the product's hot loop — every Figure 2/3 point, policy
grid, capacity sweep, and tail-latency table replays millions of requests
through the per-chunk request path. This benchmark plants the
``BENCH_engine_throughput.json`` trendline later PRs defend:

  * **grid rows** — warm-run ``run_scenario`` throughput in simulated
    requests/sec across engine × chunk-replay backend × daemon_interval ×
    num_keys (× policy, × telemetry on/off).
  * **speedup rows** — the same configs replayed through a faithful
    in-file replica of the PRE-fusion engine (``_legacy_simulate``: four
    separate latency passes, per-chunk O(K·N) occupancy for every policy,
    the telemetry histogram as a separate dispatch), so the fusion win is
    measurable from a single post-PR checkout.
  * **acceptance row** (``--acceptance``) — the ISSUE-5 criterion: warm
    ``run_scenario`` with telemetry on, wan5 topology, skewed traffic,
    1M requests, at the paper's access density (100 accesses/key ⇒
    num_keys = num_requests/100) must beat the pre-fusion engine ≥ 2x.

Methodology: sim-requests/sec = num_requests / wall-clock of one warm
``run_scenario`` call (compile + cache warmup excluded; median of
``--repeats`` (default 5) timed calls is the recorded trendline number).
Speedup ratios divide the per-side *minima* instead — contention noise on
shared runners is strictly additive, so min is the robust estimator of
true program cost (see ``_measure``). Timed work includes trace
generation and host-side result/trace materialisation, exactly what
every driver pays.

``--baseline PATH`` (default: the checked-in
``benchmarks/baselines/BENCH_engine_throughput.json``) warns —
``WARNING,engine_throughput_regression,...`` lines — when any matching grid
row regresses more than 20%. Absolute requests/sec warnings never fail the
job (wall-clock noise across runners makes that gate flaky), but
``--fail-on-regression`` promotes the *speedup-ratio* warnings to a hard
nonzero exit: fused and legacy engines run on the same box, so the
``speedup_vs_legacy`` ratio is machine-independent and a >20% drop there is
a genuine code-path regression, not runner noise.

Note on ``--backends pallas`` off-TPU: the Mosaic kernel runs in interpret
mode on CPU (a correctness/compile-path row, orders of magnitude slower
than compiled code); perf rows for the pallas backend are only meaningful
on a real TPU.
"""

from __future__ import annotations

import argparse
import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (
    WAN5_WORKLOAD_KWARGS,
    banner,
    emit,
    write_bench_json,
)
from repro.core.metadata import record_accesses
from repro.core.policy import (
    PolicyContext,
    parse_policy,
    policy_masked_step,
    split_policy,
)
from repro.kvsim import (
    SimResult,
    TelemetryConfig,
    WorkloadConfig,
    run_scenario,
    wan5_cluster,
)
from repro.kvsim.simulate import (
    _chunk_latency,
    _initial_hosts,
    _node_occupancy,
    _seed_store,
)
from repro.kvsim.telemetry import (
    TelemetryLeaves,
    build_trace,
    chunk_histogram,
    normalize_telemetry,
)
from repro.kvsim.workload import generate_trace

DEFAULT_BASELINE = os.path.join(
    os.path.dirname(__file__), "baselines", "BENCH_engine_throughput.json"
)


# ---------------------------------------------------------------------------
# The pre-fusion engine, preserved verbatim as the speedup baseline.
# ---------------------------------------------------------------------------


def _legacy_simulate(
    keys, nodes, is_read, natural, object_bytes, params, *,
    cluster, policy, daemon_interval, telemetry=None,
):
    """The PRE-ISSUE-5 scan body: separate read/write/hit/busy passes over
    [B, N] intermediates, the O(K·N) occupancy sample paid per chunk for
    EVERY policy (including static maps that never change), and the
    telemetry histogram folded by a separate dispatch after the latency
    pass. Kept verbatim so ``speedup_vs_legacy`` measures exactly what the
    fusion + hoist bought."""
    r = keys.shape[0]
    num_keys = natural.shape[0]
    n = cluster.num_nodes
    rtt = cluster.rtt_matrix()
    obj = jnp.asarray(object_bytes, jnp.float32)
    capacity = (
        cluster.capacity_vector() if cluster.has_finite_capacity else None
    )
    ctx = PolicyContext(
        rtt=rtt, object_bytes=obj, capacity_bytes=capacity, params=params
    )
    num_chunks = -(-r // daemon_interval)
    pad = num_chunks * daemon_interval - r

    def chunked(x):
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad,), x.dtype)])
        return x.reshape(num_chunks, daemon_interval)

    xs = (
        jnp.arange(num_chunks, dtype=jnp.int32),
        chunked(keys), chunked(nodes), chunked(is_read),
        (jnp.arange(num_chunks * daemon_interval) < r).reshape(
            num_chunks, daemon_interval
        ),
    )
    store = _seed_store(
        _initial_hosts(natural, num_keys, n, policy.initial_placement),
        num_keys, n,
    )
    pstate = policy.init(store, ctx)
    zero = jnp.float32(0.0)
    init = (
        store, pstate, jnp.zeros((n,), jnp.float32), zero, zero, zero, zero,
        zero, zero, zero, _node_occupancy(store.hosts, obj),
    )

    def body(carry, x):
        (store, pstate, busy, lat_sum, hits, reads, repl, drop, evic,
         cap_evic, peak) = carry
        c, ck, cn, cr, cv = x
        lat, read_hits = _chunk_latency(
            store.hosts, ck, cn, cr, rtt, cluster, policy.read_mode
        )
        lat = jnp.where(cv, lat, 0.0)
        chunk_lat = jnp.sum(lat)
        chunk_hits = jnp.sum((read_hits & cv).astype(jnp.float32))
        chunk_reads = jnp.sum((cr & cv).astype(jnp.float32))
        busy = busy.at[cn].add(lat)
        lat_sum = lat_sum + chunk_lat
        hits = hits + chunk_hits
        reads = reads + chunk_reads
        occ = _node_occupancy(store.hosts, obj)  # paid per chunk, always
        peak = jnp.maximum(peak, occ)
        zero = jnp.float32(0.0)
        chunk_moves = (zero, zero, zero, zero)
        if policy.is_active:
            store = record_accesses(store, ck, cn, now=c, valid=cv)
            stats, pstate, store = policy_masked_step(
                policy, pstate, store, c, (c % policy.period) == 0, ctx
            )
            repl, drop = repl + stats.adds, drop + stats.drops
            evic = evic + stats.expiry_evictions
            cap_evic = cap_evic + stats.capacity_evictions
            chunk_moves = (
                stats.adds, stats.drops, stats.expiry_evictions,
                stats.capacity_evictions,
            )
        if telemetry is None:
            ys = None
        else:
            w = cv.astype(jnp.float32)
            ys = TelemetryLeaves(
                hist=chunk_histogram(
                    lat, cn * 2 + cr.astype(jnp.int32), w, telemetry, n
                ),
                hits=chunk_hits, reads=chunk_reads, lat_sum=chunk_lat,
                count=jnp.sum(w), adds=chunk_moves[0], drops=chunk_moves[1],
                expiry_evictions=chunk_moves[2],
                capacity_evictions=chunk_moves[3], occupancy=occ,
            )
        return (
            store, pstate, busy, lat_sum, hits, reads, repl, drop, evic,
            cap_evic, peak,
        ), ys

    (_, _, busy, lat_sum, hits, reads, repl, drop, evic, cap_evic, peak), ys = (
        jax.lax.scan(body, init, xs)
    )
    makespan_ms = jnp.max(busy)
    return (
        r / (makespan_ms / 1000.0), hits / jnp.maximum(reads, 1.0),
        lat_sum / r, busy, repl, drop, evic, cap_evic, peak,
    ), ys


_legacy_simulate_jit = partial(
    jax.jit, static_argnames=("cluster", "policy", "daemon_interval", "telemetry")
)(_legacy_simulate)


def legacy_run_scenario(workload, cluster, policy, seed=0,
                        daemon_interval=1000, telemetry=None):
    """``run_scenario``-equivalent driver over the pre-fusion engine (same
    host-side work: trace generation, result + trace materialisation)."""
    policy = policy.resolve(workload.num_nodes)
    policy.validate(workload.num_nodes)
    static, params = split_policy(policy)
    telemetry = normalize_telemetry(telemetry)
    trace = generate_trace(workload, seed)
    leaves, telem = _legacy_simulate_jit(
        trace.keys, trace.nodes, trace.is_read, trace.natural_node,
        trace.object_bytes, params, cluster=cluster, policy=static,
        daemon_interval=daemon_interval, telemetry=telemetry,
    )
    tput, hit, mean_lat, busy, repl, drop, evic, cap_evic, peak = leaves
    result = SimResult(
        throughput_ops_s=float(tput), hit_rate=float(hit),
        mean_latency_ms=float(mean_lat),
        node_busy_ms=np.asarray(busy, dtype=np.float64),
        replication_moves=float(repl), deletion_moves=float(drop),
        evictions=float(evic), capacity_evictions=float(cap_evic),
        peak_occupancy_bytes=np.asarray(peak, dtype=np.float64),
    )
    if telemetry is None:
        return result
    return result, build_trace(telem, telemetry)


# ---------------------------------------------------------------------------
# Measurement grid.
# ---------------------------------------------------------------------------


def _wan5_workload(num_requests, num_keys):
    return WorkloadConfig(
        num_requests=num_requests, num_keys=num_keys, skewed=True,
        read_fraction=0.9, **WAN5_WORKLOAD_KWARGS,
    )


def _measure(engine, policy, workload, cluster, daemon_interval, telemetry,
             replay_backend, repeats):
    """Warm wall-times of one full scenario run: ``(median_s, min_s)``.

    The JSON trendline records the median (the BENCH methodology); speedup
    ratios use the min of each side — on shared runners, contention noise
    is strictly additive, so the minimum is the robust estimator of the
    actual program cost and the ratio of minima is stable where a ratio of
    medians swings with whatever else the box is doing.
    """
    if engine == "legacy":
        fn = lambda: legacy_run_scenario(
            workload, cluster, policy, seed=0,
            daemon_interval=daemon_interval, telemetry=telemetry,
        )
    else:
        fn = lambda: run_scenario(
            workload, cluster, policy, seed=0,
            daemon_interval=daemon_interval, telemetry=telemetry,
            replay_backend=replay_backend,
        )
    for _ in range(2):  # compile + cache warmup
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), float(np.min(times))


def _row_key(row):
    return (
        row["engine"], row["policy"], row["replay_backend"],
        row["daemon_interval"], row["num_keys"], row["telemetry"],
        row["num_requests"],
    )


def _speedup_key(row):
    return (
        row["policy"], row["daemon_interval"], row["num_keys"],
        row["telemetry"], row["num_requests"],
    )


def check_regression(rows, baseline_path, threshold=0.20, speedups=None):
    """Warn when a grid row is >20% below the checked-in baseline for the
    identical configuration; returns the warned rows, each tagged with
    ``"kind"`` so callers can gate selectively.

    Two signals: absolute requests/sec (``kind="throughput"``,
    machine-DEPENDENT — a slower runner trips it without any code change,
    so it only ever warns) and, when both sides carry them, the
    ``speedup_vs_legacy`` ratios (``kind="speedup"``) — machine-
    independent, since fused and legacy engines run on the same box, so a
    drop there is a genuine code-path regression and the one signal
    ``--fail-on-regression`` hard-gates on."""
    if not os.path.exists(baseline_path):
        print(f"NOTE,no baseline at {baseline_path}, skipping regression check")
        return []
    with open(baseline_path) as fh:
        base_metrics = json.load(fh)["metrics"]
    base = {
        tuple(_row_key(r)): r["requests_per_s"]
        for r in base_metrics["rows"]
    }
    base_speedups = {
        tuple(_speedup_key(r)): r["speedup_vs_legacy"]
        for r in base_metrics.get("speedups", [])
    }
    warned, matched = [], 0
    for row in speedups or []:
        ref = base_speedups.get(tuple(_speedup_key(row)))
        if ref is None or ref <= 0:
            continue
        ratio = row["speedup_vs_legacy"] / ref
        if ratio < 1.0 - threshold:
            warned.append({"kind": "speedup", **row})
            print(
                "WARNING,engine_speedup_regression,"
                f"{row['policy']}/di={row['daemon_interval']}/"
                f"nk={row['num_keys']},"
                f"now={row['speedup_vs_legacy']:.2f}x,baseline={ref:.2f}x,"
                f"ratio={ratio:.2f}",
                flush=True,
            )
    for row in rows:
        ref = base.get(tuple(_row_key(row)))
        if ref is None or ref <= 0:
            continue
        matched += 1
        ratio = row["requests_per_s"] / ref
        if ratio < 1.0 - threshold:
            warned.append({"kind": "throughput", **row})
            print(
                "WARNING,engine_throughput_regression,"
                f"{row['engine']}/{row['policy']}/{row['replay_backend']},"
                f"now={row['requests_per_s']:.0f},baseline={ref:.0f},"
                f"ratio={ratio:.2f} (absolute req/s — machine-dependent)",
                flush=True,
            )
    if matched == 0:
        # An all-clear here would hide a drifted sweep config silently
        # disabling the check.
        print(
            f"WARNING,engine_throughput_baseline_mismatch,0 of {len(rows)} "
            f"grid rows matched {baseline_path} — regression check did not "
            f"run (sweep config drifted from the checked-in baseline?)",
            flush=True,
        )
    elif not warned:
        print(
            f"NOTE,engine_throughput within 20% of baseline "
            f"({matched} rows compared)",
            flush=True,
        )
    return warned


def main(
    num_requests: int = 200_000,
    repeats: int = 5,
    daemon_intervals=(1000,),
    num_keys_grid=(1_000, 10_000),
    policy_specs=("replicated", "redynis"),
    backends=("jax",),
    engines=("scan", "legacy"),
    telemetry_modes=(True, False),
    acceptance: bool = False,
    baseline: str | None = DEFAULT_BASELINE,
    policy=None,
    replay_backend: str | None = None,
    fail_on_regression: bool = False,
) -> dict:
    banner("engine_throughput: simulator requests/sec, fused vs pre-fusion")
    if replay_backend is not None:
        # benchmarks/run.py forwards a single --replay-backend; measure
        # that backend only.
        backends = (replay_backend,)
    if "jax" not in backends:
        # speedup_vs_legacy compares legacy/jax against scan/jax; without
        # a jax scan row the legacy timings would be dead weight.
        engines = tuple(e for e in engines if e != "legacy")
    cluster = wan5_cluster()
    telem_cfg = TelemetryConfig()
    rows, speedups = [], []
    t_start = time.perf_counter()

    candidates = [parse_policy(s) for s in policy_specs]
    if policy is not None:
        candidates.append(policy)

    for pol in candidates:
        label = getattr(type(pol), "name", type(pol).__name__)
        label = f"{label}:{pol.mode}" if hasattr(pol, "mode") else label
        for di in daemon_intervals:
            for nk in num_keys_grid:
                wl = _wan5_workload(num_requests, nk)
                for telem_on in telemetry_modes:
                    telem = telem_cfg if telem_on else None
                    times = {}
                    for engine in engines:
                        bkds = backends if engine == "scan" else ("jax",)
                        for bk in bkds:
                            med, lo = _measure(
                                engine, pol, wl, cluster, di, telem, bk,
                                repeats,
                            )
                            times[(engine, bk)] = lo
                            row = {
                                "engine": engine, "policy": label,
                                "replay_backend": bk, "daemon_interval": di,
                                "num_keys": nk, "telemetry": telem_on,
                                "num_requests": num_requests,
                                "wall_s": med,
                                "wall_s_min": lo,
                                "requests_per_s": num_requests / med,
                            }
                            rows.append(row)
                            emit(
                                "engine_throughput",
                                round(row["requests_per_s"]),
                                "req/s",
                                engine=engine, policy=label, backend=bk,
                                daemon_interval=di, num_keys=nk,
                                telemetry=int(telem_on),
                                wall_s=round(med, 4),
                            )
                    if ("legacy", "jax") in times and ("scan", "jax") in times:
                        speedup = times[("legacy", "jax")] / times[("scan", "jax")]
                        speedups.append({
                            "policy": label, "daemon_interval": di,
                            "num_keys": nk, "telemetry": telem_on,
                            "num_requests": num_requests,
                            "speedup_vs_legacy": speedup,
                        })
                        emit(
                            "engine_speedup", round(speedup, 2), "x",
                            policy=label, daemon_interval=di, num_keys=nk,
                            telemetry=int(telem_on),
                        )

    accept = None
    if acceptance:
        # ISSUE-5 acceptance: wan5, skewed, 1M requests, telemetry ON, the
        # paper's access density (100 accesses/key) held at scale. Both
        # daemon cadences are reported; speedups are ratios of per-side
        # minima (see _measure).
        banner("acceptance: 1M-request warm run_scenario vs pre-fusion engine")
        a_req = 1_000_000
        wl = _wan5_workload(a_req, a_req // 100)
        accept = {"num_requests": a_req, "num_keys": a_req // 100,
                  "telemetry": True, "rows": []}
        for di in (1000, 500):
            for spec in policy_specs:
                pol = parse_policy(spec)
                _, t_new = _measure("scan", pol, wl, cluster, di, telem_cfg,
                                    "jax", repeats)
                _, t_old = _measure("legacy", pol, wl, cluster, di, telem_cfg,
                                    "jax", repeats)
                speedup = t_old / t_new
                accept["rows"].append({
                    "policy": spec, "daemon_interval": di,
                    "fused_wall_s": t_new, "legacy_wall_s": t_old,
                    "fused_req_per_s": a_req / t_new,
                    "legacy_req_per_s": a_req / t_old,
                    "speedup_vs_legacy": speedup,
                })
                emit(
                    "engine_acceptance", round(speedup, 2), "x", policy=spec,
                    daemon_interval=di,
                    fused_req_per_s=round(a_req / t_new),
                    legacy_req_per_s=round(a_req / t_old),
                )
        best = max(v["speedup_vs_legacy"] for v in accept["rows"])
        accept["passed"] = best >= 2.0
        print(
            f"ACCEPTANCE,{'PASS' if accept['passed'] else 'FAIL'},"
            f"best_speedup={best:.2f}x (need >= 2x)",
            flush=True,
        )

    warned = (
        check_regression(rows, baseline, speedups=speedups) if baseline else []
    )
    metrics = {
        "rows": rows,
        "speedups": speedups,
        "regressions": len(warned),
        "wall_time_s": time.perf_counter() - t_start,
    }
    if accept is not None:
        metrics["acceptance"] = accept
    write_bench_json(
        "engine_throughput", metrics,
        num_requests=num_requests, repeats=repeats,
        backend_platform=jax.default_backend(),
        topology="wan5", skewed=True, read_fraction=0.9,
    )
    if fail_on_regression:
        hard = [w for w in warned if w.get("kind") == "speedup"]
        if hard:
            raise SystemExit(
                f"FAIL,engine_speedup_regression,{len(hard)} fused-vs-legacy "
                f"speedup ratio(s) >20% below baseline (machine-independent "
                f"signal; see WARNING lines above)"
            )
    return metrics


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--num-requests", type=int, default=200_000)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--daemon-intervals", nargs="+", type=int, default=[1000])
    ap.add_argument("--num-keys", nargs="+", type=int, default=[1_000, 10_000])
    ap.add_argument(
        "--policies", nargs="+", default=["replicated", "redynis"],
        metavar="NAME[:k=v,...]",
    )
    ap.add_argument(
        "--backends", nargs="+", default=["jax"], choices=["jax", "pallas"],
        help="chunk-replay backends for the scan engine (pallas is "
        "interpret-mode off-TPU: correctness row, not a perf row)",
    )
    ap.add_argument(
        "--engines", nargs="+", default=["scan", "legacy"],
        choices=["scan", "legacy"],
    )
    ap.add_argument(
        "--telemetry", choices=["on", "off", "both"], default="both"
    )
    ap.add_argument("--acceptance", action="store_true",
                    help="run the 1M-request ISSUE-5 acceptance comparison")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="checked-in BENCH json to warn against "
                    "('' disables)")
    ap.add_argument(
        "--fail-on-regression", action="store_true",
        help="exit nonzero when a fused-vs-legacy speedup ratio regresses "
        ">20% vs the baseline (absolute req/s stays warn-only: it is "
        "machine-dependent)",
    )
    args = ap.parse_args()
    main(
        num_requests=args.num_requests,
        repeats=args.repeats,
        daemon_intervals=tuple(args.daemon_intervals),
        num_keys_grid=tuple(args.num_keys),
        policy_specs=tuple(args.policies),
        backends=tuple(args.backends),
        engines=tuple(args.engines),
        telemetry_modes={
            "on": (True,), "off": (False,), "both": (True, False)
        }[args.telemetry],
        acceptance=args.acceptance,
        baseline=args.baseline or None,
        fail_on_regression=args.fail_on_regression,
    )
